//! Sparsity sweep (the Fig. 20 experiment as a library example).
//!
//! Sweeps uniformly random tensor sparsity from 10% to 90% on one layer
//! geometry and prints achieved vs ideal speedup for all three training
//! convolutions, plus a depth-2 vs depth-3 comparison (Fig. 19's
//! trade-off) on the same tensors.
//!
//! Run: `cargo run --release --example sparsity_sweep`

use tensordash::config::ChipConfig;
use tensordash::conv::{ConvShape, TrainOp};
use tensordash::repro::simulate_layer_op;
use tensordash::trace::synthetic::random_bitmap;
use tensordash::util::rng::Rng;

fn main() {
    let shape = ConvShape::conv(4, 28, 28, 128, 128, 3, 1, 1);
    let mut rng = Rng::new(1);
    println!("layer: 28x28x128 -> 128, 3x3, batch-equivalent 64\n");
    println!(
        "{:>8} {:>7} {:>7} | {:>6} {:>6} {:>6} | {:>8} {:>8}",
        "sparsity", "ideal", "cap3", "A*W", "A*G", "W*G", "depth3", "depth2"
    );
    for lvl in 1..=9 {
        let sp = lvl as f64 / 10.0;
        let a = random_bitmap((4, 28, 28, 128), sp, &mut rng);
        let g = random_bitmap((4, 28, 28, 128), sp, &mut rng);
        let cfg3 = ChipConfig::default();
        let cfg2 = ChipConfig::default().with_depth(2);
        let mut sps = [0.0; 3];
        for op in TrainOp::ALL {
            let r = simulate_layer_op(&cfg3, &shape, op, &a, &g, 6, 16, &mut rng);
            sps[op as usize] = r.speedup();
        }
        let d3 = simulate_layer_op(&cfg3, &shape, TrainOp::Fwd, &a, &g, 6, 16, &mut rng);
        let d2 = simulate_layer_op(&cfg2, &shape, TrainOp::Fwd, &a, &g, 6, 16, &mut rng);
        println!(
            "{:>7.0}% {:>7.2} {:>7.2} | {:>6.2} {:>6.2} {:>6.2} | {:>8.2} {:>8.2}",
            sp * 100.0,
            1.0 / (1.0 - sp),
            (1.0 / (1.0 - sp)).min(3.0),
            sps[0],
            sps[1],
            sps[2],
            d3.speedup(),
            d2.speedup(),
        );
        assert!(d2.speedup() <= 2.01, "depth-2 cap violated");
        assert!(sps.iter().all(|&s| s <= 3.01), "depth-3 cap violated");
    }
    println!("\nsparsity_sweep OK");
}
