//! Sparsity sweep (the Fig. 20 experiment as a library example), driven
//! entirely through the typed `api::` pipeline.
//!
//! Sweeps uniformly random tensor sparsity from 10% to 90% on one layer
//! geometry and reports achieved vs ideal speedup for all three training
//! convolutions, plus a depth-2 vs depth-3 comparison (Fig. 19's
//! trade-off) on independently drawn tensors per level. The whole sweep
//! is a batch of `SimRequest`s executed on the `Engine` worker pool —
//! identical results at any `--jobs`-style worker count — and the final
//! `Report` is printed as a table *and* dumped as JSON to show the
//! machine-readable path.
//!
//! Run: `cargo run --release --example sparsity_sweep`

use tensordash::api::{derive_seed, Cell, Engine, Report, SimRequest};
use tensordash::config::ChipConfig;
use tensordash::conv::{ConvShape, TrainOp};

fn main() {
    let shape = ConvShape::conv(4, 28, 28, 128, 128, 3, 1, 1);
    let seed = 1u64;
    let engine = Engine::parallel();
    println!(
        "layer: 28x28x128 -> 128, 3x3, batch-equivalent 64 ({} workers)\n",
        engine.jobs()
    );

    // Two requests per sparsity level: the depth-3 chip (Fig. 20) and
    // the depth-2 variant (Fig. 19's cheaper point), same seed so both
    // see identical tensors.
    let cfg3 = ChipConfig::default();
    let cfg2 = ChipConfig::default().with_depth(2);
    let mut reqs: Vec<SimRequest> = Vec::new();
    for lvl in 1..=9u64 {
        let sp = lvl as f64 / 10.0;
        let s = derive_seed(seed, lvl - 1);
        reqs.push(SimRequest::random_sparse(shape, sp, 1, 16, cfg3.clone(), 6, s));
        reqs.push(SimRequest::random_sparse(shape, sp, 1, 16, cfg2.clone(), 6, s));
    }
    let sims = engine.run_all(&reqs);

    let mut r = Report::new(
        "sparsity_sweep",
        "Sparsity sweep — random tensors, depth 3 vs depth 2",
        &["sparsity", "ideal", "cap3", "A*W", "A*G", "W*G", "depth3", "depth2"],
    );
    for lvl in 1..=9usize {
        let sp = lvl as f64 / 10.0;
        let d3 = &sims[(lvl - 1) * 2];
        let d2 = &sims[(lvl - 1) * 2 + 1];
        let sps: Vec<f64> = TrainOp::ALL.iter().map(|&op| d3.op_speedup(op)).collect();
        r.row(vec![
            Cell::fmt(format!("{:.0}%", sp * 100.0), sp),
            Cell::num(1.0 / (1.0 - sp)),
            Cell::num((1.0 / (1.0 - sp)).min(3.0)),
            Cell::num(sps[0]),
            Cell::num(sps[1]),
            Cell::num(sps[2]),
            Cell::num(d3.overall_speedup()),
            Cell::num(d2.overall_speedup()),
        ]);
        assert!(d2.overall_speedup() <= 2.01, "depth-2 cap violated");
        assert!(sps.iter().all(|&s| s <= 3.01), "depth-3 cap violated");
    }
    r.print();

    // The same report, machine-readable — what `--format json` emits.
    println!("\nreport as tensordash.report.v1 JSON:\n{}", r.render_json());

    // Determinism spot check: a serial engine reproduces the pool's
    // results byte-for-byte.
    let serial = Engine::serial().run_all(&reqs);
    for (a, b) in sims.iter().zip(&serial) {
        assert_eq!(a.per_op, b.per_op, "worker count changed a result");
    }
    println!("\nsparsity_sweep OK");
}
