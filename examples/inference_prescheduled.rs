//! §3.6 — the scheduler as a memory-compression engine (inference).
//!
//! Demonstrates storing tensors in *scheduled* `(value, idx)` form:
//! a fully-connected layer's weights are pre-scheduled offline (the
//! static analogue of the hardware scheduler), the activations are
//! compressed by the back-side scheduler (§3.7) as they are produced,
//! and both are expanded (Fig. 12) back to dense form before the PE —
//! with the round trip verified bit-exact and the footprint /
//! access-count savings reported.
//!
//! Run: `cargo run --release --example inference_prescheduled`

use tensordash::sim::memory::scheduled_row_reads;
use tensordash::sim::Connectivity;
use tensordash::tensor::{compress_one_side, decompress};
use tensordash::util::rng::Rng;

fn sparse_rows(n: usize, sparsity: f64, rng: &mut Rng) -> Vec<[f32; 16]> {
    (0..n)
        .map(|_| {
            let mut row = [0f32; 16];
            for v in row.iter_mut() {
                if !rng.chance(sparsity) {
                    *v = rng.normal() as f32;
                }
            }
            row
        })
        .collect()
}

fn main() {
    let conn = Connectivity::new(3);
    let mut rng = Rng::new(9);

    println!("FC layer 1024 -> 256, weights pruned to 75% sparsity\n");
    // One filter's weight stream: 1024/16 = 64 rows.
    let weights = sparse_rows(64, 0.75, &mut rng);
    let sched_w = compress_one_side(&conn, &weights);
    let back_w = decompress(&conn, &sched_w);
    assert_eq!(back_w, weights, "weight round trip");
    println!(
        "weights:     {:>3} dense rows -> {:>3} scheduled rows ({:.2}x compression)",
        sched_w.dense_rows,
        sched_w.rows.len(),
        sched_w.compression()
    );

    // Activations at a typical 55% post-ReLU sparsity, compressed by the
    // back-side scheduler as the previous layer emits them (§3.7).
    let acts = sparse_rows(64, 0.55, &mut rng);
    let sched_a = compress_one_side(&conn, &acts);
    assert_eq!(decompress(&conn, &sched_a), acts, "activation round trip");
    println!(
        "activations: {:>3} dense rows -> {:>3} scheduled rows ({:.2}x compression)",
        sched_a.dense_rows,
        sched_a.rows.len(),
        sched_a.compression()
    );

    // On-chip access savings (§3.6): scheduled reads vs dense reads.
    let dense_reads = 64u64;
    let w_reads = scheduled_row_reads(dense_reads, 0.25);
    let a_reads = scheduled_row_reads(dense_reads, 0.45);
    println!(
        "\nSRAM row reads per filter: dense {dense_reads}, scheduled weights {w_reads}, \
         scheduled activations {a_reads}"
    );

    // The structural cap: compression never exceeds the staging depth.
    assert!(sched_w.compression() <= 3.0 + 1e-9);
    assert!(sched_a.compression() <= 3.0 + 1e-9);
    // At 75% weight sparsity the scheduler should get close to the cap.
    assert!(
        sched_w.compression() > 2.2,
        "weight compression {:.2} unexpectedly low",
        sched_w.compression()
    );
    println!("\ninference_prescheduled OK");
}
