//! Experiment history in ~40 lines: ingest two "commits" of a figure
//! into the persistent store, query a metric's trajectory, diff them.
//!
//! Simulates alexnet at two training epochs (standing in for the same
//! experiment re-run at two commits of the repo), ingests both reports
//! into one single-file record log, then prints:
//!
//! * the record catalog,
//! * the `overall` speedup trajectory across the two commits,
//! * the per-metric commit-to-commit diff.
//!
//! Run: `cargo run --release --example store_trajectory`
//! (same result as two `tensordash store ingest` runs followed by
//!  `store query --metric overall` and `store diff`)

use tensordash::api::{Engine, SimRequest};
use tensordash::config::ChipConfig;
use tensordash::repro;
use tensordash::store::{ExperimentStore, QueryFilter};
use tensordash::util::json::Json;

fn fig13_at(epoch: f64) -> Json {
    let engine = Engine::parallel();
    let req = SimRequest::profile("alexnet", epoch, ChipConfig::default(), 1, 42)
        .expect("known model");
    let report = repro::fig13(&[engine.run(&req)]);
    println!("simulated alexnet at epoch {epoch} ({} rows)", report.rows.len());
    Json::parse(&report.render_json()).expect("report JSON parses")
}

fn main() {
    let db = std::env::temp_dir().join(format!("td_trajectory_{}.tdstore", std::process::id()));
    let _ = std::fs::remove_file(&db);

    // 1. Ingest the same experiment from two points in its history.
    let mut store = ExperimentStore::open(&db).expect("store opens");
    store.ingest_json(&fig13_at(0.1), "commit-early").expect("ingest");
    store.ingest_json(&fig13_at(0.9), "commit-late").expect("ingest");
    store.commit().expect("fsync + index");

    // 2. Catalog: what the store holds, one row per record.
    store.query(&QueryFilter::default()).expect("catalog").print();

    // 3. Trajectory: one metric followed across commits.
    let f = QueryFilter { metric: Some("overall".to_string()), ..QueryFilter::default() };
    store.query(&f).expect("trajectory").print();

    // 4. Diff: per-metric deltas between the two commits.
    store.diff("fig13", "commit-early", "commit-late").expect("diff").print();

    let _ = std::fs::remove_file(&db);
}
