//! End-to-end driver: REAL training through the full three-layer stack.
//!
//! Trains the AOT-compiled CNN (python/compile/model.py — explicit
//! Eq.4/6/8 fwd/bwd through the Pallas kernels) for a few hundred SGD
//! steps on the synthetic classification workload, entirely from rust
//! via PJRT. Every step returns the per-layer zero bitmaps computed
//! on-device by the Pallas `zero_bitmap16` kernel; periodically a
//! `SimRequest::trace` through the `api::Engine` projects the
//! speedup/energy the accelerator would achieve on those *real*
//! tensors, and the trajectory is emitted as a structured `Report`
//! (table + JSON) at the end.
//!
//! This is the EXPERIMENTS.md §E2E run:
//!   make artifacts && cargo run --release --example train_e2e [steps]

use tensordash::api::{Cell, Engine, Report, SimRequest};
use tensordash::config::ChipConfig;
use tensordash::coordinator::data::DataGen;
use tensordash::coordinator::Trainer;
use tensordash::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("steps must be an integer"))
        .unwrap_or(300);
    let seed = 42u64;

    let rt = Runtime::new("artifacts")?;
    println!("PJRT platform: {}", rt.platform());
    let mut trainer = Trainer::new(&rt, seed as i32)?;
    let (n, h, w, c) = trainer.meta.input;
    println!(
        "model: {} conv layers + FC, batch {}, input {}x{}x{}, {} classes, lr {}",
        trainer.meta.convs.len(),
        n,
        h,
        w,
        c,
        trainer.meta.classes,
        trainer.meta.lr
    );
    let mut data = DataGen::new(h, w, c, trainer.meta.classes, seed);
    let shapes = trainer.meta.convs.clone();
    let cfg = ChipConfig::default();
    let engine = Engine::parallel();

    println!(
        "\n{:>5} {:>9} {:>6} {:>8} {:>8} {:>9}",
        "step", "loss", "acc", "A-spars", "G-spars", "speedup"
    );
    let mut first_loss = None;
    let mut last_loss = 0.0;
    let mut trajectory = Report::new(
        "train_e2e",
        "E2E — TensorDash projection on real training tensors",
        &["step", "loss", "accuracy", "A sparsity", "G sparsity", "speedup"],
    );
    for step in 1..=steps {
        let (x, y) = data.batch(n);
        let out = trainer.step(&x, &y)?;
        first_loss.get_or_insert(out.loss);
        last_loss = out.loss;
        if step == 1 || step % 25 == 0 || step == steps {
            let (sa, sg) = out.trace.mean_sparsity();
            let req = SimRequest::trace(
                &trainer.meta.name,
                shapes.clone(),
                out.trace.layers.clone(),
                cfg.clone(),
                6,
                seed,
            );
            let sim = engine.run(&req);
            println!(
                "{:>5} {:>9.4} {:>6.3} {:>8.3} {:>8.3} {:>8.2}x",
                step,
                out.loss,
                out.accuracy,
                sa,
                sg,
                sim.overall_speedup()
            );
            trajectory.row(vec![
                Cell::fmt(step.to_string(), step as f64),
                Cell::fmt(format!("{:.4}", out.loss), out.loss as f64),
                Cell::fmt(format!("{:.3}", out.accuracy), out.accuracy as f64),
                Cell::num(sa),
                Cell::num(sg),
                Cell::num(sim.overall_speedup()),
            ]);
        }
    }

    let first = first_loss.unwrap();
    println!("\nloss: {first:.4} -> {last_loss:.4}");
    anyhow::ensure!(
        last_loss < first * 0.5,
        "training did not converge (loss {first} -> {last_loss})"
    );
    let final_speedup = trajectory
        .value(trajectory.rows.len() - 1, "speedup")
        .expect("trajectory has at least the final step");
    println!("TensorDash projection on the trained model's real tensors: {final_speedup:.2}x");
    trajectory.print();
    println!("\ntrajectory as JSON:\n{}", trajectory.render_json());
    println!("\ntrain_e2e OK — all three layers compose");
    Ok(())
}
