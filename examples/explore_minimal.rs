//! Minimal design-space exploration: ~30 lines from axes to frontier.
//!
//! Builds a small search space over staging depth × tile rows, runs
//! the seeded successive-halving explorer against alexnet through a
//! cache-backed engine, and prints the Pareto frontier
//! (`tensordash.frontier.v1`) plus the cache telemetry that makes
//! repeated evaluation cheap.
//!
//! Run: `cargo run --release --example explore_minimal`
//! (same result as `tensordash explore --models alexnet
//!  --axis staging_depth=2,3 --axis tile_rows=2,4,8 --budget 6`)

use std::sync::Arc;

use tensordash::api::{Engine, UnitCache, DEFAULT_CACHE_CAP};
use tensordash::search::{run, ExploreSpec, SearchSpace};

fn main() {
    // 1. The space: two free axes, everything else pinned at Table 2.
    let mut space = SearchSpace::trivial();
    space.set_axis("staging_depth", &["2", "3"]).expect("valid axis values");
    space.set_axis("tile_rows", &["2", "4", "8"]).expect("valid axis values");
    println!("space: {} candidate configurations", space.size());

    // 2. The spec: what to evaluate, the budget, and the seed that
    //    makes the whole search byte-reproducible.
    let spec = ExploreSpec::new(space, &["alexnet"], 0.4, 2, 42, 6).expect("known model");

    // 3. A cache-backed engine: survivors re-evaluate as pure cache
    //    hits, so the halving loop only pays for new design points.
    let cache = Arc::new(UnitCache::new(DEFAULT_CACHE_CAP));
    let engine = Engine::parallel().with_cache(Arc::clone(&cache));

    let (res, report) = run(&engine, &spec);
    report.print();

    let s = cache.stats();
    println!(
        "\n{} evaluations over {} generations; cache {} hits / {} misses \
         ({:.0}% of unit lookups served without simulating)",
        res.evaluated.len(),
        res.generations,
        s.hits,
        s.misses,
        s.hit_rate() * 100.0
    );
    assert!(res.depth_ordered, "fig-19 ordering must hold on the depth slice");
}
