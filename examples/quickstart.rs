//! Quickstart: the three-layer stack in ~60 lines.
//!
//! 1. Load an AOT-compiled Pallas kernel (the 16-lane matmul) through the
//!    PJRT runtime and check its numerics from rust.
//! 2. Run one convolution layer through the TensorDash cycle simulator
//!    at 60% activation sparsity via the typed `api::` pipeline (one
//!    `SimRequest` per training op, executed on the `Engine`) and print
//!    the projected speedup.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use tensordash::api::{Engine, SimRequest};
use tensordash::config::ChipConfig;
use tensordash::conv::{ConvShape, TrainOp};
use tensordash::runtime::{literal_f32, to_f32, Runtime};
use tensordash::trace::synthetic::clustered_bitmap;
use tensordash::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // --- 1. the AOT Pallas kernel through PJRT --------------------------
    let rt = Runtime::new("artifacts")?;
    println!("PJRT platform: {}", rt.platform());
    let matmul = rt.load("matmul")?;

    let mut rng = Rng::new(7);
    let a: Vec<f32> = (0..64 * 64).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..64 * 64).map(|_| rng.normal() as f32).collect();
    let out = matmul.run(&[literal_f32(&[64, 64], &a)?, literal_f32(&[64, 64], &b)?])?;
    let got = to_f32(&out[0])?;

    // Reference matmul in plain rust.
    let mut want = vec![0f32; 64 * 64];
    for i in 0..64 {
        for k in 0..64 {
            let av = a[i * 64 + k];
            for j in 0..64 {
                want[i * 64 + j] += av * b[k * 64 + j];
            }
        }
    }
    let max_err = got
        .iter()
        .zip(&want)
        .map(|(g, w)| (g - w).abs())
        .fold(0f32, f32::max);
    println!("pallas matmul vs rust reference: max |err| = {max_err:.2e}");
    assert!(max_err < 1e-3, "kernel numerics mismatch");

    // --- 2. one layer through the TensorDash simulator ------------------
    let shape = ConvShape::conv(4, 28, 28, 128, 128, 3, 1, 1);
    let a_bm = clustered_bitmap((4, 28, 28, 128), 0.60, 0.35, &mut rng);
    let g_bm = clustered_bitmap((4, 28, 28, 128), 0.70, 0.35, &mut rng);
    let cfg = ChipConfig::default();
    println!(
        "\nlayer {}x{}x{} -> {} (3x3), A sparsity {:.2}, G sparsity {:.2}",
        shape.h,
        shape.w,
        shape.c,
        shape.f,
        a_bm.sparsity(),
        g_bm.sparsity()
    );
    let engine = Engine::parallel();
    let reqs: Vec<SimRequest> = TrainOp::ALL
        .iter()
        .map(|&op| {
            SimRequest::single_op(
                op.label(),
                shape,
                op,
                a_bm.clone(),
                g_bm.clone(),
                16,
                cfg.clone(),
                6,
                7 + op as u64,
            )
        })
        .collect();
    for (op, sim) in TrainOp::ALL.iter().zip(engine.run_all(&reqs)) {
        let (base, td) = sim.per_op[*op as usize];
        println!(
            "  {:<4} speedup {:.2}x  (baseline {} cycles -> TensorDash {})",
            op.label(),
            sim.op_speedup(*op),
            base,
            td
        );
    }
    println!("\nquickstart OK");
    Ok(())
}
