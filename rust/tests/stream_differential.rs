//! Differential property tests: the cached/skipping streaming core
//! (`sim::stream`) must be **byte-identical** to the naive uncached
//! reference on everything the simulator observes — cycle counts, MAC
//! counts, stall counts and `ScheduledTensor` contents — over random
//! streams at depths 2 and 3, densities 0–100%, and adversarial
//! memo-table collision keys.
//!
//! CI refuses to pass if these tests are filtered out or skipped (the
//! workflow counts them via `--list` before running this binary).

use tensordash::sim::connectivity::{Connectivity, LANES, MAX_DEPTH};
use tensordash::sim::pe::simulate_stream_stats;
use tensordash::sim::scheduler::{schedule_cycle, IDLE};
use tensordash::sim::stream::{memo_index, memo_key, reference, CachedScheduler};
use tensordash::sim::tile::tile_pass_stats;
use tensordash::tensor::scheduled::{ScheduledRow, ScheduledTensor};
use tensordash::tensor::{compress_one_side, decompress};
use tensordash::util::rng::Rng;

/// The pre-refactor compression loop, kept verbatim as the differential
/// baseline for [`compress_one_side`] (the production copy now rides
/// `sim::stream::drive`; the sim-side reference loops live in
/// `sim::stream::reference`).
fn compress_one_side_reference(conn: &Connectivity, dense: &[[f32; LANES]]) -> ScheduledTensor {
    let depth = conn.depth;
    let n = dense.len();
    let mut rows = Vec::new();
    if n == 0 {
        return ScheduledTensor { rows, dense_rows: 0, depth };
    }
    let mut pos = 0usize;
    let mut win = [0u16; MAX_DEPTH];
    let mut loaded = 0usize;
    let mask_of = |row: &[f32; LANES]| -> u16 {
        let mut m = 0u16;
        for (l, &v) in row.iter().enumerate() {
            if v != 0.0 {
                m |= 1 << l;
            }
        }
        m
    };
    while loaded < depth && pos + loaded < n {
        win[loaded] = mask_of(&dense[pos + loaded]);
        loaded += 1;
    }
    while loaded > 0 {
        let mut z = 0u64;
        for (s, w) in win.iter().enumerate().take(loaded) {
            z |= (*w as u64) << (s * LANES);
        }
        let sched = schedule_cycle(conn, z);
        let mut out = ScheduledRow { values: [0.0; LANES], idx: [IDLE; LANES], advance: 0 };
        for lane in 0..LANES {
            let m = sched.ms[lane];
            if m == IDLE {
                continue;
            }
            let bit = conn.lanes[lane].bits[m as usize] as usize;
            let (step, src_lane) = (bit / LANES, bit % LANES);
            out.values[lane] = dense[pos + step][src_lane];
            out.idx[lane] = m;
        }
        for (s, w) in win.iter_mut().enumerate().take(loaded) {
            *w &= !((sched.picks >> (s * LANES)) as u16);
        }
        let adv = (sched.advance as usize).min(loaded);
        out.advance = adv as u8;
        rows.push(out);
        win.copy_within(adv..loaded, 0);
        pos += adv;
        loaded -= adv;
        while loaded < depth && pos + loaded < n {
            win[loaded] = mask_of(&dense[pos + loaded]);
            loaded += 1;
        }
    }
    ScheduledTensor { rows, dense_rows: n, depth }
}

/// A stream with both uniform-random and engineered-run structure.
fn mixed_stream(rng: &mut Rng, len: usize, density: f64) -> Vec<u16> {
    let mut rows = Vec::with_capacity(len);
    while rows.len() < len {
        match rng.below(5) {
            // zero run (exercises skip batching)
            0 => {
                for _ in 0..=rng.below(9) {
                    rows.push(0);
                }
            }
            // dense run (exercises the dense-head fast path)
            1 => {
                for _ in 0..=rng.below(4) {
                    rows.push(0xFFFF);
                }
            }
            // uniform random at the requested density
            _ => rows.push(rng.mask16(density)),
        }
    }
    rows.truncate(len);
    rows
}

/// PE streams: cached/skipping core == naive reference, cycle- and
/// MAC-exact, across depths and the full density range.
#[test]
fn diff_pe_streams_all_densities() {
    for depth in [2usize, 3] {
        let conn = Connectivity::new(depth);
        let mut rng = Rng::new(0xD1FF + depth as u64);
        for pct in 0..=20 {
            let density = pct as f64 / 20.0;
            for trial in 0..30 {
                let len = rng.below(90) + usize::from(trial % 3 == 0) * 200;
                let rows = mixed_stream(&mut rng, len, density);
                let new = simulate_stream_stats(&conn, &rows);
                let old = reference::simulate_stream_stats(&conn, &rows);
                assert_eq!(new.cycles, old.cycles, "cycles d={depth} density={density}");
                assert_eq!(new.macs, old.macs, "macs d={depth} density={density}");
                // Telemetry identity: every cycle is skipped or answered
                // exactly once, and the cache only ever *saves* walks.
                assert_eq!(
                    new.cycles - new.skipped_cycles,
                    new.schedules + new.cache_hits + new.fast_paths
                );
                assert!(new.schedules <= old.schedules);
            }
        }
    }
}

/// Tile passes: identical cycles, MACs and imbalance stalls for every
/// lead bound, with rows of heterogeneous density.
#[test]
fn diff_tile_passes() {
    for depth in [2usize, 3] {
        let conn = Connectivity::new(depth);
        let mut rng = Rng::new(0x711D + depth as u64);
        for trial in 0..60 {
            let n_rows = 1 + rng.below(6);
            let len = 4 + rng.below(50);
            let streams: Vec<Vec<u16>> = (0..n_rows)
                .map(|_| {
                    let d = rng.f64();
                    mixed_stream(&mut rng, len, d)
                })
                .collect();
            for lead in [0usize, 2, 6, 4096] {
                let new = tile_pass_stats(&conn, &streams, lead);
                let old = reference::tile_pass_stats(&conn, &streams, lead);
                assert_eq!(new.cycles, old.cycles, "trial {trial} lead {lead} depth {depth}");
                assert_eq!(new.macs, old.macs);
                assert_eq!(new.imbalance_stall_row_cycles, old.imbalance_stall_row_cycles);
                assert_eq!(new.skipped_cycles, 0, "the tile must not bulk-skip");
                assert!(new.schedules <= old.schedules, "cache added walks?");
            }
        }
    }
}

/// Compression: the `ScheduledTensor` is byte-identical to the
/// reference (values, movement indices, advances) and round-trips.
#[test]
fn diff_compress_round_trips() {
    for depth in [2usize, 3] {
        let conn = Connectivity::new(depth);
        let mut rng = Rng::new(0xC0DE + depth as u64);
        for pct in [0u64, 5, 15, 40, 60, 85, 100] {
            for _ in 0..12 {
                let len = rng.below(70);
                let dense: Vec<[f32; LANES]> = (0..len)
                    .map(|_| {
                        let mut row = [0f32; LANES];
                        for v in row.iter_mut() {
                            if (rng.next_u64() % 100) < pct {
                                *v = (rng.next_u64() % 999 + 1) as f32;
                            }
                        }
                        row
                    })
                    .collect();
                let new = compress_one_side(&conn, &dense);
                let old = compress_one_side_reference(&conn, &dense);
                assert_eq!(new, old, "scheduled form diverged (depth {depth}, density {pct}%)");
                assert_eq!(decompress(&conn, &new), dense, "round trip (depth {depth})");
            }
        }
    }
}

/// Adversarial memo-table collisions: streams whose alternating windows
/// hash to the same direct-mapped slot must thrash the cache without
/// ever producing a stale schedule.
#[test]
fn diff_cache_collision_thrash() {
    for depth in [2usize, 3] {
        // Two distinct non-zero, non-dense 16-bit head masks whose
        // single-row windows collide in the memo table at this depth
        // (the widened key folds the depth in, so the pair is
        // depth-specific).
        let (za, zb) = tensordash::sim::stream::memo_collision_pair(depth);
        let (a, b) = (za as u16, zb as u16);
        assert_eq!(memo_index(memo_key(a as u64, depth)), memo_index(memo_key(b as u64, depth)));
        assert_ne!(a, b);
        let conn = Connectivity::new(depth);
        // [a, 0.., b, 0..] repeated: each scheduled window is exactly
        // `a` or `b` (the zero padding rides the advance), so the two
        // keys alternate in one slot — worst-case eviction pressure.
        let mut rows = Vec::new();
        for _ in 0..64 {
            rows.push(a);
            rows.extend(std::iter::repeat(0).take(depth - 1));
            rows.push(b);
            rows.extend(std::iter::repeat(0).take(depth - 1));
        }
        let new = simulate_stream_stats(&conn, &rows);
        let old = reference::simulate_stream_stats(&conn, &rows);
        assert_eq!(new.cycles, old.cycles, "depth {depth}");
        assert_eq!(new.macs, old.macs, "depth {depth}");

        // And at the scheduler level: alternating lookups of the
        // colliding keys must each re-walk, never return the neighbour's
        // entry.
        let mut cached = CachedScheduler::new(conn.clone());
        for _ in 0..3 {
            assert_eq!(cached.schedule(a as u64), schedule_cycle(&conn, a as u64));
            assert_eq!(cached.schedule(b as u64), schedule_cycle(&conn, b as u64));
        }
        assert_eq!(cached.stats.walks, 6, "direct-mapped thrash must miss every time");
        assert_eq!(cached.stats.hits, 0);
    }
}

/// Engineered zero runs: skipping must engage (not just match) and the
/// cycle counts still agree exactly.
#[test]
fn diff_zero_runs_engage_skipping() {
    for depth in [2usize, 3] {
        let conn = Connectivity::new(depth);
        let mut rng = Rng::new(0x0A11 + depth as u64);
        for run in [8usize, 17, 31, 64] {
            let mut rows: Vec<u16> = (0..5).map(|_| rng.mask16(0.9)).collect();
            rows.extend(vec![0u16; run]);
            rows.extend((0..5).map(|_| rng.mask16(0.9)));
            rows.extend(vec![0u16; run]);
            let new = simulate_stream_stats(&conn, &rows);
            let old = reference::simulate_stream_stats(&conn, &rows);
            assert_eq!(new.cycles, old.cycles, "run {run} depth {depth}");
            assert_eq!(new.macs, old.macs);
            assert!(
                new.skipped_cycles > 0,
                "a {run}-zero run must retire arithmetically (depth {depth})"
            );
        }
    }
}

/// Word-boundary adversaries for the packed (4-rows-per-`u64`) core:
/// zero runs ending exactly at rows 63/64/65, effectual clusters
/// straddling u64 word seams, and all-dense / single-lane masks whose
/// length lands on and around word multiples, at depths 2 and 3 — the
/// cases per-element iteration gets right for free and bit-twiddling
/// gets wrong.
#[test]
fn diff_packed_word_boundaries() {
    for depth in [2usize, 3] {
        let conn = Connectivity::new(depth);
        let mut rng = Rng::new(0x0B17 + depth as u64);

        // Zero runs ending at rows 62..66 and 127..129, with a few
        // effectual lead rows so the run start shifts against the word
        // grid, and dense + single-lane rows after the run so the run
        // boundary never coincides with the stream boundary.
        for end in [62usize, 63, 64, 65, 66, 127, 128, 129] {
            for lead in [0usize, 1, 2, 3, 5] {
                if lead >= end {
                    continue;
                }
                let mut rows: Vec<u16> = (0..lead).map(|_| rng.mask16(0.7) | 1).collect();
                rows.extend(vec![0u16; end - lead]); // run ends at row `end`
                rows.push(0xFFFF);
                rows.push(1 << (end % 16));
                let new = simulate_stream_stats(&conn, &rows);
                let old = reference::simulate_stream_stats(&conn, &rows);
                assert_eq!(new.cycles, old.cycles, "end {end} lead {lead} depth {depth}");
                assert_eq!(new.macs, old.macs, "end {end} lead {lead} depth {depth}");
                assert!(new.skipped_cycles > 0, "the run must engage skipping");
            }
        }

        // Effectual clusters of width 1..3 placed right on word seams
        // (multiples of four rows), zeros on both sides: the window
        // load straddles two words mid-cluster.
        for seam in [4usize, 8, 60, 64, 124, 128] {
            for width in [1usize, 2, 3] {
                let mut rows = vec![0u16; seam - 1];
                for k in 0..width {
                    rows.push(rng.mask16(0.8) | (1 << k));
                }
                rows.extend(vec![0u16; 7]);
                let new = simulate_stream_stats(&conn, &rows);
                let old = reference::simulate_stream_stats(&conn, &rows);
                assert_eq!(new.cycles, old.cycles, "seam {seam} width {width} depth {depth}");
                assert_eq!(new.macs, old.macs, "seam {seam} width {width} depth {depth}");
            }
        }

        // All-dense and single-lane streams of length 63/64/65: the
        // drained-row advance crosses the word seam on the last loads.
        for len in [63usize, 64, 65] {
            let dense = vec![0xFFFFu16; len];
            let lane = vec![1u16 << 9; len];
            for rows in [&dense, &lane] {
                let new = simulate_stream_stats(&conn, rows);
                let old = reference::simulate_stream_stats(&conn, rows);
                assert_eq!(new.cycles, old.cycles, "len {len} depth {depth}");
                assert_eq!(new.macs, old.macs, "len {len} depth {depth}");
            }
        }

        // Tile rows of seam-straddling lengths sharing one scheduler.
        let streams: Vec<Vec<u16>> = vec![
            vec![0u16; 64],
            {
                let mut v = vec![0u16; 63];
                v.push(0xFFFF);
                v
            },
            vec![1u16 << 4; 65],
        ];
        for lead in [0usize, 6] {
            let new = tile_pass_stats(&conn, &streams, lead);
            let old = reference::tile_pass_stats(&conn, &streams, lead);
            assert_eq!(new.cycles, old.cycles, "tile seam lead {lead} depth {depth}");
            assert_eq!(new.macs, old.macs);
            assert_eq!(new.imbalance_stall_row_cycles, old.imbalance_stall_row_cycles);
        }
    }
}
