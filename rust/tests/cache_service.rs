//! Determinism contract of the unit cache and the serving layer.
//!
//! The cache's load-bearing property: a unit result served from the
//! cache is the byte-identical result the cold path would have
//! computed — units are pure functions of their canonical key, and the
//! key captures everything the result depends on. These tests pin:
//!
//! * warm-cache runs are **byte-identical** to cold runs on two models
//!   at `--jobs {1, 4, 8}` — merged sims, per-layer tables, rendered
//!   reports;
//! * one warm cache serves every worker count interchangeably;
//! * the service returns byte-identical `report` bodies for duplicate
//!   *concurrent* requests, computes each unique unit exactly once,
//!   and reports nonzero cache-hit telemetry on a repeat;
//! * overlapping sweep requests (the Fig. 17 `rows4` column is the
//!   Fig. 18 `cols4` column) reuse units across requests;
//! * the lock-striped cache is **invisible**: any `--shards` count ×
//!   any `--jobs` count yields byte-identical results *and* identical
//!   merged hit/miss/insert telemetry;
//! * proportional per-shard caps evict exactly what a single-shard
//!   LRU of the same total capacity would evict — stripes never merge
//!   entries and never drop units the global LRU would keep alive.

use std::sync::Arc;

use tensordash::api::{
    layers_report, Engine, Service, SimRequest, SweepSpec, UnitCache, UnitCacheStats, UnitKey,
    UnitSpec, UnitTensors,
};
use tensordash::config::ChipConfig;
use tensordash::conv::{ConvShape, TrainOp};
use tensordash::repro::ModelSim;
use tensordash::tensor::TensorBitmap;
use tensordash::util::json::Json;

const MODELS: [&str; 2] = ["alexnet", "gcn"];
const SEED: u64 = 42;
const SAMPLES: usize = 1;

fn profile_request(model: &str) -> SimRequest {
    SimRequest::profile(model, 0.4, ChipConfig::default(), SAMPLES, SEED)
        .expect("known model")
}

/// Byte-level equality of two merged sims: every integer counter, every
/// f64 down to its bit pattern, every retained unit.
fn assert_bit_identical(a: &ModelSim, b: &ModelSim, ctx: &str) {
    assert_eq!(a.name, b.name, "{ctx}: name");
    assert_eq!(a.per_op, b.per_op, "{ctx}: per-op cycles");
    assert_eq!(a.sched, b.sched, "{ctx}: scheduler telemetry");
    assert_eq!(
        a.energy_base.total_pj().to_bits(),
        b.energy_base.total_pj().to_bits(),
        "{ctx}: baseline energy bits"
    );
    assert_eq!(
        a.energy_td.total_pj().to_bits(),
        b.energy_td.total_pj().to_bits(),
        "{ctx}: TensorDash energy bits"
    );
    assert_eq!(a.layers, b.layers, "{ctx}: per-unit results");
}

#[test]
fn warm_cache_is_byte_identical_to_cold_at_jobs_1_4_8() {
    for model in MODELS {
        let req = profile_request(model);
        // The uncached engine is the ground truth.
        let reference = Engine::new(1).run(&req);
        for jobs in [1usize, 4, 8] {
            let cache = Arc::new(UnitCache::new(4096));
            let engine = Engine::new(jobs).with_cache(Arc::clone(&cache));
            let cold = engine.run(&req);
            let warm = engine.run(&req);
            let ctx = format!("{model} jobs={jobs}");
            assert_bit_identical(&reference, &cold, &format!("{ctx} cold"));
            assert_bit_identical(&cold, &warm, &format!("{ctx} warm"));
            // Rendered artifacts agree byte for byte too.
            assert_eq!(
                layers_report(&cold).render_json().into_bytes(),
                layers_report(&warm).render_json().into_bytes(),
                "{ctx}: per-layer report bytes"
            );
            // The warm run hit exactly what the cold run missed, and
            // the counters are worker-count independent.
            let s = cache.stats();
            assert_eq!(s.misses as usize, reference.layers.len(), "{ctx}: misses");
            assert_eq!(s.hits, s.misses, "{ctx}: warm hits == cold misses");
            assert_eq!(s.inserts, s.misses, "{ctx}: each miss computed once");
        }
    }
}

#[test]
fn one_warm_cache_serves_every_worker_count() {
    let cache = Arc::new(UnitCache::new(4096));
    let req = profile_request("alexnet");
    let cold = Engine::new(1).with_cache(Arc::clone(&cache)).run(&req);
    for jobs in [4usize, 8] {
        let warm = Engine::new(jobs).with_cache(Arc::clone(&cache)).run(&req);
        assert_bit_identical(&cold, &warm, &format!("shared cache, jobs={jobs}"));
    }
    let s = cache.stats();
    assert_eq!(s.inserts as usize, cold.layers.len(), "units computed once ever");
    assert_eq!(s.hits as usize, 2 * cold.layers.len());
}

#[test]
fn serve_duplicate_concurrent_requests_return_byte_identical_bodies() {
    let service = Service::new(Engine::new(4), Arc::new(UnitCache::new(65_536)));
    let line = concat!(
        r#"{"op":"simulate","id":"dup","model":"alexnet","#,
        r#""epoch":0.4,"samples":1,"seed":42}"#,
    );
    let unit_count = Engine::new(1).run(&profile_request("alexnet")).layers.len() as u64;

    // Four overlapping duplicates on four threads.
    let responses: Vec<String> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                s.spawn(|| {
                    let mut h = service.handle_line(line);
                    assert_eq!(h.lines.len(), 1);
                    h.lines.pop().unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let bodies: Vec<String> = responses
        .iter()
        .map(|l| {
            let j = Json::parse(l).expect("response parses");
            assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "not ok: {l}");
            j.get("report").expect("report present").render()
        })
        .collect();
    for b in &bodies[1..] {
        assert_eq!(b, &bodies[0], "concurrent duplicates must return identical bodies");
    }
    // Whatever the interleaving, each unique unit was computed exactly
    // once — duplicates were served by cache hit or coalescing.
    let s = service.cache().stats();
    assert_eq!(s.inserts, unit_count, "each unit computed once: {s:?}");
    assert_eq!(s.hits + s.misses, 4 * unit_count, "every lookup accounted: {s:?}");

    // A sequential repeat is a pure cache hit with an identical body.
    let before = service.cache().stats();
    let mut repeat = service.handle_line(line);
    let repeat_line = repeat.lines.pop().unwrap();
    let repeat_body = Json::parse(&repeat_line).unwrap().get("report").unwrap().render();
    assert_eq!(repeat_body, bodies[0]);
    let delta = service.cache().stats().since(&before);
    assert_eq!(delta.hits, unit_count, "repeat must be fully cache-served");
    assert_eq!(delta.misses, 0);
}

#[test]
fn overlapping_sweeps_share_units_across_requests() {
    // Fig. 17 sweeps rows x {.., 4} at cols=4; Fig. 18 sweeps cols x
    // {4, ..} at rows=4 — the (4, 4) cell is shared. Model-level
    // version of the same effect: two sweeps overlapping on one model.
    let cache = Arc::new(UnitCache::new(65_536));
    let engine = Engine::new(4).with_cache(Arc::clone(&cache));
    let cfg = ChipConfig::default();
    let first = SweepSpec::models(&["alexnet", "gcn"], 0.4, &cfg, SAMPLES, SEED).cells();
    let second = SweepSpec::models(&["alexnet"], 0.4, &cfg, SAMPLES, SEED).cells();
    let a = engine.run_all(&first);
    let before = cache.stats();
    let b = engine.run_all(&second);
    // The alexnet cell of the second sweep derives the same cell seed
    // (cell index 0 in both grids), so every unit is cache-served.
    let delta = cache.stats().since(&before);
    assert_eq!(delta.misses, 0, "second sweep recomputed units: {delta:?}");
    assert_eq!(delta.hits as usize, b[0].layers.len());
    assert_bit_identical(&a[0], &b[0], "shared sweep cell");
}

#[test]
fn shard_counts_are_invisible_to_results_and_telemetry() {
    let cfg = ChipConfig::default();
    let cells = SweepSpec::models(&MODELS, 0.4, &cfg, SAMPLES, SEED).cells();
    // The uncached single-worker engine is the ground truth.
    let reference = Engine::new(1).run_all(&cells);
    let mut baseline: Option<UnitCacheStats> = None;
    for shards in [1usize, 4, 16] {
        for jobs in [1usize, 8] {
            let cache = Arc::new(UnitCache::with_shards(4096, shards));
            assert_eq!(cache.shard_count(), shards);
            let engine = Engine::new(jobs).with_cache(Arc::clone(&cache));
            let cold = engine.run_all(&cells);
            let warm = engine.run_all(&cells);
            let ctx = format!("shards={shards} jobs={jobs}");
            for ((r, c), w) in reference.iter().zip(&cold).zip(&warm) {
                assert_bit_identical(r, c, &format!("{ctx} cold {}", r.name));
                assert_bit_identical(c, w, &format!("{ctx} warm {}", c.name));
            }
            let stats = cache.stats();
            assert!(stats.hits > 0, "{ctx}: warm run must be cache-served");
            // The merged counters are byte-identical at every shard ×
            // worker combination — the stats-merge rule in action.
            match &baseline {
                None => baseline = Some(stats),
                Some(b) => {
                    assert_eq!(&stats, b, "{ctx}: telemetry must not depend on shards/jobs")
                }
            }
        }
    }
}

#[test]
fn proportional_shard_caps_evict_exactly_like_a_single_shard_lru() {
    let cfg = ChipConfig::default();
    let spec_for = |seed: u64| UnitSpec {
        layer: 0,
        op: TrainOp::Fwd,
        shape: ConvShape::conv(1, 4, 4, 16, 16, 3, 1, 1),
        tensors: UnitTensors::Explicit {
            a: Arc::new(TensorBitmap::from_raw((1, 1, 1, 16), vec![0x00FF])),
            g: Arc::new(TensorBitmap::from_raw((1, 1, 1, 16), vec![0x0F0F])),
        },
        batch_mult: 1,
        samples: 1,
        seed,
    };
    // One tiny computed unit reused as every insert's value — eviction
    // accounting depends only on the keys.
    let sim = spec_for(0).execute(&cfg);
    // 32 keys, two per `hash % 16` stripe in stripe-major order, so a
    // 32-entry cache is exactly full at 1, 4 and 16 shards alike
    // (proportional caps: 32x1, 8x4, 2x16).
    let mut buckets: Vec<Vec<UnitKey>> = (0..16).map(|_| Vec::new()).collect();
    let mut seed = 0u64;
    while buckets.iter().any(|b| b.len() < 2) {
        let key = UnitKey::for_unit(&cfg, &spec_for(seed));
        let b = (key.hash % 16) as usize;
        if buckets[b].len() < 2 {
            buckets[b].push(key);
        }
        seed += 1;
        assert!(seed < 100_000, "FNV bucket fill must converge");
    }
    let keys: Vec<UnitKey> = buckets.into_iter().flatten().collect();
    // A 33rd key in keys[0]'s stripe — at every shard count it lands
    // in the stripe that holds keys[0] (b % 16 equal implies b % 4 and
    // b % 1 equal).
    let probe = {
        let mut s = seed;
        loop {
            let k = UnitKey::for_unit(&cfg, &spec_for(s));
            if k.hash % 16 == keys[0].hash % 16 && keys.iter().all(|e| e.hash != k.hash) {
                break k;
            }
            s += 1;
            assert!(s < 1_000_000, "probe-key search must converge");
        }
    };

    let mut resident_sets: Vec<Vec<bool>> = Vec::new();
    let mut final_stats: Vec<UnitCacheStats> = Vec::new();
    for shards in [1usize, 4, 16] {
        let cache = UnitCache::with_shards(32, shards);
        for k in &keys {
            cache.insert(k, sim);
        }
        assert_eq!(cache.len(), 32, "shards={shards}: balanced fill fits exactly");
        assert_eq!(cache.stats().evictions, 0, "shards={shards}: nothing evicted on fill");
        // Touch everything in one fixed order: keys[0] becomes the
        // LRU-oldest entry of its stripe at every shard count.
        for k in &keys {
            assert!(cache.lookup(k).is_some(), "shards={shards}: resident before probe");
        }
        cache.insert(&probe, sim);
        assert_eq!(cache.stats().evictions, 1, "shards={shards}: exactly one eviction");
        let resident: Vec<bool> = keys
            .iter()
            .chain(std::iter::once(&probe))
            .map(|k| cache.lookup(k).is_some())
            .collect();
        assert!(!resident[0], "shards={shards}: the globally-oldest key is the victim");
        assert!(
            resident[1..].iter().all(|&r| r),
            "shards={shards}: no other unit may be dropped or merged away"
        );
        resident_sets.push(resident);
        final_stats.push(cache.stats());
    }
    assert!(
        resident_sets.windows(2).all(|w| w[0] == w[1]),
        "resident sets must be identical across shard counts"
    );
    assert!(
        final_stats.windows(2).all(|w| w[0] == w[1]),
        "telemetry must be identical across shard counts: {final_stats:?}"
    );
}
