//! Contract of the request-multiplexed TCP transport (`serve
//! --listen`): requests — not connections — are the scheduling unit.
//!
//! * A slow cold sweep on a connection no longer blocks that
//!   connection's fast requests: `"stream": true` replies overtake it,
//!   tagged with an `"op"` echo, while ordered replies still arrive
//!   strictly in request order (v1 contract).
//! * Shutdown drains the request queue with an in-band error per
//!   queued request before closing connections — queued work is never
//!   silently dropped.
//! * An abrupt client disconnect cancels its queued work without
//!   wedging the server: later connections are still served and the
//!   server still joins cleanly on shutdown.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use tensordash::api::{Engine, ServeOptions, Service, UnitCache, DEFAULT_CACHE_CAP};
use tensordash::util::json::Json;

/// A fresh single-job service over its own warm-capable cache.
fn service() -> Service {
    Service::new(Engine::new(1), Arc::new(UnitCache::new(DEFAULT_CACHE_CAP)))
}

/// Connect with a generous read timeout (the slow sweep is slow on
/// purpose; only a wedged server should ever hit it).
fn connect(addr: SocketAddr) -> (BufReader<TcpStream>, TcpStream) {
    let c = TcpStream::connect(addr).expect("connect");
    c.set_read_timeout(Some(Duration::from_secs(120))).expect("read timeout");
    let _ = c.set_nodelay(true);
    let r = BufReader::new(c.try_clone().expect("clone"));
    (r, c)
}

fn send(w: &mut TcpStream, line: &str) {
    w.write_all(line.as_bytes()).expect("send");
    w.write_all(b"\n").expect("send newline");
}

fn read_json(r: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    r.read_line(&mut line).expect("recv");
    Json::parse(&line).unwrap_or_else(|e| panic!("bad response line {line:?}: {e}"))
}

fn id_of(j: &Json) -> String {
    j.get("id").and_then(Json::as_str).expect("string id").to_string()
}

/// A multi-model, multi-epoch cold sweep: seconds of compute, so fast
/// requests sent behind it race it by a wide margin.
const SLOW_SWEEP: &str = concat!(
    r#"{"op":"sweep","models":["alexnet","gcn"],"epochs":[0.1,0.3,0.5,0.7,0.9],"#,
    r#""samples":3,"seed":97,"id":"slow"}"#,
);

#[test]
fn streaming_fast_requests_overtake_a_slow_sweep_on_one_connection() {
    let s = service();
    // Warm the fast request's units through the in-process path so the
    // TCP round trips below are cache hits.
    let fast = |i: usize, stream: bool| {
        let tail = if stream { r#","stream":true"# } else { "" };
        format!(
            "{{\"op\":\"simulate\",\"model\":\"gcn\",\"epoch\":0.5,\
             \"samples\":2,\"seed\":4242,\"id\":\"f{i}\"{tail}}}"
        )
    };
    let h = s.handle_line(&fast(0, false));
    assert_eq!(h.lines.len(), 1);

    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::scope(|scope| {
        let opts = ServeOptions { workers: 2, ..ServeOptions::default() };
        let server = scope.spawn(|| s.serve_listener(listener, opts));

        let (mut r, mut w) = connect(addr);
        send(&mut w, SLOW_SWEEP);
        // Let a worker dequeue the sweep before the fast requests go
        // out (the sweep then runs for seconds — the margin is wide).
        std::thread::sleep(Duration::from_millis(100));
        for i in 0..4 {
            send(&mut w, &fast(i, true));
        }
        // All four streamed replies arrive before the sweep's, each
        // ok, each tagged with the op echo that marks an out-of-order
        // response.
        let mut streamed: Vec<String> = Vec::new();
        for _ in 0..4 {
            let j = read_json(&mut r);
            assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{j:?}");
            assert_eq!(j.get("op").and_then(Json::as_str), Some("simulate"), "op echo: {j:?}");
            assert!(j.get("report").is_some(), "streamed reply carries the report");
            streamed.push(id_of(&j));
        }
        streamed.sort();
        assert_eq!(streamed, ["f0", "f1", "f2", "f3"], "every fast request overtook the sweep");
        let j = read_json(&mut r);
        assert_eq!(id_of(&j), "slow", "the ordered sweep reply comes last");
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(j.get("op"), None, "ordered v1 replies carry no op echo");

        send(&mut w, r#"{"op":"shutdown"}"#);
        let j = read_json(&mut r);
        assert_eq!(j.get("bye"), Some(&Json::Bool(true)));
        server.join().unwrap().unwrap();
    });
}

#[test]
fn shutdown_cancels_queued_requests_with_in_band_errors() {
    let s = service();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::scope(|scope| {
        // One worker: the sweep occupies it, everything after queues.
        let opts = ServeOptions { workers: 1, ..ServeOptions::default() };
        let server = scope.spawn(|| s.serve_listener(listener, opts));

        let (mut r1, mut w1) = connect(addr);
        let (mut r2, mut w2) = connect(addr);
        send(&mut w1, SLOW_SWEEP);
        std::thread::sleep(Duration::from_millis(100));
        // Queued behind the sweep: first the shutdown, then a request
        // the shutdown strands in the queue.
        send(&mut w1, r#"{"op":"shutdown","id":"sd"}"#);
        std::thread::sleep(Duration::from_millis(50));
        send(&mut w2, r#"{"op":"stats","id":"doomed"}"#);

        // Connection 1 sees the v1-ordered sweep reply then the ack —
        // and nothing after the ack.
        let j = read_json(&mut r1);
        assert_eq!(id_of(&j), "slow");
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        let j = read_json(&mut r1);
        assert_eq!(id_of(&j), "sd");
        assert_eq!(j.get("bye"), Some(&Json::Bool(true)));

        // The stranded request is answered, not dropped: an in-band
        // error naming the shutdown.
        let j = read_json(&mut r2);
        assert_eq!(id_of(&j), "doomed");
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)), "{j:?}");
        let err = j.get("error").and_then(Json::as_str).expect("error text");
        assert!(err.contains("shutting down"), "{err}");

        server.join().unwrap().unwrap();
    });
}

#[test]
fn abrupt_disconnect_does_not_wedge_the_server() {
    let s = service();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::scope(|scope| {
        let opts = ServeOptions { workers: 1, ..ServeOptions::default() };
        let server = scope.spawn(|| s.serve_listener(listener, opts));

        // A client queues a slow sweep plus pipelined work, then
        // vanishes without reading a byte.
        {
            let (_r, mut w) = connect(addr);
            send(&mut w, SLOW_SWEEP);
            std::thread::sleep(Duration::from_millis(100));
            for i in 0..3 {
                send(&mut w, &format!(r#"{{"op":"stats","id":"gone{i}"}}"#));
            }
        } // both halves drop here

        // The server keeps serving: a fresh connection's request
        // round-trips once the worker frees up.
        let (mut r, mut w) = connect(addr);
        send(&mut w, r#"{"op":"stats","id":"alive"}"#);
        let j = read_json(&mut r);
        assert_eq!(id_of(&j), "alive");
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{j:?}");

        // And still shuts down cleanly.
        send(&mut w, r#"{"op":"shutdown"}"#);
        let j = read_json(&mut r);
        assert_eq!(j.get("bye"), Some(&Json::Bool(true)));
        server.join().unwrap().unwrap();
    });
}
