//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These tests exercise the python→HLO→rust boundary with real numerics:
//! every artifact is executed and validated against plain-rust oracles.
//! They require `make artifacts` (skipped with a notice otherwise).

use tensordash::runtime::{literal_f32, literal_i32, to_f32, to_i32, Runtime};
use tensordash::util::rng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("meta.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

fn rand_vec(n: usize, rng: &mut Rng, sparsity: f64) -> Vec<f32> {
    (0..n)
        .map(|_| {
            if rng.chance(sparsity) {
                0.0
            } else {
                rng.normal() as f32
            }
        })
        .collect()
}

/// Naive NHWC conv in plain rust — the oracle for the conv artifacts.
#[allow(clippy::too_many_arguments)]
fn conv_ref(
    x: &[f32],
    w: &[f32],
    (n, h, wd, c): (usize, usize, usize, usize),
    (kh, kw, _ci, f): (usize, usize, usize, usize),
    stride: usize,
    pad: usize,
) -> Vec<f32> {
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (wd + 2 * pad - kw) / stride + 1;
    let mut out = vec![0f32; n * oh * ow * f];
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for fi in 0..f {
                    let mut acc = 0f32;
                    for ky in 0..kh {
                        for kx in 0..kw {
                            let iy = (oy * stride + ky) as isize - pad as isize;
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if iy < 0 || ix < 0 || iy >= h as isize || ix >= wd as isize {
                                continue;
                            }
                            for ci in 0..c {
                                acc += x[((ni * h + iy as usize) * wd + ix as usize) * c + ci]
                                    * w[((ky * kw + kx) * c + ci) * f + fi];
                            }
                        }
                    }
                    out[((ni * oh + oy) * ow + ox) * f + fi] = acc;
                }
            }
        }
    }
    out
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    let mut max_err = 0f32;
    for (g, w) in got.iter().zip(want) {
        max_err = max_err.max((g - w).abs());
    }
    assert!(max_err < tol, "{what}: max err {max_err}");
}

#[test]
fn matmul_artifact_matches_rust_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let exe = rt.load("matmul").unwrap();
    let mut rng = Rng::new(1);
    let a = rand_vec(64 * 64, &mut rng, 0.3);
    let b = rand_vec(64 * 64, &mut rng, 0.3);
    let out = exe
        .run(&[literal_f32(&[64, 64], &a).unwrap(), literal_f32(&[64, 64], &b).unwrap()])
        .unwrap();
    let got = to_f32(&out[0]).unwrap();
    let mut want = vec![0f32; 64 * 64];
    for i in 0..64 {
        for k in 0..64 {
            for j in 0..64 {
                want[i * 64 + j] += a[i * 64 + k] * b[k * 64 + j];
            }
        }
    }
    assert_close(&got, &want, 1e-3, "matmul");
}

#[test]
fn conv_fwd_artifact_matches_rust_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let meta = rt.meta().unwrap();
    let xs = meta.path(&["conv2", "x"]).unwrap().as_usize_vec().unwrap();
    let ws = meta.path(&["conv2", "w"]).unwrap().as_usize_vec().unwrap();
    let stride = meta.path(&["conv2", "stride"]).unwrap().as_usize().unwrap();
    let pad = meta.path(&["conv2", "padding"]).unwrap().as_usize().unwrap();
    let exe = rt.load("conv_fwd").unwrap();
    let mut rng = Rng::new(2);
    let x = rand_vec(xs.iter().product(), &mut rng, 0.5);
    let w = rand_vec(ws.iter().product(), &mut rng, 0.0);
    let out = exe
        .run(&[literal_f32(&xs, &x).unwrap(), literal_f32(&ws, &w).unwrap()])
        .unwrap();
    let got = to_f32(&out[0]).unwrap();
    let want = conv_ref(
        &x,
        &w,
        (xs[0], xs[1], xs[2], xs[3]),
        (ws[0], ws[1], ws[2], ws[3]),
        stride,
        pad,
    );
    assert_close(&got, &want, 1e-3, "conv_fwd");
}

#[test]
fn conv_gradient_artifacts_satisfy_dot_product_identity() {
    // <conv_fwd(x, w), g> == <x, conv_igrad(g, w)> == <w, conv_wgrad(x, g)>
    // — the adjoint identity pins BOTH backward artifacts to the forward
    // one with no independent oracle needed.
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let meta = rt.meta().unwrap();
    let xs = meta.path(&["conv2", "x"]).unwrap().as_usize_vec().unwrap();
    let ws = meta.path(&["conv2", "w"]).unwrap().as_usize_vec().unwrap();
    let gs = meta.path(&["conv2", "g"]).unwrap().as_usize_vec().unwrap();
    let fwd = rt.load("conv_fwd").unwrap();
    let igrad = rt.load("conv_igrad").unwrap();
    let wgrad = rt.load("conv_wgrad").unwrap();

    let mut rng = Rng::new(3);
    let x = rand_vec(xs.iter().product(), &mut rng, 0.4);
    let w = rand_vec(ws.iter().product(), &mut rng, 0.0);
    let g = rand_vec(gs.iter().product(), &mut rng, 0.4);

    let run1 = fwd.run(&[literal_f32(&xs, &x).unwrap(), literal_f32(&ws, &w).unwrap()]).unwrap();
    let o = to_f32(&run1[0]).unwrap();
    let run2 = igrad.run(&[literal_f32(&gs, &g).unwrap(), literal_f32(&ws, &w).unwrap()]).unwrap();
    let gx = to_f32(&run2[0]).unwrap();
    let run3 = wgrad.run(&[literal_f32(&xs, &x).unwrap(), literal_f32(&gs, &g).unwrap()]).unwrap();
    let gw = to_f32(&run3[0]).unwrap();

    let dot = |a: &[f32], b: &[f32]| {
        a.iter().zip(b).map(|(x, y)| (*x as f64) * (*y as f64)).sum::<f64>()
    };
    let og = dot(&o, &g);
    let xgx = dot(&x, &gx);
    let wgw = dot(&w, &gw);
    let scale = og.abs().max(1.0);
    assert!(
        (og - xgx).abs() / scale < 1e-4,
        "adjoint identity (igrad): {og} vs {xgx}"
    );
    assert!(
        (og - wgw).abs() / scale < 1e-4,
        "adjoint identity (wgrad): {og} vs {wgw}"
    );
}

#[test]
fn bitmap_artifact_matches_rust_bitmap() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let exe = rt.load("bitmap").unwrap();
    let mut rng = Rng::new(4);
    let x = rand_vec(256 * 16, &mut rng, 0.6);
    let out = exe.run(&[literal_f32(&[256, 16], &x).unwrap()]).unwrap();
    let got = to_i32(&out[0]).unwrap();
    // Rust-side oracle: same packing as tensor::bitmap.
    let bm = tensordash::tensor::TensorBitmap::from_f32((1, 1, 256, 16), &x);
    let want: Vec<i32> = bm.words().iter().map(|&w| w as i32).collect();
    assert_eq!(got, want, "on-device bitmap != rust bitmap");
}

#[test]
fn init_artifact_is_deterministic_and_scaled() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let exe = rt.load("init").unwrap();
    let p1 = exe.run(&[tensordash::runtime::literal_i32_scalar(5)]).unwrap();
    let p2 = exe.run(&[tensordash::runtime::literal_i32_scalar(5)]).unwrap();
    let p3 = exe.run(&[tensordash::runtime::literal_i32_scalar(6)]).unwrap();
    assert_eq!(p1.len(), 5, "expect 5 params");
    let v1 = to_f32(&p1[0]).unwrap();
    assert_eq!(v1, to_f32(&p2[0]).unwrap(), "same seed, same params");
    assert_ne!(v1, to_f32(&p3[0]).unwrap(), "different seed differs");
    // He-scaled: sane magnitude.
    let rms = (v1.iter().map(|v| v * v).sum::<f32>() / v1.len() as f32).sqrt();
    assert!(rms > 0.01 && rms < 1.0, "w1 rms {rms}");
    // Final bias starts at zero.
    let bias = to_f32(&p1[4]).unwrap();
    assert!(bias.iter().all(|&b| b == 0.0));
}

#[test]
fn train_step_artifact_runs_and_reduces_loss() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let mut trainer = tensordash::coordinator::Trainer::new(&rt, 11).unwrap();
    let (n, h, w, c) = trainer.meta.input;
    let mut data = tensordash::coordinator::data::DataGen::new(h, w, c, trainer.meta.classes, 11);
    let (x, y) = data.batch(n);
    let mut losses = Vec::new();
    for _ in 0..6 {
        // Same batch: must overfit quickly.
        let out = trainer.step(&x, &y).unwrap();
        losses.push(out.loss);
        // Bitmap sanity: layer-0 A bitmap must match the input batch.
        let a0 = &out.trace.layers[0].0;
        let want = tensordash::tensor::TensorBitmap::from_f32((n, h, w, c), &x);
        assert_eq!(a0, &want, "on-device A0 bitmap != input zeros");
    }
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.8),
        "loss not decreasing: {losses:?}"
    );
    let _ = literal_i32(&[0]);
}
