//! Integration tests of the typed experiment pipeline:
//! `SimRequest`/`SweepSpec` → `Engine` → `Report` → JSON → parse-back.
//!
//! These pin the acceptance properties of the api redesign:
//! * `repro --fig 13 --format json` output parses back with
//!   `util::json` and its speedup values are identical to the
//!   text-table rendering;
//! * a multi-cell sweep is byte-identical at any `--jobs` count;
//! * the `tensordash.report.v1` schema is pinned by a golden test on a
//!   small deterministic figure (Table 3).

use tensordash::api::{Engine, Report, SweepSpec};
use tensordash::config::{ChipConfig, DataType};
use tensordash::repro;
use tensordash::util::json::Json;

/// The acceptance path behind
/// `tensordash repro --fig 13 --format json --out fig13.json`:
/// the written document parses with `util::json` and every speedup cell
/// carries both the table text and the full-precision value.
#[test]
fn fig13_json_round_trips_and_matches_text_rendering() {
    let engine = Engine::new(2);
    let cfg = ChipConfig::default();
    let sims = repro::run_fig13_sims(&engine, &cfg, 1, 42);
    let report = repro::fig13(&sims);
    let text = report.render_text();
    let json = report.render_json();

    let parsed = Json::parse(&json).expect("report JSON parses with util::json");
    assert_eq!(parsed.get("schema").unwrap().as_str(), Some("tensordash.report.v1"));
    assert_eq!(parsed.get("id").unwrap().as_str(), Some("fig13"));

    let back = Report::from_json(&parsed).expect("report reconstructs from JSON");
    assert_eq!(back, report);
    assert_eq!(back.render_text(), text);

    // Speedup cells: JSON text equals the table cell text, and the raw
    // value re-formats to exactly that text.
    let cols = parsed.get("columns").unwrap().as_arr().unwrap();
    let overall = cols.iter().position(|c| c.as_str() == Some("overall")).unwrap();
    let rows = parsed.get("rows").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), sims.len() + 1, "9 models + geomean row");
    for (ri, row) in rows.iter().enumerate() {
        let cell = &row.get("cells").unwrap().as_arr().unwrap()[overall];
        let cell_text = cell.get("text").unwrap().as_str().unwrap();
        assert!(text.contains(cell_text), "row {ri}: '{cell_text}' missing from table");
        let v = cell.get("value").unwrap().as_f64().unwrap();
        assert_eq!(format!("{v:.2}"), cell_text, "row {ri}");
        assert!((1.0..=3.01).contains(&v), "row {ri}: speedup {v} out of range");
    }
}

/// `--jobs 4` must be byte-identical to `--jobs 1`: per-cell seed
/// derivation makes every cell independent of worker count and
/// completion order.
#[test]
fn multi_cell_sweep_byte_identical_across_worker_counts() {
    let models = ["alexnet", "squeezenet", "gcn"];
    let spec = SweepSpec::models(&models, 0.4, &ChipConfig::default(), 1, 7)
        .with_configs(vec![
            ("depth2".to_string(), ChipConfig::default().with_depth(2)),
            ("depth3".to_string(), ChipConfig::default()),
        ]);
    let sims1 = Engine::new(1).run_all(&spec.cells());
    let sims4 = Engine::new(4).run_all(&spec.cells());
    let r1 = repro::fig13(&sims1);
    let r4 = repro::fig13(&sims4);
    assert_eq!(r1, r4);
    assert_eq!(r1.render_json().into_bytes(), r4.render_json().into_bytes());
    assert_eq!(r1.render_text(), r4.render_text());
    assert_eq!(r1.render_csv(), r4.render_csv());
}

/// Golden test pinning the `tensordash.report.v1` JSON schema on a
/// small, fully deterministic figure. If this breaks, downstream
/// consumers of the BENCH_*/report pipeline break too — bump the
/// schema version instead of silently changing shape.
#[test]
fn table3_report_json_golden() {
    let report = repro::table3(DataType::Fp32);
    let compact = report.to_json().render();

    // Envelope: BTreeMap ordering puts columns first, schema/title last.
    assert!(
        compact.starts_with(r#"{"columns":["component","area mm2","power mW"]"#),
        "schema envelope changed: {}",
        &compact[..80.min(compact.len())]
    );
    assert!(compact.contains(r#""id":"table3_fp32""#));
    assert!(compact.contains(r#""schema":"tensordash.report.v1""#));
    // First row: the paper's Table 3 core area, text + raw value.
    assert!(
        compact.contains(r#"{"cells":[{"text":"compute cores"},{"text":"30.41","value":30.41}"#)
    );
    // Non-numeric cells carry no "value" key.
    assert!(compact.contains(r#"{"text":"-"}"#));

    // The golden document round-trips through parse → reconstruct.
    let parsed = Json::parse(&compact).unwrap();
    let back = Report::from_json(&parsed).unwrap();
    assert_eq!(back, report);
    // And pretty rendering parses to the identical value.
    assert_eq!(Json::parse(&report.render_json()).unwrap(), parsed);
}

/// Property test: randomized `tensordash.frontier.v1` reports
/// round-trip bit-exactly through render_json → parse → `from_json`.
/// The experiment store re-materialises stored frontiers through this
/// exact path (query trajectories, commit-to-commit diffs), so the
/// reconstruction must lose nothing — text and raw value of every cell.
#[test]
fn frontier_report_json_round_trips_on_randomized_inputs() {
    use tensordash::api::Cell;
    use tensordash::util::rng::Rng;
    let mut rng = Rng::new(0xF207);
    for case in 0..50 {
        let mut r = Report::with_schema(
            tensordash::api::FRONTIER_SCHEMA,
            format!("frontier_{case}"),
            "randomized frontier",
            &["config", "td cycles", "speedup", "energy pJ", "energy eff", "area mm2", "gen"],
        );
        for i in 0..(1 + rng.below(6)) {
            let cycles = rng.next_u64() >> 20;
            let energy = rng.f64() * 1e9;
            let generation = rng.below(9);
            r.row(vec![
                Cell::text(format!("cfg{i}_d{}", rng.below(4))),
                Cell::fmt(cycles.to_string(), cycles as f64),
                Cell::num(1.0 + rng.f64() * 3.0),
                Cell::fmt(format!("{energy:.3e}"), energy),
                Cell::num(rng.f64() * 2.0),
                Cell::num(rng.f64() * 100.0),
                Cell::fmt(generation.to_string(), generation as f64),
            ]);
        }
        r.meta_num("seed", rng.next_u64() as f64);
        r.meta_str("models", "alexnet,gcn");

        let parsed = Json::parse(&r.render_json()).expect("frontier JSON parses");
        let back = Report::from_json(&parsed).expect("frontier reconstructs from JSON");
        assert_eq!(back, r, "case {case}: reconstruction lost information");
        assert_eq!(back.render_json(), r.render_json(), "case {case}: renderer bytes");
    }
}

/// CSV renderer sanity on a real figure.
#[test]
fn table3_csv_has_header_and_rows() {
    let csv = repro::table3(DataType::Fp32).render_csv();
    let mut lines = csv.lines();
    assert_eq!(lines.next(), Some("component,area mm2,power mW"));
    assert!(csv.lines().count() >= 8);
    assert!(csv.contains("compute cores,30.41"));
    // The overhead row's comma-free cells need no quoting.
    assert!(
        csv.contains("\"whole-chip overhead (incl. AM/BM/CM+SP)\"")
            || csv.contains("whole-chip overhead (incl. AM/BM/CM+SP)")
    );
}
