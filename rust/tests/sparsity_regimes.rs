//! Contract of the sparsity-regime subsystem (`tensordash::sparsity`):
//! N:M structured masks, time-varying schedules, and the transformer
//! workload tier, end to end.
//!
//! Four families, mirroring the ISSUE's acceptance bars:
//!
//! 1. **N:M mask properties**: every `m`-wide channel block of a
//!    generated mask holds exactly `min(n, block)` nonzeros, density
//!    accounting is exact, masks are pure functions of their seed, and
//!    an N:M run is byte-identical at `--jobs {1, 4, 8}`.
//! 2. **Transformer tier**: `bert` runs under all three regimes with
//!    warm-vs-cold and `--jobs`/`--shards` byte-identity, through the
//!    engine, the serve path and the explorer; regimes occupy disjoint
//!    cache-key space.
//! 3. **Schedule differential**: the generalised Fig. 14 (every model
//!    scheduled onto its own trajectory curve) is byte-identical to the
//!    historical uniform sweep on the existing CNN zoo.
//! 4. **Error wording**: the serve path rejects bad `epoch`/`regime`
//!    values with the exact `api::params` wording the CLI uses.
//!
//! CI runs this binary explicitly and fails if its tests are filtered
//! out (same pattern as the stream/plan/cache gates).

use std::sync::Arc;

use tensordash::api::{Engine, Service, SimRequest, SweepSpec, UnitCache, Workload};
use tensordash::config::ChipConfig;
use tensordash::models::FIG13_MODELS;
use tensordash::repro::{ModelSim, MID_EPOCH};
use tensordash::search::{run as explore_run, ExploreSpec, SearchSpace};
use tensordash::sparsity::{apply_nm, nm_mask, nm_mask_seed, Regime};
use tensordash::trace::{ModelProfile, PHASES};
use tensordash::util::json::Json;

const SEED: u64 = 7;
const SAMPLES: usize = 1;

fn profile_request(model: &str, regime: Regime) -> SimRequest {
    SimRequest::profile(model, MID_EPOCH, ChipConfig::default(), SAMPLES, SEED)
        .expect("known model")
        .with_regime(regime)
}

/// Byte-level equality of two merged sims: every integer counter, every
/// f64 down to its bit pattern, every retained unit.
fn assert_bit_identical(a: &ModelSim, b: &ModelSim, ctx: &str) {
    assert_eq!(a.name, b.name, "{ctx}: name");
    assert_eq!(a.per_op, b.per_op, "{ctx}: per-op cycles");
    assert_eq!(a.sched, b.sched, "{ctx}: scheduler telemetry");
    assert_eq!(
        a.energy_base.total_pj().to_bits(),
        b.energy_base.total_pj().to_bits(),
        "{ctx}: baseline energy bits"
    );
    assert_eq!(
        a.energy_td.total_pj().to_bits(),
        b.energy_td.total_pj().to_bits(),
        "{ctx}: TensorDash energy bits"
    );
    assert_eq!(a.layers, b.layers, "{ctx}: per-unit results");
}

// ---------------------------------------------------------------------
// 1. N:M mask properties
// ---------------------------------------------------------------------

/// Kept lanes expected in one site's channel run: `min(n, block)` per
/// `m`-wide block, including a partial tail block when `m` does not
/// divide `c`.
fn expected_site_nonzeros(c: usize, n: usize, m: usize) -> u64 {
    let mut total = 0u64;
    let mut c0 = 0;
    while c0 < c {
        let block = m.min(c - c0);
        total += n.min(block) as u64;
        c0 += block;
    }
    total
}

#[test]
fn nm_mask_blocks_hold_exactly_min_n_block_nonzeros() {
    let dims = (2usize, 3usize, 3usize, 64usize);
    let (nn, h, w, c) = dims;
    // (3, 12) exercises the partial tail block: 64 = 5x12 + 4.
    for (n, m) in [(1usize, 4usize), (2, 4), (4, 8), (3, 12), (1, 16), (16, 16)] {
        let mask = nm_mask(dims, n, m, nm_mask_seed(SEED, 0, 0));
        for s in 0..nn {
            for y in 0..h {
                for x in 0..w {
                    let mut c0 = 0;
                    while c0 < c {
                        let block = m.min(c - c0);
                        let kept = (c0..c0 + block).filter(|&l| mask.bit(s, y, x, l)).count();
                        assert_eq!(
                            kept,
                            n.min(block),
                            "{n}:{m} site ({s},{y},{x}) block at {c0}"
                        );
                        c0 += block;
                    }
                }
            }
        }
        // Exact density accounting: sites x per-site budget.
        let sites = (nn * h * w) as u64;
        assert_eq!(
            mask.nonzeros(),
            sites * expected_site_nonzeros(c, n, m),
            "{n}:{m} density accounting"
        );
    }
}

#[test]
fn nm_masks_are_pure_functions_of_their_seed() {
    let dims = (2usize, 2usize, 2usize, 64usize);
    let seed = nm_mask_seed(SEED, 3, 1);
    let a = nm_mask(dims, 2, 4, seed);
    let b = nm_mask(dims, 2, 4, seed);
    assert_eq!(a.words(), b.words(), "same seed must reproduce the mask");
    let c = nm_mask(dims, 2, 4, seed ^ 1);
    assert_ne!(a.words(), c.words(), "different seeds must diverge");
    // Distinct (layer, tensor) coordinates get distinct streams.
    assert_ne!(nm_mask_seed(SEED, 0, 0), nm_mask_seed(SEED, 1, 0));
    assert_ne!(nm_mask_seed(SEED, 0, 0), nm_mask_seed(SEED, 0, 1));
}

#[test]
fn applying_nm_only_clears_bits_and_respects_the_block_budget() {
    let p = ModelProfile::for_model("gcn").expect("gcn profile");
    let (a, _g) = p.layer_bitmaps(0, MID_EPOCH, SEED);
    let (n, m) = (2usize, 4usize);
    let seed = nm_mask_seed(SEED, 0, 0);
    let masked = apply_nm(&a, n, m, seed);
    // AND semantics: the masked bitmap is a subset of the original.
    for (mw, ow) in masked.words().iter().zip(a.words()) {
        assert_eq!(mw & ow, *mw, "apply_nm must never set a bit");
    }
    // Every m-wide block of the result holds at most n nonzeros.
    for s in 0..masked.n {
        for y in 0..masked.h {
            for x in 0..masked.w {
                let mut c0 = 0;
                while c0 < masked.c {
                    let block = m.min(masked.c - c0);
                    let kept = (c0..c0 + block).filter(|&l| masked.bit(s, y, x, l)).count();
                    assert!(kept <= n, "block at ({s},{y},{x},{c0}) holds {kept} > {n}");
                    c0 += block;
                }
            }
        }
    }
    assert!(masked.nonzeros() <= a.nonzeros());
}

#[test]
fn nm_regime_is_byte_identical_across_jobs_1_4_8() {
    let req = profile_request("gcn", Regime::parse("nm:2:4").expect("spelling"));
    let reference = Engine::new(1).run(&req);
    for jobs in [1usize, 4, 8] {
        let cache = Arc::new(UnitCache::new(4096));
        let engine = Engine::new(jobs).with_cache(Arc::clone(&cache));
        let cold = engine.run(&req);
        let warm = engine.run(&req);
        assert_bit_identical(&reference, &cold, &format!("nm jobs={jobs} cold"));
        assert_bit_identical(&cold, &warm, &format!("nm jobs={jobs} warm"));
        assert!(cache.stats().hits > 0, "warm run must be cache-served");
    }
}

// ---------------------------------------------------------------------
// 2. Transformer tier under every regime
// ---------------------------------------------------------------------

fn regimes() -> [Regime; 3] {
    [
        Regime::Uniform,
        Regime::parse("nm:2:4").expect("spelling"),
        Regime::parse("schedule:pruned-reclaim:0.3").expect("spelling"),
    ]
}

#[test]
fn bert_is_byte_identical_warm_and_cold_across_shards_under_every_regime() {
    let mut colds: Vec<ModelSim> = Vec::new();
    for regime in regimes() {
        let req = profile_request("bert", regime.clone());
        let reference = Engine::new(1).run(&req);
        // The structured regime gets the full jobs x shards spread; the
        // others pin one mid-size point (uniform's spread is already
        // pinned zoo-wide by cache_service).
        let combos: &[(usize, usize)] = if matches!(regime, Regime::NM { .. }) {
            &[(1, 1), (8, 16)]
        } else {
            &[(4, 4)]
        };
        for &(jobs, shards) in combos {
            let cache = Arc::new(UnitCache::with_shards(65_536, shards));
            let engine = Engine::new(jobs).with_cache(Arc::clone(&cache));
            let cold = engine.run(&req);
            let warm = engine.run(&req);
            let ctx = format!("bert {} jobs={jobs} shards={shards}", regime.render());
            assert_bit_identical(&reference, &cold, &format!("{ctx} cold"));
            assert_bit_identical(&cold, &warm, &format!("{ctx} warm"));
            assert!(cache.stats().hits > 0, "{ctx}: warm run must be cache-served");
        }
        colds.push(reference);
    }
    // The N:M mask really bites: forced structural zeros change the
    // simulated schedule relative to the uniform profile.
    assert_ne!(colds[0].layers, colds[1].layers, "nm:2:4 must differ from uniform");
}

#[test]
fn regimes_occupy_disjoint_cache_key_space() {
    let cache = Arc::new(UnitCache::new(65_536));
    let engine = Engine::new(4).with_cache(Arc::clone(&cache));
    let unit_count = engine.run(&profile_request("bert", Regime::Uniform)).layers.len() as u64;
    assert_eq!(cache.stats().inserts, unit_count);
    for (i, regime) in regimes().iter().enumerate().skip(1) {
        engine.run(&profile_request("bert", regime.clone()));
        assert_eq!(
            cache.stats().inserts,
            (i as u64 + 1) * unit_count,
            "{} must miss every uniform entry",
            regime.render()
        );
    }
}

#[test]
fn serve_runs_bert_under_every_regime_and_repeats_byte_identically() {
    let service = Service::new(Engine::new(4), Arc::new(UnitCache::new(65_536)));
    for (i, spelling) in ["uniform", "nm:2:4", "schedule:pruned-reclaim:0.3"]
        .iter()
        .enumerate()
    {
        let line = format!(
            concat!(
                r#"{{"op":"simulate","id":"r{}","model":"bert","epoch":0.4,"#,
                r#""samples":1,"seed":7,"regime":"{}"}}"#,
            ),
            i, spelling
        );
        let body = |h: tensordash::api::Handled| {
            assert_eq!(h.lines.len(), 1);
            let j = Json::parse(&h.lines[0]).expect("response parses");
            assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "not ok: {}", h.lines[0]);
            j.get("report").expect("report present").render()
        };
        let cold = body(service.handle_line(&line));
        let before = service.cache().stats();
        let warm = body(service.handle_line(&line));
        assert_eq!(cold, warm, "{spelling}: repeat must be byte-identical");
        let delta = service.cache().stats().since(&before);
        assert_eq!(delta.misses, 0, "{spelling}: repeat must be fully cache-served");
    }
}

#[test]
fn explore_evaluates_bert_under_a_regime_deterministically() {
    let mut space = SearchSpace::trivial();
    space.set_axis("staging_depth", &["2", "3"]).expect("axis");
    space.set_axis("tile_rows", &["2", "4"]).expect("axis");
    let spec = ExploreSpec::new(space, &["bert"], MID_EPOCH, SAMPLES, SEED, 2)
        .expect("known model")
        .with_regime(Regime::parse("nm:2:4").expect("spelling"));
    let mut renders: Vec<String> = Vec::new();
    for jobs in [1usize, 4] {
        let engine = Engine::new(jobs).with_cache(Arc::new(UnitCache::new(65_536)));
        let (_res, report) = explore_run(&engine, &spec);
        renders.push(report.render_json());
    }
    assert_eq!(renders[0], renders[1], "explore must be jobs-independent");
    assert!(
        renders[0].contains(r#""regime":"nm:2:4""#),
        "frontier must stamp the regime: {}",
        renders[0]
    );
}

#[test]
fn serve_explore_accepts_a_regime_for_bert() {
    let service = Service::new(Engine::new(2), Arc::new(UnitCache::new(65_536)));
    let line = concat!(
        r#"{"op":"explore","id":"e","models":["bert"],"budget":2,"samples":1,"seed":7,"#,
        r#""regime":"nm:2:4","axes":{"staging_depth":[2,3],"tile_rows":[2,4]}}"#,
    );
    let h1 = service.handle_line(line);
    assert_eq!(h1.lines.len(), 1);
    let j = Json::parse(&h1.lines[0]).expect("response parses");
    assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "not ok: {}", h1.lines[0]);
    let r1 = j.get("report").expect("report present").render();
    assert!(r1.contains(r#""regime":"nm:2:4""#), "frontier must stamp the regime");
    // Byte-identical on repeat, served through the shared unit cache.
    let h2 = service.handle_line(line);
    let r2 = Json::parse(&h2.lines[0]).unwrap().get("report").unwrap().render();
    assert_eq!(r1, r2);
}

// ---------------------------------------------------------------------
// 3. Fig. 14 on the Schedule regime
// ---------------------------------------------------------------------

/// The generalised Fig. 14 stamps each model's cells with that model's
/// own trajectory curve — which is exactly what the uniform path
/// evaluates internally, so nothing moves. Pinned in two layers:
///
/// * zoo-wide, the per-layer sparsity scalars agree bitwise at every
///   phase (`layer_bitmaps` delegates to the factor path, so scalar
///   agreement plus a shared RNG stream is bitmap agreement);
/// * engine-level, two representative zoo models simulate to
///   byte-identical results at every phase under both spellings.
#[test]
fn fig14_is_byte_identical_on_the_schedule_regime() {
    // Scalar agreement across the whole CNN zoo.
    for m in FIG13_MODELS {
        let p = ModelProfile::for_model(m).expect("zoo model");
        for e in PHASES {
            let factor = p.curve.factor(e);
            for i in 0..p.topology.layers.len() {
                assert_eq!(
                    p.a_sparsity_at(i, e).to_bits(),
                    p.a_sparsity_with_factor(i, factor).to_bits(),
                    "{m} layer {i} epoch {e}: A sparsity"
                );
                assert_eq!(
                    p.g_sparsity_at(i, e).to_bits(),
                    p.g_sparsity_with_factor(i, factor).to_bits(),
                    "{m} layer {i} epoch {e}: G sparsity"
                );
            }
        }
    }
    // Engine-level differential on representative zoo models, mirroring
    // exactly how `repro::fig14` stamps its cells.
    let cfg = ChipConfig::default();
    let engine = Engine::new(8);
    let spec = SweepSpec::models(&["alexnet", "gcn"], MID_EPOCH, &cfg, SAMPLES, SEED)
        .with_epochs(&PHASES);
    let uniform = engine.run_all(&spec.cells());
    let scheduled_cells: Vec<SimRequest> = spec
        .cells()
        .into_iter()
        .map(|cell| {
            let curve = match &cell.workload {
                Workload::Profile { model, .. } => {
                    ModelProfile::for_model(model).expect("known model").curve
                }
                _ => unreachable!("model sweeps expand to profile workloads"),
            };
            cell.with_regime(Regime::Schedule { curve })
        })
        .collect();
    let scheduled = engine.run_all(&scheduled_cells);
    assert_eq!(uniform.len(), scheduled.len());
    for (u, s) in uniform.iter().zip(&scheduled) {
        assert_bit_identical(u, s, &format!("{} on its own curve", u.name));
    }
}

// ---------------------------------------------------------------------
// 4. Serve error wording matches the CLI
// ---------------------------------------------------------------------

#[test]
fn serve_rejects_bad_epoch_and_regime_with_the_params_wording() {
    let service = Service::new(Engine::new(1), Arc::new(UnitCache::new(1024)));
    let err_of = |line: &str| -> String {
        let h = service.handle_line(line);
        assert_eq!(h.lines.len(), 1);
        let j = Json::parse(&h.lines[0]).expect("response parses");
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)), "expected error: {}", h.lines[0]);
        j.get("error").and_then(Json::as_str).expect("error string").to_string()
    };
    // Epoch bounds, rejected up front on every op that takes one.
    assert_eq!(
        err_of(r#"{"op":"simulate","model":"bert","epoch":1.5}"#),
        "'epoch' must be within [0, 1]"
    );
    assert_eq!(
        err_of(r#"{"op":"explore","models":["bert"],"epoch":-0.1,"budget":2}"#),
        "'epoch' must be within [0, 1]"
    );
    assert_eq!(
        err_of(r#"{"op":"sweep","models":["gcn"],"epochs":[0.4,1.5]}"#),
        "'epochs' must be within [0, 1]"
    );
    // Regime spellings, same predicate the CLI's `--regime` prints.
    assert_eq!(
        err_of(r#"{"op":"simulate","model":"bert","regime":"nm:4:2"}"#),
        "'regime' nm requires n <= m"
    );
    assert_eq!(
        err_of(r#"{"op":"simulate","model":"bert","regime":3}"#),
        "'regime' must be a string"
    );
    assert_eq!(
        err_of(r#"{"op":"sweep","models":["gcn"],"regime":"nm:0:4"}"#),
        "'regime' nm wants positive integers n:m"
    );
}
