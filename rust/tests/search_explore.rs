//! Contract tests for the design-space explorer (`tensordash::search`).
//!
//! Three families, mirroring the ISSUE's acceptance bars:
//!
//! 1. **Pareto invariants** (property tests over seeded pseudo-random
//!    score sets): dominance is a strict partial order; the frontier
//!    never contains a dominated point; insertion order never changes
//!    the final frontier.
//! 2. **Determinism**: a fixed-budget explore run is byte-identical at
//!    `--jobs {1, 4, 8}`, cached or uncached, warm or cold.
//! 3. **Fig.-19 cross-check**: the explored staging-depth slice orders
//!    depth 3 (lookahead 2) at least as fast as depth 2, the same
//!    ordering Fig. 19 reports.
//!
//! CI runs this binary explicitly and fails if its tests are filtered
//! out (same pattern as the stream/plan/cache gates).

use std::collections::BTreeSet;
use std::sync::Arc;

use tensordash::api::{Engine, Report, UnitCache, FRONTIER_SCHEMA};
use tensordash::search::{
    explore, frontier_report, run, Evaluated, ExploreSpec, Frontier, Score, ScoreDetail,
    SearchSpace,
};
use tensordash::util::rng::Rng;

// ---------------------------------------------------------------------
// Helpers
// ---------------------------------------------------------------------

/// A pseudo-random score on a coarse grid (1..=4 per objective), so
/// dominance chains and exact ties both occur often.
fn random_score(rng: &mut Rng) -> Score {
    Score {
        td_cycles: (1 + rng.below(4)) as f64,
        energy_pj: (1 + rng.below(4)) as f64,
        area_mm2: (1 + rng.below(4)) as f64,
    }
}

fn point(tag: usize, score: Score) -> Evaluated {
    Evaluated {
        label: format!("p{tag}"),
        canon: format!("canon{tag}"),
        id: tag as u64,
        score,
        detail: ScoreDetail { base_cycles: 0.0, speedup: 1.0, energy_eff: 1.0 },
        gen: 0,
    }
}

/// Deterministic Fisher–Yates shuffle.
fn shuffle<T>(v: &mut [T], rng: &mut Rng) {
    for i in (1..v.len()).rev() {
        v.swap(i, rng.below(i + 1));
    }
}

/// Canonical flat rendering of a frontier (labels + scores in frontier
/// order) for equality checks across insertion orders.
fn frontier_fingerprint(f: &Frontier) -> Vec<(String, u64, u64, u64)> {
    f.points()
        .iter()
        .map(|p| {
            (
                p.canon.clone(),
                p.score.td_cycles as u64,
                p.score.energy_pj as u64,
                p.score.area_mm2 as u64,
            )
        })
        .collect()
}

fn tiny_space() -> SearchSpace {
    let mut space = SearchSpace::trivial();
    space.set_axis("staging_depth", &["2", "3"]).unwrap();
    space.set_axis("tile_rows", &["2", "4"]).unwrap();
    space.set_axis("tile_cols", &["4", "8"]).unwrap();
    space
}

// ---------------------------------------------------------------------
// 1. Pareto invariants
// ---------------------------------------------------------------------

#[test]
fn dominance_is_a_strict_partial_order() {
    let mut rng = Rng::new(101);
    for _ in 0..2000 {
        let (a, b, c) = (random_score(&mut rng), random_score(&mut rng), random_score(&mut rng));
        // Irreflexive.
        assert!(!a.dominates(&a), "{a:?} dominates itself");
        // Asymmetric.
        if a.dominates(&b) {
            assert!(!b.dominates(&a), "dominance must be asymmetric: {a:?} vs {b:?}");
        }
        // Transitive.
        if a.dominates(&b) && b.dominates(&c) {
            assert!(a.dominates(&c), "dominance must be transitive: {a:?} {b:?} {c:?}");
        }
    }
}

#[test]
fn frontier_never_contains_a_dominated_point() {
    let mut rng = Rng::new(202);
    for trial in 0..50 {
        let mut f = Frontier::new();
        let n = 5 + rng.below(40);
        for i in 0..n {
            f.insert(point(i, random_score(&mut rng)));
        }
        assert!(!f.is_empty(), "trial {trial}: frontier empty after {n} inserts");
        let pts = f.points();
        for a in pts {
            for b in pts {
                assert!(
                    !a.score.dominates(&b.score),
                    "trial {trial}: frontier holds dominated pair {a:?} -> {b:?}"
                );
            }
        }
    }
}

#[test]
fn insertion_order_never_changes_the_final_frontier() {
    let mut rng = Rng::new(303);
    for trial in 0..25u64 {
        let n = 6 + rng.below(30);
        let base: Vec<Evaluated> =
            (0..n).map(|i| point(i, random_score(&mut rng))).collect();
        let mut reference = Frontier::new();
        for p in &base {
            reference.insert(p.clone());
        }
        let want = frontier_fingerprint(&reference);
        for perm in 0..6u64 {
            let mut order: Vec<usize> = (0..n).collect();
            let mut prng = Rng::new(7000 + trial * 31 + perm);
            shuffle(&mut order, &mut prng);
            let mut f = Frontier::new();
            for &i in &order {
                f.insert(base[i].clone());
            }
            assert_eq!(
                frontier_fingerprint(&f),
                want,
                "trial {trial} permutation {perm}: frontier depends on insertion order"
            );
        }
    }
}

// ---------------------------------------------------------------------
// 2. Explore determinism
// ---------------------------------------------------------------------

#[test]
fn explore_is_byte_identical_at_jobs_1_4_8() {
    let spec = ExploreSpec::new(tiny_space(), &["gcn"], 0.4, 1, 11, 5).unwrap();
    let mut renders: Vec<String> = Vec::new();
    for jobs in [1usize, 4, 8] {
        // Fresh cache per run: cold every time, so this also pins the
        // cached execution path's worker independence.
        let engine = Engine::new(jobs).with_cache(Arc::new(UnitCache::new(4096)));
        let (res, report) = run(&engine, &spec);
        assert_eq!(res.evaluated.len(), 5);
        renders.push(report.render_json());
    }
    assert_eq!(renders[0], renders[1], "--jobs 1 vs 4 diverged");
    assert_eq!(renders[0], renders[2], "--jobs 1 vs 8 diverged");
    // The uncached engine produces the identical frontier (cache off
    // only drops the unit_cache_* meta annotations).
    let res_nc = explore(&Engine::new(4), &spec);
    let report_nc = frontier_report(&spec, &res_nc);
    let cached = Report::from_json(
        &tensordash::util::json::Json::parse(&renders[0]).unwrap(),
    )
    .unwrap();
    assert_eq!(report_nc.rows, cached.rows, "cached vs uncached frontier rows diverged");
}

#[test]
fn warm_explore_is_byte_identical_to_cold() {
    let spec = ExploreSpec::new(tiny_space(), &["gcn"], 0.4, 1, 13, 5).unwrap();
    let cache = Arc::new(UnitCache::new(4096));
    let engine = Engine::new(4).with_cache(Arc::clone(&cache));
    let cold = explore(&engine, &spec);
    let cold_stats = cache.stats();
    let warm = explore(&engine, &spec);
    let warm_stats = cache.stats();
    assert_eq!(
        frontier_report(&spec, &cold).render_json(),
        frontier_report(&spec, &warm).render_json(),
        "warm frontier must be byte-identical to cold"
    );
    assert_eq!(
        warm_stats.inserts, cold_stats.inserts,
        "a warm run must not compute any new unit"
    );
    assert!(warm_stats.hits > cold_stats.hits, "warm run must be served from the cache");
}

#[test]
fn explore_report_is_schema_tagged_and_round_trips() {
    let spec = ExploreSpec::new(tiny_space(), &["gcn"], 0.4, 1, 17, 4).unwrap();
    let engine = Engine::new(2).with_cache(Arc::new(UnitCache::new(4096)));
    let (res, report) = run(&engine, &spec);
    assert_eq!(report.schema, FRONTIER_SCHEMA);
    assert_eq!(report.rows.len(), res.frontier.len());
    let parsed =
        tensordash::util::json::Json::parse(&report.render_json()).expect("frontier json parses");
    let back = Report::from_json(&parsed).expect("frontier report reconstructs");
    assert_eq!(back, report);
    // Text + CSV renderers accept it too.
    assert!(report.render_text().contains("Pareto frontier"));
    assert!(report.render_csv().starts_with("config,"));
    // Every evaluated candidate has a unique content address.
    let ids: BTreeSet<u64> = res.evaluated.iter().map(|e| e.id).collect();
    assert_eq!(ids.len(), res.evaluated.len());
}

// ---------------------------------------------------------------------
// 3. Fig.-19 cross-check
// ---------------------------------------------------------------------

#[test]
fn depth_slice_reproduces_fig19_ordering() {
    // alexnet at mid-training has real sparsity, so depth 3 (lookahead
    // 2) must be strictly no slower than depth 2 — the Fig. 19
    // ordering. The depth-only space makes every evaluation a pair.
    let mut space = SearchSpace::trivial();
    space.set_axis("staging_depth", &["2", "3"]).unwrap();
    let spec = ExploreSpec::new(space, &["alexnet"], 0.4, 2, 42, 2).unwrap();
    let engine = Engine::new(4).with_cache(Arc::new(UnitCache::new(4096)));
    let (res, report) = run(&engine, &spec);
    assert_eq!(res.evaluated.len(), 2);
    assert_eq!(res.depth_pairs, 1);
    assert!(res.depth_ordered, "fig-19 gate: depth 3 slower than depth 2");
    assert_eq!(report.meta.get("depth_ordered").and_then(|j| j.as_f64()), Some(1.0));
    // The frontier itself orders depth 3 first (fewer TensorDash
    // cycles is the primary tie-break key).
    let first = &res.frontier.points()[0];
    assert!(
        first.label.contains("staging_depth=3"),
        "depth 3 should lead the frontier, got '{}'",
        first.label
    );
}
