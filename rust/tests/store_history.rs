//! Contract of the persistent experiment store (`store` subcommand,
//! `rust/src/store/`).
//!
//! The store's load-bearing properties, pinned end to end over the real
//! single-file record log:
//!
//! * a sealed store reopens on the indexed fast path (no scan);
//! * every registered schema ingests — report, layers, frontier, bench
//!   and reportset documents — and unknown schemas are a *typed* error,
//!   never a silent skip;
//! * re-ingesting an identical document is idempotent (zero new
//!   records, zero file growth), while a changed document under the
//!   same key is a last-wins update that `compact` folds away;
//! * a torn tail write (crash mid-append) truncates back to the last
//!   good frame on reopen, keeping every earlier record;
//! * query output is byte-identical across `--jobs {1, 4, 8}` and
//!   warm/cold unit-cache runs — the `unit_cache_*` telemetry keys are
//!   excluded from the config hash, so both ingest under one key;
//! * a frontier-vs-frontier diff classifies points as added / kept /
//!   removed / newly-dominated by Pareto dominance.

use std::path::PathBuf;
use std::sync::Arc;

use tensordash::api::{Cell, Engine, Report, UnitCache, FRONTIER_SCHEMA, LAYERS_SCHEMA};
use tensordash::config::ChipConfig;
use tensordash::repro;
use tensordash::store::{registered_schemas, ExperimentStore, QueryFilter, StoreError};
use tensordash::util::json::Json;

fn temp_db(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("td_hist_{tag}_{}.tdstore", std::process::id()));
    let _ = std::fs::remove_file(&p);
    p
}

/// The Fig. 13 report as the CLI would produce it: optionally cached,
/// with the cache telemetry annotated into the meta block.
fn fig13_report(jobs: usize, cache: Option<&Arc<UnitCache>>) -> Report {
    let mut engine = Engine::new(jobs);
    if let Some(c) = cache {
        engine = engine.with_cache(Arc::clone(c));
    }
    let cfg = ChipConfig::default();
    let sims = repro::run_fig13_sims(&engine, &cfg, 1, 42);
    let mut r = repro::fig13(&sims);
    if let Some(c) = cache {
        c.stats().annotate(&mut r);
    }
    r
}

/// A synthetic `tensordash.frontier.v1` report with the real column
/// layout (`search::explore::frontier_report`).
fn frontier_report(points: &[(&str, u64, f64, f64)]) -> Report {
    let mut r = Report::with_schema(
        FRONTIER_SCHEMA,
        "explore_frontier",
        "synthetic frontier",
        &["config", "td cycles", "speedup", "energy pJ", "energy eff", "area mm2", "gen"],
    );
    for (label, cycles, energy, area) in points {
        r.row(vec![
            Cell::text(label.to_string()),
            Cell::fmt(cycles.to_string(), *cycles as f64),
            Cell::num(1.5),
            Cell::fmt(format!("{energy:.3e}"), *energy),
            Cell::num(1.0),
            Cell::num(*area),
            Cell::fmt("0".to_string(), 0.0),
        ]);
    }
    r.meta_num("seed", 42.0);
    r
}

fn parse(report: &Report) -> Json {
    Json::parse(&report.render_json()).expect("report JSON parses")
}

#[test]
fn sealed_store_reopens_on_the_indexed_fast_path() {
    let db = temp_db("fastpath");
    let doc = parse(&frontier_report(&[("a", 100, 1e3, 1.0)]));
    {
        let mut store = ExperimentStore::open(&db).unwrap();
        assert_eq!(store.ingest_json(&doc, "c1").unwrap(), 1);
        store.commit().unwrap();
    }
    let mut store = ExperimentStore::open(&db).unwrap();
    let stats = store.log_stats();
    assert!(stats.fast_path, "sealed file must reopen without a scan: {stats:?}");
    assert_eq!(stats.truncated_bytes, 0);
    assert_eq!(store.len(), 1);
    let cat = store.query(&QueryFilter::default()).unwrap();
    assert_eq!(cat.rows.len(), 1);
    let _ = std::fs::remove_file(&db);
}

#[test]
fn every_registered_schema_ingests_including_reportsets() {
    let db = temp_db("schemas");
    let mut store = ExperimentStore::open(&db).unwrap();

    let mut report = Report::new("fig13", "t", &["model", "overall"]);
    report.row(vec![Cell::text("alexnet"), Cell::num(2.0)]);
    report.meta_num("seed", 7.0);
    let mut layers = Report::with_schema(LAYERS_SCHEMA, "layers", "t", &["layer", "speedup"]);
    layers.row(vec![Cell::text("conv1"), Cell::num(1.5)]);
    let frontier = frontier_report(&[("cfg0", 100, 1e3, 1.0)]);
    let bench = Json::parse(concat!(
        r#"{"schema":"tensordash.bench.v1","bench":"store_warmstart","records":"#,
        r#"[{"name":"store_warmstart_speedup","median_ns":10.0,"speedup":3.0}]}"#,
    ))
    .unwrap();
    let set = tensordash::api::report_set_json(&[report, layers]);

    assert_eq!(store.ingest_json(&set, "c1").unwrap(), 2, "reportset unwraps to members");
    assert_eq!(store.ingest_json(&parse(&frontier), "c1").unwrap(), 1);
    assert_eq!(store.ingest_json(&bench, "c1").unwrap(), 1);
    store.commit().unwrap();
    assert_eq!(store.len(), 4, "report + layers + frontier + bench");
    assert_eq!(registered_schemas().len(), 5, "alias table covers every schema");

    // Schema-alias filtering and a bench-record trajectory.
    let f = QueryFilter { schema: Some("bench".to_string()), ..QueryFilter::default() };
    assert_eq!(store.query(&f).unwrap().rows.len(), 1);
    let f = QueryFilter {
        schema: Some("bench".to_string()),
        metric: Some("speedup".to_string()),
        ..QueryFilter::default()
    };
    let traj = store.query(&f).unwrap();
    assert_eq!(traj.rows.len(), 1);
    assert_eq!(traj.value(0, "speedup"), Some(3.0));
    let _ = std::fs::remove_file(&db);
}

#[test]
fn unknown_schema_ingestion_is_a_typed_error() {
    let db = temp_db("unknown");
    let mut store = ExperimentStore::open(&db).unwrap();
    let bad = std::env::temp_dir().join(format!("td_hist_bad_{}.json", std::process::id()));
    std::fs::write(&bad, "{\"schema\":\"tensordash.mystery.v9\",\"rows\":[]}\n").unwrap();
    let err = store.ingest_file(&bad, "c1").unwrap_err();
    assert!(
        matches!(&err, StoreError::UnknownSchema(s) if s == "tensordash.mystery.v9"),
        "want UnknownSchema, got {err}"
    );
    assert!(store.is_empty(), "a rejected document must not be stored");
    let _ = std::fs::remove_file(&bad);
    let _ = std::fs::remove_file(&db);
}

#[test]
fn reingest_is_idempotent_and_updates_compact_away() {
    let db = temp_db("idem");
    let doc = parse(&frontier_report(&[("a", 100, 1e3, 1.0)]));
    {
        let mut store = ExperimentStore::open(&db).unwrap();
        assert_eq!(store.ingest_json(&doc, "c1").unwrap(), 1);
        store.commit().unwrap();
    }
    let size1 = std::fs::metadata(&db).unwrap().len();
    {
        let mut store = ExperimentStore::open(&db).unwrap();
        assert_eq!(store.ingest_json(&doc, "c1").unwrap(), 0, "identical re-ingest is a no-op");
        store.commit().unwrap();
        assert_eq!(store.len(), 1);
    }
    assert_eq!(
        std::fs::metadata(&db).unwrap().len(),
        size1,
        "idempotent re-ingest must not grow the file"
    );

    // Same key, different payload: a last-wins update...
    let doc2 = parse(&frontier_report(&[("a", 90, 1e3, 1.0)]));
    let mut store = ExperimentStore::open(&db).unwrap();
    assert_eq!(store.ingest_json(&doc2, "c1").unwrap(), 1, "update writes a new version");
    assert_eq!(store.len(), 1, "...under the same key");
    let f = QueryFilter { metric: Some("td cycles".to_string()), ..QueryFilter::default() };
    assert_eq!(store.query(&f).unwrap().value(0, "td cycles"), Some(90.0));
    // ...whose superseded version compaction drops.
    let grown = std::fs::metadata(&db).unwrap().len();
    store.compact().unwrap();
    let compacted = std::fs::metadata(&db).unwrap().len();
    assert!(compacted < grown, "compact must shrink {grown} -> {compacted}");
    assert_eq!(store.query(&f).unwrap().value(0, "td cycles"), Some(90.0));
    let _ = std::fs::remove_file(&db);
}

#[test]
fn a_torn_tail_write_recovers_to_the_last_good_frame() {
    let db = temp_db("torn");
    let doc = parse(&frontier_report(&[("a", 100, 1e3, 1.0)]));
    let golden;
    {
        let mut store = ExperimentStore::open(&db).unwrap();
        store.ingest_json(&doc, "c1").unwrap();
        store.commit().unwrap();
        golden = store.query(&QueryFilter::default()).unwrap().render_json();
    }
    // Crash mid-append: garbage bytes after the sealed image.
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new().append(true).open(&db).unwrap();
    f.write_all(&[0xAB; 9]).unwrap();
    drop(f);

    let mut store = ExperimentStore::open(&db).unwrap();
    let stats = store.log_stats();
    assert!(!stats.fast_path, "a torn tail invalidates the trailer: {stats:?}");
    assert!(stats.truncated_bytes > 0, "recovery must truncate: {stats:?}");
    assert_eq!(store.len(), 1, "the committed record survives");
    assert_eq!(store.query(&QueryFilter::default()).unwrap().render_json(), golden);
    let _ = std::fs::remove_file(&db);
}

#[test]
fn query_bytes_are_identical_across_jobs_and_cache_modes() {
    let reference_db = temp_db("ref");
    let mut reference = ExperimentStore::open(&reference_db).unwrap();
    reference.ingest_json(&parse(&fig13_report(1, None)), "c1").unwrap();
    let catalog = reference.query(&QueryFilter::default()).unwrap().render_json();
    let traj_filter = QueryFilter {
        metric: Some("overall".to_string()),
        model: Some("gcn".to_string()),
        ..QueryFilter::default()
    };
    let trajectory = reference.query(&traj_filter).unwrap().render_json();
    assert!(!reference.query(&traj_filter).unwrap().rows.is_empty());

    for jobs in [1usize, 4, 8] {
        let cache = Arc::new(UnitCache::new(65_536));
        let cold = fig13_report(jobs, Some(&cache));
        let warm = fig13_report(jobs, Some(&cache));
        for (mode, report) in [("cold", &cold), ("warm", &warm)] {
            let db = temp_db(&format!("q{jobs}{mode}"));
            let mut store = ExperimentStore::open(&db).unwrap();
            store.ingest_json(&parse(report), "c1").unwrap();
            let ctx = format!("jobs={jobs} {mode}");
            assert_eq!(
                store.query(&QueryFilter::default()).unwrap().render_json(),
                catalog,
                "{ctx}: catalog bytes"
            );
            assert_eq!(
                store.query(&traj_filter).unwrap().render_json(),
                trajectory,
                "{ctx}: trajectory bytes"
            );
            let _ = std::fs::remove_file(&db);
        }
        // Warm and cold differ only in unit_cache_* telemetry, which
        // the config hash excludes — both land under one store key.
        let db = temp_db(&format!("key{jobs}"));
        let mut store = ExperimentStore::open(&db).unwrap();
        store.ingest_json(&parse(&cold), "c1").unwrap();
        store.ingest_json(&parse(&warm), "c1").unwrap();
        assert_eq!(store.len(), 1, "jobs={jobs}: warm/cold share a key");
        let _ = std::fs::remove_file(&db);
    }
    let _ = std::fs::remove_file(&reference_db);
}

#[test]
fn frontier_diff_classifies_by_pareto_dominance() {
    let db = temp_db("fdiff");
    let mut store = ExperimentStore::open(&db).unwrap();
    // c1: a, b, c. c2: a kept, d added; d dominates b (all axes <=,
    // some <) but not c (c has fewer cycles).
    let from = frontier_report(&[
        ("a", 100, 1e3, 1.0),
        ("b", 200, 2e3, 2.0),
        ("c", 50, 9e3, 9.0),
    ]);
    let to = frontier_report(&[("a", 100, 1e3, 1.0), ("d", 80, 9e2, 0.9)]);
    store.ingest_json(&parse(&from), "c1").unwrap();
    store.ingest_json(&parse(&to), "c2").unwrap();

    let diff = store.diff("explore_frontier", "c1", "c2").unwrap();
    let got: Vec<(String, String)> = diff
        .rows
        .iter()
        .map(|r| (r.cells[0].text.clone(), r.cells[1].text.clone()))
        .collect();
    let want = [
        ("a", "kept"),
        ("d", "added"),
        ("b", "newly-dominated"),
        ("c", "removed"),
    ];
    let want: Vec<(String, String)> =
        want.iter().map(|(l, s)| (l.to_string(), s.to_string())).collect();
    assert_eq!(got, want);
    assert_eq!(diff.meta.get("from").and_then(Json::as_str), Some("c1"));
    assert_eq!(diff.value(0, "td cycles"), Some(100.0));

    let err = store.diff("explore_frontier", "c1", "c9").unwrap_err();
    assert!(matches!(err, StoreError::NotFound(_)), "missing commit must be NotFound");
    let _ = std::fs::remove_file(&db);
}
