//! Determinism contract of the plan/executor unit graph.
//!
//! The refactor's load-bearing property: a model simulation is a set of
//! independent (layer, op) units with derived per-unit seeds, so the
//! result is a pure function of the request — independent of worker
//! count, work-stealing interleave, and unit execution order. These
//! tests pin that contract:
//!
//! * `simulate` through the pooled executor is **byte-identical** across
//!   `--jobs {1, 4, 8}` — reports (text/JSON/CSV), per-layer tables and
//!   scheduler-cache telemetry included;
//! * executing the units in a shuffled order and merging in plan order
//!   reproduces the same bytes;
//! * the pooled executor matches the serial reference walk
//!   (`ModelPlan::execute_serial`, which also backs
//!   `repro::simulate_profile`) on two models at two epochs — the
//!   golden differential baseline for the executor.

use tensordash::api::{layers_report, Engine, ModelPlan, SimRequest, LAYERS_SCHEMA};
use tensordash::config::ChipConfig;
use tensordash::repro::{simulate_profile, ModelSim};
use tensordash::sim::unit::LayerOpSim;
use tensordash::trace::profiles::ModelProfile;
use tensordash::util::json::Json;
use tensordash::util::rng::Rng;

const MODELS: [&str; 2] = ["alexnet", "gcn"];
const EPOCHS: [f64; 2] = [0.1, 0.9];
const SEED: u64 = 42;
const SAMPLES: usize = 1;

fn profile_request(model: &str, epoch: f64) -> SimRequest {
    SimRequest::profile(model, epoch, ChipConfig::default(), SAMPLES, SEED)
        .expect("known model")
}

/// Byte-level equality of two merged sims: every integer counter, every
/// f64 down to its bit pattern, every retained unit.
fn assert_bit_identical(a: &ModelSim, b: &ModelSim, ctx: &str) {
    assert_eq!(a.name, b.name, "{ctx}: name");
    assert_eq!(a.per_op, b.per_op, "{ctx}: per-op cycles");
    assert_eq!(a.sched, b.sched, "{ctx}: scheduler telemetry");
    assert_eq!(
        a.energy_base.total_pj().to_bits(),
        b.energy_base.total_pj().to_bits(),
        "{ctx}: baseline energy bits"
    );
    assert_eq!(
        a.energy_td.total_pj().to_bits(),
        b.energy_td.total_pj().to_bits(),
        "{ctx}: TensorDash energy bits"
    );
    assert_eq!(a.layers.len(), b.layers.len(), "{ctx}: unit count");
    for (i, (ua, ub)) in a.layers.iter().zip(&b.layers).enumerate() {
        assert_eq!(ua, ub, "{ctx}: unit {i}");
    }
}

#[test]
fn jobs_1_4_8_are_byte_identical_including_per_layer_tables() {
    for model in MODELS {
        for epoch in EPOCHS {
            let req = profile_request(model, epoch);
            let baseline = Engine::new(1).run(&req);
            let base_layers = layers_report(&baseline);
            for jobs in [4usize, 8] {
                let sim = Engine::new(jobs).run(&req);
                assert_bit_identical(&baseline, &sim, &format!("{model}@{epoch} jobs={jobs}"));
                // The rendered artifacts — summary and per-layer table —
                // must agree byte for byte in every format.
                let layers = layers_report(&sim);
                assert_eq!(base_layers, layers);
                assert_eq!(
                    base_layers.render_json().into_bytes(),
                    layers.render_json().into_bytes()
                );
                assert_eq!(base_layers.render_text(), layers.render_text());
                assert_eq!(base_layers.render_csv(), layers.render_csv());
            }
        }
    }
}

#[test]
fn shuffled_unit_execution_order_reproduces_the_serial_bytes() {
    let req = profile_request("alexnet", 0.4);
    let plan = ModelPlan::for_request(&req).expect("profile requests lower to plans");
    let serial = plan.execute_serial();

    // Execute the units in a deterministic but scrambled order, then
    // merge in plan order — the executor's re-assembly contract.
    let n = plan.units.len();
    let mut order: Vec<usize> = (0..n).collect();
    let mut rng = Rng::new(0xD15C0);
    for i in (1..n).rev() {
        order.swap(i, rng.below(i + 1));
    }
    assert_ne!(order, (0..n).collect::<Vec<_>>(), "shuffle must actually shuffle");
    let mut slots: Vec<Option<LayerOpSim>> = vec![None; n];
    for &i in &order {
        slots[i] = Some(plan.units[i].execute(&plan.cfg));
    }
    let shuffled = plan.merge(slots.into_iter().map(|s| s.unwrap()));
    assert_bit_identical(&serial, &shuffled, "shuffled execution");
}

#[test]
fn pooled_executor_matches_the_serial_reference_on_two_models_two_epochs() {
    // Golden differential baseline: `repro::simulate_profile` is the
    // plain serial walk of the plan (the pre-pool execution path); the
    // pooled executor must reproduce it exactly.
    for model in MODELS {
        for epoch in EPOCHS {
            let p = ModelProfile::for_model(model).unwrap();
            let reference = simulate_profile(&ChipConfig::default(), &p, epoch, SAMPLES, SEED);
            let pooled = Engine::new(8).run(&profile_request(model, epoch));
            assert_bit_identical(&reference, &pooled, &format!("{model}@{epoch}"));
        }
    }
}

#[test]
fn per_layer_report_is_schema_valid_at_any_worker_count() {
    let req = profile_request("gcn", 0.4);
    for jobs in [1usize, 4, 8] {
        let sim = Engine::new(jobs).run(&req);
        let r = layers_report(&sim);
        assert_eq!(r.schema, LAYERS_SCHEMA);
        assert_eq!(r.rows.len(), sim.layers.len());
        let parsed = Json::parse(&r.render_json()).expect("layers JSON parses");
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some(LAYERS_SCHEMA));
        // Speedup column carries raw values within the structural caps.
        for i in 0..sim.layers.len() {
            let v = r.value(i, "speedup").expect("numeric speedup cell");
            assert!((1.0..=3.01).contains(&v), "unit {i}: speedup {v}");
        }
    }
}

#[test]
fn repeated_runs_are_stable() {
    // Same request, same engine, run twice: nothing (thread timing,
    // allocator state) may leak into the result.
    let req = profile_request("gcn", 0.9);
    let e = Engine::new(4);
    assert_bit_identical(&e.run(&req), &e.run(&req), "repeat");
}
