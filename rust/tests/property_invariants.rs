//! Property-based tests on the coordinator/simulator invariants.
//!
//! proptest is unavailable in this offline environment, so these are
//! hand-rolled property tests: seeded random-case generators + shrink-free
//! assertions over many trials. Each property is the kind of invariant
//! the paper's hardware must uphold by construction.

use tensordash::config::ChipConfig;
use tensordash::conv::work::{build_stream, op_work, sample_passes};
use tensordash::conv::{ConvShape, TrainOp, WgradSide};
use tensordash::sim::connectivity::{Connectivity, LANES};
use tensordash::sim::pe::{effectual_macs, simulate_stream_stats};
use tensordash::sim::scheduler::{schedule_cycle, IDLE};
use tensordash::sim::tile::{tile_pass_stats, DEFAULT_LEAD_LIMIT};
use tensordash::tensor::{compress_one_side, decompress, TensorBitmap};
use tensordash::trace::synthetic::{clustered_bitmap, random_bitmap};
use tensordash::util::rng::Rng;

const TRIALS: usize = 300;

/// Property: every schedule is VALID — each selected option maps to an
/// effectual slot, no slot is consumed twice, and every head-row bit is
/// consumed (liveness).
#[test]
fn prop_schedule_validity() {
    for depth in [2usize, 3] {
        let conn = Connectivity::new(depth);
        let mut rng = Rng::new(0xDA5);
        for _ in 0..TRIALS * 10 {
            let z = rng.next_u64() & conn.window_mask();
            let s = schedule_cycle(&conn, z);
            assert_eq!(s.picks & !z, 0, "picked ineffectual slot");
            let mut seen = 0u64;
            for lane in 0..LANES {
                if s.ms[lane] == IDLE {
                    continue;
                }
                let bit = 1u64 << conn.lanes[lane].bits[s.ms[lane] as usize];
                assert_eq!(seen & bit, 0, "slot consumed twice");
                seen |= bit;
            }
            assert_eq!(seen, s.picks, "picks bookkeeping");
            // Liveness: head row always drains.
            assert_eq!((z & !s.picks) & 0xFFFF, 0, "head row bit survived");
            assert!(s.advance >= 1 || z == 0 || depth == 0);
        }
    }
}

/// Property: the PE never loses or duplicates work, never slows down,
/// and respects the structural speedup caps.
#[test]
fn prop_pe_work_conservation_and_bounds() {
    let mut rng = Rng::new(0xBEEF);
    for depth in [2usize, 3] {
        let conn = Connectivity::new(depth);
        for _ in 0..TRIALS {
            let len = 1 + rng.below(80);
            let density = rng.f64();
            let rows: Vec<u16> = (0..len).map(|_| rng.mask16(density)).collect();
            let stats = simulate_stream_stats(&conn, &rows);
            assert_eq!(stats.macs, effectual_macs(&rows), "work conservation");
            assert!(stats.cycles <= len as u64, "slower than baseline");
            let min_cycles = (effectual_macs(&rows).div_ceil(16))
                .max((len as u64).div_ceil(depth as u64))
                .min(len as u64)
                .max(u64::from(len > 0));
            assert!(stats.cycles >= min_cycles, "beat the structural caps");
        }
    }
}

/// Property: tile-level run is work conserving, bounded by the slowest
/// row, and monotone in the lead bound.
#[test]
fn prop_tile_bounds_and_lead_monotonicity() {
    let conn = Connectivity::new(3);
    let mut rng = Rng::new(0x711E);
    for _ in 0..80 {
        let n_rows = 1 + rng.below(8);
        let len = 5 + rng.below(40);
        let streams: Vec<Vec<u16>> = (0..n_rows)
            .map(|_| {
                let d = rng.f64();
                (0..len).map(|_| rng.mask16(d)).collect()
            })
            .collect();
        let total: u64 = streams.iter().map(|s| effectual_macs(s)).sum();
        let mut last = None;
        // Wider lead bounds can only help.
        for lead in [0usize, 2, DEFAULT_LEAD_LIMIT, 1000] {
            let st = tile_pass_stats(&conn, &streams, lead);
            assert_eq!(st.macs, total, "tile work conservation");
            assert!(st.cycles <= len as u64);
            if let Some(prev) = last {
                assert!(st.cycles <= prev, "lead {lead} slower than tighter bound");
            }
            last = Some(st.cycles);
        }
    }
}

/// Property: scheduled-form compression round-trips losslessly at any
/// sparsity and never exceeds the depth-x compression cap.
#[test]
fn prop_scheduled_roundtrip() {
    let mut rng = Rng::new(0xC0DE);
    for depth in [2usize, 3] {
        let conn = Connectivity::new(depth);
        for _ in 0..TRIALS {
            let len = rng.below(60);
            let density = rng.f64();
            let dense: Vec<[f32; LANES]> = (0..len)
                .map(|_| {
                    let mut row = [0f32; LANES];
                    for v in row.iter_mut() {
                        if rng.chance(density) {
                            *v = (rng.next_u64() % 1000 + 1) as f32;
                        }
                    }
                    row
                })
                .collect();
            let st = compress_one_side(&conn, &dense);
            assert_eq!(decompress(&conn, &st), dense, "round trip");
            assert!(st.compression() <= depth as f64 + 1e-9);
        }
    }
}

/// Property: every stream builder covers exactly the effectual MACs the
/// tensor implies — summed over all B streams of an op, the stream bits
/// equal the operand's non-zero count times its fan-out.
#[test]
fn prop_stream_builders_cover_tensor() {
    let mut rng = Rng::new(0x57E);
    for trial in 0..24 {
        let stride = 1 + (trial % 2);
        let s = ConvShape::conv(2, 6, 6, 16, 16, 3, stride, 1);
        let a = random_bitmap((2, 6, 6, 16), 0.5, &mut rng);
        let g = random_bitmap((2, s.out_h(), s.out_w(), 16), 0.5, &mut rng);

        // Fwd: each A element appears once per window that covers it;
        // total bits == effectual taps == sum over windows of non-zeros.
        let w = op_work(&s, TrainOp::Fwd, WgradSide::Gradients);
        let mut bits = 0u64;
        for b in 0..w.b_groups {
            bits += build_stream(&s, TrainOp::Fwd, WgradSide::Gradients, &a, &g, b)
                .iter()
                .map(|r| r.count_ones() as u64)
                .sum::<u64>();
        }
        // Cross-check against a direct tap count.
        let mut want = 0u64;
        for n in 0..2 {
            for oy in 0..s.out_h() {
                for ox in 0..s.out_w() {
                    for ky in 0..3 {
                        for kx in 0..3 {
                            let iy = (oy * stride + ky) as isize - 1;
                            let ix = (ox * stride + kx) as isize - 1;
                            if iy < 0 || ix < 0 || iy >= 6 || ix >= 6 {
                                continue;
                            }
                            for c in 0..16 {
                                if a.bit(n, iy as usize, ix as usize, c) {
                                    want += 1;
                                }
                            }
                        }
                    }
                }
            }
        }
        assert_eq!(bits, want, "fwd stream bit coverage (stride {stride})");

        // Wgrad with B=G: every gradient element appears exactly once per
        // stream, one stream per filter channel.
        let mut gbits = 0u64;
        for f in 0..16u64 {
            gbits += build_stream(&s, TrainOp::Wgrad, WgradSide::Gradients, &a, &g, f)
                .iter()
                .map(|r| r.count_ones() as u64)
                .sum::<u64>();
        }
        assert_eq!(gbits, g.nonzeros(), "wgrad stream covers G exactly once");
    }
}

/// Property: igrad streams reference every gradient element exactly
/// (kh*kw) times across all input positions at stride 1 (full conv).
#[test]
fn prop_igrad_fanout() {
    let mut rng = Rng::new(0x16);
    let s = ConvShape::conv(1, 6, 6, 16, 16, 3, 1, 1);
    let g = random_bitmap((1, 6, 6, 16), 0.4, &mut rng);
    let mut bits = 0u64;
    let empty = TensorBitmap::from_f32((1, 6, 6, 16), &vec![0.0; 6 * 6 * 16]);
    for b in 0..(s.n * s.h * s.w) as u64 {
        bits += build_stream(&s, TrainOp::Igrad, WgradSide::Gradients, &empty, &g, b)
            .iter()
            .map(|r| r.count_ones() as u64)
            .sum::<u64>();
    }
    // Each gradient at (oy, ox) feeds inputs y = oy - 1 .. oy + 1 (those
    // inside bounds): interior gradients 9 taps, edges fewer.
    let mut want = 0u64;
    for oy in 0..6usize {
        for ox in 0..6usize {
            let fan_y = (oy.min(5 - oy) + 2).min(3) as u64;
            let fan_x = (ox.min(5 - ox) + 2).min(3) as u64;
            for c in 0..16 {
                if g.bit(0, oy, ox, c) {
                    want += fan_y.min(3) * fan_x.min(3);
                }
            }
        }
    }
    assert_eq!(bits, want, "igrad fan-out");
}

/// Property: sampled pass weights always sum to the exact total pass
/// count, for arbitrary geometry.
#[test]
fn prop_sampling_weights_exact() {
    let mut rng = Rng::new(0x5A);
    for _ in 0..40 {
        let hw = 4 + rng.below(6);
        let s = ConvShape::conv(1 + rng.below(3), hw, hw, 16, 16, 3, 1, 1);
        let a = random_bitmap((s.n, s.h, s.w, 16), 0.5, &mut rng);
        let g = random_bitmap((s.n, s.out_h(), s.out_w(), 16), 0.5, &mut rng);
        let rows = 1 + rng.below(8);
        let budget = 1 + rng.below(10);
        let passes = sample_passes(
            &s,
            TrainOp::Fwd,
            WgradSide::Gradients,
            &a,
            &g,
            rows,
            budget,
            1,
            &mut rng,
        );
        let total: u64 = passes.iter().map(|p| p.weight).sum();
        let want = ((s.n * s.out_h() * s.out_w()) as u64).div_ceil(rows as u64);
        assert_eq!(total, want);
    }
}

/// Property: whole-model simulation never reports a slowdown and stays
/// within the structural 3x cap, at any sparsity profile.
#[test]
fn prop_model_sim_bounds() {
    let cfg = ChipConfig::default();
    let mut rng = Rng::new(0xF00);
    for trial in 0..10 {
        let sp = trial as f64 / 10.0;
        let s = ConvShape::conv(2, 8, 8, 32, 32, 3, 1, 1);
        let a = clustered_bitmap((2, 8, 8, 32), sp, 0.35, &mut rng);
        let g = clustered_bitmap((2, 8, 8, 32), sp, 0.35, &mut rng);
        for op in TrainOp::ALL {
            let r = tensordash::repro::simulate_layer_op(&cfg, &s, op, &a, &g, 4, 8, &mut rng);
            assert!(r.speedup() >= 1.0 - 1e-9, "{op:?} slowdown at sparsity {sp}");
            assert!(r.speedup() <= 3.0 + 1e-9, "{op:?} beat the cap at {sp}");
        }
    }
}
