//! Bench + regeneration of Fig. 17 (speedup vs PE rows) and Fig. 18
//! (speedup vs PE columns).
//!
//! Anchors: rows 1 -> 16 declines (~2.1x -> ~1.7x, inter-row work
//! imbalance on the shared operand); columns barely matter.

use tensordash::api::Engine;
use tensordash::repro;
use tensordash::util::bench::{bench, section};

fn main() {
    let engine = Engine::parallel();
    section("Fig. 17 reproduction (rows)");
    repro::fig17_rows(&engine, 4, 42).print();
    section("Fig. 18 reproduction (columns)");
    repro::fig18_cols(&engine, 4, 42).print();
    section("timing (16-row tile pass)");
    let conn = tensordash::sim::Connectivity::new(3);
    let mut rng = tensordash::util::rng::Rng::new(1);
    let streams: Vec<Vec<u16>> = (0..16)
        .map(|_| (0..128).map(|_| rng.mask16(0.4)).collect())
        .collect();
    bench("tile_pass_16rows_128steps", 10, 200, || {
        tensordash::sim::tile::tile_pass_stats(&conn, &streams, 6)
    });
}
