//! §Perf — the design-space explorer: a warm (cache-shared) explore
//! run racing the same run cold.
//!
//! Exploration is the workload the unit cache was built for: every
//! generation re-evaluates its survivors, and a repeated search (the
//! serving pattern — HASS-style clients iterating on a space) replays
//! whole candidate sets. Warm and cold frontiers are asserted
//! **byte-identical** before anything is timed — the speedup is only
//! meaningful if the cache returns exactly what the cold path computes.
//!
//! Emits medians and the warm-over-cold speedup as
//! `BENCH_explore.json` (`$BENCH_OUT` overrides; `tensordash.bench.v1`),
//! gated by `ci/bench_floors.json` next to the other BENCH artifacts.
//! The bench itself exits non-zero below 2x warm-over-cold.

use std::collections::BTreeMap;
use std::sync::Arc;

use tensordash::api::{default_jobs, Engine, UnitCache, DEFAULT_CACHE_CAP};
use tensordash::search::{explore, frontier_report, ExploreSpec, SearchSpace};
use tensordash::util::bench::{bench, section, BenchStats};
use tensordash::util::json::Json;

fn record(name: &str, s: &BenchStats) -> Json {
    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::Str(name.to_string()));
    m.insert("median_ns".to_string(), Json::Num(s.median_ns));
    m.insert("mean_ns".to_string(), Json::Num(s.mean_ns));
    m.insert("min_ns".to_string(), Json::Num(s.min_ns));
    m.insert("stddev_ns".to_string(), Json::Num(s.stddev_ns));
    m.insert("iters".to_string(), Json::Num(s.iters as f64));
    Json::Obj(m)
}

fn main() {
    let mut space = SearchSpace::trivial();
    space.set_axis("staging_depth", &["2", "3"]).expect("static axis values");
    space.set_axis("tile_rows", &["2", "4", "8"]).expect("static axis values");
    let spec = ExploreSpec::new(space, &["alexnet"], 0.4, 2, 42, 4).expect("known model");
    let jobs = default_jobs().clamp(2, 8);

    section(&format!(
        "design-space explorer: budget {} over alexnet, warm vs cold (jobs={jobs})",
        spec.budget
    ));

    // Byte-identity first: cold cached == warm == uncached reference.
    let reference = frontier_report(&spec, &explore(&Engine::new(jobs), &spec));
    let warm_cache = Arc::new(UnitCache::new(DEFAULT_CACHE_CAP));
    let warm_engine = Engine::new(jobs).with_cache(Arc::clone(&warm_cache));
    let cold_res = explore(&warm_engine, &spec);
    let warm_res = explore(&warm_engine, &spec);
    let cold_report = frontier_report(&spec, &cold_res);
    let warm_report = frontier_report(&spec, &warm_res);
    assert_eq!(
        reference.render_json(),
        cold_report.render_json(),
        "cold cached explore must equal the uncached run"
    );
    assert_eq!(
        cold_report.render_json(),
        warm_report.render_json(),
        "warm explore must be byte-identical to cold"
    );
    let s = warm_cache.stats();
    println!(
        "  result: {} evaluations, frontier {} — byte-identical warm and cold \
         (cache {} hits / {} misses)",
        cold_res.evaluated.len(),
        cold_res.frontier.len(),
        s.hits,
        s.misses
    );

    // Cold: a fresh cache every iteration (first-search latency).
    let cold = bench("explore_cold", 1, 5, || {
        let cache = Arc::new(UnitCache::new(DEFAULT_CACHE_CAP));
        explore(&Engine::new(jobs).with_cache(cache), &spec).evaluated.len()
    });
    // Warm: the persistent cache (steady-state / repeated-search latency).
    let warm = bench("explore_warm", 1, 5, || explore(&warm_engine, &spec).evaluated.len());
    let speedup = cold.median_ns / warm.median_ns;
    println!("  -> warm explore {speedup:.2}x faster than cold");

    let mut speedup_rec = BTreeMap::new();
    speedup_rec.insert("name".to_string(), Json::Str("warm_explore_speedup".to_string()));
    speedup_rec.insert("cold_median_ns".to_string(), Json::Num(cold.median_ns));
    speedup_rec.insert("warm_median_ns".to_string(), Json::Num(warm.median_ns));
    speedup_rec.insert("speedup".to_string(), Json::Num(speedup));
    speedup_rec.insert("jobs".to_string(), Json::Num(jobs as f64));
    let records = vec![
        record("explore_cold", &cold),
        record("explore_warm", &warm),
        Json::Obj(speedup_rec),
    ];

    let out_path =
        std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_explore.json".to_string());
    let mut doc = BTreeMap::new();
    doc.insert("schema".to_string(), Json::Str("tensordash.bench.v1".to_string()));
    doc.insert("bench".to_string(), Json::Str("explore_hotpath".to_string()));
    doc.insert("records".to_string(), Json::Arr(records));
    let mut text = Json::Obj(doc).render_pretty();
    text.push('\n');
    match std::fs::write(&out_path, text.as_bytes()) {
        Ok(()) => println!("\nwrote {out_path} ({} bytes)", text.len()),
        Err(e) => eprintln!("\ncould not write {out_path}: {e}"),
    }

    // Acceptance bar (EXPERIMENTS.md §Perf), enforced after the
    // artifact is on disk so a regressing run is still archived: a
    // warm (cache-shared) explore must be >= 2x faster than cold.
    const WARM_SPEEDUP_GATE: f64 = 2.0;
    if speedup < WARM_SPEEDUP_GATE {
        eprintln!(
            "PERF GATE: warm explore speedup {speedup:.2}x < {WARM_SPEEDUP_GATE}x — \
             the unit cache stopped paying for the search workload"
        );
        std::process::exit(1);
    }
    println!("perf gate passed: warm {speedup:.2}x >= {WARM_SPEEDUP_GATE}x");
}
