//! §Perf — warm-starting the unit cache from its store-backed disk
//! mirror: one indexed record-log file racing a cold recompute.
//!
//! PR-6 re-seated the disk mirror on the experiment store's record log
//! (`rust/src/store/log.rs`): a warm process start opens **one**
//! compacted, indexed file instead of thousands of per-key files, reads
//! the frames its lookups need, and skips simulation entirely. Warm and
//! cold results are asserted **byte-identical** before anything is
//! timed — the mirror is only worth its disk if it returns exactly what
//! the cold path computes.
//!
//! Emits medians and the warm-over-cold speedup as `BENCH_store.json`
//! (`$BENCH_OUT` overrides; `tensordash.bench.v1`), which CI archives,
//! ingests into the experiment store, and gates through
//! `ci/bench_floors.json`. The bench itself exits non-zero below 2x
//! warm-over-cold.

use std::collections::BTreeMap;
use std::sync::Arc;

use tensordash::api::{default_jobs, Engine, SweepSpec, UnitCache, DEFAULT_CACHE_CAP};
use tensordash::config::ChipConfig;
use tensordash::repro::ModelSim;
use tensordash::util::bench::{bench, section, BenchStats};
use tensordash::util::json::Json;

fn record(name: &str, s: &BenchStats) -> Json {
    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::Str(name.to_string()));
    m.insert("median_ns".to_string(), Json::Num(s.median_ns));
    m.insert("mean_ns".to_string(), Json::Num(s.mean_ns));
    m.insert("min_ns".to_string(), Json::Num(s.min_ns));
    m.insert("stddev_ns".to_string(), Json::Num(s.stddev_ns));
    m.insert("iters".to_string(), Json::Num(s.iters as f64));
    Json::Obj(m)
}

fn assert_identical(a: &ModelSim, b: &ModelSim, ctx: &str) {
    assert_eq!(a.per_op, b.per_op, "{ctx}: cycles diverged");
    assert_eq!(a.sched, b.sched, "{ctx}: telemetry diverged");
    assert_eq!(
        a.energy_td.total_pj().to_bits(),
        b.energy_td.total_pj().to_bits(),
        "{ctx}: energy bits diverged"
    );
    assert_eq!(a.layers, b.layers, "{ctx}: per-unit results diverged");
}

fn main() {
    let samples = 2; // keeps a bench iteration in seconds, not minutes
    let seed = 42;
    let models = ["alexnet", "gcn"];
    let cfg = ChipConfig::default();
    let cells = SweepSpec::models(&models, 0.4, &cfg, samples, seed).cells();
    let jobs = default_jobs().clamp(2, 8);
    let dir = std::env::temp_dir().join(format!("td_warmstart_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    section(&format!(
        "store-backed warm start: {}-model sweep from one record-log file \
         (samples={samples}, jobs={jobs})",
        models.len()
    ));

    // Populate the mirror once; dropping the cache seals the log so
    // every warm start below reopens on the indexed fast path.
    let reference = Engine::new(jobs).run_all(&cells);
    {
        let cache = Arc::new(UnitCache::new(DEFAULT_CACHE_CAP).with_disk(&dir).unwrap());
        let populated = Engine::new(jobs).with_cache(Arc::clone(&cache)).run_all(&cells);
        for (r, p) in reference.iter().zip(&populated) {
            assert_identical(r, p, &format!("populate {}", r.name));
        }
    }

    // Byte-identity first: a fresh process image served purely from the
    // store file must reproduce the uncached reference bit for bit.
    let units: usize = reference.iter().map(|m| m.layers.len()).sum();
    let warm_cache = Arc::new(UnitCache::new(DEFAULT_CACHE_CAP).with_disk(&dir).unwrap());
    let log = warm_cache.disk_stats().unwrap();
    assert!(log.fast_path, "sealed mirror must reopen without a scan: {log:?}");
    let warm_sims = Engine::new(jobs).with_cache(Arc::clone(&warm_cache)).run_all(&cells);
    for (r, w) in reference.iter().zip(&warm_sims) {
        assert_identical(r, w, &format!("warm {}", r.name));
    }
    let s = warm_cache.stats();
    assert_eq!(s.disk_hits as usize, units, "every unit must come from the store: {s:?}");
    assert_eq!(s.misses, 0, "a warm start must not recompute: {s:?}");
    println!(
        "  result: {units} units from 1 store file ({} frame reads) — byte-identical to cold",
        warm_cache.disk_stats().unwrap().reads
    );

    // Cold: compute everything (fresh memory-only cache per iteration).
    let cold = bench("store_warmstart_cold", 1, 5, || {
        let cache = Arc::new(UnitCache::new(DEFAULT_CACHE_CAP));
        Engine::new(jobs).with_cache(cache).run_all(&cells)
    });
    // Warm: a fresh process image per iteration — reopen the store
    // file, read + decode frames, merge. No simulation.
    let warm = bench("store_warmstart_warm", 1, 5, || {
        let cache = Arc::new(UnitCache::new(DEFAULT_CACHE_CAP).with_disk(&dir).unwrap());
        Engine::new(jobs).with_cache(cache).run_all(&cells)
    });
    let speedup = cold.median_ns / warm.median_ns;
    println!(
        "  -> warm start {speedup:.2}x faster than cold ({:.1} ms vs {:.1} ms)",
        warm.median_ns / 1e6,
        cold.median_ns / 1e6
    );

    let mut speedup_rec = BTreeMap::new();
    speedup_rec.insert("name".to_string(), Json::Str("store_warmstart_speedup".to_string()));
    speedup_rec.insert("cold_median_ns".to_string(), Json::Num(cold.median_ns));
    speedup_rec.insert("warm_median_ns".to_string(), Json::Num(warm.median_ns));
    speedup_rec.insert("speedup".to_string(), Json::Num(speedup));
    speedup_rec.insert("jobs".to_string(), Json::Num(jobs as f64));
    speedup_rec.insert("units".to_string(), Json::Num(units as f64));
    speedup_rec.insert("store_files".to_string(), Json::Num(1.0));
    let records = vec![
        record("store_warmstart_cold", &cold),
        record("store_warmstart_warm", &warm),
        Json::Obj(speedup_rec),
    ];

    let out_path = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_store.json".to_string());
    let mut doc = BTreeMap::new();
    doc.insert("schema".to_string(), Json::Str("tensordash.bench.v1".to_string()));
    doc.insert("bench".to_string(), Json::Str("store_warmstart".to_string()));
    doc.insert("records".to_string(), Json::Arr(records));
    let mut text = Json::Obj(doc).render_pretty();
    text.push('\n');
    match std::fs::write(&out_path, text.as_bytes()) {
        Ok(()) => println!("\nwrote {out_path} ({} bytes)", text.len()),
        Err(e) => eprintln!("\ncould not write {out_path}: {e}"),
    }
    let _ = std::fs::remove_dir_all(&dir);

    // Acceptance bar (ISSUE 6 / EXPERIMENTS.md §Perf), enforced after
    // the artifact is on disk so a regressing run is still archived: a
    // store-backed warm start must be >= 2x faster than recomputing.
    const WARM_SPEEDUP_GATE: f64 = 2.0;
    if speedup < WARM_SPEEDUP_GATE {
        eprintln!(
            "PERF GATE: store warm-start speedup {speedup:.2}x < {WARM_SPEEDUP_GATE}x — \
             the record-log mirror stopped paying for itself"
        );
        std::process::exit(1);
    }
    println!("perf gate passed: warm start {speedup:.2}x >= {WARM_SPEEDUP_GATE}x");
}
