//! §Perf micro-benchmarks of the simulator hot path.
//!
//! The hardware scheduler is invoked once per simulated PE-cycle; its
//! throughput bounds every experiment above. Tracked in EXPERIMENTS.md
//! §Perf (before/after for each optimisation step).

use tensordash::sim::connectivity::Connectivity;
use tensordash::sim::pe::simulate_stream_stats;
use tensordash::sim::scheduler::schedule_cycle;
use tensordash::sim::tile::tile_pass_stats;
use tensordash::util::bench::{bench, section};
use tensordash::util::rng::Rng;

fn main() {
    let conn = Connectivity::new(3);
    let mut rng = Rng::new(42);

    section("scheduler (single combinational cycle)");
    let zs: Vec<u64> = (0..4096).map(|_| rng.next_u64() & conn.window_mask()).collect();
    let s = bench("schedule_cycle_x4096", 20, 500, || {
        let mut acc = 0u64;
        for &z in &zs {
            acc ^= schedule_cycle(&conn, z).picks;
        }
        acc
    });
    println!("  -> {:.1} ns per schedule", s.median_ns / zs.len() as f64);

    section("PE stream simulation");
    for density in [0.2f64, 0.5, 0.9] {
        let rows: Vec<u16> = (0..16384).map(|_| rng.mask16(density)).collect();
        let st = bench(
            &format!("pe_stream_16k_rows_d{:.0}", density * 100.0),
            3,
            30,
            || simulate_stream_stats(&conn, &rows),
        );
        let cycles = simulate_stream_stats(&conn, &rows).cycles;
        println!(
            "  -> {:.1} ns per simulated cycle ({cycles} cycles)",
            st.median_ns / cycles as f64
        );
    }

    section("tile pass (4 rows x 1024 steps)");
    let streams: Vec<Vec<u16>> =
        (0..4).map(|_| (0..1024).map(|_| rng.mask16(0.5)).collect()).collect();
    bench("tile_pass_4x1024", 5, 100, || tile_pass_stats(&conn, &streams, 6));
}
