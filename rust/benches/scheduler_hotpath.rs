//! §Perf micro-benchmarks of the simulator hot path.
//!
//! The hardware scheduler is invoked once per simulated PE-cycle; its
//! throughput bounds every experiment above. Tracked in EXPERIMENTS.md
//! §Perf (before/after for each optimisation step).
//!
//! Besides the console log, the run emits its medians — plus one
//! machine-independent `packed_vs_reference_speedup` record racing the
//! packed word-ops streaming core against `stream::reference` on its
//! uniform-random worst case, byte-identity asserted in-bench — as
//! `BENCH_scheduler.json` (or `$BENCH_OUT` if set) through the
//! `util::json` writer, so CI archives one machine-readable perf point
//! per PR.

use std::collections::BTreeMap;

use tensordash::sim::connectivity::Connectivity;
use tensordash::sim::pe::simulate_stream_stats;
use tensordash::sim::scheduler::schedule_cycle;
use tensordash::sim::stream::reference;
use tensordash::sim::tile::tile_pass_stats;
use tensordash::util::bench::{bench, section, BenchStats};
use tensordash::util::json::Json;
use tensordash::util::rng::Rng;

/// One benchmark record for the JSON perf log.
fn record(name: &str, s: &BenchStats) -> Json {
    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::Str(name.to_string()));
    m.insert("median_ns".to_string(), Json::Num(s.median_ns));
    m.insert("mean_ns".to_string(), Json::Num(s.mean_ns));
    m.insert("min_ns".to_string(), Json::Num(s.min_ns));
    m.insert("stddev_ns".to_string(), Json::Num(s.stddev_ns));
    m.insert("iters".to_string(), Json::Num(s.iters as f64));
    Json::Obj(m)
}

fn main() {
    let conn = Connectivity::new(3);
    let mut rng = Rng::new(42);
    let mut records: Vec<Json> = Vec::new();

    section("scheduler (single combinational cycle)");
    let zs: Vec<u64> = (0..4096).map(|_| rng.next_u64() & conn.window_mask()).collect();
    let s = bench("schedule_cycle_x4096", 20, 500, || {
        let mut acc = 0u64;
        for &z in &zs {
            acc ^= schedule_cycle(&conn, z).picks;
        }
        acc
    });
    println!("  -> {:.1} ns per schedule", s.median_ns / zs.len() as f64);
    records.push(record("schedule_cycle_x4096", &s));

    section("PE stream simulation");
    for density in [0.2f64, 0.5, 0.9] {
        let rows: Vec<u16> = (0..16384).map(|_| rng.mask16(density)).collect();
        let name = format!("pe_stream_16k_rows_d{:.0}", density * 100.0);
        let st = bench(&name, 3, 30, || simulate_stream_stats(&conn, &rows));
        let cycles = simulate_stream_stats(&conn, &rows).cycles;
        println!(
            "  -> {:.1} ns per simulated cycle ({cycles} cycles)",
            st.median_ns / cycles as f64
        );
        records.push(record(&name, &st));
    }

    section("tile pass (4 rows x 1024 steps)");
    let streams: Vec<Vec<u16>> =
        (0..4).map(|_| (0..1024).map(|_| rng.mask16(0.5)).collect()).collect();
    let t = bench("tile_pass_4x1024", 5, 100, || tile_pass_stats(&conn, &streams, 6));
    records.push(record("tile_pass_4x1024", &t));

    // Packed core vs the per-element reference on the same uniform
    // random streams — the memo table's worst case (few recurring
    // masks), so this is the machine-independent floor of the word-ops
    // rewrite, not its showcase (that's tile_hotpath's trace-like
    // workload). Byte-identity is asserted before timing.
    section("packed streaming core vs stream::reference (4 rows x 1024 steps)");
    let new = tile_pass_stats(&conn, &streams, 6);
    let old = reference::tile_pass_stats(&conn, &streams, 6);
    assert_eq!(new.cycles, old.cycles, "packed core diverged (cycles)");
    assert_eq!(new.macs, old.macs, "packed core diverged (macs)");
    let r = bench("tile_pass_reference_4x1024", 5, 100, || {
        reference::tile_pass_stats(&conn, &streams, 6)
    });
    let packed_speedup = r.median_ns / t.median_ns;
    println!("  -> packed-over-reference speedup {packed_speedup:.2}x on uniform random");
    records.push(record("tile_pass_reference_4x1024", &r));
    let mut rec = BTreeMap::new();
    rec.insert("name".to_string(), Json::Str("packed_vs_reference_speedup".to_string()));
    rec.insert("reference_median_ns".to_string(), Json::Num(r.median_ns));
    rec.insert("packed_median_ns".to_string(), Json::Num(t.median_ns));
    rec.insert("speedup".to_string(), Json::Num(packed_speedup));
    // Cycles and MACs were asserted equal above, before any timing.
    rec.insert("identical".to_string(), Json::Bool(true));
    records.push(Json::Obj(rec));

    // Machine-readable perf point for the BENCH_* trajectory.
    let out_path =
        std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_scheduler.json".to_string());
    let mut doc = BTreeMap::new();
    doc.insert("schema".to_string(), Json::Str("tensordash.bench.v1".to_string()));
    doc.insert("bench".to_string(), Json::Str("scheduler_hotpath".to_string()));
    doc.insert("records".to_string(), Json::Arr(records));
    let mut text = Json::Obj(doc).render_pretty();
    text.push('\n');
    match std::fs::write(&out_path, text.as_bytes()) {
        Ok(()) => println!("\nwrote {out_path} ({} bytes)", text.len()),
        Err(e) => eprintln!("\ncould not write {out_path}: {e}"),
    }
}
