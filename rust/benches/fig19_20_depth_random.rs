//! Bench + regeneration of Fig. 19 (staging depth 2 vs 3) and Fig. 20
//! (randomly sparse tensors).
//!
//! Anchors: depth 2 is a cheaper, lower-speedup point (cap 2x); on
//! random tensors TensorDash tracks the ideal up to the 3x cap
//! (~1.1x at 10% sparsity, ~2.95x at 90%).

use tensordash::api::Engine;
use tensordash::repro;
use tensordash::util::bench::{bench, section};

fn main() {
    let engine = Engine::parallel();
    section("Fig. 19 reproduction");
    repro::fig19(&engine, 4, 42).print();
    section("Fig. 20 reproduction");
    repro::fig20(&engine, 10, 42).print();
    section("timing (fig20 one sparsity level, 2 samples)");
    let serial = Engine::serial();
    bench("fig20_two_samples", 0, 3, || repro::fig20(&serial, 2, 7));
}
