//! §Perf — the TCP serving layer under concurrent load: 8 clients
//! hammering overlapping warm sweeps through the bounded worker pool
//! (sharded cache, blocking accept) racing the same storm through the
//! pre-pool transport (thread-per-connection over a 10ms nonblocking
//! accept poll, single-shard cache), reimplemented here verbatim as
//! the baseline.
//!
//! The poll-driven baseline taxes every connection with up to one
//! accept tick of dead time, so a client's connect/request/response
//! cycle is bounded by the poll period no matter how cheap the warm
//! request is; the pool accepts immediately and serves from the
//! lock-striped cache. Byte-identity is asserted **in-run**: every
//! client's report bodies must equal the uncached reference, across
//! both transports, before and during timing — the throughput win is
//! only meaningful if concurrency changes nothing about the bytes.
//!
//! Emits medians, the pooled-over-legacy speedup and requests/sec as
//! `BENCH_serve_concurrent.json` (`$BENCH_OUT` overrides;
//! `tensordash.bench.v1`), gated through `ci/bench_floors.json`. The
//! bench itself exits non-zero below 2x pooled-over-legacy.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use tensordash::api::{Engine, ServeOptions, Service, UnitCache, DEFAULT_CACHE_CAP};
use tensordash::util::bench::{bench, section, BenchStats};
use tensordash::util::json::Json;

/// Concurrent clients in the storm (the acceptance bar is at 8).
const CLIENTS: usize = 8;
/// Connect/request/response cycles per client per iteration.
const REQS_PER_CLIENT: usize = 12;
/// Worker pool geometry for the pooled configuration.
const WORKERS: usize = 8;
const QUEUE_DEPTH: usize = 64;
const SHARDS: usize = 16;

fn record(name: &str, s: &BenchStats) -> Json {
    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::Str(name.to_string()));
    m.insert("median_ns".to_string(), Json::Num(s.median_ns));
    m.insert("mean_ns".to_string(), Json::Num(s.mean_ns));
    m.insert("min_ns".to_string(), Json::Num(s.min_ns));
    m.insert("stddev_ns".to_string(), Json::Num(s.stddev_ns));
    m.insert("iters".to_string(), Json::Num(s.iters as f64));
    Json::Obj(m)
}

/// Extract the `report` body of a response line; panics (failing the
/// bench) on any non-ok response. Comparing bodies — not whole lines —
/// keeps the moving `cache` telemetry envelope out of the identity
/// check, exactly like the determinism contract specifies.
fn report_body(line: &str) -> String {
    let j = Json::parse(line).expect("response parses");
    assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "response not ok: {line}");
    j.get("report").expect("response carries a report").render()
}

/// One client: `reqs` sequential connect/request/read/close cycles;
/// returns the report bodies in request order.
fn run_client(addr: SocketAddr, reqs: &[String]) -> Vec<String> {
    let mut bodies = Vec::with_capacity(reqs.len());
    for line in reqs {
        let stream = TcpStream::connect(addr).expect("connect");
        let _ = stream.set_nodelay(true);
        let mut w = stream.try_clone().expect("clone");
        let mut r = BufReader::new(stream);
        w.write_all(line.as_bytes()).expect("send");
        w.write_all(b"\n").expect("send");
        let mut resp = String::new();
        r.read_line(&mut resp).expect("recv");
        bodies.push(report_body(&resp));
    }
    bodies
}

/// Fan `CLIENTS` concurrent clients at `addr` and assert every one of
/// them saw exactly `expect` — the in-run byte-identity gate.
fn run_storm(addr: SocketAddr, reqs: &[String], expect: &[String]) {
    std::thread::scope(|s| {
        let handles: Vec<_> =
            (0..CLIENTS).map(|_| s.spawn(move || run_client(addr, reqs))).collect();
        for (i, h) in handles.into_iter().enumerate() {
            let bodies = h.join().expect("client thread");
            assert_eq!(bodies, expect, "client {i}: bodies diverged from the reference");
        }
    });
}

/// The pre-pool transport, verbatim: nonblocking accept polled on a
/// 10ms sleep, one spawned thread per connection, external stop flag.
fn legacy_serve(service: &Service, listener: TcpListener, stop: &AtomicBool) {
    listener.set_nonblocking(true).expect("nonblocking listener");
    std::thread::scope(|s| {
        loop {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    s.spawn(move || {
                        stream.set_nonblocking(false).expect("blocking conn");
                        let reader = BufReader::new(stream.try_clone().expect("clone"));
                        let writer = BufWriter::new(stream);
                        let _ = service.serve_lines(reader, writer);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => panic!("legacy accept: {e}"),
            }
        }
    });
}

/// One timed iteration against the legacy transport.
fn storm_legacy(cache: &Arc<UnitCache>, reqs: &[String], expect: &[String]) {
    let service = Service::new(Engine::new(1), Arc::clone(cache));
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("local addr");
    let stop = AtomicBool::new(false);
    std::thread::scope(|s| {
        let server = s.spawn(|| legacy_serve(&service, listener, &stop));
        run_storm(addr, reqs, expect);
        stop.store(true, Ordering::SeqCst);
        server.join().expect("legacy server");
    });
}

/// One timed iteration against the bounded worker pool.
fn storm_pooled(cache: &Arc<UnitCache>, reqs: &[String], expect: &[String]) {
    let service = Service::new(Engine::new(1), Arc::clone(cache));
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("local addr");
    std::thread::scope(|s| {
        let opts =
            ServeOptions { workers: WORKERS, queue_depth: QUEUE_DEPTH, ..ServeOptions::default() };
        let server = s.spawn(|| service.serve_listener(listener, opts));
        run_storm(addr, reqs, expect);
        // Shutdown over the protocol, like a real client would.
        let stream = TcpStream::connect(addr).expect("connect");
        let mut w = stream.try_clone().expect("clone");
        let mut r = BufReader::new(stream);
        w.write_all(b"{\"op\":\"shutdown\"}\n").expect("send");
        let mut line = String::new();
        r.read_line(&mut line).expect("recv");
        assert_eq!(Json::parse(&line).unwrap().get("bye"), Some(&Json::Bool(true)));
        server.join().expect("pooled server").expect("serve_listener");
    });
}

fn main() {
    // Two overlapping sweeps (the two-model sweep's gcn cells are the
    // one-model sweep's whole unit set), alternated per client.
    let r1 = r#"{"op":"sweep","models":["alexnet","gcn"],"samples":1,"seed":42}"#.to_string();
    let r2 = r#"{"op":"sweep","models":["gcn"],"samples":1,"seed":42}"#.to_string();
    let reqs: Vec<String> =
        (0..REQS_PER_CLIENT).map(|i| if i % 2 == 0 { r1.clone() } else { r2.clone() }).collect();

    section(&format!(
        "concurrent serving: {CLIENTS} clients x {REQS_PER_CLIENT} overlapping warm sweeps, \
         pooled ({WORKERS} workers, {SHARDS} shards) vs thread-per-conn (10ms accept poll)"
    ));

    // Uncached reference bodies — the identity baseline everything
    // (both transports, every client, warm and cold) must match.
    let reference = Service::new(Engine::new(1), Arc::new(UnitCache::new(1)));
    let expect: Vec<String> = reqs
        .iter()
        .map(|l| {
            let h = reference.handle_line(l);
            assert_eq!(h.lines.len(), 1, "one response per request");
            report_body(&h.lines[0])
        })
        .collect();

    // Warm both caches through a plain service and assert warm == cold
    // reference before any TCP traffic.
    let legacy_cache = Arc::new(UnitCache::new(DEFAULT_CACHE_CAP));
    let pooled_cache = Arc::new(UnitCache::with_shards(DEFAULT_CACHE_CAP, SHARDS));
    for cache in [&legacy_cache, &pooled_cache] {
        let warmer = Service::new(Engine::new(1), Arc::clone(cache));
        for (l, want) in reqs.iter().zip(&expect) {
            let h = warmer.handle_line(l);
            assert_eq!(&report_body(&h.lines[0]), want, "warm body diverged from cold");
        }
    }
    println!(
        "  result: {} shards warm ({} units), byte-identical to the uncached reference",
        pooled_cache.shard_count(),
        pooled_cache.len()
    );

    let legacy = bench("serve_legacy_storm", 1, 3, || {
        storm_legacy(&legacy_cache, &reqs, &expect);
    });
    let pooled = bench("serve_pooled_storm", 1, 3, || {
        storm_pooled(&pooled_cache, &reqs, &expect);
    });

    let total_reqs = (CLIENTS * REQS_PER_CLIENT) as f64;
    let speedup = legacy.median_ns / pooled.median_ns;
    let rps_legacy = total_reqs / (legacy.median_ns / 1e9);
    let rps_pooled = total_reqs / (pooled.median_ns / 1e9);
    println!(
        "  -> pooled storm {speedup:.2}x faster than thread-per-conn \
         ({rps_legacy:.0} -> {rps_pooled:.0} req/s at {CLIENTS} clients)"
    );

    let mut speedup_rec = BTreeMap::new();
    speedup_rec.insert("name".to_string(), Json::Str("serve_concurrent_speedup".to_string()));
    speedup_rec.insert("legacy_median_ns".to_string(), Json::Num(legacy.median_ns));
    speedup_rec.insert("pooled_median_ns".to_string(), Json::Num(pooled.median_ns));
    speedup_rec.insert("speedup".to_string(), Json::Num(speedup));
    speedup_rec.insert("clients".to_string(), Json::Num(CLIENTS as f64));
    speedup_rec.insert("requests_per_client".to_string(), Json::Num(REQS_PER_CLIENT as f64));
    speedup_rec.insert("requests_per_sec_legacy".to_string(), Json::Num(rps_legacy));
    speedup_rec.insert("requests_per_sec_pooled".to_string(), Json::Num(rps_pooled));
    speedup_rec.insert("workers".to_string(), Json::Num(WORKERS as f64));
    speedup_rec.insert("queue_depth".to_string(), Json::Num(QUEUE_DEPTH as f64));
    speedup_rec.insert("shards".to_string(), Json::Num(SHARDS as f64));
    // Every storm — warmup and timed, both transports — asserted every
    // client's bodies against the uncached reference;
    // ci/check_bench_floors.py's require_identical gate pins this flag.
    speedup_rec.insert("identical".to_string(), Json::Bool(true));
    let records = vec![
        record("serve_legacy_storm", &legacy),
        record("serve_pooled_storm", &pooled),
        Json::Obj(speedup_rec),
    ];

    let out_path =
        std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_serve_concurrent.json".to_string());
    let mut doc = BTreeMap::new();
    doc.insert("schema".to_string(), Json::Str("tensordash.bench.v1".to_string()));
    doc.insert("bench".to_string(), Json::Str("serve_concurrent".to_string()));
    doc.insert("records".to_string(), Json::Arr(records));
    let mut text = Json::Obj(doc).render_pretty();
    text.push('\n');
    match std::fs::write(&out_path, text.as_bytes()) {
        Ok(()) => println!("\nwrote {out_path} ({} bytes)", text.len()),
        Err(e) => eprintln!("\ncould not write {out_path}: {e}"),
    }

    // Acceptance bar (EXPERIMENTS.md §Perf), enforced after the
    // artifact is on disk so a regressing run is still archived: the
    // worker pool must beat the thread-per-conn poll loop >= 2x at 8
    // concurrent clients.
    const CONCURRENT_GATE: f64 = 2.0;
    if speedup < CONCURRENT_GATE {
        eprintln!(
            "PERF GATE: concurrent serve speedup {speedup:.2}x < {CONCURRENT_GATE}x — \
             the worker pool stopped paying for itself"
        );
        std::process::exit(1);
    }
    println!("perf gate passed: pooled {speedup:.2}x >= {CONCURRENT_GATE}x at {CLIENTS} clients");
}
