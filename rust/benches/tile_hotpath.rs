//! §Perf — the tile-pass hot path: the unified streaming core with its
//! memoizing scheduler cache and zero-run skipping, measured against the
//! pre-refactor uncached loops (`sim::stream::reference`).
//!
//! Workload: *trace-like* streams at 60–90% sparsity. Real traces are
//! not uniform noise — §4.4: non-zeros cluster in a subset of feature
//! maps, so a stream is dominated by a handful of recurring channel
//! masks plus runs of all-zero rows. That recurrence is exactly what the
//! direct-mapped memo table and the zero-run skipper monetise; uniform
//! random masks (the `scheduler_hotpath` workload) are the cache's
//! worst case and remain covered there.
//!
//! Every timed pair is asserted cycle- and MAC-identical first — the
//! speedup is only meaningful if the cores agree.
//!
//! Besides the console log, the run emits its medians and the
//! cached-over-reference speedups as `BENCH_tile.json` (or `$BENCH_OUT`
//! if set) through the `util::json` writer; CI archives it next to
//! `BENCH_scheduler.json` as the perf-trajectory artifact.

use std::collections::BTreeMap;

use tensordash::sim::connectivity::Connectivity;
use tensordash::sim::pe::simulate_stream_stats;
use tensordash::sim::stream::reference;
use tensordash::sim::tile::tile_pass_stats;
use tensordash::util::bench::{bench, section, BenchStats};
use tensordash::util::json::Json;
use tensordash::util::rng::Rng;

/// One benchmark record for the JSON perf log.
fn record(name: &str, s: &BenchStats) -> Json {
    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::Str(name.to_string()));
    m.insert("median_ns".to_string(), Json::Num(s.median_ns));
    m.insert("mean_ns".to_string(), Json::Num(s.mean_ns));
    m.insert("min_ns".to_string(), Json::Num(s.min_ns));
    m.insert("stddev_ns".to_string(), Json::Num(s.stddev_ns));
    m.insert("iters".to_string(), Json::Num(s.iters as f64));
    Json::Obj(m)
}

/// A speedup summary record (reference median over cached median).
/// Every pair this bench times is asserted cycle- and MAC-identical
/// first, so the record carries `identical: true` — the
/// `require_identical` gate in `ci/bench_floors.json` pins that flag,
/// failing loudly if the equality assertion is ever dropped.
fn speedup_record(name: &str, reference_ns: f64, cached_ns: f64) -> Json {
    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::Str(name.to_string()));
    m.insert("reference_median_ns".to_string(), Json::Num(reference_ns));
    m.insert("cached_median_ns".to_string(), Json::Num(cached_ns));
    m.insert("speedup".to_string(), Json::Num(reference_ns / cached_ns));
    m.insert("identical".to_string(), Json::Bool(true));
    Json::Obj(m)
}

/// One trace-like B-side stream: a small, skewed dictionary of
/// recurring channel masks (clustered non-zeros) interleaved with
/// zero-row runs, tuned so the fraction of zero *values* lands near
/// `sparsity`.
fn trace_like_stream(rng: &mut Rng, len: usize, sparsity: f64) -> Vec<u16> {
    // Roughly 60% of the sparsity comes from whole-zero rows (dead
    // feature maps / ReLU-killed pixels), the rest from thin rows.
    let zero_frac = sparsity * 0.6;
    let residual_density = ((1.0 - sparsity) / (1.0 - zero_frac)).min(1.0);
    let dict: Vec<u16> = (0..12).map(|_| rng.mask16(residual_density)).collect();
    // Average zero-run length ~4.5 rows; solve the start probability so
    // the expected zero-row fraction matches zero_frac.
    let avg_run = 4.5;
    let p_run = zero_frac / (avg_run * (1.0 - zero_frac) + zero_frac);
    let mut out = Vec::with_capacity(len);
    while out.len() < len {
        if rng.chance(p_run) {
            for _ in 0..(2 + rng.below(6)) {
                out.push(0);
            }
        } else {
            // Skewed dictionary pick: low indices dominate, like the
            // handful of hot channel patterns in a real trace.
            let i = rng.below(dict.len()).min(rng.below(dict.len()));
            out.push(dict[i]);
        }
    }
    out.truncate(len);
    out
}

/// The acceptance bar: cached tile-pass throughput must be at least
/// this multiple of the reference at every trace-like sparsity level.
/// Raised from 2.0 with the packed word-ops streaming core (u64 mask
/// words, whole-word zero-run scans, widened memo key). The run still
/// writes `BENCH_tile.json` before failing, so the regression is
/// archived even when the gate trips.
const TILE_SPEEDUP_GATE: f64 = 3.0;

fn main() {
    let conn = Connectivity::new(3);
    let mut rng = Rng::new(2020);
    let mut records: Vec<Json> = Vec::new();
    let mut tile_speedups: Vec<(String, f64)> = Vec::new();

    for sparsity in [0.6f64, 0.75, 0.9] {
        let tag = format!("s{:.0}", sparsity * 100.0);
        section(&format!(
            "tile pass, trace-like {:.0}% sparsity (4 rows x 4096 steps)",
            sparsity * 100.0
        ));
        let streams: Vec<Vec<u16>> =
            (0..4).map(|_| trace_like_stream(&mut rng, 4096, sparsity)).collect();

        // The refactor must not change what is simulated — assert before
        // timing anything.
        let new = tile_pass_stats(&conn, &streams, 6);
        let old = reference::tile_pass_stats(&conn, &streams, 6);
        assert_eq!(new.cycles, old.cycles, "cached core diverged (cycles)");
        assert_eq!(new.macs, old.macs, "cached core diverged (macs)");
        println!(
            "  window answers: {} walks, {} memo hits, {} fast paths (hit rate {:.1}%)",
            new.schedules,
            new.cache_hits,
            new.fast_paths,
            100.0 * (new.cache_hits + new.fast_paths) as f64
                / (new.schedules + new.cache_hits + new.fast_paths).max(1) as f64
        );

        let r = bench(&format!("tile_pass_reference_{tag}"), 3, 40, || {
            reference::tile_pass_stats(&conn, &streams, 6)
        });
        let c = bench(&format!("tile_pass_cached_{tag}"), 3, 40, || {
            tile_pass_stats(&conn, &streams, 6)
        });
        println!("  -> tile-pass speedup {:.2}x (reference / cached)", r.median_ns / c.median_ns);
        records.push(record(&format!("tile_pass_reference_{tag}"), &r));
        records.push(record(&format!("tile_pass_cached_{tag}"), &c));
        records.push(speedup_record(&format!("tile_pass_speedup_{tag}"), r.median_ns, c.median_ns));
        tile_speedups.push((tag.clone(), r.median_ns / c.median_ns));

        section(&format!("PE stream, trace-like {:.0}% sparsity (16k rows)", sparsity * 100.0));
        let rows = trace_like_stream(&mut rng, 16384, sparsity);
        let new = simulate_stream_stats(&conn, &rows);
        let old = reference::simulate_stream_stats(&conn, &rows);
        assert_eq!(new.cycles, old.cycles, "cached PE core diverged (cycles)");
        assert_eq!(new.macs, old.macs, "cached PE core diverged (macs)");
        let r = bench(&format!("pe_stream_reference_{tag}"), 3, 40, || {
            reference::simulate_stream_stats(&conn, &rows)
        });
        let c = bench(&format!("pe_stream_cached_{tag}"), 3, 40, || {
            simulate_stream_stats(&conn, &rows)
        });
        println!(
            "  -> PE-stream speedup {:.2}x ({} of {} cycles zero-run-skipped)",
            r.median_ns / c.median_ns,
            new.skipped_cycles,
            new.cycles
        );
        records.push(record(&format!("pe_stream_reference_{tag}"), &r));
        records.push(record(&format!("pe_stream_cached_{tag}"), &c));
        records.push(speedup_record(&format!("pe_stream_speedup_{tag}"), r.median_ns, c.median_ns));
    }

    // Machine-readable perf point for the BENCH_* trajectory.
    let out_path = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_tile.json".to_string());
    let mut doc = BTreeMap::new();
    doc.insert("schema".to_string(), Json::Str("tensordash.bench.v1".to_string()));
    doc.insert("bench".to_string(), Json::Str("tile_hotpath".to_string()));
    doc.insert("records".to_string(), Json::Arr(records));
    let mut text = Json::Obj(doc).render_pretty();
    text.push('\n');
    match std::fs::write(&out_path, text.as_bytes()) {
        Ok(()) => println!("\nwrote {out_path} ({} bytes)", text.len()),
        Err(e) => eprintln!("\ncould not write {out_path}: {e}"),
    }

    // Enforce the stream-core acceptance bar (EXPERIMENTS.md §Perf)
    // after the artifact is on disk.
    let mut failed = false;
    for (tag, speedup) in &tile_speedups {
        if *speedup < TILE_SPEEDUP_GATE {
            eprintln!(
                "PERF GATE: tile_pass_{tag} speedup {speedup:.2}x < {TILE_SPEEDUP_GATE}x \
                 — the cached core regressed vs the uncached reference"
            );
            failed = true;
        }
    }
    if failed {
        std::process::exit(1);
    }
    println!(
        "perf gate passed: tile-pass speedups {} (all >= {TILE_SPEEDUP_GATE}x)",
        tile_speedups
            .iter()
            .map(|(t, s)| format!("{t}={s:.2}x"))
            .collect::<Vec<_>>()
            .join(", ")
    );
}
