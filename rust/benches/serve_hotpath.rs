//! §Perf — the serving layer's unit cache: a warm design-space sweep
//! racing the same sweep cold.
//!
//! The serving workload (HASS-style design-space search) re-evaluates
//! overlapping configurations against the same models; with the
//! content-addressed unit cache a repeated sweep is pure lookup +
//! merge instead of simulation. Warm and cold results are asserted
//! **byte-identical** before anything is timed — the speedup is only
//! meaningful if the cache returns exactly what the cold path
//! computes.
//!
//! Also races the binary v2 `UnitKey` encoder against the
//! canonical-JSON oracle over the sweep's full unit list (the
//! per-lookup cost every cache probe pays), after asserting that
//! decoding the bytes reproduces the JSON document exactly.
//!
//! Emits medians, the warm-over-cold speedup, the key-encode speedup
//! and requests/sec as `BENCH_serve.json` (`$BENCH_OUT` overrides;
//! `tensordash.bench.v1`), which CI archives next to the
//! scheduler/tile/model artifacts and gates through
//! `ci/bench_floors.json`. The bench itself exits non-zero below 2x
//! warm-over-cold or below 5x binary-over-JSON key encoding.

use std::collections::BTreeMap;
use std::sync::Arc;

use tensordash::api::cache::{canon_json_for_unit, fnv1a64};
use tensordash::api::{
    default_jobs, Engine, ModelPlan, Service, SweepSpec, UnitCache, UnitKey, DEFAULT_CACHE_CAP,
};
use tensordash::config::ChipConfig;
use tensordash::repro::ModelSim;
use tensordash::util::bench::{bench, section, BenchStats};
use tensordash::util::json::Json;

fn record(name: &str, s: &BenchStats) -> Json {
    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::Str(name.to_string()));
    m.insert("median_ns".to_string(), Json::Num(s.median_ns));
    m.insert("mean_ns".to_string(), Json::Num(s.mean_ns));
    m.insert("min_ns".to_string(), Json::Num(s.min_ns));
    m.insert("stddev_ns".to_string(), Json::Num(s.stddev_ns));
    m.insert("iters".to_string(), Json::Num(s.iters as f64));
    Json::Obj(m)
}

fn assert_identical(a: &ModelSim, b: &ModelSim, ctx: &str) {
    assert_eq!(a.per_op, b.per_op, "{ctx}: cycles diverged");
    assert_eq!(a.sched, b.sched, "{ctx}: telemetry diverged");
    assert_eq!(
        a.energy_td.total_pj().to_bits(),
        b.energy_td.total_pj().to_bits(),
        "{ctx}: energy bits diverged"
    );
    assert_eq!(a.layers, b.layers, "{ctx}: per-unit results diverged");
}

fn main() {
    let samples = 2; // keeps a bench iteration in seconds, not minutes
    let seed = 42;
    let models = ["alexnet", "gcn"];
    let cfg = ChipConfig::default();
    let cells = SweepSpec::models(&models, 0.4, &cfg, samples, seed).cells();
    let jobs = default_jobs().clamp(2, 8);

    section(&format!(
        "serving-layer unit cache: {}-model sweep, warm vs cold (samples={samples}, jobs={jobs})",
        models.len()
    ));

    // Byte-identity first: uncached reference == cold cached == warm.
    let reference = Engine::new(jobs).run_all(&cells);
    let warm_cache = Arc::new(UnitCache::new(DEFAULT_CACHE_CAP));
    let warm_engine = Engine::new(jobs).with_cache(Arc::clone(&warm_cache));
    let cold_sims = warm_engine.run_all(&cells);
    let warm_sims = warm_engine.run_all(&cells);
    for ((r, c), w) in reference.iter().zip(&cold_sims).zip(&warm_sims) {
        assert_identical(r, c, &format!("cold {}", r.name));
        assert_identical(c, w, &format!("warm {}", c.name));
    }
    let s = warm_cache.stats();
    println!(
        "  result: {} units/sweep, warm hit rate {:.0}% — byte-identical warm and cold",
        cold_sims.iter().map(|m| m.layers.len()).sum::<usize>(),
        s.hit_rate() * 100.0
    );

    // Cold: a fresh cache every iteration (first-request latency).
    let cold = bench("serve_sweep_cold", 1, 5, || {
        let cache = Arc::new(UnitCache::new(DEFAULT_CACHE_CAP));
        Engine::new(jobs).with_cache(cache).run_all(&cells)
    });
    // Warm: the persistent service cache (steady-state latency).
    let warm = bench("serve_sweep_warm", 1, 5, || warm_engine.run_all(&cells));
    let speedup = cold.median_ns / warm.median_ns;
    let rps_cold = cells.len() as f64 / (cold.median_ns / 1e9);
    let rps_warm = cells.len() as f64 / (warm.median_ns / 1e9);
    println!(
        "  -> warm sweep {speedup:.2}x faster than cold ({rps_cold:.1} -> {rps_warm:.1} cells/s)"
    );

    // Key-encoding microbench: every cache probe builds a UnitKey, so
    // the v2 binary encoder is on the serving hot path. Race it against
    // the canonical-JSON oracle (the v1-style encoder) over the sweep's
    // full unit list — after asserting the two forms agree, because the
    // speedup is only meaningful if decode(bytes) == json(spec).
    section("unit-key encoding: binary v2 vs canonical JSON");
    let plans: Vec<ModelPlan> = cells.iter().filter_map(ModelPlan::for_request).collect();
    let key_units: Vec<_> =
        plans.iter().flat_map(|p| p.units.iter().map(move |u| (&p.cfg, u))).collect();
    for (cfg, u) in &key_units {
        assert_eq!(
            UnitKey::for_unit(cfg, u).canon(),
            canon_json_for_unit(cfg, u),
            "binary/JSON key divergence"
        );
    }
    let kb = bench("unit_key_binary", 10, 200, || {
        let mut acc = 0u64;
        for (cfg, u) in &key_units {
            acc ^= UnitKey::for_unit(cfg, u).hash;
        }
        acc
    });
    let kj = bench("unit_key_json", 10, 200, || {
        let mut acc = 0u64;
        for (cfg, u) in &key_units {
            acc ^= fnv1a64(canon_json_for_unit(cfg, u).as_bytes());
        }
        acc
    });
    let key_speedup = kj.median_ns / kb.median_ns;
    println!(
        "  -> binary key encode {key_speedup:.2}x faster than JSON ({} keys, {:.0} -> {:.0} ns/key)",
        key_units.len(),
        kj.median_ns / key_units.len() as f64,
        kb.median_ns / key_units.len() as f64
    );

    // End-to-end serve path: a duplicate request through the protocol
    // handler (parse + cache-served engine run + report render).
    let service = Service::new(Engine::new(jobs), Arc::new(UnitCache::new(DEFAULT_CACHE_CAP)));
    let line = format!(
        r#"{{"op":"simulate","model":"alexnet","epoch":0.4,"samples":{samples},"seed":{seed}}}"#
    );
    let first = service.handle_line(&line);
    assert_eq!(first.lines.len(), 1, "serve smoke: one response line");
    let serve_warm = bench("serve_request_warm", 1, 5, || service.handle_line(&line).lines);

    let mut speedup_rec = BTreeMap::new();
    speedup_rec.insert("name".to_string(), Json::Str("warm_sweep_speedup".to_string()));
    speedup_rec.insert("cold_median_ns".to_string(), Json::Num(cold.median_ns));
    speedup_rec.insert("warm_median_ns".to_string(), Json::Num(warm.median_ns));
    speedup_rec.insert("speedup".to_string(), Json::Num(speedup));
    speedup_rec.insert("requests_per_sec_cold".to_string(), Json::Num(rps_cold));
    speedup_rec.insert("requests_per_sec_warm".to_string(), Json::Num(rps_warm));
    speedup_rec.insert("jobs".to_string(), Json::Num(jobs as f64));
    // assert_identical ran on every warm/cold pair before any timing;
    // ci/check_bench_floors.py's require_identical gate pins this flag.
    speedup_rec.insert("identical".to_string(), Json::Bool(true));
    let mut key_rec = BTreeMap::new();
    key_rec.insert("name".to_string(), Json::Str("key_encode_speedup".to_string()));
    key_rec.insert("json_median_ns".to_string(), Json::Num(kj.median_ns));
    key_rec.insert("binary_median_ns".to_string(), Json::Num(kb.median_ns));
    key_rec.insert("speedup".to_string(), Json::Num(key_speedup));
    key_rec.insert("keys".to_string(), Json::Num(key_units.len() as f64));
    // Every key's decoded canon was asserted equal to the JSON oracle
    // before timing.
    key_rec.insert("identical".to_string(), Json::Bool(true));
    let records = vec![
        record("serve_sweep_cold", &cold),
        record("serve_sweep_warm", &warm),
        record("serve_request_warm", &serve_warm),
        record("unit_key_binary", &kb),
        record("unit_key_json", &kj),
        Json::Obj(speedup_rec),
        Json::Obj(key_rec),
    ];

    let out_path = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_serve.json".to_string());
    let mut doc = BTreeMap::new();
    doc.insert("schema".to_string(), Json::Str("tensordash.bench.v1".to_string()));
    doc.insert("bench".to_string(), Json::Str("serve_hotpath".to_string()));
    doc.insert("records".to_string(), Json::Arr(records));
    let mut text = Json::Obj(doc).render_pretty();
    text.push('\n');
    match std::fs::write(&out_path, text.as_bytes()) {
        Ok(()) => println!("\nwrote {out_path} ({} bytes)", text.len()),
        Err(e) => eprintln!("\ncould not write {out_path}: {e}"),
    }

    // Acceptance bars (EXPERIMENTS.md §Perf), enforced after the
    // artifact is on disk so a regressing run is still archived: a warm
    // unit-cache sweep must be >= 2x faster than cold, and the binary
    // v2 key encoder must beat the canonical-JSON encoder >= 5x.
    const WARM_SPEEDUP_GATE: f64 = 2.0;
    const KEY_ENCODE_GATE: f64 = 5.0;
    let mut failed = false;
    if speedup < WARM_SPEEDUP_GATE {
        eprintln!(
            "PERF GATE: warm sweep speedup {speedup:.2}x < {WARM_SPEEDUP_GATE}x — \
             the unit cache stopped paying for itself"
        );
        failed = true;
    }
    if key_speedup < KEY_ENCODE_GATE {
        eprintln!(
            "PERF GATE: key encode speedup {key_speedup:.2}x < {KEY_ENCODE_GATE}x — \
             the binary key encoder stopped paying for itself"
        );
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
    println!(
        "perf gate passed: warm {speedup:.2}x >= {WARM_SPEEDUP_GATE}x, \
         key encode {key_speedup:.2}x >= {KEY_ENCODE_GATE}x"
    );
}
