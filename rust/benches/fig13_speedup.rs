//! Bench + regeneration of Fig. 13 (TensorDash speedup per model/op).
//!
//! The headline result: ~1.95x average speedup over the baseline on the
//! default Table-2 configuration.

use tensordash::config::ChipConfig;
use tensordash::repro;
use tensordash::util::bench::{bench, section};

fn main() {
    let cfg = ChipConfig::default();
    let samples = 6;
    let seed = 42;
    section("Fig. 13 reproduction");
    let sims = repro::run_fig13_sims(&cfg, samples, seed);
    repro::fig13(&sims).print();
    section("timing (full 9-model sweep)");
    bench("fig13_sweep", 0, 3, || repro::run_fig13_sims(&cfg, samples, seed));
}
