//! Bench + regeneration of Fig. 13 (TensorDash speedup per model/op).
//!
//! The headline result: ~1.95x average speedup over the baseline on the
//! default Table-2 configuration. The sweep goes through the typed
//! `api::Engine`, so the same run also demonstrates the worker pool:
//! the timing section compares 1 worker against all cores on the
//! identical (byte-for-byte) result.

use tensordash::api::Engine;
use tensordash::config::ChipConfig;
use tensordash::repro;
use tensordash::util::bench::{bench, section};

fn main() {
    let cfg = ChipConfig::default();
    let samples = 6;
    let seed = 42;
    let engine = Engine::parallel();
    section("Fig. 13 reproduction");
    let sims = repro::run_fig13_sims(&engine, &cfg, samples, seed);
    repro::fig13(&sims).print();
    section("timing (full 9-model sweep, 1 worker vs all cores)");
    let serial = Engine::serial();
    bench("fig13_sweep_jobs1", 0, 3, || repro::run_fig13_sims(&serial, &cfg, samples, seed));
    bench(
        &format!("fig13_sweep_jobs{}", engine.jobs()),
        0,
        3,
        || repro::run_fig13_sims(&engine, &cfg, samples, seed),
    );
}
