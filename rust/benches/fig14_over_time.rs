//! Bench + regeneration of Fig. 14 (speedup across training progress).
//!
//! Anchors: stable speedups; pruned ResNets settle after ~5% of training
//! (DS90 ~1.95 -> ~1.8, SM90 ~1.75 -> ~1.5); dense models inverted-U.

use tensordash::api::Engine;
use tensordash::config::ChipConfig;
use tensordash::repro;
use tensordash::trace::profiles::ModelProfile;
use tensordash::util::bench::{bench, section};

fn main() {
    let cfg = ChipConfig::default();
    let engine = Engine::parallel();
    section("Fig. 14 reproduction");
    repro::fig14(&engine, &cfg, 4, 42).print();
    section("timing (one model, one epoch point)");
    let p = ModelProfile::for_model("resnet50").unwrap();
    bench("fig14_one_point", 1, 5, || {
        repro::simulate_profile(&cfg, &p, 0.4, 4, 42)
    });
}
