//! Bench + regeneration of Fig. 15 (energy efficiency) and Fig. 16
//! (energy breakdown).
//!
//! Anchors: compute-only efficiency ~1.89x, whole-chip ~1.6x; the core
//! dominates total energy.

use tensordash::api::Engine;
use tensordash::config::ChipConfig;
use tensordash::repro;
use tensordash::util::bench::{bench, section};

fn main() {
    let cfg = ChipConfig::default();
    let engine = Engine::parallel();
    let sims = repro::run_fig13_sims(&engine, &cfg, 6, 42);
    section("Fig. 15 reproduction");
    repro::fig15(&sims).print();
    section("Fig. 16 reproduction");
    repro::fig16(&sims).print();
    section("timing (energy model alone)");
    let em = tensordash::energy::EnergyModel::new(cfg);
    let sram = tensordash::sim::memory::dense_counts(100, 1000, 64, 4, 4);
    let dram = tensordash::sim::dram::DramTraffic { read_bytes: 1 << 20, write_bytes: 1 << 18 };
    let tw = tensordash::sim::transposer::TransposerWork { groups: 1000 };
    bench("energy_model_layer", 10, 1000, || {
        em.layer_energy(100_000, &sram, &dram, &tw, true)
    });
}
