//! §Perf — request-level multiplexing vs connection-level serving:
//! one pipelined connection carrying a slow cold sweep followed by
//! `N_FAST` warm point requests, raced through the PR 8
//! connection-pool transport (workers pop *whole connections* and
//! serve them end-to-end, reimplemented here verbatim as the
//! baseline) and through the request-multiplexing transport (readers
//! tag individual requests into the shared request queue; the fast
//! requests opt into `"stream": true`).
//!
//! Under the connection-pool transport every fast request is stuck
//! behind the sweep — head-of-line blocking at connection grain — so
//! its latency is the sweep's runtime. The multiplexer executes the
//! fast requests on the free worker and streams their responses out
//! of order, so their latency is a warm cache hit. The gate is the
//! fast-request p99 ratio, floored at 2x (in practice it is orders of
//! magnitude).
//!
//! Byte-identity is asserted **in-run**: every response body — sweep
//! and fast, both transports, every iteration — must equal the
//! uncached single-thread reference before any latency is recorded.
//!
//! Emits fast-request p99s and the connpool-over-mux speedup as
//! `BENCH_serve_multiplex.json` (`$BENCH_OUT` overrides;
//! `tensordash.bench.v1`), gated through `ci/bench_floors.json`. The
//! bench itself exits non-zero below 2x.

use std::collections::{BTreeMap, VecDeque};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use tensordash::api::{Engine, ServeOptions, Service, UnitCache, DEFAULT_CACHE_CAP};
use tensordash::util::bench::section;
use tensordash::util::json::Json;

/// Warm point requests pipelined behind the cold sweep per iteration.
const N_FAST: usize = 32;
/// Iterations per transport; fast latencies are pooled across them.
const ITERS: usize = 3;
/// Worker count for both transports: one worker absorbs the sweep,
/// the other is free — if the transport can route work to it.
const WORKERS: usize = 2;

/// The slow request: a multi-model cold sweep (fresh seed per
/// iteration keeps it cold against the warm shared cache).
fn sweep_req(seed: u64) -> String {
    format!(
        "{{\"op\":\"sweep\",\"models\":[\"alexnet\",\"gcn\"],\"epochs\":[0.1,0.5,0.9],\
         \"samples\":2,\"seed\":{seed},\"id\":\"slow\"}}"
    )
}

/// A fast request: one warm point simulation, optionally streaming.
fn fast_req(i: usize, stream: bool) -> String {
    let tail = if stream { ",\"stream\":true" } else { "" };
    format!(
        "{{\"op\":\"simulate\",\"model\":\"gcn\",\"epoch\":0.5,\
         \"samples\":2,\"seed\":4242,\"id\":\"f{i}\"{tail}}}"
    )
}

/// Extract the `report` body of a response line; panics (failing the
/// bench) on any non-ok response. Comparing bodies — not whole lines —
/// keeps the moving `cache` telemetry and the streaming `op` echo out
/// of the identity check.
fn report_body(line: &str) -> String {
    let j = Json::parse(line).expect("response parses");
    assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "response not ok: {line}");
    j.get("report").expect("response carries a report").render()
}

/// One pipelined client: send the sweep, then all fast requests, then
/// read every response, asserting each body against the reference and
/// timing each fast request send-to-response. Returns fast latencies.
fn run_client(
    addr: SocketAddr,
    seed: u64,
    stream: bool,
    expect_sweep: &str,
    expect_fast: &str,
) -> Vec<f64> {
    let c = TcpStream::connect(addr).expect("connect");
    c.set_read_timeout(Some(Duration::from_secs(120))).expect("read timeout");
    let _ = c.set_nodelay(true);
    let mut r = BufReader::new(c.try_clone().expect("clone"));
    let mut w = c;
    let mut send = |line: &str| {
        w.write_all(line.as_bytes()).expect("send");
        w.write_all(b"\n").expect("send newline");
        w.flush().expect("flush");
    };
    send(&sweep_req(seed));
    let mut sent: BTreeMap<String, Instant> = BTreeMap::new();
    for i in 0..N_FAST {
        send(&fast_req(i, stream));
        sent.insert(format!("f{i}"), Instant::now());
    }
    let mut lat = Vec::with_capacity(N_FAST);
    let mut saw_sweep = false;
    for _ in 0..N_FAST + 1 {
        let mut line = String::new();
        r.read_line(&mut line).expect("recv");
        let j = Json::parse(&line).expect("response parses");
        let id = j.get("id").and_then(Json::as_str).expect("string id").to_string();
        if id == "slow" {
            assert_eq!(report_body(&line), expect_sweep, "sweep body diverged");
            saw_sweep = true;
        } else {
            let t = sent.get(&id).unwrap_or_else(|| panic!("unexpected id {id}"));
            lat.push(t.elapsed().as_nanos() as f64);
            assert_eq!(report_body(&line), expect_fast, "fast body diverged ({id})");
        }
    }
    assert!(saw_sweep, "sweep response missing");
    assert_eq!(lat.len(), N_FAST);
    lat
}

/// The PR 8 transport, verbatim: an unbounded-within-the-bench queue
/// of accepted *connections*, workers popping one and serving it
/// end-to-end with `serve_lines`. (The real transport bounded the
/// queue and shed past depth; this bench runs one connection at a
/// time, so depth never binds and the reimplementation omits it.)
struct ConnQueue {
    state: Mutex<(VecDeque<TcpStream>, bool)>,
    ready: Condvar,
}

impl ConnQueue {
    fn new() -> ConnQueue {
        ConnQueue { state: Mutex::new((VecDeque::new(), false)), ready: Condvar::new() }
    }

    fn push(&self, c: TcpStream) {
        self.state.lock().unwrap().0.push_back(c);
        self.ready.notify_one();
    }

    fn pop(&self) -> Option<TcpStream> {
        let mut g = self.state.lock().unwrap();
        loop {
            if let Some(c) = g.0.pop_front() {
                return Some(c);
            }
            if g.1 {
                return None;
            }
            g = self.ready.wait(g).unwrap();
        }
    }

    fn close(&self) {
        self.state.lock().unwrap().1 = true;
        self.ready.notify_all();
    }
}

fn connpool_serve(service: &Service, listener: TcpListener, stop: &AtomicBool) {
    let queue = ConnQueue::new();
    std::thread::scope(|s| {
        for _ in 0..WORKERS {
            let queue = &queue;
            s.spawn(move || {
                while let Some(stream) = queue.pop() {
                    let reader = BufReader::new(stream.try_clone().expect("clone"));
                    let writer = BufWriter::new(stream);
                    let _ = service.serve_lines(reader, writer);
                }
            });
        }
        loop {
            let (stream, _) = listener.accept().expect("accept");
            if stop.load(Ordering::SeqCst) {
                // The harness's stop poke.
                drop(stream);
                break;
            }
            queue.push(stream);
        }
        queue.close();
    });
}

/// One iteration against the connection-pool baseline.
fn iter_connpool(
    cache: &Arc<UnitCache>,
    seed: u64,
    expect_sweep: &str,
    expect_fast: &str,
) -> Vec<f64> {
    let service = Service::new(Engine::new(1), Arc::clone(cache));
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("local addr");
    let stop = AtomicBool::new(false);
    let mut lat = Vec::new();
    std::thread::scope(|s| {
        let server = s.spawn(|| connpool_serve(&service, listener, &stop));
        lat = run_client(addr, seed, false, expect_sweep, expect_fast);
        stop.store(true, Ordering::SeqCst);
        drop(TcpStream::connect(addr).expect("stop poke"));
        server.join().expect("connpool server");
    });
    lat
}

/// One iteration against the request multiplexer; the fast requests
/// opt into streaming, the sweep stays v1-ordered.
fn iter_mux(cache: &Arc<UnitCache>, seed: u64, expect_sweep: &str, expect_fast: &str) -> Vec<f64> {
    let service = Service::new(Engine::new(1), Arc::clone(cache));
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("local addr");
    let mut lat = Vec::new();
    std::thread::scope(|s| {
        let opts = ServeOptions { workers: WORKERS, ..ServeOptions::default() };
        let server = s.spawn(|| service.serve_listener(listener, opts));
        lat = run_client(addr, seed, true, expect_sweep, expect_fast);
        // Shutdown over the protocol, like a real client would.
        let c = TcpStream::connect(addr).expect("connect");
        let mut w = c.try_clone().expect("clone");
        let mut r = BufReader::new(c);
        w.write_all(b"{\"op\":\"shutdown\"}\n").expect("send");
        let mut line = String::new();
        r.read_line(&mut line).expect("recv");
        assert_eq!(Json::parse(&line).unwrap().get("bye"), Some(&Json::Bool(true)));
        server.join().expect("mux server").expect("serve_listener");
    });
    lat
}

/// Nearest-rank p99 (sorts in place).
fn p99(samples: &mut [f64]) -> f64 {
    assert!(!samples.is_empty());
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((0.99 * samples.len() as f64).ceil() as usize).max(1) - 1;
    samples[idx.min(samples.len() - 1)]
}

fn mean(samples: &[f64]) -> f64 {
    samples.iter().sum::<f64>() / samples.len() as f64
}

fn main() {
    section(&format!(
        "request multiplexing: 1 cold sweep + {N_FAST} warm points pipelined on one \
         connection x {ITERS} iters, request-mux (streamed) vs connection-pool baseline"
    ));

    // Uncached single-thread reference bodies — the identity baseline
    // every response on both transports must match.
    let reference = Service::new(Engine::new(1), Arc::new(UnitCache::new(1)));
    let body_of = |line: &str| {
        let h = reference.handle_line(line);
        assert_eq!(h.lines.len(), 1, "one response per request");
        report_body(&h.lines[0])
    };
    let expect_fast = body_of(&fast_req(0, false));
    let expect_sweeps: Vec<String> =
        (0..ITERS).map(|i| body_of(&sweep_req(1000 + i as u64))).collect();

    // Per-transport caches, pre-warmed with the fast point's units and
    // asserted warm == cold before any TCP traffic.
    let connpool_cache = Arc::new(UnitCache::new(DEFAULT_CACHE_CAP));
    let mux_cache = Arc::new(UnitCache::new(DEFAULT_CACHE_CAP));
    for cache in [&connpool_cache, &mux_cache] {
        let warmer = Service::new(Engine::new(1), Arc::clone(cache));
        let h = warmer.handle_line(&fast_req(0, false));
        assert_eq!(report_body(&h.lines[0]), expect_fast, "warm body diverged from cold");
    }
    println!("  result: caches warm ({} units), fast point byte-identical", mux_cache.len());

    let mut lat_connpool: Vec<f64> = Vec::new();
    let mut lat_mux: Vec<f64> = Vec::new();
    for i in 0..ITERS {
        let seed = 1000 + i as u64;
        lat_connpool.extend(iter_connpool(&connpool_cache, seed, &expect_sweeps[i], &expect_fast));
    }
    for i in 0..ITERS {
        let seed = 1000 + i as u64;
        lat_mux.extend(iter_mux(&mux_cache, seed, &expect_sweeps[i], &expect_fast));
    }

    let p99_connpool = p99(&mut lat_connpool);
    let p99_mux = p99(&mut lat_mux);
    let speedup = p99_connpool / p99_mux;
    println!(
        "  -> fast-request p99 {:.3} ms behind the connection pool, {:.3} ms multiplexed \
         ({speedup:.1}x)",
        p99_connpool / 1e6,
        p99_mux / 1e6
    );

    let mut rec_conn = BTreeMap::new();
    rec_conn.insert("name".to_string(), Json::Str("serve_connpool_fast_p99".to_string()));
    rec_conn.insert("p99_ns".to_string(), Json::Num(p99_connpool));
    rec_conn.insert("mean_ns".to_string(), Json::Num(mean(&lat_connpool)));
    rec_conn.insert("samples".to_string(), Json::Num(lat_connpool.len() as f64));
    let mut rec_mux = BTreeMap::new();
    rec_mux.insert("name".to_string(), Json::Str("serve_mux_fast_p99".to_string()));
    rec_mux.insert("p99_ns".to_string(), Json::Num(p99_mux));
    rec_mux.insert("mean_ns".to_string(), Json::Num(mean(&lat_mux)));
    rec_mux.insert("samples".to_string(), Json::Num(lat_mux.len() as f64));
    let mut rec_speedup = BTreeMap::new();
    rec_speedup.insert("name".to_string(), Json::Str("serve_multiplex_speedup".to_string()));
    rec_speedup.insert("connpool_fast_p99_ns".to_string(), Json::Num(p99_connpool));
    rec_speedup.insert("mux_fast_p99_ns".to_string(), Json::Num(p99_mux));
    rec_speedup.insert("speedup".to_string(), Json::Num(speedup));
    rec_speedup.insert("fast_requests_per_iter".to_string(), Json::Num(N_FAST as f64));
    rec_speedup.insert("iters".to_string(), Json::Num(ITERS as f64));
    rec_speedup.insert("workers".to_string(), Json::Num(WORKERS as f64));
    // Every response body — sweep and fast, both transports, every
    // iteration — was asserted against the uncached reference;
    // ci/check_bench_floors.py's require_identical gate pins this flag.
    rec_speedup.insert("identical".to_string(), Json::Bool(true));
    let records = vec![Json::Obj(rec_conn), Json::Obj(rec_mux), Json::Obj(rec_speedup)];

    let out_path =
        std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_serve_multiplex.json".to_string());
    let mut doc = BTreeMap::new();
    doc.insert("schema".to_string(), Json::Str("tensordash.bench.v1".to_string()));
    doc.insert("bench".to_string(), Json::Str("serve_multiplex".to_string()));
    doc.insert("records".to_string(), Json::Arr(records));
    let mut text = Json::Obj(doc).render_pretty();
    text.push('\n');
    match std::fs::write(&out_path, text.as_bytes()) {
        Ok(()) => println!("\nwrote {out_path} ({} bytes)", text.len()),
        Err(e) => eprintln!("\ncould not write {out_path}: {e}"),
    }

    // Acceptance bar (EXPERIMENTS.md §Perf), enforced after the
    // artifact is on disk so a regressing run is still archived: fast
    // requests multiplexed past a slow sweep must see >= 2x better p99
    // than behind the connection pool.
    const MUX_GATE: f64 = 2.0;
    if speedup < MUX_GATE {
        eprintln!(
            "PERF GATE: multiplexed fast-request p99 only {speedup:.2}x better than the \
             connection pool — request-grain scheduling stopped paying for itself"
        );
        std::process::exit(1);
    }
    println!("perf gate passed: mux fast-request p99 {speedup:.2}x >= {MUX_GATE}x");
}
