//! Regeneration of Table 3 (area/power breakdown), its bfloat16 variant
//! (§4.4) and the GCN no-sparsity control.
//!
//! Anchors: FP32 compute overhead 1.09x area / ~1.02x power, whole chip
//! ~1.0005x; bf16 1.13x / 1.05x; GCN gains ~1% and loses <1% energy
//! efficiency without power gating.

use tensordash::api::Engine;
use tensordash::config::DataType;
use tensordash::repro;
use tensordash::util::bench::{bench, section};

fn main() {
    let engine = Engine::parallel();
    section("Table 3 reproduction (FP32)");
    repro::table3(DataType::Fp32).print();
    section("Table 3 variant (bfloat16, §4.4)");
    repro::table3(DataType::Bf16).print();
    section("GCN no-sparsity control (§4.4)");
    repro::gcn_control(&engine, 6, 42).print();
    section("timing");
    bench("table3_render", 10, 100, || repro::table3(DataType::Fp32).render_text());
}
