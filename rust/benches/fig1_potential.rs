//! Bench + regeneration of the paper's Fig. 1 (potential speedup).
//!
//! Prints the figure's rows and times the profile evaluation.

use tensordash::repro;
use tensordash::util::bench::{bench, section};

fn main() {
    section("Fig. 1 reproduction");
    repro::fig1().print();
    section("timing");
    bench("fig1_potential", 1, 10, repro::fig1);
}
