//! Ablation benches over the design choices DESIGN.md calls out:
//! two-side extraction (the paper's deferred §3.1 option), the
//! inter-row lead bound, the DRAM bandwidth gate, and the §3.7
//! iterative back-side scheduler.

use tensordash::api::Engine;
use tensordash::repro::ablations;
use tensordash::util::bench::{bench, section};

fn main() {
    let engine = Engine::parallel();
    section("two-side vs one-side extraction (§3.1/Fig. 8)");
    ablations::ablation_two_side(&engine, 3, 42).print();
    section("inter-row lead bound (DESIGN.md §2b)");
    ablations::ablation_lead(&engine, 3, 42).print();
    section("DRAM bandwidth gate (extension)");
    ablations::ablation_dram_gate(&engine, 3, 42).print();
    section("back-side scheduler: combinational vs iterative (§3.7)");
    ablations::ablation_backside_scheduler().print();
    section("timing");
    let serial = Engine::serial();
    bench("two_side_layer", 0, 3, || ablations::ablation_two_side(&serial, 2, 7));
}
