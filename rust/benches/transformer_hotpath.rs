//! §Perf — the transformer tier through the plan executor: `bert`'s
//! flattened (layer, op) unit graph racing the serial walk.
//!
//! The transformer workload stresses the executor differently from the
//! CNN zoo: sixteen fc-geometry layers (attention projections, per-head
//! score/context matmuls, FFN) expand to 48 units whose costs span two
//! orders of magnitude — the 768→3072 FFN units dominate while the
//! per-head attention units are tiny — so this bench guards the
//! scheduler against stragglers the CNN plans never produce. The run
//! also re-asserts the regime contract on the hot path: an `nm:2:4`
//! structured run must be byte-identical at jobs 1 and N.
//!
//! The parallel and serial runs are asserted **byte-identical** before
//! anything is timed. Besides the console log, the run emits its
//! medians and the jobs-N-over-jobs-1 speedup as
//! `BENCH_transformer.json` (or `$BENCH_OUT` if set); CI archives it
//! next to `BENCH_model.json` as the perf-trajectory artifact.

use std::collections::BTreeMap;

use tensordash::api::{default_jobs, Engine, ModelPlan, SimRequest};
use tensordash::config::ChipConfig;
use tensordash::repro::ModelSim;
use tensordash::sparsity::Regime;
use tensordash::util::bench::{bench, section, BenchStats};
use tensordash::util::json::Json;

/// One benchmark record for the JSON perf log.
fn record(name: &str, s: &BenchStats) -> Json {
    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::Str(name.to_string()));
    m.insert("median_ns".to_string(), Json::Num(s.median_ns));
    m.insert("mean_ns".to_string(), Json::Num(s.mean_ns));
    m.insert("min_ns".to_string(), Json::Num(s.min_ns));
    m.insert("stddev_ns".to_string(), Json::Num(s.stddev_ns));
    m.insert("iters".to_string(), Json::Num(s.iters as f64));
    Json::Obj(m)
}

fn speedup_record(name: &str, serial_ns: f64, parallel_ns: f64, jobs: usize) -> Json {
    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::Str(name.to_string()));
    m.insert("serial_median_ns".to_string(), Json::Num(serial_ns));
    m.insert("parallel_median_ns".to_string(), Json::Num(parallel_ns));
    m.insert("jobs".to_string(), Json::Num(jobs as f64));
    m.insert("speedup".to_string(), Json::Num(serial_ns / parallel_ns));
    Json::Obj(m)
}

fn assert_identical(a: &ModelSim, b: &ModelSim) {
    assert_eq!(a.per_op, b.per_op, "plan-parallel diverged (cycles)");
    assert_eq!(a.sched, b.sched, "plan-parallel diverged (telemetry)");
    assert_eq!(
        a.energy_td.total_pj().to_bits(),
        b.energy_td.total_pj().to_bits(),
        "plan-parallel diverged (energy bits)"
    );
    assert_eq!(a.layers, b.layers, "plan-parallel diverged (per-unit results)");
}

fn main() {
    let model = "bert";
    let samples = 2; // keeps a bench iteration in seconds, not minutes
    let seed = 42;
    let req = SimRequest::profile(model, 0.4, ChipConfig::default(), samples, seed)
        .expect("known model");
    let units = ModelPlan::for_request(&req).expect("profile plan").unit_count();
    // The acceptance point is jobs=8 vs jobs=1; on smaller hosts use
    // every core and scale the gate accordingly.
    let jobs = default_jobs().clamp(2, 8);
    let serial_engine = Engine::new(1);
    let parallel_engine = Engine::new(jobs);

    section(&format!(
        "transformer plan executor: {model} ({units} units, samples={samples}, jobs 1 vs {jobs})"
    ));
    let s_sim = serial_engine.run(&req);
    let p_sim = parallel_engine.run(&req);
    assert_identical(&s_sim, &p_sim);
    // The regime contract holds on the hot path too: a structured run
    // is byte-identical at every worker count.
    let nm = req.clone().with_regime(Regime::parse("nm:2:4").expect("spelling"));
    assert_identical(&serial_engine.run(&nm), &parallel_engine.run(&nm));
    println!(
        "  result: {:.2}x model speedup over baseline, {} units retained — \
         byte-identical at jobs 1 and {} (uniform and nm:2:4)",
        s_sim.overall_speedup(),
        s_sim.layers.len(),
        jobs
    );

    let s = bench("simulate_transformer_jobs1", 1, 5, || serial_engine.run(&req));
    let p = bench(&format!("simulate_transformer_jobs{jobs}"), 1, 5, || {
        parallel_engine.run(&req)
    });
    let speedup = s.median_ns / p.median_ns;
    println!("  -> plan-parallel speedup {speedup:.2}x on {jobs} workers");

    let records = vec![
        record("simulate_transformer_jobs1", &s),
        record(&format!("simulate_transformer_jobs{jobs}"), &p),
        speedup_record("simulate_transformer_speedup", s.median_ns, p.median_ns, jobs),
    ];

    // Machine-readable perf point for the BENCH_* trajectory.
    let out_path =
        std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_transformer.json".to_string());
    let mut doc = BTreeMap::new();
    doc.insert("schema".to_string(), Json::Str("tensordash.bench.v1".to_string()));
    doc.insert("bench".to_string(), Json::Str("transformer_hotpath".to_string()));
    doc.insert("records".to_string(), Json::Arr(records));
    let mut text = Json::Obj(doc).render_pretty();
    text.push('\n');
    match std::fs::write(&out_path, text.as_bytes()) {
        Ok(()) => println!("\nwrote {out_path} ({} bytes)", text.len()),
        Err(e) => eprintln!("\ncould not write {out_path}: {e}"),
    }

    // Acceptance bar (EXPERIMENTS.md §Perf), enforced after the artifact
    // is on disk so a regressing run is still archived: >= 3x at 8
    // workers, pro-rated on smaller hosts (parallel efficiency >= ~45%).
    let gate = if jobs >= 8 { 3.0 } else { jobs as f64 * 0.45 };
    if speedup < gate {
        eprintln!(
            "PERF GATE: transformer plan speedup {speedup:.2}x < {gate:.2}x on {jobs} workers \
             — unit-level parallelism regressed"
        );
        std::process::exit(1);
    }
    println!("perf gate passed: {speedup:.2}x >= {gate:.2}x on {jobs} workers");
}
