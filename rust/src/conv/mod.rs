//! Lowering the paper's three training convolutions onto the accelerator.
//!
//! Each training step runs, per layer (paper §2, Eqs. 1–3):
//!
//! 1. `Fwd`   — `O = W ★ A`, sparsity extracted from **A**;
//! 2. `Igrad` — `G_A = G_O ★ W`, sparsity extracted from **G_O**;
//! 3. `Wgrad` — `G_W = G_O ★ A`, sparsity extracted from whichever of
//!    `G_O` / `A` is sparser for the layer (§2).
//!
//! [`shape::ConvShape`] describes a layer; [`stream`] reconstructs the
//! exact 16-lane operand streams a tile row consumes from the tensors'
//! zero bitmaps; [`work`] computes the dense work geometry, memory
//! traffic and transposer load.

pub mod shape;
pub mod stream;
pub mod work;

pub use shape::{ConvShape, TrainOp, WgradSide};
pub use work::{op_work, OpWork};
