//! Dense work geometry, pass sampling, and per-op memory traffic.
//!
//! For each (layer, op) the accelerator processes `b_groups` B streams x
//! `a_groups` A streams, each stream `steps` rows deep. A tile handles
//! `tile_rows` B streams against `tile_cols` A streams per *pass*; cycle
//! counts come from simulating passes ([`sample_passes`] draws a
//! deterministic sample, mirroring the paper's one-batch-per-epoch trace
//! sampling), everything else (MAC totals, SRAM/DRAM traffic, transposer
//! load) is analytic.

use super::shape::{ConvShape, TrainOp, WgradSide};
use super::stream;
use crate::sim::chip::Pass;
use crate::sim::dram::{compressed_bytes, DramTraffic};
use crate::sim::memory::{dense_counts, SramCounts};
use crate::sim::transposer::{groups_for_values, TransposerWork};
use crate::tensor::TensorBitmap;
use crate::util::rng::Rng;

/// Dense work geometry of one (layer, op).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpWork {
    /// Number of independent B streams (rows dimension).
    pub b_groups: u64,
    /// Number of A operand groups (columns dimension).
    pub a_groups: u64,
    /// Rows (16-lane steps) per stream.
    pub steps: u64,
}

impl OpWork {
    /// Baseline chip tile-cycles: every pass takes `steps` cycles.
    pub fn baseline_tile_cycles(&self, tile_rows: u64, tile_cols: u64) -> u64 {
        self.passes(tile_rows, tile_cols) * self.steps
    }

    /// Total tile passes.
    pub fn passes(&self, tile_rows: u64, tile_cols: u64) -> u64 {
        self.b_groups.div_ceil(tile_rows) * self.a_groups.div_ceil(tile_cols)
    }
}

/// Work geometry for `op` on layer `s` (see module docs for stream
/// orientation per op).
pub fn op_work(s: &ConvShape, op: TrainOp, wside: WgradSide) -> OpWork {
    match op {
        TrainOp::Fwd => OpWork {
            b_groups: (s.n * s.out_h() * s.out_w()) as u64,
            a_groups: s.f as u64,
            steps: (s.kh * s.kw * s.c_blocks()) as u64,
        },
        TrainOp::Igrad => OpWork {
            b_groups: (s.n * s.h * s.w) as u64,
            a_groups: s.c as u64,
            steps: (s.kh * s.kw * s.f_blocks()) as u64,
        },
        TrainOp::Wgrad => {
            let steps = (s.n * s.out_h() * s.out_w()).div_ceil(16) as u64;
            match wside {
                WgradSide::Gradients => OpWork {
                    b_groups: s.f as u64,
                    a_groups: (s.kh * s.kw * s.c) as u64,
                    steps,
                },
                WgradSide::Activations => OpWork {
                    b_groups: (s.kh * s.kw * s.c) as u64,
                    a_groups: s.f as u64,
                    steps,
                },
            }
        }
    }
}

/// Pick the Wgrad B side: "we target sparsity in G_O or A whichever is
/// higher" (paper §2).
pub fn pick_wgrad_side(a: &TensorBitmap, g: &TensorBitmap) -> WgradSide {
    if g.sparsity() >= a.sparsity() {
        WgradSide::Gradients
    } else {
        WgradSide::Activations
    }
}

/// Build the `idx`-th B stream of `op`.
pub fn build_stream(
    s: &ConvShape,
    op: TrainOp,
    wside: WgradSide,
    a: &TensorBitmap,
    g: &TensorBitmap,
    idx: u64,
) -> Vec<u16> {
    match op {
        TrainOp::Fwd => {
            let (oh, ow) = (s.out_h(), s.out_w());
            let per = (oh * ow) as u64;
            let n = (idx / per) as usize;
            let oy = ((idx % per) / ow as u64) as usize;
            let ox = (idx % ow as u64) as usize;
            stream::fwd_stream(a, s, n, oy, ox)
        }
        TrainOp::Igrad => {
            let per = (s.h * s.w) as u64;
            let n = (idx / per) as usize;
            let y = ((idx % per) / s.w as u64) as usize;
            let x = (idx % s.w as u64) as usize;
            stream::igrad_stream(g, s, n, y, x)
        }
        TrainOp::Wgrad => match wside {
            WgradSide::Gradients => stream::wgrad_g_stream(g, s, idx as usize),
            WgradSide::Activations => {
                let c = (idx % s.c as u64) as usize;
                let rest = (idx / s.c as u64) as usize;
                let kx = rest % s.kw;
                let ky = rest / s.kw;
                stream::wgrad_a_stream(a, s, ky, kx, c)
            }
        },
    }
}

/// Deterministically sample up to `max_passes` tile passes of `op`.
///
/// Consecutive B streams map to consecutive tile rows (the natural work
/// assignment); a sample is a uniformly drawn pass index. Every returned
/// pass carries weight = (total passes represented) / (samples), folded
/// to integers via largest-remainder so aggregate totals stay exact.
pub fn sample_passes(
    s: &ConvShape,
    op: TrainOp,
    wside: WgradSide,
    a: &TensorBitmap,
    g: &TensorBitmap,
    tile_rows: usize,
    max_passes: usize,
    stream_repeat: usize,
    rng: &mut Rng,
) -> Vec<Pass> {
    let work = op_work(s, op, wside);
    let b_passes = work.b_groups.div_ceil(tile_rows as u64) as usize;
    let n_sample = b_passes.min(max_passes.max(1));
    let chosen: Vec<usize> = if n_sample == b_passes {
        (0..b_passes).collect()
    } else {
        rng.sample_indices(b_passes, n_sample)
    };
    // Spread total weight over samples exactly.
    let total = b_passes as u64;
    let basew = total / n_sample as u64;
    let extra = (total % n_sample as u64) as usize;
    chosen
        .iter()
        .enumerate()
        .map(|(i, &p)| {
            let streams: Vec<Vec<u16>> = (0..tile_rows as u64)
                .map(|r| p as u64 * tile_rows as u64 + r)
                .filter(|&b| b < work.b_groups)
                .map(|b| {
                    let one = build_stream(s, op, wside, a, g, b);
                    if stream_repeat > 1 {
                        // Wgrad's reduction runs over the batch: extend
                        // the stream to the paper's real batch length.
                        one.repeat(stream_repeat)
                    } else {
                        one
                    }
                })
                .collect();
            Pass { streams, weight: basew + u64::from(i < extra) }
        })
        .collect()
}

/// Analytic SRAM access counts for one (layer, op).
pub fn sram_counts(
    s: &ConvShape,
    op: TrainOp,
    wside: WgradSide,
    tile_rows: u64,
    tile_cols: u64,
) -> SramCounts {
    let w = op_work(s, op, wside);
    dense_counts(w.steps, w.b_groups, w.a_groups, tile_rows, tile_cols)
}

/// Off-chip traffic for one (layer, op): read both operand tensors, write
/// the output tensor, all zero-compressed (compressing DMA — used by
/// baseline AND TensorDash, Table 2).
///
/// `out_density` is the output tensor's non-zero fraction if known (the
/// coordinator passes the next layer's measured bitmap density; synthetic
/// profiles pass their profile value), else 1.0.
///
/// `batch_mult` scales the *batch-dependent* tensors (activations,
/// gradients) to the paper's real batch sizes (64–143) while the
/// sparsity statistics come from a small simulated batch — weights are
/// batch-independent (DESIGN.md sampling substitution).
pub fn dram_traffic(
    s: &ConvShape,
    op: TrainOp,
    a: &TensorBitmap,
    g: &TensorBitmap,
    elem_bytes: u64,
    out_density: f64,
    batch_mult: u64,
) -> DramTraffic {
    let m = batch_mult.max(1);
    let (in1, d1, in2, d2, out_vals) = match op {
        // A and W in; O out.
        TrainOp::Fwd => (s.a_values() * m, a.density(), s.w_values(), 1.0, s.g_values() * m),
        // G and W in; G_A out.
        TrainOp::Igrad => (s.g_values() * m, g.density(), s.w_values(), 1.0, s.a_values() * m),
        // G and A in; G_W out (dense).
        TrainOp::Wgrad => {
            (s.g_values() * m, g.density(), s.a_values() * m, a.density(), s.w_values())
        }
    };
    DramTraffic {
        read_bytes: compressed_bytes(in1, elem_bytes, d1) + compressed_bytes(in2, elem_bytes, d2),
        write_bytes: compressed_bytes(out_vals, elem_bytes, out_density),
    }
}

/// Transposer load: ops whose operand order differs from the stored
/// layout. Weights are reconstructed (rotated/transposed) for Igrad;
/// gradients are re-grouped spatially for Wgrad's B=G side; activations
/// likewise for B=A (paper §3.4: "needed for the weights and the
/// gradients").
pub fn transposer_work(s: &ConvShape, op: TrainOp, wside: WgradSide) -> TransposerWork {
    let groups = match op {
        TrainOp::Fwd => 0,
        TrainOp::Igrad => groups_for_values(s.w_values()),
        TrainOp::Wgrad => match wside {
            WgradSide::Gradients => groups_for_values(s.g_values()),
            WgradSide::Activations => groups_for_values(s.a_values()),
        },
    };
    TransposerWork { groups }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bitmap(dims: (usize, usize, usize, usize), density: f64, seed: u64) -> TensorBitmap {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..dims.0 * dims.1 * dims.2 * dims.3)
            .map(|_| if rng.chance(density) { 1.0 } else { 0.0 })
            .collect();
        TensorBitmap::from_f32(dims, &data)
    }

    fn layer() -> ConvShape {
        ConvShape::conv(2, 8, 8, 32, 32, 3, 1, 1)
    }

    #[test]
    fn work_geometry_mac_consistency() {
        // All three ops must cover the same MAC count (paper §2) up to
        // lane padding in the wgrad spatial blocks.
        let s = layer();
        for op in TrainOp::ALL {
            let w = op_work(&s, op, WgradSide::Gradients);
            let covered = w.b_groups * w.a_groups * w.steps * 16;
            let macs = s.macs();
            assert!(
                covered >= macs,
                "{op:?} covers {covered} < {macs}"
            );
            // within 2x (padding waste only; exact when dims align)
            assert!(covered <= macs * 2, "{op:?} covers {covered} >> {macs}");
        }
    }

    #[test]
    fn fwd_work_exact() {
        let s = layer();
        let w = op_work(&s, TrainOp::Fwd, WgradSide::Gradients);
        assert_eq!(w.b_groups, 2 * 64);
        assert_eq!(w.a_groups, 32);
        assert_eq!(w.steps, 9 * 2);
        assert_eq!(w.b_groups * w.a_groups * w.steps * 16, s.macs());
    }

    #[test]
    fn sampling_weights_sum_exact() {
        let s = layer();
        let (a, g) = (bitmap((2, 8, 8, 32), 0.5, 1), bitmap((2, 8, 8, 32), 0.5, 2));
        let mut rng = Rng::new(3);
        let passes =
            sample_passes(&s, TrainOp::Fwd, WgradSide::Gradients, &a, &g, 4, 7, 1, &mut rng);
        assert_eq!(passes.len(), 7);
        let total_weight: u64 = passes.iter().map(|p| p.weight).sum();
        assert_eq!(total_weight, (2u64 * 64).div_ceil(4));
    }

    #[test]
    fn sampling_full_coverage_when_small() {
        let s = ConvShape::fc(4, 64, 32);
        let (a, g) = (bitmap((4, 1, 1, 64), 0.5, 4), bitmap((4, 1, 1, 32), 0.5, 5));
        let mut rng = Rng::new(6);
        // b_groups = 4 -> 1 pass with 4 rows.
        let passes =
            sample_passes(&s, TrainOp::Fwd, WgradSide::Gradients, &a, &g, 4, 100, 1, &mut rng);
        assert_eq!(passes.len(), 1);
        assert_eq!(passes[0].streams.len(), 4);
        assert_eq!(passes[0].weight, 1);
    }

    #[test]
    fn wgrad_side_choice() {
        let sparse = bitmap((2, 8, 8, 32), 0.2, 7);
        let dense = bitmap((2, 8, 8, 32), 0.9, 8);
        assert_eq!(pick_wgrad_side(&dense, &sparse), WgradSide::Gradients);
        assert_eq!(pick_wgrad_side(&sparse, &dense), WgradSide::Activations);
    }

    #[test]
    fn dram_traffic_compression() {
        let s = layer();
        let a = bitmap((2, 8, 8, 32), 1.0, 9);
        let g = bitmap((2, 8, 8, 32), 0.0, 10);
        let t = dram_traffic(&s, TrainOp::Wgrad, &a, &g, 4, 1.0, 1);
        // G side compresses to just the presence bitmap.
        let g_bytes = s.g_values() / 8;
        let a_bytes = s.a_values() / 8 + s.a_values() * 4;
        assert_eq!(t.read_bytes, g_bytes + a_bytes);
        assert_eq!(t.write_bytes, s.w_values() / 8 + s.w_values() * 4);
    }

    #[test]
    fn transposer_only_for_backward_ops() {
        let s = layer();
        assert_eq!(transposer_work(&s, TrainOp::Fwd, WgradSide::Gradients).groups, 0);
        assert!(transposer_work(&s, TrainOp::Igrad, WgradSide::Gradients).groups > 0);
        assert!(transposer_work(&s, TrainOp::Wgrad, WgradSide::Gradients).groups > 0);
    }

    #[test]
    fn baseline_cycles_match_dense_math() {
        let s = layer();
        let w = op_work(&s, TrainOp::Fwd, WgradSide::Gradients);
        // 128 B-groups / 4 rows = 32 passes x 8 col passes x 18 steps.
        assert_eq!(w.baseline_tile_cycles(4, 4), 32 * 8 * 18);
    }
}
