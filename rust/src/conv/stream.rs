//! Reconstructing PE operand streams from tensor zero bitmaps.
//!
//! A tile row consumes its B operand as a sequence of 16-lane rows. This
//! module builds those sequences — including the exact operand *order*
//! each of the three training convolutions uses (paper §2, Table 1):
//!
//! * **Fwd**: one stream per output position `(n, oy, ox)`; steps run
//!   over `(ky, kx, channel-block)` of the window, channel fastest
//!   (matching the §3.4 layout: 16 channel-contiguous values per access).
//! * **Igrad**: one stream per *input* position `(n, y, x)`; steps run
//!   over the reconstructed (rotated, C/F-swapped) filter positions with
//!   the output gradients **dilated by the stride** — positions that
//!   fall between dilation holes or outside the gradient tensor
//!   contribute all-zero lane words.
//! * **Wgrad**: the reduction runs over batch x output-space. With B = G
//!   one stream per filter channel `f` (lanes = 16 consecutive `ox`
//!   positions — the transposed access the §3.4 transposers provide);
//!   with B = A one stream per weight position `(ky, kx, c)`.
//!
//! These builders are exact: feeding them the bitmaps of real tensors
//! reproduces the real MAC streams (validated in rust/tests against the
//! runtime-executed model).

use super::shape::ConvShape;
use crate::tensor::TensorBitmap;

/// B stream for the forward conv at output `(n, oy, ox)`.
///
/// `a` is the input-activation bitmap of shape `(n, h, w, c)`.
pub fn fwd_stream(a: &TensorBitmap, s: &ConvShape, n: usize, oy: usize, ox: usize) -> Vec<u16> {
    debug_assert_eq!(a.c, s.c);
    let mut rows = Vec::with_capacity(s.kh * s.kw * s.c_blocks());
    for ky in 0..s.kh {
        for kx in 0..s.kw {
            let iy = (oy * s.stride + ky) as isize - s.pad as isize;
            let ix = (ox * s.stride + kx) as isize - s.pad as isize;
            for cb in 0..s.c_blocks() {
                rows.push(a.lane_word_padded(n, iy, ix, cb));
            }
        }
    }
    rows
}

/// B stream for the input-gradient conv at input position `(n, y, x)`.
///
/// `g` is the output-gradient bitmap of shape `(n, oh, ow, f)`. The
/// gradients are dilated by the forward stride and convolved with the
/// rotated filters; a window position maps back to gradient `(oy, ox)`
/// only when the dilated coordinate is divisible by the stride.
pub fn igrad_stream(g: &TensorBitmap, s: &ConvShape, n: usize, y: usize, x: usize) -> Vec<u16> {
    debug_assert_eq!(g.c, s.f);
    let (oh, ow) = (s.out_h(), s.out_w());
    let mut rows = Vec::with_capacity(s.kh * s.kw * s.f_blocks());
    for ky in 0..s.kh {
        for kx in 0..s.kw {
            // Position in the dilated gradient tensor. The forward output
            // (oy, ox) contributes to input y iff y = oy*stride + ky - pad.
            let dy = y as isize + s.pad as isize - ky as isize;
            let dx = x as isize + s.pad as isize - kx as isize;
            let valid = dy >= 0
                && dx >= 0
                && dy % s.stride as isize == 0
                && dx % s.stride as isize == 0
                && (dy / s.stride as isize) < oh as isize
                && (dx / s.stride as isize) < ow as isize;
            for fb in 0..s.f_blocks() {
                rows.push(if valid {
                    g.lane_word(
                        n,
                        (dy / s.stride as isize) as usize,
                        (dx / s.stride as isize) as usize,
                        fb,
                    )
                } else {
                    0
                });
            }
        }
    }
    rows
}

/// Map a flat reduction index to `(n, oy, ox)` for the Wgrad reduction
/// over batch x output-space.
#[inline]
fn wgrad_pos(s: &ConvShape, r: usize) -> (usize, usize, usize) {
    let (oh, ow) = (s.out_h(), s.out_w());
    let per_n = oh * ow;
    (r / per_n, (r % per_n) / ow, r % ow)
}

/// Total flat reduction length of the Wgrad op.
pub fn wgrad_reduction(s: &ConvShape) -> usize {
    s.n * s.out_h() * s.out_w()
}

/// B stream for the weight-gradient conv with **B = gradients**: fixed
/// filter channel `f`, lanes along 16 *consecutive flat reduction
/// indices* `(n, oy, ox)` — the transposed access the §3.4 layout's
/// transposers provide (and the §3.6.2 group-spanning schedule permits;
/// for FC layers the reduction is the batch dimension).
pub fn wgrad_g_stream(g: &TensorBitmap, s: &ConvShape, f: usize) -> Vec<u16> {
    debug_assert_eq!(g.c, s.f);
    let red = wgrad_reduction(s);
    let mut rows = Vec::with_capacity(red.div_ceil(16));
    for base in (0..red).step_by(16) {
        let mut word = 0u16;
        for l in 0..16 {
            let r = base + l;
            if r >= red {
                break;
            }
            let (n, oy, ox) = wgrad_pos(s, r);
            if g.bit(n, oy, ox, f) {
                word |= 1 << l;
            }
        }
        rows.push(word);
    }
    rows
}

/// B stream for the weight-gradient conv with **B = activations**: fixed
/// weight position `(ky, kx, c)`, lanes along the same flat reduction.
pub fn wgrad_a_stream(
    a: &TensorBitmap,
    s: &ConvShape,
    ky: usize,
    kx: usize,
    c: usize,
) -> Vec<u16> {
    debug_assert_eq!(a.c, s.c);
    let red = wgrad_reduction(s);
    let mut rows = Vec::with_capacity(red.div_ceil(16));
    for base in (0..red).step_by(16) {
        let mut word = 0u16;
        for l in 0..16 {
            let r = base + l;
            if r >= red {
                break;
            }
            let (n, oy, ox) = wgrad_pos(s, r);
            let iy = (oy * s.stride + ky) as isize - s.pad as isize;
            let ix = (ox * s.stride + kx) as isize - s.pad as isize;
            if iy >= 0
                && ix >= 0
                && (iy as usize) < a.h
                && (ix as usize) < a.w
                && a.bit(n, iy as usize, ix as usize, c)
            {
                word |= 1 << l;
            }
        }
        rows.push(word);
    }
    rows
}

// ---------------------------------------------------------------------
// A-side (dense-operand) stream builders — used by the two-side
// extraction mode (§3.1/Fig. 8, the paper's deferred evaluation): the
// A operand of each op, in the SAME step order as the matching B stream,
// so `AZ & BZ` is a per-slot AND of the two streams.
// ---------------------------------------------------------------------

/// Weight bitmaps are stored as `(f, kh, kw, c)` tensors (`n` = filter).
pub type WeightBitmap = TensorBitmap;

/// A stream of the forward conv for filter `f`: steps over
/// `(ky, kx, c-block)` — aligned with [`fwd_stream`].
pub fn fwd_weight_stream(w: &WeightBitmap, s: &ConvShape, f: usize) -> Vec<u16> {
    debug_assert_eq!(w.c, s.c);
    debug_assert_eq!((w.h, w.w), (s.kh, s.kw));
    let mut rows = Vec::with_capacity(s.kh * s.kw * s.c_blocks());
    for ky in 0..s.kh {
        for kx in 0..s.kw {
            for cb in 0..s.c_blocks() {
                rows.push(w.lane_word(f, ky, kx, cb));
            }
        }
    }
    rows
}

/// A stream of the input-gradient conv for output channel `c`: the
/// reconstructed (rotated, C/F-swapped) filters, steps over
/// `(ky, kx, f-block)` with lanes along the filter dim — aligned with
/// [`igrad_stream`].
pub fn igrad_weight_stream(w: &WeightBitmap, s: &ConvShape, c: usize) -> Vec<u16> {
    debug_assert_eq!(w.c, s.c);
    let mut rows = Vec::with_capacity(s.kh * s.kw * s.f_blocks());
    for ky in 0..s.kh {
        for kx in 0..s.kw {
            for fb in 0..s.f_blocks() {
                let mut word = 0u16;
                for l in 0..16 {
                    let f = fb * 16 + l;
                    if f < s.f && w.bit(f, s.kh - 1 - ky, s.kw - 1 - kx, c) {
                        word |= 1 << l;
                    }
                }
                rows.push(word);
            }
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_bitmap(dims: (usize, usize, usize, usize), density: f64, seed: u64) -> TensorBitmap {
        let (n, h, w, c) = dims;
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..n * h * w * c)
            .map(|_| if rng.chance(density) { 1.0 } else { 0.0 })
            .collect();
        TensorBitmap::from_f32(dims, &data)
    }

    #[test]
    fn fwd_stream_length_and_density() {
        let s = ConvShape::conv(2, 8, 8, 32, 32, 3, 1, 1);
        let a = random_bitmap((2, 8, 8, 32), 0.5, 1);
        let st = fwd_stream(&a, &s, 0, 3, 3);
        assert_eq!(st.len(), 3 * 3 * 2);
        // interior window: expected ~50% bit density
        let ones: u32 = st.iter().map(|w| w.count_ones()).sum();
        let d = ones as f64 / (st.len() as f64 * 16.0);
        assert!(d > 0.3 && d < 0.7, "density {d}");
    }

    #[test]
    fn fwd_stream_corner_has_halo_zeros() {
        let s = ConvShape::conv(1, 8, 8, 16, 16, 3, 1, 1);
        let a = random_bitmap((1, 8, 8, 16), 1.0, 2);
        let st = fwd_stream(&a, &s, 0, 0, 0);
        // (ky=0) row and (kx=0) column fall outside: 3 + 2 = 5 of 9 taps
        // valid => 4 zero rows... taps (0,0),(0,1),(0,2),(1,0),(2,0) are
        // out of bounds = 5 zero rows of 9.
        let zero_rows = st.iter().filter(|&&w| w == 0).count();
        assert_eq!(zero_rows, 5);
        assert_eq!(st.len(), 9);
    }

    #[test]
    fn fwd_stream_exhaustive_bit_check() {
        // Every bit in the stream must equal the source bitmap bit.
        let s = ConvShape::conv(1, 5, 5, 16, 16, 3, 2, 1);
        let a = random_bitmap((1, 5, 5, 16), 0.4, 3);
        for oy in 0..s.out_h() {
            for ox in 0..s.out_w() {
                let st = fwd_stream(&a, &s, 0, oy, ox);
                let mut i = 0;
                for ky in 0..3 {
                    for kx in 0..3 {
                        let iy = (oy * 2 + ky) as isize - 1;
                        let ix = (ox * 2 + kx) as isize - 1;
                        for l in 0..16 {
                            let want = iy >= 0
                                && ix >= 0
                                && (iy as usize) < 5
                                && (ix as usize) < 5
                                && a.bit(0, iy as usize, ix as usize, l);
                            assert_eq!(st[i] & (1 << l) != 0, want);
                        }
                        i += 1;
                    }
                }
            }
        }
    }

    #[test]
    fn igrad_stream_dilation_holes() {
        // stride 2: only every other window position maps to a gradient.
        let s = ConvShape::conv(1, 8, 8, 16, 16, 3, 2, 1);
        let g = random_bitmap((1, 4, 4, 16), 1.0, 4);
        // Input position (1,1): dy = 1+1-ky for ky in 0..3 => 2,1,0; only
        // even dy/dx map to gradients (stride 2).
        let st = igrad_stream(&g, &s, 0, 1, 1);
        assert_eq!(st.len(), 9);
        // valid (ky,kx) are those with dy,dx even: ky in {0,2} x kx {0,2}.
        let nonzero = st.iter().filter(|&&w| w != 0).count();
        assert_eq!(nonzero, 4);
    }

    #[test]
    fn igrad_stream_stride1_matches_full_conv() {
        let s = ConvShape::conv(1, 6, 6, 16, 16, 3, 1, 1);
        let g = random_bitmap((1, 6, 6, 16), 0.5, 5);
        // interior input position: all 9 taps valid.
        let st = igrad_stream(&g, &s, 0, 3, 3);
        assert_eq!(st.len(), 9);
        let mut i = 0;
        for ky in 0..3usize {
            for kx in 0..3usize {
                let oy = 3 + 1 - ky;
                let ox = 3 + 1 - kx;
                assert_eq!(st[i], g.lane_word(0, oy, ox, 0));
                i += 1;
            }
        }
    }

    #[test]
    fn wgrad_g_stream_covers_reduction() {
        let s = ConvShape::conv(2, 8, 8, 16, 32, 3, 1, 1);
        let g = random_bitmap((2, 8, 8, 32), 0.5, 6);
        let st = wgrad_g_stream(&g, &s, 17);
        // flat reduction 2*8*8 = 128 -> 8 rows of 16 lanes, no padding.
        assert_eq!(st.len(), 8);
        // lane l of row 0 = flat index l = (n=0, oy=l/8, ox=l%8).
        for l in 0..16usize {
            assert_eq!(st[0] & (1 << l) != 0, g.bit(0, l / 8, l % 8, 17));
        }
        // row 4 starts at flat 64 = sample 1.
        assert_eq!(st[4] & 1 != 0, g.bit(1, 0, 0, 17));
    }

    #[test]
    fn wgrad_a_stream_matches_padded_taps() {
        let s = ConvShape::conv(1, 8, 8, 16, 16, 3, 1, 1);
        let a = random_bitmap((1, 8, 8, 16), 0.6, 7);
        let st = wgrad_a_stream(&a, &s, 0, 0, 5);
        // 64 outputs -> 4 rows of 16.
        assert_eq!(st.len(), 4);
        // row 0 covers oy in {0,1}: oy=0 -> iy=-1 halo (lanes 0..8 zero);
        // oy=1 -> iy=0, ix = ox-1.
        assert_eq!(st[0] & 0xFF, 0, "first output row is halo");
        for l in 8..16usize {
            let ox = l - 8;
            let want = ox >= 1 && a.bit(0, 0, ox - 1, 5);
            assert_eq!(st[0] & (1 << l) != 0, want, "lane {l}");
        }
    }

    #[test]
    fn wgrad_fc_lanes_along_batch() {
        // FC layers: the reduction is the batch dimension — no fake
        // padding lanes (the bug this test pins down).
        let s = ConvShape::fc(32, 64, 32);
        let g = random_bitmap((32, 1, 1, 32), 0.5, 10);
        let st = wgrad_g_stream(&g, &s, 7);
        assert_eq!(st.len(), 2);
        for l in 0..16usize {
            assert_eq!(st[0] & (1 << l) != 0, g.bit(l, 0, 0, 7));
            assert_eq!(st[1] & (1 << l) != 0, g.bit(16 + l, 0, 0, 7));
        }
    }

    #[test]
    fn fc_layer_streams() {
        // FC layers degenerate to single-tap streams.
        let s = ConvShape::fc(4, 64, 32);
        let a = random_bitmap((4, 1, 1, 64), 0.5, 8);
        let st = fwd_stream(&a, &s, 2, 0, 0);
        assert_eq!(st.len(), 4); // 64/16 channel blocks
        assert_eq!(st[0], a.lane_word(2, 0, 0, 0));
        let g = random_bitmap((4, 1, 1, 32), 0.5, 9);
        let gi = igrad_stream(&g, &s, 1, 0, 0);
        assert_eq!(gi.len(), 2);
        assert_eq!(gi[0], g.lane_word(1, 0, 0, 0));
    }
}
