//! Layer geometry and the three training operations.

/// A convolutional (or fully-connected) layer's geometry for one batch.
///
/// Fully-connected layers are the `h = w = kh = kw = 1` special case
/// (paper Table 1: "a fully-connected layer can be treated as a
/// special-case convolutional layer").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvShape {
    /// Batch samples processed together.
    pub n: usize,
    /// Input spatial dims.
    pub h: usize,
    pub w: usize,
    /// Input channels (multiple of 16).
    pub c: usize,
    /// Filters / output channels (multiple of 16 for lane alignment).
    pub f: usize,
    /// Kernel spatial dims.
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
}

impl ConvShape {
    pub fn conv(
        n: usize,
        h: usize,
        w: usize,
        c: usize,
        f: usize,
        k: usize,
        stride: usize,
        pad: usize,
    ) -> Self {
        ConvShape { n, h, w, c, f, kh: k, kw: k, stride, pad }
    }

    /// Fully-connected layer: `c` inputs, `f` outputs.
    pub fn fc(n: usize, c: usize, f: usize) -> Self {
        ConvShape { n, h: 1, w: 1, c, f, kh: 1, kw: 1, stride: 1, pad: 0 }
    }

    pub fn is_fc(&self) -> bool {
        self.h == 1 && self.w == 1 && self.kh == 1 && self.kw == 1
    }

    pub fn out_h(&self) -> usize {
        (self.h + 2 * self.pad - self.kh) / self.stride + 1
    }

    pub fn out_w(&self) -> usize {
        (self.w + 2 * self.pad - self.kw) / self.stride + 1
    }

    /// Channel blocks of 16 on the input side.
    pub fn c_blocks(&self) -> usize {
        self.c.div_ceil(16)
    }

    /// Channel blocks of 16 on the filter side.
    pub fn f_blocks(&self) -> usize {
        self.f.div_ceil(16)
    }

    /// MACs of ONE of the three operations (they perform the same number
    /// of MACs, paper §2).
    pub fn macs(&self) -> u64 {
        (self.n * self.out_h() * self.out_w()) as u64
            * (self.c * self.f * self.kh * self.kw) as u64
    }

    /// Input activation tensor element count.
    pub fn a_values(&self) -> u64 {
        (self.n * self.h * self.w * self.c) as u64
    }

    /// Output-gradient tensor element count.
    pub fn g_values(&self) -> u64 {
        (self.n * self.out_h() * self.out_w() * self.f) as u64
    }

    /// Weight tensor element count.
    pub fn w_values(&self) -> u64 {
        (self.kh * self.kw * self.c * self.f) as u64
    }
}

/// The three per-layer training computations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TrainOp {
    /// `O = W ★ A` (Eq. 4) — the paper's `A ★ W` column in Fig. 13.
    Fwd,
    /// `G_A = G_O ★ W` (Eq. 6) — `A ★ G`.
    Igrad,
    /// `G_W = G_O ★ A` (Eq. 8) — `W ★ G`.
    Wgrad,
}

impl TrainOp {
    pub const ALL: [TrainOp; 3] = [TrainOp::Fwd, TrainOp::Igrad, TrainOp::Wgrad];

    /// The paper's figure labels.
    pub fn label(self) -> &'static str {
        match self {
            TrainOp::Fwd => "A*W",
            TrainOp::Igrad => "A*G",
            TrainOp::Wgrad => "W*G",
        }
    }
}

/// Which tensor the Wgrad op schedules on its B side (§2: "whichever is
/// higher" sparsity).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WgradSide {
    Gradients,
    Activations,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry() {
        let s = ConvShape::conv(16, 8, 8, 16, 32, 3, 1, 1);
        assert_eq!((s.out_h(), s.out_w()), (8, 8));
        assert_eq!(s.macs(), 16 * 64 * (16 * 32 * 9) as u64);
        let s2 = ConvShape::conv(16, 8, 8, 32, 32, 3, 2, 1);
        assert_eq!((s2.out_h(), s2.out_w()), (4, 4));
    }

    #[test]
    fn fc_special_case() {
        let s = ConvShape::fc(16, 512, 10);
        assert!(s.is_fc());
        assert_eq!(s.macs(), 16 * 512 * 10);
        assert_eq!((s.out_h(), s.out_w()), (1, 1));
    }

    #[test]
    fn alexnet_conv1_like() {
        // 227x227x3 k11 s4 -> 55x55. (c padded to 16 by the zoo.)
        let s = ConvShape::conv(4, 227, 227, 16, 96, 11, 4, 0);
        assert_eq!((s.out_h(), s.out_w()), (55, 55));
    }
}
