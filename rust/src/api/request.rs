//! Typed simulation requests: what to simulate, under which chip
//! configuration, with which sampling budget and seed.
//!
//! A [`SimRequest`] is the unit of work the [`Engine`](super::Engine)
//! executes; a [`SweepSpec`] is a declarative grid over
//! `ChipConfig` × epoch × model that expands into one request per cell
//! with a *deterministically derived* per-cell seed — so a sweep's
//! results are identical whether its cells run on 1 worker or 16, and
//! independent of execution order.

use std::sync::Arc;

use crate::config::ChipConfig;
use crate::conv::{ConvShape, TrainOp};
use crate::sparsity::Regime;
use crate::tensor::TensorBitmap;
use crate::trace::profiles::ModelProfile;

/// What to simulate.
#[derive(Debug, Clone)]
pub enum Workload {
    /// A full model from its synthetic sparsity profile at an epoch
    /// fraction (the Fig. 13/14/17/18/19 workload), under a sparsity
    /// [`Regime`] (`Uniform` reproduces the pre-regime behaviour
    /// byte-for-byte).
    Profile { model: String, epoch: f64, regime: Regime },
    /// Like `Profile`, but carrying a pre-resolved profile behind an
    /// `Arc` — the serving layer's artifact store loads each model once
    /// and every request shares it without re-building the topology.
    ProfileShared { profile: Arc<ModelProfile>, epoch: f64, regime: Regime },
    /// A full model from *captured* (real-training) bitmaps — the
    /// `train` subcommand and `train_e2e` workload. The layer bitmaps
    /// sit behind one `Arc` so plan expansion and unit execution share
    /// them without copying the step's whole trace.
    Trace { shapes: Vec<ConvShape>, layers: Arc<Vec<(TensorBitmap, TensorBitmap)>> },
    /// Uniformly random tensors on one layer geometry at a sparsity
    /// level, all three training ops (the Fig. 20 workload).
    RandomSparse { shape: ConvShape, sparsity: f64, samples_per_level: usize, batch_mult: u64 },
    /// One (layer, op) with explicit bitmaps (the quickstart /
    /// `sparsity_sweep` workload).
    SingleOp {
        shape: ConvShape,
        op: TrainOp,
        a: TensorBitmap,
        g: TensorBitmap,
        batch_mult: u64,
    },
}

/// One executable simulation request.
#[derive(Debug, Clone)]
pub struct SimRequest {
    /// Result label (becomes `ModelSim::name` — figure row labels and
    /// the ex-gcn geomean filter key off it).
    pub label: String,
    pub cfg: ChipConfig,
    pub workload: Workload,
    /// Pass-sample budget per (layer, op) — see `repro::DEFAULT_SAMPLES`.
    pub samples: usize,
    pub seed: u64,
}

impl SimRequest {
    /// A model-profile request. Fails on an unknown model name so the
    /// error surfaces at request-build time, not inside a worker thread.
    pub fn profile(
        model: &str,
        epoch: f64,
        cfg: ChipConfig,
        samples: usize,
        seed: u64,
    ) -> Result<SimRequest, String> {
        if ModelProfile::for_model(model).is_none() {
            return Err(format!("unknown model '{model}' (see models::ALL_MODELS)"));
        }
        Ok(SimRequest {
            label: model.to_string(),
            cfg,
            workload: Workload::Profile {
                model: model.to_string(),
                epoch,
                regime: Regime::Uniform,
            },
            samples,
            seed,
        })
    }

    /// A model-profile request over an already-loaded (`Arc`-shared)
    /// profile — the zero-copy path the serving layer uses.
    pub fn profile_shared(
        profile: Arc<ModelProfile>,
        epoch: f64,
        cfg: ChipConfig,
        samples: usize,
        seed: u64,
    ) -> SimRequest {
        SimRequest {
            label: profile.name().to_string(),
            cfg,
            workload: Workload::ProfileShared { profile, epoch, regime: Regime::Uniform },
            samples,
            seed,
        }
    }

    /// Replace the sparsity regime of a profile workload. No-op on the
    /// explicit-bitmap workloads (their tensors are already decided).
    pub fn with_regime(mut self, regime: Regime) -> SimRequest {
        match &mut self.workload {
            Workload::Profile { regime: r, .. } | Workload::ProfileShared { regime: r, .. } => {
                *r = regime;
            }
            _ => {}
        }
        self
    }

    pub fn trace(
        label: &str,
        shapes: Vec<ConvShape>,
        layers: Vec<(TensorBitmap, TensorBitmap)>,
        cfg: ChipConfig,
        samples: usize,
        seed: u64,
    ) -> SimRequest {
        SimRequest {
            label: label.to_string(),
            cfg,
            workload: Workload::Trace { shapes, layers: Arc::new(layers) },
            samples,
            seed,
        }
    }

    pub fn random_sparse(
        shape: ConvShape,
        sparsity: f64,
        samples_per_level: usize,
        batch_mult: u64,
        cfg: ChipConfig,
        samples: usize,
        seed: u64,
    ) -> SimRequest {
        SimRequest {
            label: format!("sparsity {:.0}%", sparsity * 100.0),
            cfg,
            workload: Workload::RandomSparse { shape, sparsity, samples_per_level, batch_mult },
            samples,
            seed,
        }
    }

    pub fn single_op(
        label: &str,
        shape: ConvShape,
        op: TrainOp,
        a: TensorBitmap,
        g: TensorBitmap,
        batch_mult: u64,
        cfg: ChipConfig,
        samples: usize,
        seed: u64,
    ) -> SimRequest {
        SimRequest {
            label: label.to_string(),
            cfg,
            workload: Workload::SingleOp { shape, op, a, g, batch_mult },
            samples,
            seed,
        }
    }

    pub fn with_label(mut self, label: impl Into<String>) -> SimRequest {
        self.label = label.into();
        self
    }
}

/// Derive the seed for sweep cell `cell` from the sweep's base seed.
///
/// splitmix64-style finalizer: statistically independent streams per
/// cell, stable across releases (pinned by a unit test), and — because
/// it depends only on `(base, cell)` — independent of worker count and
/// execution order. Derivation chains: the plan executor derives each
/// (layer, op) unit's seed from its cell's seed with the same function
/// (`derive_seed(cell_seed, layer*3 + op)`, see
/// [`super::plan::ModelPlan`]), so the whole request → cell → unit tree
/// is order-free.
pub fn derive_seed(base: u64, cell: u64) -> u64 {
    let mut z = base ^ cell.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// A declarative sweep grid: `models` × `epochs` × `configs`.
///
/// Cell order (and therefore cell index, label and derived seed) is
/// model-major, then epoch, then config — pinned by tests and relied on
/// by the figure builders that reshape the flat result vector.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// Labelled chip configurations (the label lands in cell labels when
    /// more than one config is swept).
    pub configs: Vec<(String, ChipConfig)>,
    pub epochs: Vec<f64>,
    pub models: Vec<String>,
    pub samples: usize,
    pub base_seed: u64,
    /// Sparsity regime applied to every cell ([`Regime::Uniform`] keeps
    /// the historical bytes; seeds never depend on it, so regimes stay
    /// directly comparable on identical base tensors).
    pub regime: Regime,
}

impl SweepSpec {
    /// A single-config, single-epoch sweep over `models`.
    pub fn models(
        models: &[&str],
        epoch: f64,
        cfg: &ChipConfig,
        samples: usize,
        seed: u64,
    ) -> SweepSpec {
        SweepSpec {
            configs: vec![("default".to_string(), cfg.clone())],
            epochs: vec![epoch],
            models: models.iter().map(|m| m.to_string()).collect(),
            samples,
            base_seed: seed,
            regime: Regime::Uniform,
        }
    }

    pub fn with_epochs(mut self, epochs: &[f64]) -> SweepSpec {
        self.epochs = epochs.to_vec();
        self
    }

    pub fn with_regime(mut self, regime: Regime) -> SweepSpec {
        self.regime = regime;
        self
    }

    pub fn with_configs(mut self, configs: Vec<(String, ChipConfig)>) -> SweepSpec {
        assert!(!configs.is_empty(), "sweep needs at least one config");
        self.configs = configs;
        self
    }

    /// Number of cells in the grid.
    pub fn len(&self) -> usize {
        self.models.len() * self.epochs.len() * self.configs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Expand the grid into per-cell requests with derived seeds.
    ///
    /// The seed feeds synthetic-tensor generation and pass sampling, so
    /// it is derived from the `(model, epoch)` coordinate only: cells
    /// that differ just in `ChipConfig` (the Fig. 17–19 axes) see
    /// *identical* tensors and stay directly comparable, while distinct
    /// workloads get statistically independent streams.
    pub fn cells(&self) -> Vec<SimRequest> {
        // Uphold the build-time-rejection invariant the engine relies
        // on: a typo'd model name fails here, on the calling thread,
        // with a clear message — not inside a worker.
        for m in &self.models {
            assert!(
                ModelProfile::for_model(m).is_some(),
                "unknown model '{m}' in sweep (see models::ALL_MODELS)"
            );
        }
        let mut out = Vec::with_capacity(self.len());
        let single = self.epochs.len() == 1 && self.configs.len() == 1;
        for (mi, model) in self.models.iter().enumerate() {
            for (ei, &epoch) in self.epochs.iter().enumerate() {
                let key = (mi * self.epochs.len() + ei) as u64;
                let seed = derive_seed(self.base_seed, key);
                for (clabel, cfg) in &self.configs {
                    let label = if single {
                        model.clone()
                    } else {
                        format!("{model}@{epoch:.2}/{clabel}")
                    };
                    out.push(SimRequest {
                        label,
                        cfg: cfg.clone(),
                        workload: Workload::Profile {
                            model: model.clone(),
                            epoch,
                            regime: self.regime.clone(),
                        },
                        samples: self.samples,
                        seed,
                    });
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derive_seed_is_stable_and_spread() {
        // Pinned values: changing the derivation silently would change
        // every published report.
        assert_eq!(derive_seed(42, 0), derive_seed(42, 0));
        assert_ne!(derive_seed(42, 0), derive_seed(42, 1));
        assert_ne!(derive_seed(42, 0), derive_seed(43, 0));
        // Distinct cells never collide in a realistic grid.
        let seeds: std::collections::BTreeSet<u64> =
            (0..10_000).map(|i| derive_seed(7, i)).collect();
        assert_eq!(seeds.len(), 10_000);
    }

    #[test]
    fn sweep_cell_order_is_model_major() {
        let cfg = ChipConfig::default();
        let spec = SweepSpec::models(&["alexnet", "gcn"], 0.4, &cfg, 2, 9)
            .with_epochs(&[0.1, 0.9]);
        let cells = spec.cells();
        assert_eq!(cells.len(), 4);
        assert_eq!(cells[0].label, "alexnet@0.10/default");
        assert_eq!(cells[1].label, "alexnet@0.90/default");
        assert_eq!(cells[2].label, "gcn@0.10/default");
        assert_eq!(cells[3].label, "gcn@0.90/default");
        assert_eq!(cells[1].seed, derive_seed(9, 1));
    }

    #[test]
    fn config_variants_share_the_workload_seed() {
        // Fig. 17–19 comparisons: same tensors under every config.
        let spec = SweepSpec::models(&["alexnet", "vgg16"], 0.4, &ChipConfig::default(), 2, 5)
            .with_configs(vec![
                ("depth2".to_string(), ChipConfig::default().with_depth(2)),
                ("depth3".to_string(), ChipConfig::default()),
            ]);
        let cells = spec.cells();
        assert_eq!(cells.len(), 4);
        assert_eq!(cells[0].seed, cells[1].seed); // alexnet: depth2 == depth3
        assert_eq!(cells[2].seed, cells[3].seed); // vgg16
        assert_ne!(cells[0].seed, cells[2].seed); // across models: independent
        assert_eq!(cells[0].label, "alexnet@0.40/depth2");
    }

    #[test]
    fn single_point_sweep_labels_are_bare_model_names() {
        let cfg = ChipConfig::default();
        let cells = SweepSpec::models(&["vgg16"], 0.4, &cfg, 2, 1).cells();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].label, "vgg16");
    }

    #[test]
    #[should_panic(expected = "unknown model")]
    fn sweep_rejects_unknown_model_on_calling_thread() {
        SweepSpec::models(&["resnet5O"], 0.4, &ChipConfig::default(), 2, 1).cells();
    }

    #[test]
    fn profile_request_rejects_unknown_model() {
        assert!(SimRequest::profile("nope", 0.4, ChipConfig::default(), 2, 1).is_err());
        assert!(SimRequest::profile("resnet50", 0.4, ChipConfig::default(), 2, 1).is_ok());
        assert!(SimRequest::profile("bert", 0.4, ChipConfig::default(), 2, 1).is_ok());
    }

    #[test]
    fn regimes_thread_through_requests_and_sweeps() {
        let nm = Regime::parse("nm:2:4").unwrap();
        let req = SimRequest::profile("bert", 0.4, ChipConfig::default(), 2, 1)
            .unwrap()
            .with_regime(nm.clone());
        match &req.workload {
            Workload::Profile { regime, .. } => assert_eq!(*regime, nm),
            w => panic!("unexpected workload {w:?}"),
        }
        // Sweeps stamp the regime on every cell, but seeds stay derived
        // from the (model, epoch) coordinate alone: regimes compare on
        // identical base tensors.
        let cfg = ChipConfig::default();
        let base = SweepSpec::models(&["alexnet", "gcn"], 0.4, &cfg, 2, 9).cells();
        let cells = SweepSpec::models(&["alexnet", "gcn"], 0.4, &cfg, 2, 9)
            .with_regime(nm.clone())
            .cells();
        assert_eq!(cells.len(), base.len());
        for (b, c) in base.iter().zip(&cells) {
            assert_eq!(b.seed, c.seed);
            match &c.workload {
                Workload::Profile { regime, .. } => assert_eq!(*regime, nm),
                w => panic!("unexpected workload {w:?}"),
            }
        }
    }
}
