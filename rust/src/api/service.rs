//! The persistent simulation service: a JSON-lines request loop over
//! stdin/stdout or TCP, answering simulation requests from the shared
//! [`UnitCache`] wherever possible.
//!
//! The dominant real workload for a simulator like this is
//! design-space search: thousands of overlapping configuration queries
//! against one model set, where successive requests share most of
//! their (layer × op) units. The service keeps one process resident so
//! those requests stop paying process startup, artifact reload and
//! unit recomputation:
//!
//! * **Protocol** — one JSON object per line in, one JSON object per
//!   line out (`tensordash.serve.v1`). Ops: `simulate`, `sweep`,
//!   `trace`, `explore`, `batch`, `stats`, `store_ingest`,
//!   `store_query`, `store_diff`, `shutdown`. Unknown fields are
//!   ignored; malformed lines answer `{"ok":false,...}` without
//!   killing the loop. Every response is built through one typed
//!   [`ServeReply`] envelope so ops cannot drift apart.
//! * **Coalescing** — a `batch` op runs all of its sub-requests
//!   through *one* engine invocation, so identical units across the
//!   batch's cells simulate once (deterministically, in the engine's
//!   serial lookup phase); units identical to ones in flight on other
//!   concurrent connections block on the first computation instead of
//!   repeating it ([`UnitCache::compute_coalesced`]).
//! * **Artifact store** — model profiles and captured-trace bitmap
//!   files are loaded once and shared by `Arc` across every request
//!   and connection ([`ArtifactStore`]); a trace request never copies
//!   a bitmap.
//! * **Determinism** — the `report` field of a response is computed
//!   from the merged simulation only: a cache-served response is
//!   byte-identical to a cold-computed one. Cache telemetry rides in
//!   the separate `cache` envelope field (counters move between runs
//!   by design, so they must not — and do not — touch the report).
//! * **Transport** — the TCP mode multiplexes at *request* grain: a
//!   per-connection reader thread parses and tags each line into one
//!   global depth-limited request queue, `--workers` compute threads
//!   execute individual requests, and a per-connection writer thread
//!   delivers the responses. One slow cold sweep therefore no longer
//!   pins a compute slot against a whole connection — cheap cache-hit
//!   requests from the same or other connections overtake it. Past
//!   `--queue-depth` queued *requests* the reader sheds load with an
//!   explicit `tensordash.serve.v1` "overloaded" error line; the
//!   connection itself stays open.
//! * **Ordering & streaming** — by default the writer re-sequences
//!   completions so responses stream strictly in request order per
//!   connection, exactly the v1 contract. A request carrying
//!   `"stream": true` opts out: its response is written the moment it
//!   completes, tagged with an `"op"` echo so the client can correlate
//!   out-of-order lines (ids are already echoed).
//! * **Deadlines & cancellation** — `--request-timeout` (or a
//!   per-request `timeout_ms` field) stamps each request with a
//!   deadline at enqueue; a request still queued past its deadline
//!   answers an in-band "timeout" error instead of computing, exactly
//!   mirroring the "overloaded" shed semantics. Work queued for a
//!   client that disconnected is cancelled at dequeue, and shutdown
//!   drains the queue with in-band errors — a dead client cannot hold
//!   compute slots. The `stats` op reports shed/timeout/cancel/stream
//!   counters under `mux`.
//! * **Telemetry** — every handled request records its wall-clock
//!   duration into a fixed-capacity reservoir (the most recent
//!   `LAT_RESERVOIR_CAP` samples, plus exact running count and max,
//!   so a resident server's memory stays bounded); the `stats` op
//!   reports p50/p99 percentiles over the retained window plus the
//!   exact max (nearest-rank, so the summary is a deterministic
//!   function of the recorded durations), letting store-backed serve
//!   runs be compared across PRs.
//! * **Store ops** — `store_ingest`/`store_query`/`store_diff` expose
//!   the [`ExperimentStore`](crate::store::ExperimentStore) over the
//!   same protocol as the `store` CLI subcommand: ingest response
//!   reports into an indexed history file, query a metric's trajectory
//!   across commits, diff two commits' reports or frontiers.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::config::ChipConfig;
use crate::conv::{ConvShape, TrainOp};
use crate::repro::{self, ModelSim};
use crate::search::{self, ExploreSpec, SearchSpace, SPACE_SCHEMA};
use crate::store::{ExperimentStore, QueryFilter};
use crate::tensor::TensorBitmap;
use crate::trace::profiles::ModelProfile;
use crate::util::json::Json;

use super::cache::{shape_json, UnitCache};
use super::engine::Engine;
use super::params;
use super::plan::layers_report;
use super::report::{report_set_json, Cell, Report};
use super::request::{SimRequest, SweepSpec, Workload};

/// Schema tag of every response line.
pub const SERVE_SCHEMA: &str = "tensordash.serve.v1";
/// Schema tag of on-disk trace artifacts ([`TraceArtifact`]).
pub const TRACE_SCHEMA: &str = "tensordash.trace.v1";
/// Default compute-pool size for the TCP transport (`--workers`).
pub const DEFAULT_SERVE_WORKERS: usize = 8;
/// Default pending-request queue depth (`--queue-depth`); past this
/// many queued requests the readers shed load in-band.
pub const DEFAULT_QUEUE_DEPTH: usize = 64;
/// Latency samples retained by the stats reservoir.
const LAT_RESERVOIR_CAP: usize = 4096;

// ---------------------------------------------------------------------
// Trace artifacts + the Arc-backed artifact store
// ---------------------------------------------------------------------

/// A captured training trace: per-layer geometry plus (A, G) zero
/// bitmaps, loaded once and shared by `Arc` across every request that
/// references it.
#[derive(Debug, Clone)]
pub struct TraceArtifact {
    pub name: String,
    pub shapes: Vec<ConvShape>,
    pub layers: Arc<Vec<(TensorBitmap, TensorBitmap)>>,
}

fn shape_from_json(j: &Json) -> Option<ConvShape> {
    Some(ConvShape {
        n: j.get("n")?.as_usize()?,
        h: j.get("h")?.as_usize()?,
        w: j.get("w")?.as_usize()?,
        c: j.get("c")?.as_usize()?,
        f: j.get("f")?.as_usize()?,
        kh: j.get("kh")?.as_usize()?,
        kw: j.get("kw")?.as_usize()?,
        stride: j.get("stride")?.as_usize()?,
        pad: j.get("pad")?.as_usize()?,
    })
}

impl TraceArtifact {
    pub fn new(
        name: impl Into<String>,
        shapes: Vec<ConvShape>,
        layers: Vec<(TensorBitmap, TensorBitmap)>,
    ) -> TraceArtifact {
        assert_eq!(shapes.len(), layers.len(), "trace shapes/layers mismatch");
        TraceArtifact { name: name.into(), shapes, layers: Arc::new(layers) }
    }

    pub fn to_json(&self) -> Json {
        let layers = self
            .shapes
            .iter()
            .zip(self.layers.iter())
            .map(|(s, (a, g))| {
                let mut m = BTreeMap::new();
                m.insert("shape".to_string(), shape_json(s));
                m.insert("a".to_string(), a.to_json());
                m.insert("g".to_string(), g.to_json());
                Json::Obj(m)
            })
            .collect();
        let mut m = BTreeMap::new();
        m.insert("schema".to_string(), Json::Str(TRACE_SCHEMA.to_string()));
        m.insert("model".to_string(), Json::Str(self.name.clone()));
        m.insert("layers".to_string(), Json::Arr(layers));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Option<TraceArtifact> {
        if j.get("schema")?.as_str()? != TRACE_SCHEMA {
            return None;
        }
        let name = j.get("model")?.as_str()?.to_string();
        let mut shapes = Vec::new();
        let mut layers = Vec::new();
        for l in j.get("layers")?.as_arr()? {
            shapes.push(shape_from_json(l.get("shape")?)?);
            let a = TensorBitmap::from_json(l.get("a")?)?;
            let g = TensorBitmap::from_json(l.get("g")?)?;
            layers.push((a, g));
        }
        Some(TraceArtifact { name, shapes, layers: Arc::new(layers) })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let mut text = self.to_json().render_pretty();
        text.push('\n');
        std::fs::write(path, text.as_bytes())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<TraceArtifact, String> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| format!("parsing {}: {e}", path.display()))?;
        TraceArtifact::from_json(&j)
            .ok_or_else(|| format!("{} is not a {TRACE_SCHEMA} document", path.display()))
    }

    /// Build a request over this trace; the bitmap vector is shared by
    /// `Arc`, never copied.
    pub fn request(&self, cfg: ChipConfig, samples: usize, seed: u64) -> SimRequest {
        SimRequest {
            label: self.name.clone(),
            cfg,
            workload: Workload::Trace {
                shapes: self.shapes.clone(),
                layers: Arc::clone(&self.layers),
            },
            samples,
            seed,
        }
    }
}

/// Memoizing artifact store: model profiles and trace files resolve
/// once per service lifetime and are shared by `Arc` thereafter.
#[derive(Debug, Default)]
pub struct ArtifactStore {
    profiles: Mutex<HashMap<String, Arc<ModelProfile>>>,
    traces: Mutex<HashMap<String, Arc<TraceArtifact>>>,
}

impl ArtifactStore {
    /// Resolve a model profile, loading it on first use.
    pub fn profile(&self, name: &str) -> Option<Arc<ModelProfile>> {
        let mut g = self.profiles.lock().unwrap();
        if let Some(p) = g.get(name) {
            return Some(Arc::clone(p));
        }
        let p = Arc::new(ModelProfile::for_model(name)?);
        g.insert(name.to_string(), Arc::clone(&p));
        Some(p)
    }

    /// Resolve a trace artifact by path, loading the file on first use.
    pub fn trace(&self, path: &str) -> Result<Arc<TraceArtifact>, String> {
        {
            let g = self.traces.lock().unwrap();
            if let Some(t) = g.get(path) {
                return Ok(Arc::clone(t));
            }
        }
        // Load outside the lock: a slow disk must not block other
        // connections' already-resident artifacts.
        let t = Arc::new(TraceArtifact::load(path)?);
        let mut g = self.traces.lock().unwrap();
        let entry = g.entry(path.to_string()).or_insert_with(|| Arc::clone(&t));
        Ok(Arc::clone(entry))
    }

    /// Register an in-memory trace under a key (tests, embedded use).
    pub fn register_trace(&self, key: &str, t: TraceArtifact) -> Arc<TraceArtifact> {
        let t = Arc::new(t);
        self.traces.lock().unwrap().insert(key.to_string(), Arc::clone(&t));
        t
    }

    /// (profiles, traces) currently resident.
    pub fn loaded(&self) -> (usize, usize) {
        (self.profiles.lock().unwrap().len(), self.traces.lock().unwrap().len())
    }
}

// ---------------------------------------------------------------------
// The response envelope
// ---------------------------------------------------------------------

/// One typed serve response. Every op builds its response through this
/// one envelope so `schema`/`id`/`ok`/`error` fields cannot drift
/// between ops, and so the transport can render the same reply either
/// as exact v1 bytes (in-order mode) or with an `"op"` echo
/// (streaming mode, where the client must correlate out-of-order
/// lines).
#[derive(Debug, Clone)]
pub struct ServeReply {
    id: Option<Json>,
    op: Option<String>,
    ok: bool,
    error: Option<String>,
    fields: BTreeMap<String, Json>,
}

impl ServeReply {
    /// A successful reply for `op`, echoing the request's `id`.
    pub fn ok(id: Option<Json>, op: impl Into<String>) -> ServeReply {
        ServeReply { id, op: Some(op.into()), ok: true, error: None, fields: BTreeMap::new() }
    }

    /// An in-band error reply.
    pub fn err(id: Option<Json>, op: Option<String>, msg: impl Into<String>) -> ServeReply {
        ServeReply { id, op, ok: false, error: Some(msg.into()), fields: BTreeMap::new() }
    }

    /// Attach one payload field (`report`, `cache`, `latency`, ...).
    pub fn field(mut self, key: &str, value: Json) -> ServeReply {
        self.fields.insert(key.to_string(), value);
        self
    }

    /// Render to one protocol line. `echo_op: false` is the exact v1
    /// byte contract (no `op` key); `echo_op: true` adds the `"op"`
    /// echo used by streaming responses.
    pub fn render(&self, echo_op: bool) -> String {
        let mut m = BTreeMap::new();
        m.insert("schema".to_string(), Json::Str(SERVE_SCHEMA.to_string()));
        if let Some(id) = &self.id {
            m.insert("id".to_string(), id.clone());
        }
        m.insert("ok".to_string(), Json::Bool(self.ok));
        if let Some(e) = &self.error {
            m.insert("error".to_string(), Json::Str(e.clone()));
        }
        if echo_op {
            if let Some(op) = &self.op {
                m.insert("op".to_string(), Json::Str(op.clone()));
            }
        }
        for (k, v) in &self.fields {
            m.insert(k.clone(), v.clone());
        }
        Json::Obj(m).render()
    }
}

// ---------------------------------------------------------------------
// Request parsing
// ---------------------------------------------------------------------

/// One parsed sub-request: its response id, the engine cells it
/// expands to, and how to shape the resulting sims into reports.
struct SubReq {
    id: Option<Json>,
    per_layer: bool,
    kind: SubKind,
    cells: Vec<SimRequest>,
}

enum SubKind {
    Simulate { model: String, epoch: f64, cfg: ChipConfig, samples: usize, seed: u64 },
    Sweep,
    Trace { name: String },
}

impl SubKind {
    fn op_name(&self) -> &'static str {
        match self {
            SubKind::Simulate { .. } => "simulate",
            SubKind::Sweep => "sweep",
            SubKind::Trace { .. } => "trace",
        }
    }
}

// ---------------------------------------------------------------------
// The service
// ---------------------------------------------------------------------

/// Result of handling one input line: the rendered v1 response lines
/// (one per sub-request) and whether the service should shut down.
pub struct Handled {
    pub lines: Vec<String>,
    pub shutdown: bool,
}

/// Result of handling one parsed request: the typed replies (rendered
/// by the transport, which knows whether the client opted into
/// streaming) and whether the service should shut down.
pub struct HandledReplies {
    pub replies: Vec<ServeReply>,
    pub shutdown: bool,
}

/// Fixed-capacity latency reservoir: a ring of the most recent
/// [`LAT_RESERVOIR_CAP`] samples plus an exact running count and max.
/// A resident server's memory stays bounded under sustained load
/// (the old unbounded `Vec<u64>` grew by 8 bytes per request forever),
/// while p50/p99 summarize the retained window and count/max stay
/// exact over the whole session. The retained window is a pure
/// function of the recorded sequence, so percentiles are as
/// deterministic as the durations themselves.
#[derive(Debug, Default)]
struct LatReservoir {
    count: u64,
    max_ns: u64,
    ring: Vec<u64>,
    pos: usize,
}

impl LatReservoir {
    fn record(&mut self, ns: u64) {
        self.count += 1;
        self.max_ns = self.max_ns.max(ns);
        if self.ring.len() < LAT_RESERVOIR_CAP {
            self.ring.push(ns);
        } else {
            self.ring[self.pos] = ns;
            self.pos = (self.pos + 1) % LAT_RESERVOIR_CAP;
        }
    }
}

/// Multiplexer telemetry: how often the transport shed, timed out,
/// cancelled or streamed a request. Reported by the `stats` op under
/// `mux`.
#[derive(Debug, Default)]
struct MuxCounters {
    shed: AtomicU64,
    timeouts: AtomicU64,
    cancelled: AtomicU64,
    streamed: AtomicU64,
}

impl MuxCounters {
    fn to_json(&self) -> Json {
        let load = |c: &AtomicU64| Json::Num(c.load(Ordering::Relaxed) as f64);
        let mut m = BTreeMap::new();
        m.insert("cancelled".to_string(), load(&self.cancelled));
        m.insert("shed".to_string(), load(&self.shed));
        m.insert("streamed".to_string(), load(&self.streamed));
        m.insert("timeouts".to_string(), load(&self.timeouts));
        Json::Obj(m)
    }
}

fn bump(counter: &AtomicU64) {
    counter.fetch_add(1, Ordering::Relaxed);
}

/// The persistent simulation service. Share by reference across
/// connection-handler threads; all interior state is synchronized.
#[derive(Debug)]
pub struct Service {
    engine: Engine,
    cache: Arc<UnitCache>,
    artifacts: ArtifactStore,
    stop: AtomicBool,
    /// Wall-clock nanoseconds of handled requests, across all
    /// connections; the `stats` op summarizes them as percentiles.
    latency: Mutex<LatReservoir>,
    mux: MuxCounters,
}

impl Service {
    /// Build a service over `engine`, attaching `cache` to it (every
    /// request the service runs is cache-aware).
    pub fn new(engine: Engine, cache: Arc<UnitCache>) -> Service {
        Service {
            engine: engine.with_cache(Arc::clone(&cache)),
            cache,
            artifacts: ArtifactStore::default(),
            stop: AtomicBool::new(false),
            latency: Mutex::new(LatReservoir::default()),
            mux: MuxCounters::default(),
        }
    }

    pub fn artifacts(&self) -> &ArtifactStore {
        &self.artifacts
    }

    pub fn cache(&self) -> &Arc<UnitCache> {
        &self.cache
    }

    /// Handle one protocol line in v1 (in-order, no `op` echo) form,
    /// recording its wall-clock duration for the `stats` op's latency
    /// summary. Never panics on malformed input; the error is reported
    /// in-band. This is the stdin/stdout path; the TCP transport goes
    /// through [`Self::handle_json`] so it can render streaming
    /// responses itself.
    pub fn handle_line(&self, line: &str) -> Handled {
        let t0 = Instant::now();
        let h = self.dispatch_line(line);
        let ns = t0.elapsed().as_nanos().min(u64::MAX as u128) as u64;
        self.latency.lock().unwrap().record(ns);
        Handled {
            lines: h.replies.iter().map(|r| r.render(false)).collect(),
            shutdown: h.shutdown,
        }
    }

    fn dispatch_line(&self, line: &str) -> HandledReplies {
        match Json::parse(line) {
            Ok(j) => self.handle_json(&j),
            Err(e) => HandledReplies {
                replies: vec![ServeReply::err(None, None, format!("bad json: {e}"))],
                shutdown: false,
            },
        }
    }

    /// Dispatch one parsed request object to its op handler. Pure with
    /// respect to telemetry: the caller records latency (so queued
    /// time never pollutes the compute-latency reservoir).
    pub fn handle_json(&self, j: &Json) -> HandledReplies {
        let id = j.get("id").cloned();
        match j.get("op").and_then(Json::as_str) {
            Some("shutdown") => HandledReplies {
                replies: vec![ServeReply::ok(id, "shutdown").field("bye", Json::Bool(true))],
                shutdown: true,
            },
            Some("stats") => {
                HandledReplies { replies: vec![self.stats_reply(id)], shutdown: false }
            }
            Some("explore") => {
                HandledReplies { replies: vec![self.explore_reply(j, id)], shutdown: false }
            }
            Some(op @ ("store_ingest" | "store_query" | "store_diff")) => {
                HandledReplies { replies: vec![store_reply(op, j, id)], shutdown: false }
            }
            Some("batch") => {
                let subs = match j.get("requests").and_then(Json::as_arr) {
                    Some(reqs) => reqs.iter().collect::<Vec<_>>(),
                    None => {
                        let op = Some("batch".to_string());
                        let err = ServeReply::err(id, op, "'batch' needs a 'requests' array");
                        return HandledReplies { replies: vec![err], shutdown: false };
                    }
                };
                HandledReplies { replies: self.run_batch(&subs), shutdown: false }
            }
            _ => HandledReplies { replies: self.run_batch(&[j]), shutdown: false },
        }
    }

    /// Parse, execute (one engine invocation for the whole batch, so
    /// identical units across sub-requests coalesce) and build typed
    /// replies in request order.
    fn run_batch(&self, subs: &[&Json]) -> Vec<ServeReply> {
        let parsed: Vec<Result<SubReq, (Option<Json>, String, String)>> =
            subs.iter().map(|j| self.parse_request(j)).collect();
        let mut all_cells: Vec<SimRequest> = Vec::new();
        for sub in parsed.iter().flatten() {
            all_cells.extend(sub.cells.iter().cloned());
        }
        let before = self.cache.stats();
        let sims = self.engine.run_all(&all_cells);
        let delta = self.cache.stats().since(&before);
        let mut out = Vec::with_capacity(parsed.len());
        let mut cursor = 0usize;
        for sub in parsed {
            match sub {
                Err((id, op, msg)) => out.push(ServeReply::err(id, Some(op), msg)),
                Ok(sub) => {
                    let slice = &sims[cursor..cursor + sub.cells.len()];
                    cursor += sub.cells.len();
                    let reports = self.build_reports(&sub, slice);
                    let reply = ServeReply::ok(sub.id, sub.kind.op_name())
                        .field("report", report_set_json(&reports))
                        .field("cache", delta.to_json());
                    out.push(reply);
                }
            }
        }
        out
    }

    fn parse_request(&self, j: &Json) -> Result<SubReq, (Option<Json>, String, String)> {
        let id = j.get("id").cloned();
        let op = j.get("op").and_then(Json::as_str).unwrap_or("simulate").to_string();
        match self.parse_request_inner(j) {
            Ok((kind, per_layer, cells)) => Ok(SubReq { id, per_layer, kind, cells }),
            Err(msg) => Err((id, op, msg)),
        }
    }

    #[allow(clippy::type_complexity)]
    fn parse_request_inner(&self, j: &Json) -> Result<(SubKind, bool, Vec<SimRequest>), String> {
        let per_layer = params::get_bool(j, "per_layer", false)?;
        let samples = params::get_usize(j, "samples", repro::DEFAULT_SAMPLES)?;
        let seed = params::get_seed(j, params::DEFAULT_SEED)?;
        match j.get("op").and_then(Json::as_str) {
            Some("simulate") | None => {
                let model = j
                    .get("model")
                    .and_then(Json::as_str)
                    .ok_or("'simulate' needs a 'model'")?
                    .to_string();
                let epoch = params::get_epoch(j, "epoch", repro::MID_EPOCH)?;
                let regime = params::get_regime(j)?;
                let cfg = params::chip_config(j)?;
                let profile = self
                    .artifacts
                    .profile(&model)
                    .ok_or_else(|| format!("unknown model '{model}'"))?;
                let req = SimRequest::profile_shared(profile, epoch, cfg.clone(), samples, seed)
                    .with_regime(regime);
                Ok((SubKind::Simulate { model, epoch, cfg, samples, seed }, per_layer, vec![req]))
            }
            Some("sweep") => {
                let models = self.resolve_models(j, "sweep")?;
                let epochs: Vec<f64> = match j.get("epochs") {
                    None => vec![repro::MID_EPOCH],
                    Some(v) => v
                        .as_arr()
                        .ok_or("'epochs' must be an array")?
                        .iter()
                        .map(Json::as_f64)
                        .collect::<Option<_>>()
                        .ok_or("'epochs' must contain numbers")?,
                };
                if epochs.iter().any(|e| !(0.0..=1.0).contains(e)) {
                    return Err("'epochs' must be within [0, 1]".to_string());
                }
                let regime = params::get_regime(j)?;
                let cfg = params::chip_config(j)?;
                let names: Vec<&str> = models.iter().map(|(m, _)| m.as_str()).collect();
                let spec = SweepSpec::models(&names, repro::MID_EPOCH, &cfg, samples, seed)
                    .with_epochs(&epochs)
                    .with_regime(regime);
                // Keep SweepSpec's label/seed semantics, then swap
                // each cell onto the store's Arc'd profile so plan
                // expansion stops re-building topologies per request.
                let mut cells = spec.cells();
                for cell in &mut cells {
                    let shared = match &cell.workload {
                        Workload::Profile { model, epoch, regime } => models
                            .iter()
                            .find(|(m, _)| m == model)
                            .map(|(_, p)| (Arc::clone(p), *epoch, regime.clone())),
                        _ => None,
                    };
                    if let Some((profile, epoch, regime)) = shared {
                        cell.workload = Workload::ProfileShared { profile, epoch, regime };
                    }
                }
                Ok((SubKind::Sweep, per_layer, cells))
            }
            Some("trace") => {
                let path = j
                    .get("path")
                    .and_then(Json::as_str)
                    .ok_or("'trace' needs a 'path'")?;
                let artifact = self.artifacts.trace(path)?;
                let cfg = params::chip_config(j)?;
                let req = artifact.request(cfg, samples, seed);
                Ok((SubKind::Trace { name: artifact.name.clone() }, per_layer, vec![req]))
            }
            Some(other) => Err(format!("unknown op '{other}'")),
        }
    }

    fn build_reports(&self, sub: &SubReq, sims: &[ModelSim]) -> Vec<Report> {
        match &sub.kind {
            SubKind::Simulate { model, epoch, cfg, samples, seed } => {
                let sim = &sims[0];
                let mut reports =
                    vec![repro::simulate_report(model, *epoch, cfg, *samples, *seed, sim)];
                if sub.per_layer {
                    reports.push(layers_report(sim));
                }
                reports
            }
            SubKind::Sweep => {
                let mut r = Report::new(
                    "sweep",
                    "Sweep — overall speedup and efficiency per cell",
                    &["cell", "speedup", "compute eff", "chip eff"],
                );
                for sim in sims {
                    r.row(vec![
                        Cell::text(sim.name.clone()),
                        Cell::num(sim.overall_speedup()),
                        Cell::num(sim.compute_efficiency()),
                        Cell::num(sim.total_efficiency()),
                    ]);
                }
                r.meta_num("cells", sims.len() as f64);
                let mut reports = vec![r];
                if sub.per_layer {
                    reports.extend(sims.iter().map(layers_report));
                }
                reports
            }
            SubKind::Trace { name } => {
                let sim = &sims[0];
                let mut r = Report::new(
                    "trace",
                    format!("{name} — projection from captured bitmaps"),
                    &["metric", "A*W", "A*G", "W*G", "overall"],
                );
                r.row(vec![
                    Cell::text("speedup"),
                    Cell::num(sim.op_speedup(TrainOp::Fwd)),
                    Cell::num(sim.op_speedup(TrainOp::Igrad)),
                    Cell::num(sim.op_speedup(TrainOp::Wgrad)),
                    Cell::num(sim.overall_speedup()),
                ]);
                r.row(vec![
                    Cell::text("whole-chip efficiency"),
                    Cell::empty(),
                    Cell::empty(),
                    Cell::empty(),
                    Cell::num(sim.total_efficiency()),
                ]);
                r.meta_str("model", name);
                let mut reports = vec![r];
                if sub.per_layer {
                    reports.push(layers_report(sim));
                }
                reports
            }
        }
    }

    /// The `explore` op: a cache-driven design-space search
    /// ([`crate::search`]) over this service's shared engine + cache.
    /// Overlapping requests share units across connections exactly like
    /// simulate/sweep do. The report (frontier rows *and* provenance
    /// meta) is deterministic in the request, so a warm response is
    /// byte-identical to a cold one; cache telemetry rides in the
    /// separate `cache` envelope field.
    fn explore_reply(&self, j: &Json, id: Option<Json>) -> ServeReply {
        match self.parse_and_run_explore(j) {
            Ok((report, cache)) => ServeReply::ok(id, "explore")
                .field("report", report.to_json())
                .field("cache", cache),
            Err(msg) => ServeReply::err(id, Some("explore".to_string()), msg),
        }
    }

    /// Parse a request's `models` array and resolve every name through
    /// the artifact store (profiles load once per service lifetime).
    /// Shared by the sweep and explore ops so validation and error
    /// wording cannot drift between them.
    fn resolve_models(
        &self,
        j: &Json,
        op: &str,
    ) -> Result<Vec<(String, Arc<ModelProfile>)>, String> {
        let names: Vec<String> = j
            .get("models")
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("'{op}' needs a 'models' array"))?
            .iter()
            .map(|m| m.as_str().map(str::to_string))
            .collect::<Option<_>>()
            .ok_or("'models' must contain strings")?;
        if names.is_empty() {
            return Err(format!("'{op}' needs at least one model"));
        }
        let mut out = Vec::with_capacity(names.len());
        for m in names {
            let p = self.artifacts.profile(&m).ok_or_else(|| format!("unknown model '{m}'"))?;
            out.push((m, p));
        }
        Ok(out)
    }

    fn parse_and_run_explore(&self, j: &Json) -> Result<(Report, Json), String> {
        let models = self.resolve_models(j, "explore")?;
        let space = match j.get("axes") {
            None => SearchSpace::default_space(),
            Some(axes @ Json::Obj(_)) => {
                let mut doc = BTreeMap::new();
                doc.insert("schema".to_string(), Json::Str(SPACE_SCHEMA.to_string()));
                doc.insert("axes".to_string(), axes.clone());
                SearchSpace::from_json(&Json::Obj(doc))?
            }
            Some(_) => return Err("'axes' must be an object of axis -> value arrays".to_string()),
        };
        let epoch = params::get_epoch(j, "epoch", repro::MID_EPOCH)?;
        let regime = params::get_regime(j)?;
        let samples = params::get_usize(j, "samples", repro::DEFAULT_SAMPLES)?;
        let seed = params::get_seed(j, params::DEFAULT_SEED)?;
        let budget = params::get_usize(j, "budget", params::DEFAULT_EXPLORE_BUDGET)?.max(1);
        let population =
            params::get_usize(j, "population", search::default_population(budget))?.max(1);
        let spec = ExploreSpec::with_profiles(space, models, epoch, samples, seed, budget)
            .with_population(population)
            .with_regime(regime);
        let before = self.cache.stats();
        let res = search::explore(&self.engine, &spec);
        let delta = self.cache.stats().since(&before);
        Ok((search::frontier_report(&spec, &res), delta.to_json()))
    }

    /// Per-request latency summary: exact count and max over every
    /// duration recorded so far, p50/p99 (nearest-rank: the smallest
    /// sample with at least p% of samples at or below it — a
    /// deterministic function of the recorded durations) over the
    /// reservoir's retained window, in nanoseconds.
    fn latency_json(&self) -> Json {
        let (count, max_ns, mut window) = {
            let r = self.latency.lock().unwrap();
            (r.count, r.max_ns, r.ring.clone())
        };
        window.sort_unstable();
        let pick = |p: f64| -> f64 {
            if window.is_empty() {
                return 0.0;
            }
            let rank = ((p / 100.0) * window.len() as f64).ceil() as usize;
            window[rank.clamp(1, window.len()) - 1] as f64
        };
        let mut m = BTreeMap::new();
        m.insert("count".to_string(), Json::Num(count as f64));
        m.insert("p50_ns".to_string(), Json::Num(pick(50.0)));
        m.insert("p99_ns".to_string(), Json::Num(pick(99.0)));
        m.insert("max_ns".to_string(), Json::Num(max_ns as f64));
        Json::Obj(m)
    }

    fn stats_reply(&self, id: Option<Json>) -> ServeReply {
        let (profiles, traces) = self.artifacts.loaded();
        ServeReply::ok(id, "stats")
            .field("cache", self.cache.stats().to_json())
            .field("cache_entries", Json::Num(self.cache.len() as f64))
            .field("cache_shards", Json::Num(self.cache.shard_count() as f64))
            .field("latency", self.latency_json())
            .field("mux", self.mux.to_json())
            .field("profiles_loaded", Json::Num(profiles as f64))
            .field("traces_loaded", Json::Num(traces as f64))
    }

    /// The blocking line loop: read requests from `reader`, stream
    /// responses to `writer` (flushed per line), return on EOF or a
    /// `shutdown` op. This is the stdin/stdout mode (and the reference
    /// single-threaded transport the benches race against).
    pub fn serve_lines<R: BufRead, W: Write>(
        &self,
        reader: R,
        mut writer: W,
    ) -> std::io::Result<()> {
        for line in reader.lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let h = self.handle_line(&line);
            for l in &h.lines {
                writer.write_all(l.as_bytes())?;
                writer.write_all(b"\n")?;
            }
            writer.flush()?;
            if h.shutdown {
                self.stop.store(true, Ordering::SeqCst);
                break;
            }
        }
        Ok(())
    }

    /// Bind `addr` and serve it with the request-multiplexing
    /// transport: see [`Self::serve_listener`].
    pub fn serve_tcp(&self, addr: &str, opts: ServeOptions) -> std::io::Result<()> {
        let listener = TcpListener::bind(addr)?;
        self.serve_listener(listener, opts)
    }

    /// Serve an already-bound listener until a `shutdown` op arrives
    /// on any connection.
    ///
    /// The calling thread becomes the fixed accept thread: it blocks
    /// in `accept()` (no polling — an idle server burns no CPU;
    /// shutdown wakes it with a self-connect poke) and gives each
    /// connection a reader thread and a writer thread. Readers parse
    /// and tag requests into one global depth-limited *request* queue
    /// that `opts.workers` compute threads drain, so admission control
    /// is per request: past `opts.queue_depth` queued requests the
    /// reader answers an in-band "overloaded" error and the connection
    /// stays open. Writers re-sequence completions into request order
    /// (v1 contract) unless the request opted into `"stream": true`,
    /// in which case its response is written on completion with an
    /// `"op"` echo.
    ///
    /// `opts.request_timeout` stamps every request with a deadline at
    /// enqueue; a request still queued past its deadline answers an
    /// in-band "timeout" error instead of computing. Requests queued
    /// for a disconnected client are cancelled at dequeue, and
    /// shutdown drains the queue with in-band errors before
    /// half-closing every connection's read side.
    pub fn serve_listener(
        &self,
        listener: TcpListener,
        opts: ServeOptions,
    ) -> std::io::Result<()> {
        let workers = opts.workers.max(1);
        let local = listener.local_addr()?;
        let timeout_desc = match opts.request_timeout {
            Some(t) => format!("{}ms", t.as_millis()),
            None => "off".to_string(),
        };
        eprintln!(
            "tensordash serve: listening on {local} ({workers} workers, request queue depth {}, \
             request timeout {timeout_desc})",
            opts.queue_depth.max(1)
        );
        let queue = ReqQueue::new(opts.queue_depth);
        let default_timeout = opts.request_timeout;
        // Read halves of live connections, tracked so shutdown can
        // half-close them. Each reader reaps its own entry on exit — a
        // resident service must not accumulate one fd per past
        // connection.
        let conns: Mutex<Vec<(u64, TcpStream)>> = Mutex::new(Vec::new());
        let mut next_conn = 0u64;
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| self.compute_loop(&queue, local));
            }
            loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        if self.stop.load(Ordering::SeqCst) {
                            // The shutdown poke (or a late client).
                            drop(stream);
                            break;
                        }
                        // A connection whose socket cannot be cloned
                        // for the writer/tracker is shed outright
                        // (try_clone fails under fd pressure, where
                        // shedding is the right move anyway).
                        let halves = (stream.try_clone(), stream.try_clone());
                        let (write_half, track_half) = match halves {
                            (Ok(w), Ok(t)) => (w, t),
                            _ => {
                                bump(&self.mux.shed);
                                shed(stream, "overloaded: cannot service connection, retry later");
                                continue;
                            }
                        };
                        let id = next_conn;
                        next_conn += 1;
                        conns.lock().unwrap().push((id, track_half));
                        let conn = Arc::new(ConnShared::default());
                        let writer_conn = Arc::clone(&conn);
                        s.spawn(move || writer_loop(&writer_conn, write_half));
                        let queue = &queue;
                        let conns = &conns;
                        s.spawn(move || {
                            self.reader_loop(stream, conn, queue, default_timeout);
                            conns.lock().unwrap().retain(|(i, _)| *i != id);
                        });
                    }
                    // Transient accept failures (ECONNABORTED, EMFILE
                    // pressure, ...) must not take the service down —
                    // only the shutdown op ends the loop.
                    Err(e) => {
                        if self.stop.load(Ordering::SeqCst) {
                            break;
                        }
                        eprintln!("serve: accept failed (retrying): {e}");
                        std::thread::sleep(Duration::from_millis(100));
                    }
                }
            }
            // Shutdown: cancel requests that were queued but never
            // executed with an in-band error each (close() also wakes
            // every idle worker), then half-close the read side of
            // live connections — blocked readers see EOF and exit,
            // and each writer drains its remaining completions before
            // exiting.
            for job in queue.close() {
                bump(&self.mux.cancelled);
                let ReqJob { conn, id, op, seq, stream, .. } = job;
                let reply = ServeReply::err(id, op, "overloaded: service shutting down");
                conn.post(seq, vec![reply.render(stream)], false);
            }
            for (_, c) in conns.lock().unwrap().iter() {
                let _ = c.shutdown(std::net::Shutdown::Read);
            }
        });
        Ok(())
    }

    /// One per-connection reader: parse and tag each line into the
    /// global request queue. Ordered (non-streaming) requests take a
    /// sequence ticket the writer re-sequences by; streaming requests
    /// skip ticketing entirely. In-band parse errors are posted
    /// straight to the writer with an ordered ticket so they hold
    /// their place in the response stream, exactly like v1.
    fn reader_loop(
        &self,
        stream: TcpStream,
        conn: Arc<ConnShared>,
        queue: &ReqQueue,
        default_timeout: Option<Duration>,
    ) {
        if stream.set_nonblocking(false).is_err() {
            conn.mark_dead();
            return;
        }
        let reader = BufReader::new(stream);
        let mut next_seq = 0u64;
        for line in reader.lines() {
            let line = match line {
                Ok(l) => l,
                Err(_) => {
                    // A torn read is a dead client: responses for its
                    // queued work are dropped and remaining queued
                    // work cancels at dequeue.
                    conn.mark_dead();
                    return;
                }
            };
            if line.trim().is_empty() {
                continue;
            }
            let mut ticket = || {
                let s = next_seq;
                next_seq += 1;
                Some(s)
            };
            let req = match Json::parse(&line) {
                Ok(j) => j,
                Err(e) => {
                    let reply = ServeReply::err(None, None, format!("bad json: {e}"));
                    let seq = ticket();
                    conn.add_outstanding();
                    conn.post(seq, vec![reply.render(false)], false);
                    continue;
                }
            };
            let id = req.get("id").cloned();
            let op = req.get("op").and_then(Json::as_str).map(str::to_string);
            let (stream_mode, deadline) = match parse_routing(&req, default_timeout) {
                Ok(r) => r,
                Err(msg) => {
                    let reply = ServeReply::err(id, op, msg);
                    let seq = ticket();
                    conn.add_outstanding();
                    conn.post(seq, vec![reply.render(false)], false);
                    continue;
                }
            };
            let seq = if stream_mode { None } else { ticket() };
            conn.add_outstanding();
            let job = ReqJob {
                conn: Arc::clone(&conn),
                req,
                id,
                op,
                seq,
                stream: stream_mode,
                deadline,
            };
            if let Err(job) = queue.push(job) {
                // Per-request load shedding: the connection stays
                // open; only this request is refused.
                bump(&self.mux.shed);
                let ReqJob { conn: jc, id, op, seq, stream: streamed, .. } = job;
                let reply = ServeReply::err(id, op, "overloaded: request queue full, retry later");
                jc.post(seq, vec![reply.render(streamed)], false);
            }
        }
        // Clean EOF is not a dead client: pipelined requests still in
        // flight keep their responses; the writer exits once the last
        // one drains.
        conn.mark_eof();
    }

    /// One compute worker: execute individual requests off the global
    /// queue. Exits when the queue closes; a worker that observes the
    /// stop flag pokes the accept thread out of its blocking
    /// `accept()` so the whole scope can join.
    fn compute_loop(&self, queue: &ReqQueue, local: SocketAddr) {
        while let Some(job) = queue.pop() {
            self.execute_job(job);
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
        }
        if self.stop.load(Ordering::SeqCst) {
            poke_listener(local);
        }
    }

    /// Execute one dequeued request: cancel it if its client is gone,
    /// time it out if its deadline passed while queued, otherwise
    /// compute and post the response to the connection's writer.
    fn execute_job(&self, job: ReqJob) {
        let ReqJob { conn, req, id, op, seq, stream, deadline } = job;
        if conn.is_dead() {
            // Disconnect cancellation: a dead client must not hold a
            // compute slot. Nothing is posted (its writer is gone).
            bump(&self.mux.cancelled);
            return;
        }
        if let Some(d) = deadline {
            if Instant::now() > d {
                bump(&self.mux.timeouts);
                let msg = "timeout: request deadline passed in queue, retry later";
                let reply = ServeReply::err(id, op, msg);
                conn.post(seq, vec![reply.render(stream)], false);
                return;
            }
        }
        let t0 = Instant::now();
        let h = self.handle_json(&req);
        let ns = t0.elapsed().as_nanos().min(u64::MAX as u128) as u64;
        self.latency.lock().unwrap().record(ns);
        if stream {
            bump(&self.mux.streamed);
        }
        if h.shutdown {
            self.stop.store(true, Ordering::SeqCst);
        }
        let lines: Vec<String> = h.replies.iter().map(|r| r.render(stream)).collect();
        conn.post(seq, lines, h.shutdown);
    }
}

// ---------------------------------------------------------------------
// TCP transport plumbing — the request queue, per-connection writer
// state, and backpressure
// ---------------------------------------------------------------------

/// Options for the TCP transport ([`Service::serve_tcp`]).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Compute-pool size (`--workers`).
    pub workers: usize,
    /// Global pending-request queue depth (`--queue-depth`); past it
    /// readers shed requests with an in-band "overloaded" error.
    pub queue_depth: usize,
    /// Default per-request deadline (`--request-timeout`), measured
    /// from enqueue; `None` means requests wait indefinitely. A
    /// request-level `timeout_ms` field overrides it (0 disables).
    pub request_timeout: Option<Duration>,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            workers: DEFAULT_SERVE_WORKERS,
            queue_depth: DEFAULT_QUEUE_DEPTH,
            request_timeout: None,
        }
    }
}

/// Per-request routing fields: `stream` (opt out of response
/// ordering) and `timeout_ms` (override the server's default
/// deadline; 0 disables it for this request).
fn parse_routing(
    req: &Json,
    default_timeout: Option<Duration>,
) -> Result<(bool, Option<Instant>), String> {
    let stream = params::get_bool(req, "stream", false)?;
    let timeout = match req.get("timeout_ms") {
        None => default_timeout,
        Some(_) => {
            let ms = params::get_usize(req, "timeout_ms", 0)?;
            (ms > 0).then(|| Duration::from_millis(ms as u64))
        }
    };
    let deadline = timeout.and_then(|t| Instant::now().checked_add(t));
    Ok((stream, deadline))
}

/// One request's completed response: its ordering ticket (`None` for
/// streaming requests) and rendered lines.
struct Completion {
    seq: Option<u64>,
    lines: Vec<String>,
    shutdown: bool,
}

/// State shared between one connection's reader, its writer, and the
/// compute workers executing its requests.
#[derive(Default)]
struct ConnShared {
    state: Mutex<ConnState>,
    wake: Condvar,
}

#[derive(Default)]
struct ConnState {
    /// Completions awaiting the writer, in completion order.
    mailbox: Vec<Completion>,
    /// Requests admitted by the reader whose completion has not yet
    /// reached the writer; the writer exits at EOF only once this
    /// drains, so pipelined responses are never lost.
    outstanding: u64,
    /// Reader saw clean EOF: no further requests will be admitted.
    eof: bool,
    /// Connection is unusable (torn read, failed write, post-shutdown):
    /// posts are dropped and queued work cancels at dequeue.
    dead: bool,
}

impl ConnShared {
    fn is_dead(&self) -> bool {
        self.state.lock().unwrap().dead
    }

    fn mark_dead(&self) {
        self.state.lock().unwrap().dead = true;
        self.wake.notify_all();
    }

    fn mark_eof(&self) {
        self.state.lock().unwrap().eof = true;
        self.wake.notify_all();
    }

    fn add_outstanding(&self) {
        self.state.lock().unwrap().outstanding += 1;
    }

    /// Deliver one request's response to the writer. Dropped silently
    /// when the connection is already dead — its writer has exited.
    fn post(&self, seq: Option<u64>, lines: Vec<String>, shutdown: bool) {
        let mut g = self.state.lock().unwrap();
        if g.dead {
            return;
        }
        g.mailbox.push(Completion { seq, lines, shutdown });
        self.wake.notify_all();
    }
}

/// Restores request order on the writer side: ordered completions
/// arrive tagged with their reader-assigned sequence number and are
/// held until every earlier one has been released. Streaming
/// completions never enter the resequencer.
#[derive(Default)]
struct Resequencer {
    next: u64,
    held: BTreeMap<u64, (Vec<String>, bool)>,
}

impl Resequencer {
    /// Accept one ordered completion; returns every line now ready to
    /// write, in request order, and whether a released completion was
    /// the shutdown ack (the writer must close *after* writing it).
    fn push(&mut self, seq: u64, lines: Vec<String>, shutdown: bool) -> (Vec<String>, bool) {
        self.held.insert(seq, (lines, shutdown));
        let mut out = Vec::new();
        let mut shut = false;
        while let Some((lines, s)) = self.held.remove(&self.next) {
            out.extend(lines);
            shut |= s;
            self.next += 1;
        }
        (out, shut)
    }

    /// Completions held waiting for an earlier sequence number.
    fn buffered(&self) -> usize {
        self.held.len()
    }
}

/// One tagged request in the global queue.
struct ReqJob {
    conn: Arc<ConnShared>,
    req: Json,
    id: Option<Json>,
    op: Option<String>,
    /// Ordering ticket; `None` for streaming requests.
    seq: Option<u64>,
    stream: bool,
    /// Absolute deadline stamped at enqueue.
    deadline: Option<Instant>,
}

/// Depth-bounded global request queue between the per-connection
/// readers and the compute pool. `push` never blocks: at depth the
/// job comes straight back so the reader can shed it in-band, keeping
/// admission control on the read side and workers ignorant of load.
struct ReqQueue {
    depth: usize,
    state: Mutex<ReqQueueState>,
    ready: Condvar,
}

#[derive(Default)]
struct ReqQueueState {
    pending: VecDeque<ReqJob>,
    closed: bool,
}

impl ReqQueue {
    fn new(depth: usize) -> ReqQueue {
        ReqQueue {
            depth: depth.max(1),
            state: Mutex::new(ReqQueueState::default()),
            ready: Condvar::new(),
        }
    }

    /// Enqueue a request; hands it back when the queue is at depth or
    /// closed (the caller sheds it).
    fn push(&self, job: ReqJob) -> Result<(), ReqJob> {
        let mut g = self.state.lock().unwrap();
        if g.closed || g.pending.len() >= self.depth {
            return Err(job);
        }
        g.pending.push_back(job);
        self.ready.notify_one();
        Ok(())
    }

    /// Block until a request is available (`Some`) or the queue is
    /// closed and drained (`None`).
    fn pop(&self) -> Option<ReqJob> {
        let mut g = self.state.lock().unwrap();
        loop {
            if let Some(job) = g.pending.pop_front() {
                return Some(job);
            }
            if g.closed {
                return None;
            }
            g = self.ready.wait(g).unwrap();
        }
    }

    /// Close the queue, waking every waiting worker; returns the
    /// requests that were queued but never executed.
    fn close(&self) -> Vec<ReqJob> {
        let mut g = self.state.lock().unwrap();
        g.closed = true;
        let drained = g.pending.drain(..).collect();
        self.ready.notify_all();
        drained
    }
}

/// One per-connection writer: drain the mailbox, re-sequence ordered
/// completions, write streaming ones immediately. Exits when the
/// connection dies, when the shutdown ack has been written, or at
/// clean EOF once every admitted request's response has drained.
fn writer_loop(conn: &ConnShared, stream: TcpStream) {
    let mut writer = BufWriter::new(stream);
    let mut reseq = Resequencer::default();
    loop {
        let batch: Vec<Completion> = {
            let mut g = conn.state.lock().unwrap();
            loop {
                if g.dead {
                    return;
                }
                if !g.mailbox.is_empty() {
                    break;
                }
                if g.eof && g.outstanding == 0 {
                    debug_assert_eq!(reseq.buffered(), 0, "resequencer drained at EOF");
                    return;
                }
                g = conn.wake.wait(g).unwrap();
            }
            let batch: Vec<Completion> = g.mailbox.drain(..).collect();
            g.outstanding = g.outstanding.saturating_sub(batch.len() as u64);
            batch
        };
        let mut lines: Vec<String> = Vec::new();
        let mut shutdown = false;
        for c in batch {
            match c.seq {
                Some(seq) => {
                    let (ready, shut) = reseq.push(seq, c.lines, c.shutdown);
                    lines.extend(ready);
                    shutdown |= shut;
                }
                None => {
                    lines.extend(c.lines);
                    shutdown |= c.shutdown;
                }
            }
        }
        // Write and flush outside the lock: a slow client must not
        // block the workers posting into the mailbox.
        let mut failed = false;
        for l in &lines {
            if writer.write_all(l.as_bytes()).is_err() || writer.write_all(b"\n").is_err() {
                failed = true;
                break;
            }
        }
        if !failed && writer.flush().is_err() {
            failed = true;
        }
        if failed || shutdown {
            // v1 contract: nothing is written after the shutdown ack.
            conn.mark_dead();
            return;
        }
    }
}

/// Backpressure of last resort: answer a connection the transport
/// cannot service at all with an explicit in-protocol error line,
/// then close it. The write gets a short timeout so a shed client
/// that never reads cannot wedge the accept thread.
fn shed(mut stream: TcpStream, msg: &str) {
    let _ = stream.set_write_timeout(Some(Duration::from_millis(250)));
    let _ = stream.write_all(error_line(None, msg).as_bytes());
    let _ = stream.write_all(b"\n");
}

/// Wake a thread blocked in `accept()` by connecting to its listener
/// and immediately dropping the connection. Tries the bound address
/// first, then loopback on the same port for wildcard binds. Best
/// effort: a failed connect means the listener is already past
/// `accept()`.
fn poke_listener(local: SocketAddr) {
    let timeout = Duration::from_millis(200);
    if TcpStream::connect_timeout(&local, timeout).is_ok() {
        return;
    }
    let loopback = SocketAddr::from(([127, 0, 0, 1], local.port()));
    let _ = TcpStream::connect_timeout(&loopback, timeout);
}

fn error_line(id: Option<Json>, msg: &str) -> String {
    ServeReply::err(id, None, msg).render(false)
}

// ---------------------------------------------------------------------
// Store ops — the ExperimentStore over the serve protocol
// ---------------------------------------------------------------------

/// Dispatch one `store_*` op. Stateless with respect to the service:
/// each request opens the store file it names (`db`), so different
/// requests may address different stores.
fn store_reply(op: &str, j: &Json, id: Option<Json>) -> ServeReply {
    let result = match op {
        "store_ingest" => store_ingest(j),
        "store_query" => store_query(j),
        _ => store_diff(j),
    };
    match result {
        Ok(m) => {
            let mut reply = ServeReply::ok(id, op);
            reply.fields = m;
            reply
        }
        Err(msg) => ServeReply::err(id, Some(op.to_string()), msg),
    }
}

/// Open the store file named by the request's `db` field. Query/diff
/// refuse to invent an empty store from a mistyped path; only ingest
/// creates the file.
fn open_store(j: &Json, create: bool) -> Result<ExperimentStore, String> {
    let db = j.get("db").and_then(Json::as_str).ok_or("store ops need a 'db' file path")?;
    if !create && !Path::new(db).exists() {
        return Err(format!("store file '{db}' does not exist"));
    }
    ExperimentStore::open(db).map_err(|e| e.to_string())
}

/// `store_ingest`: `{op, db, commit, files: [path...]}` and/or an
/// inline `doc`. Responds with how many records were written (0 =
/// everything already stored byte-identically).
fn store_ingest(j: &Json) -> Result<BTreeMap<String, Json>, String> {
    let mut store = open_store(j, true)?;
    let commit = j
        .get("commit")
        .and_then(Json::as_str)
        .ok_or("'store_ingest' needs a 'commit' string")?
        .to_string();
    let mut written = 0usize;
    let mut files = 0usize;
    if let Some(v) = j.get("files") {
        for f in v.as_arr().ok_or("'files' must be an array of paths")? {
            let path = f.as_str().ok_or("'files' must contain path strings")?;
            written += store.ingest_file(path, &commit).map_err(|e| e.to_string())?;
            files += 1;
        }
    }
    if let Some(doc) = j.get("doc") {
        written += store.ingest_json(doc, &commit).map_err(|e| e.to_string())?;
    } else if files == 0 {
        return Err("'store_ingest' needs 'files' and/or an inline 'doc'".to_string());
    }
    store.commit().map_err(|e| e.to_string())?;
    let mut m = BTreeMap::new();
    m.insert("ingested".to_string(), Json::Num(written as f64));
    m.insert("files".to_string(), Json::Num(files as f64));
    m.insert("records".to_string(), Json::Num(store.len() as f64));
    Ok(m)
}

/// `store_query`: `{op, db, schema?, figure?, commit?, model?,
/// metric?}` — the record catalog, or with `metric` the metric's
/// trajectory across commits. The response report renders through the
/// ordinary Report pipeline, byte-deterministically.
fn store_query(j: &Json) -> Result<BTreeMap<String, Json>, String> {
    let mut store = open_store(j, false)?;
    let field = |k: &str| j.get(k).and_then(Json::as_str).map(str::to_string);
    let filter = QueryFilter {
        schema: field("schema"),
        id: field("figure"),
        commit: field("commit"),
        model: field("model"),
        metric: field("metric"),
    };
    let report = store.query(&filter).map_err(|e| e.to_string())?;
    let mut m = BTreeMap::new();
    m.insert("report".to_string(), report.to_json());
    Ok(m)
}

/// `store_diff`: `{op, db, figure, from, to}` — compare one document
/// between two commits (per-metric deltas, or Pareto-dominance
/// classification for frontiers).
fn store_diff(j: &Json) -> Result<BTreeMap<String, Json>, String> {
    let mut store = open_store(j, false)?;
    let need = |k: &str| -> Result<String, String> {
        j.get(k)
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| format!("'store_diff' needs a '{k}' string"))
    };
    let report = store
        .diff(&need("figure")?, &need("from")?, &need("to")?)
        .map_err(|e| e.to_string())?;
    let mut m = BTreeMap::new();
    m.insert("report".to_string(), report.to_json());
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::cache::DEFAULT_CACHE_CAP;
    use crate::trace::synthetic::clustered_bitmap;
    use crate::util::rng::Rng;

    fn service(jobs: usize) -> Service {
        Service::new(Engine::new(jobs), Arc::new(UnitCache::new(DEFAULT_CACHE_CAP)))
    }

    fn report_field(line: &str) -> Json {
        let j = Json::parse(line).expect("response parses");
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "response not ok: {line}");
        j.get("report").expect("response carries a report").clone()
    }

    #[test]
    fn simulate_response_is_deterministic_and_cache_served() {
        let s = service(2);
        let req = r#"{"op":"simulate","id":"r1","model":"gcn","epoch":0.4,"samples":1,"seed":7}"#;
        let first = s.handle_line(req);
        assert_eq!(first.lines.len(), 1);
        assert!(!first.shutdown);
        let second = s.handle_line(req);
        // The report body is byte-identical warm vs cold; only the
        // cache envelope moves.
        assert_eq!(
            report_field(&first.lines[0]).render(),
            report_field(&second.lines[0]).render()
        );
        let stats = s.cache().stats();
        assert!(stats.hits > 0, "second request must be cache-served: {stats:?}");
        assert_eq!(stats.misses, stats.inserts);
    }

    #[test]
    fn batch_coalesces_duplicate_requests_into_one_computation() {
        let s = service(2);
        let line = concat!(
            r#"{"op":"batch","requests":["#,
            r#"{"op":"simulate","id":"a","model":"gcn","samples":1,"seed":7},"#,
            r#"{"op":"simulate","id":"b","model":"gcn","samples":1,"seed":7}"#,
            r#"]}"#,
        );
        let h = s.handle_line(line);
        assert_eq!(h.lines.len(), 2, "one response line per sub-request");
        assert_eq!(
            report_field(&h.lines[0]).render(),
            report_field(&h.lines[1]).render(),
            "duplicate sub-requests must be byte-identical"
        );
        let stats = s.cache().stats();
        assert!(stats.coalesced > 0, "duplicates must coalesce: {stats:?}");
        // Responses carry their ids in order.
        assert_eq!(Json::parse(&h.lines[0]).unwrap().get("id").unwrap().as_str(), Some("a"));
        assert_eq!(Json::parse(&h.lines[1]).unwrap().get("id").unwrap().as_str(), Some("b"));
    }

    #[test]
    fn malformed_lines_answer_in_band_errors() {
        let s = service(1);
        let bad = s.handle_line("{nope");
        assert_eq!(bad.lines.len(), 1);
        let j = Json::parse(&bad.lines[0]).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
        let unknown = s.handle_line(r#"{"op":"simulate","id":9,"model":"resnet5O"}"#);
        let j = Json::parse(&unknown.lines[0]).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(j.get("id").unwrap().as_f64(), Some(9.0));
        assert!(j.get("error").unwrap().as_str().unwrap().contains("unknown model"));
    }

    #[test]
    fn sweep_reports_one_row_per_cell_in_request_order() {
        let s = service(2);
        let line = r#"{"op":"sweep","models":["alexnet","gcn"],"samples":1,"seed":5}"#;
        let h = s.handle_line(line);
        let report = report_field(&h.lines[0]);
        let r = Report::from_json(&report).expect("sweep report reconstructs");
        assert_eq!(r.rows.len(), 2);
        assert_eq!(r.rows[0].cells[0].text, "alexnet");
        assert_eq!(r.rows[1].cells[0].text, "gcn");
        // Profiles were loaded once into the artifact store.
        assert_eq!(s.artifacts().loaded().0, 2);
        let again = s.handle_line(line);
        assert_eq!(report_field(&again.lines[0]).render(), report.render());
        assert_eq!(s.artifacts().loaded().0, 2, "profiles load once per model");
    }

    #[test]
    fn trace_artifact_round_trips_and_serves() {
        let mut rng = Rng::new(3);
        let shape = ConvShape::conv(1, 4, 4, 16, 16, 3, 1, 1);
        let a = clustered_bitmap((1, 4, 4, 16), 0.6, 0.35, &mut rng);
        let g = clustered_bitmap((1, 4, 4, 16), 0.6, 0.35, &mut rng);
        let artifact = TraceArtifact::new("tiny", vec![shape], vec![(a, g)]);
        // JSON round trip.
        let back = TraceArtifact::from_json(&artifact.to_json()).expect("trace reconstructs");
        assert_eq!(back.name, "tiny");
        assert_eq!(back.shapes, artifact.shapes);
        assert_eq!(back.layers, artifact.layers);
        // Disk round trip through the store (loaded once).
        let path = std::env::temp_dir().join(format!("td_trace_{}.json", std::process::id()));
        artifact.save(&path).unwrap();
        let s = service(1);
        let line = format!(
            r#"{{"op":"trace","id":"t","path":"{}","samples":1,"seed":3}}"#,
            path.display()
        );
        let h1 = s.handle_line(&line);
        let h2 = s.handle_line(&line);
        assert_eq!(
            report_field(&h1.lines[0]).render(),
            report_field(&h2.lines[0]).render()
        );
        assert_eq!(s.artifacts().loaded().1, 1, "trace file loads once");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn explore_op_returns_a_deterministic_frontier_and_shares_the_cache() {
        use crate::api::FRONTIER_SCHEMA;
        let s = service(2);
        // alexnet: fig 19's sparsity regime, so the depth_ordered gate
        // has a real margin (gcn is the no-sparsity control).
        let line = concat!(
            r#"{"op":"explore","id":"e","models":["alexnet"],"budget":3,"samples":1,"seed":7,"#,
            r#""axes":{"staging_depth":[2,3],"tile_rows":[2,4]}}"#,
        );
        let h1 = s.handle_line(line);
        assert_eq!(h1.lines.len(), 1);
        let r1 = report_field(&h1.lines[0]);
        let rep = Report::from_json(&r1).expect("frontier report reconstructs");
        assert_eq!(rep.schema, FRONTIER_SCHEMA);
        assert!(!rep.rows.is_empty(), "frontier must not be empty");
        assert_eq!(rep.meta.get("depth_ordered").and_then(Json::as_f64), Some(1.0));
        // Warm repeat: the whole report (rows + meta) is byte-identical;
        // only the cache envelope moves.
        let h2 = s.handle_line(line);
        assert_eq!(report_field(&h2.lines[0]).render(), r1.render());
        let stats = s.cache().stats();
        assert!(stats.hits > 0, "explore must share units through the cache: {stats:?}");
        // Bad requests answer in-band.
        let bad = s.handle_line(r#"{"op":"explore","id":9}"#);
        let j = Json::parse(&bad.lines[0]).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn stats_reports_deterministic_latency_percentiles() {
        let s = service(1);
        // Record a few cheap requests, then read the summary.
        s.handle_line(r#"{"op":"stats"}"#);
        s.handle_line(r#"{"op":"stats"}"#);
        s.handle_line(r#"{"op":"stats"}"#);
        let h = s.handle_line(r#"{"op":"stats","id":"s"}"#);
        let j = Json::parse(&h.lines[0]).unwrap();
        let lat = j.get("latency").expect("stats carries a latency block");
        assert_eq!(lat.get("count").unwrap().as_f64(), Some(3.0));
        let p50 = lat.get("p50_ns").unwrap().as_f64().unwrap();
        let p99 = lat.get("p99_ns").unwrap().as_f64().unwrap();
        let max = lat.get("max_ns").unwrap().as_f64().unwrap();
        assert!(p50 <= p99 && p99 <= max, "percentiles must be ordered: {p50} {p99} {max}");
        assert!(max > 0.0, "a handled line takes nonzero time");
        // The multiplexer counters ride along, all zero off-TCP.
        let mux = j.get("mux").expect("stats carries the mux counters");
        for k in ["cancelled", "shed", "streamed", "timeouts"] {
            assert_eq!(mux.get(k).unwrap().as_f64(), Some(0.0), "{k}");
        }
    }

    #[test]
    fn store_ops_ingest_query_and_diff_over_the_protocol() {
        let name = format!("td_serve_store_{}.tdstore", std::process::id());
        let db = std::env::temp_dir().join(name);
        let _ = std::fs::remove_file(&db);
        let s = service(1);
        let mut fig = Report::new("fig13", "Demo", &["model", "overall"]);
        fig.row(vec![Cell::text("alexnet"), Cell::num(2.0)]);
        let doc1 = fig.to_json().render();
        let mut fig2 = Report::new("fig13", "Demo", &["model", "overall"]);
        fig2.row(vec![Cell::text("alexnet"), Cell::num(2.5)]);
        let doc2 = fig2.to_json().render();
        let db_s = db.display();
        for (commit, doc) in [("c1", &doc1), ("c2", &doc2)] {
            let line = format!(
                r#"{{"op":"store_ingest","db":"{db_s}","commit":"{commit}","doc":{doc}}}"#
            );
            let h = s.handle_line(&line);
            let j = Json::parse(&h.lines[0]).unwrap();
            assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{}", h.lines[0]);
            assert_eq!(j.get("ingested").unwrap().as_f64(), Some(1.0));
        }
        // Trajectory query: both commits' values in ingestion order.
        let q = format!(r#"{{"op":"store_query","db":"{db_s}","metric":"overall"}}"#);
        let h = s.handle_line(&q);
        let r = Report::from_json(Json::parse(&h.lines[0]).unwrap().get("report").unwrap())
            .expect("query report reconstructs");
        assert_eq!(r.rows.len(), 2);
        assert_eq!(r.value(0, "overall"), Some(2.0));
        assert_eq!(r.value(1, "overall"), Some(2.5));
        // Diff between the two commits.
        let d = format!(
            r#"{{"op":"store_diff","db":"{db_s}","figure":"fig13","from":"c1","to":"c2"}}"#
        );
        let h = s.handle_line(&d);
        let r = Report::from_json(Json::parse(&h.lines[0]).unwrap().get("report").unwrap())
            .expect("diff report reconstructs");
        assert_eq!(r.value(0, "delta"), Some(0.5));
        // Query on a missing store answers in-band, creating nothing.
        let missing = s.handle_line(r#"{"op":"store_query","db":"/nonexistent/x.tdstore"}"#);
        let j = Json::parse(&missing.lines[0]).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
        let _ = std::fs::remove_file(&db);
    }

    #[test]
    fn shutdown_acks_and_stops_the_line_loop() {
        let s = service(1);
        let input = b"{\"op\":\"stats\"}\n{\"op\":\"shutdown\"}\n{\"op\":\"stats\"}\n" as &[u8];
        let mut out = Vec::new();
        s.serve_lines(input, &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2, "nothing after the shutdown ack: {text}");
        let ack = Json::parse(lines[1]).unwrap();
        assert_eq!(ack.get("bye"), Some(&Json::Bool(true)));
    }

    #[test]
    fn latency_reservoir_is_bounded_with_exact_count_and_max() {
        let mut r = LatReservoir::default();
        let total = (LAT_RESERVOIR_CAP as u64) * 2 + 123;
        for ns in 1..=total {
            r.record(ns);
        }
        assert_eq!(r.count, total, "count stays exact past the ring capacity");
        assert_eq!(r.max_ns, total, "max stays exact past the ring capacity");
        assert_eq!(r.ring.len(), LAT_RESERVOIR_CAP, "memory is bounded");
        // The ring retains exactly the most recent CAP samples.
        let oldest = total - LAT_RESERVOIR_CAP as u64;
        assert!(r.ring.iter().all(|&v| v > oldest), "only recent samples retained");
        let sum: u64 = r.ring.iter().sum();
        let expect: u64 = (oldest + 1..=total).sum();
        assert_eq!(sum, expect, "ring holds each recent sample exactly once");
    }

    #[test]
    fn serve_reply_pins_v1_bytes_and_streaming_op_echo() {
        let err = ServeReply::err(Some(Json::Num(7.0)), Some("simulate".to_string()), "boom");
        assert_eq!(
            err.render(false),
            r#"{"error":"boom","id":7,"ok":false,"schema":"tensordash.serve.v1"}"#
        );
        assert_eq!(
            err.render(true),
            r#"{"error":"boom","id":7,"ok":false,"op":"simulate","schema":"tensordash.serve.v1"}"#
        );
        let ack = ServeReply::ok(Some(Json::Str("x".to_string())), "shutdown")
            .field("bye", Json::Bool(true));
        assert_eq!(
            ack.render(false),
            r#"{"bye":true,"id":"x","ok":true,"schema":"tensordash.serve.v1"}"#
        );
    }

    #[test]
    fn op_responses_keep_the_v1_envelope_bytes() {
        let s = service(1);
        let req = r#"{"op":"simulate","id":"r","model":"gcn","samples":1,"seed":7}"#;
        let h = s.handle_line(req);
        let j = Json::parse(&h.lines[0]).unwrap();
        let keys: Vec<&str> = match &j {
            Json::Obj(m) => m.keys().map(String::as_str).collect(),
            _ => panic!("response must be an object"),
        };
        assert_eq!(keys, ["cache", "id", "ok", "report", "schema"], "no new top-level keys");
        // Rebuilding the envelope by hand reproduces the typed reply's
        // line byte-for-byte: ServeReply is a pure refactoring of the
        // v1 envelope, not a new format.
        let mut m = BTreeMap::new();
        m.insert("schema".to_string(), Json::Str(SERVE_SCHEMA.to_string()));
        m.insert("id".to_string(), Json::Str("r".to_string()));
        m.insert("ok".to_string(), Json::Bool(true));
        m.insert("report".to_string(), j.get("report").unwrap().clone());
        m.insert("cache".to_string(), j.get("cache").unwrap().clone());
        assert_eq!(h.lines[0], Json::Obj(m).render());
    }

    #[test]
    fn resequencer_restores_request_order_for_any_completion_order() {
        let mut rng = Rng::new(7);
        for _ in 0..64 {
            let n = 1 + rng.below(12);
            let mut order: Vec<u64> = (0..n as u64).collect();
            // Fisher-Yates over the completion order.
            for i in (1..order.len()).rev() {
                let k = rng.below(i + 1);
                order.swap(i, k);
            }
            let mut reseq = Resequencer::default();
            let mut out: Vec<String> = Vec::new();
            for &seq in &order {
                let lines = vec![format!("a{seq}"), format!("b{seq}")];
                let (ready, shut) = reseq.push(seq, lines, false);
                assert!(!shut);
                out.extend(ready);
            }
            let want: Vec<String> =
                (0..n as u64).flat_map(|s| [format!("a{s}"), format!("b{s}")]).collect();
            assert_eq!(out, want, "completion order {order:?}");
            assert_eq!(reseq.buffered(), 0, "nothing left behind");
        }
    }

    #[test]
    fn dead_connections_cancel_queued_work_and_deadlines_time_out() {
        let s = service(1);
        // A queued request whose client disconnected: cancelled
        // without computing (no latency sample), nothing posted.
        let conn = Arc::new(ConnShared::default());
        conn.add_outstanding();
        conn.mark_dead();
        s.execute_job(ReqJob {
            conn: Arc::clone(&conn),
            req: Json::parse(r#"{"op":"stats","id":1}"#).unwrap(),
            id: Some(Json::Num(1.0)),
            op: Some("stats".to_string()),
            seq: Some(0),
            stream: false,
            deadline: None,
        });
        assert_eq!(s.mux.cancelled.load(Ordering::Relaxed), 1);
        assert!(conn.state.lock().unwrap().mailbox.is_empty(), "nothing posted to a dead conn");
        assert_eq!(s.latency.lock().unwrap().count, 0, "cancelled work is not computed");
        // A queued request whose deadline passed: in-band timeout
        // error with the streaming op echo, still without computing.
        let live = Arc::new(ConnShared::default());
        live.add_outstanding();
        let deadline = Instant::now();
        std::thread::sleep(Duration::from_millis(5));
        s.execute_job(ReqJob {
            conn: Arc::clone(&live),
            req: Json::parse(r#"{"op":"stats","id":2}"#).unwrap(),
            id: Some(Json::Num(2.0)),
            op: Some("stats".to_string()),
            seq: None,
            stream: true,
            deadline: Some(deadline),
        });
        assert_eq!(s.mux.timeouts.load(Ordering::Relaxed), 1);
        let g = live.state.lock().unwrap();
        assert_eq!(g.mailbox.len(), 1, "timeout answers in-band");
        let j = Json::parse(&g.mailbox[0].lines[0]).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
        assert!(j.get("error").unwrap().as_str().unwrap().contains("timeout"));
        assert_eq!(j.get("id").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("op").unwrap().as_str(), Some("stats"));
        assert_eq!(s.latency.lock().unwrap().count, 0, "timed-out work is not computed");
    }

    #[test]
    fn tcp_multiplexer_sheds_streams_and_keeps_v1_order() {
        use std::io::{BufRead, BufReader, Write};

        let s = service(1);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::scope(|scope| {
            // One worker and a depth-1 request queue: one request in
            // service, one queued, the next one shed in-band.
            let opts = ServeOptions { workers: 1, queue_depth: 1, request_timeout: None };
            let server = scope.spawn(|| s.serve_listener(listener, opts));

            let c = TcpStream::connect(addr).unwrap();
            c.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
            let mut r = BufReader::new(c.try_clone().unwrap());
            let mut w = c;
            // R1: a slow cold sweep the single worker picks up.
            let slow = concat!(
                r#"{"op":"sweep","models":["alexnet","gcn"],"epochs":[0.1,0.3,0.5,0.7,0.9],"#,
                r#""samples":3,"seed":97,"id":"slow"}"#,
            );
            w.write_all(slow.as_bytes()).unwrap();
            w.write_all(b"\n").unwrap();
            std::thread::sleep(Duration::from_millis(150));
            // R2 fills the depth-1 queue behind it...
            w.write_all(b"{\"op\":\"stats\",\"id\":\"queued\"}\n").unwrap();
            std::thread::sleep(Duration::from_millis(75));
            // ...so R3 is shed — and, being a streaming request, its
            // error overtakes both pending ordered responses while
            // the connection stays open.
            w.write_all(b"{\"op\":\"stats\",\"id\":\"shed\",\"stream\":true}\n").unwrap();
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            let j = Json::parse(&line).unwrap();
            assert_eq!(j.get("id").unwrap().as_str(), Some("shed"), "{line}");
            assert_eq!(j.get("ok"), Some(&Json::Bool(false)), "{line}");
            assert!(j.get("error").unwrap().as_str().unwrap().contains("overloaded"), "{line}");
            assert_eq!(j.get("op").unwrap().as_str(), Some("stats"), "op echo: {line}");
            // The ordered responses still arrive strictly in request
            // order: the slow sweep first, then the queued stats.
            for want in ["slow", "queued"] {
                let mut line = String::new();
                r.read_line(&mut line).unwrap();
                let j = Json::parse(&line).unwrap();
                assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{line}");
                assert_eq!(j.get("id").unwrap().as_str(), Some(want), "in order: {line}");
            }
            // Shutdown acks and joins the server cleanly.
            w.write_all(b"{\"op\":\"shutdown\"}\n").unwrap();
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            assert_eq!(Json::parse(&line).unwrap().get("bye"), Some(&Json::Bool(true)));
            server.join().unwrap().unwrap();
        });
    }
}
