//! The persistent simulation service: a JSON-lines request loop over
//! stdin/stdout or TCP, answering simulation requests from the shared
//! [`UnitCache`] wherever possible.
//!
//! The dominant real workload for a simulator like this is
//! design-space search: thousands of overlapping configuration queries
//! against one model set, where successive requests share most of
//! their (layer × op) units. The service keeps one process resident so
//! those requests stop paying process startup, artifact reload and
//! unit recomputation:
//!
//! * **Protocol** — one JSON object per line in, one JSON object per
//!   line out (`tensordash.serve.v1`), responses streamed strictly in
//!   request order. Ops: `simulate`, `sweep`, `trace`, `explore`,
//!   `batch`, `stats`, `store_ingest`, `store_query`, `store_diff`,
//!   `shutdown`. Unknown fields are ignored; malformed lines answer
//!   `{"ok":false,...}` without killing the loop.
//! * **Coalescing** — a `batch` op runs all of its sub-requests
//!   through *one* engine invocation, so identical units across the
//!   batch's cells simulate once (deterministically, in the engine's
//!   serial lookup phase); units identical to ones in flight on other
//!   concurrent connections block on the first computation instead of
//!   repeating it ([`UnitCache::compute_coalesced`]).
//! * **Artifact store** — model profiles and captured-trace bitmap
//!   files are loaded once and shared by `Arc` across every request
//!   and connection ([`ArtifactStore`]); a trace request never copies
//!   a bitmap.
//! * **Determinism** — the `report` field of a response is computed
//!   from the merged simulation only: a cache-served response is
//!   byte-identical to a cold-computed one. Cache telemetry rides in
//!   the separate `cache` envelope field (counters move between runs
//!   by design, so they must not — and do not — touch the report).
//! * **Transport** — the TCP mode runs a *bounded worker pool*: one
//!   fixed accept thread blocks in `accept()` (no polling; shutdown
//!   wakes it with a self-connect poke) and feeds a depth-limited
//!   connection queue that `--workers` pool threads drain. A worker
//!   owns a connection until EOF, so responses per connection still
//!   stream strictly in request order. Past `--queue-depth` pending
//!   connections the accept thread *sheds load*: the client gets an
//!   explicit `tensordash.serve.v1` "overloaded" error line and a
//!   closed socket instead of an unbounded thread spawn.
//! * **Telemetry** — every handled line records its wall-clock
//!   duration into a fixed-capacity reservoir (the most recent
//!   `LAT_RESERVOIR_CAP` samples, plus exact running count and max,
//!   so a resident server's memory stays bounded); the `stats` op
//!   reports p50/p99 percentiles over the retained window plus the
//!   exact max (nearest-rank, so the summary is a deterministic
//!   function of the recorded durations), letting store-backed serve
//!   runs be compared across PRs.
//! * **Store ops** — `store_ingest`/`store_query`/`store_diff` expose
//!   the [`ExperimentStore`](crate::store::ExperimentStore) over the
//!   same protocol as the `store` CLI subcommand: ingest response
//!   reports into an indexed history file, query a metric's trajectory
//!   across commits, diff two commits' reports or frontiers.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::config::{ChipConfig, DataType};
use crate::conv::{ConvShape, TrainOp};
use crate::repro::{self, ModelSim};
use crate::search::{self, ExploreSpec, SearchSpace, SPACE_SCHEMA};
use crate::store::{ExperimentStore, QueryFilter};
use crate::tensor::TensorBitmap;
use crate::trace::profiles::ModelProfile;
use crate::util::json::Json;

use super::cache::{shape_json, UnitCache};
use super::engine::Engine;
use super::plan::layers_report;
use super::report::{report_set_json, Cell, Report};
use super::request::{SimRequest, SweepSpec, Workload};

/// Schema tag of every response line.
pub const SERVE_SCHEMA: &str = "tensordash.serve.v1";
/// Schema tag of on-disk trace artifacts ([`TraceArtifact`]).
pub const TRACE_SCHEMA: &str = "tensordash.trace.v1";
/// Default worker-pool size for the TCP transport (`--workers`).
pub const DEFAULT_SERVE_WORKERS: usize = 8;
/// Default pending-connection queue depth (`--queue-depth`); past this
/// many queued connections the accept thread sheds load.
pub const DEFAULT_QUEUE_DEPTH: usize = 64;
/// Latency samples retained by the stats reservoir.
const LAT_RESERVOIR_CAP: usize = 4096;

// ---------------------------------------------------------------------
// Trace artifacts + the Arc-backed artifact store
// ---------------------------------------------------------------------

/// A captured training trace: per-layer geometry plus (A, G) zero
/// bitmaps, loaded once and shared by `Arc` across every request that
/// references it.
#[derive(Debug, Clone)]
pub struct TraceArtifact {
    pub name: String,
    pub shapes: Vec<ConvShape>,
    pub layers: Arc<Vec<(TensorBitmap, TensorBitmap)>>,
}

fn shape_from_json(j: &Json) -> Option<ConvShape> {
    Some(ConvShape {
        n: j.get("n")?.as_usize()?,
        h: j.get("h")?.as_usize()?,
        w: j.get("w")?.as_usize()?,
        c: j.get("c")?.as_usize()?,
        f: j.get("f")?.as_usize()?,
        kh: j.get("kh")?.as_usize()?,
        kw: j.get("kw")?.as_usize()?,
        stride: j.get("stride")?.as_usize()?,
        pad: j.get("pad")?.as_usize()?,
    })
}

impl TraceArtifact {
    pub fn new(
        name: impl Into<String>,
        shapes: Vec<ConvShape>,
        layers: Vec<(TensorBitmap, TensorBitmap)>,
    ) -> TraceArtifact {
        assert_eq!(shapes.len(), layers.len(), "trace shapes/layers mismatch");
        TraceArtifact { name: name.into(), shapes, layers: Arc::new(layers) }
    }

    pub fn to_json(&self) -> Json {
        let layers = self
            .shapes
            .iter()
            .zip(self.layers.iter())
            .map(|(s, (a, g))| {
                let mut m = BTreeMap::new();
                m.insert("shape".to_string(), shape_json(s));
                m.insert("a".to_string(), a.to_json());
                m.insert("g".to_string(), g.to_json());
                Json::Obj(m)
            })
            .collect();
        let mut m = BTreeMap::new();
        m.insert("schema".to_string(), Json::Str(TRACE_SCHEMA.to_string()));
        m.insert("model".to_string(), Json::Str(self.name.clone()));
        m.insert("layers".to_string(), Json::Arr(layers));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Option<TraceArtifact> {
        if j.get("schema")?.as_str()? != TRACE_SCHEMA {
            return None;
        }
        let name = j.get("model")?.as_str()?.to_string();
        let mut shapes = Vec::new();
        let mut layers = Vec::new();
        for l in j.get("layers")?.as_arr()? {
            shapes.push(shape_from_json(l.get("shape")?)?);
            let a = TensorBitmap::from_json(l.get("a")?)?;
            let g = TensorBitmap::from_json(l.get("g")?)?;
            layers.push((a, g));
        }
        Some(TraceArtifact { name, shapes, layers: Arc::new(layers) })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let mut text = self.to_json().render_pretty();
        text.push('\n');
        std::fs::write(path, text.as_bytes())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<TraceArtifact, String> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| format!("parsing {}: {e}", path.display()))?;
        TraceArtifact::from_json(&j)
            .ok_or_else(|| format!("{} is not a {TRACE_SCHEMA} document", path.display()))
    }

    /// Build a request over this trace; the bitmap vector is shared by
    /// `Arc`, never copied.
    pub fn request(&self, cfg: ChipConfig, samples: usize, seed: u64) -> SimRequest {
        SimRequest {
            label: self.name.clone(),
            cfg,
            workload: Workload::Trace {
                shapes: self.shapes.clone(),
                layers: Arc::clone(&self.layers),
            },
            samples,
            seed,
        }
    }
}

/// Memoizing artifact store: model profiles and trace files resolve
/// once per service lifetime and are shared by `Arc` thereafter.
#[derive(Debug, Default)]
pub struct ArtifactStore {
    profiles: Mutex<HashMap<String, Arc<ModelProfile>>>,
    traces: Mutex<HashMap<String, Arc<TraceArtifact>>>,
}

impl ArtifactStore {
    /// Resolve a model profile, loading it on first use.
    pub fn profile(&self, name: &str) -> Option<Arc<ModelProfile>> {
        let mut g = self.profiles.lock().unwrap();
        if let Some(p) = g.get(name) {
            return Some(Arc::clone(p));
        }
        let p = Arc::new(ModelProfile::for_model(name)?);
        g.insert(name.to_string(), Arc::clone(&p));
        Some(p)
    }

    /// Resolve a trace artifact by path, loading the file on first use.
    pub fn trace(&self, path: &str) -> Result<Arc<TraceArtifact>, String> {
        {
            let g = self.traces.lock().unwrap();
            if let Some(t) = g.get(path) {
                return Ok(Arc::clone(t));
            }
        }
        // Load outside the lock: a slow disk must not block other
        // connections' already-resident artifacts.
        let t = Arc::new(TraceArtifact::load(path)?);
        let mut g = self.traces.lock().unwrap();
        let entry = g.entry(path.to_string()).or_insert_with(|| Arc::clone(&t));
        Ok(Arc::clone(entry))
    }

    /// Register an in-memory trace under a key (tests, embedded use).
    pub fn register_trace(&self, key: &str, t: TraceArtifact) -> Arc<TraceArtifact> {
        let t = Arc::new(t);
        self.traces.lock().unwrap().insert(key.to_string(), Arc::clone(&t));
        t
    }

    /// (profiles, traces) currently resident.
    pub fn loaded(&self) -> (usize, usize) {
        (self.profiles.lock().unwrap().len(), self.traces.lock().unwrap().len())
    }
}

// ---------------------------------------------------------------------
// Request parsing
// ---------------------------------------------------------------------

/// One parsed sub-request: its response id, the engine cells it
/// expands to, and how to shape the resulting sims into reports.
struct SubReq {
    id: Option<Json>,
    per_layer: bool,
    kind: SubKind,
    cells: Vec<SimRequest>,
}

enum SubKind {
    Simulate { model: String, epoch: f64, cfg: ChipConfig, samples: usize, seed: u64 },
    Sweep,
    Trace { name: String },
}

fn parse_cfg(j: &Json) -> Result<ChipConfig, String> {
    let mut cfg = ChipConfig::default();
    // Zero geometry would divide-by-zero deep inside a worker; reject
    // it here so the error stays in-band instead of killing the loop.
    if let Some(v) = j.get("rows") {
        cfg.tile_rows = match v.as_usize() {
            Some(r) if r >= 1 => r,
            _ => return Err("'rows' must be a positive number".to_string()),
        };
    }
    if let Some(v) = j.get("cols") {
        cfg.tile_cols = match v.as_usize() {
            Some(c) if c >= 1 => c,
            _ => return Err("'cols' must be a positive number".to_string()),
        };
    }
    if let Some(v) = j.get("depth") {
        let d = v.as_usize().ok_or("'depth' must be a number")?;
        if d != 2 && d != 3 {
            return Err("'depth' must be 2 or 3".to_string());
        }
        cfg.staging_depth = d;
    }
    if let Some(v) = j.get("bf16") {
        if v.as_bool().ok_or("'bf16' must be a boolean")? {
            cfg.dtype = DataType::Bf16;
        }
    }
    if let Some(v) = j.get("power_gate") {
        cfg.power_gate = v.as_bool().ok_or("'power_gate' must be a boolean")?;
    }
    Ok(cfg)
}

fn get_usize(j: &Json, key: &str, default: usize) -> Result<usize, String> {
    match j.get(key) {
        None => Ok(default),
        Some(v) => v.as_usize().ok_or_else(|| format!("'{key}' must be a number")),
    }
}

fn get_f64(j: &Json, key: &str, default: f64) -> Result<f64, String> {
    match j.get(key) {
        None => Ok(default),
        Some(v) => v.as_f64().ok_or_else(|| format!("'{key}' must be a number")),
    }
}

/// Seeds are u64 and must survive the protocol exactly — JSON numbers
/// ride through f64, which is only exact up to 2^53, so numbers are
/// accepted in that range only and larger seeds travel as decimal
/// strings (the same reason cache keys hex-encode their seeds).
fn get_seed(j: &Json, default: u64) -> Result<u64, String> {
    match j.get("seed") {
        None => Ok(default),
        Some(Json::Num(v)) => {
            if *v >= 0.0 && *v <= 9.0e15 && v.trunc() == *v {
                Ok(*v as u64)
            } else {
                Err("'seed' as a JSON number must be a non-negative integer <= 9e15; \
                     pass larger seeds as a decimal string"
                    .to_string())
            }
        }
        Some(Json::Str(s)) => {
            s.parse::<u64>().map_err(|_| format!("'seed' string '{s}' is not a u64"))
        }
        Some(_) => Err("'seed' must be a number or a decimal string".to_string()),
    }
}

// ---------------------------------------------------------------------
// The service
// ---------------------------------------------------------------------

/// Result of handling one input line: the response lines (one per
/// sub-request) and whether the service should shut down.
pub struct Handled {
    pub lines: Vec<String>,
    pub shutdown: bool,
}

/// Fixed-capacity latency reservoir: a ring of the most recent
/// [`LAT_RESERVOIR_CAP`] samples plus an exact running count and max.
/// A resident server's memory stays bounded under sustained load
/// (the old unbounded `Vec<u64>` grew by 8 bytes per request forever),
/// while p50/p99 summarize the retained window and count/max stay
/// exact over the whole session. The retained window is a pure
/// function of the recorded sequence, so percentiles are as
/// deterministic as the durations themselves.
#[derive(Debug, Default)]
struct LatReservoir {
    count: u64,
    max_ns: u64,
    ring: Vec<u64>,
    pos: usize,
}

impl LatReservoir {
    fn record(&mut self, ns: u64) {
        self.count += 1;
        self.max_ns = self.max_ns.max(ns);
        if self.ring.len() < LAT_RESERVOIR_CAP {
            self.ring.push(ns);
        } else {
            self.ring[self.pos] = ns;
            self.pos = (self.pos + 1) % LAT_RESERVOIR_CAP;
        }
    }
}

/// The persistent simulation service. Share by reference across
/// connection-handler threads; all interior state is synchronized.
#[derive(Debug)]
pub struct Service {
    engine: Engine,
    cache: Arc<UnitCache>,
    artifacts: ArtifactStore,
    stop: AtomicBool,
    /// Wall-clock nanoseconds of handled lines, across all
    /// connections; the `stats` op summarizes them as percentiles.
    latency: Mutex<LatReservoir>,
}

impl Service {
    /// Build a service over `engine`, attaching `cache` to it (every
    /// request the service runs is cache-aware).
    pub fn new(engine: Engine, cache: Arc<UnitCache>) -> Service {
        Service {
            engine: engine.with_cache(Arc::clone(&cache)),
            cache,
            artifacts: ArtifactStore::default(),
            stop: AtomicBool::new(false),
            latency: Mutex::new(LatReservoir::default()),
        }
    }

    pub fn artifacts(&self) -> &ArtifactStore {
        &self.artifacts
    }

    pub fn cache(&self) -> &Arc<UnitCache> {
        &self.cache
    }

    /// Handle one protocol line, recording its wall-clock duration for
    /// the `stats` op's latency summary. Never panics on malformed
    /// input; the error is reported in-band.
    pub fn handle_line(&self, line: &str) -> Handled {
        let t0 = Instant::now();
        let h = self.handle_line_inner(line);
        let ns = t0.elapsed().as_nanos().min(u64::MAX as u128) as u64;
        self.latency.lock().unwrap().record(ns);
        h
    }

    fn handle_line_inner(&self, line: &str) -> Handled {
        let j = match Json::parse(line) {
            Ok(j) => j,
            Err(e) => {
                return Handled {
                    lines: vec![error_line(None, &format!("bad json: {e}"))],
                    shutdown: false,
                }
            }
        };
        let id = j.get("id").cloned();
        match j.get("op").and_then(Json::as_str) {
            Some("shutdown") => {
                let mut m = envelope(id);
                m.insert("ok".to_string(), Json::Bool(true));
                m.insert("bye".to_string(), Json::Bool(true));
                Handled { lines: vec![Json::Obj(m).render()], shutdown: true }
            }
            Some("stats") => Handled { lines: vec![self.stats_line(id)], shutdown: false },
            Some("explore") => Handled { lines: vec![self.explore_line(&j, id)], shutdown: false },
            Some(op @ ("store_ingest" | "store_query" | "store_diff")) => {
                Handled { lines: vec![store_line(op, &j, id)], shutdown: false }
            }
            Some("batch") => {
                let subs = match j.get("requests").and_then(Json::as_arr) {
                    Some(reqs) => reqs.iter().collect::<Vec<_>>(),
                    None => {
                        return Handled {
                            lines: vec![error_line(id, "'batch' needs a 'requests' array")],
                            shutdown: false,
                        }
                    }
                };
                Handled { lines: self.run_batch(&subs), shutdown: false }
            }
            _ => Handled { lines: self.run_batch(&[&j]), shutdown: false },
        }
    }

    /// Parse, execute (one engine invocation for the whole batch, so
    /// identical units across sub-requests coalesce) and render
    /// responses in request order.
    fn run_batch(&self, subs: &[&Json]) -> Vec<String> {
        let parsed: Vec<Result<SubReq, (Option<Json>, String)>> =
            subs.iter().map(|j| self.parse_request(j)).collect();
        let mut all_cells: Vec<SimRequest> = Vec::new();
        for sub in parsed.iter().flatten() {
            all_cells.extend(sub.cells.iter().cloned());
        }
        let before = self.cache.stats();
        let sims = self.engine.run_all(&all_cells);
        let delta = self.cache.stats().since(&before);
        let mut out = Vec::with_capacity(parsed.len());
        let mut cursor = 0usize;
        for sub in parsed {
            match sub {
                Err((id, msg)) => out.push(error_line(id, &msg)),
                Ok(sub) => {
                    let slice = &sims[cursor..cursor + sub.cells.len()];
                    cursor += sub.cells.len();
                    let reports = self.build_reports(&sub, slice);
                    let mut m = envelope(sub.id);
                    m.insert("ok".to_string(), Json::Bool(true));
                    m.insert("report".to_string(), report_set_json(&reports));
                    m.insert("cache".to_string(), delta.to_json());
                    out.push(Json::Obj(m).render());
                }
            }
        }
        out
    }

    fn parse_request(&self, j: &Json) -> Result<SubReq, (Option<Json>, String)> {
        let id = j.get("id").cloned();
        match self.parse_request_inner(j) {
            Ok((kind, per_layer, cells)) => Ok(SubReq { id, per_layer, kind, cells }),
            Err(msg) => Err((id, msg)),
        }
    }

    #[allow(clippy::type_complexity)]
    fn parse_request_inner(&self, j: &Json) -> Result<(SubKind, bool, Vec<SimRequest>), String> {
        let per_layer = match j.get("per_layer") {
            None => false,
            Some(v) => v.as_bool().ok_or("'per_layer' must be a boolean")?,
        };
        let samples = get_usize(j, "samples", repro::DEFAULT_SAMPLES)?;
        let seed = get_seed(j, 42)?;
        match j.get("op").and_then(Json::as_str) {
            Some("simulate") | None => {
                let model = j
                    .get("model")
                    .and_then(Json::as_str)
                    .ok_or("'simulate' needs a 'model'")?
                    .to_string();
                let epoch = get_f64(j, "epoch", repro::MID_EPOCH)?;
                let cfg = parse_cfg(j)?;
                let profile = self
                    .artifacts
                    .profile(&model)
                    .ok_or_else(|| format!("unknown model '{model}'"))?;
                let req = SimRequest::profile_shared(profile, epoch, cfg.clone(), samples, seed);
                Ok((SubKind::Simulate { model, epoch, cfg, samples, seed }, per_layer, vec![req]))
            }
            Some("sweep") => {
                let models = self.resolve_models(j, "sweep")?;
                let epochs: Vec<f64> = match j.get("epochs") {
                    None => vec![repro::MID_EPOCH],
                    Some(v) => v
                        .as_arr()
                        .ok_or("'epochs' must be an array")?
                        .iter()
                        .map(Json::as_f64)
                        .collect::<Option<_>>()
                        .ok_or("'epochs' must contain numbers")?,
                };
                let cfg = parse_cfg(j)?;
                let names: Vec<&str> = models.iter().map(|(m, _)| m.as_str()).collect();
                let spec = SweepSpec::models(&names, repro::MID_EPOCH, &cfg, samples, seed)
                    .with_epochs(&epochs);
                // Keep SweepSpec's label/seed semantics, then swap
                // each cell onto the store's Arc'd profile so plan
                // expansion stops re-building topologies per request.
                let mut cells = spec.cells();
                for cell in &mut cells {
                    let shared = match &cell.workload {
                        Workload::Profile { model, epoch } => models
                            .iter()
                            .find(|(m, _)| m == model)
                            .map(|(_, p)| (Arc::clone(p), *epoch)),
                        _ => None,
                    };
                    if let Some((profile, epoch)) = shared {
                        cell.workload = Workload::ProfileShared { profile, epoch };
                    }
                }
                Ok((SubKind::Sweep, per_layer, cells))
            }
            Some("trace") => {
                let path = j
                    .get("path")
                    .and_then(Json::as_str)
                    .ok_or("'trace' needs a 'path'")?;
                let artifact = self.artifacts.trace(path)?;
                let cfg = parse_cfg(j)?;
                let req = artifact.request(cfg, samples, seed);
                Ok((SubKind::Trace { name: artifact.name.clone() }, per_layer, vec![req]))
            }
            Some(other) => Err(format!("unknown op '{other}'")),
        }
    }

    fn build_reports(&self, sub: &SubReq, sims: &[ModelSim]) -> Vec<Report> {
        match &sub.kind {
            SubKind::Simulate { model, epoch, cfg, samples, seed } => {
                let sim = &sims[0];
                let mut reports =
                    vec![repro::simulate_report(model, *epoch, cfg, *samples, *seed, sim)];
                if sub.per_layer {
                    reports.push(layers_report(sim));
                }
                reports
            }
            SubKind::Sweep => {
                let mut r = Report::new(
                    "sweep",
                    "Sweep — overall speedup and efficiency per cell",
                    &["cell", "speedup", "compute eff", "chip eff"],
                );
                for sim in sims {
                    r.row(vec![
                        Cell::text(sim.name.clone()),
                        Cell::num(sim.overall_speedup()),
                        Cell::num(sim.compute_efficiency()),
                        Cell::num(sim.total_efficiency()),
                    ]);
                }
                r.meta_num("cells", sims.len() as f64);
                let mut reports = vec![r];
                if sub.per_layer {
                    reports.extend(sims.iter().map(layers_report));
                }
                reports
            }
            SubKind::Trace { name } => {
                let sim = &sims[0];
                let mut r = Report::new(
                    "trace",
                    format!("{name} — projection from captured bitmaps"),
                    &["metric", "A*W", "A*G", "W*G", "overall"],
                );
                r.row(vec![
                    Cell::text("speedup"),
                    Cell::num(sim.op_speedup(TrainOp::Fwd)),
                    Cell::num(sim.op_speedup(TrainOp::Igrad)),
                    Cell::num(sim.op_speedup(TrainOp::Wgrad)),
                    Cell::num(sim.overall_speedup()),
                ]);
                r.row(vec![
                    Cell::text("whole-chip efficiency"),
                    Cell::empty(),
                    Cell::empty(),
                    Cell::empty(),
                    Cell::num(sim.total_efficiency()),
                ]);
                r.meta_str("model", name);
                let mut reports = vec![r];
                if sub.per_layer {
                    reports.push(layers_report(sim));
                }
                reports
            }
        }
    }

    /// The `explore` op: a cache-driven design-space search
    /// ([`crate::search`]) over this service's shared engine + cache.
    /// Overlapping requests share units across connections exactly like
    /// simulate/sweep do. The report (frontier rows *and* provenance
    /// meta) is deterministic in the request, so a warm response is
    /// byte-identical to a cold one; cache telemetry rides in the
    /// separate `cache` envelope field.
    fn explore_line(&self, j: &Json, id: Option<Json>) -> String {
        match self.parse_and_run_explore(j) {
            Ok((report, cache)) => {
                let mut m = envelope(id);
                m.insert("ok".to_string(), Json::Bool(true));
                m.insert("report".to_string(), report.to_json());
                m.insert("cache".to_string(), cache);
                Json::Obj(m).render()
            }
            Err(msg) => error_line(id, &msg),
        }
    }

    /// Parse a request's `models` array and resolve every name through
    /// the artifact store (profiles load once per service lifetime).
    /// Shared by the sweep and explore ops so validation and error
    /// wording cannot drift between them.
    fn resolve_models(
        &self,
        j: &Json,
        op: &str,
    ) -> Result<Vec<(String, Arc<ModelProfile>)>, String> {
        let names: Vec<String> = j
            .get("models")
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("'{op}' needs a 'models' array"))?
            .iter()
            .map(|m| m.as_str().map(str::to_string))
            .collect::<Option<_>>()
            .ok_or("'models' must contain strings")?;
        if names.is_empty() {
            return Err(format!("'{op}' needs at least one model"));
        }
        let mut out = Vec::with_capacity(names.len());
        for m in names {
            let p = self.artifacts.profile(&m).ok_or_else(|| format!("unknown model '{m}'"))?;
            out.push((m, p));
        }
        Ok(out)
    }

    fn parse_and_run_explore(&self, j: &Json) -> Result<(Report, Json), String> {
        let models = self.resolve_models(j, "explore")?;
        let space = match j.get("axes") {
            None => SearchSpace::default_space(),
            Some(axes @ Json::Obj(_)) => {
                let mut doc = BTreeMap::new();
                doc.insert("schema".to_string(), Json::Str(SPACE_SCHEMA.to_string()));
                doc.insert("axes".to_string(), axes.clone());
                SearchSpace::from_json(&Json::Obj(doc))?
            }
            Some(_) => return Err("'axes' must be an object of axis -> value arrays".to_string()),
        };
        let epoch = get_f64(j, "epoch", repro::MID_EPOCH)?;
        let samples = get_usize(j, "samples", repro::DEFAULT_SAMPLES)?;
        let seed = get_seed(j, 42)?;
        let budget = get_usize(j, "budget", 8)?.max(1);
        let population =
            get_usize(j, "population", search::default_population(budget))?.max(1);
        let spec = ExploreSpec::with_profiles(space, models, epoch, samples, seed, budget)
            .with_population(population);
        let before = self.cache.stats();
        let res = search::explore(&self.engine, &spec);
        let delta = self.cache.stats().since(&before);
        Ok((search::frontier_report(&spec, &res), delta.to_json()))
    }

    /// Per-request latency summary: exact count and max over every
    /// duration recorded so far, p50/p99 (nearest-rank: the smallest
    /// sample with at least p% of samples at or below it — a
    /// deterministic function of the recorded durations) over the
    /// reservoir's retained window, in nanoseconds.
    fn latency_json(&self) -> Json {
        let (count, max_ns, mut window) = {
            let r = self.latency.lock().unwrap();
            (r.count, r.max_ns, r.ring.clone())
        };
        window.sort_unstable();
        let pick = |p: f64| -> f64 {
            if window.is_empty() {
                return 0.0;
            }
            let rank = ((p / 100.0) * window.len() as f64).ceil() as usize;
            window[rank.clamp(1, window.len()) - 1] as f64
        };
        let mut m = BTreeMap::new();
        m.insert("count".to_string(), Json::Num(count as f64));
        m.insert("p50_ns".to_string(), Json::Num(pick(50.0)));
        m.insert("p99_ns".to_string(), Json::Num(pick(99.0)));
        m.insert("max_ns".to_string(), Json::Num(max_ns as f64));
        Json::Obj(m)
    }

    fn stats_line(&self, id: Option<Json>) -> String {
        let (profiles, traces) = self.artifacts.loaded();
        let mut m = envelope(id);
        m.insert("ok".to_string(), Json::Bool(true));
        m.insert("cache".to_string(), self.cache.stats().to_json());
        m.insert("cache_entries".to_string(), Json::Num(self.cache.len() as f64));
        m.insert("cache_shards".to_string(), Json::Num(self.cache.shard_count() as f64));
        m.insert("latency".to_string(), self.latency_json());
        m.insert("profiles_loaded".to_string(), Json::Num(profiles as f64));
        m.insert("traces_loaded".to_string(), Json::Num(traces as f64));
        Json::Obj(m).render()
    }

    /// The blocking line loop: read requests from `reader`, stream
    /// responses to `writer` (flushed per line), return on EOF or a
    /// `shutdown` op. This is both the stdin/stdout mode and the
    /// per-connection TCP loop.
    pub fn serve_lines<R: BufRead, W: Write>(
        &self,
        reader: R,
        mut writer: W,
    ) -> std::io::Result<()> {
        for line in reader.lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let h = self.handle_line(&line);
            for l in &h.lines {
                writer.write_all(l.as_bytes())?;
                writer.write_all(b"\n")?;
            }
            writer.flush()?;
            if h.shutdown {
                self.stop.store(true, Ordering::SeqCst);
                break;
            }
        }
        Ok(())
    }

    /// Bind `addr` and serve it with a bounded worker pool: see
    /// [`Self::serve_listener`].
    pub fn serve_tcp(
        &self,
        addr: &str,
        workers: usize,
        queue_depth: usize,
    ) -> std::io::Result<()> {
        let listener = TcpListener::bind(addr)?;
        self.serve_listener(listener, workers, queue_depth)
    }

    /// Serve an already-bound listener until a `shutdown` op arrives
    /// on any connection. The calling thread becomes the fixed accept
    /// thread: it blocks in `accept()` (no polling — an idle server
    /// burns no CPU; shutdown wakes it with a self-connect poke) and
    /// pushes each connection onto a depth-limited queue that
    /// `workers` pool threads drain. A worker owns a connection until
    /// EOF, so responses per connection stream strictly in request
    /// order. When the queue is at `queue_depth` the accept thread
    /// sheds load: the client gets an explicit "overloaded" error line
    /// and a closed socket. On shutdown every in-service connection is
    /// half-closed so workers blocked in a read drain promptly, and
    /// queued-but-unserved connections are refused with an error line.
    pub fn serve_listener(
        &self,
        listener: TcpListener,
        workers: usize,
        queue_depth: usize,
    ) -> std::io::Result<()> {
        let workers = workers.max(1);
        let local = listener.local_addr()?;
        eprintln!(
            "tensordash serve: listening on {local} ({workers} workers, queue depth {})",
            queue_depth.max(1)
        );
        let queue = ConnQueue::new(queue_depth);
        // Connections currently owned by workers, tracked so shutdown
        // can half-close them. Each worker reaps its own entry on
        // handoff — a resident service must not accumulate one fd per
        // past connection.
        let conns: Mutex<Vec<(u64, TcpStream)>> = Mutex::new(Vec::new());
        let next_id = AtomicU64::new(0);
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| self.worker_loop(&queue, &conns, &next_id, local));
            }
            loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        if self.stop.load(Ordering::SeqCst) {
                            // The shutdown poke (or a late client).
                            drop(stream);
                            break;
                        }
                        if let Err(stream) = queue.push(stream) {
                            shed(stream, "overloaded: connection queue full, retry later");
                        }
                    }
                    // Transient accept failures (ECONNABORTED, EMFILE
                    // pressure, ...) must not take the service down —
                    // only the shutdown op ends the loop.
                    Err(e) => {
                        if self.stop.load(Ordering::SeqCst) {
                            break;
                        }
                        eprintln!("serve: accept failed (retrying): {e}");
                        std::thread::sleep(Duration::from_millis(100));
                    }
                }
            }
            // Shutdown: refuse connections that were queued but never
            // served (close() also wakes every idle worker), then
            // half-close the read side of in-service connections —
            // idle readers see EOF and exit, while workers
            // mid-computation can still write their in-flight response
            // before the scope joins them.
            for stream in queue.close() {
                shed(stream, "overloaded: service shutting down");
            }
            for (_, c) in conns.lock().unwrap().iter() {
                let _ = c.shutdown(std::net::Shutdown::Read);
            }
        });
        Ok(())
    }

    /// One pool worker: take a connection from the queue, own it until
    /// its line loop ends, repeat. Exits when the queue closes; a
    /// worker that observes the stop flag pokes the accept thread out
    /// of its blocking `accept()` so the whole scope can join.
    fn worker_loop(
        &self,
        queue: &ConnQueue,
        conns: &Mutex<Vec<(u64, TcpStream)>>,
        next_id: &AtomicU64,
        local: SocketAddr,
    ) {
        while let Some(stream) = queue.pop() {
            let id = next_id.fetch_add(1, Ordering::Relaxed);
            // An untracked connection could not be half-closed on
            // shutdown, so an idle client would hang the scope join
            // forever — refuse the connection instead of serving it
            // untracked (try_clone fails under fd pressure, where
            // shedding is the right move anyway).
            match stream.try_clone() {
                Ok(clone) => conns.lock().unwrap().push((id, clone)),
                Err(e) => {
                    eprintln!("serve: refusing untrackable connection: {e}");
                    continue;
                }
            }
            let _ = self.handle_conn(stream);
            conns.lock().unwrap().retain(|(i, _)| *i != id);
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
        }
        if self.stop.load(Ordering::SeqCst) {
            poke_listener(local);
        }
    }

    fn handle_conn(&self, stream: TcpStream) -> std::io::Result<()> {
        stream.set_nonblocking(false)?;
        let reader = BufReader::new(stream.try_clone()?);
        let writer = BufWriter::new(stream);
        self.serve_lines(reader, writer)
    }
}

// ---------------------------------------------------------------------
// TCP transport plumbing — the bounded handoff queue and backpressure
// ---------------------------------------------------------------------

/// Depth-bounded handoff queue between the accept thread and the
/// worker pool. `push` never blocks: at depth the connection comes
/// straight back so the accept thread can shed it, keeping admission
/// control on the accept side and workers ignorant of load.
struct ConnQueue {
    depth: usize,
    state: Mutex<QueueState>,
    ready: Condvar,
}

#[derive(Default)]
struct QueueState {
    pending: VecDeque<TcpStream>,
    closed: bool,
}

impl ConnQueue {
    fn new(depth: usize) -> ConnQueue {
        ConnQueue {
            depth: depth.max(1),
            state: Mutex::new(QueueState::default()),
            ready: Condvar::new(),
        }
    }

    /// Enqueue a connection; hands it back when the queue is at depth
    /// or closed (the caller sheds it).
    fn push(&self, conn: TcpStream) -> Result<(), TcpStream> {
        let mut g = self.state.lock().unwrap();
        if g.closed || g.pending.len() >= self.depth {
            return Err(conn);
        }
        g.pending.push_back(conn);
        self.ready.notify_one();
        Ok(())
    }

    /// Block until a connection is available (`Some`) or the queue is
    /// closed and drained (`None`).
    fn pop(&self) -> Option<TcpStream> {
        let mut g = self.state.lock().unwrap();
        loop {
            if let Some(c) = g.pending.pop_front() {
                return Some(c);
            }
            if g.closed {
                return None;
            }
            g = self.ready.wait(g).unwrap();
        }
    }

    /// Close the queue, waking every waiting worker; returns the
    /// connections that were queued but never served.
    fn close(&self) -> Vec<TcpStream> {
        let mut g = self.state.lock().unwrap();
        g.closed = true;
        let drained = g.pending.drain(..).collect();
        self.ready.notify_all();
        drained
    }
}

/// Backpressure: answer a connection the pool cannot take with an
/// explicit in-protocol error line, then close it. The write gets a
/// short timeout so a shed client that never reads cannot wedge the
/// accept thread.
fn shed(mut stream: TcpStream, msg: &str) {
    let _ = stream.set_write_timeout(Some(Duration::from_millis(250)));
    let _ = stream.write_all(error_line(None, msg).as_bytes());
    let _ = stream.write_all(b"\n");
}

/// Wake a thread blocked in `accept()` by connecting to its listener
/// and immediately dropping the connection. Tries the bound address
/// first, then loopback on the same port for wildcard binds. Best
/// effort: a failed connect means the listener is already past
/// `accept()`.
fn poke_listener(local: SocketAddr) {
    let timeout = Duration::from_millis(200);
    if TcpStream::connect_timeout(&local, timeout).is_ok() {
        return;
    }
    let loopback = SocketAddr::from(([127, 0, 0, 1], local.port()));
    let _ = TcpStream::connect_timeout(&loopback, timeout);
}

fn envelope(id: Option<Json>) -> BTreeMap<String, Json> {
    let mut m = BTreeMap::new();
    m.insert("schema".to_string(), Json::Str(SERVE_SCHEMA.to_string()));
    if let Some(id) = id {
        m.insert("id".to_string(), id);
    }
    m
}

fn error_line(id: Option<Json>, msg: &str) -> String {
    let mut m = envelope(id);
    m.insert("ok".to_string(), Json::Bool(false));
    m.insert("error".to_string(), Json::Str(msg.to_string()));
    Json::Obj(m).render()
}

// ---------------------------------------------------------------------
// Store ops — the ExperimentStore over the serve protocol
// ---------------------------------------------------------------------

/// Dispatch one `store_*` op. Stateless with respect to the service:
/// each request opens the store file it names (`db`), so different
/// requests may address different stores.
fn store_line(op: &str, j: &Json, id: Option<Json>) -> String {
    let result = match op {
        "store_ingest" => store_ingest(j),
        "store_query" => store_query(j),
        _ => store_diff(j),
    };
    match result {
        Ok(m) => {
            let mut env = envelope(id);
            env.insert("ok".to_string(), Json::Bool(true));
            env.extend(m);
            Json::Obj(env).render()
        }
        Err(msg) => error_line(id, &msg),
    }
}

/// Open the store file named by the request's `db` field. Query/diff
/// refuse to invent an empty store from a mistyped path; only ingest
/// creates the file.
fn open_store(j: &Json, create: bool) -> Result<ExperimentStore, String> {
    let db = j.get("db").and_then(Json::as_str).ok_or("store ops need a 'db' file path")?;
    if !create && !Path::new(db).exists() {
        return Err(format!("store file '{db}' does not exist"));
    }
    ExperimentStore::open(db).map_err(|e| e.to_string())
}

/// `store_ingest`: `{op, db, commit, files: [path...]}` and/or an
/// inline `doc`. Responds with how many records were written (0 =
/// everything already stored byte-identically).
fn store_ingest(j: &Json) -> Result<BTreeMap<String, Json>, String> {
    let mut store = open_store(j, true)?;
    let commit = j
        .get("commit")
        .and_then(Json::as_str)
        .ok_or("'store_ingest' needs a 'commit' string")?
        .to_string();
    let mut written = 0usize;
    let mut files = 0usize;
    if let Some(v) = j.get("files") {
        for f in v.as_arr().ok_or("'files' must be an array of paths")? {
            let path = f.as_str().ok_or("'files' must contain path strings")?;
            written += store.ingest_file(path, &commit).map_err(|e| e.to_string())?;
            files += 1;
        }
    }
    if let Some(doc) = j.get("doc") {
        written += store.ingest_json(doc, &commit).map_err(|e| e.to_string())?;
    } else if files == 0 {
        return Err("'store_ingest' needs 'files' and/or an inline 'doc'".to_string());
    }
    store.commit().map_err(|e| e.to_string())?;
    let mut m = BTreeMap::new();
    m.insert("ingested".to_string(), Json::Num(written as f64));
    m.insert("files".to_string(), Json::Num(files as f64));
    m.insert("records".to_string(), Json::Num(store.len() as f64));
    Ok(m)
}

/// `store_query`: `{op, db, schema?, figure?, commit?, model?,
/// metric?}` — the record catalog, or with `metric` the metric's
/// trajectory across commits. The response report renders through the
/// ordinary Report pipeline, byte-deterministically.
fn store_query(j: &Json) -> Result<BTreeMap<String, Json>, String> {
    let mut store = open_store(j, false)?;
    let field = |k: &str| j.get(k).and_then(Json::as_str).map(str::to_string);
    let filter = QueryFilter {
        schema: field("schema"),
        id: field("figure"),
        commit: field("commit"),
        model: field("model"),
        metric: field("metric"),
    };
    let report = store.query(&filter).map_err(|e| e.to_string())?;
    let mut m = BTreeMap::new();
    m.insert("report".to_string(), report.to_json());
    Ok(m)
}

/// `store_diff`: `{op, db, figure, from, to}` — compare one document
/// between two commits (per-metric deltas, or Pareto-dominance
/// classification for frontiers).
fn store_diff(j: &Json) -> Result<BTreeMap<String, Json>, String> {
    let mut store = open_store(j, false)?;
    let need = |k: &str| -> Result<String, String> {
        j.get(k)
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| format!("'store_diff' needs a '{k}' string"))
    };
    let report = store
        .diff(&need("figure")?, &need("from")?, &need("to")?)
        .map_err(|e| e.to_string())?;
    let mut m = BTreeMap::new();
    m.insert("report".to_string(), report.to_json());
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::cache::DEFAULT_CACHE_CAP;
    use crate::trace::synthetic::clustered_bitmap;
    use crate::util::rng::Rng;

    fn service(jobs: usize) -> Service {
        Service::new(Engine::new(jobs), Arc::new(UnitCache::new(DEFAULT_CACHE_CAP)))
    }

    fn report_field(line: &str) -> Json {
        let j = Json::parse(line).expect("response parses");
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "response not ok: {line}");
        j.get("report").expect("response carries a report").clone()
    }

    #[test]
    fn simulate_response_is_deterministic_and_cache_served() {
        let s = service(2);
        let req = r#"{"op":"simulate","id":"r1","model":"gcn","epoch":0.4,"samples":1,"seed":7}"#;
        let first = s.handle_line(req);
        assert_eq!(first.lines.len(), 1);
        assert!(!first.shutdown);
        let second = s.handle_line(req);
        // The report body is byte-identical warm vs cold; only the
        // cache envelope moves.
        assert_eq!(
            report_field(&first.lines[0]).render(),
            report_field(&second.lines[0]).render()
        );
        let stats = s.cache().stats();
        assert!(stats.hits > 0, "second request must be cache-served: {stats:?}");
        assert_eq!(stats.misses, stats.inserts);
    }

    #[test]
    fn batch_coalesces_duplicate_requests_into_one_computation() {
        let s = service(2);
        let line = concat!(
            r#"{"op":"batch","requests":["#,
            r#"{"op":"simulate","id":"a","model":"gcn","samples":1,"seed":7},"#,
            r#"{"op":"simulate","id":"b","model":"gcn","samples":1,"seed":7}"#,
            r#"]}"#,
        );
        let h = s.handle_line(line);
        assert_eq!(h.lines.len(), 2, "one response line per sub-request");
        assert_eq!(
            report_field(&h.lines[0]).render(),
            report_field(&h.lines[1]).render(),
            "duplicate sub-requests must be byte-identical"
        );
        let stats = s.cache().stats();
        assert!(stats.coalesced > 0, "duplicates must coalesce: {stats:?}");
        // Responses carry their ids in order.
        assert_eq!(Json::parse(&h.lines[0]).unwrap().get("id").unwrap().as_str(), Some("a"));
        assert_eq!(Json::parse(&h.lines[1]).unwrap().get("id").unwrap().as_str(), Some("b"));
    }

    #[test]
    fn malformed_lines_answer_in_band_errors() {
        let s = service(1);
        let bad = s.handle_line("{nope");
        assert_eq!(bad.lines.len(), 1);
        let j = Json::parse(&bad.lines[0]).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
        let unknown = s.handle_line(r#"{"op":"simulate","id":9,"model":"resnet5O"}"#);
        let j = Json::parse(&unknown.lines[0]).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(j.get("id").unwrap().as_f64(), Some(9.0));
        assert!(j.get("error").unwrap().as_str().unwrap().contains("unknown model"));
    }

    #[test]
    fn sweep_reports_one_row_per_cell_in_request_order() {
        let s = service(2);
        let line = r#"{"op":"sweep","models":["alexnet","gcn"],"samples":1,"seed":5}"#;
        let h = s.handle_line(line);
        let report = report_field(&h.lines[0]);
        let r = Report::from_json(&report).expect("sweep report reconstructs");
        assert_eq!(r.rows.len(), 2);
        assert_eq!(r.rows[0].cells[0].text, "alexnet");
        assert_eq!(r.rows[1].cells[0].text, "gcn");
        // Profiles were loaded once into the artifact store.
        assert_eq!(s.artifacts().loaded().0, 2);
        let again = s.handle_line(line);
        assert_eq!(report_field(&again.lines[0]).render(), report.render());
        assert_eq!(s.artifacts().loaded().0, 2, "profiles load once per model");
    }

    #[test]
    fn trace_artifact_round_trips_and_serves() {
        let mut rng = Rng::new(3);
        let shape = ConvShape::conv(1, 4, 4, 16, 16, 3, 1, 1);
        let a = clustered_bitmap((1, 4, 4, 16), 0.6, 0.35, &mut rng);
        let g = clustered_bitmap((1, 4, 4, 16), 0.6, 0.35, &mut rng);
        let artifact = TraceArtifact::new("tiny", vec![shape], vec![(a, g)]);
        // JSON round trip.
        let back = TraceArtifact::from_json(&artifact.to_json()).expect("trace reconstructs");
        assert_eq!(back.name, "tiny");
        assert_eq!(back.shapes, artifact.shapes);
        assert_eq!(back.layers, artifact.layers);
        // Disk round trip through the store (loaded once).
        let path = std::env::temp_dir().join(format!("td_trace_{}.json", std::process::id()));
        artifact.save(&path).unwrap();
        let s = service(1);
        let line = format!(
            r#"{{"op":"trace","id":"t","path":"{}","samples":1,"seed":3}}"#,
            path.display()
        );
        let h1 = s.handle_line(&line);
        let h2 = s.handle_line(&line);
        assert_eq!(
            report_field(&h1.lines[0]).render(),
            report_field(&h2.lines[0]).render()
        );
        assert_eq!(s.artifacts().loaded().1, 1, "trace file loads once");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn explore_op_returns_a_deterministic_frontier_and_shares_the_cache() {
        use crate::api::FRONTIER_SCHEMA;
        let s = service(2);
        // alexnet: fig 19's sparsity regime, so the depth_ordered gate
        // has a real margin (gcn is the no-sparsity control).
        let line = concat!(
            r#"{"op":"explore","id":"e","models":["alexnet"],"budget":3,"samples":1,"seed":7,"#,
            r#""axes":{"staging_depth":[2,3],"tile_rows":[2,4]}}"#,
        );
        let h1 = s.handle_line(line);
        assert_eq!(h1.lines.len(), 1);
        let r1 = report_field(&h1.lines[0]);
        let rep = Report::from_json(&r1).expect("frontier report reconstructs");
        assert_eq!(rep.schema, FRONTIER_SCHEMA);
        assert!(!rep.rows.is_empty(), "frontier must not be empty");
        assert_eq!(rep.meta.get("depth_ordered").and_then(Json::as_f64), Some(1.0));
        // Warm repeat: the whole report (rows + meta) is byte-identical;
        // only the cache envelope moves.
        let h2 = s.handle_line(line);
        assert_eq!(report_field(&h2.lines[0]).render(), r1.render());
        let stats = s.cache().stats();
        assert!(stats.hits > 0, "explore must share units through the cache: {stats:?}");
        // Bad requests answer in-band.
        let bad = s.handle_line(r#"{"op":"explore","id":9}"#);
        let j = Json::parse(&bad.lines[0]).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn stats_reports_deterministic_latency_percentiles() {
        let s = service(1);
        // Record a few cheap requests, then read the summary.
        s.handle_line(r#"{"op":"stats"}"#);
        s.handle_line(r#"{"op":"stats"}"#);
        s.handle_line(r#"{"op":"stats"}"#);
        let h = s.handle_line(r#"{"op":"stats","id":"s"}"#);
        let j = Json::parse(&h.lines[0]).unwrap();
        let lat = j.get("latency").expect("stats carries a latency block");
        assert_eq!(lat.get("count").unwrap().as_f64(), Some(3.0));
        let p50 = lat.get("p50_ns").unwrap().as_f64().unwrap();
        let p99 = lat.get("p99_ns").unwrap().as_f64().unwrap();
        let max = lat.get("max_ns").unwrap().as_f64().unwrap();
        assert!(p50 <= p99 && p99 <= max, "percentiles must be ordered: {p50} {p99} {max}");
        assert!(max > 0.0, "a handled line takes nonzero time");
    }

    #[test]
    fn store_ops_ingest_query_and_diff_over_the_protocol() {
        let name = format!("td_serve_store_{}.tdstore", std::process::id());
        let db = std::env::temp_dir().join(name);
        let _ = std::fs::remove_file(&db);
        let s = service(1);
        let mut fig = Report::new("fig13", "Demo", &["model", "overall"]);
        fig.row(vec![Cell::text("alexnet"), Cell::num(2.0)]);
        let doc1 = fig.to_json().render();
        let mut fig2 = Report::new("fig13", "Demo", &["model", "overall"]);
        fig2.row(vec![Cell::text("alexnet"), Cell::num(2.5)]);
        let doc2 = fig2.to_json().render();
        let db_s = db.display();
        for (commit, doc) in [("c1", &doc1), ("c2", &doc2)] {
            let line = format!(
                r#"{{"op":"store_ingest","db":"{db_s}","commit":"{commit}","doc":{doc}}}"#
            );
            let h = s.handle_line(&line);
            let j = Json::parse(&h.lines[0]).unwrap();
            assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{}", h.lines[0]);
            assert_eq!(j.get("ingested").unwrap().as_f64(), Some(1.0));
        }
        // Trajectory query: both commits' values in ingestion order.
        let q = format!(r#"{{"op":"store_query","db":"{db_s}","metric":"overall"}}"#);
        let h = s.handle_line(&q);
        let r = Report::from_json(Json::parse(&h.lines[0]).unwrap().get("report").unwrap())
            .expect("query report reconstructs");
        assert_eq!(r.rows.len(), 2);
        assert_eq!(r.value(0, "overall"), Some(2.0));
        assert_eq!(r.value(1, "overall"), Some(2.5));
        // Diff between the two commits.
        let d = format!(
            r#"{{"op":"store_diff","db":"{db_s}","figure":"fig13","from":"c1","to":"c2"}}"#
        );
        let h = s.handle_line(&d);
        let r = Report::from_json(Json::parse(&h.lines[0]).unwrap().get("report").unwrap())
            .expect("diff report reconstructs");
        assert_eq!(r.value(0, "delta"), Some(0.5));
        // Query on a missing store answers in-band, creating nothing.
        let missing = s.handle_line(r#"{"op":"store_query","db":"/nonexistent/x.tdstore"}"#);
        let j = Json::parse(&missing.lines[0]).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
        let _ = std::fs::remove_file(&db);
    }

    #[test]
    fn shutdown_acks_and_stops_the_line_loop() {
        let s = service(1);
        let input = b"{\"op\":\"stats\"}\n{\"op\":\"shutdown\"}\n{\"op\":\"stats\"}\n" as &[u8];
        let mut out = Vec::new();
        s.serve_lines(input, &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2, "nothing after the shutdown ack: {text}");
        let ack = Json::parse(lines[1]).unwrap();
        assert_eq!(ack.get("bye"), Some(&Json::Bool(true)));
    }

    #[test]
    fn latency_reservoir_is_bounded_with_exact_count_and_max() {
        let mut r = LatReservoir::default();
        let total = (LAT_RESERVOIR_CAP as u64) * 2 + 123;
        for ns in 1..=total {
            r.record(ns);
        }
        assert_eq!(r.count, total, "count stays exact past the ring capacity");
        assert_eq!(r.max_ns, total, "max stays exact past the ring capacity");
        assert_eq!(r.ring.len(), LAT_RESERVOIR_CAP, "memory is bounded");
        // The ring retains exactly the most recent CAP samples.
        let oldest = total - LAT_RESERVOIR_CAP as u64;
        assert!(r.ring.iter().all(|&v| v > oldest), "only recent samples retained");
        let sum: u64 = r.ring.iter().sum();
        let expect: u64 = (oldest + 1..=total).sum();
        assert_eq!(sum, expect, "ring holds each recent sample exactly once");
    }

    #[test]
    fn tcp_worker_pool_keeps_order_sheds_past_depth_and_shuts_down() {
        use std::io::{BufRead, BufReader, Write};

        let s = service(1);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::scope(|scope| {
            // workers=1, queue_depth=1: one connection in service, one
            // queued, the next one shed.
            let server = scope.spawn(|| s.serve_listener(listener, 1, 1));

            let connect = || {
                let c = TcpStream::connect(addr).unwrap();
                c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
                c
            };
            // Connection A is picked up by the single worker; three
            // pipelined requests come back in request order.
            let a = connect();
            let mut a_r = BufReader::new(a.try_clone().unwrap());
            let mut a_w = a;
            for id in 1..=3 {
                a_w.write_all(format!("{{\"op\":\"stats\",\"id\":{id}}}\n").as_bytes()).unwrap();
            }
            for want in 1..=3 {
                let mut line = String::new();
                a_r.read_line(&mut line).unwrap();
                let j = Json::parse(&line).unwrap();
                assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{line}");
                assert_eq!(j.get("id").unwrap().as_f64(), Some(want as f64), "in order: {line}");
            }
            // B fills the queue (the worker still owns A) ...
            let b = connect();
            std::thread::sleep(Duration::from_millis(300));
            // ... so C is shed with an explicit in-protocol error.
            let c = connect();
            let mut c_r = BufReader::new(c);
            let mut line = String::new();
            c_r.read_line(&mut line).unwrap();
            let j = Json::parse(&line).unwrap();
            assert_eq!(j.get("ok"), Some(&Json::Bool(false)), "shed response: {line}");
            assert!(
                j.get("error").unwrap().as_str().unwrap().contains("overloaded"),
                "shed response names the overload: {line}"
            );
            // Shutdown over A acks, unblocks the accept thread and the
            // queued-but-unserved B, and joins the server cleanly.
            a_w.write_all(b"{\"op\":\"shutdown\"}\n").unwrap();
            let mut line = String::new();
            a_r.read_line(&mut line).unwrap();
            assert_eq!(Json::parse(&line).unwrap().get("bye"), Some(&Json::Bool(true)));
            let mut b_r = BufReader::new(b);
            let mut b_line = String::new();
            // B either gets the shutting-down refusal or a clean EOF.
            let n = b_r.read_line(&mut b_line).unwrap();
            if n > 0 {
                let j = Json::parse(&b_line).unwrap();
                assert_eq!(j.get("ok"), Some(&Json::Bool(false)), "{b_line}");
            }
            server.join().unwrap().unwrap();
        });
    }
}
