//! One parameter-parsing path for the CLI and the serve protocol.
//!
//! Before this module, `simulate --depth 9` and
//! `{"op":"simulate","depth":9}` went through two hand-written parsers
//! that had already drifted: the serve path validated chip geometry
//! (positive rows/cols, depth ∈ {2,3}) while the CLI accepted anything
//! and crashed deep inside a worker; the explore budget defaulted to 8
//! over the wire and 12 on the CLI; seeds were range-checked in one
//! place and not the other. [`ParamSource`] abstracts *where* a value
//! comes from — a parsed JSON request object or a parsed `--flag`
//! vector — and the typed getters below parse every shared parameter
//! (chip axes, samples, seed, epoch, budget, booleans) through one
//! code path, so names, defaults and validation cannot diverge again.
//!
//! Error text is shared as a template; only the *spelling* of the
//! parameter differs per source (`'rows'` in a serve response,
//! `--rows` in a CLI error), keeping serve's v1 error bytes intact —
//! the exact strings are pinned by tests below.
//!
//! Two deliberate semantic notes:
//!
//! * JSON numbers keep their historical v1 coercion: an integral-typed
//!   parameter given `2.9` truncates to `2`, exactly as
//!   [`Json::as_usize`] always did, so existing clients see identical
//!   behaviour. Decimal *strings* are accepted everywhere a number is
//!   (they are the CLI's native form) but must parse exactly.
//! * Canonical parameter names are snake_case (`power_gate`); the CLI
//!   spelling is the kebab-case flag (`--power-gate`). The mapping is
//!   mechanical, never per-parameter.

use crate::config::{ChipConfig, DataType};
use crate::sparsity::Regime;
use crate::util::cli::Args;
use crate::util::json::Json;

/// Default seed shared by every subcommand and serve op.
pub const DEFAULT_SEED: u64 = 42;
/// Default unique-candidate budget for `explore`, CLI and serve alike.
/// (Pre-unification the serve op defaulted to 8 — the CLI's 12 wins.)
pub const DEFAULT_EXPLORE_BUDGET: usize = 12;

/// One parameter value as a source surfaced it, before typing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ParamValue<'a> {
    Num(f64),
    Str(&'a str),
    Bool(bool),
    /// Present but of an un-coercible shape (array, object, null).
    Other,
}

/// Anything parameters can be read from. `name` is always the
/// canonical snake_case parameter name; the source maps it to its own
/// spelling (JSON key, `--kebab-case` flag).
pub trait ParamSource {
    /// The raw value for `name`, or `None` when absent.
    fn value(&self, name: &str) -> Option<ParamValue<'_>>;
    /// How this source spells `name` in error messages.
    fn spell(&self, name: &str) -> String;
}

impl ParamSource for Json {
    fn value(&self, name: &str) -> Option<ParamValue<'_>> {
        Some(match self.get(name)? {
            Json::Num(n) => ParamValue::Num(*n),
            Json::Str(s) => ParamValue::Str(s),
            Json::Bool(b) => ParamValue::Bool(*b),
            _ => ParamValue::Other,
        })
    }

    fn spell(&self, name: &str) -> String {
        format!("'{name}'")
    }
}

impl ParamSource for Args {
    fn value(&self, name: &str) -> Option<ParamValue<'_>> {
        let key = name.replace('_', "-");
        if let Some(v) = self.get(&key) {
            return Some(ParamValue::Str(v));
        }
        if self.flag(&key) {
            return Some(ParamValue::Bool(true));
        }
        None
    }

    fn spell(&self, name: &str) -> String {
        format!("--{}", name.replace('_', "-"))
    }
}

/// An integer parameter. JSON numbers truncate (v1 coercion); strings
/// must parse exactly.
pub fn get_usize<S: ParamSource + ?Sized>(
    src: &S,
    name: &str,
    default: usize,
) -> Result<usize, String> {
    match src.value(name) {
        None => Ok(default),
        Some(ParamValue::Num(n)) => Ok(n as usize),
        Some(ParamValue::Str(s)) => {
            s.parse().map_err(|_| format!("{} must be a number", src.spell(name)))
        }
        Some(_) => Err(format!("{} must be a number", src.spell(name))),
    }
}

/// A float parameter; strings parse as decimals.
pub fn get_f64<S: ParamSource + ?Sized>(
    src: &S,
    name: &str,
    default: f64,
) -> Result<f64, String> {
    match src.value(name) {
        None => Ok(default),
        Some(ParamValue::Num(n)) => Ok(n),
        Some(ParamValue::Str(s)) => {
            s.parse().map_err(|_| format!("{} must be a number", src.spell(name)))
        }
        Some(_) => Err(format!("{} must be a number", src.spell(name))),
    }
}

/// A boolean parameter. A bare CLI flag is `true`; anything that is
/// not a real boolean is rejected rather than guessed at.
pub fn get_bool<S: ParamSource + ?Sized>(
    src: &S,
    name: &str,
    default: bool,
) -> Result<bool, String> {
    match src.value(name) {
        None => Ok(default),
        Some(ParamValue::Bool(b)) => Ok(b),
        Some(_) => Err(format!("{} must be a boolean", src.spell(name))),
    }
}

/// An epoch-fraction parameter. Every sparsity profile (and every
/// schedule curve) is defined on the training-run fraction [0, 1];
/// values outside it used to sail through and silently clamp deep in
/// the generator, so both paths now reject them up front with the same
/// wording.
pub fn get_epoch<S: ParamSource + ?Sized>(
    src: &S,
    name: &str,
    default: f64,
) -> Result<f64, String> {
    let e = get_f64(src, name, default)?;
    if !(0.0..=1.0).contains(&e) {
        return Err(format!("{} must be within [0, 1]", src.spell(name)));
    }
    Ok(e)
}

/// The sparsity-regime parameter (absent = `uniform`, the historical
/// generator). The value is the regime's canonical spelling —
/// `uniform`, `nm:N:M` or `schedule:<curve>` — validated up front
/// (N > M, block size > 16, malformed curves) with identical wording on
/// the CLI and over the wire.
pub fn get_regime<S: ParamSource + ?Sized>(src: &S) -> Result<Regime, String> {
    match src.value("regime") {
        None => Ok(Regime::Uniform),
        Some(ParamValue::Str(s)) => {
            Regime::parse(s).map_err(|msg| format!("{} {msg}", src.spell("regime")))
        }
        Some(_) => Err(format!("{} must be a string", src.spell("regime"))),
    }
}

/// The seed parameter. Seeds are u64 and must survive the protocol
/// exactly — JSON numbers ride through f64, which is only exact up to
/// 2^53, so numbers are accepted in that range only and larger seeds
/// travel as decimal strings (the same reason cache keys hex-encode
/// their seeds). The string form is also the CLI's native one.
pub fn get_seed<S: ParamSource + ?Sized>(src: &S, default: u64) -> Result<u64, String> {
    match src.value("seed") {
        None => Ok(default),
        Some(ParamValue::Num(v)) => {
            if v >= 0.0 && v <= 9.0e15 && v.trunc() == v {
                Ok(v as u64)
            } else {
                Err(format!(
                    "{} as a JSON number must be a non-negative integer <= 9e15; \
                     pass larger seeds as a decimal string",
                    src.spell("seed")
                ))
            }
        }
        Some(ParamValue::Str(s)) => s
            .parse::<u64>()
            .map_err(|_| format!("{} string '{s}' is not a u64", src.spell("seed"))),
        Some(_) => Err(format!("{} must be a number or a decimal string", src.spell("seed"))),
    }
}

/// Integer value of a chip-geometry parameter, with the v1 JSON
/// truncation; `None` when the shape cannot be a number at all.
fn dim(v: ParamValue<'_>) -> Option<usize> {
    match v {
        ParamValue::Num(n) => Some(n as usize),
        ParamValue::Str(s) => s.parse().ok(),
        _ => None,
    }
}

/// The shared chip-configuration parameters: `rows`, `cols`, `depth`,
/// `bf16`, `power_gate`, each defaulting to the paper's Table-2 value.
/// Zero geometry would divide-by-zero deep inside a worker and the
/// simulator hard-asserts depth ∈ {2,3}, so both are rejected here —
/// in-band for serve, before any simulation starts for the CLI (which
/// historically skipped this validation entirely).
pub fn chip_config<S: ParamSource + ?Sized>(src: &S) -> Result<ChipConfig, String> {
    let mut cfg = ChipConfig::default();
    if let Some(v) = src.value("rows") {
        cfg.tile_rows = match dim(v) {
            Some(r) if r >= 1 => r,
            _ => return Err(format!("{} must be a positive number", src.spell("rows"))),
        };
    }
    if let Some(v) = src.value("cols") {
        cfg.tile_cols = match dim(v) {
            Some(c) if c >= 1 => c,
            _ => return Err(format!("{} must be a positive number", src.spell("cols"))),
        };
    }
    if let Some(v) = src.value("depth") {
        let d = dim(v).ok_or_else(|| format!("{} must be a number", src.spell("depth")))?;
        if d != 2 && d != 3 {
            return Err(format!("{} must be 2 or 3", src.spell("depth")));
        }
        cfg.staging_depth = d;
    }
    if get_bool(src, "bf16", false)? {
        cfg.dtype = DataType::Bf16;
    }
    if src.value("power_gate").is_some() {
        cfg.power_gate = get_bool(src, "power_gate", false)?;
    }
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn json(s: &str) -> Json {
        Json::parse(s).expect("test json parses")
    }

    /// Parse a space-separated CLI line with the binary's known flags.
    fn cli(line: &str) -> Args {
        Args::parse_from(
            line.split_whitespace().map(str::to_string),
            &["bf16", "power-gate", "per-layer"],
        )
    }

    #[test]
    fn equivalent_sources_parse_identically() {
        let j = json(
            r#"{"rows":4,"cols":8,"depth":3,"bf16":true,"power_gate":true,
                "samples":3,"seed":"12345678901234567890","epoch":0.25}"#,
        );
        let a = cli(
            "--rows 4 --cols 8 --depth 3 --bf16 --power-gate \
             --samples 3 --seed 12345678901234567890 --epoch 0.25",
        );
        assert_eq!(chip_config(&j).unwrap(), chip_config(&a).unwrap());
        assert_eq!(get_usize(&j, "samples", 1).unwrap(), get_usize(&a, "samples", 1).unwrap());
        assert_eq!(get_seed(&j, DEFAULT_SEED).unwrap(), get_seed(&a, DEFAULT_SEED).unwrap());
        assert_eq!(get_seed(&j, 0).unwrap(), 12345678901234567890u64);
        assert_eq!(get_f64(&j, "epoch", 0.0).unwrap(), get_f64(&a, "epoch", 0.0).unwrap());
        let cfg = chip_config(&j).unwrap();
        assert_eq!((cfg.tile_rows, cfg.tile_cols, cfg.staging_depth), (4, 8, 3));
        assert_eq!(cfg.dtype, DataType::Bf16);
        assert!(cfg.power_gate);
    }

    #[test]
    fn defaults_match_across_sources() {
        let j = json("{}");
        let a = cli("");
        assert_eq!(chip_config(&j).unwrap(), ChipConfig::default());
        assert_eq!(chip_config(&j).unwrap(), chip_config(&a).unwrap());
        assert_eq!(get_seed(&j, DEFAULT_SEED).unwrap(), get_seed(&a, DEFAULT_SEED).unwrap());
        assert_eq!(get_usize(&j, "budget", DEFAULT_EXPLORE_BUDGET).unwrap(), 12);
        assert!(!get_bool(&j, "per_layer", false).unwrap());
        assert!(!get_bool(&a, "per_layer", false).unwrap());
    }

    #[test]
    fn serve_error_bytes_stay_v1() {
        // These strings are the wire contract: each is pinned to the
        // exact pre-refactor serve error text.
        let err = |s: &str| -> String {
            let j = json(s);
            chip_config(&j).unwrap_err()
        };
        assert_eq!(err(r#"{"rows":0}"#), "'rows' must be a positive number");
        assert_eq!(err(r#"{"rows":"x"}"#), "'rows' must be a positive number");
        assert_eq!(err(r#"{"cols":-2}"#), "'cols' must be a positive number");
        assert_eq!(err(r#"{"depth":[2]}"#), "'depth' must be a number");
        assert_eq!(err(r#"{"depth":4}"#), "'depth' must be 2 or 3");
        assert_eq!(err(r#"{"bf16":1}"#), "'bf16' must be a boolean");
        assert_eq!(err(r#"{"power_gate":"yes"}"#), "'power_gate' must be a boolean");
        assert_eq!(
            get_usize(&json(r#"{"samples":true}"#), "samples", 1).unwrap_err(),
            "'samples' must be a number"
        );
        assert_eq!(
            get_seed(&json(r#"{"seed":1e16}"#), 0).unwrap_err(),
            "'seed' as a JSON number must be a non-negative integer <= 9e15; \
             pass larger seeds as a decimal string"
        );
        assert_eq!(
            get_seed(&json(r#"{"seed":"xyz"}"#), 0).unwrap_err(),
            "'seed' string 'xyz' is not a u64"
        );
        assert_eq!(
            get_seed(&json(r#"{"seed":[1]}"#), 0).unwrap_err(),
            "'seed' must be a number or a decimal string"
        );
        assert_eq!(
            get_bool(&json(r#"{"per_layer":3}"#), "per_layer", false).unwrap_err(),
            "'per_layer' must be a boolean"
        );
    }

    #[test]
    fn cli_spellings_use_kebab_flags() {
        // Same templates, CLI spelling; snake_case names map to
        // kebab-case flags mechanically.
        assert_eq!(chip_config(&cli("--rows 0")).unwrap_err(), "--rows must be a positive number");
        assert_eq!(chip_config(&cli("--depth 4")).unwrap_err(), "--depth must be 2 or 3");
        assert_eq!(chip_config(&cli("--depth huge")).unwrap_err(), "--depth must be a number");
        assert_eq!(
            get_seed(&cli("--seed not-a-number"), 0).unwrap_err(),
            "--seed string 'not-a-number' is not a u64"
        );
        let gated = chip_config(&cli("--power-gate")).unwrap();
        assert!(gated.power_gate, "power_gate maps to --power-gate");
    }

    #[test]
    fn epoch_bounds_share_wording_across_sources() {
        assert_eq!(get_epoch(&json(r#"{"epoch":0.4}"#), "epoch", 0.0).unwrap(), 0.4);
        assert_eq!(get_epoch(&json("{}"), "epoch", 0.4).unwrap(), 0.4);
        assert_eq!(get_epoch(&cli("--epoch 1"), "epoch", 0.0).unwrap(), 1.0);
        // Identical template, per-source spelling — the wire contract.
        assert_eq!(
            get_epoch(&json(r#"{"epoch":1.5}"#), "epoch", 0.0).unwrap_err(),
            "'epoch' must be within [0, 1]"
        );
        assert_eq!(
            get_epoch(&cli("--epoch -0.1"), "epoch", 0.0).unwrap_err(),
            "--epoch must be within [0, 1]"
        );
        assert_eq!(
            get_epoch(&json(r#"{"epoch":"x"}"#), "epoch", 0.0).unwrap_err(),
            "'epoch' must be a number"
        );
    }

    #[test]
    fn regime_parses_and_rejects_identically_across_sources() {
        use crate::sparsity::{MaskAxis, Regime};
        assert_eq!(get_regime(&json("{}")).unwrap(), Regime::Uniform);
        assert_eq!(get_regime(&cli("")).unwrap(), Regime::Uniform);
        assert_eq!(
            get_regime(&json(r#"{"regime":"nm:2:4"}"#)).unwrap(),
            Regime::NM { n: 2, m: 4, axis: MaskAxis::Channel }
        );
        assert_eq!(
            get_regime(&json(r#"{"regime":"nm:2:4"}"#)).unwrap(),
            get_regime(&cli("--regime nm:2:4")).unwrap()
        );
        // N > M is rejected up front, both paths, same predicate.
        assert_eq!(
            get_regime(&json(r#"{"regime":"nm:4:2"}"#)).unwrap_err(),
            "'regime' nm requires n <= m"
        );
        assert_eq!(
            get_regime(&cli("--regime nm:4:2")).unwrap_err(),
            "--regime nm requires n <= m"
        );
        assert_eq!(
            get_regime(&json(r#"{"regime":7}"#)).unwrap_err(),
            "'regime' must be a string"
        );
        assert_eq!(
            get_regime(&cli("--regime schedule:nope")).unwrap_err(),
            "--regime must name a schedule curve: flat, dense-u:<swing>, \
             pruned-reclaim:<boost> or piecewise:<e@f,...>"
        );
    }

    #[test]
    fn json_numbers_keep_v1_truncation_and_strings_widen() {
        // Historical v1 coercion: JSON numbers truncate toward zero.
        assert_eq!(get_usize(&json(r#"{"samples":2.9}"#), "samples", 0).unwrap(), 2);
        let cfg = chip_config(&json(r#"{"depth":2.5}"#)).unwrap();
        assert_eq!(cfg.staging_depth, 2);
        // Widening: numeric parameters now also accept decimal strings
        // over the wire (previously CLI-only).
        assert_eq!(get_usize(&json(r#"{"samples":"7"}"#), "samples", 0).unwrap(), 7);
        assert_eq!(get_f64(&json(r#"{"epoch":"0.5"}"#), "epoch", 0.0).unwrap(), 0.5);
        assert!(get_usize(&json(r#"{"samples":"2.9"}"#), "samples", 0).is_err());
    }
}
