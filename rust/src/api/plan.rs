//! Model plans: a [`SimRequest`] expanded into its deterministic
//! parallel unit graph.
//!
//! A model simulation is a sum over independent (layer, training-op)
//! *units* — the grain the paper itself reports (every figure aggregates
//! per-(layer, op) behaviour over nine models). [`ModelPlan::for_request`]
//! makes that structure explicit: one [`UnitSpec`] per layer ×
//! {Fwd, Igrad, Wgrad}, each carrying
//!
//! * a recipe for its operand bitmaps ([`UnitTensors`]) — either
//!   generated in-worker from the model's synthetic sparsity profile
//!   (so tensor generation parallelises with the cycle simulation) or
//!   explicit captured bitmaps shared via `Arc` across the op triplet;
//! * its own pass-sampling seed, derived with [`derive_seed`] from the
//!   request seed and the unit index. This replaces the old shared
//!   sequential RNG that made per-layer results depend on iteration
//!   order: units are now pure functions of their spec, so the engine
//!   may execute them in any order, on any worker, and the merged
//!   [`ModelSim`] is byte-identical for any `--jobs N`.
//!
//! The merge is a fold of [`ModelSim::merge_unit`] in unit (plan)
//! order — a deterministic reduction: integer cycle counters commute,
//! and the f64 energy sums are always added in the same order because
//! the executor re-assembles unit results by index before folding.
//!
//! The full per-unit vector survives the merge (`ModelSim::layers`), so
//! per-layer speedup/energy/bottleneck tables are a first-class report:
//! [`layers_report`] renders them under the `tensordash.layers.v1`
//! schema (CLI `--per-layer`).

use std::sync::{Arc, OnceLock};

use crate::config::ChipConfig;
use crate::conv::{ConvShape, TrainOp};
use crate::metrics::pct;
use crate::repro::ModelSim;
use crate::sim::unit::{simulate_unit, LayerOpSim};
use crate::sparsity::{self, Regime};
use crate::tensor::TensorBitmap;
use crate::trace::profiles::ModelProfile;
use crate::util::hash::bitmap_hash;

use super::report::{Cell, Report, LAYERS_SCHEMA};
use super::request::{derive_seed, SimRequest, Workload};

/// Where a unit's operand bitmaps come from.
#[derive(Debug, Clone)]
pub enum UnitTensors {
    /// Generated in-worker from the model's synthetic sparsity profile —
    /// deterministic in `(model, layer, epoch, bitmap_seed)`, so the
    /// generation cost parallelises along with the cycle simulation.
    /// The layer's op triplet shares one lazily-filled cache: whichever
    /// unit runs first generates the (A, G) pair, the other two reuse
    /// it (generation is pure, so the winner is irrelevant) — the
    /// serial path pays one generation per layer, exactly like the
    /// pre-plan walk.
    Profile {
        profile: Arc<ModelProfile>,
        epoch: f64,
        bitmap_seed: u64,
        /// Sparsity regime the generator applies on top of the profile
        /// (`Uniform` is exactly the historical generator).
        regime: Regime,
        bitmaps: Arc<OnceLock<(TensorBitmap, TensorBitmap)>>,
    },
    /// Captured-trace bitmaps: the whole step's layer vector shared by
    /// every unit without copying (the unit's `layer` indexes it).
    Trace { layers: Arc<Vec<(TensorBitmap, TensorBitmap)>> },
    /// Explicit bitmaps (single-op requests), shared across units
    /// without copying.
    Explicit { a: Arc<TensorBitmap>, g: Arc<TensorBitmap> },
}

/// The cache-key view of a unit's operand bitmaps: everything their
/// content depends on, with explicit/captured bitmaps collapsed to
/// content hashes. Profile bitmaps are deterministic in
/// `(model, layer, epoch, bitmap_seed)`, so keying the *recipe* lets a
/// cache hit skip generation too; the two hash variants are
/// interchangeable across [`UnitTensors::Trace`] and
/// [`UnitTensors::Explicit`] carriers by construction.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorRecipe {
    Profile { model: String, layer: usize, epoch: f64, bitmap_seed: u64, regime: Regime },
    Bitmaps { a: u64, g: u64 },
}

/// One independent simulation unit: a (layer, op) pair with everything
/// needed to execute it on any worker at any time.
#[derive(Debug, Clone)]
pub struct UnitSpec {
    /// Layer index within the plan (also the profile layer index).
    pub layer: usize,
    pub op: TrainOp,
    pub shape: ConvShape,
    pub tensors: UnitTensors,
    pub batch_mult: u64,
    /// Pass-sample budget (see `repro::DEFAULT_SAMPLES`).
    pub samples: usize,
    /// Per-unit pass-sampling seed (derived, order-independent).
    pub seed: u64,
}

impl UnitSpec {
    /// Execute this unit. Pure: depends only on the spec and `cfg`.
    pub fn execute(&self, cfg: &ChipConfig) -> LayerOpSim {
        let (a, g): (&TensorBitmap, &TensorBitmap) = match &self.tensors {
            UnitTensors::Profile { profile, epoch, bitmap_seed, regime, bitmaps } => {
                let pair = bitmaps.get_or_init(|| {
                    regime_bitmaps(profile, self.layer, *epoch, *bitmap_seed, regime)
                });
                (&pair.0, &pair.1)
            }
            UnitTensors::Trace { layers } => {
                let pair = &layers[self.layer];
                (&pair.0, &pair.1)
            }
            UnitTensors::Explicit { a, g } => (a.as_ref(), g.as_ref()),
        };
        simulate_unit(
            cfg,
            &self.shape,
            self.op,
            self.layer,
            a,
            g,
            self.samples,
            self.batch_mult,
            self.seed,
        )
    }

    /// The content recipe of this unit's operand bitmaps — the tensor
    /// fragment of its [`crate::api::UnitKey`]. Hashing captured and
    /// explicit bitmaps here (rather than in the key encoder) keeps the
    /// key layer free of tensor types.
    pub fn tensor_recipe(&self) -> TensorRecipe {
        match &self.tensors {
            UnitTensors::Profile { profile, epoch, bitmap_seed, regime, .. } => {
                TensorRecipe::Profile {
                    model: profile.name().to_string(),
                    layer: self.layer,
                    epoch: *epoch,
                    bitmap_seed: *bitmap_seed,
                    regime: regime.clone(),
                }
            }
            UnitTensors::Trace { layers } => {
                let (a, g) = &layers[self.layer];
                TensorRecipe::Bitmaps { a: bitmap_hash(a), g: bitmap_hash(g) }
            }
            UnitTensors::Explicit { a, g } => {
                TensorRecipe::Bitmaps { a: bitmap_hash(a), g: bitmap_hash(g) }
            }
        }
    }
}

/// A request lowered to its unit graph: the unit list plus the config
/// and label shared by every unit.
#[derive(Debug, Clone)]
pub struct ModelPlan {
    pub name: String,
    pub cfg: ChipConfig,
    pub units: Vec<UnitSpec>,
}

impl ModelPlan {
    /// Plan a synthetic-profile model simulation: one unit per
    /// layer × op, bitmaps generated in-worker from `profile`.
    pub fn profile(
        profile: &ModelProfile,
        epoch: f64,
        cfg: &ChipConfig,
        samples: usize,
        seed: u64,
    ) -> ModelPlan {
        Self::profile_shared(Arc::new(profile.clone()), epoch, cfg, samples, seed)
    }

    /// [`ModelPlan::profile`] over an already-shared profile: the
    /// serving layer's artifact store resolves each model once and
    /// every request's plan clones only the `Arc`.
    pub fn profile_shared(
        shared: Arc<ModelProfile>,
        epoch: f64,
        cfg: &ChipConfig,
        samples: usize,
        seed: u64,
    ) -> ModelPlan {
        Self::profile_regime(shared, epoch, Regime::Uniform, cfg, samples, seed)
    }

    /// [`ModelPlan::profile_shared`] under an explicit sparsity regime.
    /// Unit seeds and the bitmap seed are regime-independent, so every
    /// regime of a `(model, epoch, seed)` cell perturbs the *same* base
    /// tensors and results stay directly comparable.
    pub fn profile_regime(
        shared: Arc<ModelProfile>,
        epoch: f64,
        regime: Regime,
        cfg: &ChipConfig,
        samples: usize,
        seed: u64,
    ) -> ModelPlan {
        let profile = shared.as_ref();
        let batch_mult = profile.batch_mult();
        let mut plan = ModelPlan {
            name: profile.name().to_string(),
            cfg: cfg.clone(),
            units: Vec::with_capacity(profile.topology.layers.len() * TrainOp::ALL.len()),
        };
        for (i, layer) in profile.topology.layers.iter().enumerate() {
            // One shared lazy cache per layer: the op triplet generates
            // its (A, G) bitmaps once, whichever unit runs first.
            let bitmaps = Arc::new(OnceLock::new());
            for op in TrainOp::ALL {
                plan.units.push(UnitSpec {
                    layer: i,
                    op,
                    shape: layer.shape,
                    tensors: UnitTensors::Profile {
                        profile: Arc::clone(&shared),
                        epoch,
                        // The bitmap stream is keyed on (model, layer,
                        // epoch, seed) exactly as before the plan
                        // refactor — config sweeps still see identical
                        // tensors per (model, epoch) cell.
                        bitmap_seed: seed,
                        regime: regime.clone(),
                        bitmaps: Arc::clone(&bitmaps),
                    },
                    batch_mult,
                    samples,
                    seed: derive_seed(seed, plan_unit_key(i, op)),
                });
            }
        }
        plan
    }

    /// Plan a captured-trace simulation (the coordinator's path): one
    /// unit per conv layer × op over the real bitmaps the training step
    /// produced. The whole layer vector is shared by every unit via one
    /// `Arc` — no bitmap is copied.
    pub fn trace(
        name: &str,
        shapes: &[ConvShape],
        layers: Arc<Vec<(TensorBitmap, TensorBitmap)>>,
        cfg: &ChipConfig,
        samples: usize,
        seed: u64,
    ) -> ModelPlan {
        assert_eq!(shapes.len(), layers.len(), "trace shapes/layers mismatch");
        let mut plan = ModelPlan {
            name: name.to_string(),
            cfg: cfg.clone(),
            units: Vec::with_capacity(shapes.len() * TrainOp::ALL.len()),
        };
        for (i, shape) in shapes.iter().enumerate() {
            for op in TrainOp::ALL {
                plan.units.push(UnitSpec {
                    layer: i,
                    op,
                    shape: *shape,
                    tensors: UnitTensors::Trace { layers: Arc::clone(&layers) },
                    batch_mult: 1,
                    samples,
                    seed: derive_seed(seed, plan_unit_key(i, op)),
                });
            }
        }
        plan
    }

    /// Expand a request into its unit graph. Returns `None` for
    /// workloads that are inherently sequential (`RandomSparse` draws
    /// its tensors and passes from one rolling RNG stream; the engine
    /// keeps executing those as a single cell-level work item).
    pub fn for_request(req: &SimRequest) -> Option<ModelPlan> {
        match &req.workload {
            Workload::Profile { model, epoch, regime } => {
                // Unknown names are rejected at request-build time; an
                // invariant breach here should be loud.
                let p = ModelProfile::for_model(model)
                    .unwrap_or_else(|| panic!("unknown model '{model}' reached the planner"));
                let mut plan = ModelPlan::profile_regime(
                    Arc::new(p),
                    *epoch,
                    regime.clone(),
                    &req.cfg,
                    req.samples,
                    req.seed,
                );
                plan.name = req.label.clone();
                Some(plan)
            }
            Workload::ProfileShared { profile, epoch, regime } => {
                let mut plan = ModelPlan::profile_regime(
                    Arc::clone(profile),
                    *epoch,
                    regime.clone(),
                    &req.cfg,
                    req.samples,
                    req.seed,
                );
                plan.name = req.label.clone();
                Some(plan)
            }
            Workload::Trace { shapes, layers } => Some(ModelPlan::trace(
                &req.label,
                shapes,
                Arc::clone(layers),
                &req.cfg,
                req.samples,
                req.seed,
            )),
            Workload::SingleOp { shape, op, a, g, batch_mult } => {
                Some(ModelPlan {
                    name: req.label.clone(),
                    cfg: req.cfg.clone(),
                    units: vec![UnitSpec {
                        layer: 0,
                        op: *op,
                        shape: *shape,
                        tensors: UnitTensors::Explicit {
                            a: Arc::new(a.clone()),
                            g: Arc::new(g.clone()),
                        },
                        batch_mult: *batch_mult,
                        samples: req.samples,
                        // The request seed directly: a single-op request
                        // is its own unit, and this keeps the workload
                        // byte-identical to the pre-plan executor.
                        seed: req.seed,
                    }],
                })
            }
            Workload::RandomSparse { .. } => None,
        }
    }

    pub fn unit_count(&self) -> usize {
        self.units.len()
    }

    /// Execute every unit on the calling thread, merging in unit order —
    /// the serial reference the determinism tests pin the pooled
    /// executor against.
    pub fn execute_serial(&self) -> ModelSim {
        self.merge(self.units.iter().map(|u| u.execute(&self.cfg)))
    }

    /// Deterministically merge per-unit results produced *in plan
    /// order* (the executor re-assembles worker results by unit index
    /// before calling this, so f64 energy sums always fold in the same
    /// order).
    pub fn merge(&self, units: impl IntoIterator<Item = LayerOpSim>) -> ModelSim {
        let mut sim = ModelSim::empty(self.name.clone());
        for u in units {
            sim.merge_unit(&u);
        }
        sim
    }
}

/// The unit key fed to [`derive_seed`]: layer-major, op-minor — pinned,
/// because changing it silently would change every published report.
fn plan_unit_key(layer: usize, op: TrainOp) -> u64 {
    (layer * TrainOp::ALL.len() + op as usize) as u64
}

/// Generate one layer's (A, G) bitmaps under a sparsity regime — a pure
/// function of its arguments, so the op triplet's lazy cache may be
/// filled by whichever worker gets there first at any `--jobs`.
fn regime_bitmaps(
    profile: &ModelProfile,
    layer: usize,
    epoch: f64,
    seed: u64,
    regime: &Regime,
) -> (TensorBitmap, TensorBitmap) {
    match regime {
        Regime::Uniform => profile.layer_bitmaps(layer, epoch, seed),
        // The request's curve replaces the model's own trajectory; the
        // underlying RNG stream is unchanged, so scheduling a model
        // onto its own curve is bit-identical to Uniform.
        Regime::Schedule { curve } => {
            profile.layer_bitmaps_with_factor(layer, epoch, seed, curve.factor(epoch))
        }
        // Structured masks AND into the profile bitmaps; mask streams
        // are seeded per (seed, layer, tensor) — order-free.
        Regime::NM { n, m, .. } => {
            let (a, g) = profile.layer_bitmaps(layer, epoch, seed);
            (
                sparsity::apply_nm(&a, *n, *m, sparsity::nm_mask_seed(seed, layer as u64, 0)),
                sparsity::apply_nm(&g, *n, *m, sparsity::nm_mask_seed(seed, layer as u64, 1)),
            )
        }
    }
}

/// Render the per-unit breakdown of a merged [`ModelSim`] as a
/// `tensordash.layers.v1` report: one row per (layer, op) with cycle,
/// speedup, sparsity, energy and bottleneck columns. Layer labels
/// resolve through the model registry when `sim.name` is a known
/// profile; otherwise units are labelled `layer<N>`.
pub fn layers_report(sim: &ModelSim) -> Report {
    let names: Option<Vec<String>> = ModelProfile::for_model(&sim.name)
        .map(|p| p.topology.layers.iter().map(|l| l.name.clone()).collect());
    let mut r = Report::with_schema(
        LAYERS_SCHEMA,
        "layers",
        format!("{} — per-(layer, op) unit breakdown", sim.name),
        &[
            "layer",
            "op",
            "base cycles",
            "td cycles",
            "speedup",
            "B sparsity",
            "gated",
            "bottleneck",
            "base pJ",
            "td pJ",
            "energy eff",
        ],
    );
    for u in &sim.layers {
        let label = names
            .as_ref()
            .and_then(|n| n.get(u.layer).cloned())
            .unwrap_or_else(|| format!("layer{}", u.layer));
        r.row(vec![
            Cell::text(label),
            Cell::text(u.op.label()),
            Cell::fmt(u.base_chip_cycles.to_string(), u.base_chip_cycles as f64),
            Cell::fmt(u.td_chip_cycles.to_string(), u.td_chip_cycles as f64),
            Cell::num(u.speedup()),
            Cell::fmt(pct(u.b_sparsity), u.b_sparsity),
            Cell::text(if u.gated { "yes" } else { "-" }),
            Cell::text(u.bottleneck()),
            Cell::fmt(format!("{:.3e}", u.energy_base.total_pj()), u.energy_base.total_pj()),
            Cell::fmt(format!("{:.3e}", u.energy_td.total_pj()), u.energy_td.total_pj()),
            Cell::num(u.energy_efficiency()),
        ]);
    }
    r.meta_str("model", &sim.name);
    r.meta_num("units", sim.layers.len() as f64);
    r.meta_num("overall_speedup", sim.overall_speedup());
    r.meta_num("total_efficiency", sim.total_efficiency());
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn profile_plan_expands_layer_op_grid() {
        let p = ModelProfile::for_model("alexnet").unwrap();
        let plan = ModelPlan::profile(&p, 0.4, &ChipConfig::default(), 2, 42);
        assert_eq!(plan.unit_count(), p.topology.layers.len() * 3);
        // Layer-major, op-minor, with per-unit derived seeds.
        assert_eq!(plan.units[0].layer, 0);
        assert_eq!(plan.units[0].op, TrainOp::Fwd);
        assert_eq!(plan.units[4].layer, 1);
        assert_eq!(plan.units[4].op, TrainOp::Igrad);
        assert_eq!(plan.units[4].seed, derive_seed(42, 4));
        let seeds: std::collections::BTreeSet<u64> =
            plan.units.iter().map(|u| u.seed).collect();
        assert_eq!(seeds.len(), plan.unit_count(), "unit seeds must be distinct");
    }

    #[test]
    fn serial_execution_retains_per_unit_results() {
        let p = ModelProfile::for_model("gcn").unwrap();
        let plan = ModelPlan::profile(&p, 0.4, &ChipConfig::default(), 1, 7);
        let sim = plan.execute_serial();
        assert_eq!(sim.layers.len(), plan.unit_count());
        // The merged per-op sums equal the fold of the retained units.
        for op in TrainOp::ALL {
            let base: u64 = sim
                .layers
                .iter()
                .filter(|u| u.op == op)
                .map(|u| u.base_chip_cycles)
                .sum();
            assert_eq!(sim.per_op[op as usize].0, base, "{op:?}");
        }
    }

    #[test]
    fn unit_execution_is_order_independent() {
        let p = ModelProfile::for_model("gcn").unwrap();
        let plan = ModelPlan::profile(&p, 0.4, &ChipConfig::default(), 1, 3);
        // Execute in reverse order, merge in plan order: identical.
        let forward = plan.execute_serial();
        let mut rev: Vec<LayerOpSim> =
            plan.units.iter().rev().map(|u| u.execute(&plan.cfg)).collect();
        rev.reverse();
        let merged = plan.merge(rev);
        assert_eq!(forward.per_op, merged.per_op);
        assert_eq!(forward.layers, merged.layers);
        assert_eq!(
            forward.energy_td.total_pj().to_bits(),
            merged.energy_td.total_pj().to_bits()
        );
    }

    #[test]
    fn regime_reaches_units_and_recipes() {
        let nm = Regime::parse("nm:2:4").unwrap();
        let req = SimRequest::profile("gcn", 0.4, ChipConfig::default(), 1, 7)
            .unwrap()
            .with_regime(nm.clone());
        let plan = ModelPlan::for_request(&req).unwrap();
        for u in &plan.units {
            match u.tensor_recipe() {
                TensorRecipe::Profile { regime, .. } => assert_eq!(regime, nm),
                r => panic!("unexpected recipe {r:?}"),
            }
        }
        // Uniform and NM plans share unit seeds (regimes perturb the
        // same base tensors), but execute to different masked streams.
        let base = ModelPlan::for_request(&req.clone().with_regime(Regime::Uniform)).unwrap();
        for (a, b) in plan.units.iter().zip(&base.units) {
            assert_eq!(a.seed, b.seed);
        }
    }

    #[test]
    fn schedule_regime_on_own_curve_is_byte_identical() {
        let p = ModelProfile::for_model("alexnet").unwrap();
        let own = Regime::Schedule { curve: p.curve.clone() };
        let req = SimRequest::profile("alexnet", 0.3, ChipConfig::default(), 1, 11).unwrap();
        let uniform = ModelPlan::for_request(&req).unwrap().execute_serial();
        let scheduled = ModelPlan::for_request(&req.with_regime(own)).unwrap().execute_serial();
        assert_eq!(uniform.per_op, scheduled.per_op);
        assert_eq!(uniform.layers, scheduled.layers);
        assert_eq!(
            uniform.energy_td.total_pj().to_bits(),
            scheduled.energy_td.total_pj().to_bits()
        );
    }

    #[test]
    fn random_sparse_requests_stay_monolithic() {
        let shape = ConvShape::conv(2, 8, 8, 32, 32, 3, 1, 1);
        let req = SimRequest::random_sparse(shape, 0.5, 1, 1, ChipConfig::default(), 2, 5);
        assert!(ModelPlan::for_request(&req).is_none());
    }

    #[test]
    fn layers_report_is_schema_tagged_and_renders_everywhere() {
        let p = ModelProfile::for_model("gcn").unwrap();
        let sim = ModelPlan::profile(&p, 0.4, &ChipConfig::default(), 1, 9).execute_serial();
        let r = layers_report(&sim);
        assert_eq!(r.schema, LAYERS_SCHEMA);
        assert_eq!(r.rows.len(), sim.layers.len());
        // Text, JSON and CSV renderers all accept it; JSON round-trips.
        let text = r.render_text();
        assert!(text.contains("per-(layer, op)"));
        let json = r.render_json();
        let parsed = Json::parse(&json).unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some(LAYERS_SCHEMA));
        let back = Report::from_json(&parsed).unwrap();
        assert_eq!(back, r);
        let csv = r.render_csv();
        assert!(csv.starts_with("layer,op,"));
        assert_eq!(csv.lines().count(), sim.layers.len() + 1);
        // Named layers resolve through the registry for profile sims.
        assert!(r.rows[0].cells[0].text != "layer0");
    }
}
