//! Content-addressed cache of per-unit simulation results.
//!
//! PR 3 made every (layer, op) unit a pure function of
//! `(UnitSpec, derived seed, ChipConfig)`; this module exploits that
//! purity. A [`UnitKey`] is the *canonical JSON* of everything a unit's
//! result depends on — chip config, op, layer geometry, sampling
//! budget, derived seed, and a content hash of the operand bitmaps —
//! prefixed with a version tag and hashed with FNV-1a. Two units with
//! equal keys are byte-interchangeable, so:
//!
//! * sweep cells that share units (the Fig. 17 `rows4` column *is* the
//!   Fig. 18 `cols4` column; Fig. 19's `depth3` arm *is* the default
//!   config) are computed once per process, not once per figure;
//! * a serving loop ([`super::service`]) answers repeated design-space
//!   queries (HASS-style search) from the cache instead of
//!   re-simulating, and coalesces identical units that are in flight
//!   concurrently.
//!
//! **What is deliberately *not* in the key:** the unit's `layer` index
//! (it only labels the result; [`UnitCache`] callers re-stamp it on a
//! hit, so two layers with identical geometry/tensors/seed share one
//! entry) and the request `label` (presentation only). Everything else
//! — *every* `ChipConfig` field included — must be serialized here;
//! **adding a field to `ChipConfig` or changing any serialization
//! detail requires bumping [`UNIT_KEY_VERSION`]**, or stale disk
//! entries would silently alias new configurations. The golden-key
//! test below pins the canonical bytes and the hash so accidental
//! drift fails loudly.
//!
//! The store itself is a mutex-guarded LRU (`cap` entries, stamp-based
//! eviction, counters for hit/miss/insert/evict/coalesce telemetry)
//! with an optional on-disk mirror backed by the single-file
//! [`RecordLog`](crate::store::RecordLog) (`units.tdstore` under the
//! cache directory): entries are keyed by the full canonical key
//! string, so a (cosmically unlikely) 64-bit hash collision reads as a
//! miss, never as a wrong answer, and a warm start restores the whole
//! mirror from one compacted in-file index instead of opening
//! thousands of per-key files. The mirror is single-writer per file —
//! one process owns a cache directory at a time. In-flight coalescing
//! uses one `OnceLock` per missing key: concurrent computations of the
//! same unit block on the first and share its result.

use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::sync::{Arc, Mutex, OnceLock};

use crate::config::{ChipConfig, DataType, SparsitySide};
use crate::conv::{ConvShape, TrainOp};
use crate::energy::EnergyBreakdown;
use crate::sim::stream::CacheStats;
use crate::sim::unit::LayerOpSim;
use crate::store::{LogStats, RecordLog};
use crate::util::json::Json;

use super::plan::{UnitSpec, UnitTensors};
use super::report::Report;

/// Version tag embedded in every canonical key. Bump on **any** change
/// to the key serialization, `ChipConfig`'s field set, or the unit
/// pipeline's observable behaviour — the disk store self-invalidates
/// because old entries hash under the old version string.
pub const UNIT_KEY_VERSION: &str = "tensordash.unitkey.v1";

/// Schema tag of the per-unit documents in the disk mirror.
pub const UNIT_CACHE_SCHEMA: &str = "tensordash.unitcache.v1";

/// File name of the record log holding a cache directory's mirror.
pub const UNIT_CACHE_FILE: &str = "units.tdstore";

/// Default in-memory capacity (units, not bytes — a `LayerOpSim` is a
/// small `Copy` struct, so 64k entries is a few MiB).
pub const DEFAULT_CACHE_CAP: usize = 65_536;

// ---------------------------------------------------------------------
// Stable hashing — shared with the search candidate encoder
// ---------------------------------------------------------------------

/// Re-exported from [`crate::util::hash`]: the cache keys and the
/// design-space search candidate ids hash through one module, so the
/// two content-addressing schemes can never drift apart.
pub use crate::util::hash::{bitmap_hash, fnv1a64};

// ---------------------------------------------------------------------
// Canonical key serialization
// ---------------------------------------------------------------------

fn num(v: f64) -> Json {
    Json::Num(v)
}

/// u64 values (seeds, content hashes) exceed f64's 2^53 integer range,
/// so they serialize as fixed-width hex strings, never JSON numbers.
fn hex64(v: u64) -> Json {
    Json::Str(format!("{v:016x}"))
}

/// Canonical JSON of a chip configuration. Every field, sorted keys.
pub fn cfg_json(cfg: &ChipConfig) -> Json {
    let mut m = BTreeMap::new();
    m.insert("lanes".to_string(), num(cfg.lanes as f64));
    m.insert("staging_depth".to_string(), num(cfg.staging_depth as f64));
    m.insert("tile_rows".to_string(), num(cfg.tile_rows as f64));
    m.insert("tile_cols".to_string(), num(cfg.tile_cols as f64));
    m.insert("tiles".to_string(), num(cfg.tiles as f64));
    m.insert("freq_mhz".to_string(), num(cfg.freq_mhz as f64));
    let dtype = match cfg.dtype {
        DataType::Fp32 => "fp32",
        DataType::Bf16 => "bf16",
    };
    m.insert("dtype".to_string(), Json::Str(dtype.to_string()));
    let side = match cfg.side {
        SparsitySide::BSide => "b",
        SparsitySide::Both => "both",
    };
    m.insert("side".to_string(), Json::Str(side.to_string()));
    m.insert("sram_bank_bytes".to_string(), num(cfg.sram_bank_bytes as f64));
    m.insert("sram_banks".to_string(), num(cfg.sram_banks as f64));
    m.insert("spad_bytes".to_string(), num(cfg.spad_bytes as f64));
    m.insert("spad_banks".to_string(), num(cfg.spad_banks as f64));
    m.insert("transposers".to_string(), num(cfg.transposers as f64));
    m.insert("dram_gbps".to_string(), num(cfg.dram_gbps));
    m.insert("power_gate".to_string(), Json::Bool(cfg.power_gate));
    m.insert("lead_limit".to_string(), num(cfg.lead_limit as f64));
    m.insert("dram_gate".to_string(), Json::Bool(cfg.dram_gate));
    Json::Obj(m)
}

/// Canonical JSON of a layer geometry.
pub fn shape_json(s: &ConvShape) -> Json {
    let mut m = BTreeMap::new();
    m.insert("n".to_string(), num(s.n as f64));
    m.insert("h".to_string(), num(s.h as f64));
    m.insert("w".to_string(), num(s.w as f64));
    m.insert("c".to_string(), num(s.c as f64));
    m.insert("f".to_string(), num(s.f as f64));
    m.insert("kh".to_string(), num(s.kh as f64));
    m.insert("kw".to_string(), num(s.kw as f64));
    m.insert("stride".to_string(), num(s.stride as f64));
    m.insert("pad".to_string(), num(s.pad as f64));
    Json::Obj(m)
}

fn tensors_json(spec: &UnitSpec) -> Json {
    let mut m = BTreeMap::new();
    match &spec.tensors {
        // Profile bitmaps are deterministic in (model, layer, epoch,
        // seed) — key the *recipe*, so cache hits skip generation too.
        UnitTensors::Profile { profile, epoch, bitmap_seed, .. } => {
            m.insert("kind".to_string(), Json::Str("profile".to_string()));
            m.insert("model".to_string(), Json::Str(profile.name().to_string()));
            m.insert("layer".to_string(), num(spec.layer as f64));
            m.insert("epoch".to_string(), num(*epoch));
            m.insert("bitmap_seed".to_string(), hex64(*bitmap_seed));
        }
        // Captured/explicit bitmaps are content-addressed: equal bytes
        // hit regardless of which request carried them.
        UnitTensors::Trace { layers } => {
            let (a, g) = &layers[spec.layer];
            m.insert("kind".to_string(), Json::Str("bitmaps".to_string()));
            m.insert("a".to_string(), hex64(bitmap_hash(a)));
            m.insert("g".to_string(), hex64(bitmap_hash(g)));
        }
        UnitTensors::Explicit { a, g } => {
            m.insert("kind".to_string(), Json::Str("bitmaps".to_string()));
            m.insert("a".to_string(), hex64(bitmap_hash(a)));
            m.insert("g".to_string(), hex64(bitmap_hash(g)));
        }
    }
    Json::Obj(m)
}

/// The cache key of one unit under one chip configuration: the
/// canonical JSON document plus its FNV-1a hash. The map is keyed by
/// the hash; the canonical string rides along so lookups verify the
/// full key and a hash collision degrades to a miss.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct UnitKey {
    pub hash: u64,
    pub canon: String,
}

impl UnitKey {
    /// Build the canonical, versioned key for `spec` under `cfg`.
    pub fn for_unit(cfg: &ChipConfig, spec: &UnitSpec) -> UnitKey {
        let mut m = BTreeMap::new();
        m.insert("v".to_string(), Json::Str(UNIT_KEY_VERSION.to_string()));
        m.insert("cfg".to_string(), cfg_json(cfg));
        m.insert("op".to_string(), Json::Str(spec.op.label().to_string()));
        m.insert("shape".to_string(), shape_json(&spec.shape));
        m.insert("batch_mult".to_string(), num(spec.batch_mult as f64));
        m.insert("samples".to_string(), num(spec.samples as f64));
        m.insert("seed".to_string(), hex64(spec.seed));
        m.insert("tensors".to_string(), tensors_json(spec));
        let canon = Json::Obj(m).render();
        UnitKey { hash: fnv1a64(canon.as_bytes()), canon }
    }

}

// ---------------------------------------------------------------------
// Unit result (de)serialization — the on-disk store's payload
// ---------------------------------------------------------------------

fn energy_json(e: &EnergyBreakdown) -> Json {
    let mut m = BTreeMap::new();
    m.insert("core_pj".to_string(), num(e.core_pj));
    m.insert("overhead_pj".to_string(), num(e.overhead_pj));
    m.insert("sram_pj".to_string(), num(e.sram_pj));
    m.insert("spad_pj".to_string(), num(e.spad_pj));
    m.insert("dram_pj".to_string(), num(e.dram_pj));
    Json::Obj(m)
}

fn energy_from_json(j: &Json) -> Option<EnergyBreakdown> {
    Some(EnergyBreakdown {
        core_pj: j.get("core_pj")?.as_f64()?,
        overhead_pj: j.get("overhead_pj")?.as_f64()?,
        sram_pj: j.get("sram_pj")?.as_f64()?,
        spad_pj: j.get("spad_pj")?.as_f64()?,
        dram_pj: j.get("dram_pj")?.as_f64()?,
    })
}

/// Serialize one unit result. Cycle counters are JSON numbers — they
/// stay far below 2^53 in any realistic simulation (the f64 round trip
/// is exact there); energies round-trip bit-exactly through the
/// shortest-representation float writer.
pub fn unit_to_json(u: &LayerOpSim) -> Json {
    let mut m = BTreeMap::new();
    m.insert("layer".to_string(), num(u.layer as f64));
    m.insert("op".to_string(), Json::Str(u.op.label().to_string()));
    m.insert("base_chip_cycles".to_string(), num(u.base_chip_cycles as f64));
    m.insert("td_chip_cycles".to_string(), num(u.td_chip_cycles as f64));
    m.insert("dram_cycles".to_string(), num(u.dram_cycles as f64));
    m.insert("dram_bound".to_string(), Json::Bool(u.dram_bound));
    m.insert("energy_base".to_string(), energy_json(&u.energy_base));
    m.insert("energy_td".to_string(), energy_json(&u.energy_td));
    m.insert("b_sparsity".to_string(), num(u.b_sparsity));
    m.insert("gated".to_string(), Json::Bool(u.gated));
    let mut s = BTreeMap::new();
    s.insert("walks".to_string(), num(u.sched.walks as f64));
    s.insert("hits".to_string(), num(u.sched.hits as f64));
    s.insert("fast_paths".to_string(), num(u.sched.fast_paths as f64));
    s.insert("skipped_cycles".to_string(), num(u.sched.skipped_cycles as f64));
    m.insert("sched".to_string(), Json::Obj(s));
    Json::Obj(m)
}

fn op_from_label(s: &str) -> Option<TrainOp> {
    match s {
        "A*W" => Some(TrainOp::Fwd),
        "A*G" => Some(TrainOp::Igrad),
        "W*G" => Some(TrainOp::Wgrad),
        _ => None,
    }
}

pub fn unit_from_json(j: &Json) -> Option<LayerOpSim> {
    let s = j.get("sched")?;
    Some(LayerOpSim {
        layer: j.get("layer")?.as_usize()?,
        op: op_from_label(j.get("op")?.as_str()?)?,
        base_chip_cycles: j.get("base_chip_cycles")?.as_f64()? as u64,
        td_chip_cycles: j.get("td_chip_cycles")?.as_f64()? as u64,
        dram_cycles: j.get("dram_cycles")?.as_f64()? as u64,
        dram_bound: j.get("dram_bound")?.as_bool()?,
        energy_base: energy_from_json(j.get("energy_base")?)?,
        energy_td: energy_from_json(j.get("energy_td")?)?,
        b_sparsity: j.get("b_sparsity")?.as_f64()?,
        gated: j.get("gated")?.as_bool()?,
        sched: CacheStats {
            walks: s.get("walks")?.as_f64()? as u64,
            hits: s.get("hits")?.as_f64()? as u64,
            fast_paths: s.get("fast_paths")?.as_f64()? as u64,
            skipped_cycles: s.get("skipped_cycles")?.as_f64()? as u64,
        },
    })
}

// ---------------------------------------------------------------------
// Telemetry
// ---------------------------------------------------------------------

/// Unit-cache counters. `hits`/`misses` are counted by the engine's
/// deterministic lookup phase (so they are identical for any `--jobs`);
/// `coalesced` counts units that piggybacked on an identical unit
/// already pending — in the same batch or in flight on another request.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct UnitCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub inserts: u64,
    pub evictions: u64,
    pub coalesced: u64,
    /// Subset of `hits` that were promoted from the on-disk store.
    pub disk_hits: u64,
    /// Lookups that probed a configured disk mirror and found nothing
    /// (always 0 for a memory-only cache) — `misses` alone cannot tell
    /// a cold disk from no disk at all.
    pub disk_misses: u64,
}

impl UnitCacheStats {
    /// Counter deltas accumulated since an earlier snapshot.
    pub fn since(&self, before: &UnitCacheStats) -> UnitCacheStats {
        UnitCacheStats {
            hits: self.hits - before.hits,
            misses: self.misses - before.misses,
            inserts: self.inserts - before.inserts,
            evictions: self.evictions - before.evictions,
            coalesced: self.coalesced - before.coalesced,
            disk_hits: self.disk_hits - before.disk_hits,
            disk_misses: self.disk_misses - before.disk_misses,
        }
    }

    /// Fraction of lookups answered without computing.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("hits".to_string(), num(self.hits as f64));
        m.insert("misses".to_string(), num(self.misses as f64));
        m.insert("inserts".to_string(), num(self.inserts as f64));
        m.insert("evictions".to_string(), num(self.evictions as f64));
        m.insert("coalesced".to_string(), num(self.coalesced as f64));
        m.insert("disk_hits".to_string(), num(self.disk_hits as f64));
        m.insert("disk_misses".to_string(), num(self.disk_misses as f64));
        m.insert("hit_rate".to_string(), num(self.hit_rate()));
        Json::Obj(m)
    }

    /// Thread the counters into a report's meta block (`unit_cache_*`
    /// keys). Presentation only: the report's rows never depend on the
    /// cache, which is what keeps warm and cold runs byte-identical.
    pub fn annotate(&self, r: &mut Report) {
        r.meta_num("unit_cache_hits", self.hits as f64);
        r.meta_num("unit_cache_misses", self.misses as f64);
        r.meta_num("unit_cache_inserts", self.inserts as f64);
        r.meta_num("unit_cache_evictions", self.evictions as f64);
        r.meta_num("unit_cache_coalesced", self.coalesced as f64);
        r.meta_num("unit_cache_disk_hits", self.disk_hits as f64);
        r.meta_num("unit_cache_disk_misses", self.disk_misses as f64);
        r.meta_num("unit_cache_hit_rate", self.hit_rate());
    }
}

// ---------------------------------------------------------------------
// The cache
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
struct CachedUnit {
    canon: String,
    stamp: u64,
    sim: LayerOpSim,
}

#[derive(Debug, Default)]
struct Inner {
    /// hash -> entry; the entry's `canon` is verified on every lookup.
    map: HashMap<u64, CachedUnit>,
    /// LRU index: stamp -> hash. Oldest stamp evicts first.
    lru: BTreeMap<u64, u64>,
    clock: u64,
    stats: UnitCacheStats,
    /// Keys currently being computed: concurrent requests for the same
    /// unit block on the first computation's `OnceLock`. Keyed by the
    /// full canonical string — sharing a slot on a hash collision
    /// would hand one unit another's result, so hashes are not enough
    /// here.
    inflight: HashMap<String, Arc<OnceLock<LayerOpSim>>>,
}

/// Thread-safe LRU of per-unit results with an optional disk mirror.
/// Shared across requests (and service connections) via `Arc`.
#[derive(Debug)]
pub struct UnitCache {
    cap: usize,
    /// The record-log disk mirror. Its own mutex (not `inner`) so disk
    /// IO never blocks memory lookups on other threads.
    disk: Option<Mutex<RecordLog>>,
    inner: Mutex<Inner>,
}

impl UnitCache {
    pub fn new(cap: usize) -> UnitCache {
        UnitCache { cap: cap.max(1), disk: None, inner: Mutex::new(Inner::default()) }
    }

    /// Mirror entries to the `units.tdstore` record log under `dir`
    /// (created if missing). Entries persist across processes — the
    /// log is sealed with its in-file index when the cache drops, so
    /// the next process warm-starts from one indexed file — and the
    /// versioned key makes stale schemas read as misses.
    pub fn with_disk(mut self, dir: impl Into<PathBuf>) -> std::io::Result<UnitCache> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        self.disk = Some(Mutex::new(RecordLog::open(dir.join(UNIT_CACHE_FILE))?));
        Ok(self)
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> UnitCacheStats {
        self.inner.lock().unwrap().stats
    }

    /// Backend telemetry of the disk mirror (`None` for a memory-only
    /// cache): whether the last open took the indexed fast path, and
    /// how many record frames were read/appended through this handle.
    pub fn disk_stats(&self) -> Option<LogStats> {
        Some(self.disk.as_ref()?.lock().unwrap().stats())
    }

    /// Look one key up, counting a hit or a miss. Memory first, then
    /// the disk mirror (a disk hit is promoted into memory).
    pub fn lookup(&self, key: &UnitKey) -> Option<LayerOpSim> {
        {
            let mut g = self.inner.lock().unwrap();
            if let Some(sim) = Self::touch(&mut g, key) {
                g.stats.hits += 1;
                return Some(sim);
            }
        }
        if let Some(sim) = self.disk_load(key) {
            let mut g = self.inner.lock().unwrap();
            Self::insert_locked(&mut g, key, sim, self.cap, false);
            g.stats.hits += 1;
            g.stats.disk_hits += 1;
            return Some(sim);
        }
        let mut g = self.inner.lock().unwrap();
        g.stats.misses += 1;
        if self.disk.is_some() {
            g.stats.disk_misses += 1;
        }
        None
    }

    /// Insert a computed result (and mirror it to disk, best effort).
    pub fn insert(&self, key: &UnitKey, sim: LayerOpSim) {
        {
            let mut g = self.inner.lock().unwrap();
            Self::insert_locked(&mut g, key, sim, self.cap, true);
        }
        self.disk_store(key, &sim);
    }

    /// Record that a unit was served by piggybacking on an identical
    /// pending unit (the engine's deterministic batch-level dedupe).
    pub fn note_coalesced(&self) {
        self.inner.lock().unwrap().stats.coalesced += 1;
    }

    /// Compute-or-wait for a key that missed the lookup phase. If an
    /// identical unit is already in flight (another batch, another
    /// connection), block on its `OnceLock` and share the result;
    /// otherwise run `f`, publish, and insert. Does *not* count
    /// hits/misses — those belong to the deterministic lookup phase.
    pub fn compute_coalesced(&self, key: &UnitKey, f: impl FnOnce() -> LayerOpSim) -> LayerOpSim {
        let slot = {
            let mut g = self.inner.lock().unwrap();
            // Re-check under the lock: another request may have
            // completed this unit since our lookup phase ran.
            if let Some(sim) = Self::touch(&mut g, key) {
                return sim;
            }
            Arc::clone(g.inflight.entry(key.canon.clone()).or_default())
        };
        let mut ran = false;
        let sim = *slot.get_or_init(|| {
            ran = true;
            f()
        });
        {
            let mut g = self.inner.lock().unwrap();
            if ran {
                Self::insert_locked(&mut g, key, sim, self.cap, true);
                g.inflight.remove(&key.canon);
            } else {
                g.stats.coalesced += 1;
            }
        }
        if ran {
            self.disk_store(key, &sim);
        }
        sim
    }

    // -- internals ----------------------------------------------------

    /// Map lookup + LRU touch. Verifies the full canonical key, so a
    /// 64-bit collision reads as a miss.
    fn touch(g: &mut Inner, key: &UnitKey) -> Option<LayerOpSim> {
        let (old, sim) = match g.map.get(&key.hash) {
            Some(e) if e.canon == key.canon => (e.stamp, e.sim),
            _ => return None,
        };
        g.clock += 1;
        let fresh = g.clock;
        g.map.get_mut(&key.hash).expect("entry present").stamp = fresh;
        g.lru.remove(&old);
        g.lru.insert(fresh, key.hash);
        Some(sim)
    }

    fn insert_locked(g: &mut Inner, key: &UnitKey, sim: LayerOpSim, cap: usize, count: bool) {
        g.clock += 1;
        let stamp = g.clock;
        let entry = CachedUnit { canon: key.canon.clone(), stamp, sim };
        if let Some(prev) = g.map.insert(key.hash, entry) {
            g.lru.remove(&prev.stamp);
        }
        g.lru.insert(stamp, key.hash);
        if count {
            g.stats.inserts += 1;
        }
        while g.map.len() > cap {
            let (old, hash) = {
                let (k, v) = g.lru.iter().next().expect("lru tracks every entry");
                (*k, *v)
            };
            g.lru.remove(&old);
            g.map.remove(&hash);
            g.stats.evictions += 1;
        }
    }

    /// Look `key` up in the record-log mirror. The log stores entries
    /// under the full canonical key string (and re-verifies it on every
    /// frame read), so hash collisions and stale key versions both read
    /// as misses.
    fn disk_load(&self, key: &UnitKey) -> Option<LayerOpSim> {
        let log = self.disk.as_ref()?;
        let text = log.lock().unwrap().get(&key.canon).ok()??;
        let j = Json::parse(&text).ok()?;
        if j.get("schema")?.as_str()? != UNIT_CACHE_SCHEMA {
            return None;
        }
        unit_from_json(j.get("unit")?)
    }

    fn disk_store(&self, key: &UnitKey, sim: &LayerOpSim) {
        let Some(log) = &self.disk else { return };
        let mut m = BTreeMap::new();
        m.insert("schema".to_string(), Json::Str(UNIT_CACHE_SCHEMA.to_string()));
        m.insert("unit".to_string(), unit_to_json(sim));
        let text = Json::Obj(m).render();
        let mut g = log.lock().unwrap();
        // Idempotent: re-computing a unit already mirrored (promotion
        // races, repeated runs) must not grow the log.
        if g.get(&key.canon).ok().flatten().as_deref() == Some(text.as_str()) {
            return;
        }
        // Best effort: a full disk degrades to a memory-only cache.
        let _ = g.append(&key.canon, &text);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::TensorBitmap;
    use std::sync::Arc;

    fn explicit_spec(seed: u64, samples: usize, layer: usize) -> UnitSpec {
        let a = TensorBitmap::from_raw((1, 1, 1, 16), vec![0x00FF]);
        let g = TensorBitmap::from_raw((1, 1, 1, 16), vec![0x0F0F]);
        UnitSpec {
            layer,
            op: TrainOp::Fwd,
            shape: ConvShape::conv(1, 4, 4, 16, 16, 3, 1, 1),
            tensors: UnitTensors::Explicit { a: Arc::new(a), g: Arc::new(g) },
            batch_mult: 1,
            samples,
            seed,
        }
    }

    /// A real (small) unit result to cache in the tests below.
    fn small_unit(seed: u64) -> (UnitKey, LayerOpSim) {
        let cfg = ChipConfig::default();
        let spec = explicit_spec(seed, 2, 0);
        let key = UnitKey::for_unit(&cfg, &spec);
        (key, spec.execute(&cfg))
    }

    #[test]
    fn golden_key_pins_canonical_bytes_and_hash() {
        // Any change to the key schema, the canonical JSON writer, the
        // hex encoding, `ChipConfig`'s defaults or its field
        // serialization shows up here first. If this test fails and
        // the change is intentional, bump UNIT_KEY_VERSION.
        let key = UnitKey::for_unit(&ChipConfig::default(), &explicit_spec(42, 2, 0));
        let golden = concat!(
            "{\"batch_mult\":1,\"cfg\":{\"dram_gate\":false,\"dram_gbps\":51.2,",
            "\"dtype\":\"fp32\",\"freq_mhz\":500,\"lanes\":16,\"lead_limit\":6,",
            "\"power_gate\":false,\"side\":\"b\",\"spad_banks\":3,\"spad_bytes\":1024,",
            "\"sram_bank_bytes\":262144,\"sram_banks\":4,\"staging_depth\":3,",
            "\"tile_cols\":4,\"tile_rows\":4,\"tiles\":16,\"transposers\":15},",
            "\"op\":\"A*W\",\"samples\":2,\"seed\":\"000000000000002a\",",
            "\"shape\":{\"c\":16,\"f\":16,\"h\":4,\"kh\":3,\"kw\":3,\"n\":1,",
            "\"pad\":1,\"stride\":1,\"w\":4},",
            "\"tensors\":{\"a\":\"cab5d030f0dd4d63\",\"g\":\"c9a5fd30eff666aa\",",
            "\"kind\":\"bitmaps\"},\"v\":\"tensordash.unitkey.v1\"}",
        );
        assert_eq!(key.canon, golden);
        assert_eq!(key.hash, fnv1a64(golden.as_bytes()));
    }

    #[test]
    fn key_ignores_layer_but_tracks_everything_else() {
        let cfg = ChipConfig::default();
        let base = UnitKey::for_unit(&cfg, &explicit_spec(42, 2, 0));
        // The layer index only labels the result; identical geometry +
        // tensors + seed share one entry.
        assert_eq!(base, UnitKey::for_unit(&cfg, &explicit_spec(42, 2, 7)));
        // Everything result-relevant changes the key.
        assert_ne!(base.canon, UnitKey::for_unit(&cfg, &explicit_spec(43, 2, 0)).canon);
        assert_ne!(base.canon, UnitKey::for_unit(&cfg, &explicit_spec(42, 3, 0)).canon);
        let depth2 = ChipConfig::default().with_depth(2);
        assert_ne!(base.canon, UnitKey::for_unit(&depth2, &explicit_spec(42, 2, 0)).canon);
    }

    #[test]
    fn unit_result_json_round_trips_bit_exactly() {
        let (_, sim) = small_unit(11);
        let text = unit_to_json(&sim).render_pretty();
        let back = unit_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, sim);
        assert_eq!(back.energy_td.total_pj().to_bits(), sim.energy_td.total_pj().to_bits());
        assert_eq!(back.sched, sim.sched);
    }

    #[test]
    fn lookup_hits_after_insert_and_counts_stats() {
        let cache = UnitCache::new(8);
        let (key, sim) = small_unit(1);
        assert!(cache.lookup(&key).is_none());
        cache.insert(&key, sim);
        assert_eq!(cache.lookup(&key), Some(sim));
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.inserts), (1, 1, 1));
    }

    #[test]
    fn lru_evicts_least_recently_used_first() {
        let cache = UnitCache::new(2);
        let (k1, s1) = small_unit(1);
        let (k2, s2) = small_unit(2);
        let (k3, s3) = small_unit(3);
        cache.insert(&k1, s1);
        cache.insert(&k2, s2);
        // Touch k1 so k2 becomes the LRU victim.
        assert!(cache.lookup(&k1).is_some());
        cache.insert(&k3, s3);
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats().evictions, 1);
        assert!(cache.lookup(&k2).is_none(), "LRU entry must be evicted");
        assert!(cache.lookup(&k1).is_some());
        assert!(cache.lookup(&k3).is_some());
    }

    #[test]
    fn capacity_is_enforced_under_bulk_inserts() {
        let cache = UnitCache::new(4);
        for seed in 0..10u64 {
            let (k, s) = small_unit(seed);
            cache.insert(&k, s);
        }
        assert_eq!(cache.len(), 4);
        assert_eq!(cache.stats().evictions, 6);
        // The four most recent survive.
        for seed in 6..10u64 {
            let (k, _) = small_unit(seed);
            assert!(cache.lookup(&k).is_some(), "seed {seed} should be resident");
        }
    }

    #[test]
    fn compute_coalesced_runs_each_key_once() {
        let cache = UnitCache::new(8);
        let (key, _) = small_unit(5);
        let mut runs = 0usize;
        let first = cache.compute_coalesced(&key, || {
            runs += 1;
            small_unit(5).1
        });
        let second = cache.compute_coalesced(&key, || {
            runs += 1;
            small_unit(5).1
        });
        assert_eq!(runs, 1, "second call must be served from the cache");
        assert_eq!(first, second);
        assert_eq!(cache.stats().inserts, 1);
    }

    #[test]
    fn disk_store_round_trips_across_cache_instances() {
        let dir = std::env::temp_dir().join(format!("td_unitcache_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let (key, sim) = small_unit(9);
        {
            let cache = UnitCache::new(8).with_disk(&dir).unwrap();
            // A cold disk-backed cache records the disk probe failure.
            assert!(cache.lookup(&key).is_none());
            let s = cache.stats();
            assert_eq!((s.misses, s.disk_misses), (1, 1));
            cache.insert(&key, sim);
        }
        let cache = UnitCache::new(8).with_disk(&dir).unwrap();
        // Warm start restores the mirror's in-file index without a scan.
        assert!(cache.disk_stats().unwrap().fast_path, "reopen must take the indexed path");
        assert_eq!(cache.lookup(&key), Some(sim), "disk mirror must survive the process");
        let s = cache.stats();
        assert_eq!((s.hits, s.disk_hits, s.disk_misses), (1, 1, 0));
        // Promoted into memory: the second lookup is a pure memory hit.
        assert_eq!(cache.lookup(&key), Some(sim));
        assert_eq!(cache.stats().disk_hits, 1);
        // The whole mirror is one record log, not per-key files.
        let files: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
        assert_eq!(files.len(), 1);
        assert_eq!(files[0].as_ref().unwrap().file_name(), UNIT_CACHE_FILE);
        // Memory-only caches never count disk misses (and report no
        // disk telemetry at all).
        let mem = UnitCache::new(8);
        assert!(mem.lookup(&key).is_none());
        assert_eq!(mem.stats().disk_misses, 0);
        assert!(mem.disk_stats().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn disk_store_is_idempotent_per_unit() {
        let dir = std::env::temp_dir().join(format!("td_unitcache_idem_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let (key, sim) = small_unit(13);
        let cache = UnitCache::new(8).with_disk(&dir).unwrap();
        cache.insert(&key, sim);
        cache.insert(&key, sim);
        cache.insert(&key, sim);
        assert_eq!(
            cache.disk_stats().unwrap().appends,
            1,
            "re-inserting an identical unit must not grow the log"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stats_since_subtracts_snapshots() {
        let cache = UnitCache::new(8);
        let (key, sim) = small_unit(3);
        cache.insert(&key, sim);
        let before = cache.stats();
        assert!(cache.lookup(&key).is_some());
        let delta = cache.stats().since(&before);
        assert_eq!((delta.hits, delta.misses, delta.inserts), (1, 0, 0));
        assert!((delta.hit_rate() - 1.0).abs() < 1e-12);
    }
}
