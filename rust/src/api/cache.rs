//! Content-addressed cache of per-unit simulation results.
//!
//! PR 3 made every (layer, op) unit a pure function of
//! `(UnitSpec, derived seed, ChipConfig)`; this module exploits that
//! purity. A [`UnitKey`] is a *fixed-layout binary encoding* (the v3
//! key format — versioned magic, little-endian fields) of everything a
//! unit's result depends on — chip config, op, layer geometry, sampling
//! budget, derived seed, the sparsity regime, and a content hash of the
//! operand bitmaps —
//! hashed with FNV-1a over the bytes. The canonical JSON document of
//! the same content is *derived* from the bytes ([`UnitKey::canon`])
//! and only materialises at the disk-mirror boundary; the hot lookup
//! path never serializes JSON. Two units with equal keys are
//! byte-interchangeable, so:
//!
//! * sweep cells that share units (the Fig. 17 `rows4` column *is* the
//!   Fig. 18 `cols4` column; Fig. 19's `depth3` arm *is* the default
//!   config) are computed once per process, not once per figure;
//! * a serving loop ([`super::service`]) answers repeated design-space
//!   queries (HASS-style search) from the cache instead of
//!   re-simulating, and coalesces identical units that are in flight
//!   concurrently.
//!
//! **What is deliberately *not* in the key:** the unit's `layer` index
//! (it only labels the result; [`UnitCache`] callers re-stamp it on a
//! hit, so two layers with identical geometry/tensors/seed share one
//! entry) and the request `label` (presentation only). Everything else
//! — *every* `ChipConfig` field included — must be serialized here;
//! **adding a field to `ChipConfig` or changing any encoding detail
//! requires bumping the binary format byte *and* [`UNIT_KEY_VERSION`]
//! together**, or stale disk entries would silently alias new
//! configurations. The golden-key test below pins the v3 bytes, the
//! hash and the derived canonical JSON so accidental drift fails
//! loudly.
//!
//! The store itself is a **lock-striped LRU**: `shards` independent
//! mutex-guarded stripes, each a stamp-based LRU over a proportional
//! slice of the total capacity (`ceil(cap / shards)` entries), with
//! counters for hit/miss/insert/evict/coalesce telemetry. A key's
//! stripe is `key.hash % shards` — deterministic, because the FNV-1a
//! hash is a pure function of the v3 key bytes — so concurrent serve
//! connections touching different units take different locks instead
//! of convoying on one global mutex. [`UnitCache::stats`] merges the
//! per-stripe counters by summation; since hits and misses are counted
//! in the engine's *serial* lookup phase and shard choice is
//! deterministic, the merged telemetry is byte-identical at any shard
//! count (while nothing evicts; see the shard-determinism tests).
//! `UnitCache::new` builds the single-shard (exact global LRU)
//! degenerate case; [`UnitCache::with_shards`] stripes it.
//!
//! The optional on-disk mirror is backed by the single-file
//! [`RecordLog`](crate::store::RecordLog) (`units.tdstore` under the
//! cache directory): entries are keyed by the full canonical key
//! string, so a (cosmically unlikely) 64-bit hash collision reads as a
//! miss, never as a wrong answer, and a warm start restores the whole
//! mirror from one compacted in-file index instead of opening
//! thousands of per-key files. The mirror is single-writer per file —
//! one process owns a cache directory at a time (shards share it; disk
//! IO already has its own lock). In-flight coalescing uses one
//! `OnceLock` per missing key, held in the key's own stripe:
//! concurrent computations of the same unit block on the first and
//! share its result.

use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::sync::{Arc, Mutex, OnceLock};

use crate::config::{ChipConfig, DataType, SparsitySide};
use crate::sparsity::{Curve, MaskAxis, Regime};
use crate::conv::{ConvShape, TrainOp};
use crate::energy::EnergyBreakdown;
use crate::sim::stream::CacheStats;
use crate::sim::unit::LayerOpSim;
use crate::store::{LogStats, RecordLog};
use crate::util::json::Json;

use super::plan::{TensorRecipe, UnitSpec};
use super::report::Report;

/// Version tag embedded in every canonical key document. Bump on
/// **any** change to the key encoding, `ChipConfig`'s field set, or the
/// unit pipeline's observable behaviour — the disk store
/// self-invalidates because old entries are stored under the old
/// version's canonical string. v3 = v2 plus the sparsity-regime tag in
/// profile recipes (v2 was the first fixed-layout binary encoding; v1
/// was canonical JSON built per lookup); v1 and v2 mirror entries both
/// read as clean misses under v3.
pub const UNIT_KEY_VERSION: &str = "tensordash.unitkey.v3";

/// Schema tag of the per-unit documents in the disk mirror.
pub const UNIT_CACHE_SCHEMA: &str = "tensordash.unitcache.v1";

/// File name of the record log holding a cache directory's mirror.
pub const UNIT_CACHE_FILE: &str = "units.tdstore";

/// Default in-memory capacity (units, not bytes — a `LayerOpSim` is a
/// small `Copy` struct, so 64k entries is a few MiB).
pub const DEFAULT_CACHE_CAP: usize = 65_536;

/// Default lock-stripe count for concurrent use (the `serve`
/// subcommand and `--cache` CLI runs). Enough stripes that 8-16
/// connections rarely collide, few enough that the per-stripe LRU
/// slices stay large. `UnitCache::new` stays single-shard for exact
/// global LRU semantics.
pub const DEFAULT_CACHE_SHARDS: usize = 8;

// ---------------------------------------------------------------------
// Stable hashing — shared with the search candidate encoder
// ---------------------------------------------------------------------

/// Re-exported from [`crate::util::hash`]: the cache keys and the
/// design-space search candidate ids hash through one module, so the
/// two content-addressing schemes can never drift apart.
pub use crate::util::hash::{bitmap_hash, fnv1a64};

// ---------------------------------------------------------------------
// Canonical key serialization
// ---------------------------------------------------------------------

fn num(v: f64) -> Json {
    Json::Num(v)
}

/// u64 values (seeds, content hashes) exceed f64's 2^53 integer range,
/// so they serialize as fixed-width hex strings, never JSON numbers.
fn hex64(v: u64) -> Json {
    Json::Str(format!("{v:016x}"))
}

/// Canonical JSON of a chip configuration. Every field, sorted keys.
pub fn cfg_json(cfg: &ChipConfig) -> Json {
    let mut m = BTreeMap::new();
    m.insert("lanes".to_string(), num(cfg.lanes as f64));
    m.insert("staging_depth".to_string(), num(cfg.staging_depth as f64));
    m.insert("tile_rows".to_string(), num(cfg.tile_rows as f64));
    m.insert("tile_cols".to_string(), num(cfg.tile_cols as f64));
    m.insert("tiles".to_string(), num(cfg.tiles as f64));
    m.insert("freq_mhz".to_string(), num(cfg.freq_mhz as f64));
    let dtype = match cfg.dtype {
        DataType::Fp32 => "fp32",
        DataType::Bf16 => "bf16",
    };
    m.insert("dtype".to_string(), Json::Str(dtype.to_string()));
    let side = match cfg.side {
        SparsitySide::BSide => "b",
        SparsitySide::Both => "both",
    };
    m.insert("side".to_string(), Json::Str(side.to_string()));
    m.insert("sram_bank_bytes".to_string(), num(cfg.sram_bank_bytes as f64));
    m.insert("sram_banks".to_string(), num(cfg.sram_banks as f64));
    m.insert("spad_bytes".to_string(), num(cfg.spad_bytes as f64));
    m.insert("spad_banks".to_string(), num(cfg.spad_banks as f64));
    m.insert("transposers".to_string(), num(cfg.transposers as f64));
    m.insert("dram_gbps".to_string(), num(cfg.dram_gbps));
    m.insert("power_gate".to_string(), Json::Bool(cfg.power_gate));
    m.insert("lead_limit".to_string(), num(cfg.lead_limit as f64));
    m.insert("dram_gate".to_string(), Json::Bool(cfg.dram_gate));
    Json::Obj(m)
}

/// Canonical JSON of a layer geometry.
pub fn shape_json(s: &ConvShape) -> Json {
    let mut m = BTreeMap::new();
    m.insert("n".to_string(), num(s.n as f64));
    m.insert("h".to_string(), num(s.h as f64));
    m.insert("w".to_string(), num(s.w as f64));
    m.insert("c".to_string(), num(s.c as f64));
    m.insert("f".to_string(), num(s.f as f64));
    m.insert("kh".to_string(), num(s.kh as f64));
    m.insert("kw".to_string(), num(s.kw as f64));
    m.insert("stride".to_string(), num(s.stride as f64));
    m.insert("pad".to_string(), num(s.pad as f64));
    Json::Obj(m)
}

/// Canonical JSON of a tensor recipe — the `tensors` fragment of the
/// canonical key document. Profile bitmaps key their generation recipe
/// (so cache hits skip generation too); captured/explicit bitmaps are
/// content-addressed, hitting regardless of which request carried them.
fn recipe_json(r: &TensorRecipe) -> Json {
    let mut m = BTreeMap::new();
    match r {
        TensorRecipe::Profile { model, layer, epoch, bitmap_seed, regime } => {
            m.insert("kind".to_string(), Json::Str("profile".to_string()));
            m.insert("model".to_string(), Json::Str(model.clone()));
            m.insert("layer".to_string(), num(*layer as f64));
            m.insert("epoch".to_string(), num(*epoch));
            m.insert("bitmap_seed".to_string(), hex64(*bitmap_seed));
            // The regime's canonical spelling: `render` round-trips
            // through `parse` exactly (floats use the shortest
            // representation), so one string is the whole encoding.
            m.insert("regime".to_string(), Json::Str(regime.render()));
        }
        TensorRecipe::Bitmaps { a, g } => {
            m.insert("kind".to_string(), Json::Str("bitmaps".to_string()));
            m.insert("a".to_string(), hex64(*a));
            m.insert("g".to_string(), hex64(*g));
        }
    }
    Json::Obj(m)
}

/// The full canonical key document for decoded/recipe form content.
fn canon_json(
    cfg: &ChipConfig,
    op: TrainOp,
    shape: &ConvShape,
    batch_mult: u64,
    samples: u64,
    seed: u64,
    recipe: &TensorRecipe,
) -> String {
    let mut m = BTreeMap::new();
    m.insert("v".to_string(), Json::Str(UNIT_KEY_VERSION.to_string()));
    m.insert("cfg".to_string(), cfg_json(cfg));
    m.insert("op".to_string(), Json::Str(op.label().to_string()));
    m.insert("shape".to_string(), shape_json(shape));
    m.insert("batch_mult".to_string(), num(batch_mult as f64));
    m.insert("samples".to_string(), num(samples as f64));
    m.insert("seed".to_string(), hex64(seed));
    m.insert("tensors".to_string(), recipe_json(recipe));
    Json::Obj(m).render()
}

/// The canonical JSON key document built *directly* from the spec —
/// the agreement oracle for the binary encoding: [`UnitKey::canon`]
/// (which decodes the v3 bytes) must return exactly this string for
/// every unit. Also the yardstick the `serve_hotpath` bench races the
/// binary encoder against.
pub fn canon_json_for_unit(cfg: &ChipConfig, spec: &UnitSpec) -> String {
    canon_json(
        cfg,
        spec.op,
        &spec.shape,
        spec.batch_mult,
        spec.samples as u64,
        spec.seed,
        &spec.tensor_recipe(),
    )
}

// ---------------------------------------------------------------------
// Binary v3 key encoding
// ---------------------------------------------------------------------
//
// Byte layout (DESIGN.md §4; all multi-byte integers little-endian):
//
//   magic   "TDK" + format byte (= 3)                          4 bytes
//   enums   op u8 | dtype u8 | side u8 | flags u8              4 bytes
//           (op: 0 Fwd, 1 Igrad, 2 Wgrad; dtype: 0 fp32, 1 bf16;
//            side: 0 b, 1 both; flags: bit0 power_gate,
//            bit1 dram_gate)
//   cfg     lanes, staging_depth, tile_rows, tile_cols, tiles,
//           lead_limit, freq_mhz, sram_bank_bytes, sram_banks,
//           spad_bytes, spad_banks, transposers          12 x u64
//           dram_gbps (f64 bit pattern)                       u64
//   shape   n, h, w, c, f, kh, kw, stride, pad            9 x u64
//   unit    batch_mult, samples, seed                     3 x u64
//   tensors kind u8 = 0 (profile): epoch (f64 bits) u64,
//             bitmap_seed u64, layer u64,
//             model-name byte length u32 + UTF-8 bytes,
//             regime tag u8 = 0 (uniform)
//                        u8 = 1 (nm): n u64, m u64, axis u8 (0 channel)
//                        u8 = 2 (schedule): curve tag u8 = 0 (flat)
//                          | 1 (dense-u): swing (f64 bits) u64
//                          | 2 (pruned-reclaim): boost (f64 bits) u64
//                          | 3 (piecewise): knot count u32,
//                              then per knot e, f (f64 bits) 2 x u64
//           kind u8 = 1 (bitmaps): a hash u64, g hash u64
//             (bitmaps are content-addressed; any regime's masks are
//              already baked into the hashes, so no regime tag here)
//
// The layout is self-contained: [`UnitKey::canon`] decodes it back to
// the canonical JSON document (needed only at the disk-mirror
// boundary). Any change here is a key-schema change: bump `KEY_FORMAT`
// *and* [`UNIT_KEY_VERSION`] together and repin the golden test.

const KEY_MAGIC: [u8; 3] = *b"TDK";
const KEY_FORMAT: u8 = 3;
const TENSORS_PROFILE: u8 = 0;
const TENSORS_BITMAPS: u8 = 1;
const REGIME_UNIFORM: u8 = 0;
const REGIME_NM: u8 = 1;
const REGIME_SCHEDULE: u8 = 2;
const AXIS_CHANNEL: u8 = 0;
const CURVE_FLAT: u8 = 0;
const CURVE_DENSE_U: u8 = 1;
const CURVE_PRUNED_RECLAIM: u8 = 2;
const CURVE_PIECEWISE: u8 = 3;

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append the regime's binary tag (profile recipes only — explicit
/// bitmaps are content-addressed and carry no regime).
fn encode_regime(out: &mut Vec<u8>, regime: &Regime) {
    match regime {
        Regime::Uniform => out.push(REGIME_UNIFORM),
        Regime::NM { n, m, axis } => {
            out.push(REGIME_NM);
            put_u64(out, *n as u64);
            put_u64(out, *m as u64);
            out.push(match axis {
                MaskAxis::Channel => AXIS_CHANNEL,
            });
        }
        Regime::Schedule { curve } => {
            out.push(REGIME_SCHEDULE);
            match curve {
                Curve::Flat => out.push(CURVE_FLAT),
                Curve::DenseU { swing } => {
                    out.push(CURVE_DENSE_U);
                    put_u64(out, swing.to_bits());
                }
                Curve::PrunedReclaim { start_boost } => {
                    out.push(CURVE_PRUNED_RECLAIM);
                    put_u64(out, start_boost.to_bits());
                }
                Curve::Piecewise { points } => {
                    out.push(CURVE_PIECEWISE);
                    out.extend_from_slice(&(points.len() as u32).to_le_bytes());
                    for (e, f) in points {
                        put_u64(out, e.to_bits());
                        put_u64(out, f.to_bits());
                    }
                }
            }
        }
    }
}

fn encode_key(cfg: &ChipConfig, spec: &UnitSpec) -> Vec<u8> {
    let mut b = Vec::with_capacity(256);
    b.extend_from_slice(&KEY_MAGIC);
    b.push(KEY_FORMAT);
    b.push(match spec.op {
        TrainOp::Fwd => 0,
        TrainOp::Igrad => 1,
        TrainOp::Wgrad => 2,
    });
    b.push(match cfg.dtype {
        DataType::Fp32 => 0,
        DataType::Bf16 => 1,
    });
    b.push(match cfg.side {
        SparsitySide::BSide => 0,
        SparsitySide::Both => 1,
    });
    b.push((cfg.power_gate as u8) | ((cfg.dram_gate as u8) << 1));
    for v in [
        cfg.lanes as u64,
        cfg.staging_depth as u64,
        cfg.tile_rows as u64,
        cfg.tile_cols as u64,
        cfg.tiles as u64,
        cfg.lead_limit as u64,
        cfg.freq_mhz,
        cfg.sram_bank_bytes,
        cfg.sram_banks,
        cfg.spad_bytes,
        cfg.spad_banks,
        cfg.transposers,
    ] {
        put_u64(&mut b, v);
    }
    put_u64(&mut b, cfg.dram_gbps.to_bits());
    let s = &spec.shape;
    for v in [s.n, s.h, s.w, s.c, s.f, s.kh, s.kw, s.stride, s.pad] {
        put_u64(&mut b, v as u64);
    }
    put_u64(&mut b, spec.batch_mult);
    put_u64(&mut b, spec.samples as u64);
    put_u64(&mut b, spec.seed);
    match spec.tensor_recipe() {
        TensorRecipe::Profile { model, layer, epoch, bitmap_seed, regime } => {
            b.push(TENSORS_PROFILE);
            put_u64(&mut b, epoch.to_bits());
            put_u64(&mut b, bitmap_seed);
            put_u64(&mut b, layer as u64);
            b.extend_from_slice(&(model.len() as u32).to_le_bytes());
            b.extend_from_slice(model.as_bytes());
            encode_regime(&mut b, &regime);
        }
        TensorRecipe::Bitmaps { a, g } => {
            b.push(TENSORS_BITMAPS);
            put_u64(&mut b, a);
            put_u64(&mut b, g);
        }
    }
    b
}

/// Sequential little-endian reader over a v3 key's payload bytes.
/// Panics on truncation — v3 bytes only come out of [`encode_key`]
/// within this process, so malformed input is an invariant breach.
struct KeyReader<'a> {
    b: &'a [u8],
}

impl<'a> KeyReader<'a> {
    fn u8(&mut self) -> u8 {
        let (v, rest) = self.b.split_first().expect("truncated v3 unit key");
        self.b = rest;
        *v
    }

    fn u32(&mut self) -> u32 {
        let (head, rest) = self.b.split_at(4);
        self.b = rest;
        u32::from_le_bytes(head.try_into().expect("4-byte field"))
    }

    fn u64(&mut self) -> u64 {
        let (head, rest) = self.b.split_at(8);
        self.b = rest;
        u64::from_le_bytes(head.try_into().expect("8-byte field"))
    }

    fn str(&mut self, len: usize) -> String {
        let (head, rest) = self.b.split_at(len);
        self.b = rest;
        String::from_utf8(head.to_vec()).expect("UTF-8 model name in v3 unit key")
    }
}

/// Inverse of [`encode_regime`].
fn decode_regime(r: &mut KeyReader) -> Regime {
    match r.u8() {
        REGIME_UNIFORM => Regime::Uniform,
        REGIME_NM => {
            let n = r.u64() as usize;
            let m = r.u64() as usize;
            let axis = match r.u8() {
                AXIS_CHANNEL => MaskAxis::Channel,
                k => panic!("bad mask-axis tag {k} in v3 unit key"),
            };
            Regime::NM { n, m, axis }
        }
        REGIME_SCHEDULE => {
            let curve = match r.u8() {
                CURVE_FLAT => Curve::Flat,
                CURVE_DENSE_U => Curve::DenseU { swing: f64::from_bits(r.u64()) },
                CURVE_PRUNED_RECLAIM => Curve::PrunedReclaim { start_boost: f64::from_bits(r.u64()) },
                CURVE_PIECEWISE => {
                    let count = r.u32() as usize;
                    let points = (0..count)
                        .map(|_| (f64::from_bits(r.u64()), f64::from_bits(r.u64())))
                        .collect();
                    Curve::Piecewise { points }
                }
                k => panic!("bad curve tag {k} in v3 unit key"),
            };
            Regime::Schedule { curve }
        }
        k => panic!("bad regime tag {k} in v3 unit key"),
    }
}

/// Decode a v3 key back into its content. Exactly inverts
/// [`encode_key`]; the agreement test pins the round trip.
#[allow(clippy::type_complexity)]
fn decode_key(bytes: &[u8]) -> (ChipConfig, TrainOp, ConvShape, u64, u64, u64, TensorRecipe) {
    assert!(
        bytes.len() > 4 && bytes[..3] == KEY_MAGIC && bytes[3] == KEY_FORMAT,
        "not a v3 unit key"
    );
    let mut r = KeyReader { b: &bytes[4..] };
    let op = match r.u8() {
        0 => TrainOp::Fwd,
        1 => TrainOp::Igrad,
        2 => TrainOp::Wgrad,
        k => panic!("bad op tag {k} in v3 unit key"),
    };
    let dtype = match r.u8() {
        0 => DataType::Fp32,
        1 => DataType::Bf16,
        k => panic!("bad dtype tag {k} in v3 unit key"),
    };
    let side = match r.u8() {
        0 => SparsitySide::BSide,
        1 => SparsitySide::Both,
        k => panic!("bad side tag {k} in v3 unit key"),
    };
    let flags = r.u8();
    let lanes = r.u64() as usize;
    let staging_depth = r.u64() as usize;
    let tile_rows = r.u64() as usize;
    let tile_cols = r.u64() as usize;
    let tiles = r.u64() as usize;
    let lead_limit = r.u64() as usize;
    let freq_mhz = r.u64();
    let sram_bank_bytes = r.u64();
    let sram_banks = r.u64();
    let spad_bytes = r.u64();
    let spad_banks = r.u64();
    let transposers = r.u64();
    let dram_gbps = f64::from_bits(r.u64());
    let cfg = ChipConfig {
        lanes,
        staging_depth,
        tile_rows,
        tile_cols,
        tiles,
        freq_mhz,
        dtype,
        side,
        sram_bank_bytes,
        sram_banks,
        spad_bytes,
        spad_banks,
        transposers,
        dram_gbps,
        power_gate: flags & 1 != 0,
        lead_limit,
        dram_gate: flags & 2 != 0,
    };
    let n = r.u64() as usize;
    let h = r.u64() as usize;
    let w = r.u64() as usize;
    let c = r.u64() as usize;
    let f = r.u64() as usize;
    let kh = r.u64() as usize;
    let kw = r.u64() as usize;
    let stride = r.u64() as usize;
    let pad = r.u64() as usize;
    let shape = ConvShape { n, h, w, c, f, kh, kw, stride, pad };
    let batch_mult = r.u64();
    let samples = r.u64();
    let seed = r.u64();
    let recipe = match r.u8() {
        TENSORS_PROFILE => {
            let epoch = f64::from_bits(r.u64());
            let bitmap_seed = r.u64();
            let layer = r.u64() as usize;
            let len = r.u32() as usize;
            let model = r.str(len);
            let regime = decode_regime(&mut r);
            TensorRecipe::Profile { model, layer, epoch, bitmap_seed, regime }
        }
        TENSORS_BITMAPS => TensorRecipe::Bitmaps { a: r.u64(), g: r.u64() },
        k => panic!("bad tensors tag {k} in v3 unit key"),
    };
    assert!(r.b.is_empty(), "trailing bytes in v3 unit key");
    (cfg, op, shape, batch_mult, samples, seed, recipe)
}

/// The cache key of one unit under one chip configuration: the v3
/// fixed-layout binary encoding plus its FNV-1a hash. The in-memory
/// map is keyed by the hash; the bytes ride along so lookups verify
/// the full key and a hash collision degrades to a miss. The canonical
/// JSON string is derived on demand ([`UnitKey::canon`]) for the disk
/// mirror only — building a key costs a few hundred byte writes, no
/// JSON rendering.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct UnitKey {
    pub hash: u64,
    pub bytes: Vec<u8>,
}

impl UnitKey {
    /// Build the binary, versioned key for `spec` under `cfg`.
    pub fn for_unit(cfg: &ChipConfig, spec: &UnitSpec) -> UnitKey {
        let bytes = encode_key(cfg, spec);
        UnitKey { hash: fnv1a64(&bytes), bytes }
    }

    /// The canonical JSON key document, decoded from the binary form —
    /// the disk mirror's record key (human-inspectable, and distinct
    /// per [`UNIT_KEY_VERSION`], so stale v1/v2 mirror entries read as
    /// clean misses). Panics on bytes not produced by
    /// [`UnitKey::for_unit`].
    pub fn canon(&self) -> String {
        let (cfg, op, shape, batch_mult, samples, seed, recipe) = decode_key(&self.bytes);
        canon_json(&cfg, op, &shape, batch_mult, samples, seed, &recipe)
    }
}

// ---------------------------------------------------------------------
// Unit result (de)serialization — the on-disk store's payload
// ---------------------------------------------------------------------

fn energy_json(e: &EnergyBreakdown) -> Json {
    let mut m = BTreeMap::new();
    m.insert("core_pj".to_string(), num(e.core_pj));
    m.insert("overhead_pj".to_string(), num(e.overhead_pj));
    m.insert("sram_pj".to_string(), num(e.sram_pj));
    m.insert("spad_pj".to_string(), num(e.spad_pj));
    m.insert("dram_pj".to_string(), num(e.dram_pj));
    Json::Obj(m)
}

fn energy_from_json(j: &Json) -> Option<EnergyBreakdown> {
    Some(EnergyBreakdown {
        core_pj: j.get("core_pj")?.as_f64()?,
        overhead_pj: j.get("overhead_pj")?.as_f64()?,
        sram_pj: j.get("sram_pj")?.as_f64()?,
        spad_pj: j.get("spad_pj")?.as_f64()?,
        dram_pj: j.get("dram_pj")?.as_f64()?,
    })
}

/// Serialize one unit result. Cycle counters are JSON numbers — they
/// stay far below 2^53 in any realistic simulation (the f64 round trip
/// is exact there); energies round-trip bit-exactly through the
/// shortest-representation float writer.
pub fn unit_to_json(u: &LayerOpSim) -> Json {
    let mut m = BTreeMap::new();
    m.insert("layer".to_string(), num(u.layer as f64));
    m.insert("op".to_string(), Json::Str(u.op.label().to_string()));
    m.insert("base_chip_cycles".to_string(), num(u.base_chip_cycles as f64));
    m.insert("td_chip_cycles".to_string(), num(u.td_chip_cycles as f64));
    m.insert("dram_cycles".to_string(), num(u.dram_cycles as f64));
    m.insert("dram_bound".to_string(), Json::Bool(u.dram_bound));
    m.insert("energy_base".to_string(), energy_json(&u.energy_base));
    m.insert("energy_td".to_string(), energy_json(&u.energy_td));
    m.insert("b_sparsity".to_string(), num(u.b_sparsity));
    m.insert("gated".to_string(), Json::Bool(u.gated));
    let mut s = BTreeMap::new();
    s.insert("walks".to_string(), num(u.sched.walks as f64));
    s.insert("hits".to_string(), num(u.sched.hits as f64));
    s.insert("fast_paths".to_string(), num(u.sched.fast_paths as f64));
    s.insert("skipped_cycles".to_string(), num(u.sched.skipped_cycles as f64));
    m.insert("sched".to_string(), Json::Obj(s));
    Json::Obj(m)
}

fn op_from_label(s: &str) -> Option<TrainOp> {
    match s {
        "A*W" => Some(TrainOp::Fwd),
        "A*G" => Some(TrainOp::Igrad),
        "W*G" => Some(TrainOp::Wgrad),
        _ => None,
    }
}

pub fn unit_from_json(j: &Json) -> Option<LayerOpSim> {
    let s = j.get("sched")?;
    Some(LayerOpSim {
        layer: j.get("layer")?.as_usize()?,
        op: op_from_label(j.get("op")?.as_str()?)?,
        base_chip_cycles: j.get("base_chip_cycles")?.as_f64()? as u64,
        td_chip_cycles: j.get("td_chip_cycles")?.as_f64()? as u64,
        dram_cycles: j.get("dram_cycles")?.as_f64()? as u64,
        dram_bound: j.get("dram_bound")?.as_bool()?,
        energy_base: energy_from_json(j.get("energy_base")?)?,
        energy_td: energy_from_json(j.get("energy_td")?)?,
        b_sparsity: j.get("b_sparsity")?.as_f64()?,
        gated: j.get("gated")?.as_bool()?,
        sched: CacheStats {
            walks: s.get("walks")?.as_f64()? as u64,
            hits: s.get("hits")?.as_f64()? as u64,
            fast_paths: s.get("fast_paths")?.as_f64()? as u64,
            skipped_cycles: s.get("skipped_cycles")?.as_f64()? as u64,
        },
    })
}

// ---------------------------------------------------------------------
// Telemetry
// ---------------------------------------------------------------------

/// Unit-cache counters. `hits`/`misses` are counted by the engine's
/// deterministic lookup phase (so they are identical for any `--jobs`);
/// `coalesced` counts units that piggybacked on an identical unit
/// already pending — in the same batch or in flight on another request.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct UnitCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub inserts: u64,
    pub evictions: u64,
    pub coalesced: u64,
    /// Subset of `hits` that were promoted from the on-disk store.
    pub disk_hits: u64,
    /// Lookups that probed a configured disk mirror and found nothing
    /// (always 0 for a memory-only cache) — `misses` alone cannot tell
    /// a cold disk from no disk at all.
    pub disk_misses: u64,
}

impl UnitCacheStats {
    /// Counter deltas accumulated since an earlier snapshot.
    pub fn since(&self, before: &UnitCacheStats) -> UnitCacheStats {
        UnitCacheStats {
            hits: self.hits - before.hits,
            misses: self.misses - before.misses,
            inserts: self.inserts - before.inserts,
            evictions: self.evictions - before.evictions,
            coalesced: self.coalesced - before.coalesced,
            disk_hits: self.disk_hits - before.disk_hits,
            disk_misses: self.disk_misses - before.disk_misses,
        }
    }

    /// Fraction of lookups answered without computing.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("hits".to_string(), num(self.hits as f64));
        m.insert("misses".to_string(), num(self.misses as f64));
        m.insert("inserts".to_string(), num(self.inserts as f64));
        m.insert("evictions".to_string(), num(self.evictions as f64));
        m.insert("coalesced".to_string(), num(self.coalesced as f64));
        m.insert("disk_hits".to_string(), num(self.disk_hits as f64));
        m.insert("disk_misses".to_string(), num(self.disk_misses as f64));
        m.insert("hit_rate".to_string(), num(self.hit_rate()));
        Json::Obj(m)
    }

    /// Thread the counters into a report's meta block (`unit_cache_*`
    /// keys). Presentation only: the report's rows never depend on the
    /// cache, which is what keeps warm and cold runs byte-identical.
    pub fn annotate(&self, r: &mut Report) {
        r.meta_num("unit_cache_hits", self.hits as f64);
        r.meta_num("unit_cache_misses", self.misses as f64);
        r.meta_num("unit_cache_inserts", self.inserts as f64);
        r.meta_num("unit_cache_evictions", self.evictions as f64);
        r.meta_num("unit_cache_coalesced", self.coalesced as f64);
        r.meta_num("unit_cache_disk_hits", self.disk_hits as f64);
        r.meta_num("unit_cache_disk_misses", self.disk_misses as f64);
        r.meta_num("unit_cache_hit_rate", self.hit_rate());
    }
}

// ---------------------------------------------------------------------
// The cache
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
struct CachedUnit {
    /// The full v3 key bytes, verified on every lookup.
    bytes: Vec<u8>,
    stamp: u64,
    sim: LayerOpSim,
}

#[derive(Debug, Default)]
struct Inner {
    /// hash -> entry; the entry's key bytes are verified on every
    /// lookup.
    map: HashMap<u64, CachedUnit>,
    /// LRU index: stamp -> hash. Oldest stamp evicts first.
    lru: BTreeMap<u64, u64>,
    clock: u64,
    stats: UnitCacheStats,
    /// Keys currently being computed: concurrent requests for the same
    /// unit block on the first computation's `OnceLock`. Keyed by the
    /// full key bytes — sharing a slot on a hash collision would hand
    /// one unit another's result, so hashes are not enough here.
    inflight: HashMap<Vec<u8>, Arc<OnceLock<LayerOpSim>>>,
}

/// Thread-safe lock-striped LRU of per-unit results with an optional
/// disk mirror. Shared across requests (and service connections) via
/// `Arc`. A key lives in stripe `key.hash % shards`; each stripe has
/// its own mutex, LRU order, in-flight table and counters.
#[derive(Debug)]
pub struct UnitCache {
    cap: usize,
    /// Per-stripe capacity: `ceil(cap / shards)`, at least 1. The
    /// proportional split means a balanced key population sees the
    /// same total residency as a single-shard cache of `cap`.
    shard_cap: usize,
    /// The record-log disk mirror. Its own mutex (not a stripe lock)
    /// so disk IO never blocks memory lookups on other threads; shared
    /// by every stripe.
    disk: Option<Mutex<RecordLog>>,
    shards: Vec<Mutex<Inner>>,
}

impl UnitCache {
    /// A single-shard cache: one lock, exact global LRU over `cap`
    /// entries. The right choice for single-threaded CLI runs and the
    /// degenerate case the sharded constructor is tested against.
    pub fn new(cap: usize) -> UnitCache {
        UnitCache::with_shards(cap, 1)
    }

    /// A lock-striped cache: `shards` independent stripes (clamped to
    /// at least 1), each an LRU of `ceil(cap / shards)` entries. Shard
    /// choice is `key.hash % shards` — deterministic in the key — so
    /// results and (while nothing evicts) telemetry are byte-identical
    /// at any shard count.
    pub fn with_shards(cap: usize, shards: usize) -> UnitCache {
        let cap = cap.max(1);
        let shards = shards.max(1);
        UnitCache {
            cap,
            shard_cap: cap.div_ceil(shards),
            disk: None,
            shards: (0..shards).map(|_| Mutex::new(Inner::default())).collect(),
        }
    }

    /// The stripe owning `key`. Pure in the key bytes: FNV-1a hash
    /// modulo the stripe count.
    fn shard(&self, key: &UnitKey) -> &Mutex<Inner> {
        &self.shards[(key.hash % self.shards.len() as u64) as usize]
    }

    /// Mirror entries to the `units.tdstore` record log under `dir`
    /// (created if missing). Entries persist across processes — the
    /// log is sealed with its in-file index when the cache drops, so
    /// the next process warm-starts from one indexed file — and the
    /// versioned key makes stale schemas read as misses.
    pub fn with_disk(mut self, dir: impl Into<PathBuf>) -> std::io::Result<UnitCache> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        self.disk = Some(Mutex::new(RecordLog::open(dir.join(UNIT_CACHE_FILE))?));
        Ok(self)
    }

    /// Total requested capacity across all stripes.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Lock-stripe count (1 for `UnitCache::new`).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Resident entries, summed across stripes.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Counters merged across stripes by summation — the stats-merge
    /// rule that keeps telemetry byte-identical at any shard count:
    /// hits/misses/coalesced are counted in the engine's serial lookup
    /// phase and shard choice is deterministic, so only the *grouping*
    /// of the counters varies with the stripe count, never the sums.
    pub fn stats(&self) -> UnitCacheStats {
        let mut total = UnitCacheStats::default();
        for s in &self.shards {
            let st = s.lock().unwrap().stats;
            total.hits += st.hits;
            total.misses += st.misses;
            total.inserts += st.inserts;
            total.evictions += st.evictions;
            total.coalesced += st.coalesced;
            total.disk_hits += st.disk_hits;
            total.disk_misses += st.disk_misses;
        }
        total
    }

    /// Backend telemetry of the disk mirror (`None` for a memory-only
    /// cache): whether the last open took the indexed fast path, and
    /// how many record frames were read/appended through this handle.
    pub fn disk_stats(&self) -> Option<LogStats> {
        Some(self.disk.as_ref()?.lock().unwrap().stats())
    }

    /// Look one key up, counting a hit or a miss in the key's stripe.
    /// Memory first, then the disk mirror (a disk hit is promoted into
    /// memory).
    pub fn lookup(&self, key: &UnitKey) -> Option<LayerOpSim> {
        let shard = self.shard(key);
        {
            let mut g = shard.lock().unwrap();
            if let Some(sim) = Self::touch(&mut g, key) {
                g.stats.hits += 1;
                return Some(sim);
            }
        }
        if let Some(sim) = self.disk_load(key) {
            let mut g = shard.lock().unwrap();
            Self::insert_locked(&mut g, key, sim, self.shard_cap, false);
            g.stats.hits += 1;
            g.stats.disk_hits += 1;
            return Some(sim);
        }
        let mut g = shard.lock().unwrap();
        g.stats.misses += 1;
        if self.disk.is_some() {
            g.stats.disk_misses += 1;
        }
        None
    }

    /// Insert a computed result (and mirror it to disk, best effort).
    pub fn insert(&self, key: &UnitKey, sim: LayerOpSim) {
        {
            let mut g = self.shard(key).lock().unwrap();
            Self::insert_locked(&mut g, key, sim, self.shard_cap, true);
        }
        self.disk_store(key, &sim);
    }

    /// Record that `key`'s unit was served by piggybacking on an
    /// identical pending unit (the engine's deterministic batch-level
    /// dedupe). Counted in the key's own stripe so per-stripe counters
    /// stay attributable; the merged sum is shard-count independent.
    pub fn note_coalesced(&self, key: &UnitKey) {
        self.shard(key).lock().unwrap().stats.coalesced += 1;
    }

    /// Compute-or-wait for a key that missed the lookup phase. If an
    /// identical unit is already in flight (another batch, another
    /// connection), block on its `OnceLock` — held in the key's stripe,
    /// so duplicate units still compute exactly once at any shard
    /// count — and share the result; otherwise run `f`, publish, and
    /// insert. Does *not* count hits/misses — those belong to the
    /// deterministic lookup phase.
    pub fn compute_coalesced(&self, key: &UnitKey, f: impl FnOnce() -> LayerOpSim) -> LayerOpSim {
        let shard = self.shard(key);
        let slot = {
            let mut g = shard.lock().unwrap();
            // Re-check under the lock: another request may have
            // completed this unit since our lookup phase ran.
            if let Some(sim) = Self::touch(&mut g, key) {
                return sim;
            }
            Arc::clone(g.inflight.entry(key.bytes.clone()).or_default())
        };
        let mut ran = false;
        let sim = *slot.get_or_init(|| {
            ran = true;
            f()
        });
        {
            let mut g = shard.lock().unwrap();
            if ran {
                Self::insert_locked(&mut g, key, sim, self.shard_cap, true);
                g.inflight.remove(&key.bytes);
            } else {
                g.stats.coalesced += 1;
            }
        }
        if ran {
            self.disk_store(key, &sim);
        }
        sim
    }

    // -- internals ----------------------------------------------------

    /// Map lookup + LRU touch. Verifies the full key bytes, so a
    /// 64-bit collision reads as a miss.
    fn touch(g: &mut Inner, key: &UnitKey) -> Option<LayerOpSim> {
        let (old, sim) = match g.map.get(&key.hash) {
            Some(e) if e.bytes == key.bytes => (e.stamp, e.sim),
            _ => return None,
        };
        g.clock += 1;
        let fresh = g.clock;
        g.map.get_mut(&key.hash).expect("entry present").stamp = fresh;
        g.lru.remove(&old);
        g.lru.insert(fresh, key.hash);
        Some(sim)
    }

    fn insert_locked(g: &mut Inner, key: &UnitKey, sim: LayerOpSim, cap: usize, count: bool) {
        g.clock += 1;
        let stamp = g.clock;
        let entry = CachedUnit { bytes: key.bytes.clone(), stamp, sim };
        if let Some(prev) = g.map.insert(key.hash, entry) {
            g.lru.remove(&prev.stamp);
        }
        g.lru.insert(stamp, key.hash);
        if count {
            g.stats.inserts += 1;
        }
        while g.map.len() > cap {
            let (old, hash) = {
                let (k, v) = g.lru.iter().next().expect("lru tracks every entry");
                (*k, *v)
            };
            g.lru.remove(&old);
            g.map.remove(&hash);
            g.stats.evictions += 1;
        }
    }

    /// Look `key` up in the record-log mirror. The log stores entries
    /// under the full canonical key string — derived here from the
    /// binary key, the only place the lookup path ever renders JSON —
    /// and re-verifies it on every frame read, so hash collisions and
    /// stale key versions both read as misses.
    fn disk_load(&self, key: &UnitKey) -> Option<LayerOpSim> {
        let log = self.disk.as_ref()?;
        let canon = key.canon();
        let text = log.lock().unwrap().get(&canon).ok()??;
        let j = Json::parse(&text).ok()?;
        if j.get("schema")?.as_str()? != UNIT_CACHE_SCHEMA {
            return None;
        }
        unit_from_json(j.get("unit")?)
    }

    fn disk_store(&self, key: &UnitKey, sim: &LayerOpSim) {
        let Some(log) = &self.disk else { return };
        let mut m = BTreeMap::new();
        m.insert("schema".to_string(), Json::Str(UNIT_CACHE_SCHEMA.to_string()));
        m.insert("unit".to_string(), unit_to_json(sim));
        let text = Json::Obj(m).render();
        let canon = key.canon();
        let mut g = log.lock().unwrap();
        // Idempotent: re-computing a unit already mirrored (promotion
        // races, repeated runs) must not grow the log.
        if g.get(&canon).ok().flatten().as_deref() == Some(text.as_str()) {
            return;
        }
        // Best effort: a full disk degrades to a memory-only cache.
        let _ = g.append(&canon, &text);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::plan::UnitTensors;
    use crate::tensor::TensorBitmap;
    use std::sync::Arc;

    fn explicit_spec(seed: u64, samples: usize, layer: usize) -> UnitSpec {
        let a = TensorBitmap::from_raw((1, 1, 1, 16), vec![0x00FF]);
        let g = TensorBitmap::from_raw((1, 1, 1, 16), vec![0x0F0F]);
        UnitSpec {
            layer,
            op: TrainOp::Fwd,
            shape: ConvShape::conv(1, 4, 4, 16, 16, 3, 1, 1),
            tensors: UnitTensors::Explicit { a: Arc::new(a), g: Arc::new(g) },
            batch_mult: 1,
            samples,
            seed,
        }
    }

    /// A real (small) unit result to cache in the tests below.
    fn small_unit(seed: u64) -> (UnitKey, LayerOpSim) {
        let cfg = ChipConfig::default();
        let spec = explicit_spec(seed, 2, 0);
        let key = UnitKey::for_unit(&cfg, &spec);
        (key, spec.execute(&cfg))
    }

    /// The exact canonical string PR 3's v1 JSON encoder produced for
    /// `explicit_spec(42, 2, 0)` under the default config — kept as the
    /// stale-mirror fixture: a v3 cache must treat a mirror entry
    /// stored under this key (or its v2 respelling) as a clean miss.
    const V1_GOLDEN_CANON: &str = concat!(
        "{\"batch_mult\":1,\"cfg\":{\"dram_gate\":false,\"dram_gbps\":51.2,",
        "\"dtype\":\"fp32\",\"freq_mhz\":500,\"lanes\":16,\"lead_limit\":6,",
        "\"power_gate\":false,\"side\":\"b\",\"spad_banks\":3,\"spad_bytes\":1024,",
        "\"sram_bank_bytes\":262144,\"sram_banks\":4,\"staging_depth\":3,",
        "\"tile_cols\":4,\"tile_rows\":4,\"tiles\":16,\"transposers\":15},",
        "\"op\":\"A*W\",\"samples\":2,\"seed\":\"000000000000002a\",",
        "\"shape\":{\"c\":16,\"f\":16,\"h\":4,\"kh\":3,\"kw\":3,\"n\":1,",
        "\"pad\":1,\"stride\":1,\"w\":4},",
        "\"tensors\":{\"a\":\"cab5d030f0dd4d63\",\"g\":\"c9a5fd30eff666aa\",",
        "\"kind\":\"bitmaps\"},\"v\":\"tensordash.unitkey.v1\"}",
    );

    #[test]
    fn golden_key_pins_v3_bytes_and_hash() {
        // Any change to the binary layout, the field order, the enum
        // tags or `ChipConfig`'s field set shows up here first. If this
        // test fails and the change is intentional, bump KEY_FORMAT and
        // UNIT_KEY_VERSION together and repin.
        let key = UnitKey::for_unit(&ChipConfig::default(), &explicit_spec(42, 2, 0));
        let mut golden: Vec<u8> = vec![b'T', b'D', b'K', 3, 0, 0, 0, 0];
        // cfg u64 block: lanes, depth, rows, cols, tiles, lead_limit,
        // freq, sram bank bytes/banks, spad bytes/banks, transposers.
        for v in [16u64, 3, 4, 4, 16, 6, 500, 262144, 4, 1024, 3, 15] {
            golden.extend_from_slice(&v.to_le_bytes());
        }
        golden.extend_from_slice(&51.2f64.to_bits().to_le_bytes());
        // shape: n h w c f kh kw stride pad.
        for v in [1u64, 4, 4, 16, 16, 3, 3, 1, 1] {
            golden.extend_from_slice(&v.to_le_bytes());
        }
        // batch_mult, samples, seed.
        for v in [1u64, 2, 42] {
            golden.extend_from_slice(&v.to_le_bytes());
        }
        // tensors: bitmaps kind + the two content hashes.
        golden.push(1);
        golden.extend_from_slice(&0xcab5_d030_f0dd_4d63u64.to_le_bytes());
        golden.extend_from_slice(&0xc9a5_fd30_eff6_66aau64.to_le_bytes());
        assert_eq!(golden.len(), 225, "fixed-size prefix + bitmaps tensors");
        assert_eq!(key.bytes, golden);
        assert_eq!(key.hash, fnv1a64(&golden));
        // The derived canonical document is the v1 golden with the
        // version tag bumped — same content, new namespace on disk
        // (explicit bitmaps carry no regime, so only the tag moved).
        assert_eq!(key.canon(), V1_GOLDEN_CANON.replace("unitkey.v1", "unitkey.v3"));
        assert_ne!(key.canon(), V1_GOLDEN_CANON);
    }

    #[test]
    fn golden_profile_key_pins_regime_tail_bytes() {
        // The v3 addition is the regime tag at the end of profile
        // recipes. Pin the exact tensors-section tail for each regime
        // so the encoding can never drift silently.
        let cfg = ChipConfig::default();
        let p = Arc::new(crate::trace::profiles::ModelProfile::for_model("gcn").unwrap());
        let tail_for = |regime: Regime, extra: &[u8]| {
            let plan = crate::api::plan::ModelPlan::profile_regime(
                Arc::clone(&p),
                0.4,
                regime,
                &cfg,
                1,
                7,
            );
            let unit = &plan.units[0];
            let key = UnitKey::for_unit(&cfg, unit);
            let mut tail: Vec<u8> = vec![TENSORS_PROFILE];
            tail.extend_from_slice(&0.4f64.to_bits().to_le_bytes());
            tail.extend_from_slice(&7u64.to_le_bytes()); // plan bitmap seed
            tail.extend_from_slice(&0u64.to_le_bytes()); // layer 0
            tail.extend_from_slice(&3u32.to_le_bytes());
            tail.extend_from_slice(b"gcn");
            tail.extend_from_slice(extra);
            assert!(
                key.bytes.ends_with(&tail),
                "regime tail must pin exactly: {:?}",
                &key.bytes[key.bytes.len() - tail.len().min(key.bytes.len())..]
            );
            key
        };
        let uniform = tail_for(Regime::Uniform, &[REGIME_UNIFORM]);
        let mut nm_tail = vec![REGIME_NM];
        nm_tail.extend_from_slice(&2u64.to_le_bytes());
        nm_tail.extend_from_slice(&4u64.to_le_bytes());
        nm_tail.push(AXIS_CHANNEL);
        let nm = tail_for(Regime::NM { n: 2, m: 4, axis: MaskAxis::Channel }, &nm_tail);
        let mut sched_tail = vec![REGIME_SCHEDULE, CURVE_DENSE_U];
        sched_tail.extend_from_slice(&0.25f64.to_bits().to_le_bytes());
        let sched = tail_for(Regime::Schedule { curve: Curve::DenseU { swing: 0.25 } }, &sched_tail);
        // Distinct regimes must key distinctly (same unit otherwise).
        assert_ne!(uniform, nm);
        assert_ne!(uniform, sched);
        assert_ne!(nm, sched);
        // And the canonical documents spell the regime out.
        assert!(uniform.canon().contains("\"regime\":\"uniform\""));
        assert!(nm.canon().contains("\"regime\":\"nm:2:4\""));
        assert!(sched.canon().contains("\"regime\":\"schedule:dense-u:0.25\""));
    }

    #[test]
    fn key_ignores_layer_but_tracks_everything_else() {
        let cfg = ChipConfig::default();
        let base = UnitKey::for_unit(&cfg, &explicit_spec(42, 2, 0));
        // The layer index only labels the result; identical geometry +
        // tensors + seed share one entry.
        assert_eq!(base, UnitKey::for_unit(&cfg, &explicit_spec(42, 2, 7)));
        // Everything result-relevant changes the key.
        assert_ne!(base, UnitKey::for_unit(&cfg, &explicit_spec(43, 2, 0)));
        assert_ne!(base, UnitKey::for_unit(&cfg, &explicit_spec(42, 3, 0)));
        let depth2 = ChipConfig::default().with_depth(2);
        assert_ne!(base, UnitKey::for_unit(&depth2, &explicit_spec(42, 2, 0)));
    }

    #[test]
    fn binary_and_json_keys_agree_for_every_tensor_kind() {
        // The agreement property: decoding the v2 bytes must rebuild
        // exactly the canonical JSON the direct builder produces, for
        // explicit-bitmap and profile-recipe units alike, across
        // configs. (This is what makes the disk mirror keyed by
        // `canon()` trustworthy without ever encoding JSON on the hot
        // path.)
        let configs = [ChipConfig::default(), ChipConfig::default().with_depth(2)];
        for cfg in &configs {
            for seed in [0u64, 1, 42, u64::MAX] {
                for samples in [1usize, 2, 7] {
                    let spec = explicit_spec(seed, samples, 0);
                    let key = UnitKey::for_unit(cfg, &spec);
                    assert_eq!(key.canon(), canon_json_for_unit(cfg, &spec));
                    assert_eq!(key.hash, fnv1a64(&key.bytes));
                }
            }
        }
        // Profile recipes carry the model name, layer and regime; every
        // unit of a real plan must round-trip under each regime, and
        // distinct layers must key distinctly (their bitmaps differ by
        // recipe).
        let p = Arc::new(crate::trace::profiles::ModelProfile::for_model("gcn").unwrap());
        let regimes = [
            Regime::Uniform,
            Regime::NM { n: 2, m: 4, axis: MaskAxis::Channel },
            Regime::Schedule { curve: Curve::Piecewise { points: vec![(0.0, 1.0), (1.0, 0.5)] } },
        ];
        for regime in regimes {
            let plan = crate::api::plan::ModelPlan::profile_regime(
                Arc::clone(&p),
                0.4,
                regime,
                &configs[0],
                1,
                7,
            );
            let mut seen = std::collections::HashSet::new();
            for u in &plan.units {
                let key = UnitKey::for_unit(&plan.cfg, u);
                let canon = key.canon();
                assert_eq!(canon, canon_json_for_unit(&plan.cfg, u));
                assert!(canon.contains("\"kind\":\"profile\""));
                assert!(canon.contains("\"regime\":"));
                assert!(canon.contains(UNIT_KEY_VERSION));
                seen.insert(key.bytes.clone());
            }
            assert_eq!(seen.len(), plan.units.len(), "every (layer, op) unit keys distinctly");
        }
    }

    #[test]
    fn stale_v1_mirror_entries_read_as_clean_misses() {
        let dir = std::env::temp_dir().join(format!("td_unitcache_v1_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let (key, sim) = small_unit(42);
        // Plant a well-formed v1 entry: the exact canonical string the
        // v1 encoder produced for this very unit, with a valid payload.
        let mut m = BTreeMap::new();
        m.insert("schema".to_string(), Json::Str(UNIT_CACHE_SCHEMA.to_string()));
        m.insert("unit".to_string(), unit_to_json(&sim));
        let payload = Json::Obj(m).render();
        {
            let mut log = RecordLog::open(dir.join(UNIT_CACHE_FILE)).unwrap();
            log.append(V1_GOLDEN_CANON, &payload).unwrap();
        }
        // The v3 canonical string differs (the version tag is part of
        // the document), so the stale entry is unreachable: a clean
        // miss, not an error and never a wrong answer.
        assert_ne!(key.canon(), V1_GOLDEN_CANON);
        let cache = UnitCache::new(8).with_disk(&dir).unwrap();
        assert!(cache.lookup(&key).is_none());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.disk_misses), (0, 1, 1));
        // And the mirror keeps working under the v3 namespace.
        cache.insert(&key, sim);
        assert_eq!(cache.lookup(&key), Some(sim));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_v2_mirror_entries_read_as_clean_misses() {
        let dir = std::env::temp_dir().join(format!("td_unitcache_v2_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let (key, sim) = small_unit(42);
        // A v2 mirror entry is the v1 canonical document with the
        // version tag respelled — exactly what the v2 encoder stored
        // for this unit (the regime tag did not exist yet).
        let v2_canon = V1_GOLDEN_CANON.replace("unitkey.v1", "unitkey.v2");
        let mut m = BTreeMap::new();
        m.insert("schema".to_string(), Json::Str(UNIT_CACHE_SCHEMA.to_string()));
        m.insert("unit".to_string(), unit_to_json(&sim));
        let payload = Json::Obj(m).render();
        {
            let mut log = RecordLog::open(dir.join(UNIT_CACHE_FILE)).unwrap();
            log.append(&v2_canon, &payload).unwrap();
        }
        assert_ne!(key.canon(), v2_canon);
        let cache = UnitCache::new(8).with_disk(&dir).unwrap();
        assert!(cache.lookup(&key).is_none(), "v2 entries must read as misses under v3");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.disk_misses), (0, 1, 1));
        cache.insert(&key, sim);
        assert_eq!(cache.lookup(&key), Some(sim));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unit_result_json_round_trips_bit_exactly() {
        let (_, sim) = small_unit(11);
        let text = unit_to_json(&sim).render_pretty();
        let back = unit_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, sim);
        assert_eq!(back.energy_td.total_pj().to_bits(), sim.energy_td.total_pj().to_bits());
        assert_eq!(back.sched, sim.sched);
    }

    #[test]
    fn lookup_hits_after_insert_and_counts_stats() {
        let cache = UnitCache::new(8);
        let (key, sim) = small_unit(1);
        assert!(cache.lookup(&key).is_none());
        cache.insert(&key, sim);
        assert_eq!(cache.lookup(&key), Some(sim));
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.inserts), (1, 1, 1));
    }

    #[test]
    fn lru_evicts_least_recently_used_first() {
        let cache = UnitCache::new(2);
        let (k1, s1) = small_unit(1);
        let (k2, s2) = small_unit(2);
        let (k3, s3) = small_unit(3);
        cache.insert(&k1, s1);
        cache.insert(&k2, s2);
        // Touch k1 so k2 becomes the LRU victim.
        assert!(cache.lookup(&k1).is_some());
        cache.insert(&k3, s3);
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats().evictions, 1);
        assert!(cache.lookup(&k2).is_none(), "LRU entry must be evicted");
        assert!(cache.lookup(&k1).is_some());
        assert!(cache.lookup(&k3).is_some());
    }

    #[test]
    fn capacity_is_enforced_under_bulk_inserts() {
        let cache = UnitCache::new(4);
        for seed in 0..10u64 {
            let (k, s) = small_unit(seed);
            cache.insert(&k, s);
        }
        assert_eq!(cache.len(), 4);
        assert_eq!(cache.stats().evictions, 6);
        // The four most recent survive.
        for seed in 6..10u64 {
            let (k, _) = small_unit(seed);
            assert!(cache.lookup(&k).is_some(), "seed {seed} should be resident");
        }
    }

    #[test]
    fn compute_coalesced_runs_each_key_once() {
        let cache = UnitCache::new(8);
        let (key, _) = small_unit(5);
        let mut runs = 0usize;
        let first = cache.compute_coalesced(&key, || {
            runs += 1;
            small_unit(5).1
        });
        let second = cache.compute_coalesced(&key, || {
            runs += 1;
            small_unit(5).1
        });
        assert_eq!(runs, 1, "second call must be served from the cache");
        assert_eq!(first, second);
        assert_eq!(cache.stats().inserts, 1);
    }

    #[test]
    fn disk_store_round_trips_across_cache_instances() {
        let dir = std::env::temp_dir().join(format!("td_unitcache_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let (key, sim) = small_unit(9);
        {
            let cache = UnitCache::new(8).with_disk(&dir).unwrap();
            // A cold disk-backed cache records the disk probe failure.
            assert!(cache.lookup(&key).is_none());
            let s = cache.stats();
            assert_eq!((s.misses, s.disk_misses), (1, 1));
            cache.insert(&key, sim);
        }
        let cache = UnitCache::new(8).with_disk(&dir).unwrap();
        // Warm start restores the mirror's in-file index without a scan.
        assert!(cache.disk_stats().unwrap().fast_path, "reopen must take the indexed path");
        assert_eq!(cache.lookup(&key), Some(sim), "disk mirror must survive the process");
        let s = cache.stats();
        assert_eq!((s.hits, s.disk_hits, s.disk_misses), (1, 1, 0));
        // Promoted into memory: the second lookup is a pure memory hit.
        assert_eq!(cache.lookup(&key), Some(sim));
        assert_eq!(cache.stats().disk_hits, 1);
        // The whole mirror is one record log, not per-key files.
        let files: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
        assert_eq!(files.len(), 1);
        assert_eq!(files[0].as_ref().unwrap().file_name(), UNIT_CACHE_FILE);
        // Memory-only caches never count disk misses (and report no
        // disk telemetry at all).
        let mem = UnitCache::new(8);
        assert!(mem.lookup(&key).is_none());
        assert_eq!(mem.stats().disk_misses, 0);
        assert!(mem.disk_stats().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn disk_store_is_idempotent_per_unit() {
        let dir = std::env::temp_dir().join(format!("td_unitcache_idem_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let (key, sim) = small_unit(13);
        let cache = UnitCache::new(8).with_disk(&dir).unwrap();
        cache.insert(&key, sim);
        cache.insert(&key, sim);
        cache.insert(&key, sim);
        assert_eq!(
            cache.disk_stats().unwrap().appends,
            1,
            "re-inserting an identical unit must not grow the log"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sharded_cache_matches_single_shard_contents_and_merged_stats() {
        // Drive a single-shard and a striped cache through the same
        // sequence (capacity far from pressure): lookup results, total
        // residency and the summed telemetry must be identical — the
        // stats-merge rule the serving layer's determinism rests on.
        let single = UnitCache::new(64);
        let sharded = UnitCache::with_shards(64, 4);
        let units: Vec<_> = (0..12u64).map(small_unit).collect();
        for (k, s) in &units {
            assert!(single.lookup(k).is_none());
            assert!(sharded.lookup(k).is_none());
            single.insert(k, *s);
            sharded.insert(k, *s);
        }
        for (k, s) in &units {
            assert_eq!(single.lookup(k), Some(*s));
            assert_eq!(sharded.lookup(k), Some(*s));
        }
        assert_eq!(single.shard_count(), 1);
        assert_eq!(sharded.shard_count(), 4);
        assert_eq!(sharded.len(), single.len());
        assert_eq!(sharded.capacity(), single.capacity());
        assert_eq!(sharded.stats(), single.stats(), "merged counters must not depend on shards");
        // Shard choice is a pure function of the key bytes: an
        // independently re-derived key finds the same stripe.
        let rekey = UnitKey::for_unit(&ChipConfig::default(), &explicit_spec(3, 2, 9));
        assert_eq!(sharded.lookup(&rekey), Some(units[3].1));
    }

    #[test]
    fn proportional_shard_caps_evict_within_one_stripe_only() {
        // cap 4 over 4 stripes = 1 entry per stripe. Two keys landing
        // in the same stripe displace each other; keys in other stripes
        // are untouched — per-stripe LRU, not a merged global one.
        let cache = UnitCache::with_shards(4, 4);
        let units: Vec<_> = (0..32u64).map(small_unit).collect();
        let stripe = |k: &UnitKey| (k.hash % 4) as usize;
        let (a, b) = {
            let first = &units[0];
            let twin = units[1..]
                .iter()
                .find(|(k, _)| stripe(k) == stripe(&first.0))
                .expect("32 keys must collide in 4 stripes");
            (first.clone(), twin.clone())
        };
        let other = units[1..]
            .iter()
            .find(|(k, _)| stripe(k) != stripe(&a.0))
            .expect("some key lands elsewhere")
            .clone();
        cache.insert(&a.0, a.1);
        cache.insert(&other.0, other.1);
        cache.insert(&b.0, b.1);
        assert_eq!(cache.stats().evictions, 1, "stripe overflow evicts exactly once");
        assert!(cache.lookup(&a.0).is_none(), "displaced within its stripe");
        assert_eq!(cache.lookup(&b.0), Some(b.1));
        assert_eq!(cache.lookup(&other.0), Some(other.1), "other stripes untouched");
    }

    #[test]
    fn stats_since_subtracts_snapshots() {
        let cache = UnitCache::new(8);
        let (key, sim) = small_unit(3);
        cache.insert(&key, sim);
        let before = cache.stats();
        assert!(cache.lookup(&key).is_some());
        let delta = cache.stats().since(&before);
        assert_eq!((delta.hits, delta.misses, delta.inserts), (1, 0, 0));
        assert!((delta.hit_rate() - 1.0).abs() < 1e-12);
    }
}
