//! The typed experiment API — the single front door to the simulator.
//!
//! Every evaluation in the paper (Figs. 13–20, Table 3, the ablations)
//! flows through the same pipeline:
//!
//! ```text
//!   SimRequest / SweepSpec  ──►  Engine (--jobs N worker pool)  ──►  Report
//!        what to run              deterministic execution         data first
//!                                                                     │
//!                                        ┌────────────────────────────┼───────────────┐
//!                                   render_text()               render_json()    render_csv()
//!                                (metrics::Table)          (tensordash.report.v1)
//! ```
//!
//! * [`SimRequest`] — one unit of simulation: a [`Workload`] (model
//!   profile, captured trace, random-sparsity level, or a single conv
//!   op) plus `ChipConfig`, sampling budget and seed.
//! * [`SweepSpec`] — a grid over `ChipConfig` × epoch × model that
//!   expands to one request per cell with a seed derived by
//!   [`derive_seed`], making results independent of worker count and
//!   execution order.
//! * [`ModelPlan`] / [`UnitSpec`] — a request lowered to its
//!   deterministic parallel unit graph: one independent (layer, op)
//!   unit per layer × {Fwd, Igrad, Wgrad}, each with its own derived
//!   seed, merged back in plan order. The retained per-unit vector
//!   feeds the `tensordash.layers.v1` breakdown ([`layers_report`]).
//! * [`Engine`] — executes the *flattened* cell×unit work list on a
//!   `std::thread` pool ([`Engine::map`] is the generic primitive the
//!   figure sweeps use), so a single-model simulation saturates all
//!   cores, not just multi-cell sweeps.
//! * [`Report`] / [`ReportRow`] / [`Cell`] — the structured result:
//!   `repro::` figure functions *return* reports; text tables, JSON and
//!   CSV are renderers over them, so every figure regenerates
//!   identically — and machine-readably — from every entry point (CLI,
//!   benches, examples, tests).
//! * [`UnitCache`] — a content-addressed store of per-unit results
//!   keyed by the canonical, versioned [`UnitKey`]; attach one to an
//!   [`Engine`] with [`Engine::with_cache`] and sweep cells, repeated
//!   requests and multi-figure runs stop recomputing shared units.
//!   Byte-identity between warm and cold runs is a tested invariant.
//! * [`Service`] — the persistent JSON-lines serving loop
//!   (stdin/stdout and TCP, `serve` subcommand) over a shared cache
//!   and an `Arc`-backed [`ArtifactStore`], with batched request
//!   coalescing. The TCP transport multiplexes at request grain
//!   ([`ServeOptions`]): per-connection readers feed one bounded
//!   request queue, a compute pool executes individual requests, and
//!   per-connection writers re-sequence responses (or stream them
//!   out of order on request). Every response renders through the
//!   typed [`ServeReply`] envelope.
//! * [`params`](crate::api::params) — the one parameter-parsing path
//!   shared by the CLI and the serve protocol, so names, defaults and
//!   error text cannot drift between them.

pub mod cache;
pub mod engine;
pub mod params;
pub mod plan;
pub mod report;
pub mod request;
pub mod service;

pub use cache::{
    UnitCache, UnitCacheStats, UnitKey, DEFAULT_CACHE_CAP, DEFAULT_CACHE_SHARDS, UNIT_CACHE_FILE,
    UNIT_KEY_VERSION,
};
pub use engine::{default_jobs, Engine};
pub use plan::{layers_report, ModelPlan, TensorRecipe, UnitSpec, UnitTensors};
pub use report::{
    report_set_json, Cell, Report, ReportRow, FRONTIER_SCHEMA, LAYERS_SCHEMA, REPORT_SCHEMA,
    REPORT_SET_SCHEMA,
};
pub use request::{derive_seed, SimRequest, SweepSpec, Workload};
pub use params::{ParamSource, ParamValue, DEFAULT_EXPLORE_BUDGET, DEFAULT_SEED};
pub use service::{
    ArtifactStore, Handled, HandledReplies, ServeOptions, ServeReply, Service, TraceArtifact,
    DEFAULT_QUEUE_DEPTH, DEFAULT_SERVE_WORKERS, SERVE_SCHEMA, TRACE_SCHEMA,
};
