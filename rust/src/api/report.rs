//! Structured experiment results: data first, rendering second.
//!
//! Every figure/table in the reproduction is materialised as a
//! [`Report`] — a titled grid of typed [`Cell`]s — before anything is
//! printed. Renderers then turn one `Report` into the three interchange
//! forms the pipeline needs:
//!
//! * `render_text()` — the aligned console table (via [`crate::metrics::Table`],
//!   which is now *one renderer* over `Report`, not the result type);
//! * `to_json()` / `render_json()` — the `tensordash.report.v1` schema
//!   written through [`Json::render`](crate::util::json::Json), consumed
//!   by CI, the `BENCH_*.json` perf trajectory and downstream tooling;
//! * `render_csv()` — flat spreadsheet form.
//!
//! A numeric cell carries both its raw `f64` **and** the display text it
//! was formatted with, so the JSON form is lossless in both directions:
//! machine consumers read full-precision values while `from_json` can
//! reconstruct a byte-identical text rendering.

use std::collections::BTreeMap;

use crate::metrics::{f2, Table};
use crate::util::json::Json;

/// One table cell: display text plus, for numeric cells, the raw value.
#[derive(Debug, Clone, PartialEq)]
pub struct Cell {
    pub text: String,
    pub value: Option<f64>,
}

impl Cell {
    /// A plain text cell (labels, dashes, blanks).
    pub fn text(s: impl Into<String>) -> Cell {
        Cell { text: s.into(), value: None }
    }

    /// An empty cell (geomean rows leave per-op columns blank).
    pub fn empty() -> Cell {
        Cell::text("")
    }

    /// A numeric cell with the default 2-decimal display format.
    pub fn num(v: f64) -> Cell {
        Cell { text: f2(v), value: Some(v) }
    }

    /// A numeric cell with caller-chosen display text (percentages,
    /// `{:+.0}%` deltas, 3-decimal overheads, ...).
    pub fn fmt(text: impl Into<String>, v: f64) -> Cell {
        Cell { text: text.into(), value: Some(v) }
    }
}

/// One report row.
#[derive(Debug, Clone, PartialEq)]
pub struct ReportRow {
    pub cells: Vec<Cell>,
}

/// A structured experiment result: the single type every `repro::`
/// driver returns and every renderer/serialiser consumes.
#[derive(Debug, Clone, PartialEq)]
pub struct Report {
    /// Serialised schema tag: [`REPORT_SCHEMA`] for ordinary figures,
    /// [`LAYERS_SCHEMA`] for per-(layer, op) unit breakdowns.
    pub schema: String,
    /// Stable machine identifier, e.g. `"fig13"`, `"table3_fp32"`.
    pub id: String,
    /// Human title (the old table heading).
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<ReportRow>,
    /// Free-form provenance: seed, samples, jobs, config knobs.
    pub meta: BTreeMap<String, Json>,
}

/// Version tag written into every serialised report.
pub const REPORT_SCHEMA: &str = "tensordash.report.v1";
/// Version tag for the per-(layer, op) unit breakdown a model plan
/// retains (`--per-layer`, `api::plan::layers_report`).
pub const LAYERS_SCHEMA: &str = "tensordash.layers.v1";
/// Version tag for a multi-report document (`repro --all --format json`).
pub const REPORT_SET_SCHEMA: &str = "tensordash.reportset.v1";
/// Version tag for a design-space Pareto frontier
/// (`explore` subcommand / service op, [`crate::search`]).
pub const FRONTIER_SCHEMA: &str = "tensordash.frontier.v1";

impl Report {
    pub fn new(id: impl Into<String>, title: impl Into<String>, columns: &[&str]) -> Report {
        Report::with_schema(REPORT_SCHEMA, id, title, columns)
    }

    /// A report under a non-default schema tag (e.g. [`LAYERS_SCHEMA`]).
    pub fn with_schema(
        schema: impl Into<String>,
        id: impl Into<String>,
        title: impl Into<String>,
        columns: &[&str],
    ) -> Report {
        Report {
            schema: schema.into(),
            id: id.into(),
            title: title.into(),
            columns: columns.iter().map(|c| c.to_string()).collect(),
            rows: Vec::new(),
            meta: BTreeMap::new(),
        }
    }

    /// Append a row; arity is checked against `columns`.
    pub fn row(&mut self, cells: Vec<Cell>) {
        assert_eq!(cells.len(), self.columns.len(), "report row arity mismatch");
        self.rows.push(ReportRow { cells });
    }

    pub fn meta_num(&mut self, key: &str, v: f64) {
        self.meta.insert(key.to_string(), Json::Num(v));
    }

    pub fn meta_str(&mut self, key: &str, v: &str) {
        self.meta.insert(key.to_string(), Json::Str(v.to_string()));
    }

    /// Raw numeric value at (row, column-name), if that cell is numeric.
    pub fn value(&self, row: usize, column: &str) -> Option<f64> {
        let c = self.columns.iter().position(|c| c == column)?;
        self.rows.get(row)?.cells.get(c)?.value
    }

    // -- renderers ----------------------------------------------------

    /// The text renderer: lower onto [`crate::metrics::Table`].
    pub fn to_table(&self) -> Table {
        let href: Vec<&str> = self.columns.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(self.title.clone(), &href);
        for r in &self.rows {
            t.row(r.cells.iter().map(|c| c.text.clone()).collect());
        }
        t
    }

    pub fn render_text(&self) -> String {
        self.to_table().render()
    }

    pub fn print(&self) {
        print!("{}", self.render_text());
    }

    /// The `tensordash.report.v1` / `tensordash.layers.v1` JSON document.
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("schema".to_string(), Json::Str(self.schema.clone()));
        obj.insert("id".to_string(), Json::Str(self.id.clone()));
        obj.insert("title".to_string(), Json::Str(self.title.clone()));
        obj.insert(
            "columns".to_string(),
            Json::Arr(self.columns.iter().map(|c| Json::Str(c.clone())).collect()),
        );
        let rows = self
            .rows
            .iter()
            .map(|r| {
                let cells = r
                    .cells
                    .iter()
                    .map(|c| {
                        let mut m = BTreeMap::new();
                        m.insert("text".to_string(), Json::Str(c.text.clone()));
                        if let Some(v) = c.value {
                            m.insert("value".to_string(), Json::Num(v));
                        }
                        Json::Obj(m)
                    })
                    .collect();
                let mut m = BTreeMap::new();
                m.insert("cells".to_string(), Json::Arr(cells));
                Json::Obj(m)
            })
            .collect();
        obj.insert("rows".to_string(), Json::Arr(rows));
        if !self.meta.is_empty() {
            obj.insert("meta".to_string(), Json::Obj(self.meta.clone()));
        }
        Json::Obj(obj)
    }

    pub fn render_json(&self) -> String {
        self.to_json().render_pretty()
    }

    /// Reconstruct a report from its `tensordash.report.v1` (or
    /// `tensordash.layers.v1` / `tensordash.frontier.v1`) JSON form.
    /// Lossless: `from_json(parse(render_json(r))) == r`.
    pub fn from_json(j: &Json) -> Option<Report> {
        let schema = j.get("schema")?.as_str()?;
        if schema != REPORT_SCHEMA && schema != LAYERS_SCHEMA && schema != FRONTIER_SCHEMA {
            return None;
        }
        let columns: Vec<String> = j
            .get("columns")?
            .as_arr()?
            .iter()
            .map(|c| c.as_str().map(str::to_string))
            .collect::<Option<_>>()?;
        let mut rows = Vec::new();
        for r in j.get("rows")?.as_arr()? {
            let mut cells = Vec::new();
            for c in r.get("cells")?.as_arr()? {
                cells.push(Cell {
                    text: c.get("text")?.as_str()?.to_string(),
                    value: c.get("value").and_then(|v| v.as_f64()),
                });
            }
            if cells.len() != columns.len() {
                return None;
            }
            rows.push(ReportRow { cells });
        }
        let meta = match j.get("meta") {
            Some(Json::Obj(m)) => m.clone(),
            _ => BTreeMap::new(),
        };
        Some(Report {
            schema: schema.to_string(),
            id: j.get("id")?.as_str()?.to_string(),
            title: j.get("title")?.as_str()?.to_string(),
            columns,
            rows,
            meta,
        })
    }

    /// CSV renderer (RFC-4180-style quoting; cell display text).
    pub fn render_csv(&self) -> String {
        fn esc(s: &str) -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        }
        let mut out = String::new();
        out.push_str(&self.columns.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.cells.iter().map(|c| esc(&c.text)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Combine reports into one JSON document: a single report stays a bare
/// `tensordash.report.v1` object; several become a
/// `tensordash.reportset.v1` wrapper.
pub fn report_set_json(reports: &[Report]) -> Json {
    if reports.len() == 1 {
        return reports[0].to_json();
    }
    let mut obj = BTreeMap::new();
    obj.insert("schema".to_string(), Json::Str(REPORT_SET_SCHEMA.to_string()));
    obj.insert(
        "reports".to_string(),
        Json::Arr(reports.iter().map(|r| r.to_json()).collect()),
    );
    Json::Obj(obj)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> Report {
        let mut r = Report::new("demo", "Demo — speedups", &["model", "overall"]);
        r.row(vec![Cell::text("alexnet"), Cell::num(1.953_222)]);
        r.row(vec![Cell::text("gcn"), Cell::num(1.01)]);
        r.meta_num("seed", 42.0);
        r.meta_str("config", "default");
        r
    }

    #[test]
    fn text_render_matches_table() {
        let r = demo();
        let s = r.render_text();
        assert!(s.contains("## Demo — speedups"));
        assert!(s.contains("1.95"));
        assert!(s.contains("alexnet"));
    }

    #[test]
    fn json_round_trip_is_lossless() {
        let r = demo();
        let j = Json::parse(&r.render_json()).expect("report json parses");
        let back = Report::from_json(&j).expect("report json reconstructs");
        assert_eq!(back, r);
        assert_eq!(back.render_text(), r.render_text());
        // Full-precision value survives even though text is 2-decimal.
        assert_eq!(back.value(0, "overall"), Some(1.953_222));
    }

    #[test]
    fn csv_quotes_commas() {
        let mut r = Report::new("x", "t", &["a", "b"]);
        r.row(vec![Cell::text("v,w"), Cell::fmt("say \"hi\"", 1.0)]);
        let csv = r.render_csv();
        assert!(csv.starts_with("a,b\n"));
        assert!(csv.contains("\"v,w\""));
        assert!(csv.contains("\"say \"\"hi\"\"\""));
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut r = Report::new("x", "t", &["a", "b"]);
        r.row(vec![Cell::empty()]);
    }

    #[test]
    fn layers_schema_round_trips_and_foreign_schemas_are_rejected() {
        let mut r = Report::with_schema(LAYERS_SCHEMA, "layers", "t", &["a"]);
        r.row(vec![Cell::num(1.0)]);
        let j = Json::parse(&r.render_json()).unwrap();
        assert_eq!(j.get("schema").unwrap().as_str(), Some(LAYERS_SCHEMA));
        assert_eq!(Report::from_json(&j).unwrap(), r);
        let mut bad = r.to_json();
        if let Json::Obj(m) = &mut bad {
            m.insert("schema".to_string(), Json::Str("tensordash.report.v9".into()));
        }
        assert!(Report::from_json(&bad).is_none(), "unknown schema must not parse");
    }

    #[test]
    fn report_set_wraps_multiple() {
        let rs = [demo(), demo()];
        let j = report_set_json(&rs);
        assert_eq!(j.get("schema").unwrap().as_str(), Some(REPORT_SET_SCHEMA));
        assert_eq!(j.get("reports").unwrap().as_arr().unwrap().len(), 2);
        let single = report_set_json(&rs[..1]);
        assert_eq!(single.get("schema").unwrap().as_str(), Some(REPORT_SCHEMA));
    }
}
