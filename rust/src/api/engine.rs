//! The experiment engine: executes [`SimRequest`]s on a `std::thread`
//! worker pool.
//!
//! Design constraints:
//!
//! * **No new dependencies** — plain `std::thread::scope` workers over an
//!   atomic work index (rayon is unavailable offline).
//! * **Determinism** — every cell's result depends only on its own
//!   request (config + workload + samples + seed), never on worker
//!   count or completion order; results are re-assembled in submission
//!   order. `--jobs 4` is byte-identical to `--jobs 1`.
//! * **Throughput** — sweep cells are embarrassingly parallel (each is a
//!   full cycle-simulation), so the pool scales until the hardware runs
//!   out of cores.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::repro::{simulate_layer_op, simulate_profile, simulate_trace, ModelSim};
use crate::trace::profiles::ModelProfile;
use crate::trace::synthetic::random_bitmap;
use crate::util::rng::Rng;

use super::request::{SimRequest, Workload};

/// Number of workers the engine uses when the caller does not say
/// (`--jobs` unset): every available core.
pub fn default_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Executes requests; cheap to construct, freely shareable by reference.
#[derive(Debug, Clone)]
pub struct Engine {
    jobs: usize,
}

impl Engine {
    pub fn new(jobs: usize) -> Engine {
        Engine { jobs: jobs.max(1) }
    }

    /// A single-threaded engine (tests, tiny workloads).
    pub fn serial() -> Engine {
        Engine::new(1)
    }

    /// An engine using [`default_jobs`] workers.
    pub fn parallel() -> Engine {
        Engine::new(default_jobs())
    }

    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// Execute one request synchronously on the calling thread.
    pub fn run(&self, req: &SimRequest) -> ModelSim {
        execute(req)
    }

    /// Execute a batch of requests on the worker pool; results are in
    /// input order regardless of worker count.
    pub fn run_all(&self, reqs: &[SimRequest]) -> Vec<ModelSim> {
        self.map(reqs.len(), |i| execute(&reqs[i]))
    }

    /// The pool primitive: compute `f(0..n)` with work stealing, return
    /// results in index order. `f` only sees the cell index, so any
    /// deterministic per-cell computation (not just `SimRequest`s) can
    /// ride the pool — the geometry/ablation sweeps use this directly.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let jobs = self.jobs.min(n.max(1));
        if jobs <= 1 {
            return (0..n).map(f).collect();
        }
        let next = AtomicUsize::new(0);
        let results: Mutex<Vec<(usize, T)>> = Mutex::new(Vec::with_capacity(n));
        std::thread::scope(|s| {
            for _ in 0..jobs {
                s.spawn(|| {
                    let mut local: Vec<(usize, T)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(i)));
                    }
                    results.lock().unwrap().extend(local);
                });
            }
        });
        let mut v = results.into_inner().unwrap();
        v.sort_by_key(|(i, _)| *i);
        v.into_iter().map(|(_, t)| t).collect()
    }
}

/// Execute one request. Pure: depends only on the request contents.
fn execute(req: &SimRequest) -> ModelSim {
    match &req.workload {
        Workload::Profile { model, epoch } => {
            // Unknown names are rejected at request-build time; an
            // invariant breach here should be loud.
            let p = ModelProfile::for_model(model)
                .unwrap_or_else(|| panic!("unknown model '{model}' reached the engine"));
            let mut sim = simulate_profile(&req.cfg, &p, *epoch, req.samples, req.seed);
            sim.name = req.label.clone();
            sim
        }
        Workload::Trace { shapes, layers } => {
            let mut sim = simulate_trace(&req.cfg, shapes, layers, req.samples, req.seed);
            sim.name = req.label.clone();
            sim
        }
        Workload::SingleOp { shape, op, a, g, batch_mult } => {
            let mut rng = Rng::new(req.seed);
            let r = simulate_layer_op(&req.cfg, shape, *op, a, g, req.samples, *batch_mult, &mut rng);
            let mut per_op = [(0u64, 0u64); 3];
            per_op[*op as usize] = (r.base_chip_cycles, r.td_chip_cycles);
            ModelSim {
                name: req.label.clone(),
                per_op,
                energy_base: r.energy_base,
                energy_td: r.energy_td,
                sched: r.sched,
            }
        }
        Workload::RandomSparse { shape, sparsity, samples_per_level, batch_mult } => {
            use crate::conv::TrainOp;
            let mut rng = Rng::new(req.seed);
            let mut per_op = [(0u64, 0u64); 3];
            let mut e_base = crate::energy::EnergyBreakdown::default();
            let mut e_td = crate::energy::EnergyBreakdown::default();
            let mut sched = crate::sim::CacheStats::default();
            for _ in 0..*samples_per_level {
                let a = random_bitmap((shape.n, shape.h, shape.w, shape.c), *sparsity, &mut rng);
                let g =
                    random_bitmap((shape.n, shape.out_h(), shape.out_w(), shape.f), *sparsity, &mut rng);
                for op in TrainOp::ALL {
                    let r =
                        simulate_layer_op(&req.cfg, shape, op, &a, &g, req.samples, *batch_mult, &mut rng);
                    per_op[op as usize].0 += r.base_chip_cycles;
                    per_op[op as usize].1 += r.td_chip_cycles;
                    e_base.merge(&r.energy_base);
                    e_td.merge(&r.energy_td);
                    sched.merge(&r.sched);
                }
            }
            ModelSim { name: req.label.clone(), per_op, energy_base: e_base, energy_td: e_td, sched }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::SweepSpec;
    use crate::config::ChipConfig;

    #[test]
    fn map_preserves_order_and_covers_all_indices() {
        let e = Engine::new(4);
        let out = e.map(97, |i| i * 3);
        assert_eq!(out.len(), 97);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * 3);
        }
        // Serial path too.
        assert_eq!(Engine::serial().map(5, |i| i), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn parallel_sweep_matches_serial_exactly() {
        let cfg = ChipConfig::default();
        // Two tiny-ish profile cells; samples=1 keeps this fast.
        let spec = SweepSpec::models(&["alexnet", "gcn"], 0.4, &cfg, 1, 11);
        let serial: Vec<ModelSim> = Engine::serial().run_all(&spec.cells());
        let parallel: Vec<ModelSim> = Engine::new(4).run_all(&spec.cells());
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.per_op, b.per_op);
            assert_eq!(a.energy_base.total_pj().to_bits(), b.energy_base.total_pj().to_bits());
            assert_eq!(a.energy_td.total_pj().to_bits(), b.energy_td.total_pj().to_bits());
            // Scheduler-cache telemetry is per-cell (one cache per
            // run_passes call), so it too must not depend on workers.
            assert_eq!(a.sched, b.sched);
        }
    }
}
