//! The experiment engine: executes [`SimRequest`]s on a `std::thread`
//! worker pool, optionally through a shared [`UnitCache`].
//!
//! Design constraints:
//!
//! * **No new dependencies** — plain `std::thread::scope` workers over an
//!   atomic work index (rayon is unavailable offline).
//! * **Determinism** — every work item's result depends only on its own
//!   spec (config + workload + samples + derived seed), never on worker
//!   count or completion order; results are re-assembled in submission
//!   order and merged per cell in unit order. `--jobs 4` is
//!   byte-identical to `--jobs 1`. With a cache attached the same holds
//!   — a cache hit returns the byte-identical result the cold path
//!   would have computed (units are pure functions of their key), and
//!   hit/miss/coalesce telemetry is counted in a serial lookup phase so
//!   it too is independent of worker count.
//! * **Throughput** — requests are expanded through
//!   [`ModelPlan`](super::plan::ModelPlan) into per-(layer, op) units
//!   and the *flattened* cell×unit list feeds one work-stealing pool.
//!   A single `simulate resnet50` saturates every core (its ~160 units
//!   spread over the workers), and a fig13-style sweep load-balances at
//!   unit grain instead of whole-model grain. Under a cache, identical
//!   units across a batch's cells are coalesced onto one job (the
//!   dense-baseline cell of a TensorDash-vs-baseline sweep simulates
//!   once), and repeated requests skip simulation entirely.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::repro::{simulate_layer_op, ModelSim};
use crate::sim::unit::LayerOpSim;
use crate::trace::synthetic::random_bitmap;
use crate::util::rng::Rng;

use super::cache::{UnitCache, UnitKey};
use super::plan::ModelPlan;
use super::request::{SimRequest, Workload};

/// Number of workers the engine uses when the caller does not say
/// (`--jobs` unset): every available core.
pub fn default_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Executes requests; cheap to construct, freely shareable by reference.
#[derive(Debug, Clone)]
pub struct Engine {
    jobs: usize,
    cache: Option<Arc<UnitCache>>,
}

impl Engine {
    pub fn new(jobs: usize) -> Engine {
        Engine { jobs: jobs.max(1), cache: None }
    }

    /// A single-threaded engine (tests, tiny workloads).
    pub fn serial() -> Engine {
        Engine::new(1)
    }

    /// An engine using [`default_jobs`] workers.
    pub fn parallel() -> Engine {
        Engine::new(default_jobs())
    }

    /// Attach a shared unit cache: plan units are served from it when
    /// their canonical key matches, computed-and-inserted otherwise.
    /// Results are byte-identical with and without the cache.
    pub fn with_cache(mut self, cache: Arc<UnitCache>) -> Engine {
        self.cache = Some(cache);
        self
    }

    pub fn jobs(&self) -> usize {
        self.jobs
    }

    pub fn cache(&self) -> Option<&Arc<UnitCache>> {
        self.cache.as_ref()
    }

    /// Execute one request on the worker pool. A single model request
    /// still fans out: its plan's units fill every worker.
    pub fn run(&self, req: &SimRequest) -> ModelSim {
        self.run_all(std::slice::from_ref(req)).pop().expect("one request, one result")
    }

    /// Execute a batch of requests on the worker pool; results are in
    /// input order regardless of worker count.
    ///
    /// Every request that lowers to a [`ModelPlan`] contributes its
    /// units to one flat work list (nested cell×unit work stealing);
    /// workloads that stay monolithic (`RandomSparse`) ride the same
    /// pool as single items. Unit results are re-assembled by index and
    /// merged per cell in plan order, so the fold — including its f64
    /// energy sums — is identical for any worker count.
    pub fn run_all(&self, reqs: &[SimRequest]) -> Vec<ModelSim> {
        match &self.cache {
            Some(cache) => self.run_all_cached(reqs, cache),
            None => self.run_all_uncached(reqs),
        }
    }

    fn run_all_uncached(&self, reqs: &[SimRequest]) -> Vec<ModelSim> {
        enum Job<'p> {
            Unit { cell: usize, plan: &'p ModelPlan, unit: usize },
            Whole { cell: usize },
        }
        enum Out {
            Unit(LayerOpSim),
            Whole(ModelSim),
        }
        let plans: Vec<Option<ModelPlan>> = reqs.iter().map(ModelPlan::for_request).collect();
        let mut jobs: Vec<Job> = Vec::new();
        for (cell, plan) in plans.iter().enumerate() {
            match plan {
                Some(p) => {
                    jobs.extend((0..p.units.len()).map(|unit| Job::Unit { cell, plan: p, unit }))
                }
                None => jobs.push(Job::Whole { cell }),
            }
        }
        let outs = self.map(jobs.len(), |i| match &jobs[i] {
            Job::Unit { plan, unit, .. } => Out::Unit(plan.units[*unit].execute(&plan.cfg)),
            Job::Whole { cell } => Out::Whole(execute_monolithic(&reqs[*cell])),
        });
        // Deterministic merge: jobs were emitted cell-major / unit-minor
        // and `map` returns results in job order, so folding in sequence
        // reproduces each plan's unit order exactly.
        let mut sims: Vec<ModelSim> =
            reqs.iter().map(|r| ModelSim::empty(r.label.clone())).collect();
        for (job, out) in jobs.iter().zip(outs) {
            match (job, out) {
                (Job::Unit { cell, .. }, Out::Unit(u)) => sims[*cell].merge_unit(&u),
                (Job::Whole { cell }, Out::Whole(mut s)) => {
                    s.name = reqs[*cell].label.clone();
                    sims[*cell] = s;
                }
                _ => unreachable!("job/result kind mismatch"),
            }
        }
        sims
    }

    /// The cached execution path. Three deterministic phases:
    ///
    /// 1. **Lookup** (serial): every plan unit's canonical key is
    ///    probed against the cache; hits are collected, and misses are
    ///    deduplicated — the *first* occurrence of a key becomes a pool
    ///    job, later occurrences (other cells of the batch wanting the
    ///    same unit) coalesce onto it. Because this phase runs on the
    ///    calling thread in request order, the hit/miss/coalesce
    ///    telemetry is identical for any `--jobs N`.
    /// 2. **Compute** (pooled): unique missing units execute on the
    ///    work-stealing pool through
    ///    [`UnitCache::compute_coalesced`], which also folds in units
    ///    identical to ones in flight on *other* concurrent batches
    ///    (the serving path).
    /// 3. **Merge** (serial): per cell, in plan order, from hit or job
    ///    result — the same fold as the uncached path, so the merged
    ///    sims are byte-identical warm or cold. Cached entries are
    ///    shared across layers with identical geometry, so the unit's
    ///    `layer` label is re-stamped from the plan before merging.
    fn run_all_cached(&self, reqs: &[SimRequest], cache: &UnitCache) -> Vec<ModelSim> {
        enum Job<'p> {
            Unit { plan: &'p ModelPlan, unit: usize, key: UnitKey },
            Whole { cell: usize },
        }
        enum Out {
            Unit(LayerOpSim),
            Whole(ModelSim),
        }
        enum Source {
            Hit(LayerOpSim),
            Job(usize),
        }
        let plans: Vec<Option<ModelPlan>> = reqs.iter().map(ModelPlan::for_request).collect();
        let mut jobs: Vec<Job> = Vec::new();
        let mut cells: Vec<Vec<Source>> = Vec::with_capacity(reqs.len());
        let mut whole_job: Vec<Option<usize>> = vec![None; reqs.len()];
        // Batch-level coalescing: full key bytes -> job index of the
        // first (authoritative) occurrence. Keyed by the bytes, not the
        // 64-bit hash, so a collision can never merge distinct units.
        let mut pending: HashMap<Vec<u8>, usize> = HashMap::new();
        for (cell, plan) in plans.iter().enumerate() {
            match plan {
                Some(p) => {
                    let mut srcs = Vec::with_capacity(p.units.len());
                    for (ui, u) in p.units.iter().enumerate() {
                        let key = UnitKey::for_unit(&p.cfg, u);
                        if let Some(hit) = cache.lookup(&key) {
                            srcs.push(Source::Hit(hit));
                        } else if let Some(&j) = pending.get(&key.bytes) {
                            cache.note_coalesced(&key);
                            srcs.push(Source::Job(j));
                        } else {
                            let j = jobs.len();
                            pending.insert(key.bytes.clone(), j);
                            jobs.push(Job::Unit { plan: p, unit: ui, key });
                            srcs.push(Source::Job(j));
                        }
                    }
                    cells.push(srcs);
                }
                None => {
                    whole_job[cell] = Some(jobs.len());
                    jobs.push(Job::Whole { cell });
                    cells.push(Vec::new());
                }
            }
        }
        let mut outs: Vec<Option<Out>> = self
            .map(jobs.len(), |i| match &jobs[i] {
                Job::Unit { plan, unit, key } => Out::Unit(
                    cache.compute_coalesced(key, || plan.units[*unit].execute(&plan.cfg)),
                ),
                Job::Whole { cell } => Out::Whole(execute_monolithic(&reqs[*cell])),
            })
            .into_iter()
            .map(Some)
            .collect();
        let mut sims: Vec<ModelSim> =
            reqs.iter().map(|r| ModelSim::empty(r.label.clone())).collect();
        for (cell, plan) in plans.iter().enumerate() {
            match plan {
                Some(p) => {
                    for (ui, src) in cells[cell].iter().enumerate() {
                        let mut u = match src {
                            Source::Hit(u) => *u,
                            Source::Job(j) => match outs[*j].as_ref() {
                                Some(Out::Unit(u)) => *u,
                                _ => unreachable!("unit job produced a unit result"),
                            },
                        };
                        u.layer = p.units[ui].layer;
                        sims[cell].merge_unit(&u);
                    }
                }
                None => {
                    let j = whole_job[cell].expect("monolithic cell has a job");
                    match outs[j].take() {
                        Some(Out::Whole(mut s)) => {
                            s.name = reqs[cell].label.clone();
                            sims[cell] = s;
                        }
                        _ => unreachable!("whole job produced a whole result"),
                    }
                }
            }
        }
        sims
    }

    /// The pool primitive: compute `f(0..n)` with work stealing, return
    /// results in index order. `f` only sees the cell index, so any
    /// deterministic per-cell computation (not just `SimRequest`s) can
    /// ride the pool — the geometry/ablation sweeps use this directly.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let jobs = self.jobs.min(n.max(1));
        if jobs <= 1 {
            return (0..n).map(f).collect();
        }
        let next = AtomicUsize::new(0);
        let results: Mutex<Vec<(usize, T)>> = Mutex::new(Vec::with_capacity(n));
        std::thread::scope(|s| {
            for _ in 0..jobs {
                s.spawn(|| {
                    let mut local: Vec<(usize, T)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(i)));
                    }
                    results.lock().unwrap().extend(local);
                });
            }
        });
        let mut v = results.into_inner().unwrap();
        v.sort_by_key(|(i, _)| *i);
        v.into_iter().map(|(_, t)| t).collect()
    }
}

/// Execute a request that did not lower to a unit plan. Pure: depends
/// only on the request contents.
fn execute_monolithic(req: &SimRequest) -> ModelSim {
    match &req.workload {
        Workload::RandomSparse { shape, sparsity, samples_per_level, batch_mult } => {
            use crate::conv::TrainOp;
            // One rolling RNG stream feeds tensor draws *and* pass
            // sampling — the published Fig. 20 numbers depend on that
            // sequence, which is why this workload is not unit-split.
            let mut rng = Rng::new(req.seed);
            let mut sim = ModelSim::empty(req.label.clone());
            for draw in 0..*samples_per_level {
                let a = random_bitmap((shape.n, shape.h, shape.w, shape.c), *sparsity, &mut rng);
                let g = random_bitmap(
                    (shape.n, shape.out_h(), shape.out_w(), shape.f),
                    *sparsity,
                    &mut rng,
                );
                for op in TrainOp::ALL {
                    let mut r = simulate_layer_op(
                        &req.cfg,
                        shape,
                        op,
                        &a,
                        &g,
                        req.samples,
                        *batch_mult,
                        &mut rng,
                    );
                    r.layer = draw; // unit index = tensor draw
                    sim.merge_unit(&r);
                }
            }
            sim
        }
        // Plannable workloads never reach this path (`run_all` expands
        // them); keep a correct fallback anyway.
        _ => {
            let plan = ModelPlan::for_request(req).expect("plannable workload");
            let mut sim = plan.execute_serial();
            sim.name = req.label.clone();
            sim
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::SweepSpec;
    use crate::config::ChipConfig;

    #[test]
    fn map_preserves_order_and_covers_all_indices() {
        let e = Engine::new(4);
        let out = e.map(97, |i| i * 3);
        assert_eq!(out.len(), 97);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * 3);
        }
        // Serial path too.
        assert_eq!(Engine::serial().map(5, |i| i), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn single_request_fans_out_units_and_retains_them() {
        let req = SimRequest::profile("alexnet", 0.4, ChipConfig::default(), 1, 5).unwrap();
        let serial = Engine::serial().run(&req);
        let parallel = Engine::new(4).run(&req);
        // One model request is many unit jobs — and still byte-stable.
        assert_eq!(serial, parallel);
        assert_eq!(serial.layers.len(), 8 * 3, "alexnet: 8 layers x 3 ops");
        // Units arrive in plan order whatever the worker interleaving.
        for (i, u) in serial.layers.iter().enumerate() {
            assert_eq!(u.layer, i / 3);
            assert_eq!(u.op as usize, i % 3);
        }
    }

    #[test]
    fn parallel_sweep_matches_serial_exactly() {
        let cfg = ChipConfig::default();
        // Two tiny-ish profile cells; samples=1 keeps this fast.
        let spec = SweepSpec::models(&["alexnet", "gcn"], 0.4, &cfg, 1, 11);
        let serial: Vec<ModelSim> = Engine::serial().run_all(&spec.cells());
        let parallel: Vec<ModelSim> = Engine::new(4).run_all(&spec.cells());
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.per_op, b.per_op);
            assert_eq!(a.energy_base.total_pj().to_bits(), b.energy_base.total_pj().to_bits());
            assert_eq!(a.energy_td.total_pj().to_bits(), b.energy_td.total_pj().to_bits());
            // Scheduler-cache telemetry is per-cell (one cache per
            // run_passes call), so it too must not depend on workers.
            assert_eq!(a.sched, b.sched);
        }
    }

    #[test]
    fn cached_engine_matches_uncached_bytes_and_coalesces_duplicates() {
        let cfg = ChipConfig::default();
        let req = SimRequest::profile("gcn", 0.4, cfg.clone(), 1, 11).unwrap();
        let plain = Engine::new(2).run(&req);

        let cache = Arc::new(UnitCache::new(1024));
        let cached_engine = Engine::new(2).with_cache(Arc::clone(&cache));
        // Cold: every unit misses, computes, inserts.
        let cold = cached_engine.run(&req);
        assert_eq!(plain, cold, "cold cached run must equal the uncached run");
        let s = cache.stats();
        assert_eq!(s.hits, 0);
        assert_eq!(s.misses as usize, plain.layers.len());
        // Warm: every unit hits; bytes identical.
        let warm = cached_engine.run(&req);
        assert_eq!(plain, warm, "warm run must be byte-identical to cold");
        let s = cache.stats();
        assert_eq!(s.hits as usize, plain.layers.len());

        // A batch with a duplicated cell coalesces instead of recomputing.
        let cache2 = Arc::new(UnitCache::new(1024));
        let e2 = Engine::new(2).with_cache(Arc::clone(&cache2));
        let pair = e2.run_all(&[req.clone(), req.clone()]);
        assert_eq!(pair[0], pair[1]);
        assert_eq!(pair[0].per_op, plain.per_op);
        let s2 = cache2.stats();
        assert_eq!(s2.coalesced as usize, plain.layers.len(), "second cell rides the first");
        assert_eq!(s2.inserts as usize, plain.layers.len(), "each unique unit computed once");
    }

    #[test]
    fn shared_profile_requests_match_named_requests() {
        use crate::trace::profiles::ModelProfile;
        let cfg = ChipConfig::default();
        let named = SimRequest::profile("gcn", 0.4, cfg.clone(), 1, 3).unwrap();
        let shared = SimRequest::profile_shared(
            Arc::new(ModelProfile::for_model("gcn").unwrap()),
            0.4,
            cfg,
            1,
            3,
        );
        let e = Engine::new(2);
        assert_eq!(e.run(&named), e.run(&shared));
    }
}
