//! Accelerator configuration — defaults are the paper's Table 2.

/// Arithmetic datatype of the MAC datapath. The PE is datatype agnostic
/// (paper §3); the datatype only affects the area/power model (§4.4) and
/// operand width used by the memory model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataType {
    Fp32,
    Bf16,
}

impl DataType {
    pub fn bytes(self) -> u64 {
        match self {
            DataType::Fp32 => 4,
            DataType::Bf16 => 2,
        }
    }
}

/// Which operand sides the front-end extracts sparsity from (§3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SparsitySide {
    /// Tile configuration of Fig. 11: one scheduler per row, B side only.
    /// This is the evaluated default — "there is sufficient sparsity on
    /// one of the operands in each of the three major operations".
    BSide,
    /// Full per-PE configuration of Fig. 8: AZ & BZ both considered.
    Both,
}

/// Chip configuration (Table 2 defaults).
#[derive(Debug, Clone, PartialEq)]
pub struct ChipConfig {
    /// MAC lanes per PE (16 in the paper; the scheduler structure is
    /// specialised for 16).
    pub lanes: usize,
    /// Staging buffer depth in rows: 3 (lookahead 2) or 2 (lookahead 1).
    pub staging_depth: usize,
    /// PE rows per tile.
    pub tile_rows: usize,
    /// PE columns per tile.
    pub tile_cols: usize,
    /// Number of tiles on the chip.
    pub tiles: usize,
    /// Core clock in MHz.
    pub freq_mhz: u64,
    /// Datapath datatype.
    pub dtype: DataType,
    /// Sparsity extraction configuration.
    pub side: SparsitySide,
    /// AM/BM/CM SRAM: bytes per bank and banks per tile.
    pub sram_bank_bytes: u64,
    pub sram_banks: u64,
    /// Scratchpads: bytes per bank, banks per pad.
    pub spad_bytes: u64,
    pub spad_banks: u64,
    /// Number of 16x16 transposers (§3.4).
    pub transposers: u64,
    /// Off-chip: LPDDR4-3200, 4 channels => peak bytes/sec.
    pub dram_gbps: f64,
    /// Whether TensorDash-specific components are power-gated when a
    /// tensor shows no sparsity (§3.5).
    pub power_gate: bool,
    /// Inter-row lead bound in stream rows for the shared A-side storage
    /// (see sim::tile). 0 = per-cycle lockstep; large = free running.
    pub lead_limit: usize,
    /// Gate performance on DRAM bandwidth (extension; the paper's
    /// performance simulator is evidently compute-bound — e.g. Fig. 20
    /// shows near-ideal speedup at 10% sparsity, impossible under a
    /// bandwidth gate — so the default is off and DRAM traffic feeds
    /// only the energy model, like the paper's).
    pub dram_gate: bool,
}

impl Default for ChipConfig {
    fn default() -> Self {
        ChipConfig {
            lanes: 16,
            staging_depth: 3,
            tile_rows: 4,
            tile_cols: 4,
            tiles: 16,
            freq_mhz: 500,
            dtype: DataType::Fp32,
            side: SparsitySide::BSide,
            sram_bank_bytes: 256 * 1024,
            sram_banks: 4,
            spad_bytes: 1024,
            spad_banks: 3,
            transposers: 15,
            dram_gbps: 51.2, // 4 x LPDDR4-3200 x32
            power_gate: false,
            lead_limit: crate::sim::tile::DEFAULT_LEAD_LIMIT,
            dram_gate: false,
        }
    }
}

impl ChipConfig {
    /// Total MAC throughput per cycle (4096 for the default config).
    pub fn macs_per_cycle(&self) -> u64 {
        (self.lanes * self.tile_rows * self.tile_cols * self.tiles) as u64
    }

    /// Total PEs (256 for the default config).
    pub fn total_pes(&self) -> u64 {
        (self.tile_rows * self.tile_cols * self.tiles) as u64
    }

    /// Peak DRAM bytes available per core cycle.
    pub fn dram_bytes_per_cycle(&self) -> f64 {
        self.dram_gbps * 1e9 / (self.freq_mhz as f64 * 1e6)
    }

    pub fn with_depth(mut self, depth: usize) -> Self {
        assert!(depth == 2 || depth == 3, "staging depth must be 2 or 3");
        self.staging_depth = depth;
        self
    }

    pub fn with_geometry(mut self, rows: usize, cols: usize) -> Self {
        self.tile_rows = rows;
        self.tile_cols = cols;
        self
    }

    pub fn with_dtype(mut self, dtype: DataType) -> Self {
        self.dtype = dtype;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_defaults() {
        let c = ChipConfig::default();
        assert_eq!(c.macs_per_cycle(), 4096);
        assert_eq!(c.total_pes(), 256);
        assert_eq!(c.lanes, 16);
        assert_eq!(c.staging_depth, 3);
        assert_eq!(c.tiles, 16);
    }

    #[test]
    fn dram_bandwidth_per_cycle() {
        let c = ChipConfig::default();
        // 51.2 GB/s at 500 MHz = 102.4 B/cycle.
        assert!((c.dram_bytes_per_cycle() - 102.4).abs() < 1e-9);
    }
}
