//! Area, power and energy model.
//!
//! Substitution (DESIGN.md): the paper's numbers come from Synopsys DC +
//! Cadence Innovus at 65nm plus CACTI and Micron's DRAM power model —
//! none of which exist here. The quantities the paper reports are
//! *ratios over a component breakdown*, so this module carries that
//! breakdown directly: the per-component silicon constants are taken
//! from the paper's Table 3 (65nm, 500 MHz) and the SRAM/DRAM per-access
//! energies are CACTI/LPDDR4-class constants chosen once (documented
//! below) and never tuned per experiment.
//!
//! Energy accounting follows the constant-power model the paper's own
//! arithmetic implies (compute energy-efficiency 1.89x ~= speedup 1.95x
//! / power overhead 1.02x): component energy = component power x busy
//! time; memory energy = per-access energy x access counts.

use crate::config::{ChipConfig, DataType};
use crate::sim::dram::DramTraffic;
use crate::sim::memory::SramCounts;
use crate::sim::transposer::TransposerWork;

/// Per-component silicon numbers for the **default Table 2 geometry**
/// (256 PEs, 16 tiles of 4x4, 16 MACs/PE, 65nm, 500 MHz).
#[derive(Debug, Clone, Copy)]
pub struct SiliconTable {
    /// Baseline compute cores (MACs + accumulators + control).
    pub core_area_mm2: f64,
    pub core_power_mw: f64,
    /// TensorDash schedulers + B-side muxes (one scheduler per tile row).
    pub sched_bmux_area_mm2: f64,
    pub sched_bmux_power_mw: f64,
    /// TensorDash A-side mux blocks (per PE).
    pub amux_area_mm2: f64,
    pub amux_power_mw: f64,
    /// Transposers (§3.4) — part of TensorDash's memory path.
    pub transposer_area_mm2: f64,
    pub transposer_power_mw: f64,
}

/// Paper Table 3 (FP32).
pub const FP32_TABLE: SiliconTable = SiliconTable {
    core_area_mm2: 30.41,
    core_power_mw: 13_910.0,
    sched_bmux_area_mm2: 0.91,
    sched_bmux_power_mw: 102.8,
    amux_area_mm2: 1.73,
    amux_power_mw: 145.3,
    transposer_area_mm2: 0.38,
    transposer_power_mw: 47.3,
};

/// bfloat16 variant (§4.4): multiplier cores scale ~quadratically, the
/// datapath muxes/comparators ~linearly, and the priority encoders not
/// at all — yielding the paper's 1.13x area / 1.05x power overheads.
pub const BF16_TABLE: SiliconTable = SiliconTable {
    core_area_mm2: 13.00,
    core_power_mw: 5_600.0,
    sched_bmux_area_mm2: 0.71,
    sched_bmux_power_mw: 100.0,
    amux_area_mm2: 0.88,
    amux_power_mw: 140.0,
    transposer_area_mm2: 0.19,
    transposer_power_mw: 40.0,
};

/// On-chip memory macros (CACTI-class, 65nm). One AM/BM/CM chunk is
/// 256KB x 4 banks x 16 tiles; the paper reports 192 mm^2 per chunk.
pub const SRAM_CHUNK_AREA_MM2: f64 = 192.0;
pub const SPAD_TOTAL_AREA_MM2: f64 = 17.0;

/// Per-access energies (documented constants, not per-experiment tuning):
/// 64B row from a 256KB bank ~ 45 pJ (CACTI 65nm class); 1KB scratchpad
/// row ~ 3 pJ; LPDDR4 ~ 30 pJ/byte incl. PHY + DRAM core.
pub const SRAM_ROW_PJ: f64 = 45.0;
pub const SPAD_ROW_PJ: f64 = 3.0;
pub const DRAM_PJ_PER_BYTE: f64 = 30.0;

impl SiliconTable {
    pub fn for_dtype(dtype: DataType) -> &'static SiliconTable {
        match dtype {
            DataType::Fp32 => &FP32_TABLE,
            DataType::Bf16 => &BF16_TABLE,
        }
    }

    /// SRAM row energy scales with the data width.
    pub fn sram_row_pj(dtype: DataType) -> f64 {
        match dtype {
            DataType::Fp32 => SRAM_ROW_PJ,
            DataType::Bf16 => SRAM_ROW_PJ * 0.62, // 32B rows
        }
    }
}

/// Area report (Table 3 + the whole-chip variant discussed in §4.3).
#[derive(Debug, Clone, Copy)]
pub struct AreaReport {
    pub core_mm2: f64,
    pub sched_bmux_mm2: f64,
    pub amux_mm2: f64,
    pub transposer_mm2: f64,
    pub sram_mm2: f64,
    pub spad_mm2: f64,
}

impl AreaReport {
    pub fn compute(cfg: &ChipConfig) -> AreaReport {
        let t = SiliconTable::for_dtype(cfg.dtype);
        // Scale from the default 256-PE geometry.
        let pe_scale = cfg.total_pes() as f64 / 256.0;
        let row_scale = (cfg.tiles * cfg.tile_rows) as f64 / 64.0;
        let sram_scale = (cfg.sram_bank_bytes * cfg.sram_banks * cfg.tiles as u64) as f64
            / (256.0 * 1024.0 * 4.0 * 16.0);
        AreaReport {
            core_mm2: t.core_area_mm2 * pe_scale,
            sched_bmux_mm2: t.sched_bmux_area_mm2 * row_scale,
            amux_mm2: t.amux_area_mm2 * pe_scale,
            transposer_mm2: t.transposer_area_mm2 * cfg.transposers as f64 / 15.0,
            sram_mm2: 3.0 * SRAM_CHUNK_AREA_MM2 * sram_scale,
            spad_mm2: SPAD_TOTAL_AREA_MM2 * pe_scale,
        }
    }

    pub fn tensordash_compute(&self) -> f64 {
        self.core_mm2 + self.sched_bmux_mm2 + self.amux_mm2 + self.transposer_mm2
    }

    pub fn baseline_compute(&self) -> f64 {
        self.core_mm2
    }

    pub fn compute_overhead(&self) -> f64 {
        self.tensordash_compute() / self.baseline_compute()
    }

    pub fn whole_chip_overhead(&self) -> f64 {
        let mem = self.sram_mm2 + self.spad_mm2;
        (self.tensordash_compute() + mem) / (self.baseline_compute() + mem)
    }
}

/// Energy of one simulated layer-op (or a whole model when merged).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub core_pj: f64,
    /// TensorDash-specific compute overhead (schedulers, muxes,
    /// transposers). Zero for the baseline.
    pub overhead_pj: f64,
    pub sram_pj: f64,
    pub spad_pj: f64,
    pub dram_pj: f64,
}

impl EnergyBreakdown {
    pub fn total_pj(&self) -> f64 {
        self.core_pj + self.overhead_pj + self.sram_pj + self.spad_pj + self.dram_pj
    }

    pub fn compute_pj(&self) -> f64 {
        self.core_pj + self.overhead_pj
    }

    pub fn merge(&mut self, o: &EnergyBreakdown) {
        self.core_pj += o.core_pj;
        self.overhead_pj += o.overhead_pj;
        self.sram_pj += o.sram_pj;
        self.spad_pj += o.spad_pj;
        self.dram_pj += o.dram_pj;
    }
}

/// Energy model front door.
pub struct EnergyModel {
    pub cfg: ChipConfig,
    table: &'static SiliconTable,
}

impl EnergyModel {
    pub fn new(cfg: ChipConfig) -> Self {
        let table = SiliconTable::for_dtype(cfg.dtype);
        EnergyModel { cfg, table }
    }

    fn pj_per_cycle(&self, power_mw: f64) -> f64 {
        // mW / MHz = nJ/cycle; x1000 = pJ/cycle.
        power_mw / self.cfg.freq_mhz as f64 * 1000.0
    }

    /// Energy for a layer-op given its *chip* cycle count and access
    /// counts. `tensordash` selects whether the sparsity front-end is
    /// powered (false = baseline, or power-gated TensorDash §3.5).
    pub fn layer_energy(
        &self,
        chip_cycles: u64,
        sram: &SramCounts,
        dram: &DramTraffic,
        transposers: &TransposerWork,
        tensordash: bool,
    ) -> EnergyBreakdown {
        let pe_scale = self.cfg.total_pes() as f64 / 256.0;
        let row_scale = (self.cfg.tiles * self.cfg.tile_rows) as f64 / 64.0;
        let core = self.pj_per_cycle(self.table.core_power_mw * pe_scale) * chip_cycles as f64;
        let overhead = if tensordash {
            self.pj_per_cycle(
                self.table.sched_bmux_power_mw * row_scale
                    + self.table.amux_power_mw * pe_scale,
            ) * chip_cycles as f64
                + self.pj_per_cycle(self.table.transposer_power_mw)
                    * transposers.min_cycles(self.cfg.transposers).min(chip_cycles) as f64
        } else {
            0.0
        };
        EnergyBreakdown {
            core_pj: core,
            overhead_pj: overhead,
            sram_pj: sram.sram_rows() as f64 * SiliconTable::sram_row_pj(self.cfg.dtype),
            spad_pj: sram.spad_rows() as f64 * SPAD_ROW_PJ,
            dram_pj: dram.total() as f64 * DRAM_PJ_PER_BYTE,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipConfig;

    #[test]
    fn table3_fp32_ratios() {
        let cfg = ChipConfig::default();
        let a = AreaReport::compute(&cfg);
        // Paper: 33.44 / 30.80 ~ 1.09x compute-area overhead. (Our
        // baseline core is 30.41 — Table 3's 30.80 includes misc.)
        let ovh = a.compute_overhead();
        assert!((1.08..1.11).contains(&ovh), "compute overhead {ovh}");
        // Whole chip: ~1.0005x (the paper's "imperceptible").
        let whole = a.whole_chip_overhead();
        assert!(whole < 1.006, "whole-chip overhead {whole}");
        assert!(whole > 1.0);
    }

    #[test]
    fn table3_bf16_ratios() {
        let cfg = ChipConfig::default().with_dtype(DataType::Bf16);
        let a = AreaReport::compute(&cfg);
        let ovh = a.compute_overhead();
        assert!((1.11..1.16).contains(&ovh), "bf16 compute overhead {ovh}");
    }

    #[test]
    fn power_overhead_two_percent() {
        // schedulers+muxes vs core: (102.8 + 145.3) / 13910 ~ 1.8%.
        let t = FP32_TABLE;
        let ovh = (t.sched_bmux_power_mw + t.amux_power_mw) / t.core_power_mw;
        assert!(ovh < 0.025 && ovh > 0.015);
        // bf16: ~5% (paper §4.4: 1.05x).
        let t = BF16_TABLE;
        let ovh = (t.sched_bmux_power_mw + t.amux_power_mw + t.transposer_power_mw)
            / t.core_power_mw;
        assert!((0.04..0.06).contains(&ovh), "bf16 power overhead {ovh}");
    }

    #[test]
    fn energy_ratio_tracks_speedup() {
        // Same work, TensorDash finishes 2x faster with ~2% more power
        // => compute energy efficiency just under 2x.
        let m = EnergyModel::new(ChipConfig::default());
        let sram = SramCounts::default();
        let dram = DramTraffic::default();
        let tw = TransposerWork::default();
        let base = m.layer_energy(1000, &sram, &dram, &tw, false);
        let td = m.layer_energy(500, &sram, &dram, &tw, true);
        let eff = base.total_pj() / td.total_pj();
        assert!(eff > 1.9 && eff < 2.0, "eff {eff}");
    }

    #[test]
    fn memory_energy_identical_across_designs() {
        let m = EnergyModel::new(ChipConfig::default());
        let sram = SramCounts { bm_reads: 1000, am_reads: 1000, ..Default::default() };
        let dram = DramTraffic { read_bytes: 4096, write_bytes: 0 };
        let tw = TransposerWork::default();
        let base = m.layer_energy(100, &sram, &dram, &tw, false);
        let td = m.layer_energy(50, &sram, &dram, &tw, true);
        assert_eq!(base.sram_pj, td.sram_pj);
        assert_eq!(base.dram_pj, td.dram_pj);
    }
}
