//! Declarative search spaces over [`ChipConfig`] axes.
//!
//! A [`SearchSpace`] is a small grid description: for each configurable
//! chip axis (staging depth, tile geometry, tile count, lane count,
//! datatype, sparsity side, SRAM/scratchpad sizing, transposer count) an
//! ordered list of candidate values. A [`Candidate`] is one index per
//! axis; [`SearchSpace::config`] lowers it to a concrete `ChipConfig`.
//!
//! **Content addressing.** A candidate's canonical encoding is the
//! canonical JSON of its full chip configuration —
//! [`crate::api::cache::cfg_json`], the *same* document that forms the
//! `cfg` fragment of every [`crate::api::UnitKey`] its evaluation
//! produces — hashed with the shared [`crate::util::hash::fnv1a64`].
//! Two candidates with equal ids are the same design point whatever
//! axis indices produced them, so the explorer dedupes re-visited
//! configurations exactly as the unit cache dedupes their units.
//!
//! Candidate ids are **stable across unit-key format bumps**: the unit
//! cache moved its key to a binary v2 encoding (DESIGN.md §4), but
//! candidate identity stays FNV-1a over the canonical-JSON `cfg`
//! fragment — explore reports render ids as `{:016x}`, so changing
//! this encoding would silently change every published frontier id.
//! The pinned-id test below locks the origin candidate's id.
//!
//! Axis values are validated against per-axis bounds at construction
//! time (the calling thread), never inside a worker: the cycle
//! simulator hard-asserts some of them (16 lanes, staging depth 2 or
//! 3), and a zero bank count would divide-by-zero deep in the memory
//! model.

use std::collections::BTreeMap;

use crate::api::cache::cfg_json;
use crate::config::{ChipConfig, DataType, SparsitySide};
use crate::util::hash::fnv1a64;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Schema tag of an on-disk space file (`explore --space FILE`).
pub const SPACE_SCHEMA: &str = "tensordash.space.v1";

/// Canonical axis order. Every space carries all axes (singleton axes
/// pin their field to one value), in exactly this order — candidate
/// indices, labels and mutation neighborhoods all key off it.
pub const AXIS_NAMES: [&str; 12] = [
    "staging_depth",
    "tile_rows",
    "tile_cols",
    "tiles",
    "lanes",
    "dtype",
    "side",
    "sram_bank_bytes",
    "sram_banks",
    "spad_bytes",
    "spad_banks",
    "transposers",
];

/// Human-readable bounds per axis (the `info` listing and error
/// messages). Bounds reflect what the simulator accepts today — e.g.
/// the scheduler is specialised for 16 lanes, so that axis is fixed.
pub fn axis_bounds(name: &str) -> &'static str {
    match name {
        "staging_depth" => "{2, 3} (lookahead 1 or 2)",
        "tile_rows" => "1..=64",
        "tile_cols" => "1..=64",
        "tiles" => "1..=256",
        "lanes" => "{16} (scheduler is specialised for 16 lanes)",
        "dtype" => "{fp32, bf16}",
        "side" => "{b, both}",
        "sram_bank_bytes" => "1024..=16777216",
        "sram_banks" => "1..=64",
        "spad_bytes" => "64..=1048576",
        "spad_banks" => "1..=16",
        "transposers" => "1..=64",
        _ => "unknown axis",
    }
}

/// Canonicalize + bounds-check one axis value token. Returns the
/// canonical token (numbers are re-rendered, so `"04"` and `"4"` are
/// the same value).
fn canon_token(name: &str, token: &str) -> Result<String, String> {
    let bad = |t: &str| format!("axis '{name}': bad value '{t}' (bounds: {})", axis_bounds(name));
    let num = |t: &str, lo: u64, hi: u64| -> Result<String, String> {
        let v: u64 = t.trim().parse().map_err(|_| bad(t))?;
        if v < lo || v > hi {
            return Err(bad(t));
        }
        Ok(v.to_string())
    };
    match name {
        "staging_depth" => num(token, 2, 3),
        "tile_rows" | "tile_cols" => num(token, 1, 64),
        "tiles" => num(token, 1, 256),
        "lanes" => num(token, 16, 16),
        "dtype" => match token.trim() {
            "fp32" => Ok("fp32".to_string()),
            "bf16" => Ok("bf16".to_string()),
            t => Err(bad(t)),
        },
        "side" => match token.trim() {
            "b" => Ok("b".to_string()),
            "both" => Ok("both".to_string()),
            t => Err(bad(t)),
        },
        "sram_bank_bytes" => num(token, 1024, 16 * 1024 * 1024),
        "sram_banks" => num(token, 1, 64),
        "spad_bytes" => num(token, 64, 1024 * 1024),
        "spad_banks" => num(token, 1, 16),
        "transposers" => num(token, 1, 64),
        _ => Err(format!(
            "unknown axis '{name}' (axes: {})",
            AXIS_NAMES.join(", ")
        )),
    }
}

/// Apply one canonical axis token to a config. Tokens are produced by
/// [`canon_token`], so the parses here cannot fail.
fn apply_token(cfg: &mut ChipConfig, name: &str, token: &str) {
    let v = || token.parse::<u64>().expect("canonical numeric token");
    match name {
        "staging_depth" => cfg.staging_depth = v() as usize,
        "tile_rows" => cfg.tile_rows = v() as usize,
        "tile_cols" => cfg.tile_cols = v() as usize,
        "tiles" => cfg.tiles = v() as usize,
        "lanes" => cfg.lanes = v() as usize,
        "dtype" => {
            cfg.dtype = match token {
                "bf16" => DataType::Bf16,
                _ => DataType::Fp32,
            }
        }
        "side" => {
            cfg.side = match token {
                "both" => SparsitySide::Both,
                _ => SparsitySide::BSide,
            }
        }
        "sram_bank_bytes" => cfg.sram_bank_bytes = v(),
        "sram_banks" => cfg.sram_banks = v(),
        "spad_bytes" => cfg.spad_bytes = v(),
        "spad_banks" => cfg.spad_banks = v(),
        "transposers" => cfg.transposers = v(),
        _ => unreachable!("axis names validated at construction"),
    }
}

/// The default config's canonical token for an axis (the value a
/// singleton axis pins, and the origin candidate's preferred value).
fn default_token(name: &str) -> String {
    let d = ChipConfig::default();
    match name {
        "staging_depth" => d.staging_depth.to_string(),
        "tile_rows" => d.tile_rows.to_string(),
        "tile_cols" => d.tile_cols.to_string(),
        "tiles" => d.tiles.to_string(),
        "lanes" => d.lanes.to_string(),
        "dtype" => "fp32".to_string(),
        "side" => "b".to_string(),
        "sram_bank_bytes" => d.sram_bank_bytes.to_string(),
        "sram_banks" => d.sram_banks.to_string(),
        "spad_bytes" => d.spad_bytes.to_string(),
        "spad_banks" => d.spad_banks.to_string(),
        "transposers" => d.transposers.to_string(),
        _ => unreachable!("axis names validated at construction"),
    }
}

/// One axis: its canonical name and ordered, validated value tokens.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Axis {
    pub name: String,
    pub values: Vec<String>,
}

/// One candidate design point: an index into each axis, in
/// [`AXIS_NAMES`] order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Candidate {
    pub indices: Vec<usize>,
}

/// A declarative grid over [`ChipConfig`] axes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SearchSpace {
    axes: Vec<Axis>,
}

impl SearchSpace {
    /// The trivial space: every axis a singleton at the Table-2 default.
    pub fn trivial() -> SearchSpace {
        SearchSpace {
            axes: AXIS_NAMES
                .iter()
                .map(|n| Axis { name: n.to_string(), values: vec![default_token(n)] })
                .collect(),
        }
    }

    /// The default exploration space (the Figs. 17–19 design axes):
    /// staging depth × tile rows × tile cols, everything else pinned.
    pub fn default_space() -> SearchSpace {
        let mut s = SearchSpace::trivial();
        s.set_axis("staging_depth", &["2", "3"]).expect("static axis values");
        s.set_axis("tile_rows", &["1", "2", "4", "8", "16"]).expect("static axis values");
        s.set_axis("tile_cols", &["4", "8", "16"]).expect("static axis values");
        s
    }

    /// Replace one axis's value list (validated, deduped in order).
    pub fn set_axis(&mut self, name: &str, values: &[&str]) -> Result<(), String> {
        let slot = self
            .axes
            .iter_mut()
            .find(|a| a.name == name)
            .ok_or_else(|| format!("unknown axis '{name}' (axes: {})", AXIS_NAMES.join(", ")))?;
        let mut canon: Vec<String> = Vec::with_capacity(values.len());
        for v in values {
            let c = canon_token(name, v)?;
            if !canon.contains(&c) {
                canon.push(c);
            }
        }
        if canon.is_empty() {
            return Err(format!("axis '{name}': needs at least one value"));
        }
        slot.values = canon;
        Ok(())
    }

    /// Build a space from `--axis name=v1,v2` style pairs: named axes
    /// get the given values, unnamed axes stay pinned at the default.
    pub fn from_pairs(pairs: &[(String, String)]) -> Result<SearchSpace, String> {
        let mut s = SearchSpace::trivial();
        for (name, list) in pairs {
            let values: Vec<&str> =
                list.split(',').map(str::trim).filter(|v| !v.is_empty()).collect();
            s.set_axis(name, &values)?;
        }
        Ok(s)
    }

    /// Parse a `tensordash.space.v1` document:
    /// `{"schema":"tensordash.space.v1","axes":{"staging_depth":[2,3],...}}`
    /// (values may be numbers or strings). Unnamed axes stay pinned.
    pub fn from_json(j: &Json) -> Result<SearchSpace, String> {
        match j.get("schema").and_then(Json::as_str) {
            Some(SPACE_SCHEMA) => {}
            other => return Err(format!("expected schema '{SPACE_SCHEMA}', got {other:?}")),
        }
        let axes = match j.get("axes") {
            Some(Json::Obj(m)) => m,
            _ => return Err("space file needs an 'axes' object".to_string()),
        };
        let mut s = SearchSpace::trivial();
        for (name, vals) in axes {
            let arr = vals
                .as_arr()
                .ok_or_else(|| format!("axis '{name}': values must be an array"))?;
            let mut tokens: Vec<String> = Vec::with_capacity(arr.len());
            for v in arr {
                tokens.push(match v {
                    Json::Str(t) => t.clone(),
                    Json::Num(n) => {
                        if n.trunc() != *n || *n < 0.0 {
                            return Err(format!("axis '{name}': bad numeric value {n}"));
                        }
                        format!("{}", *n as u64)
                    }
                    _ => return Err(format!("axis '{name}': values must be numbers or strings")),
                });
            }
            let refs: Vec<&str> = tokens.iter().map(String::as_str).collect();
            s.set_axis(name, &refs)?;
        }
        Ok(s)
    }

    pub fn axes(&self) -> &[Axis] {
        &self.axes
    }

    /// Axes with more than one value — the ones actually searched.
    pub fn free_axes(&self) -> impl Iterator<Item = &Axis> {
        self.axes.iter().filter(|a| a.values.len() > 1)
    }

    /// Number of distinct candidates (product of axis arities).
    pub fn size(&self) -> u64 {
        self.axes.iter().fold(1u64, |acc, a| acc.saturating_mul(a.values.len() as u64))
    }

    /// The candidate closest to the Table-2 default: per axis, the
    /// default value's index when present, else index 0.
    pub fn origin(&self) -> Candidate {
        Candidate {
            indices: self
                .axes
                .iter()
                .map(|a| {
                    let d = default_token(&a.name);
                    a.values.iter().position(|v| *v == d).unwrap_or(0)
                })
                .collect(),
        }
    }

    /// Uniform deterministic sample (one index draw per axis, in axis
    /// order — the stream consumption is part of the determinism
    /// contract).
    pub fn sample(&self, rng: &mut Rng) -> Candidate {
        Candidate {
            indices: self.axes.iter().map(|a| rng.below(a.values.len())).collect(),
        }
    }

    /// The candidate's mutation neighborhood: each free axis stepped
    /// one index down, then one up (axis-major order, deterministic).
    pub fn neighbors(&self, c: &Candidate) -> Vec<Candidate> {
        let mut out = Vec::new();
        for (ai, axis) in self.axes.iter().enumerate() {
            if axis.values.len() < 2 {
                continue;
            }
            if c.indices[ai] > 0 {
                let mut n = c.clone();
                n.indices[ai] -= 1;
                out.push(n);
            }
            if c.indices[ai] + 1 < axis.values.len() {
                let mut n = c.clone();
                n.indices[ai] += 1;
                out.push(n);
            }
        }
        out
    }

    /// Lower a candidate to its chip configuration.
    pub fn config(&self, c: &Candidate) -> ChipConfig {
        assert_eq!(c.indices.len(), self.axes.len(), "candidate/space arity mismatch");
        let mut cfg = ChipConfig::default();
        for (axis, &i) in self.axes.iter().zip(&c.indices) {
            apply_token(&mut cfg, &axis.name, &axis.values[i]);
        }
        cfg
    }

    /// Canonical encoding: the candidate's full config as canonical
    /// JSON — exactly the `cfg` fragment of the unit keys its
    /// evaluation produces, so candidate identity and unit-cache
    /// addressing can never disagree.
    pub fn canon(&self, c: &Candidate) -> String {
        cfg_json(&self.config(c)).render()
    }

    /// Content address of a candidate: FNV-1a of [`Self::canon`].
    pub fn id(&self, c: &Candidate) -> u64 {
        fnv1a64(self.canon(c).as_bytes())
    }

    /// Short human label: `axis=value` for every free axis (singleton
    /// axes are implied), or `"default"` when nothing is free.
    pub fn label(&self, c: &Candidate) -> String {
        let parts: Vec<String> = self
            .axes
            .iter()
            .zip(&c.indices)
            .filter(|(a, _)| a.values.len() > 1)
            .map(|(a, &i)| format!("{}={}", a.name, a.values[i]))
            .collect();
        if parts.is_empty() {
            "default".to_string()
        } else {
            parts.join(" ")
        }
    }

    /// The space as a `tensordash.space.v1` JSON document (free axes
    /// only — pinned axes are implied by the schema's defaults).
    pub fn to_json(&self) -> Json {
        let mut axes = BTreeMap::new();
        for a in self.free_axes() {
            axes.insert(
                a.name.clone(),
                Json::Arr(a.values.iter().map(|v| Json::Str(v.clone())).collect()),
            );
        }
        let mut m = BTreeMap::new();
        m.insert("schema".to_string(), Json::Str(SPACE_SCHEMA.to_string()));
        m.insert("axes".to_string(), Json::Obj(axes));
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trivial_space_is_the_default_config() {
        let s = SearchSpace::trivial();
        assert_eq!(s.size(), 1);
        let c = s.origin();
        let cfg = s.config(&c);
        assert_eq!(cfg.staging_depth, 3);
        assert_eq!(cfg.tile_rows, 4);
        assert_eq!(cfg.macs_per_cycle(), ChipConfig::default().macs_per_cycle());
        assert_eq!(s.label(&c), "default");
        // Canonical encoding is the unit-key cfg fragment.
        assert_eq!(s.canon(&c), cfg_json(&ChipConfig::default()).render());
    }

    #[test]
    fn axis_values_validate_and_canonicalize() {
        let mut s = SearchSpace::trivial();
        s.set_axis("staging_depth", &["2", "3", "02"]).unwrap();
        let depth = s.axes().iter().find(|a| a.name == "staging_depth").unwrap();
        assert_eq!(depth.values, vec!["2", "3"], "duplicates canonicalize away");
        assert!(s.set_axis("staging_depth", &["4"]).is_err(), "depth 4 out of bounds");
        assert!(s.set_axis("lanes", &["8"]).is_err(), "lanes are fixed at 16");
        assert!(s.set_axis("dtype", &["fp64"]).is_err());
        assert!(s.set_axis("nope", &["1"]).is_err());
        assert!(s.set_axis("tiles", &[]).is_err(), "empty axis rejected");
    }

    #[test]
    fn origin_candidate_id_is_pinned() {
        // Explore output stability: frontier reports print candidate
        // ids as {:016x}, so the id of the Table-2 default config is a
        // published value. It must not move when the *unit* key
        // encoding changes (it did not across the JSON->binary v2 key
        // bump) — only a deliberate cfg_json/hash change may repin it.
        let s = SearchSpace::trivial();
        let id = s.id(&s.origin());
        assert_eq!(format!("{id:016x}"), "343d7c2bb22c2e90");
        assert_eq!(id, fnv1a64(cfg_json(&ChipConfig::default()).render().as_bytes()));
    }

    #[test]
    fn candidate_id_is_content_addressed() {
        let s = SearchSpace::default_space();
        let a = s.origin();
        let mut b = s.origin();
        assert_eq!(s.id(&a), s.id(&b));
        b.indices[0] = if a.indices[0] == 0 { 1 } else { 0 }; // flip depth
        assert_ne!(s.id(&a), s.id(&b));
        assert_ne!(s.canon(&a), s.canon(&b));
    }

    #[test]
    fn neighbors_step_one_free_axis_within_bounds() {
        let s = SearchSpace::default_space();
        let o = s.origin(); // depth=3 (idx 1), rows=4 (idx 2), cols=4 (idx 0)
        let ns = s.neighbors(&o);
        // depth: down only (idx 1 of 2); rows: both; cols: up only.
        assert_eq!(ns.len(), 4);
        for n in &ns {
            let diff: usize = n
                .indices
                .iter()
                .zip(&o.indices)
                .filter(|(a, b)| a != b)
                .count();
            assert_eq!(diff, 1, "neighbor changes exactly one axis");
        }
    }

    #[test]
    fn sampling_is_deterministic_and_in_bounds() {
        let s = SearchSpace::default_space();
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        for _ in 0..32 {
            let a = s.sample(&mut r1);
            let b = s.sample(&mut r2);
            assert_eq!(a, b);
            for (axis, &i) in s.axes().iter().zip(&a.indices) {
                assert!(i < axis.values.len());
            }
        }
    }

    #[test]
    fn space_json_round_trips_free_axes() {
        let s = SearchSpace::default_space();
        let j = s.to_json();
        assert_eq!(j.get("schema").unwrap().as_str(), Some(SPACE_SCHEMA));
        let back = SearchSpace::from_json(&j).unwrap();
        assert_eq!(back, s);
        // Numeric values parse too.
        let doc = Json::parse(
            r#"{"schema":"tensordash.space.v1","axes":{"staging_depth":[2,3],"dtype":["bf16","fp32"]}}"#,
        )
        .unwrap();
        let parsed = SearchSpace::from_json(&doc).unwrap();
        assert_eq!(parsed.size(), 4);
        assert!(SearchSpace::from_json(&Json::parse(r#"{"schema":"nope"}"#).unwrap()).is_err());
    }

    #[test]
    fn from_pairs_matches_set_axis() {
        let pairs = vec![
            ("staging_depth".to_string(), "2,3".to_string()),
            ("tile_rows".to_string(), "2, 4".to_string()),
        ];
        let s = SearchSpace::from_pairs(&pairs).unwrap();
        assert_eq!(s.size(), 4);
        assert!(SearchSpace::from_pairs(&[("x".to_string(), "1".to_string())]).is_err());
    }
}
