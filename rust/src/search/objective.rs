//! Multi-objective scoring of one evaluated design point.
//!
//! The paper's evaluation already exposes the three quantities a chip
//! architect trades off (§Figs. 13–19, Table 3): how long the workload
//! takes (TensorDash chip cycles), what it costs to run (energy,
//! including DRAM), and what the design costs to build (silicon area).
//! A [`Score`] packs those into one minimization vector extracted from
//! the merged [`ModelSim`]s plus the analytic [`AreaReport`]; Pareto
//! [`Score::dominates`] ordering over that vector is what the
//! [`frontier`](super::frontier) keeps.
//!
//! Scores are *derived data*: every field is computed from the
//! deterministic simulation results (or the pure area model), so a
//! score is byte-identical warm or cold, at any `--jobs`.

use std::collections::BTreeMap;

use crate::config::ChipConfig;
use crate::energy::AreaReport;
use crate::metrics::geomean;
use crate::repro::ModelSim;
use crate::util::json::Json;

/// The minimization vector of one candidate: fewer cycles, less
/// energy, less silicon — all lower-is-better.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Score {
    /// TensorDash chip cycles summed over every evaluated model.
    pub td_cycles: f64,
    /// TensorDash energy (core + overhead + SRAM + scratchpad + DRAM)
    /// summed over every evaluated model, picojoules.
    pub energy_pj: f64,
    /// Area proxy: TensorDash compute (cores + schedulers/muxes +
    /// transposers) plus on-chip SRAM and scratchpads, mm².
    pub area_mm2: f64,
}

impl Score {
    /// Strict Pareto dominance: no objective worse, at least one
    /// strictly better. Irreflexive by construction (a score never
    /// dominates an equal score).
    pub fn dominates(&self, o: &Score) -> bool {
        let le = self.td_cycles <= o.td_cycles
            && self.energy_pj <= o.energy_pj
            && self.area_mm2 <= o.area_mm2;
        let lt = self.td_cycles < o.td_cycles
            || self.energy_pj < o.energy_pj
            || self.area_mm2 < o.area_mm2;
        le && lt
    }

    /// Total order for stable tie-breaking: lexicographic over
    /// (cycles, energy, area) with `f64::total_cmp`, so sorting is
    /// deterministic even for bit-different equal-comparing values.
    pub fn cmp_lex(&self, o: &Score) -> std::cmp::Ordering {
        self.td_cycles
            .total_cmp(&o.td_cycles)
            .then(self.energy_pj.total_cmp(&o.energy_pj))
            .then(self.area_mm2.total_cmp(&o.area_mm2))
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("td_cycles".to_string(), Json::Num(self.td_cycles));
        m.insert("energy_pj".to_string(), Json::Num(self.energy_pj));
        m.insert("area_mm2".to_string(), Json::Num(self.area_mm2));
        Json::Obj(m)
    }
}

/// Presentation metrics that ride along with a score (the frontier
/// report's speedup/efficiency columns) — not part of the dominance
/// vector.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScoreDetail {
    /// Baseline chip cycles summed over every evaluated model.
    pub base_cycles: f64,
    /// Geomean of per-model overall speedups.
    pub speedup: f64,
    /// Geomean of per-model whole-chip energy efficiencies.
    pub energy_eff: f64,
}

/// Extract the score (and its presentation detail) of one candidate
/// from the merged simulations of its model sweep.
pub fn score_sims(cfg: &ChipConfig, sims: &[ModelSim]) -> (Score, ScoreDetail) {
    assert!(!sims.is_empty(), "a score needs at least one simulated model");
    let mut td = 0u64;
    let mut base = 0u64;
    let mut energy = 0.0f64;
    for s in sims {
        for (b, t) in &s.per_op {
            base += b;
            td += t;
        }
        energy += s.energy_td.total_pj();
    }
    let a = AreaReport::compute(cfg);
    let score = Score {
        td_cycles: td as f64,
        energy_pj: energy,
        area_mm2: a.tensordash_compute() + a.sram_mm2 + a.spad_mm2,
    };
    let detail = ScoreDetail {
        base_cycles: base as f64,
        speedup: geomean(sims.iter().map(ModelSim::overall_speedup)),
        energy_eff: geomean(sims.iter().map(ModelSim::total_efficiency)),
    };
    (score, detail)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(c: f64, e: f64, a: f64) -> Score {
        Score { td_cycles: c, energy_pj: e, area_mm2: a }
    }

    #[test]
    fn dominance_requires_strict_improvement_somewhere() {
        assert!(s(1.0, 1.0, 1.0).dominates(&s(2.0, 1.0, 1.0)));
        assert!(s(1.0, 1.0, 1.0).dominates(&s(2.0, 2.0, 2.0)));
        assert!(!s(1.0, 1.0, 1.0).dominates(&s(1.0, 1.0, 1.0)), "irreflexive");
        // Trade-offs don't dominate either way.
        assert!(!s(1.0, 2.0, 1.0).dominates(&s(2.0, 1.0, 1.0)));
        assert!(!s(2.0, 1.0, 1.0).dominates(&s(1.0, 2.0, 1.0)));
    }

    #[test]
    fn lex_order_is_total_and_stable() {
        let mut v = vec![s(2.0, 1.0, 1.0), s(1.0, 2.0, 1.0), s(1.0, 1.0, 2.0), s(1.0, 1.0, 1.0)];
        v.sort_by(|a, b| a.cmp_lex(b));
        assert_eq!(v[0], s(1.0, 1.0, 1.0));
        assert_eq!(v[1], s(1.0, 1.0, 2.0));
        assert_eq!(v[2], s(1.0, 2.0, 1.0));
        assert_eq!(v[3], s(2.0, 1.0, 1.0));
    }

    #[test]
    fn score_extraction_sums_models_and_prices_area() {
        use crate::api::Engine;
        use crate::api::SimRequest;
        let cfg = ChipConfig::default();
        let req = SimRequest::profile("gcn", 0.4, cfg.clone(), 1, 5).unwrap();
        let sim = Engine::serial().run(&req);
        let (one, d1) = score_sims(&cfg, std::slice::from_ref(&sim));
        let (two, _) = score_sims(&cfg, &[sim.clone(), sim.clone()]);
        assert_eq!(two.td_cycles, one.td_cycles * 2.0);
        assert_eq!(two.energy_pj, one.energy_pj * 2.0);
        assert_eq!(two.area_mm2, one.area_mm2, "area is per-design, not per-model");
        assert!(one.td_cycles > 0.0 && one.energy_pj > 0.0 && one.area_mm2 > 0.0);
        assert!((d1.speedup - sim.overall_speedup()).abs() < 1e-12);
        assert!(d1.base_cycles >= one.td_cycles);
    }
}
