//! Design-space exploration: cache-driven Pareto search over chip
//! configurations.
//!
//! The paper's headline numbers rest on specific design-point choices
//! (8-input mux interconnect, staging depth 3, 16-lane PEs, 4×4 tiles)
//! that the authors justify by sweeping the configuration space
//! (§Figs. 17–19). This subsystem turns those hand-rolled figure grids
//! into a first-class workload — HASS-style hardware search over the
//! sparsity-exploiting accelerator — built on top of the PR-4
//! content-addressed unit cache, which makes re-evaluating overlapping
//! configurations nearly free:
//!
//! ```text
//!   SearchSpace ──sample/mutate──► Candidate batch
//!        │                             │ one Engine::run_all
//!        │                             ▼ (survivors = cache hits)
//!   canonical cfg encoding        score_sims → Score (cycles, energy, area)
//!   (the unit-key cfg fragment)        │
//!                                      ▼
//!                              Frontier (Pareto, stable order)
//!                                      │
//!                                      ▼
//!                        tensordash.frontier.v1 Report
//! ```
//!
//! * [`space`] — declarative axes over `ChipConfig` with bounds,
//!   mutation neighborhoods and content-addressed candidate encoding;
//! * [`objective`] — the (cycles, energy, area) minimization vector
//!   extracted from merged simulations + the analytic area model;
//! * [`frontier`] — dominance-pruned Pareto set with a stable
//!   tie-break order (property-tested invariants);
//! * [`explore`] — the seeded successive-halving + local-mutation
//!   loop, byte-deterministic at any `--jobs`, surfaced as the
//!   `explore` CLI subcommand and the `explore` service op.

pub mod explore;
pub mod frontier;
pub mod objective;
pub mod space;

pub use explore::{default_population, explore, frontier_report, run, ExploreResult, ExploreSpec};
pub use frontier::{diff_points, DiffStatus, Evaluated, Frontier};
pub use objective::{score_sims, Score, ScoreDetail};
pub use space::{axis_bounds, Axis, Candidate, SearchSpace, AXIS_NAMES, SPACE_SCHEMA};
