//! The cache-driven exploration loop: seeded successive halving with
//! local mutation over a [`SearchSpace`].
//!
//! Each generation evaluates one *batch* of candidates — the previous
//! generation's survivors plus fresh candidates (generation 0: the
//! Table-2 origin, its staging-depth twin, and uniform samples; later
//! generations: one-axis mutations of the survivors, topped up with
//! samples). The whole batch goes through **one**
//! [`Engine::run_all`] invocation, so
//!
//! * survivor re-evaluations are pure unit-cache hits (this is what
//!   makes the halving loop cheap, and what the CI smoke's
//!   "nonzero cache hits across generations" assertion checks);
//! * units shared between candidates that were already simulated in a
//!   previous generation — or in a previous *request*, through the
//!   serving layer's shared cache — are never recomputed.
//!
//! **Determinism.** Every random decision draws from an `Rng` seeded by
//! `derive_seed(seed ^ SEARCH_SEED_DOMAIN, generation)` on the calling
//! thread; the engine's execution is byte-deterministic at any
//! `--jobs`; candidate dedupe keys on content addresses; and the
//! frontier's order is a total sort. A fixed-budget explore run is
//! therefore byte-identical at `--jobs {1,4,8}`, warm or cold — the
//! same contract every other pipeline stage carries, pinned by
//! `rust/tests/search_explore.rs`.
//!
//! **Validation gate.** Whenever the explored set contains pairs of
//! configurations differing only in staging depth, the fig-19 ordering
//! (depth 3 / lookahead 2 at least as fast as depth 2 / lookahead 1)
//! must hold over the slice; the result records it and the `explore`
//! CLI refuses to bless a frontier that violates it.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use crate::api::cache::cfg_json;
use crate::api::report::FRONTIER_SCHEMA;
use crate::api::{derive_seed, Cell, Engine, Report, SimRequest};
use crate::sparsity::Regime;
use crate::trace::profiles::ModelProfile;
use crate::util::rng::Rng;

use super::frontier::{Evaluated, Frontier};
use super::objective::score_sims;
use super::space::{Candidate, SearchSpace};

/// Domain separator for the search RNG streams: keeps mutation draws
/// statistically independent of the simulation seeds derived from the
/// same base seed.
const SEARCH_SEED_DOMAIN: u64 = 0x7365_6172_6368_2e31; // "search.1"

/// What to explore: the space, the evaluation workload, and the budget.
#[derive(Debug, Clone)]
pub struct ExploreSpec {
    pub space: SearchSpace,
    /// Evaluation models (name, shared profile) — resolved up front so
    /// unknown names fail on the calling thread, never in a worker.
    pub models: Vec<(String, Arc<ModelProfile>)>,
    pub epoch: f64,
    /// Pass-sample budget per unit (see `repro::DEFAULT_SAMPLES`).
    pub samples: usize,
    pub seed: u64,
    /// Maximum number of *unique* candidates evaluated. Survivor
    /// re-evaluations are cache hits and do not count.
    pub budget: usize,
    /// Batch size per generation (survivors + fresh candidates).
    pub population: usize,
    /// Sparsity regime every evaluation request carries. Seeds never
    /// depend on it, so regimes are directly comparable sweeps over the
    /// same base tensors.
    pub regime: Regime,
}

impl ExploreSpec {
    /// Build a spec, resolving model names through the profile
    /// registry. Population defaults to [`default_population`].
    pub fn new(
        space: SearchSpace,
        models: &[&str],
        epoch: f64,
        samples: usize,
        seed: u64,
        budget: usize,
    ) -> Result<ExploreSpec, String> {
        let mut resolved = Vec::with_capacity(models.len());
        for m in models {
            let p = ModelProfile::for_model(m)
                .ok_or_else(|| format!("unknown model '{m}' (see models::ALL_MODELS)"))?;
            resolved.push((m.to_string(), Arc::new(p)));
        }
        Ok(ExploreSpec::with_profiles(space, resolved, epoch, samples, seed, budget))
    }

    /// Build a spec over already-loaded (`Arc`-shared) profiles — the
    /// serving layer's zero-copy path through its artifact store.
    pub fn with_profiles(
        space: SearchSpace,
        models: Vec<(String, Arc<ModelProfile>)>,
        epoch: f64,
        samples: usize,
        seed: u64,
        budget: usize,
    ) -> ExploreSpec {
        assert!(!models.is_empty(), "explore needs at least one model");
        let population = default_population(budget);
        ExploreSpec {
            space,
            models,
            epoch,
            samples,
            seed,
            budget,
            population,
            regime: Regime::Uniform,
        }
    }

    pub fn with_population(mut self, population: usize) -> ExploreSpec {
        self.population = population.max(1);
        self
    }

    /// Evaluate every candidate under `regime` instead of the default
    /// uniform workload.
    pub fn with_regime(mut self, regime: Regime) -> ExploreSpec {
        self.regime = regime;
        self
    }
}

/// Default generation batch size for a budget: half the budget, kept
/// in `2..=8` so small budgets still get a halving step and large ones
/// still get several generations.
pub fn default_population(budget: usize) -> usize {
    (budget / 2).clamp(2, 8)
}

/// Everything an exploration run produced.
#[derive(Debug, Clone)]
pub struct ExploreResult {
    pub frontier: Frontier,
    /// Every unique candidate evaluated, in evaluation order.
    pub evaluated: Vec<Evaluated>,
    pub generations: usize,
    /// Pairs of evaluated configs differing only in staging depth.
    pub depth_pairs: usize,
    /// The fig-19 gate: over all depth pairs, depth 3 needed no more
    /// TensorDash cycles than depth 2. Vacuously true with no pairs.
    pub depth_ordered: bool,
}

/// Offer a candidate into the fresh list iff its content address is
/// new to the whole run and to this batch.
fn offer(
    space: &SearchSpace,
    seen: &BTreeSet<u64>,
    ids: &mut BTreeSet<u64>,
    fresh: &mut Vec<Candidate>,
    c: Candidate,
) -> bool {
    let id = space.id(&c);
    if seen.contains(&id) || !ids.insert(id) {
        return false;
    }
    fresh.push(c);
    true
}

/// The staging-depth twin of a candidate (same indices, other depth
/// value), when the space's depth axis has exactly two values.
fn depth_twin(space: &SearchSpace, c: &Candidate) -> Option<Candidate> {
    let (ai, axis) = space
        .axes()
        .iter()
        .enumerate()
        .find(|(_, a)| a.name == "staging_depth")?;
    if axis.values.len() != 2 {
        return None;
    }
    let mut t = c.clone();
    t.indices[ai] = 1 - c.indices[ai];
    Some(t)
}

/// Run the exploration loop. Pure in `(engine determinism, spec)`:
/// byte-identical results for any worker count, warm or cold cache.
pub fn explore(engine: &Engine, spec: &ExploreSpec) -> ExploreResult {
    assert!(spec.budget >= 1, "explore needs a budget of at least 1");
    let pop = spec.population.max(1);
    let n_models = spec.models.len();
    let mut seen: BTreeSet<u64> = BTreeSet::new();
    let mut evaluated: Vec<Evaluated> = Vec::new();
    let mut frontier = Frontier::new();
    let mut survivors: Vec<Candidate> = Vec::new();
    // Depth slice: neutral-config canon -> per-depth td-cycle totals.
    let mut depth_slice: BTreeMap<String, [Option<f64>; 2]> = BTreeMap::new();
    let mut generations = 0usize;

    while evaluated.len() < spec.budget {
        let gen = generations;
        let mut rng = Rng::new(derive_seed(spec.seed ^ SEARCH_SEED_DOMAIN, gen as u64));
        let want = (pop.saturating_sub(survivors.len()))
            .max(1)
            .min(spec.budget - evaluated.len());

        // -- assemble fresh candidates --------------------------------
        let mut fresh: Vec<Candidate> = Vec::new();
        let mut fresh_ids: BTreeSet<u64> = BTreeSet::new();
        if gen == 0 {
            // Seed with the Table-2 origin and its staging-depth twin,
            // so the fig-19 depth slice always has at least one pair.
            let origin = spec.space.origin();
            let twin = depth_twin(&spec.space, &origin);
            offer(&spec.space, &seen, &mut fresh_ids, &mut fresh, origin);
            if let Some(t) = twin {
                offer(&spec.space, &seen, &mut fresh_ids, &mut fresh, t);
            }
        } else {
            // Local mutation: walk the survivor ranking round-robin,
            // one random neighbor per visit.
            let limit = 16 * (spec.space.axes().len() + 1) * pop.max(1);
            let mut attempts = 0usize;
            'mutate: while fresh.len() < want && !survivors.is_empty() {
                let mut progressed = false;
                for s in &survivors {
                    if fresh.len() >= want || attempts >= limit {
                        break 'mutate;
                    }
                    let ns = spec.space.neighbors(s);
                    attempts += 1;
                    if ns.is_empty() {
                        continue;
                    }
                    let pick = ns[rng.below(ns.len())].clone();
                    if offer(&spec.space, &seen, &mut fresh_ids, &mut fresh, pick) {
                        progressed = true;
                    }
                }
                if !progressed && attempts >= limit {
                    break;
                }
            }
        }
        // Top up with uniform samples (also how generation 0 fills).
        let limit = 64 * (want + 1);
        let mut attempts = 0usize;
        while fresh.len() < want && attempts < limit {
            let c = spec.space.sample(&mut rng);
            offer(&spec.space, &seen, &mut fresh_ids, &mut fresh, c);
            attempts += 1;
        }
        // Generation 0 may have seeded past a tiny budget.
        fresh.truncate(spec.budget - evaluated.len());
        if fresh.is_empty() {
            break; // space exhausted around the survivors
        }

        // -- evaluate the batch through one engine invocation ---------
        // Survivors first: their units are already cached, so the
        // engine's serial lookup phase answers them without compute.
        let batch: Vec<Candidate> =
            survivors.iter().cloned().chain(fresh.iter().cloned()).collect();
        let mut reqs: Vec<SimRequest> = Vec::with_capacity(batch.len() * n_models);
        for c in &batch {
            let cfg = spec.space.config(c);
            for (mi, (_, profile)) in spec.models.iter().enumerate() {
                // Seed per model only: every candidate sees identical
                // tensors (the Fig. 17–19 comparability convention).
                reqs.push(
                    SimRequest::profile_shared(
                        Arc::clone(profile),
                        spec.epoch,
                        cfg.clone(),
                        spec.samples,
                        derive_seed(spec.seed, mi as u64),
                    )
                    .with_regime(spec.regime.clone()),
                );
            }
        }
        let sims = engine.run_all(&reqs);

        // -- fold scores, record fresh evaluations --------------------
        let mut batch_eval: Vec<Evaluated> = Vec::with_capacity(batch.len());
        for (c, slice) in batch.iter().zip(sims.chunks(n_models)) {
            let cfg = spec.space.config(c);
            let (score, detail) = score_sims(&cfg, slice);
            let id = spec.space.id(c);
            let e = Evaluated {
                label: spec.space.label(c),
                canon: spec.space.canon(c),
                id,
                score,
                detail,
                gen,
            };
            if seen.insert(id) {
                evaluated.push(e.clone());
                frontier.insert(e.clone());
                let mut neutral = cfg.clone();
                neutral.staging_depth = 3;
                let slot = depth_slice.entry(cfg_json(&neutral).render()).or_default();
                slot[cfg.staging_depth - 2] = Some(score.td_cycles);
            }
            batch_eval.push(e);
        }

        // -- successive halving: keep the batch's top half ------------
        let mut order: Vec<usize> = (0..batch_eval.len()).collect();
        let rank = |i: usize| -> usize {
            batch_eval
                .iter()
                .filter(|o| o.score.dominates(&batch_eval[i].score))
                .count()
        };
        let ranks: Vec<usize> = order.iter().map(|&i| rank(i)).collect();
        order.sort_by(|&a, &b| {
            ranks[a]
                .cmp(&ranks[b])
                .then_with(|| batch_eval[a].score.cmp_lex(&batch_eval[b].score))
                .then_with(|| batch_eval[a].canon.cmp(&batch_eval[b].canon))
        });
        let keep = batch.len().div_ceil(2).max(1);
        survivors = order.iter().take(keep).map(|&i| batch[i].clone()).collect();
        generations += 1;
    }

    let mut depth_pairs = 0usize;
    let (mut d2, mut d3) = (0.0f64, 0.0f64);
    for slot in depth_slice.values() {
        if let [Some(c2), Some(c3)] = slot {
            depth_pairs += 1;
            d2 += *c2;
            d3 += *c3;
        }
    }
    ExploreResult {
        frontier,
        evaluated,
        generations,
        depth_pairs,
        depth_ordered: depth_pairs == 0 || d3 <= d2,
    }
}

/// Render an exploration result as the `tensordash.frontier.v1`
/// report: one row per frontier point in the stable tie-break order,
/// provenance + gate verdict in the meta block. Byte-deterministic for
/// a fixed spec.
pub fn frontier_report(spec: &ExploreSpec, res: &ExploreResult) -> Report {
    let models: Vec<&str> = spec.models.iter().map(|(m, _)| m.as_str()).collect();
    let mut r = Report::with_schema(
        FRONTIER_SCHEMA,
        "frontier",
        format!(
            "Design-space Pareto frontier — {} evaluations over [{}]",
            res.evaluated.len(),
            models.join(", ")
        ),
        &["config", "td cycles", "speedup", "energy pJ", "energy eff", "area mm2", "gen"],
    );
    for p in res.frontier.points() {
        r.row(vec![
            Cell::text(p.label.clone()),
            Cell::fmt((p.score.td_cycles as u64).to_string(), p.score.td_cycles),
            Cell::num(p.detail.speedup),
            Cell::fmt(format!("{:.3e}", p.score.energy_pj), p.score.energy_pj),
            Cell::num(p.detail.energy_eff),
            Cell::num(p.score.area_mm2),
            Cell::fmt(p.gen.to_string(), p.gen as f64),
        ]);
    }
    r.meta_str("models", &models.join(","));
    r.meta_str("regime", &spec.regime.render());
    r.meta_num("epoch", spec.epoch);
    r.meta_num("samples", spec.samples as f64);
    r.meta_num("seed", spec.seed as f64);
    r.meta_num("budget", spec.budget as f64);
    r.meta_num("population", spec.population as f64);
    r.meta_num("evaluations", res.evaluated.len() as f64);
    r.meta_num("generations", res.generations as f64);
    r.meta_num("frontier_size", res.frontier.len() as f64);
    r.meta_num("space_size", spec.space.size() as f64);
    r.meta_num("depth_pairs", res.depth_pairs as f64);
    r.meta_num("depth_ordered", if res.depth_ordered { 1.0 } else { 0.0 });
    r.meta.insert("space".to_string(), spec.space.to_json());
    r
}

/// Convenience wrapper: explore, build the frontier report, and — when
/// the engine carries a unit cache — annotate the run's cache-counter
/// deltas (`unit_cache_*` meta keys; presentation only, the rows never
/// depend on the cache).
pub fn run(engine: &Engine, spec: &ExploreSpec) -> (ExploreResult, Report) {
    let before = engine.cache().map(|c| c.stats());
    let res = explore(engine, spec);
    let mut report = frontier_report(spec, &res);
    if let (Some(cache), Some(b)) = (engine.cache(), before) {
        cache.stats().since(&b).annotate(&mut report);
    }
    (res, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::UnitCache;

    fn tiny_spec(budget: usize) -> ExploreSpec {
        let mut space = SearchSpace::trivial();
        space.set_axis("staging_depth", &["2", "3"]).unwrap();
        space.set_axis("tile_rows", &["2", "4"]).unwrap();
        ExploreSpec::new(space, &["gcn"], 0.4, 1, 7, budget).unwrap()
    }

    #[test]
    fn explore_respects_budget_and_builds_a_frontier() {
        let (res, report) = run(&Engine::serial(), &tiny_spec(3));
        assert_eq!(res.evaluated.len(), 3);
        assert!(!res.frontier.is_empty());
        assert!(res.frontier.len() <= res.evaluated.len());
        assert_eq!(report.schema, FRONTIER_SCHEMA);
        assert_eq!(report.rows.len(), res.frontier.len());
        // Unique content addresses: no candidate evaluated twice.
        let ids: BTreeSet<u64> = res.evaluated.iter().map(|e| e.id).collect();
        assert_eq!(ids.len(), res.evaluated.len());
    }

    #[test]
    fn generation_zero_seeds_the_depth_pair() {
        // alexnet: real sparsity, so the fig-19 ordering has a margin
        // (gcn is the no-sparsity control and is excluded from fig 19).
        let mut space = SearchSpace::trivial();
        space.set_axis("staging_depth", &["2", "3"]).unwrap();
        let spec = ExploreSpec::new(space, &["alexnet"], 0.4, 1, 7, 2).unwrap();
        let (res, _) = run(&Engine::parallel(), &spec);
        assert!(res.depth_pairs >= 1, "origin + depth twin must pair up");
        assert!(res.depth_ordered, "fig-19 ordering: depth 3 no slower than depth 2");
    }

    #[test]
    fn survivor_reevaluation_hits_the_cache_across_generations() {
        let cache = Arc::new(UnitCache::new(4096));
        let engine = Engine::new(2).with_cache(Arc::clone(&cache));
        let (res, report) = run(&engine, &tiny_spec(4));
        assert!(res.generations >= 2, "budget 4 at population 2 needs several generations");
        let s = cache.stats();
        assert!(s.hits > 0, "survivors must re-evaluate as cache hits: {s:?}");
        assert_eq!(
            report.meta.get("unit_cache_hits").and_then(|j| j.as_f64()),
            Some(s.hits as f64)
        );
    }

    #[test]
    fn exhausting_a_small_space_stops_early() {
        let mut space = SearchSpace::trivial();
        space.set_axis("staging_depth", &["2", "3"]).unwrap();
        let spec = ExploreSpec::new(space, &["gcn"], 0.4, 1, 7, 50).unwrap();
        let (res, _) = run(&Engine::serial(), &spec);
        assert_eq!(res.evaluated.len(), 2, "only two candidates exist");
    }
}
