//! Pareto-frontier tracking with dominance pruning.
//!
//! The frontier is the running set of non-dominated design points. Its
//! contract (pinned by property tests in `rust/tests/search_explore.rs`):
//!
//! * no point in the frontier is dominated by any other point in it;
//! * a point is rejected iff some already-seen point dominates it, or
//!   it is an exact duplicate (same score *and* same canonical config);
//! * the final frontier is a pure function of the *set* of points ever
//!   inserted — insertion order never changes it — because "the
//!   non-dominated subset of S" is order-free and the internal order is
//!   re-established by a total sort key;
//! * iteration order is the stable tie-break: lexicographic score
//!   ([`Score::cmp_lex`]), then the canonical config string. Reports
//!   built from a frontier are therefore byte-deterministic.

use std::collections::BTreeMap;

use crate::util::json::Json;

use super::objective::{Score, ScoreDetail};

/// One evaluated design point (frontier member or not).
#[derive(Debug, Clone, PartialEq)]
pub struct Evaluated {
    /// Short human label (`axis=value` pairs of the free axes).
    pub label: String,
    /// Canonical config encoding (the unit-key `cfg` fragment).
    pub canon: String,
    /// Content address: FNV-1a of `canon`.
    pub id: u64,
    pub score: Score,
    pub detail: ScoreDetail,
    /// Generation the point was first evaluated in.
    pub gen: usize,
}

impl Evaluated {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("label".to_string(), Json::Str(self.label.clone()));
        m.insert("id".to_string(), Json::Str(format!("{:016x}", self.id)));
        m.insert("score".to_string(), self.score.to_json());
        m.insert("speedup".to_string(), Json::Num(self.detail.speedup));
        m.insert("energy_eff".to_string(), Json::Num(self.detail.energy_eff));
        m.insert("gen".to_string(), Json::Num(self.gen as f64));
        Json::Obj(m)
    }
}

/// The non-dominated set, kept sorted by the stable tie-break order.
#[derive(Debug, Clone, Default)]
pub struct Frontier {
    points: Vec<Evaluated>,
}

impl Frontier {
    pub fn new() -> Frontier {
        Frontier::default()
    }

    /// Offer a point. Returns `true` if it joined the frontier (possibly
    /// evicting points it dominates), `false` if a resident point
    /// dominates it or it is an exact duplicate.
    pub fn insert(&mut self, e: Evaluated) -> bool {
        for p in &self.points {
            if p.score.dominates(&e.score) {
                return false;
            }
            if p.score == e.score && p.canon == e.canon {
                return false;
            }
        }
        self.points.retain(|p| !e.score.dominates(&p.score));
        self.points.push(e);
        self.points
            .sort_by(|a, b| a.score.cmp_lex(&b.score).then_with(|| a.canon.cmp(&b.canon)));
        true
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Frontier members in the stable tie-break order.
    pub fn points(&self) -> &[Evaluated] {
        &self.points
    }

    /// Whether `s` would be rejected (some resident point dominates it).
    pub fn dominated(&self, s: &Score) -> bool {
        self.points.iter().any(|p| p.score.dominates(s))
    }
}

/// Classification of one design point when comparing two frontiers
/// (the `store diff` path over `tensordash.frontier.v1` documents).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DiffStatus {
    /// Present in the newer frontier only.
    Added,
    /// Present in both frontiers (same config label).
    Kept,
    /// Dropped from the newer frontier without being dominated by any
    /// of its points (e.g. the search space no longer reaches it).
    Removed,
    /// Dropped from the newer frontier *because* some newer point
    /// strictly dominates it — the frontier genuinely moved.
    NewlyDominated,
}

impl DiffStatus {
    pub fn as_str(&self) -> &'static str {
        match self {
            DiffStatus::Added => "added",
            DiffStatus::Kept => "kept",
            DiffStatus::Removed => "removed",
            DiffStatus::NewlyDominated => "newly-dominated",
        }
    }
}

/// Compare two frontiers given as `(config label, score)` lists.
///
/// Points are matched by config label. The result lists every point of
/// `to` in its order (classified [`DiffStatus::Added`] or
/// [`DiffStatus::Kept`]), followed by the points only in `from` in
/// their order (classified [`DiffStatus::NewlyDominated`] when some
/// `to` point strictly dominates them, else [`DiffStatus::Removed`]).
/// Pure and order-stable, so diff reports are byte-deterministic.
pub fn diff_points(
    from: &[(String, Score)],
    to: &[(String, Score)],
) -> Vec<(String, Score, DiffStatus)> {
    let mut out = Vec::with_capacity(from.len() + to.len());
    for (label, score) in to {
        let status = if from.iter().any(|(l, _)| l == label) {
            DiffStatus::Kept
        } else {
            DiffStatus::Added
        };
        out.push((label.clone(), *score, status));
    }
    for (label, score) in from {
        if to.iter().any(|(l, _)| l == label) {
            continue;
        }
        let status = if to.iter().any(|(_, s)| s.dominates(score)) {
            DiffStatus::NewlyDominated
        } else {
            DiffStatus::Removed
        };
        out.push((label.clone(), *score, status));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(tag: &str, c: f64, e: f64, a: f64) -> Evaluated {
        Evaluated {
            label: tag.to_string(),
            canon: tag.to_string(),
            id: crate::util::hash::fnv1a64(tag.as_bytes()),
            score: Score { td_cycles: c, energy_pj: e, area_mm2: a },
            detail: ScoreDetail { base_cycles: c * 2.0, speedup: 2.0, energy_eff: 1.5 },
            gen: 0,
        }
    }

    #[test]
    fn dominated_points_never_join_and_get_evicted() {
        let mut f = Frontier::new();
        assert!(f.insert(pt("mid", 2.0, 2.0, 2.0)));
        assert!(!f.insert(pt("worse", 3.0, 2.0, 2.0)), "dominated on one axis");
        assert!(f.insert(pt("tradeoff", 1.0, 3.0, 2.0)), "trade-offs coexist");
        assert_eq!(f.len(), 2);
        // A strictly better point evicts what it dominates.
        assert!(f.insert(pt("best", 1.0, 1.0, 1.0)));
        assert_eq!(f.len(), 1);
        assert_eq!(f.points()[0].label, "best");
        assert!(f.dominated(&pt("mid", 2.0, 2.0, 2.0).score));
    }

    #[test]
    fn exact_duplicates_are_rejected_but_score_ties_coexist() {
        let mut f = Frontier::new();
        assert!(f.insert(pt("a", 1.0, 2.0, 3.0)));
        assert!(!f.insert(pt("a", 1.0, 2.0, 3.0)), "same config, same score");
        // A *different* config with the identical score is a distinct
        // non-dominated point (dominance is strict).
        assert!(f.insert(pt("b", 1.0, 2.0, 3.0)));
        assert_eq!(f.len(), 2);
        // Tie-break order: by canon when scores tie.
        assert_eq!(f.points()[0].label, "a");
        assert_eq!(f.points()[1].label, "b");
    }

    #[test]
    fn iteration_order_is_lex_score_then_canon() {
        let mut f = Frontier::new();
        f.insert(pt("late", 3.0, 1.0, 1.0));
        f.insert(pt("early", 1.0, 3.0, 1.0));
        f.insert(pt("middle", 2.0, 2.0, 1.0));
        let labels: Vec<&str> = f.points().iter().map(|p| p.label.as_str()).collect();
        assert_eq!(labels, vec!["early", "middle", "late"]);
    }
}
