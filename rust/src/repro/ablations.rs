//! Ablation studies over the design choices DESIGN.md calls out.
//!
//! * **Two-side extraction** (§3.1/Fig. 8) — the paper's PE can extract
//!   sparsity on both operands ("we leave the evaluation of this option
//!   for future work"); here it is evaluated: per-PE schedulers and
//!   staging buffers (§3.3), one effectual-mask stream per PE formed as
//!   `AZ & BZ`, pass cycles = the slowest PE. The gain is largest for
//!   the pruned-training variants where the *weights* carry 90%
//!   sparsity the one-side configuration cannot reach.
//! * **Lead bound** — the inter-row synchronisation slack of the shared
//!   A-side storage (DESIGN.md §2b).
//! * **DRAM gate** — the optional bandwidth-bound performance model.
//! * **Iterative back-side scheduler** (§3.7) — same schedule over 6
//!   cycles; reported as compression throughput.
//!
//! Like the figure drivers, every ablation returns a structured
//! [`Report`] and fans its cells out over the [`Engine`] worker pool
//! with per-cell derived seeds (config variants of the same workload
//! share a seed so the comparison columns see identical tensors). The
//! whole-model cells execute through the plan pipeline
//! (`repro::simulate_profile` lowers to a serial
//! [`crate::api::ModelPlan`] walk), so their per-unit seeds — and
//! therefore their numbers — match the engine's parallel executor
//! exactly.

use crate::api::{derive_seed, Cell, Engine, Report};
use crate::config::ChipConfig;
use crate::conv::stream::{fwd_weight_stream, igrad_weight_stream, wgrad_a_stream};
use crate::conv::work::{build_stream, op_work, pick_wgrad_side};
use crate::conv::{ConvShape, TrainOp, WgradSide};
use crate::metrics::{f2, geomean};
use crate::sim::pe::simulate_stream_cached;
use crate::sim::tile::tile_pass_stats_cached;
use crate::sim::{CachedScheduler, Connectivity};
use crate::tensor::TensorBitmap;
use crate::trace::profiles::ModelProfile;
use crate::util::rng::Rng;

/// AND two mask streams slot-wise (their step orders are aligned by
/// construction — asserted).
fn and_streams(b: &[u16], a: &[u16]) -> Vec<u16> {
    assert_eq!(b.len(), a.len(), "A/B stream step orders misaligned");
    b.iter().zip(a).map(|(x, y)| x & y).collect()
}

/// Two-side pass cycles: per-PE schedulers, pass ends when the slowest
/// PE finishes its `AZ & BZ` stream. The caller's scheduler cache is
/// shared across the whole PE grid — the `AZ & BZ` streams of one pass
/// repeat window patterns heavily.
fn two_side_pass_cycles(
    sched: &mut CachedScheduler,
    b_streams: &[Vec<u16>],
    a_streams: &[Vec<u16>],
) -> u64 {
    let mut worst = 0u64;
    for b in b_streams {
        for a in a_streams {
            worst = worst.max(simulate_stream_cached(sched, &and_streams(b, a)).cycles);
        }
    }
    worst
}

/// Speedup of one (layer, op) under one-side vs two-side extraction.
/// Returns (one_side, two_side).
#[allow(clippy::too_many_arguments)]
pub fn layer_two_side(
    cfg: &ChipConfig,
    shape: &ConvShape,
    op: TrainOp,
    a_bm: &TensorBitmap,
    g_bm: &TensorBitmap,
    w_bm: &TensorBitmap,
    samples: usize,
    rng: &mut Rng,
) -> (f64, f64) {
    let mut sched = CachedScheduler::new(Connectivity::new(cfg.staging_depth));
    let wside = match op {
        TrainOp::Wgrad => pick_wgrad_side(a_bm, g_bm),
        _ => WgradSide::Gradients,
    };
    let work = op_work(shape, op, wside);
    let b_passes = work.b_groups.div_ceil(cfg.tile_rows as u64);
    let a_passes = work.a_groups.div_ceil(cfg.tile_cols as u64);
    let n_b = (samples as u64).min(b_passes);
    let n_a = (samples as u64).min(a_passes);
    let mut base = 0u64;
    let mut one = 0u64;
    let mut two = 0u64;
    for _ in 0..n_b {
        let bp = rng.below(b_passes as usize) as u64;
        let b_streams: Vec<Vec<u16>> = (0..cfg.tile_rows as u64)
            .map(|r| bp * cfg.tile_rows as u64 + r)
            .filter(|&b| b < work.b_groups)
            .map(|b| build_stream(shape, op, wside, a_bm, g_bm, b))
            .collect();
        let len = b_streams.iter().map(|s| s.len()).max().unwrap_or(0) as u64;
        // One-side: the row schedule ignores the A operand.
        let one_cycles = tile_pass_stats_cached(&mut sched, &b_streams, cfg.lead_limit).cycles;
        for _ in 0..n_a {
            let ap = rng.below(a_passes as usize) as u64;
            let a_streams: Vec<Vec<u16>> = (0..cfg.tile_cols as u64)
                .map(|c| ap * cfg.tile_cols as u64 + c)
                .filter(|&c| c < work.a_groups)
                .map(|c| match op {
                    TrainOp::Fwd => fwd_weight_stream(w_bm, shape, c as usize),
                    TrainOp::Igrad => igrad_weight_stream(w_bm, shape, c as usize),
                    TrainOp::Wgrad => {
                        // B = G (the sparser side picked above); the A
                        // operand is the activation patch stream.
                        let cc = (c % shape.c as u64) as usize;
                        let rest = (c / shape.c as u64) as usize;
                        wgrad_a_stream(a_bm, shape, rest / shape.kw, rest % shape.kw, cc)
                    }
                })
                .collect();
            base += len;
            one += one_cycles;
            two += two_side_pass_cycles(&mut sched, &b_streams, &a_streams);
        }
    }
    (base as f64 / one.max(1) as f64, base as f64 / two.max(1) as f64)
}

/// Ablation: one-side (the paper's evaluated config) vs two-side (its
/// deferred option) on the dense and pruned ResNet-50 variants.
pub fn ablation_two_side(engine: &Engine, samples: usize, seed: u64) -> Report {
    let mut r = Report::new(
        "ablation_two_side",
        "Ablation — one-side (Fig. 11) vs two-side (Fig. 8) extraction",
        &["model", "op", "one-side", "two-side", "gain"],
    );
    let cfg = ChipConfig::default();
    let models = ["resnet50", "resnet50_DS90", "resnet50_SM90"];
    // A mid-network bottleneck 3x3 (layer index 10 = s2b3 conv) is
    // representative; full-model two-side sims are quadratic in tile
    // size and this is an ablation, not a headline.
    let li = 10;
    // Bitmaps depend only on (model, seed): synthesize each model's
    // tensors once and share them across its three op cells.
    let inputs: Vec<_> = models
        .iter()
        .map(|m| {
            let p = ModelProfile::for_model(m).unwrap();
            let (a_bm, g_bm) = p.layer_bitmaps(li, crate::repro::MID_EPOCH, seed);
            let w_bm = p.layer_weight_bitmap(li, seed);
            (a_bm, g_bm, w_bm, p.topology.layers[li].shape)
        })
        .collect();
    // One cell per (model, op); pass sampling is per-cell seeded.
    let cells = engine.map(models.len() * TrainOp::ALL.len(), |i| {
        let (a_bm, g_bm, w_bm, shape) = &inputs[i / TrainOp::ALL.len()];
        let op = TrainOp::ALL[i % TrainOp::ALL.len()];
        let mut rng = Rng::new(derive_seed(seed, i as u64));
        layer_two_side(&cfg, shape, op, a_bm, g_bm, w_bm, samples, &mut rng)
    });
    for (i, (one, two)) in cells.iter().enumerate() {
        let model = models[i / TrainOp::ALL.len()];
        let op = TrainOp::ALL[i % TrainOp::ALL.len()];
        let gain = two / one - 1.0;
        r.row(vec![
            Cell::text(model),
            Cell::text(op.label()),
            Cell::num(*one),
            Cell::num(*two),
            Cell::fmt(format!("{:+.0}%", gain * 100.0), gain),
        ]);
    }
    r
}

/// Ablation: the inter-row lead bound (DESIGN.md §2b).
pub fn ablation_lead(engine: &Engine, samples: usize, seed: u64) -> Report {
    let mut r = Report::new(
        "ablation_lead",
        "Ablation — shared-operand lead bound (rows may run ahead by N)",
        &["lead", "geomean speedup"],
    );
    let leads = [0usize, 2, 6, 16, 4096];
    let models: Vec<&str> =
        crate::models::FIG13_MODELS.iter().copied().filter(|m| *m != "gcn").collect();
    // Flat (lead, model) grid; each model keeps one derived seed across
    // all lead settings so the column stays comparable.
    let vals = engine.map(leads.len() * models.len(), |i| {
        let lead = leads[i / models.len()];
        let mi = i % models.len();
        let p = ModelProfile::for_model(models[mi]).unwrap();
        let mut cfg = ChipConfig::default();
        cfg.lead_limit = lead;
        crate::repro::simulate_profile(
            &cfg,
            &p,
            crate::repro::MID_EPOCH,
            samples,
            derive_seed(seed, mi as u64),
        )
        .overall_speedup()
    });
    for (j, &lead) in leads.iter().enumerate() {
        let label = if lead == 0 {
            "0 (lockstep)".to_string()
        } else if lead >= 4096 {
            "inf (pass barrier)".to_string()
        } else {
            lead.to_string()
        };
        let slice = &vals[j * models.len()..(j + 1) * models.len()];
        r.row(vec![Cell::text(label), Cell::num(geomean(slice.iter().copied()))]);
    }
    r
}

/// Ablation: compute-bound (paper) vs DRAM-bandwidth-gated performance.
pub fn ablation_dram_gate(engine: &Engine, samples: usize, seed: u64) -> Report {
    let mut r = Report::new(
        "ablation_dram_gate",
        "Ablation — DRAM bandwidth gate (extension; paper model is compute bound)",
        &["model", "compute-bound", "bandwidth-gated"],
    );
    let models = ["alexnet", "resnet50", "vgg16", "snli"];
    // (model, gated?) grid; both variants of a model share its seed.
    let vals = engine.map(models.len() * 2, |i| {
        let mi = i / 2;
        let gated = i % 2 == 1;
        let p = ModelProfile::for_model(models[mi]).unwrap();
        let mut cfg = ChipConfig::default();
        cfg.dram_gate = gated;
        crate::repro::simulate_profile(
            &cfg,
            &p,
            crate::repro::MID_EPOCH,
            samples,
            derive_seed(seed, mi as u64),
        )
        .overall_speedup()
    });
    for (mi, m) in models.iter().enumerate() {
        r.row(vec![
            Cell::text(*m),
            Cell::num(vals[mi * 2]),
            Cell::num(vals[mi * 2 + 1]),
        ]);
    }
    r
}

/// §3.7 — back-side scheduler as a compression engine: combinational vs
/// iterative cost for compressing a tensor into scheduled form.
pub fn ablation_backside_scheduler() -> Report {
    use crate::sim::scheduler::{schedule_cycle, schedule_iterative};
    let conn = Connectivity::new(3);
    let mut rng = Rng::new(77);
    let rows: Vec<u64> = (0..4096)
        .map(|_| {
            (rng.mask16(0.4) as u64)
                | ((rng.mask16(0.4) as u64) << 16)
                | ((rng.mask16(0.4) as u64) << 32)
        })
        .collect();
    let mut comb_cycles = 0u64;
    let mut iter_cycles = 0u64;
    for &z in &rows {
        let a = schedule_cycle(&conn, z);
        let (b, c) = schedule_iterative(&conn, z);
        assert_eq!(a.picks, b.picks, "iterative scheduler must match");
        comb_cycles += 1;
        iter_cycles += c;
    }
    let mut r = Report::new(
        "ablation_backside_scheduler",
        "§3.7 — back-side scheduler: combinational vs iterative",
        &["variant", "cycles / scheduled row", "relative hw cost"],
    );
    let comb = comb_cycles as f64 / rows.len() as f64;
    let iter = iter_cycles as f64 / rows.len() as f64;
    r.row(vec![
        Cell::text("combinational (6 levels)"),
        Cell::fmt(f2(comb), comb),
        Cell::text("1.00 (all levels)"),
    ]);
    r.row(vec![
        Cell::text("iterative (1 level reused)"),
        Cell::fmt(f2(iter), iter),
        Cell::text("~0.17 (one level)"),
    ]);
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synthetic::{clustered_bitmap, random_bitmap};

    #[test]
    fn two_side_never_worse_than_one_side() {
        let cfg = ChipConfig::default();
        let s = ConvShape::conv(2, 8, 8, 32, 32, 3, 1, 1);
        let mut rng = Rng::new(5);
        let a = clustered_bitmap((2, 8, 8, 32), 0.5, 0.35, &mut rng);
        let g = clustered_bitmap((2, 8, 8, 32), 0.6, 0.35, &mut rng);
        let w = random_bitmap((32, 3, 3, 32), 0.9, &mut rng);
        for op in TrainOp::ALL {
            let (one, two) = layer_two_side(&cfg, &s, op, &a, &g, &w, 3, &mut rng);
            assert!(
                two >= one * 0.98,
                "{op:?}: two-side {two} < one-side {one}"
            );
            assert!(two <= 3.01);
        }
    }

    #[test]
    fn two_side_exploits_pruned_weights() {
        // With 90% weight sparsity, Fwd two-side must clearly beat
        // one-side (which only sees the activations).
        let cfg = ChipConfig::default();
        let s = ConvShape::conv(2, 8, 8, 32, 32, 3, 1, 1);
        let mut rng = Rng::new(6);
        let a = clustered_bitmap((2, 8, 8, 32), 0.3, 0.35, &mut rng);
        let g = clustered_bitmap((2, 8, 8, 32), 0.3, 0.35, &mut rng);
        let w = random_bitmap((32, 3, 3, 32), 0.9, &mut rng);
        let (one, two) = layer_two_side(&cfg, &s, TrainOp::Fwd, &a, &g, &w, 3, &mut rng);
        assert!(two > one * 1.3, "two-side {two} vs one-side {one}");
    }

    #[test]
    fn weight_stream_orders_align() {
        let s = ConvShape::conv(1, 6, 6, 16, 32, 3, 1, 1);
        let mut rng = Rng::new(7);
        let a = random_bitmap((1, 6, 6, 16), 0.5, &mut rng);
        let w = random_bitmap((32, 3, 3, 16), 0.5, &mut rng);
        let b = crate::conv::stream::fwd_stream(&a, &s, 0, 2, 2);
        let aw = fwd_weight_stream(&w, &s, 3);
        assert_eq!(b.len(), aw.len());
        let g = random_bitmap((1, 6, 6, 32), 0.5, &mut rng);
        let bi = crate::conv::stream::igrad_stream(&g, &s, 0, 2, 2);
        let ai = igrad_weight_stream(&w, &s, 3);
        assert_eq!(bi.len(), ai.len());
        // igrad A-stream lane l of step (ky,kx,fb) is the rotated filter.
        assert_eq!(ai[0] & 1 != 0, w.bit(0, 2, 2, 3));
    }

    #[test]
    fn backside_table_builds() {
        let t = ablation_backside_scheduler().render_text();
        assert!(t.contains("6.00"));
        assert!(t.contains("1.00"));
    }

    #[test]
    fn two_side_ablation_deterministic_across_jobs() {
        let a = ablation_two_side(&Engine::serial(), 1, 3);
        let b = ablation_two_side(&Engine::new(3), 1, 3);
        assert_eq!(a, b);
    }
}
