//! Experiment drivers: one function per paper table/figure.
//!
//! Each driver is a thin builder over the typed [`crate::api`] pipeline:
//! it assembles [`SimRequest`]s/[`SweepSpec`]s, hands them to an
//! [`Engine`] (which fans sweep cells out over `--jobs` workers), and
//! shapes the results into a structured [`Report`]. Rendering — text
//! table, JSON, CSV — happens strictly *after* the data exists, so every
//! figure regenerates identically, and machine-readably, from every
//! entry point (CLI, benches, examples, tests). See DESIGN.md
//! §Experiment-index for the figure → function mapping.

pub mod ablations;

use crate::api::{derive_seed, Cell, Engine, ModelPlan, Report, SimRequest, SweepSpec, Workload};
use crate::config::{ChipConfig, DataType};
use crate::conv::{ConvShape, TrainOp};
use crate::energy::{AreaReport, EnergyBreakdown};
use crate::metrics::{geomean, pct};
use crate::models::FIG13_MODELS;
use crate::sparsity::Regime;
use crate::sim::unit::{cycle_ratio, simulate_unit_with_rng};
use crate::tensor::TensorBitmap;
use crate::trace::profiles::{ModelProfile, PHASES};
use crate::util::rng::Rng;

/// Re-export: the per-(layer, op) unit outcome now lives with the unit
/// pipeline in [`crate::sim::unit`]; `repro::LayerOpSim` remains the
/// stable path for downstream users.
pub use crate::sim::unit::LayerOpSim;

/// Default pass-sample budget per (layer, op). Validated against
/// exhaustive simulation by [`validate_sampling`].
pub const DEFAULT_SAMPLES: usize = 6;

/// Simulate one training operation of one layer from its tensors' zero
/// bitmaps.
///
/// Thin wrapper over the staged unit pipeline
/// ([`crate::sim::unit::simulate_unit_with_rng`]) with a caller-owned
/// RNG — [`validate_sampling`] and the property tests drive exhaustive
/// and sampled runs from explicit RNG streams. Plan-based execution
/// derives a seed per unit instead (see [`crate::api::plan`]).
pub fn simulate_layer_op(
    cfg: &ChipConfig,
    shape: &ConvShape,
    op: TrainOp,
    a_bm: &TensorBitmap,
    g_bm: &TensorBitmap,
    samples: usize,
    batch_mult: u64,
    rng: &mut Rng,
) -> LayerOpSim {
    simulate_unit_with_rng(cfg, shape, op, 0, a_bm, g_bm, samples, batch_mult, rng)
}

/// Whole-model aggregation: the deterministic fold of a plan's
/// per-unit results, with the full unit vector retained.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSim {
    pub name: String,
    /// Chip cycles summed per op: (base, td).
    pub per_op: [(u64, u64); 3],
    pub energy_base: EnergyBreakdown,
    pub energy_td: EnergyBreakdown,
    /// Scheduler-cache telemetry summed over every simulated (layer, op).
    pub sched: crate::sim::CacheStats,
    /// Every merged unit in plan order (layer-major, op-minor) — the
    /// per-layer speedup/energy/bottleneck breakdown the `--per-layer`
    /// report renders; no longer thrown away by the aggregation.
    pub layers: Vec<LayerOpSim>,
}

impl ModelSim {
    /// An empty aggregate to fold units into.
    pub fn empty(name: impl Into<String>) -> ModelSim {
        ModelSim {
            name: name.into(),
            per_op: [(0, 0); 3],
            energy_base: EnergyBreakdown::default(),
            energy_td: EnergyBreakdown::default(),
            sched: crate::sim::CacheStats::default(),
            layers: Vec::new(),
        }
    }

    /// Fold one unit result into the aggregate — the single accumulation
    /// path shared by the plan merge and every monolithic workload loop
    /// (previously four hand-rolled copies of these five updates).
    pub fn merge_unit(&mut self, u: &LayerOpSim) {
        self.per_op[u.op as usize].0 += u.base_chip_cycles;
        self.per_op[u.op as usize].1 += u.td_chip_cycles;
        self.energy_base.merge(&u.energy_base);
        self.energy_td.merge(&u.energy_td);
        self.sched.merge(&u.sched);
        self.layers.push(*u);
    }

    pub fn op_speedup(&self, op: TrainOp) -> f64 {
        let (b, t) = self.per_op[op as usize];
        cycle_ratio(b, t)
    }

    pub fn overall_speedup(&self) -> f64 {
        let b: u64 = self.per_op.iter().map(|(b, _)| b).sum();
        let t: u64 = self.per_op.iter().map(|(_, t)| t).sum();
        cycle_ratio(b, t)
    }

    pub fn compute_efficiency(&self) -> f64 {
        let (b, t) = (self.energy_base.compute_pj(), self.energy_td.compute_pj());
        if b == 0.0 || t == 0.0 {
            1.0
        } else {
            b / t
        }
    }

    pub fn total_efficiency(&self) -> f64 {
        let (b, t) = (self.energy_base.total_pj(), self.energy_td.total_pj());
        if b == 0.0 || t == 0.0 {
            1.0
        } else {
            b / t
        }
    }
}

/// Simulate a full model from its synthetic sparsity profile at epoch
/// fraction `epoch`.
///
/// Thin wrapper over the plan pipeline: expands the profile into its
/// unit graph and executes it serially on the calling thread. Use an
/// [`Engine`] with a profile [`SimRequest`] to execute the same units
/// on the worker pool — byte-identically.
pub fn simulate_profile(
    cfg: &ChipConfig,
    profile: &ModelProfile,
    epoch: f64,
    samples: usize,
    seed: u64,
) -> ModelSim {
    ModelPlan::profile(profile, epoch, cfg, samples, seed).execute_serial()
}

/// Simulate a model from *captured* (real-training) bitmaps. `name`
/// labels the result (the coordinator threads the model name from
/// `artifacts/meta.json` here).
///
/// Copies the slice once into the plan's shared storage; callers that
/// already own the bitmaps should go through [`SimRequest::trace`] +
/// [`Engine`], which shares them copy-free.
pub fn simulate_trace(
    cfg: &ChipConfig,
    name: &str,
    shapes: &[ConvShape],
    layers: &[(TensorBitmap, TensorBitmap)],
    samples: usize,
    seed: u64,
) -> ModelSim {
    let shared = std::sync::Arc::new(layers.to_vec());
    ModelPlan::trace(name, shapes, shared, cfg, samples, seed).execute_serial()
}

// ---------------------------------------------------------------------
// Figure/table drivers — SimRequest builders returning Reports
// ---------------------------------------------------------------------

/// The representative mid-training epoch used by single-point figures.
pub const MID_EPOCH: f64 = 0.4;

/// Fig. 1 — potential speedup (allMACs / remaining MACs) per conv.
pub fn fig1() -> Report {
    let mut r = Report::new(
        "fig1",
        "Fig. 1 — potential speedup from eliminating zero-operand MACs",
        &["model", "A*W", "A*G", "W*G", "mean"],
    );
    let mut all = Vec::new();
    for p in ModelProfile::all() {
        // MAC-weighted potential per op.
        let mut pot = [0.0f64; 3];
        let total_macs: u64 = p.topology.layers.iter().map(|l| l.shape.macs()).sum();
        for (i, l) in p.topology.layers.iter().enumerate() {
            let w = l.shape.macs() as f64 / total_macs as f64;
            for op in TrainOp::ALL {
                pot[op as usize] += w * p.potential(i, op, MID_EPOCH);
            }
        }
        let mean = (pot[0] + pot[1] + pot[2]) / 3.0;
        if p.name() != "gcn" {
            all.push(mean);
        }
        r.row(vec![
            Cell::text(p.name()),
            Cell::num(pot[0]),
            Cell::num(pot[1]),
            Cell::num(pot[2]),
            Cell::num(mean),
        ]);
    }
    r.row(vec![
        Cell::text("average(ex-gcn)"),
        Cell::empty(),
        Cell::empty(),
        Cell::empty(),
        Cell::num(all.iter().sum::<f64>() / all.len() as f64),
    ]);
    r
}

/// Run the Fig. 13 simulation set once (also feeds Figs. 15/16): a
/// single-config sweep over the nine evaluation models, executed on the
/// engine's worker pool.
pub fn run_fig13_sims(
    engine: &Engine,
    cfg: &ChipConfig,
    samples: usize,
    seed: u64,
) -> Vec<ModelSim> {
    let spec = SweepSpec::models(&FIG13_MODELS, MID_EPOCH, cfg, samples, seed);
    engine.run_all(&spec.cells())
}

/// Fig. 13 — TensorDash speedup over the baseline per op and model.
pub fn fig13(sims: &[ModelSim]) -> Report {
    let mut r = Report::new(
        "fig13",
        "Fig. 13 — TensorDash speedup over baseline (default Table-2 config)",
        &["model", "A*W", "A*G", "W*G", "overall"],
    );
    for s in sims {
        r.row(vec![
            Cell::text(s.name.clone()),
            Cell::num(s.op_speedup(TrainOp::Fwd)),
            Cell::num(s.op_speedup(TrainOp::Igrad)),
            Cell::num(s.op_speedup(TrainOp::Wgrad)),
            Cell::num(s.overall_speedup()),
        ]);
    }
    let avg = geomean(sims.iter().filter(|s| s.name != "gcn").map(|s| s.overall_speedup()));
    r.row(vec![
        Cell::text("geomean(ex-gcn)"),
        Cell::empty(),
        Cell::empty(),
        Cell::empty(),
        Cell::num(avg),
    ]);
    // Scheduler-cache telemetry of the sweep, surfaced machine-readably
    // (the counters are per-cell deterministic, so this meta block is
    // byte-identical at any --jobs count).
    let mut cache = crate::sim::CacheStats::default();
    for s in sims {
        cache.merge(&s.sched);
    }
    r.meta_num("sched_walks", cache.walks as f64);
    r.meta_num("sched_cache_hits", cache.hits as f64);
    r.meta_num("sched_fast_paths", cache.fast_paths as f64);
    r.meta_num("sched_skipped_cycles", cache.skipped_cycles as f64);
    r.meta_num("sched_hit_rate", cache.hit_rate());
    r
}

/// Fig. 14 — speedup as training progresses: a model × epoch sweep,
/// expressed on the [`Regime::Schedule`] machinery: each model's cells
/// run under that model's own trajectory curve. A model scheduled onto
/// its own curve is bit-identical to the uniform default (the curve
/// *is* the profile's trajectory), so this generalisation changes no
/// bytes — pinned by `fig14_is_byte_identical_on_the_schedule_regime`.
pub fn fig14(engine: &Engine, cfg: &ChipConfig, samples: usize, seed: u64) -> Report {
    let mut columns: Vec<String> = vec!["model".into()];
    columns.extend(PHASES.iter().map(|e| format!("{:.0}%", e * 100.0)));
    let href: Vec<&str> = columns.iter().map(|s| s.as_str()).collect();
    let mut r = Report::new("fig14", "Fig. 14 — speedup vs training progress", &href);
    let spec = SweepSpec::models(&FIG13_MODELS, MID_EPOCH, cfg, samples, seed).with_epochs(&PHASES);
    let cells: Vec<SimRequest> = spec
        .cells()
        .into_iter()
        .map(|cell| {
            let curve = match &cell.workload {
                Workload::Profile { model, .. } => {
                    ModelProfile::for_model(model).expect("sweep validated the name").curve
                }
                _ => unreachable!("model sweeps expand to profile workloads"),
            };
            cell.with_regime(Regime::Schedule { curve })
        })
        .collect();
    let sims = engine.run_all(&cells);
    for (mi, m) in FIG13_MODELS.iter().enumerate() {
        let mut row = vec![Cell::text(*m)];
        for ei in 0..PHASES.len() {
            row.push(Cell::num(sims[mi * PHASES.len() + ei].overall_speedup()));
        }
        r.row(row);
    }
    r.meta_num("seed", seed as f64);
    r.meta_num("samples", samples as f64);
    r
}

/// Fig. 15 — energy efficiency of TensorDash over the baseline.
pub fn fig15(sims: &[ModelSim]) -> Report {
    let mut r = Report::new(
        "fig15",
        "Fig. 15 — energy efficiency (TensorDash / baseline)",
        &["model", "compute", "whole chip"],
    );
    for s in sims {
        r.row(vec![
            Cell::text(s.name.clone()),
            Cell::num(s.compute_efficiency()),
            Cell::num(s.total_efficiency()),
        ]);
    }
    let ex: Vec<&ModelSim> = sims.iter().filter(|s| s.name != "gcn").collect();
    r.row(vec![
        Cell::text("geomean(ex-gcn)"),
        Cell::num(geomean(ex.iter().map(|s| s.compute_efficiency()))),
        Cell::num(geomean(ex.iter().map(|s| s.total_efficiency()))),
    ]);
    r
}

/// Fig. 16 — energy breakdown (off-chip / core / on-chip).
pub fn fig16(sims: &[ModelSim]) -> Report {
    let mut r = Report::new(
        "fig16",
        "Fig. 16 — energy breakdown, TensorDash relative to its baseline",
        &[
            "model",
            "TD/base",
            "base core%",
            "base SRAM%",
            "base DRAM%",
            "TD core%",
            "TD SRAM%",
            "TD DRAM%",
        ],
    );
    for s in sims {
        let b = &s.energy_base;
        let d = &s.energy_td;
        let bt = b.total_pj();
        let dt = d.total_pj();
        let p = |v: f64| Cell::fmt(pct(v), v);
        r.row(vec![
            Cell::text(s.name.clone()),
            Cell::num(dt / bt),
            p(b.compute_pj() / bt),
            p((b.sram_pj + b.spad_pj) / bt),
            p(b.dram_pj / bt),
            p(d.compute_pj() / dt),
            p((d.sram_pj + d.spad_pj) / dt),
            p(d.dram_pj / dt),
        ]);
    }
    r
}

/// Fig. 17 / Fig. 18 — tile geometry sweeps.
pub fn fig17_rows(engine: &Engine, samples: usize, seed: u64) -> Report {
    geometry_sweep(
        engine,
        &[1, 2, 4, 8, 16],
        true,
        samples,
        seed,
        "fig17",
        "Fig. 17 — speedup vs PE rows (cols=4)",
    )
}

pub fn fig18_cols(engine: &Engine, samples: usize, seed: u64) -> Report {
    geometry_sweep(
        engine,
        &[4, 8, 16],
        false,
        samples,
        seed,
        "fig18",
        "Fig. 18 — speedup vs PE columns (rows=4)",
    )
}

fn geometry_sweep(
    engine: &Engine,
    sizes: &[usize],
    vary_rows: bool,
    samples: usize,
    seed: u64,
    id: &str,
    title: &str,
) -> Report {
    let mut columns: Vec<String> = vec!["model".into()];
    columns.extend(sizes.iter().map(|s| s.to_string()));
    let href: Vec<&str> = columns.iter().map(|s| s.as_str()).collect();
    let mut r = Report::new(id, title, &href);
    let models: Vec<&str> = FIG13_MODELS.iter().copied().filter(|m| *m != "gcn").collect();
    let configs: Vec<(String, ChipConfig)> = sizes
        .iter()
        .map(|&sz| {
            let cfg = if vary_rows {
                ChipConfig::default().with_geometry(sz, 4)
            } else {
                ChipConfig::default().with_geometry(4, sz)
            };
            (format!("{}{sz}", if vary_rows { "rows" } else { "cols" }), cfg)
        })
        .collect();
    let spec = SweepSpec::models(&models, MID_EPOCH, &ChipConfig::default(), samples, seed)
        .with_configs(configs);
    let sims = engine.run_all(&spec.cells());
    let mut avgs = vec![Vec::new(); sizes.len()];
    for (mi, m) in models.iter().enumerate() {
        let mut row = vec![Cell::text(*m)];
        for j in 0..sizes.len() {
            let v = sims[mi * sizes.len() + j].overall_speedup();
            avgs[j].push(v);
            row.push(Cell::num(v));
        }
        r.row(row);
    }
    let mut row = vec![Cell::text("geomean")];
    for a in &avgs {
        row.push(Cell::num(geomean(a.iter().copied())));
    }
    r.row(row);
    r
}

/// Fig. 19 — staging-buffer depth 2 vs 3 (same tensors per model: the
/// sweep derives one seed per model, shared by both depth configs).
pub fn fig19(engine: &Engine, samples: usize, seed: u64) -> Report {
    let mut r = Report::new(
        "fig19",
        "Fig. 19 — speedup with staging depth 2 (lookahead 1) vs 3",
        &["model", "depth 2", "depth 3"],
    );
    let models: Vec<&str> = FIG13_MODELS.iter().copied().filter(|m| *m != "gcn").collect();
    let spec = SweepSpec::models(&models, MID_EPOCH, &ChipConfig::default(), samples, seed)
        .with_configs(vec![
            ("depth2".to_string(), ChipConfig::default().with_depth(2)),
            ("depth3".to_string(), ChipConfig::default()),
        ]);
    let sims = engine.run_all(&spec.cells());
    let (mut a2, mut a3) = (Vec::new(), Vec::new());
    for (mi, m) in models.iter().enumerate() {
        let s2 = sims[mi * 2].overall_speedup();
        let s3 = sims[mi * 2 + 1].overall_speedup();
        a2.push(s2);
        a3.push(s3);
        r.row(vec![Cell::text(*m), Cell::num(s2), Cell::num(s3)]);
    }
    r.row(vec![
        Cell::text("geomean"),
        Cell::num(geomean(a2.iter().copied())),
        Cell::num(geomean(a3.iter().copied())),
    ]);
    r
}

/// Fig. 20 — randomly sparse tensors (DenseNet121 3rd-conv geometry),
/// sparsity 10%..90%, `samples_per_level` tensor draws per level, all
/// three ops. One request per sparsity level, so the nine levels fan
/// out over the worker pool with independent derived seeds.
pub fn fig20(engine: &Engine, samples_per_level: usize, seed: u64) -> Report {
    let mut r = Report::new(
        "fig20",
        "Fig. 20 — speedup on randomly sparse tensors (DenseNet121 conv3 dims)",
        &["sparsity", "ideal", "cap", "A*W", "A*G", "W*G", "mean"],
    );
    // DenseNet121's third convolution: dense block 1, first 3x3
    // (128 -> 32, 56x56) — long reduction streams (72 rows forward).
    let shape = crate::models::densenet121(crate::models::BATCH).layers[2].shape;
    let cfg = ChipConfig::default();
    let reqs: Vec<SimRequest> = (1..=9u64)
        .map(|lvl| {
            SimRequest::random_sparse(
                shape,
                lvl as f64 / 10.0,
                samples_per_level,
                16,
                cfg.clone(),
                DEFAULT_SAMPLES,
                derive_seed(seed, lvl - 1),
            )
        })
        .collect();
    let sims = engine.run_all(&reqs);
    for (i, sim) in sims.iter().enumerate() {
        let sp = (i + 1) as f64 / 10.0;
        let sps: Vec<f64> = TrainOp::ALL.iter().map(|&op| sim.op_speedup(op)).collect();
        let mean = (sps[0] + sps[1] + sps[2]) / 3.0;
        r.row(vec![
            Cell::fmt(pct(sp), sp),
            Cell::num(1.0 / (1.0 - sp)),
            Cell::num((1.0 / (1.0 - sp)).min(3.0)),
            Cell::num(sps[0]),
            Cell::num(sps[1]),
            Cell::num(sps[2]),
            Cell::num(mean),
        ]);
    }
    r.meta_num("samples_per_level", samples_per_level as f64);
    r.meta_num("seed", seed as f64);
    r
}

/// Table 3 — area and power breakdown (plus the §4.4 bf16 variant).
pub fn table3(dtype: DataType) -> Report {
    let cfg = ChipConfig::default().with_dtype(dtype);
    let a = AreaReport::compute(&cfg);
    let st = crate::energy::SiliconTable::for_dtype(dtype);
    let (id, label) = match dtype {
        DataType::Fp32 => ("table3_fp32", "Table 3 — area/power breakdown (FP32, 65nm @500MHz)"),
        DataType::Bf16 => ("table3_bf16", "Table 3 variant — bfloat16 (§4.4)"),
    };
    let mut r = Report::new(id, label, &["component", "area mm2", "power mW"]);
    let td_power =
        st.core_power_mw + st.transposer_power_mw + st.sched_bmux_power_mw + st.amux_power_mw;
    r.row(vec![Cell::text("compute cores"), Cell::num(a.core_mm2), Cell::num(st.core_power_mw)]);
    r.row(vec![
        Cell::text("transposers"),
        Cell::num(a.transposer_mm2),
        Cell::num(st.transposer_power_mw),
    ]);
    r.row(vec![
        Cell::text("schedulers+B-muxes"),
        Cell::num(a.sched_bmux_mm2),
        Cell::num(st.sched_bmux_power_mw),
    ]);
    r.row(vec![Cell::text("A-side muxes"), Cell::num(a.amux_mm2), Cell::num(st.amux_power_mw)]);
    r.row(vec![
        Cell::text("TensorDash total"),
        Cell::num(a.tensordash_compute()),
        Cell::num(td_power),
    ]);
    r.row(vec![
        Cell::text("baseline total"),
        Cell::num(a.baseline_compute()),
        Cell::num(st.core_power_mw),
    ]);
    r.row(vec![
        Cell::text("compute overhead"),
        Cell::fmt(format!("{:.3}x", a.compute_overhead()), a.compute_overhead()),
        Cell::fmt(format!("{:.3}x", td_power / st.core_power_mw), td_power / st.core_power_mw),
    ]);
    r.row(vec![
        Cell::text("whole-chip overhead (incl. AM/BM/CM+SP)"),
        Cell::fmt(format!("{:.4}x", a.whole_chip_overhead()), a.whole_chip_overhead()),
        Cell::text("-"),
    ]);
    r
}

/// §4.4 — GCN, the no-sparsity control: with and without power gating.
pub fn gcn_control(engine: &Engine, samples: usize, seed: u64) -> Report {
    let mut r = Report::new(
        "gcn_control",
        "GCN (no sparsity): TensorDash must not hurt",
        &["config", "speedup", "compute eff", "total eff"],
    );
    let mut gated_cfg = ChipConfig::default();
    gated_cfg.power_gate = true;
    let reqs = vec![
        SimRequest::profile("gcn", MID_EPOCH, ChipConfig::default(), samples, seed)
            .expect("gcn profile exists")
            .with_label("no power gating"),
        SimRequest::profile("gcn", MID_EPOCH, gated_cfg, samples, seed)
            .expect("gcn profile exists")
            .with_label("power gated (§3.5)"),
    ];
    for s in &engine.run_all(&reqs) {
        r.row(vec![
            Cell::text(s.name.clone()),
            Cell::num(s.overall_speedup()),
            Cell::num(s.compute_efficiency()),
            Cell::num(s.total_efficiency()),
        ]);
    }
    r
}

/// The `simulate` summary report: per-op and overall speedups plus
/// efficiency rows for one model simulation, with provenance and
/// scheduler-cache telemetry in the meta block. Shared by the CLI
/// `simulate` subcommand and the serving layer, so both render the
/// identical artifact for identical requests.
pub fn simulate_report(
    model: &str,
    epoch: f64,
    cfg: &ChipConfig,
    samples: usize,
    seed: u64,
    sim: &ModelSim,
) -> Report {
    let mut r = Report::new(
        "simulate",
        format!(
            "{model} @ epoch {epoch} ({}x{} tile, depth {})",
            cfg.tile_rows, cfg.tile_cols, cfg.staging_depth
        ),
        &["metric", "A*W", "A*G", "W*G", "overall"],
    );
    r.row(vec![
        Cell::text("speedup"),
        Cell::num(sim.op_speedup(TrainOp::Fwd)),
        Cell::num(sim.op_speedup(TrainOp::Igrad)),
        Cell::num(sim.op_speedup(TrainOp::Wgrad)),
        Cell::num(sim.overall_speedup()),
    ]);
    r.row(vec![
        Cell::text("compute efficiency"),
        Cell::empty(),
        Cell::empty(),
        Cell::empty(),
        Cell::num(sim.compute_efficiency()),
    ]);
    r.row(vec![
        Cell::text("whole-chip efficiency"),
        Cell::empty(),
        Cell::empty(),
        Cell::empty(),
        Cell::num(sim.total_efficiency()),
    ]);
    r.meta_str("model", model);
    r.meta_num("epoch", epoch);
    r.meta_num("seed", seed as f64);
    r.meta_num("samples", samples as f64);
    // Scheduler-cache telemetry of the underlying cycle simulation
    // (walks = actual encoder walks, i.e. memo misses).
    r.meta_num("sched_walks", sim.sched.walks as f64);
    r.meta_num("sched_cache_hits", sim.sched.hits as f64);
    r.meta_num("sched_fast_paths", sim.sched.fast_paths as f64);
    r.meta_num("sched_skipped_cycles", sim.sched.skipped_cycles as f64);
    r.meta_num("sched_hit_rate", sim.sched.hit_rate());
    r
}

/// Methodology check: sampled pass simulation vs exhaustive on a small
/// layer (keeps `DEFAULT_SAMPLES` honest).
pub fn validate_sampling(seed: u64) -> (f64, f64) {
    let shape = ConvShape::conv(2, 10, 10, 32, 32, 3, 1, 1);
    let mut rng = Rng::new(seed);
    let a = crate::trace::synthetic::clustered_bitmap((2, 10, 10, 32), 0.6, 0.35, &mut rng);
    let g = crate::trace::synthetic::clustered_bitmap((2, 10, 10, 32), 0.6, 0.35, &mut rng);
    let cfg = ChipConfig::default();
    let mut r1 = Rng::new(seed ^ 1);
    let exact =
        simulate_layer_op(&cfg, &shape, TrainOp::Fwd, &a, &g, usize::MAX >> 1, 16, &mut r1);
    let mut r2 = Rng::new(seed ^ 2);
    let sampled =
        simulate_layer_op(&cfg, &shape, TrainOp::Fwd, &a, &g, DEFAULT_SAMPLES, 16, &mut r2);
    (exact.speedup(), sampled.speedup())
}

/// [`validate_sampling`] as a structured report (the `repro --all`
/// trailer, now machine-readable like everything else).
pub fn sampling_report(seed: u64) -> Report {
    let (exact, sampled) = validate_sampling(seed);
    let mut r = Report::new(
        "sampling_validation",
        "Methodology — sampled vs exhaustive pass simulation",
        &["method", "speedup"],
    );
    r.row(vec![Cell::text("exhaustive"), Cell::num(exact)]);
    r.row(vec![Cell::text(format!("sampled ({DEFAULT_SAMPLES} passes)")), Cell::num(sampled)]);
    r.meta_num("seed", seed as f64);
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synthetic::clustered_bitmap;

    fn small_bitmaps(sp: f64, seed: u64) -> (ConvShape, TensorBitmap, TensorBitmap) {
        let s = ConvShape::conv(2, 8, 8, 32, 32, 3, 1, 1);
        let mut rng = Rng::new(seed);
        let a = clustered_bitmap((2, 8, 8, 32), sp, 0.35, &mut rng);
        let g = clustered_bitmap((2, 8, 8, 32), sp, 0.35, &mut rng);
        (s, a, g)
    }

    #[test]
    fn layer_op_speedup_bounds() {
        let (s, a, g) = small_bitmaps(0.6, 1);
        let mut rng = Rng::new(2);
        for op in TrainOp::ALL {
            let r = simulate_layer_op(&ChipConfig::default(), &s, op, &a, &g, 8, 16, &mut rng);
            let sp = r.speedup();
            assert!((1.0..=3.01).contains(&sp), "{op:?} speedup {sp}");
            assert!(r.energy_td.total_pj() < r.energy_base.total_pj());
        }
    }

    #[test]
    fn dense_tensors_no_slowdown() {
        let (s, a, g) = small_bitmaps(0.0, 3);
        let mut rng = Rng::new(4);
        let r =
            simulate_layer_op(&ChipConfig::default(), &s, TrainOp::Fwd, &a, &g, 8, 16, &mut rng);
        // Even with fully dense tensors TensorDash may skip the *padding*
        // zeros at window halos — a small real gain, never a slowdown.
        assert!(
            (1.0..1.1).contains(&r.speedup()),
            "dense speedup {}",
            r.speedup()
        );
        // Energy overhead without gating is bounded by the ~2% power adder.
        let eff = r.energy_base.total_pj() / r.energy_td.total_pj();
        assert!(eff > 0.97 && eff < 1.12, "dense eff {eff}");
    }

    #[test]
    fn power_gating_removes_the_penalty() {
        let (s, a, g) = small_bitmaps(0.0, 5);
        let mut cfg = ChipConfig::default();
        cfg.power_gate = true;
        let mut rng = Rng::new(6);
        let r = simulate_layer_op(&cfg, &s, TrainOp::Fwd, &a, &g, 8, 16, &mut rng);
        assert!(r.gated);
        assert_eq!(r.energy_base.total_pj(), r.energy_td.total_pj());
    }

    #[test]
    fn sampling_close_to_exhaustive() {
        let (exact, sampled) = validate_sampling(42);
        assert!(
            (exact - sampled).abs() / exact < 0.12,
            "sampled {sampled} vs exact {exact}"
        );
    }

    #[test]
    fn fig20_monotonic_and_capped() {
        let t = fig20(&Engine::serial(), 2, 7);
        // mean speedup column increases with sparsity and respects caps.
        let means: Vec<f64> = t
            .rows
            .iter()
            .map(|r| r.cells.last().unwrap().value.unwrap())
            .collect();
        assert_eq!(means.len(), 9);
        for w in means.windows(2) {
            // Per-level seeds are independent draws now; allow a little
            // more sampling noise than the shared-stream version did.
            assert!(w[1] >= w[0] - 0.08, "non-monotonic: {means:?}");
        }
        assert!(means[0] >= 1.0 && means[0] < 1.35);
        assert!(means[8] <= 3.01);
        assert!(means[8] > 2.5, "90% sparsity should approach the 3x cap: {}", means[8]);
    }

    #[test]
    fn fig20_parallel_matches_serial() {
        let a = fig20(&Engine::serial(), 1, 13);
        let b = fig20(&Engine::new(4), 1, 13);
        assert_eq!(a, b, "worker count must not change results");
        assert_eq!(a.render_json(), b.render_json());
    }

    #[test]
    fn table3_prints_both_dtypes() {
        let t = table3(DataType::Fp32).render_text();
        assert!(t.contains("30.41"));
        let b = table3(DataType::Bf16).render_text();
        assert!(b.contains("bfloat16"));
    }

    #[test]
    fn sampling_report_is_structured() {
        let r = sampling_report(42);
        assert_eq!(r.rows.len(), 2);
        let exact = r.value(0, "speedup").unwrap();
        let sampled = r.value(1, "speedup").unwrap();
        assert!((exact - sampled).abs() / exact < 0.12);
    }
}
