//! Stable, dependency-free hashing shared by every content-addressed
//! key in the system.
//!
//! Both the unit cache ([`crate::api::cache::UnitKey`]) and the
//! design-space search candidate encoder ([`crate::search::space`])
//! address content by 64-bit FNV-1a over a canonical byte string. They
//! must agree on the hash — a search candidate's canonical config is
//! exactly the `cfg` fragment of the unit keys its evaluation produces —
//! so the function lives here, in one module, instead of being
//! duplicated per consumer. The test vectors below pin the algorithm;
//! changing it invalidates every cache key and candidate id at once.

use crate::tensor::TensorBitmap;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over `bytes`, continuing from state `h`.
pub fn fnv1a64_with(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// 64-bit FNV-1a — the stable hash behind every cache key and search
/// candidate id. Pinned by test vectors; changing it invalidates every
/// key.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    fnv1a64_with(FNV_OFFSET, bytes)
}

/// Content hash of a bitmap: dims then packed words, little-endian.
pub fn bitmap_hash(bm: &TensorBitmap) -> u64 {
    let mut h = FNV_OFFSET;
    for d in [bm.n, bm.h, bm.w, bm.c] {
        h = fnv1a64_with(h, &(d as u64).to_le_bytes());
    }
    for w in bm.words() {
        h = fnv1a64_with(h, &w.to_le_bytes());
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn fnv1a64_matches_published_vectors() {
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn fnv1a64_with_continues_the_stream() {
        let whole = fnv1a64(b"foobar");
        let split = fnv1a64_with(fnv1a64(b"foo"), b"bar");
        assert_eq!(whole, split);
    }

    #[test]
    fn bitmap_hash_tracks_contents_and_dims() {
        let mut rng = Rng::new(1);
        let a = crate::trace::synthetic::random_bitmap((2, 4, 4, 16), 0.5, &mut rng);
        let same = TensorBitmap::from_raw((2, 4, 4, 16), a.words().to_vec());
        assert_eq!(bitmap_hash(&a), bitmap_hash(&same));
        let reshaped = TensorBitmap::from_raw((4, 2, 4, 16), a.words().to_vec());
        assert_ne!(bitmap_hash(&a), bitmap_hash(&reshaped));
        let mut words = a.words().to_vec();
        words[0] ^= 1;
        let flipped = TensorBitmap::from_raw((2, 4, 4, 16), words);
        assert_ne!(bitmap_hash(&a), bitmap_hash(&flipped));
    }
}
