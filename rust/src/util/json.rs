//! Minimal JSON reader for `artifacts/meta.json`.
//!
//! The AOT compile path emits a machine-generated, known-shape JSON
//! document; this parser supports exactly the JSON subset it uses
//! (objects, arrays, strings without escapes beyond \" \\ \/ \n \t,
//! integers, floats, booleans, null). No serde available offline.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style path access: keys and array indices.
    pub fn path(&self, parts: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in parts {
            cur = match cur {
                Json::Obj(m) => m.get(*p)?,
                Json::Arr(a) => a.get(p.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Array of numbers -> `Vec<usize>` (shape lists in meta.json).
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\n' | b'\r' | b'\t') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    out.push(match c {
                        b'"' => '"',
                        b'\\' => '\\',
                        b'/' => '/',
                        b'n' => '\n',
                        b't' => '\t',
                        b'r' => '\r',
                        _ => return Err(self.err("unsupported escape")),
                    });
                }
                Some(c) => {
                    // Copy raw UTF-8 bytes through.
                    let start = self.i;
                    let len = utf8_len(c);
                    self.i += len;
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_meta_like_document() {
        let doc = r#"{
          "model": {"batch": 16, "lr": 0.05,
                    "convs": [{"kernel": 3, "out_hw": [8, 8]}]},
          "params": [{"shape": [3,3,16,32], "dtype": "f32"}],
          "ok": true, "none": null
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.path(&["model", "batch"]).unwrap().as_usize(), Some(16));
        assert_eq!(j.path(&["model", "lr"]).unwrap().as_f64(), Some(0.05));
        assert_eq!(
            j.path(&["params", "0", "shape"]).unwrap().as_usize_vec(),
            Some(vec![3, 3, 16, 32])
        );
        assert_eq!(
            j.path(&["model", "convs", "0", "kernel"]).unwrap().as_usize(),
            Some(3)
        );
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(j.get("none"), Some(&Json::Null));
    }

    #[test]
    fn strings_and_escapes() {
        let j = Json::parse(r#"{"s": "a\"b\\c\nd"}"#).unwrap();
        assert_eq!(j.get("s").unwrap().as_str(), Some("a\"b\\c\nd"));
    }

    #[test]
    fn numbers() {
        let j = Json::parse("[-1, 2.5, 1e3, 0]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(-1.0));
        assert_eq!(a[1].as_f64(), Some(2.5));
        assert_eq!(a[2].as_f64(), Some(1000.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("123abc").is_err());
    }

    #[test]
    fn parses_the_real_meta_json_if_present() {
        if let Ok(text) = std::fs::read_to_string(
            concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/meta.json"),
        ) {
            let j = Json::parse(&text).expect("real meta.json must parse");
            assert!(j.path(&["model", "batch"]).is_some());
        }
    }
}
