//! Minimal JSON reader **and writer**.
//!
//! Reading: `artifacts/meta.json` (the AOT compile path emits a
//! machine-generated, known-shape document; the parser supports exactly
//! the JSON subset it uses — objects, arrays, strings without escapes
//! beyond \" \\ \/ \n \t \r, integers, floats, booleans, null).
//!
//! Writing: the experiment pipeline serialises [`api::Report`](crate::api::Report)s
//! and `BENCH_*.json` perf records through [`Json::render`] /
//! [`Json::render_pretty`]. The writer emits only the subset the parser
//! accepts, so `parse(render(x)) == x` for every finite value — pinned
//! by property tests. No serde available offline.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style path access: keys and array indices.
    pub fn path(&self, parts: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in parts {
            cur = match cur {
                Json::Obj(m) => m.get(*p)?,
                Json::Arr(a) => a.get(p.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Array of numbers -> `Vec<usize>` (shape lists in meta.json).
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // -- writer -------------------------------------------------------

    /// Compact one-line rendering. Round-trips through [`Json::parse`]
    /// for every value this module can represent (non-finite numbers,
    /// which JSON cannot express, render as `null`).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Human-readable rendering with 2-space indentation (the form
    /// `--out FILE` writes). Parses back identically to [`render`](Json::render).
    pub fn render_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in, colon) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
                ": ",
            ),
            None => ("", String::new(), String::new(), ":"),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => out.push_str(&render_num(*n)),
            Json::Str(s) => render_str(s, out),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    render_str(k, out);
                    out.push_str(colon);
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

/// Number formatting: integers without a trailing `.0`, everything else
/// through Rust's shortest-round-trip `Display` — so parsing the text
/// back recovers the exact same `f64`.
fn render_num(n: f64) -> String {
    if !n.is_finite() {
        return "null".to_string();
    }
    if n == n.trunc() && n.abs() < 9.0e15 {
        (n as i64).to_string()
    } else {
        n.to_string()
    }
}

/// String escaping limited to exactly the escapes the parser accepts.
/// (Control characters other than \n \t \r do not appear in this
/// project's documents; they would pass through raw.)
fn render_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            _ => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\n' | b'\r' | b'\t') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    out.push(match c {
                        b'"' => '"',
                        b'\\' => '\\',
                        b'/' => '/',
                        b'n' => '\n',
                        b't' => '\t',
                        b'r' => '\r',
                        _ => return Err(self.err("unsupported escape")),
                    });
                }
                Some(c) => {
                    // Copy raw UTF-8 bytes through.
                    let start = self.i;
                    let len = utf8_len(c);
                    self.i += len;
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_meta_like_document() {
        let doc = r#"{
          "model": {"batch": 16, "lr": 0.05,
                    "convs": [{"kernel": 3, "out_hw": [8, 8]}]},
          "params": [{"shape": [3,3,16,32], "dtype": "f32"}],
          "ok": true, "none": null
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.path(&["model", "batch"]).unwrap().as_usize(), Some(16));
        assert_eq!(j.path(&["model", "lr"]).unwrap().as_f64(), Some(0.05));
        assert_eq!(
            j.path(&["params", "0", "shape"]).unwrap().as_usize_vec(),
            Some(vec![3, 3, 16, 32])
        );
        assert_eq!(
            j.path(&["model", "convs", "0", "kernel"]).unwrap().as_usize(),
            Some(3)
        );
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(j.get("none"), Some(&Json::Null));
    }

    #[test]
    fn strings_and_escapes() {
        let j = Json::parse(r#"{"s": "a\"b\\c\nd"}"#).unwrap();
        assert_eq!(j.get("s").unwrap().as_str(), Some("a\"b\\c\nd"));
    }

    #[test]
    fn numbers() {
        let j = Json::parse("[-1, 2.5, 1e3, 0]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(-1.0));
        assert_eq!(a[1].as_f64(), Some(2.5));
        assert_eq!(a[2].as_f64(), Some(1000.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("123abc").is_err());
    }

    // -- writer tests -------------------------------------------------

    #[test]
    fn renders_scalars() {
        assert_eq!(Json::Null.render(), "null");
        assert_eq!(Json::Bool(true).render(), "true");
        assert_eq!(Json::Num(2.0).render(), "2");
        assert_eq!(Json::Num(-0.5).render(), "-0.5");
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::Str("a\"b\\c\nd\te\rf".into()).render(), r#""a\"b\\c\nd\te\rf""#);
    }

    #[test]
    fn renders_compound() {
        let mut m = std::collections::BTreeMap::new();
        m.insert("b".to_string(), Json::Arr(vec![Json::Num(1.0), Json::Null]));
        m.insert("a".to_string(), Json::Str("x".into()));
        let j = Json::Obj(m);
        assert_eq!(j.render(), r#"{"a":"x","b":[1,null]}"#);
        // Pretty form parses back to the same value.
        assert_eq!(Json::parse(&j.render_pretty()).unwrap(), j);
        assert_eq!(Json::Arr(vec![]).render(), "[]");
        assert_eq!(Json::Obj(Default::default()).render(), "{}");
    }

    /// Random JSON document generator for the round-trip property test.
    fn arbitrary(rng: &mut crate::util::rng::Rng, depth: usize) -> Json {
        let pick = if depth == 0 { rng.below(4) } else { rng.below(6) };
        match pick {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => {
                // Mix of integers, fractions and extreme magnitudes.
                match rng.below(4) {
                    0 => Json::Num((rng.below(2_000_001) as f64) - 1_000_000.0),
                    1 => Json::Num(rng.f64() * 2.0 - 1.0),
                    2 => Json::Num((rng.f64() - 0.5) * 1e12),
                    _ => Json::Num(rng.f64() * 1e-9),
                }
            }
            3 => {
                let n = rng.below(12);
                let s: String = (0..n)
                    .map(|_| {
                        let alphabet = "ab\"\\\n\t\r xyZ0—é";
                        let chars: Vec<char> = alphabet.chars().collect();
                        chars[rng.below(chars.len())]
                    })
                    .collect();
                Json::Str(s)
            }
            4 => {
                let n = rng.below(5);
                Json::Arr((0..n).map(|_| arbitrary(rng, depth - 1)).collect())
            }
            _ => {
                let n = rng.below(5);
                let mut m = std::collections::BTreeMap::new();
                for i in 0..n {
                    m.insert(format!("k{i}"), arbitrary(rng, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }

    #[test]
    fn property_round_trip_compact_and_pretty() {
        let mut rng = crate::util::rng::Rng::new(20260731);
        for _ in 0..500 {
            let x = arbitrary(&mut rng, 3);
            let compact = Json::parse(&x.render())
                .unwrap_or_else(|e| panic!("compact reparse failed: {e} for {}", x.render()));
            assert_eq!(compact, x, "compact round trip: {}", x.render());
            let pretty = Json::parse(&x.render_pretty())
                .unwrap_or_else(|e| panic!("pretty reparse failed: {e} for {}", x.render_pretty()));
            assert_eq!(pretty, x, "pretty round trip");
        }
    }

    #[test]
    fn property_float_formatting_round_trips_exactly() {
        // Shortest-round-trip Display: parse(render(x)) recovers the
        // exact f64 bits for any finite value, including awkward ones.
        let mut rng = crate::util::rng::Rng::new(99);
        let mut cases = vec![0.0, -0.0, 1.0 / 3.0, 0.1, 1e-300, 1e300, 2f64.powi(-52), 102.4];
        for _ in 0..2000 {
            let bits = rng.next_u64();
            let v = f64::from_bits(bits);
            if v.is_finite() {
                cases.push(v);
            }
        }
        for v in cases {
            let j = Json::parse(&Json::Num(v).render()).unwrap();
            let got = j.as_f64().unwrap();
            assert!(
                got == v || (got == 0.0 && v == 0.0),
                "float {v:?} rendered {} reparsed {got:?}",
                Json::Num(v).render()
            );
        }
    }

    #[test]
    fn parses_the_real_meta_json_if_present() {
        if let Ok(text) = std::fs::read_to_string(
            concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/meta.json"),
        ) {
            let j = Json::parse(&text).expect("real meta.json must parse");
            assert!(j.path(&["model", "batch"]).is_some());
        }
    }
}
