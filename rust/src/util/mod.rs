//! Small in-repo substrates.
//!
//! The build environment is fully offline, so the usual ecosystem crates
//! (clap, serde_json, rand, criterion, proptest) are unavailable; the
//! pieces of them this project needs are implemented here. Each is
//! deliberately minimal but fully tested.

pub mod bench;
pub mod cli;
pub mod hash;
pub mod json;
pub mod rng;
