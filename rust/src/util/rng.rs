//! Deterministic pseudo-random numbers (splitmix64 / xoshiro256**).
//!
//! All synthetic-tensor generation and pass sampling is seeded through
//! this RNG so every experiment in EXPERIMENTS.md is exactly
//! reproducible.

/// xoshiro256** seeded via splitmix64 — solid statistical quality for
/// simulation workloads, tiny, and dependency-free.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// A 16-bit mask with each bit set independently with probability `d`
    /// — one staging-buffer row at density `d`.
    ///
    /// Uses 8-bit probability resolution (two u64 draws per word instead
    /// of sixteen) — quantisation of < 0.4% is far below the sampling
    /// noise of any experiment here.
    pub fn mask16(&mut self, d: f64) -> u16 {
        if d >= 1.0 {
            return 0xFFFF;
        }
        if d <= 0.0 {
            return 0;
        }
        let t = (d * 256.0).round().clamp(1.0, 255.0) as u64;
        let mut m = 0u16;
        let r1 = self.next_u64();
        for l in 0..8 {
            m |= u16::from(((r1 >> (8 * l)) & 0xFF) < t) << l;
        }
        let r2 = self.next_u64();
        for l in 0..8 {
            m |= u16::from(((r2 >> (8 * l)) & 0xFF) < t) << (l + 8);
        }
        m
    }

    /// Like [`Self::mask16`] but with an independent per-lane threshold
    /// in [0, 256] (256 = always set) — used by the clustered
    /// feature-map generator.
    pub fn mask16_thresholds(&mut self, t: &[u16; 16]) -> u16 {
        let mut m = 0u16;
        let r1 = self.next_u64();
        for l in 0..8 {
            m |= u16::from(((r1 >> (8 * l)) & 0xFF) < t[l] as u64) << l;
        }
        let r2 = self.next_u64();
        for l in 0..8 {
            m |= u16::from(((r2 >> (8 * l)) & 0xFF) < t[l + 8] as u64) << (l + 8);
        }
        m
    }

    /// Standard normal via Box–Muller (used for synthetic values).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n), order arbitrary.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 3 > n {
            // partial Fisher–Yates
            let mut idx: Vec<usize> = (0..n).collect();
            for i in 0..k {
                let j = i + self.below(n - i);
                idx.swap(i, j);
            }
            idx.truncate(k);
            idx
        } else {
            let mut seen = std::collections::HashSet::with_capacity(k);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let v = self.below(n);
                if seen.insert(v) {
                    out.push(v);
                }
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn mask16_density() {
        let mut r = Rng::new(11);
        let mut ones = 0u64;
        let n = 20_000;
        for _ in 0..n {
            ones += r.mask16(0.3).count_ones() as u64;
        }
        let d = ones as f64 / (n as f64 * 16.0);
        assert!((d - 0.3).abs() < 0.01, "density {d}");
        assert_eq!(r.mask16(0.0), 0);
        assert_eq!(r.mask16(1.0), 0xFFFF);
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut hit = [false; 10];
        for _ in 0..1000 {
            hit[r.below(10)] = true;
        }
        assert!(hit.iter().all(|&h| h));
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        for (n, k) in [(10, 10), (100, 7), (50, 30)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
