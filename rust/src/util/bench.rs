//! Minimal benchmarking harness (criterion is unavailable offline).
//!
//! Provides warm-up, repeated timed runs, and median/mean/stddev
//! reporting. Used by every `benches/*.rs` target; those binaries also
//! print the paper's table/figure rows they regenerate.

use std::time::Instant;

#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub iters: u32,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
}

impl BenchStats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Run `f` with warmup then `iters` timed iterations. `f` must do the
/// same work every call; return a value to defeat dead-code elimination
/// (it is passed through `std::hint::black_box`).
pub fn bench<T>(name: &str, warmup: u32, iters: u32, mut f: impl FnMut() -> T) -> BenchStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len() as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let median = samples[samples.len() / 2];
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
    let stats = BenchStats {
        iters,
        mean_ns: mean,
        median_ns: median,
        stddev_ns: var.sqrt(),
        min_ns: samples[0],
    };
    println!(
        "bench {name:<40} {:>12.3} ms/iter (median {:.3} ms, min {:.3} ms, sd {:.1}%, n={})",
        stats.mean_ns / 1e6,
        stats.median_ns / 1e6,
        stats.min_ns / 1e6,
        if mean > 0.0 { stats.stddev_ns / mean * 100.0 } else { 0.0 },
        iters,
    );
    stats
}

/// Pretty separator for the table/figure sections benches print.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let s = bench("noop-ish", 1, 5, || {
            let mut x = 0u64;
            for i in 0..1000u64 {
                x = x.wrapping_add(i * i);
            }
            x
        });
        assert!(s.mean_ns > 0.0);
        assert!(s.min_ns <= s.median_ns);
        assert_eq!(s.iters, 5);
    }
}
