//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    /// Every `--key value` occurrence in command-line order — repeated
    /// options (`--axis a=1 --axis b=2`) keep all values here, while
    /// `options` keeps last-wins semantics for ordinary lookups.
    pub pairs: Vec<(String, String)>,
}

impl Args {
    /// Parse from an explicit iterator (testable) — flags must be listed
    /// in `known_flags` so `--flag positional` is not mis-parsed.
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I, known_flags: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = it.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.pairs.push((k.to_string(), v.to_string()));
                    out.options.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&body) {
                    out.flags.push(body.to_string());
                } else if let Some(v) = it.peek() {
                    if v.starts_with("--") {
                        out.flags.push(body.to_string());
                    } else {
                        let v = it.next().unwrap();
                        out.pairs.push((body.to_string(), v.clone()));
                        out.options.insert(body.to_string(), v);
                    }
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn parse(known_flags: &[&str]) -> Args {
        Self::parse_from(std::env::args().skip(1), known_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// All values of a repeated option, in command-line order:
    /// `--axis depth=2,3 --axis rows=2,4` -> both values. Empty when
    /// the option never appeared.
    pub fn get_multi(&self, name: &str) -> Vec<String> {
        self.pairs
            .iter()
            .filter(|(k, _)| k == name)
            .map(|(_, v)| v.clone())
            .collect()
    }

    /// Comma-separated list option: `--preload a,b` -> `["a", "b"]`.
    /// Segments are trimmed and empties dropped; `None` when absent.
    pub fn get_list(&self, name: &str) -> Option<Vec<String>> {
        self.get(name).map(|v| {
            v.split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(str::to_string)
                .collect()
        })
    }

    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{v}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str], flags: &[&str]) -> Args {
        Args::parse_from(s.iter().map(|v| v.to_string()), flags)
    }

    #[test]
    fn positional_options_flags() {
        let a = args(
            &["repro", "--fig", "13", "--verbose", "--seed=7", "extra"],
            &["verbose"],
        );
        assert_eq!(a.positional, vec!["repro", "extra"]);
        assert_eq!(a.get("fig"), Some("13"));
        assert_eq!(a.get("seed"), Some("7"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_getters() {
        let a = args(&["--n", "5", "--x", "2.5"], &[]);
        assert_eq!(a.get_usize("n", 0).unwrap(), 5);
        assert_eq!(a.get_f64("x", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_usize("missing", 9).unwrap(), 9);
        assert!(args(&["--n", "zz"], &[]).get_usize("n", 0).is_err());
    }

    #[test]
    fn list_option_splits_and_trims() {
        let a = args(&["--preload", "alexnet, gcn,,resnet50"], &[]);
        assert_eq!(
            a.get_list("preload"),
            Some(vec!["alexnet".to_string(), "gcn".to_string(), "resnet50".to_string()])
        );
        assert_eq!(a.get_list("missing"), None);
    }

    #[test]
    fn repeated_options_keep_every_value_in_order() {
        let a = args(
            &["explore", "--axis", "depth=2,3", "--axis=rows=2,4", "--seed", "7"],
            &[],
        );
        assert_eq!(a.get_multi("axis"), vec!["depth=2,3", "rows=2,4"]);
        assert_eq!(a.get("axis"), Some("rows=2,4"), "plain lookup stays last-wins");
        assert_eq!(a.get_multi("seed"), vec!["7"]);
        assert!(a.get_multi("missing").is_empty());
    }

    #[test]
    fn unknown_flag_before_flag() {
        let a = args(&["--a", "--b"], &[]);
        assert!(a.flag("a"));
        assert!(a.flag("b"));
    }
}
