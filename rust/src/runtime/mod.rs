//! PJRT runtime: load and execute the AOT-compiled HLO artifacts.
//!
//! This is the only place the `xla` crate is touched. Pattern (see
//! /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. The interchange format is HLO **text**
//! — serialized protos from jax ≥ 0.5 carry 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! Python never runs here: the artifacts under `artifacts/` were
//! produced once by `make artifacts`, and the rust binary is
//! self-contained afterwards.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// A PJRT CPU client plus the artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
}

/// A compiled computation ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at an artifact directory.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, artifact_dir: artifact_dir.as_ref().to_path_buf() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.artifact_dir
    }

    /// Load `<artifact_dir>/<name>.hlo.txt` and compile it.
    pub fn load(&self, name: &str) -> Result<Executable> {
        let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
        anyhow::ensure!(
            path.exists(),
            "artifact {} not found — run `make artifacts` first",
            path.display()
        );
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        Ok(Executable { exe, name: name.to_string() })
    }

    /// Read `<artifact_dir>/meta.json`.
    pub fn meta(&self) -> Result<crate::util::json::Json> {
        let path = self.artifact_dir.join("meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        crate::util::json::Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))
    }
}

impl Executable {
    /// Execute with literal inputs; the artifacts are lowered with
    /// `return_tuple=True`, so the single output literal is decomposed
    /// into the tuple's leaves.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let result = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        result
            .to_tuple()
            .with_context(|| format!("decomposing result tuple of {}", self.name))
    }
}

/// Build an f32 literal of the given shape (row-major values).
pub fn literal_f32(dims: &[usize], values: &[f32]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(values.len() == n, "shape/value mismatch: {dims:?} vs {}", values.len());
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(values).reshape(&dims_i64)?)
}

/// Build a 1-D i32 literal.
pub fn literal_i32(values: &[i32]) -> xla::Literal {
    xla::Literal::vec1(values)
}

/// Build an i32 scalar literal.
pub fn literal_i32_scalar(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract a literal's data as `Vec<f32>`.
pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract a literal's data as `Vec<i32>`.
pub fn to_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
    Ok(lit.to_vec::<i32>()?)
}

/// Extract a scalar f32.
pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = literal_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(to_f32(&l).unwrap(), vec![1., 2., 3., 4., 5., 6.]);
        assert!(literal_f32(&[2, 2], &[1.0]).is_err());
        let i = literal_i32(&[7, 8]);
        assert_eq!(to_i32(&i).unwrap(), vec![7, 8]);
    }

    // Artifact-dependent tests live in rust/tests/ (they need
    // `make artifacts` to have run first).
}
