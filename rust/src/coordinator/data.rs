//! Synthetic classification workload for the end-to-end training runs.
//!
//! Substitution (DESIGN.md): the paper traces ImageNet-class training;
//! here the e2e driver learns a synthetic but *real* (learnable) task:
//! each class is a fixed random non-negative template over the input
//! volume, and samples are noisy, randomly scaled copies. ReLU-style
//! clamping keeps inputs non-negative like post-activation features.
//! Only the resulting sparsity statistics reach the simulator.

use crate::util::rng::Rng;

/// Deterministic synthetic dataset generator.
pub struct DataGen {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub classes: usize,
    templates: Vec<Vec<f32>>,
    rng: Rng,
}

impl DataGen {
    pub fn new(h: usize, w: usize, c: usize, classes: usize, seed: u64) -> DataGen {
        let mut rng = Rng::new(seed);
        let size = h * w * c;
        let templates = (0..classes)
            .map(|_| {
                (0..size)
                    .map(|_| {
                        // Sparse-ish non-negative templates: ~45% zeros.
                        let v = rng.normal() as f32;
                        if v > -0.1 {
                            v.max(0.0) * 2.0
                        } else {
                            0.0
                        }
                    })
                    .collect()
            })
            .collect();
        DataGen { h, w, c, classes, templates, rng }
    }

    /// Next batch: (x, y) with `x` NHWC row-major, `y` class labels.
    pub fn batch(&mut self, n: usize) -> (Vec<f32>, Vec<i32>) {
        let size = self.h * self.w * self.c;
        let mut x = Vec::with_capacity(n * size);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let label = self.rng.below(self.classes);
            y.push(label as i32);
            let scale = 0.7 + 0.6 * self.rng.f64() as f32;
            for i in 0..size {
                let noise = 0.25 * self.rng.normal() as f32;
                x.push((self.templates[label][i] * scale + noise).max(0.0));
            }
        }
        (x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_labelled() {
        let mut g1 = DataGen::new(8, 8, 16, 10, 42);
        let mut g2 = DataGen::new(8, 8, 16, 10, 42);
        let (x1, y1) = g1.batch(16);
        let (x2, y2) = g2.batch(16);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
        assert_eq!(x1.len(), 16 * 8 * 8 * 16);
        assert!(y1.iter().all(|&l| (0..10).contains(&l)));
    }

    #[test]
    fn inputs_nonnegative_with_some_zeros() {
        let mut g = DataGen::new(8, 8, 16, 10, 1);
        let (x, _) = g.batch(8);
        assert!(x.iter().all(|&v| v >= 0.0));
        let zeros = x.iter().filter(|&&v| v == 0.0).count() as f64 / x.len() as f64;
        assert!(zeros > 0.1 && zeros < 0.8, "input zero fraction {zeros}");
    }

    #[test]
    fn classes_are_distinguishable() {
        // Templates of different classes differ substantially.
        let g = DataGen::new(8, 8, 16, 4, 7);
        let d01: f32 = g.templates[0]
            .iter()
            .zip(&g.templates[1])
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(d01 / g.templates[0].len() as f32 > 0.5);
    }
}
