//! L3 coordinator: the training-loop driver.
//!
//! Owns the request path end to end — python never runs here. The
//! coordinator loads the AOT artifacts (init + train step), generates
//! the synthetic classification workload, executes training steps via
//! PJRT, captures the per-layer sparsity bitmaps each step returns, and
//! feeds them to the cycle-accurate simulator, producing the projected
//! TensorDash speedup/energy for the *actual* tensors the model
//! produced while it learned.

pub mod data;

use anyhow::{Context, Result};

use crate::conv::ConvShape;
use crate::runtime::{
    literal_f32, literal_i32, literal_i32_scalar, scalar_f32, to_i32, Executable, Runtime,
};
use crate::trace::capture::StepTrace;
use crate::util::json::Json;

/// Model geometry parsed from `artifacts/meta.json`.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    /// Model name from `model.name` — labels the captured-trace
    /// simulation reports. Older artifacts without the field fall back
    /// to `"captured"`.
    pub name: String,
    pub batch: usize,
    pub input: (usize, usize, usize, usize),
    pub classes: usize,
    pub lr: f64,
    pub convs: Vec<ConvShape>,
    pub param_shapes: Vec<Vec<usize>>,
}

impl ModelMeta {
    pub fn parse(meta: &Json) -> Result<ModelMeta> {
        let model = meta.get("model").context("meta.json: no model")?;
        let name = model
            .get("name")
            .and_then(|v| v.as_str())
            .unwrap_or("captured")
            .to_string();
        let input = model
            .get("input")
            .and_then(|v| v.as_usize_vec())
            .context("meta.json: model.input")?;
        anyhow::ensure!(input.len() == 4, "model.input must be NHWC");
        let batch = input[0];
        let mut convs = Vec::new();
        let (mut h, mut w) = (input[1], input[2]);
        let mut c = input[3];
        for conv in model.get("convs").and_then(|v| v.as_arr()).context("convs")? {
            let k = conv.get("kernel").and_then(|v| v.as_usize()).context("kernel")?;
            let s = conv.get("stride").and_then(|v| v.as_usize()).context("stride")?;
            let p = conv.get("padding").and_then(|v| v.as_usize()).context("padding")?;
            let cout = conv.get("c_out").and_then(|v| v.as_usize()).context("c_out")?;
            let shape = ConvShape { n: batch, h, w, c, f: cout, kh: k, kw: k, stride: s, pad: p };
            let out_hw = conv.get("out_hw").and_then(|v| v.as_usize_vec()).context("out_hw")?;
            anyhow::ensure!(
                (shape.out_h(), shape.out_w()) == (out_hw[0], out_hw[1]),
                "meta out_hw mismatch: computed {:?} vs meta {:?}",
                (shape.out_h(), shape.out_w()),
                out_hw
            );
            h = out_hw[0];
            w = out_hw[1];
            c = cout;
            convs.push(shape);
        }
        let param_shapes = meta
            .get("params")
            .and_then(|v| v.as_arr())
            .context("params")?
            .iter()
            .map(|p| p.get("shape").and_then(|s| s.as_usize_vec()).context("param shape"))
            .collect::<Result<Vec<_>>>()?;
        Ok(ModelMeta {
            name,
            batch,
            input: (input[0], input[1], input[2], input[3]),
            classes: model.get("classes").and_then(|v| v.as_usize()).context("classes")?,
            lr: model.get("lr").and_then(|v| v.as_f64()).context("lr")?,
            convs,
            param_shapes,
        })
    }
}

/// Outcome of one coordinated training step.
#[derive(Debug, Clone)]
pub struct StepOutcome {
    pub step: usize,
    pub loss: f32,
    pub accuracy: f32,
    pub trace: StepTrace,
}

/// The trainer: persistent parameters + compiled executables.
pub struct Trainer {
    pub meta: ModelMeta,
    train_step: Executable,
    params: Vec<xla::Literal>,
    steps_done: usize,
}

impl Trainer {
    /// Load artifacts, compile, and initialise parameters on-device via
    /// the `init` artifact (seeded, reproducible).
    pub fn new(rt: &Runtime, seed: i32) -> Result<Trainer> {
        let meta = ModelMeta::parse(&rt.meta()?)?;
        let init = rt.load("init")?;
        let train_step = rt.load("train_step")?;
        let params = init.run(&[literal_i32_scalar(seed)])?;
        anyhow::ensure!(
            params.len() == meta.param_shapes.len(),
            "init returned {} params, meta says {}",
            params.len(),
            meta.param_shapes.len()
        );
        Ok(Trainer { meta, train_step, params, steps_done: 0 })
    }

    pub fn steps_done(&self) -> usize {
        self.steps_done
    }

    /// Execute one SGD step on a batch, updating the held parameters and
    /// returning metrics + the captured sparsity trace.
    pub fn step(&mut self, x: &[f32], y: &[i32]) -> Result<StepOutcome> {
        let (n, h, w, c) = self.meta.input;
        anyhow::ensure!(x.len() == n * h * w * c, "bad x size");
        anyhow::ensure!(y.len() == n, "bad y size");
        let mut inputs: Vec<xla::Literal> = std::mem::take(&mut self.params);
        inputs.push(literal_f32(&[n, h, w, c], x)?);
        inputs.push(literal_i32(y));
        let outs = self.train_step.run(&inputs)?;
        let n_params = self.meta.param_shapes.len();
        let n_layers = self.meta.convs.len();
        anyhow::ensure!(
            outs.len() == n_params + 2 + 2 * n_layers,
            "train_step returned {} outputs, expected {}",
            outs.len(),
            n_params + 2 + 2 * n_layers
        );
        let mut outs = outs.into_iter();
        self.params = (&mut outs).take(n_params).collect();
        let loss = scalar_f32(&outs.next().unwrap())?;
        let acc = scalar_f32(&outs.next().unwrap())?;
        let a_words: Vec<Vec<i32>> = (&mut outs)
            .take(n_layers)
            .map(|l| to_i32(&l))
            .collect::<Result<_>>()?;
        let g_words: Vec<Vec<i32>> = outs.map(|l| to_i32(&l)).collect::<Result<_>>()?;
        let trace = StepTrace::from_words(&self.meta.convs, &a_words, &g_words, loss, acc)?;
        self.steps_done += 1;
        Ok(StepOutcome { step: self.steps_done, loss, accuracy: acc, trace })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parses_the_expected_document() {
        let doc = r#"{
          "model": {"name": "aot-cnn", "batch": 16, "input": [16,8,8,16], "classes": 10, "lr": 0.05,
            "convs": [
              {"kernel":3,"stride":1,"padding":1,"c_in":16,"c_out":32,"out_hw":[8,8]},
              {"kernel":3,"stride":2,"padding":1,"c_in":32,"c_out":32,"out_hw":[4,4]}
            ]},
          "params": [{"shape":[3,3,16,32],"dtype":"f32"},{"shape":[3,3,32,32],"dtype":"f32"}]
        }"#;
        let meta = ModelMeta::parse(&Json::parse(doc).unwrap()).unwrap();
        assert_eq!(meta.name, "aot-cnn");
        assert_eq!(meta.batch, 16);
        assert_eq!(meta.convs.len(), 2);
        assert_eq!(meta.convs[1].stride, 2);
        assert_eq!(meta.convs[1].out_h(), 4);
        assert_eq!(meta.param_shapes[0], vec![3, 3, 16, 32]);
    }

    #[test]
    fn meta_without_name_falls_back_to_captured() {
        let doc = r#"{
          "model": {"batch": 4, "input": [4,8,8,16], "classes": 10, "lr": 0.05, "convs": []},
          "params": []
        }"#;
        let meta = ModelMeta::parse(&Json::parse(doc).unwrap()).unwrap();
        assert_eq!(meta.name, "captured");
    }

    #[test]
    fn meta_rejects_inconsistent_out_hw() {
        let doc = r#"{
          "model": {"batch": 4, "input": [4,8,8,16], "classes": 10, "lr": 0.05,
            "convs": [{"kernel":3,"stride":1,"padding":1,"c_in":16,"c_out":32,"out_hw":[5,5]}]},
          "params": []
        }"#;
        assert!(ModelMeta::parse(&Json::parse(doc).unwrap()).is_err());
    }
}
