//! The hierarchical hardware scheduler (paper §3.2, Fig. 10).
//!
//! Input: the window bit-vector `Z` where bit `(step, lane)` is set iff
//! that staging-buffer pair is *effectual* and not yet consumed (for
//! two-side extraction `Z = AZ & BZ`; for the tile's one-side
//! configuration `Z` is the B-side vector alone).
//!
//! Each lane runs an 8-to-3 static priority encoder over its movement
//! options. Lanes are arranged in six levels — groups
//! `{0,5,10} {1,6,11} {2,7,12} {3,8,13} {4,9,14} {15}` — such that lanes
//! within a level cannot reach the same slot (their option sets are ≥5
//! lanes apart, the widest lookaside being ±3). After each level its
//! selections are ANDed out of `Z` before the next level sees it, which
//! guarantees a *valid* schedule: every pair consumed at most once. The
//! whole structure is combinational — one schedule per cycle.

use super::connectivity::{Connectivity, LANES};

/// `MS` value meaning "no effectual option available — lane idles".
pub const IDLE: u8 = 0xFF;

/// The Fig. 10 level grouping.
pub const LEVELS: [&[usize]; 6] = [
    &[0, 5, 10],
    &[1, 6, 11],
    &[2, 7, 12],
    &[3, 8, 13],
    &[4, 9, 14],
    &[15],
];

/// One cycle's scheduling decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Schedule {
    /// Per-lane mux select (index into the lane's option list), or
    /// [`IDLE`]. Shared by the A-side and B-side muxes of the lane.
    pub ms: [u8; LANES],
    /// Window bits consumed by this schedule.
    pub picks: u64,
    /// The `AS` signal: number of leading staging rows fully drained
    /// after this cycle (0..=depth). The staging buffer shifts by this
    /// amount and refills from the (banked) scratchpads.
    pub advance: u8,
}

impl Schedule {
    /// Number of busy multiplier lanes this cycle.
    #[inline]
    pub fn busy_lanes(&self) -> u32 {
        self.picks.count_ones()
    }
}

/// One lane's 8-to-3 static priority encoder: pick the lane's highest-
/// priority available option out of `remaining`, record the mux select
/// in `ms` and consume the picked bit. The single implementation shared
/// by the combinational ([`schedule_cycle`]) and iterative
/// ([`schedule_iterative`]) schedulers — they differ only in how many
/// cycles the level walk costs, never in selection semantics.
#[inline(always)]
fn encode_lane(
    conn: &Connectivity,
    lane: usize,
    remaining: &mut u64,
    ms: &mut [u8; LANES],
    picks: &mut u64,
) {
    // Cheap early-out: nothing this lane can reach is available
    // (very common at high sparsity).
    if *remaining & conn.reach[lane] == 0 {
        return;
    }
    // Branchless 8-to-3 priority encode: gather each option's
    // availability into one byte, then take the lowest set bit.
    // Unused option slots point at the UNUSED_OPT sentinel bit,
    // which is never set.
    let b = &conn.lanes[lane].bits;
    let avail = (((*remaining >> b[0]) & 1)
        | ((*remaining >> b[1]) & 1) << 1
        | ((*remaining >> b[2]) & 1) << 2
        | ((*remaining >> b[3]) & 1) << 3
        | ((*remaining >> b[4]) & 1) << 4
        | ((*remaining >> b[5]) & 1) << 5
        | ((*remaining >> b[6]) & 1) << 6
        | ((*remaining >> b[7]) & 1) << 7) as u32;
    if avail != 0 {
        let k = avail.trailing_zeros() as usize;
        ms[lane] = k as u8;
        let bit = 1u64 << b[k];
        *picks |= bit;
        *remaining &= !bit;
    }
}

/// The Fig. 10 level walk shared by the combinational and iterative
/// schedulers: run every level's lane encoders over `z`, returning the
/// per-lane selections and the consumed bits. The two schedulers differ
/// only in how many cycles this walk costs, never in selection
/// semantics.
fn walk_levels(conn: &Connectivity, z: u64) -> ([u8; LANES], u64) {
    let mut remaining = z;
    let mut ms = [IDLE; LANES];
    let mut picks = 0u64;
    for level in LEVELS {
        // All lanes of a level decide combinationally on the same view;
        // their option sets are disjoint by construction, so consuming
        // from `remaining` lane-by-lane is equivalent (and checked by the
        // property tests).
        for &lane in level {
            encode_lane(conn, lane, &mut remaining, &mut ms, &mut picks);
        }
    }
    (ms, picks)
}

/// `AS`: leading fully-drained rows = index of the lowest surviving bit
/// divided by the row width (64 trailing zeros when empty => depth).
#[inline]
fn advance_of(z: u64, picks: u64, depth: u8) -> u8 {
    let after = z & !picks;
    ((after.trailing_zeros() as u8) / LANES as u8).min(depth)
}

/// Run the combinational scheduler over window vector `z`.
///
/// `z` must only contain bits within `conn.window_mask()`. Rows of the
/// window that extend past the end of the operand stream must simply be
/// zero (an empty row is indistinguishable from a fully-ineffectual one).
pub fn schedule_cycle(conn: &Connectivity, z: u64) -> Schedule {
    debug_assert_eq!(z & !conn.window_mask(), 0, "z has bits outside window");
    let depth = conn.depth as u8;
    // Fast path: an all-ineffectual window is skipped whole (§3.5 spirit:
    // nothing to schedule, AS = depth). Very common at high sparsity.
    if z == 0 {
        return Schedule { ms: [IDLE; LANES], picks: 0, advance: depth };
    }
    let (ms, picks) = walk_levels(conn, z);
    Schedule { ms, picks, advance: advance_of(z, picks, depth) }
}

/// The §3.7 *iterative* scheduler: reuses ONE level of priority encoders
/// over several cycles instead of instantiating all six. Produces the
/// exact same schedule as [`schedule_cycle`] (same priority structure —
/// literally the same [`walk_levels`] body), but takes `LEVELS.len()`
/// cycles per scheduled row — the cheaper back-side configuration used
/// when pre-scheduling tensors into memory, where a schedule is needed
/// only once per *stored* row, not per executed cycle.
///
/// Returns the schedule plus the cycles the iteration consumed. The
/// all-ineffectual window takes the same early-out the combinational
/// path has: detecting `z == 0` is a single NOR, so the all-skip row is
/// emitted in one cycle instead of iterating six idle levels.
pub fn schedule_iterative(conn: &Connectivity, z: u64) -> (Schedule, u64) {
    let depth = conn.depth as u8;
    if z == 0 {
        return (Schedule { ms: [IDLE; LANES], picks: 0, advance: depth }, 1);
    }
    // One level per cycle: identical selection semantics.
    let (ms, picks) = walk_levels(conn, z);
    (Schedule { ms, picks, advance: advance_of(z, picks, depth) }, LEVELS.len() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::connectivity::slot_bit;

    fn conn() -> Connectivity {
        Connectivity::new(3)
    }

    fn row_mask(bits: &[usize]) -> u64 {
        bits.iter().map(|&b| 1u64 << b).fold(0, |a, b| a | b)
    }

    #[test]
    fn dense_head_row_takes_priority() {
        // Full head row: every lane picks its dense value, advance = 1
        // (rows +1/+2 untouched even if populated).
        let z = 0xFFFF | (0xFFFFu64 << 16) | (0xFFFFu64 << 32);
        let s = schedule_cycle(&conn(), z);
        assert!(s.ms.iter().all(|&m| m == 0));
        assert_eq!(s.picks, 0xFFFF);
        assert_eq!(s.advance, 1);
        assert_eq!(s.busy_lanes(), 16);
    }

    #[test]
    fn empty_window_skips_all_rows() {
        // All pairs ineffectual: nothing scheduled, whole window drained
        // in one cycle — the paper's 3x maximum speedup.
        let s = schedule_cycle(&conn(), 0);
        assert!(s.ms.iter().all(|&m| m == IDLE));
        assert_eq!(s.picks, 0);
        assert_eq!(s.advance, 3);
    }

    #[test]
    fn lookahead_fills_idle_lane() {
        // Lane 4 has nothing at step 0 but a value at step +1 -> lookahead.
        let mut z = 0u64;
        for l in 0..16 {
            if l != 4 {
                z |= 1 << slot_bit(0, l);
            }
        }
        z |= 1 << slot_bit(1, 4);
        let s = schedule_cycle(&conn(), z);
        assert_eq!(s.ms[4], 1, "lane 4 should take lookahead (+1,4)");
        // Rows 0 and 1 drain, and the (empty) row 2 counts as drained too.
        assert_eq!(s.advance, 3);
    }

    #[test]
    fn lookaside_steals_neighbor() {
        // Lane 8 idle at (0,8),(1,8),(2,8); its first lookaside (+1,7) set.
        let mut z = 0u64;
        for l in 0..16 {
            if l != 8 {
                z |= 1 << slot_bit(0, l);
            }
        }
        z |= 1 << slot_bit(1, 7);
        let s = schedule_cycle(&conn(), z);
        assert_eq!(s.ms[8], 3, "lane 8 should take lookaside (+1, i-1)");
        // lane 7's own dense pick is untouched by lane 8's steal.
        assert_eq!(s.ms[7], 0);
    }

    #[test]
    fn no_double_consumption_across_levels() {
        // Slot (1,7) is reachable by lanes 6 ((+1,i+1)), 7 ((+1,i)),
        // 8 ((+1,i-1)) and 10 ((+1,i-3)). However the scheduler resolves
        // the contention, exactly ONE lane may consume it.
        let z = (1u64 << slot_bit(1, 7)) | (1 << slot_bit(0, 7));
        let s = schedule_cycle(&conn(), z);
        assert_eq!(s.ms[7], 0, "lane 7 prefers its dense value");
        assert_eq!(s.picks, z, "both pairs consumed");
        let takers = [6, 8, 10].iter().filter(|&&l| s.ms[l] != IDLE).count();
        assert_eq!(takers, 1, "exactly one neighbour steals (1,7)");
        // lane 10 sits in the FIRST level {0,5,10}, so it wins the steal.
        assert_eq!(s.ms[10], 7);

        let z2 = 1u64 << slot_bit(1, 7);
        let s2 = schedule_cycle(&conn(), z2);
        let takers: Vec<usize> = (0..LANES).filter(|&l| s2.ms[l] != IDLE).collect();
        assert_eq!(takers.len(), 1, "single pair consumed exactly once");
        assert_eq!(takers[0], 10, "earliest level wins");
    }

    #[test]
    fn advance_counts_leading_drained_rows_only() {
        // Head row drains; +1 row still holds a pair no lane consumed
        // (e.g. more pairs than consumable): advance stays 1.
        let mut z = 0xFFFFu64; // dense head
        z |= 0xFFFFu64 << 16; // dense +1 row too
        let s = schedule_cycle(&conn(), z);
        assert_eq!(s.advance, 1);
    }

    #[test]
    fn iterative_scheduler_matches_combinational() {
        // §3.7: same schedule, 6 cycles instead of 1.
        let c = conn();
        let mut state = 0xABCDu64;
        for _ in 0..2000 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            let z = state & c.window_mask();
            let fast = schedule_cycle(&c, z);
            let (slow, cycles) = schedule_iterative(&c, z);
            assert_eq!(fast.picks, slow.picks);
            assert_eq!(fast.ms, slow.ms);
            assert_eq!(fast.advance, slow.advance);
            assert_eq!(cycles, if z == 0 { 1 } else { 6 });
        }
    }

    #[test]
    fn iterative_empty_window_early_out() {
        // The combinational z == 0 early-out applies to the iterative
        // scheduler too: the all-skip row costs one cycle, not six.
        let c = conn();
        let (s, cycles) = schedule_iterative(&c, 0);
        assert_eq!(cycles, 1);
        assert_eq!(s, schedule_cycle(&c, 0));
        assert_eq!(s.advance, 3);
        assert!(s.ms.iter().all(|&m| m == IDLE));
    }

    #[test]
    fn schedule_is_work_conserving_small_cases() {
        // For any z, picks ⊆ z and every picked bit reachable by picker.
        let c = conn();
        for trial in 0..500u64 {
            let z = (trial.wrapping_mul(0x9E3779B97F4A7C15)) & c.window_mask();
            let s = schedule_cycle(&c, z);
            assert_eq!(s.picks & !z, 0, "picked a non-effectual slot");
            for (lane, &m) in s.ms.iter().enumerate() {
                if m != IDLE {
                    let bit = 1u64 << c.lanes[lane].bits[m as usize];
                    assert_ne!(s.picks & bit, 0);
                }
            }
        }
    }
}
