//! Off-chip memory model: 16GB 4-channel LPDDR4-3200 with the
//! compressing-DMA zero compression of Rhu et al. (paper Table 2 — used
//! by BOTH the baseline and TensorDash).
//!
//! Zero compression: each transferred value carries a presence bit; only
//! non-zero values move as data. Compressed bytes for a tensor of `n`
//! values with non-zero fraction `d` and `w`-byte elements:
//! `ceil(n/8) + n*d*w`.

/// Off-chip traffic for one layer-operation, in bytes (post-compression).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DramTraffic {
    pub read_bytes: u64,
    pub write_bytes: u64,
}

impl DramTraffic {
    pub fn total(&self) -> u64 {
        self.read_bytes + self.write_bytes
    }

    pub fn merge(&mut self, o: &DramTraffic) {
        self.read_bytes += o.read_bytes;
        self.write_bytes += o.write_bytes;
    }
}

/// Compressed size in bytes of `values` elements of `elem_bytes` width at
/// `nonzero_fraction` density (compressing-DMA encoding).
pub fn compressed_bytes(values: u64, elem_bytes: u64, nonzero_fraction: f64) -> u64 {
    let bitmap = values.div_ceil(8);
    let data = (values as f64 * nonzero_fraction).ceil() as u64 * elem_bytes;
    bitmap + data
}

/// Dense (uncompressed) size in bytes.
pub fn dense_bytes(values: u64, elem_bytes: u64) -> u64 {
    values * elem_bytes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compression_never_exceeds_dense_plus_bitmap() {
        let v = 1 << 20;
        assert_eq!(compressed_bytes(v, 4, 1.0), v / 8 + v * 4);
        assert_eq!(compressed_bytes(v, 4, 0.0), v / 8);
        assert!(compressed_bytes(v, 4, 0.5) < dense_bytes(v, 4));
    }

    #[test]
    fn bf16_halves_data_term() {
        let v = 4096;
        let fp32 = compressed_bytes(v, 4, 0.5);
        let bf16 = compressed_bytes(v, 2, 0.5);
        assert_eq!(fp32 - bf16, v / 2 * 2);
    }
}
