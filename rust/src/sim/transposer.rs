//! The 16x16 tensor transposers (paper §3.4).
//!
//! During training every tensor is used by two convolutions that access
//! it in different orders (e.g. filters are "reconstructed" channel-wise
//! and rotated for the backward pass; gradients are grouped by channel
//! for op 2 but by spatial position for op 3). The §3.4 layout stores
//! tensors in 16x16 groups so that a transposer can read 16 blocks of 16
//! channel-contiguous values and serve them transposed (one value from
//! each block).
//!
//! Each transposer fills its 1KB 16x16 buffer with 16 row reads and then
//! supplies 16 transposed rows — a sustained rate of one 16-value row
//! per cycle per transposer (fill and drain overlap across the pool).

/// Work done by the transposer pool for one layer-operation.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransposerWork {
    /// 16x16 groups passed through the transposers.
    pub groups: u64,
}

impl TransposerWork {
    pub fn merge(&mut self, o: &TransposerWork) {
        self.groups += o.groups;
    }

    /// Row accesses through transposer buffers (16 in + 16 out per group).
    pub fn row_accesses(&self) -> u64 {
        self.groups * 32
    }

    /// Minimum cycles for `n_transposers` to stream this work: each group
    /// needs 16 row-supply cycles, transposers work in parallel.
    pub fn min_cycles(&self, n_transposers: u64) -> u64 {
        (self.groups * 16).div_ceil(n_transposers.max(1))
    }
}

/// Groups that must be transposed for a tensor of `values` elements.
pub fn groups_for_values(values: u64) -> u64 {
    values.div_ceil(256)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_math() {
        assert_eq!(groups_for_values(256), 1);
        assert_eq!(groups_for_values(257), 2);
        let w = TransposerWork { groups: 30 };
        assert_eq!(w.row_accesses(), 960);
        // 15 transposers, 30 groups x 16 supply cycles -> 32 cycles.
        assert_eq!(w.min_cycles(15), 32);
    }
}
