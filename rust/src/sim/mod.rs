//! Cycle-accurate model of the TensorDash micro-architecture (paper §3).
//!
//! The model is exact at the level the paper describes the hardware:
//!
//! * [`connectivity`] — the sparse operand interconnect: the per-lane
//!   8-input multiplexer pattern of Fig. 9 (2 lookahead + 5 lookaside)
//!   and its 5-option depth-2 variant (Fig. 19).
//! * [`scheduler`] — the combinational hierarchical scheduler of Fig. 10:
//!   per-lane static-priority encoders arranged in six levels whose lane
//!   groups cannot make overlapping choices.
//! * [`pe`] — a single processing element consuming a 16-lane operand
//!   stream through a 2/3-deep staging buffer.
//! * [`tile`] — the Fig. 11 tile: per-row schedulers and B-side staging,
//!   shared A-side staging per column, rows synchronised on the common
//!   staging-buffer advance (work imbalance => Fig. 17).
//! * [`stream`] — the shared streaming window core: the `Z`-vector
//!   cursor (load/consume/shift/refill) every per-cycle loop runs on,
//!   the memoizing [`stream::CachedScheduler`] (analytical fast paths +
//!   direct-mapped memo table) and arithmetic zero-run skipping.
//! * [`chip`] — many tiles processing independent work chunks plus the
//!   DRAM bandwidth gate.
//! * [`unit`] — one (layer, training-op) simulation unit as a typed
//!   three-stage pipeline (lower → sample → simulate/account); the
//!   grain the [`crate::api::plan`] executor schedules in parallel.
//! * [`memory`], [`dram`], [`transposer`] — the on-chip SRAM hierarchy
//!   (AM/BM/CM + scratchpads), the LPDDR4 + compressing-DMA model and the
//!   16x16 transposers of §3.4; these feed the energy model.

pub mod chip;
pub mod connectivity;
pub mod dram;
pub mod memory;
pub mod pe;
pub mod scheduler;
pub mod stream;
pub mod tile;
pub mod transposer;
pub mod unit;

pub use chip::{ChipSim, LayerCycles, Pass};
pub use connectivity::{Connectivity, LANES};
pub use pe::{baseline_cycles, simulate_stream};
pub use scheduler::{schedule_cycle, Schedule, IDLE};
pub use stream::{CacheStats, CachedScheduler, PackedStream, StreamWindow};
pub use tile::{tile_pass_cycles, DEFAULT_LEAD_LIMIT};
pub use unit::{cycle_ratio, simulate_unit, LayerOpSim};
