//! The TensorDash tile (paper §3.3, Fig. 11).
//!
//! A tile is a grid of PEs: PEs along a row share the same B operand
//! stream (one scheduler + one B-side staging buffer per row) and PEs
//! along a column share the same A stream (one A-side staging buffer per
//! column, with per-PE multiplexer blocks driven by the row's `MS`
//! signals). Sparsity is extracted from the **B side only** in this
//! configuration.
//!
//! **Inter-row synchronisation.** Every row's schedule indexes the
//! shared per-column A-side storage, so rows cannot drift apart without
//! bound: a row may run ahead of the slowest row only as far as the
//! A-side staging + banked scratchpad slack allows. We model this as a
//! *bounded lead* of `lead_limit` rows — `0` degenerates to per-cycle
//! lockstep, a large value to a free-running pass barrier. Work
//! imbalance across rows (§4.4: non-zeros cluster in a subset of
//! feature maps) then produces exactly the stalls the paper studies in
//! Fig. 17: speedup declines as rows are added.
//!
//! Each row's window state is a [`StreamWindow`] from
//! [`crate::sim::stream`]; rows step cycle-by-cycle against the lead
//! bound (so arithmetic zero-run skipping does not apply here — the
//! global cycle loop must observe every cycle), but all rows share one
//! [`CachedScheduler`], so empty windows and recurring window patterns
//! are answered without an encoder walk.

use super::connectivity::Connectivity;
use super::stream::{CachedScheduler, StreamWindow};

/// Default lead bound in stream rows: the 3-deep staging buffer plus one
/// scratchpad bank refill of slack on the shared A side.
pub const DEFAULT_LEAD_LIMIT: usize = 6;

/// Counters for one tile pass.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TileStats {
    pub cycles: u64,
    /// Effectual MACs issued per B-stream row-slot (multiply by the
    /// number of tile columns for whole-tile MACs — columns replicate the
    /// row schedule onto different A operands).
    pub macs: u64,
    /// Row-cycles spent stalled on the shared-operand lead bound.
    pub imbalance_stall_row_cycles: u64,
    /// Actual encoder walks (scheduler-cache misses) this pass cost.
    pub schedules: u64,
    /// Scheduler answers served from the memo table.
    pub cache_hits: u64,
    /// Scheduler answers served by the analytical fast paths.
    pub fast_paths: u64,
    /// Cycles retired by zero-run skipping (always 0 for the tile — the
    /// lead-bound loop steps every cycle — kept so the telemetry shape
    /// matches [`crate::sim::pe::StreamStats`]).
    pub skipped_cycles: u64,
}

/// Simulate one tile pass: `streams[r]` is the B-side effectual mask
/// stream for PE-row `r`. Returns the cycle count under the given lead
/// bound.
pub fn tile_pass_cycles(conn: &Connectivity, streams: &[Vec<u16>], lead_limit: usize) -> u64 {
    tile_pass_stats(conn, streams, lead_limit).cycles
}

/// Full-stats variant of [`tile_pass_cycles`] (fresh scheduler cache —
/// use [`tile_pass_stats_cached`] to amortise one across passes).
pub fn tile_pass_stats(conn: &Connectivity, streams: &[Vec<u16>], lead_limit: usize) -> TileStats {
    let mut sched = CachedScheduler::new(conn.clone());
    tile_pass_stats_cached(&mut sched, streams, lead_limit)
}

/// Tile pass through a caller-owned [`CachedScheduler`] (one per
/// worker/pass batch, so recurring window patterns stay warm across
/// passes while `Engine::map` cells remain independent). The returned
/// telemetry covers this pass only (counter deltas).
pub fn tile_pass_stats_cached(
    sched: &mut CachedScheduler,
    streams: &[Vec<u16>],
    lead_limit: usize,
) -> TileStats {
    let before = sched.stats;
    let depth = sched.depth();
    let mut stats = TileStats::default();
    let mut rows: Vec<StreamWindow> = streams.iter().map(|s| StreamWindow::new(s, depth)).collect();
    if rows.iter().all(|r| r.done()) {
        return stats;
    }
    loop {
        // The slowest unfinished row pins the shared A-side window.
        let min_pos = rows.iter().filter(|r| !r.done()).map(|r| r.pos()).min().unwrap();
        for row in rows.iter_mut() {
            if row.done() {
                continue;
            }
            if row.pos() > min_pos + lead_limit {
                // Shared-operand slack exhausted: this row stalls until
                // the laggards advance.
                stats.imbalance_stall_row_cycles += 1;
                continue;
            }
            let s = sched.schedule(row.z());
            stats.macs += s.picks.count_ones() as u64;
            row.apply(&s);
        }
        stats.cycles += 1;
        if rows.iter().all(|r| r.done()) {
            break;
        }
    }
    let d = sched.stats.since(&before);
    stats.schedules = d.walks;
    stats.cache_hits = d.hits;
    stats.fast_paths = d.fast_paths;
    stats.skipped_cycles = d.skipped_cycles;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::pe::{effectual_macs, simulate_stream};

    fn c3() -> Connectivity {
        Connectivity::new(3)
    }

    const L: usize = DEFAULT_LEAD_LIMIT;

    fn random_streams(n: usize, len: usize, seed: u64, and_mask: bool) -> Vec<Vec<u16>> {
        let mut state = seed;
        (0..n)
            .map(|_| {
                (0..len)
                    .map(|_| {
                        state = state
                            .wrapping_mul(6364136223846793005)
                            .wrapping_add(1442695040888963407);
                        let w = (state >> 33) as u16;
                        if and_mask {
                            w & (state >> 17) as u16
                        } else {
                            w
                        }
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn single_row_tile_equals_pe() {
        for (i, len) in [1usize, 5, 17, 64].iter().enumerate() {
            let rows = &random_streams(1, *len, 99 + i as u64, false)[0];
            assert_eq!(
                tile_pass_cycles(&c3(), std::slice::from_ref(rows), L),
                simulate_stream(&c3(), rows),
            );
        }
    }

    #[test]
    fn tile_is_gated_by_densest_row() {
        let sparse = vec![0u16; 30];
        let dense = vec![0xFFFFu16; 30];
        assert_eq!(tile_pass_cycles(&c3(), &[sparse.clone()], L), 10);
        // The all-zero row finishes its visible window fast but the pass
        // still takes the dense row's 30 cycles.
        assert_eq!(tile_pass_cycles(&c3(), &[sparse, dense], L), 30);
    }

    #[test]
    fn more_rows_never_faster() {
        let streams = random_streams(16, 40, 1234, true);
        let mut last = 0;
        for r in [1usize, 2, 4, 8, 16] {
            let c = tile_pass_cycles(&c3(), &streams[..r], L);
            assert!(c >= last, "rows={r}: {c} < {last}");
            last = c;
        }
    }

    #[test]
    fn tighter_lead_never_faster() {
        let streams = random_streams(4, 60, 777, true);
        let free = tile_pass_cycles(&c3(), &streams, usize::MAX / 2);
        let bounded = tile_pass_cycles(&c3(), &streams, L);
        let lockstep = tile_pass_cycles(&c3(), &streams, 0);
        assert!(free <= bounded);
        assert!(bounded <= lockstep);
        // And free-running equals the slowest independent row.
        let max_alone = streams.iter().map(|s| simulate_stream(&c3(), s)).max().unwrap();
        assert_eq!(free, max_alone);
    }

    #[test]
    fn tile_work_conserving() {
        let streams = random_streams(4, 25, 77, true);
        let stats = tile_pass_stats(&c3(), &streams, L);
        let want: u64 = streams.iter().map(|s| effectual_macs(s)).sum();
        assert_eq!(stats.macs, want);
        let base = streams.iter().map(|s| s.len()).max().unwrap() as u64;
        assert!(stats.cycles <= base);
        assert!(stats.cycles >= (base + 2) / 3);
    }

    #[test]
    fn tile_telemetry_accounts_for_every_scheduled_row_cycle() {
        let streams = random_streams(4, 25, 78, true);
        let st = tile_pass_stats(&c3(), &streams, L);
        // Scheduled row-cycles = active row-steps that were not stalled;
        // each is answered by exactly one of walk / hit / fast path, and
        // the tile never bulk-skips.
        assert_eq!(st.skipped_cycles, 0);
        assert!(st.schedules + st.cache_hits + st.fast_paths >= st.cycles);
    }

    #[test]
    fn shared_cache_across_passes_keeps_results_identical() {
        let streams = random_streams(3, 40, 555, true);
        let cold = tile_pass_stats(&c3(), &streams, L);
        let mut sched = CachedScheduler::new(c3());
        let first = tile_pass_stats_cached(&mut sched, &streams, L);
        let warm = tile_pass_stats_cached(&mut sched, &streams, L);
        for s in [&first, &warm] {
            assert_eq!(s.cycles, cold.cycles);
            assert_eq!(s.macs, cold.macs);
            assert_eq!(s.imbalance_stall_row_cycles, cold.imbalance_stall_row_cycles);
        }
        // The warm rerun of identical streams walks strictly less.
        assert!(warm.schedules <= first.schedules);
        assert!(warm.cache_hits >= first.cache_hits);
    }

    #[test]
    fn uneven_stream_lengths() {
        let a = vec![0xFFFFu16; 10];
        let b = vec![0xFFFFu16; 3];
        assert_eq!(tile_pass_cycles(&c3(), &[a, b], L), 10);
    }

    #[test]
    fn empty_tile() {
        assert_eq!(tile_pass_cycles(&c3(), &[], L), 0);
        assert_eq!(tile_pass_cycles(&c3(), &[vec![], vec![]], L), 0);
    }

    #[test]
    fn lane_lead_buildup_tracks_low_sparsity() {
        // The per-lane lead mechanism: at ~10% sparsity a single row
        // approaches the ideal 1.11x (paper Fig. 20's low end).
        let mut state = 5u64;
        let rows: Vec<u16> = (0..3000)
            .map(|_| {
                let mut w = 0u16;
                for l in 0..16 {
                    state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                    if (state >> 40) % 10 != 0 {
                        w |= 1 << l;
                    }
                }
                w
            })
            .collect();
        let cycles = tile_pass_cycles(&c3(), std::slice::from_ref(&rows), L);
        let speedup = rows.len() as f64 / cycles as f64;
        assert!(
            speedup > 1.06,
            "10% sparsity single-PE speedup {speedup} (ideal 1.11)"
        );
    }
}
