//! The simulation *unit*: one (layer, training-op) pair, decomposed
//! into a typed three-stage pipeline.
//!
//! The paper's aggregates (1.95x training speedup, 1.6x whole-chip
//! energy efficiency) are sums over every (layer, op) pair of every
//! model; this module makes that grain explicit so the executor can
//! schedule units independently:
//!
//! 1. **Lower** ([`lower_unit`]) — pure geometry: resolve the Wgrad
//!    B side, the A-side pass multiplier, the batch-scaling split
//!    (stream repetition vs residual cycle multiplier) and the §3.5
//!    power-gating decision. No randomness, no simulation.
//! 2. **Sample** ([`sample_unit_passes`]) — draw the pass sample from a
//!    unit-local RNG. Each unit owns its seed (derived by
//!    [`crate::api::derive_seed`] from the request seed and the unit
//!    index), so the result never depends on which other units ran
//!    before it — the property the plan executor's work stealing and
//!    deterministic merge rely on.
//! 3. **Simulate + account** ([`account_unit`]) — run the sampled
//!    passes through the cycle simulator, then fold in the analytic
//!    SRAM/DRAM/transposer traffic and the energy model.
//!
//! [`simulate_unit`] composes the three stages; the legacy
//! `repro::simulate_layer_op` is a thin wrapper that threads a
//! caller-owned RNG through stage 2 (sampling-validation and the
//! property tests rely on that byte-exact behaviour).

use crate::config::ChipConfig;
use crate::conv::work::{
    dram_traffic, pick_wgrad_side, sample_passes, sram_counts, transposer_work,
};
use crate::conv::{op_work, ConvShape, TrainOp, WgradSide};
use crate::energy::{EnergyBreakdown, EnergyModel};
use crate::sim::chip::{ChipSim, LayerCycles, Pass};
use crate::sim::stream::CacheStats;
use crate::tensor::TensorBitmap;
use crate::util::rng::Rng;

/// Guarded cycle ratio: empty or zero-cycle units are "no work", which
/// is a 1.0x ratio (not 0x — dividing a guarded denominator into a
/// zero numerator used to report a bogus 0x "slowdown" for units with
/// no sampled passes).
pub fn cycle_ratio(base: u64, td: u64) -> f64 {
    if base == 0 {
        1.0
    } else {
        base as f64 / td.max(1) as f64
    }
}

/// Simulation outcome of one (layer, op) unit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerOpSim {
    /// Layer index within the owning plan (0 for standalone units).
    pub layer: usize,
    pub op: TrainOp,
    pub base_chip_cycles: u64,
    pub td_chip_cycles: u64,
    /// Cycles the unit's off-chip traffic needs at the configured DRAM
    /// bandwidth — the memory-bound floor (informational unless
    /// `cfg.dram_gate` is set).
    pub dram_cycles: u64,
    /// Whether off-chip traffic needs more cycles than the TensorDash
    /// *compute* does. Decided at accounting time against the
    /// compute-only cycle count — comparing against `td_chip_cycles`
    /// would mislabel every DRAM-bound unit as compute-bound when
    /// `cfg.dram_gate` folds the memory floor into the chip cycles.
    pub dram_bound: bool,
    pub energy_base: EnergyBreakdown,
    pub energy_td: EnergyBreakdown,
    /// Sparsity of the operand scheduled on the B side.
    pub b_sparsity: f64,
    /// Whether §3.5 power gating bypassed TensorDash for this op.
    pub gated: bool,
    /// Scheduler-cache telemetry of the underlying tile simulation
    /// (walks / memo hits / fast paths / zero-run-skipped cycles).
    pub sched: CacheStats,
}

impl LayerOpSim {
    pub fn speedup(&self) -> f64 {
        cycle_ratio(self.base_chip_cycles, self.td_chip_cycles)
    }

    /// Energy efficiency (baseline energy over TensorDash energy),
    /// guarded like [`cycle_ratio`] for empty units.
    pub fn energy_efficiency(&self) -> f64 {
        let (b, t) = (self.energy_base.total_pj(), self.energy_td.total_pj());
        if b == 0.0 || t == 0.0 {
            1.0
        } else {
            b / t
        }
    }

    /// What limits this unit: `"dram"` when its off-chip traffic needs
    /// more cycles than the (TensorDash) compute does, else `"compute"`.
    pub fn bottleneck(&self) -> &'static str {
        if self.dram_bound {
            "dram"
        } else {
            "compute"
        }
    }
}

/// Stage-1 output: the dense geometry and scaling decisions of a unit.
#[derive(Debug, Clone, Copy)]
pub struct UnitLowering {
    /// Which operand the Wgrad scheduler targets (paper §2: the sparser
    /// of G_O / A; `Gradients` for Fwd/Igrad where it is unused).
    pub wside: WgradSide,
    /// A-side pass multiplier (dense A groups over tile columns).
    pub a_passes: u64,
    /// Stream repetition folded into each sampled pass (Wgrad's batch
    /// reduction runs over the batch, so its streams get longer).
    pub repeat: usize,
    /// Residual batch multiplier applied to cycle counts after `repeat`
    /// is capped (~512-row streams have converged lead behaviour).
    pub mult: u64,
    /// Sparsity of the operand scheduled on the B side.
    pub b_sparsity: f64,
    /// §3.5: per-tensor zero counters power-gate the TensorDash
    /// front-end when the targeted tensor shows (almost) no sparsity.
    pub gated: bool,
}

/// Stage 1 — lower one (layer, op) onto the accelerator. Pure in its
/// inputs: no RNG, no simulation.
pub fn lower_unit(
    cfg: &ChipConfig,
    shape: &ConvShape,
    op: TrainOp,
    a_bm: &TensorBitmap,
    g_bm: &TensorBitmap,
    batch_mult: u64,
) -> UnitLowering {
    let m = batch_mult.max(1);
    let wside = match op {
        TrainOp::Wgrad => pick_wgrad_side(a_bm, g_bm),
        _ => WgradSide::Gradients,
    };
    let work = op_work(shape, op, wside);
    let a_passes = work.a_groups.div_ceil(cfg.tile_cols as u64);

    // Scale batch-dependent work to the paper's real batch size (the
    // sparsity statistics come from the small simulated batch). Fwd and
    // Igrad gain m-times more windows (weight multiplier); Wgrad's
    // *reduction* runs over the batch, so its streams get m-times longer
    // instead (a 1-row stream cannot express lookahead). Repetition is
    // capped once streams exceed ~512 rows — the per-lane lead behaviour
    // has converged by then — and the remaining factor scales cycles.
    let (repeat, mult) = match op {
        TrainOp::Wgrad => {
            let steps = work.steps.max(1);
            let full = 512u64.div_ceil(steps).clamp(1, m) as usize;
            (full, m.div_ceil(full as u64))
        }
        _ => (1, m),
    };
    let b_sparsity = match op {
        TrainOp::Fwd => a_bm.sparsity(),
        TrainOp::Igrad => g_bm.sparsity(),
        TrainOp::Wgrad => match wside {
            WgradSide::Gradients => g_bm.sparsity(),
            WgradSide::Activations => a_bm.sparsity(),
        },
    };
    let gated = cfg.power_gate && b_sparsity < 0.025;
    UnitLowering { wside, a_passes, repeat, mult, b_sparsity, gated }
}

/// Stage 2 — draw the unit's pass sample. The RNG is the *only* source
/// of randomness in a unit; giving every unit its own seeded stream is
/// what makes the plan executor order-independent.
pub fn sample_unit_passes(
    cfg: &ChipConfig,
    shape: &ConvShape,
    op: TrainOp,
    low: &UnitLowering,
    a_bm: &TensorBitmap,
    g_bm: &TensorBitmap,
    samples: usize,
    rng: &mut Rng,
) -> Vec<Pass> {
    sample_passes(shape, op, low.wside, a_bm, g_bm, cfg.tile_rows, samples, low.repeat, rng)
}

/// Stage 3 — fold the simulated tile cycles together with the analytic
/// memory traffic into the unit's chip-level cycle and energy outcome.
pub fn account_unit(
    cfg: &ChipConfig,
    shape: &ConvShape,
    op: TrainOp,
    layer: usize,
    low: &UnitLowering,
    lc: &LayerCycles,
    a_bm: &TensorBitmap,
    g_bm: &TensorBitmap,
    batch_mult: u64,
) -> LayerOpSim {
    let m = batch_mult.max(1);
    let chip = ChipSim::new(cfg.clone());
    let emodel = EnergyModel::new(cfg.clone());

    let base_tile = lc.base * low.a_passes * low.mult;
    let td_tile = if low.gated { base_tile } else { lc.td * low.a_passes * low.mult };

    let mut sram = sram_counts(shape, op, low.wside, cfg.tile_rows as u64, cfg.tile_cols as u64);
    sram = sram.scaled(m);
    let out_density = match op {
        TrainOp::Fwd => 1.0,              // pre-activation outputs are dense
        TrainOp::Igrad => a_bm.density(), // G_A inherits the ReLU mask
        TrainOp::Wgrad => 1.0,            // weight gradients are dense
    };
    let dram = dram_traffic(shape, op, a_bm, g_bm, cfg.dtype.bytes(), out_density, m);
    let mut trans = transposer_work(shape, op, low.wside);
    if op == TrainOp::Wgrad {
        // Wgrad transposes gradients/activations, which scale with batch;
        // Igrad transposes the (batch-independent) weights.
        trans.groups *= m;
    }

    let base_chip = chip.chip_cycles(base_tile, dram.total());
    let td_chip = chip.chip_cycles(td_tile, dram.total());
    let dram_cycles = chip.dram_stream_cycles(dram.total());
    // Compute-only TD cycles: what `chip_cycles` returns before the
    // optional bandwidth gate folds the memory floor in.
    let td_compute = td_tile.div_ceil(cfg.tiles as u64);
    LayerOpSim {
        layer,
        op,
        base_chip_cycles: base_chip,
        td_chip_cycles: td_chip,
        dram_cycles,
        dram_bound: dram_cycles > td_compute,
        energy_base: emodel.layer_energy(base_chip, &sram, &dram, &trans, false),
        energy_td: emodel.layer_energy(td_chip, &sram, &dram, &trans, !low.gated),
        b_sparsity: low.b_sparsity,
        gated: low.gated,
        sched: lc.sched,
    }
}

/// The composed unit pipeline with a caller-owned RNG threaded through
/// stage 2 (the legacy `simulate_layer_op` calling convention —
/// sampling-validation draws exhaustive and sampled runs from distinct
/// RNG streams).
pub fn simulate_unit_with_rng(
    cfg: &ChipConfig,
    shape: &ConvShape,
    op: TrainOp,
    layer: usize,
    a_bm: &TensorBitmap,
    g_bm: &TensorBitmap,
    samples: usize,
    batch_mult: u64,
    rng: &mut Rng,
) -> LayerOpSim {
    let low = lower_unit(cfg, shape, op, a_bm, g_bm, batch_mult);
    let passes = sample_unit_passes(cfg, shape, op, &low, a_bm, g_bm, samples, rng);
    let lc = ChipSim::new(cfg.clone()).run_passes(&passes);
    account_unit(cfg, shape, op, layer, &low, &lc, a_bm, g_bm, batch_mult)
}

/// The composed unit pipeline from a per-unit seed — the plan
/// executor's entry point. Pure in `(cfg, shape, op, bitmaps, samples,
/// batch_mult, seed)`: two calls with the same arguments are
/// byte-identical regardless of what ran in between.
pub fn simulate_unit(
    cfg: &ChipConfig,
    shape: &ConvShape,
    op: TrainOp,
    layer: usize,
    a_bm: &TensorBitmap,
    g_bm: &TensorBitmap,
    samples: usize,
    batch_mult: u64,
    seed: u64,
) -> LayerOpSim {
    let mut rng = Rng::new(seed);
    simulate_unit_with_rng(cfg, shape, op, layer, a_bm, g_bm, samples, batch_mult, &mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synthetic::clustered_bitmap;

    fn inputs(sp: f64, seed: u64) -> (ConvShape, TensorBitmap, TensorBitmap) {
        let s = ConvShape::conv(2, 8, 8, 32, 32, 3, 1, 1);
        let mut rng = Rng::new(seed);
        let a = clustered_bitmap((2, 8, 8, 32), sp, 0.35, &mut rng);
        let g = clustered_bitmap((2, 8, 8, 32), sp, 0.35, &mut rng);
        (s, a, g)
    }

    #[test]
    fn cycle_ratio_guards_both_sides() {
        // Empty units are 1.0x, not 0x (the old code only guarded the
        // denominator and reported a bogus 0x for zero-cycle units).
        assert_eq!(cycle_ratio(0, 0), 1.0);
        assert_eq!(cycle_ratio(0, 5), 1.0);
        assert_eq!(cycle_ratio(10, 0), 10.0); // denominator guard
        assert_eq!(cycle_ratio(30, 10), 3.0);
    }

    #[test]
    fn unit_is_order_independent() {
        let cfg = ChipConfig::default();
        let (s, a, g) = inputs(0.6, 1);
        let first = simulate_unit(&cfg, &s, TrainOp::Fwd, 0, &a, &g, 4, 16, 99);
        // Simulate something else in between — must not matter.
        let _ = simulate_unit(&cfg, &s, TrainOp::Wgrad, 1, &a, &g, 4, 16, 7);
        let again = simulate_unit(&cfg, &s, TrainOp::Fwd, 0, &a, &g, 4, 16, 99);
        assert_eq!(first, again);
        // And a different seed samples different passes (statistically).
        let other = simulate_unit(&cfg, &s, TrainOp::Fwd, 0, &a, &g, 4, 16, 100);
        assert_eq!(other.op, TrainOp::Fwd);
    }

    #[test]
    fn staged_pipeline_matches_composed_wrapper() {
        let cfg = ChipConfig::default();
        let (s, a, g) = inputs(0.5, 2);
        for op in TrainOp::ALL {
            let composed = simulate_unit(&cfg, &s, op, 3, &a, &g, 4, 16, 11);
            let low = lower_unit(&cfg, &s, op, &a, &g, 16);
            let mut rng = Rng::new(11);
            let passes = sample_unit_passes(&cfg, &s, op, &low, &a, &g, 4, &mut rng);
            let lc = ChipSim::new(cfg.clone()).run_passes(&passes);
            let staged = account_unit(&cfg, &s, op, 3, &low, &lc, &a, &g, 16);
            assert_eq!(composed, staged, "{op:?}");
        }
    }

    #[test]
    fn lowering_is_pure_geometry() {
        let cfg = ChipConfig::default();
        let (s, a, g) = inputs(0.4, 3);
        let l1 = lower_unit(&cfg, &s, TrainOp::Wgrad, &a, &g, 16);
        let l2 = lower_unit(&cfg, &s, TrainOp::Wgrad, &a, &g, 16);
        assert_eq!(l1.wside, l2.wside);
        assert_eq!(l1.a_passes, l2.a_passes);
        assert_eq!((l1.repeat, l1.mult), (l2.repeat, l2.mult));
        // Fwd/Igrad keep the full multiplier on cycles.
        let lf = lower_unit(&cfg, &s, TrainOp::Fwd, &a, &g, 16);
        assert_eq!(lf.repeat, 1);
        assert_eq!(lf.mult, 16);
    }

    #[test]
    fn bottleneck_is_compute_without_a_dram_wall() {
        let cfg = ChipConfig::default();
        let (s, a, g) = inputs(0.6, 4);
        let u = simulate_unit(&cfg, &s, TrainOp::Fwd, 0, &a, &g, 4, 16, 5);
        assert!(u.dram_cycles > 0);
        assert!(matches!(u.bottleneck(), "compute" | "dram"));
        assert!(u.energy_efficiency() >= 1.0);
    }

    #[test]
    fn bottleneck_reports_dram_even_when_the_gate_binds_chip_cycles() {
        // With the bandwidth gate on, chip cycles saturate at the memory
        // floor (td_chip == dram_cycles); the bottleneck decision must
        // compare against the *compute-only* cycles or every DRAM-bound
        // unit would be mislabeled "compute".
        let mut cfg = ChipConfig::default();
        cfg.dram_gate = true;
        cfg.dram_gbps = 0.05; // starved bandwidth -> memory bound
        let (s, a, g) = inputs(0.6, 6);
        let u = simulate_unit(&cfg, &s, TrainOp::Fwd, 0, &a, &g, 4, 16, 7);
        assert_eq!(u.td_chip_cycles, u.dram_cycles, "gate folds the floor in");
        assert!(u.dram_bound);
        assert_eq!(u.bottleneck(), "dram");
    }

    #[test]
    fn high_reuse_layer_is_compute_bound_on_the_default_chip() {
        // 128-channel 3x3 conv at batch-equivalent 32: enough MACs per
        // transferred byte that the default 51.2 GB/s stays ahead.
        let s = ConvShape::conv(2, 14, 14, 128, 128, 3, 1, 1);
        let mut rng = Rng::new(8);
        let a = clustered_bitmap((2, 14, 14, 128), 0.6, 0.35, &mut rng);
        let g = clustered_bitmap((2, 14, 14, 128), 0.6, 0.35, &mut rng);
        let v = simulate_unit(&ChipConfig::default(), &s, TrainOp::Fwd, 0, &a, &g, 4, 16, 7);
        assert!(!v.dram_bound, "dram {} vs td {}", v.dram_cycles, v.td_chip_cycles);
        assert_eq!(v.bottleneck(), "compute");
    }
}
