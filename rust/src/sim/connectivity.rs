//! The sparse operand interconnect (paper Fig. 9).
//!
//! Each of the 16 multiplier lanes is fed by a small multiplexer that can
//! read one of a *limited* set of staging-buffer entries. For the 3-deep
//! staging buffer the per-lane options, in the scheduler's static priority
//! order (§3.2), are — in `(step, lane)` notation relative to lane `i`:
//!
//! ```text
//!   (+0, i)    dense-schedule value
//!   (+1, i)    lookahead 1
//!   (+2, i)    lookahead 2
//!   (+1, i-1)  lookaside    \
//!   (+1, i+1)  lookaside     |  5 lookaside options
//!   (+2, i-2)  lookaside     |  (ring-wrapped at the lane ends)
//!   (+2, i+2)  lookaside     |
//!   (+1, i-3)  lookaside    /
//! ```
//!
//! 8 options => a 3-bit `MS` select per lane. The 2-deep variant
//! (Fig. 19) keeps the 5 options with step <= 1.

/// MAC lanes per PE. The scheduler level structure is specific to 16.
pub const LANES: usize = 16;

/// Maximum staging depth supported (the paper evaluates 2 and 3).
pub const MAX_DEPTH: usize = 3;

/// Encodes a staging-buffer slot as a bit index into a `u64` window mask.
#[inline(always)]
pub const fn slot_bit(step: usize, lane: usize) -> u8 {
    (step * LANES + lane) as u8
}

/// Sentinel bit index for unused option slots: bit 63 is outside every
/// window mask (max depth 3 => bits 0..48), so a padded option can never
/// appear available — this lets the scheduler scan a fixed 8 options
/// branchlessly for both depths.
pub const UNUSED_OPT: u8 = 63;

/// The movement options of one lane, priority ordered.
#[derive(Debug, Clone, Copy)]
pub struct LaneOptions {
    /// Bit indices (into the window mask) of each option; unused slots
    /// hold [`UNUSED_OPT`].
    pub bits: [u8; 8],
    /// Number of valid options (8 for depth 3, 5 for depth 2).
    pub len: usize,
}

/// The full interconnect pattern: identical per lane, shifted with
/// wrap-around (the ports are "arranged into a ring", §3.1).
#[derive(Debug, Clone)]
pub struct Connectivity {
    pub depth: usize,
    pub lanes: [LaneOptions; LANES],
    /// Per-lane masks of reachable window bits (for invariant checks).
    pub reach: [u64; LANES],
}

/// `(step, lane_offset)` template, priority ordered, for depth 3.
pub const TEMPLATE_D3: [(usize, isize); 8] = [
    (0, 0),
    (1, 0),
    (2, 0),
    (1, -1),
    (1, 1),
    (2, -2),
    (2, 2),
    (1, -3),
];

/// Depth-2 template: the 5 movements with step <= 1 (Fig. 19).
pub const TEMPLATE_D2: [(usize, isize); 5] = [(0, 0), (1, 0), (1, -1), (1, 1), (1, -3)];

impl Connectivity {
    pub fn new(depth: usize) -> Self {
        assert!(
            depth == 2 || depth == 3,
            "staging depth must be 2 or 3 (got {depth})"
        );
        let template: &[(usize, isize)] = if depth == 3 { &TEMPLATE_D3 } else { &TEMPLATE_D2 };
        let mut lanes = [LaneOptions { bits: [0; 8], len: 0 }; LANES];
        let mut reach = [0u64; LANES];
        for i in 0..LANES {
            let mut bits = [UNUSED_OPT; 8];
            for (k, &(step, off)) in template.iter().enumerate() {
                let lane = (i as isize + off).rem_euclid(LANES as isize) as usize;
                bits[k] = slot_bit(step, lane);
                reach[i] |= 1u64 << bits[k];
            }
            lanes[i] = LaneOptions { bits, len: template.len() };
        }
        Connectivity { depth, lanes, reach }
    }

    /// Mask of all window bits valid for this depth.
    #[inline(always)]
    pub fn window_mask(&self) -> u64 {
        (1u64 << (self.depth * LANES)) - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn depth3_has_eight_options_depth2_five() {
        let c3 = Connectivity::new(3);
        let c2 = Connectivity::new(2);
        assert!(c3.lanes.iter().all(|l| l.len == 8));
        assert!(c2.lanes.iter().all(|l| l.len == 5));
    }

    #[test]
    fn fig9_lane8_options() {
        // The paper's worked example: lane #8 can take (+0,8), lookahead
        // (+1,8)/(+2,8), or steal (+1,7), (+1,9), (+2,6), (+2,10), (+1,5).
        let c = Connectivity::new(3);
        let want: Vec<u8> = [
            (0usize, 8usize),
            (1, 8),
            (2, 8),
            (1, 7),
            (1, 9),
            (2, 6),
            (2, 10),
            (1, 5),
        ]
        .iter()
        .map(|&(s, l)| slot_bit(s, l))
        .collect();
        assert_eq!(&c.lanes[8].bits[..8], &want[..]);
    }

    #[test]
    fn ring_wraparound() {
        let c = Connectivity::new(3);
        // lane 0: (+1, -1) wraps to lane 15, (+2,-2) to 14, (+1,-3) to 13.
        assert_eq!(c.lanes[0].bits[3], slot_bit(1, 15));
        assert_eq!(c.lanes[0].bits[5], slot_bit(2, 14));
        assert_eq!(c.lanes[0].bits[7], slot_bit(1, 13));
        // lane 15: (+1, +1) wraps to lane 0, (+2,+2) to 1.
        assert_eq!(c.lanes[15].bits[4], slot_bit(1, 0));
        assert_eq!(c.lanes[15].bits[6], slot_bit(2, 1));
    }

    #[test]
    fn dense_option_is_exclusive_to_its_lane() {
        // Step-0 slots appear only in their own lane's option list, so the
        // head row can always fully drain in one cycle (no starvation).
        let c = Connectivity::new(3);
        for i in 0..LANES {
            for j in 0..LANES {
                if i == j {
                    continue;
                }
                assert_eq!(c.reach[j] & (1u64 << slot_bit(0, i)), 0);
            }
        }
    }

    #[test]
    fn level_groups_cannot_overlap() {
        // Lanes 5 apart (the Fig. 10 level grouping) must have disjoint
        // reachable sets — this is what makes per-level decisions safe.
        let c = Connectivity::new(3);
        for base in 0..LANES {
            for other in [base + 5, base + 10] {
                if other >= LANES {
                    continue;
                }
                assert_eq!(
                    c.reach[base] & c.reach[other],
                    0,
                    "lanes {base} and {other} overlap"
                );
            }
        }
    }
}
