//! On-chip memory hierarchy model (paper Table 2).
//!
//! Three shared SRAM chunks per tile — AM (A operands), BM (B operands)
//! and CM (outputs), each 256KB x 4 banks — plus three 1KB x 3-bank
//! scratchpads per PE. The model counts 16-value-row accesses; the
//! dataflow gives each operand row spatial reuse across the tile
//! dimension that shares it (B along columns, A along rows), which is
//! how the paper's PE grid amortises SRAM energy.
//!
//! Access *counts* are identical for baseline and TensorDash (TensorDash
//! reads the same rows, just faster) — the energy advantage comes from
//! finishing in fewer cycles. When tensors are kept in *scheduled* form
//! (§3.6) reads shrink by the compression factor; that variant is
//! modelled by [`scheduled_row_reads`].

/// Access counts for one layer-operation, in 16-value rows.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SramCounts {
    /// B-operand rows read from BM (after spatial reuse).
    pub bm_reads: u64,
    /// A-operand rows read from AM (after spatial reuse).
    pub am_reads: u64,
    /// Output rows written to (and later read from) CM.
    pub cm_writes: u64,
    pub cm_reads: u64,
    /// Scratchpad row reads (one per operand row entering a staging
    /// buffer; banked 3-wide so refills keep up with `AS`).
    pub spad_reads: u64,
    /// Scratchpad row writes (filling from AM/BM).
    pub spad_writes: u64,
}

impl SramCounts {
    pub fn merge(&mut self, o: &SramCounts) {
        self.bm_reads += o.bm_reads;
        self.am_reads += o.am_reads;
        self.cm_writes += o.cm_writes;
        self.cm_reads += o.cm_reads;
        self.spad_reads += o.spad_reads;
        self.spad_writes += o.spad_writes;
    }

    /// Scale all counts (e.g. to the paper's real batch size).
    pub fn scaled(&self, m: u64) -> SramCounts {
        SramCounts {
            bm_reads: self.bm_reads * m,
            am_reads: self.am_reads * m,
            cm_writes: self.cm_writes * m,
            cm_reads: self.cm_reads * m,
            spad_reads: self.spad_reads * m,
            spad_writes: self.spad_writes * m,
        }
    }

    /// Total AM+BM+CM row accesses.
    pub fn sram_rows(&self) -> u64 {
        self.bm_reads + self.am_reads + self.cm_writes + self.cm_reads
    }

    /// Total scratchpad row accesses.
    pub fn spad_rows(&self) -> u64 {
        self.spad_reads + self.spad_writes
    }
}

/// Analytic access counts for a MAC workload of `reduce_rows` 16-value
/// reduction rows per output group, `b_groups` B-side groups (windows or
/// gradient streams), `a_groups` A-side groups (filters etc.), mapped on
/// a `tile_rows x tile_cols` grid.
///
/// Dataflow: per pass, each of the `tile_rows` B streams is read once
/// (shared by all columns) and each of the `tile_cols` A streams is read
/// once (shared by all rows); outputs are accumulated in-PE and written
/// once per (B group, A group) pair.
pub fn dense_counts(
    reduce_rows: u64,
    b_groups: u64,
    a_groups: u64,
    tile_rows: u64,
    tile_cols: u64,
) -> SramCounts {
    let b_passes = b_groups.div_ceil(tile_rows);
    let a_passes = a_groups.div_ceil(tile_cols);
    // B re-streamed per A pass-group and vice versa (output stationary).
    let bm_reads = b_passes * tile_rows * reduce_rows * a_passes;
    let am_reads = a_passes * tile_cols * reduce_rows * b_passes;
    let outputs = (b_groups * a_groups).div_ceil(16);
    SramCounts {
        bm_reads,
        am_reads,
        cm_writes: outputs,
        cm_reads: 0,
        spad_reads: bm_reads + am_reads,
        spad_writes: bm_reads + am_reads,
    }
}

/// Row reads when a tensor is stored *scheduled* (§3.6): only non-zero
/// values plus a 3-bit movement index per value (modelled as a 16-bit
/// metadata word per row, i.e. a 1/16 row-equivalent overhead).
pub fn scheduled_row_reads(dense_rows: u64, nonzero_fraction: f64) -> u64 {
    let data = (dense_rows as f64 * nonzero_fraction).ceil() as u64;
    let metadata = dense_rows.div_ceil(16);
    data + metadata
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_counts_reuse() {
        // 4x4 tile, 8 B groups, 8 A groups, 10 reduction rows:
        // 2 B passes x 2 A passes; BM rows = 2*4*10*2 = 160 = AM rows.
        let c = dense_counts(10, 8, 8, 4, 4);
        assert_eq!(c.bm_reads, 160);
        assert_eq!(c.am_reads, 160);
        assert_eq!(c.cm_writes, 4);
        assert_eq!(c.sram_rows(), 324);
        assert_eq!(c.spad_rows(), 2 * (160 + 160));
    }

    #[test]
    fn scheduled_reads_shrink_with_sparsity() {
        assert_eq!(scheduled_row_reads(160, 1.0), 170); // metadata overhead
        assert_eq!(scheduled_row_reads(160, 0.25), 50);
        assert!(scheduled_row_reads(160, 0.1) < 160);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = dense_counts(10, 8, 8, 4, 4);
        let b = a;
        a.merge(&b);
        assert_eq!(a.bm_reads, 320);
    }
}
