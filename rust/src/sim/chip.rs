//! Chip-level aggregation: many tiles over many work passes.
//!
//! A *pass* is the unit of tile work: `tile_rows` B-side streams (one per
//! PE row) processed to completion against `tile_cols` A-side operands.
//! Tiles work on independent passes, so chip cycles for a layer are the
//! weighted pass cycles divided by the tile count, plus any DRAM
//! bandwidth stall (both architectures share the memory system, §4).
//!
//! Sampling: the evaluation samples passes (like the paper samples one
//! batch per epoch); each sampled pass carries a `weight` = how many
//! real passes it represents. `repro::` validates sampling against
//! exhaustive simulation on small layers.

use super::connectivity::Connectivity;
use super::stream::{CacheStats, CachedScheduler};
use super::tile::tile_pass_stats_cached;
use crate::config::{ChipConfig, SparsitySide};

/// One sampled unit of tile work.
#[derive(Debug, Clone)]
pub struct Pass {
    /// B-side effectual-mask stream per PE row (`<= tile_rows` entries).
    /// For `SparsitySide::Both` experiments the masks must already be
    /// `AZ & BZ`.
    pub streams: Vec<Vec<u16>>,
    /// Number of real passes this sample stands for.
    pub weight: u64,
}

/// Aggregated cycle/work counts for one layer-operation.
#[derive(Debug, Clone, Copy, Default)]
pub struct LayerCycles {
    /// Baseline (dense-schedule) tile cycles, weighted.
    pub base: u64,
    /// TensorDash tile cycles, weighted.
    pub td: u64,
    /// Dense MAC slots (lanes x steps x rows x weight), per column slot.
    pub mac_slots: u64,
    /// Effectual MACs issued, per column slot.
    pub macs_effectual: u64,
    /// Row-cycles lost to inter-row synchronisation, weighted.
    pub stall_row_cycles: u64,
    /// Scheduler-cache telemetry (walks / hits / fast paths / skips).
    /// *Unweighted*: it counts actual simulation work performed, not
    /// modeled hardware events, so pass weights do not apply.
    pub sched: CacheStats,
}

impl LayerCycles {
    pub fn merge(&mut self, other: &LayerCycles) {
        self.base += other.base;
        self.td += other.td;
        self.mac_slots += other.mac_slots;
        self.macs_effectual += other.macs_effectual;
        self.stall_row_cycles += other.stall_row_cycles;
        self.sched.merge(&other.sched);
    }

    pub fn speedup(&self) -> f64 {
        if self.td == 0 {
            1.0
        } else {
            self.base as f64 / self.td as f64
        }
    }
}

/// Cycle-level simulator front door.
pub struct ChipSim {
    pub cfg: ChipConfig,
    conn: Connectivity,
}

impl ChipSim {
    pub fn new(cfg: ChipConfig) -> Self {
        let conn = Connectivity::new(cfg.staging_depth);
        assert_eq!(cfg.lanes, 16, "the scheduler is specialised for 16 lanes");
        assert!(
            matches!(cfg.side, SparsitySide::BSide | SparsitySide::Both),
            "unknown sparsity side"
        );
        ChipSim { cfg, conn }
    }

    pub fn connectivity(&self) -> &Connectivity {
        &self.conn
    }

    /// Simulate a set of sampled passes for one layer-operation.
    ///
    /// One scheduler cache serves the whole call: recurring window
    /// patterns stay warm across the passes of one (layer, op), while
    /// every `Engine::map` cell still builds its own `ChipSim` — so the
    /// telemetry, like the cycle counts, is byte-identical for any
    /// `--jobs N`.
    pub fn run_passes(&self, passes: &[Pass]) -> LayerCycles {
        let mut out = LayerCycles::default();
        let mut sched = CachedScheduler::new(self.conn.clone());
        for pass in passes {
            let max_len = pass.streams.iter().map(|s| s.len()).max().unwrap_or(0) as u64;
            if max_len == 0 {
                continue;
            }
            let stats = tile_pass_stats_cached(&mut sched, &pass.streams, self.cfg.lead_limit);
            out.base += max_len * pass.weight;
            out.td += stats.cycles * pass.weight;
            out.mac_slots += max_len * 16 * pass.streams.len() as u64 * pass.weight;
            out.macs_effectual += stats.macs * pass.weight;
            out.stall_row_cycles += stats.imbalance_stall_row_cycles * pass.weight;
        }
        out.sched = sched.stats;
        out
    }

    /// Cycles needed to stream `dram_bytes` of (compressed) off-chip
    /// traffic at the configured bandwidth — the memory-bound floor the
    /// optional gate and the per-unit bottleneck report compare against.
    pub fn dram_stream_cycles(&self, dram_bytes: u64) -> u64 {
        (dram_bytes as f64 / self.cfg.dram_bytes_per_cycle()).ceil() as u64
    }

    /// Convert weighted per-tile pass cycles to whole-chip cycles. When
    /// `cfg.dram_gate` is set, a layer additionally cannot finish faster
    /// than its (compressed) off-chip traffic can stream — an extension
    /// over the paper's compute-bound simulator.
    pub fn chip_cycles(&self, tile_cycles: u64, dram_bytes: u64) -> u64 {
        let compute = tile_cycles.div_ceil(self.cfg.tiles as u64);
        if self.cfg.dram_gate {
            compute.max(self.dram_stream_cycles(dram_bytes))
        } else {
            compute
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> ChipSim {
        ChipSim::new(ChipConfig::default())
    }

    #[test]
    fn weighted_aggregation() {
        // `&[Pass]` means a single pass needs no clone dance — just a
        // one-element slice borrow.
        let p = Pass { streams: vec![vec![0u16; 30]], weight: 5 };
        let lc = sim().run_passes(std::slice::from_ref(&p));
        assert_eq!(lc.base, 150);
        assert_eq!(lc.td, 50); // all-zero stream -> 3x
        assert!((lc.speedup() - 3.0).abs() < 1e-12);
        // Telemetry is unweighted simulation work: the all-zero windows
        // are all fast-path answers, no encoder walk.
        assert_eq!(lc.sched.walks, 0);
        assert_eq!(lc.sched.fast_paths, lc.td / 5);
    }

    #[test]
    fn never_slower_than_baseline() {
        let mut state = 5u64;
        let passes: Vec<Pass> = (0..10)
            .map(|_| Pass {
                streams: (0..4)
                    .map(|_| {
                        (0..20)
                            .map(|_| {
                                state = state.wrapping_mul(6364136223846793005).wrapping_add(11);
                                (state >> 30) as u16
                            })
                            .collect()
                    })
                    .collect(),
                weight: 1,
            })
            .collect();
        let lc = sim().run_passes(&passes);
        assert!(lc.td <= lc.base);
        assert!(lc.speedup() >= 1.0);
    }

    #[test]
    fn dram_gate() {
        // Default: compute bound (paper methodology).
        let s = sim();
        assert_eq!(s.chip_cycles(1600, 102_400), 100);
        // With the gate enabled: 102400 bytes at 102.4 B/cycle -> 1000.
        let mut cfg = ChipConfig::default();
        cfg.dram_gate = true;
        let s = ChipSim::new(cfg);
        assert_eq!(s.chip_cycles(1600, 0), 100);
        assert_eq!(s.chip_cycles(1600, 102_400), 1000);
    }
}
