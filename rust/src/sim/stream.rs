//! The unified streaming window core.
//!
//! Every per-cycle loop in the simulator — single-PE streams
//! ([`crate::sim::pe`]), tile rows ([`crate::sim::tile`]) and the
//! back-side compression engine ([`crate::tensor::scheduled`]) — runs
//! the same state machine: pack up to `depth` 16-lane effectual masks
//! into the scheduler's 48-bit window vector `Z`, schedule a cycle,
//! AND out the consumed pairs, shift by the advance, refill from the
//! stream. This module is the single implementation of that machine,
//! built on a bit-parallel packed mask representation:
//!
//! * [`PackedStream`] — the per-row 16-lane effectual masks packed four
//!   rows per `u64` word (row `i` at bits `16*(i % 4)` of word `i / 4`,
//!   plus one always-zero pad word). Window loads become one unaligned
//!   two-word funnel shift instead of a per-row loop, and zero-run
//!   detection becomes whole-word compares plus a `trailing_zeros`
//!   scan instead of per-element iteration — the access pattern the
//!   long dynamic zero runs of backprop sparsity reward most.
//! * [`StreamWindow`] — the cursor (load / consume / shift / refill)
//!   over a [`PackedStream`], plus arithmetic zero-run skipping: a run
//!   of `k` all-zero rows retires in `ceil(k / depth)` cycles computed
//!   in O(k / 4) word reads instead of iterated schedule/shift cycles.
//! * [`CachedScheduler`] — a memoizing wrapper around
//!   [`schedule_cycle`]: analytical fast paths for the empty window and
//!   the fully-dense head row (constant-time, no encoder walk), and a
//!   fixed-size direct-mapped memo table keyed on the widened
//!   [`memo_key`] — the 48-bit packed window in the low bits, the
//!   staging depth in the top byte — so a probe is a single `u64`
//!   compare and the recurring window patterns that dominate real
//!   traces (§4.4: dense rows, empty rows, clustered-nonzero channel
//!   patterns) schedule in one lookup. The schedule is a pure function
//!   of `(z, depth)`, so caching can never change simulated cycles or
//!   MACs — only how fast the simulator produces them. [`reference`]
//!   keeps the pre-refactor uncached loops as the differential baseline
//!   (`rust/tests/stream_differential.rs` pins byte-identity,
//!   `rust/benches/tile_hotpath.rs` pins the throughput win).
//! * [`drive`] — the run-to-completion loop, generic over a per-cycle
//!   sink ([`StreamEvent`]), shared by the PE simulator and the
//!   compression engine. The tile steps its rows cycle-by-cycle against
//!   the shared-operand lead bound and therefore uses [`StreamWindow`]
//!   directly.
//!
//! **Determinism.** Simulation results depend only on the window
//! contents, never on cache state. Telemetry (hit/miss/skip counters)
//! *does* depend on cache state, so callers that surface telemetry
//! construct one fresh [`CachedScheduler`] per independent unit of work
//! (e.g. one per [`crate::sim::ChipSim::run_passes`] call). `Engine::map`
//! cells each build their own simulator, so `--jobs N` output — counters
//! included — is byte-identical to `--jobs 1`.

use super::connectivity::{Connectivity, LANES};
use super::scheduler::{schedule_cycle, Schedule, IDLE};

/// Mask of the window's head row (step 0).
const HEAD_ROW: u64 = 0xFFFF;

/// Effectual-mask rows per packed `u64` word.
pub const ROWS_PER_WORD: usize = 64 / LANES;

/// log2 of the memo-table size. 4096 direct-mapped entries (~160 KiB)
/// comfortably hold the working set of recurring window patterns a
/// trace-like stream produces while staying L2-resident.
pub const MEMO_BITS: u32 = 12;

/// Number of direct-mapped memo entries.
pub const MEMO_SIZE: usize = 1 << MEMO_BITS;

/// The widened memo key: the packed multi-row window vector (≤ 48 bits
/// for the 3-deep staging buffer) in the low bits and the staging depth
/// in the top byte. One `u64` equality check replaces the old
/// `(z, depth)` two-field probe, and `key == 0` doubles as the
/// empty-slot sentinel: a real key always carries depth bits, and the
/// all-zero window is answered by a fast path before it can reach the
/// table.
#[inline(always)]
pub fn memo_key(z: u64, depth: usize) -> u64 {
    debug_assert_eq!(z >> 48, 0, "window vector exceeds 48 bits");
    z | ((depth as u64) << 56)
}

/// The direct-mapped slot a widened [`memo_key`] hashes to. Fibonacci
/// hashing spreads the low-entropy sparse windows across the table;
/// public so the differential tests can construct adversarial collision
/// pairs.
#[inline(always)]
pub fn memo_index(key: u64) -> usize {
    (key.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> (64 - MEMO_BITS)) as usize
}

/// The first pair of distinct single-head-row window vectors whose
/// widened keys collide in the memo table at the given depth —
/// adversarial-test support for the direct-mapped eviction path.
/// Scanning vectors `1..` in order, the pigeonhole principle bounds
/// both members of the pair by `MEMO_SIZE + 1`, so they are always
/// valid non-empty, non-dense `u16` head masks.
pub fn memo_collision_pair(depth: usize) -> (u64, u64) {
    let mut first: Vec<Option<u64>> = vec![None; MEMO_SIZE];
    for m in 1u64..=(MEMO_SIZE as u64 + 1) {
        let idx = memo_index(memo_key(m, depth));
        match first[idx] {
            None => first[idx] = Some(m),
            Some(other) => return (other, m),
        }
    }
    unreachable!("MEMO_SIZE + 1 distinct keys cannot all map to distinct slots")
}

/// Telemetry counters of a [`CachedScheduler`] (monotone).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Full encoder walks performed ([`schedule_cycle`] calls — the
    /// expensive path, i.e. memo misses).
    pub walks: u64,
    /// Direct-mapped memo-table hits.
    pub hits: u64,
    /// Analytical fast-path answers: empty window or fully-dense head
    /// row (no table access, no walk).
    pub fast_paths: u64,
    /// Cycles retired arithmetically by zero-run skipping
    /// ([`StreamWindow::skip_zero_run`]) — these cycles never reach the
    /// scheduler at all.
    pub skipped_cycles: u64,
}

impl CacheStats {
    pub fn merge(&mut self, other: &CacheStats) {
        self.walks += other.walks;
        self.hits += other.hits;
        self.fast_paths += other.fast_paths;
        self.skipped_cycles += other.skipped_cycles;
    }

    /// Counter deltas accumulated since an earlier snapshot.
    pub fn since(&self, before: &CacheStats) -> CacheStats {
        CacheStats {
            walks: self.walks - before.walks,
            hits: self.hits - before.hits,
            fast_paths: self.fast_paths - before.fast_paths,
            skipped_cycles: self.skipped_cycles - before.skipped_cycles,
        }
    }

    /// Fraction of scheduler answers that avoided an encoder walk.
    pub fn hit_rate(&self) -> f64 {
        let answered = self.walks + self.hits + self.fast_paths;
        if answered == 0 {
            0.0
        } else {
            (self.hits + self.fast_paths) as f64 / answered as f64
        }
    }
}

/// One memo slot. `key == 0` marks an empty slot (see [`memo_key`]).
#[derive(Debug, Clone, Copy)]
struct MemoEntry {
    key: u64,
    sched: Schedule,
}

/// A memoizing wrapper around the combinational scheduler. See the
/// module docs for the fast paths, the key layout and the determinism
/// argument.
#[derive(Debug, Clone)]
pub struct CachedScheduler {
    conn: Connectivity,
    table: Vec<MemoEntry>,
    pub stats: CacheStats,
}

impl CachedScheduler {
    pub fn new(conn: Connectivity) -> CachedScheduler {
        let empty =
            MemoEntry { key: 0, sched: Schedule { ms: [IDLE; LANES], picks: 0, advance: 0 } };
        CachedScheduler { conn, table: vec![empty; MEMO_SIZE], stats: CacheStats::default() }
    }

    #[inline]
    pub fn depth(&self) -> usize {
        self.conn.depth
    }

    pub fn connectivity(&self) -> &Connectivity {
        &self.conn
    }

    /// Schedule one window — bit-identical to
    /// `schedule_cycle(conn, z)`, answered without an encoder walk
    /// whenever a fast path or the memo table applies.
    pub fn schedule(&mut self, z: u64) -> Schedule {
        debug_assert_eq!(z & !self.conn.window_mask(), 0, "z has bits outside window");
        let depth = self.conn.depth as u8;
        // Fast path 1: all-ineffectual window — nothing to schedule,
        // the whole window drains (AS = depth).
        if z == 0 {
            self.stats.fast_paths += 1;
            return Schedule { ms: [IDLE; LANES], picks: 0, advance: depth };
        }
        // Fast path 2: fully-dense head row. Step-0 slots are exclusive
        // to their own lane and option 0 is every lane's top priority,
        // so each lane takes its dense value: MS = 0 everywhere, picks =
        // exactly the head row, and the advance falls out of the same
        // leading-drained-rows arithmetic the walk uses.
        if z & HEAD_ROW == HEAD_ROW {
            self.stats.fast_paths += 1;
            let after = z & !HEAD_ROW;
            let advance = ((after.trailing_zeros() as u8) / LANES as u8).min(depth);
            return Schedule { ms: [0; LANES], picks: HEAD_ROW, advance };
        }
        // Direct-mapped memo probe on the widened single-u64 key.
        let key = memo_key(z, self.conn.depth);
        let idx = memo_index(key);
        let e = &self.table[idx];
        if e.key == key {
            self.stats.hits += 1;
            return e.sched;
        }
        let sched = schedule_cycle(&self.conn, z);
        self.stats.walks += 1;
        self.table[idx] = MemoEntry { key, sched };
        sched
    }
}

/// A stream of 16-lane effectual masks packed four rows per `u64` word:
/// row `i` occupies bits `16 * (i % 4) ..` of word `i / 4`. Rows past
/// the stream length read as zero (the packing never writes them), and
/// one always-zero pad word terminates the vector so an unaligned
/// two-word window load never reads out of bounds.
#[derive(Debug, Clone)]
pub struct PackedStream {
    words: Vec<u64>,
    len: usize,
}

impl PackedStream {
    /// Pack a mask stream. O(n) single pass; the result is immutable.
    pub fn pack(rows: &[u16]) -> PackedStream {
        let n = rows.len();
        let mut words = vec![0u64; n.div_ceil(ROWS_PER_WORD) + 1];
        for (i, &m) in rows.iter().enumerate() {
            words[i / ROWS_PER_WORD] |= (m as u64) << ((i % ROWS_PER_WORD) * LANES);
        }
        PackedStream { words, len: n }
    }

    /// Rows in the stream.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The effectual mask of row `i` (`i < len`).
    #[inline]
    pub fn row(&self, i: usize) -> u16 {
        debug_assert!(i < self.len);
        (self.words[i / ROWS_PER_WORD] >> ((i % ROWS_PER_WORD) * LANES)) as u16
    }

    /// Unaligned load of four consecutive rows starting at `start`
    /// (`start < len`): row `start + s` lands at bits `16s`. Rows past
    /// the stream end read as zero. One or two word reads plus a funnel
    /// shift — never a per-row loop.
    #[inline]
    pub fn load4(&self, start: usize) -> u64 {
        debug_assert!(start < self.len);
        let w = start / ROWS_PER_WORD;
        let sh = (start % ROWS_PER_WORD) * LANES;
        if sh == 0 {
            self.words[w]
        } else {
            // The pad word makes `w + 1` always in bounds.
            (self.words[w] >> sh) | (self.words[w + 1] << (64 - sh))
        }
    }

    /// Index of the first row at or after `start` with any effectual
    /// lane, or `len` when the rest of the stream is all-zero. Scans
    /// whole words (four rows per compare) and finishes with one
    /// `trailing_zeros`; zero-padding past `len` guarantees any set bit
    /// names a real row.
    #[inline]
    pub fn next_effectual(&self, start: usize) -> usize {
        if start >= self.len {
            return self.len;
        }
        let mut w = start / ROWS_PER_WORD;
        let sh = (start % ROWS_PER_WORD) * LANES;
        // Rows `start..` of the first word, earlier rows shifted out.
        let head = self.words[w] >> sh;
        if head != 0 {
            let hit = start + head.trailing_zeros() as usize / LANES;
            debug_assert!(hit < self.len);
            return hit;
        }
        let data_words = self.len.div_ceil(ROWS_PER_WORD);
        w += 1;
        while w < data_words && self.words[w] == 0 {
            w += 1;
        }
        if w >= data_words {
            return self.len;
        }
        let hit = w * ROWS_PER_WORD + self.words[w].trailing_zeros() as usize / LANES;
        debug_assert!(hit < self.len);
        hit
    }
}

/// The shared window cursor: the packed `Z` vector over a
/// [`PackedStream`] of 16-lane effectual masks, with
/// load/consume/shift/refill and arithmetic zero-run skipping.
pub struct StreamWindow {
    packed: PackedStream,
    /// Remaining-effectual window, row `s` of the window at bits
    /// `16s..16s+16`.
    z: u64,
    /// Stream index of the row at window step 0.
    pos: usize,
    /// Rows currently loaded (`<= depth`; less only near stream end).
    loaded: usize,
    depth: usize,
}

impl StreamWindow {
    pub fn new(stream: &[u16], depth: usize) -> StreamWindow {
        debug_assert!(depth >= 1 && depth * LANES <= 48, "depth outside staging range");
        let mut w = StreamWindow { packed: PackedStream::pack(stream), z: 0, pos: 0, loaded: 0, depth };
        w.refill();
        w
    }

    /// Load the unfilled window tail in one unaligned packed load
    /// instead of a per-row loop. Rows already resident keep their
    /// consumed (ANDed-out) state: only fresh rows are ORed in above
    /// them.
    #[inline]
    fn refill(&mut self) {
        let start = self.pos + self.loaded;
        if self.loaded >= self.depth || start >= self.packed.len() {
            return;
        }
        let fresh = (self.depth - self.loaded).min(self.packed.len() - start);
        let mask = (1u64 << (fresh * LANES)) - 1;
        self.z |= (self.packed.load4(start) & mask) << (self.loaded * LANES);
        self.loaded += fresh;
    }

    /// The current window vector for the scheduler.
    #[inline]
    pub fn z(&self) -> u64 {
        self.z
    }

    /// Stream index of the row at window step 0.
    #[inline]
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Rows currently loaded in the window.
    #[inline]
    pub fn loaded(&self) -> usize {
        self.loaded
    }

    /// Whether the stream is fully consumed. (The refill invariant makes
    /// `loaded == 0` equivalent to `pos >= stream.len()`.)
    #[inline]
    pub fn done(&self) -> bool {
        self.loaded == 0
    }

    /// Consume one schedule: AND out the picks, shift by the advance
    /// (capped at what is actually loaded — missing rows look drained to
    /// the scheduler), refill. Returns the rows actually advanced.
    #[inline]
    pub fn apply(&mut self, sched: &Schedule) -> usize {
        let adv = (sched.advance as usize).min(self.loaded);
        debug_assert!(adv >= 1, "head row must drain every cycle");
        self.z = (self.z & !sched.picks) >> (adv * LANES);
        self.pos += adv;
        self.loaded -= adv;
        self.refill();
        adv
    }

    /// Arithmetic zero-run skipping. When the loaded window is entirely
    /// ineffectual (`z == 0`), extend the run over the un-loaded stream
    /// tail — a whole-word scan, four rows per compare — and retire it
    /// wholesale: a run of `k` all-zero rows costs `ceil(k / depth)`
    /// all-skip cycles when it reaches the stream end, and
    /// `floor(k / depth)` full-depth skip cycles when a non-zero row
    /// follows (the residual `k % depth` zero rows then drain for free
    /// with the next real schedule's advance, exactly as the iterated
    /// loop would). Returns the cycles retired (0 if the window holds
    /// any effectual pair or the stream is done); the cursor lands on
    /// the state the iterated loop would reach.
    pub fn skip_zero_run(&mut self) -> u64 {
        if self.z != 0 || self.loaded == 0 {
            return 0;
        }
        let n = self.packed.len();
        // All `loaded` window rows are zero; word-scan the tail for the
        // next effectual row.
        let end = self.packed.next_effectual(self.pos + self.loaded);
        let k = end - self.pos;
        if end == n {
            // The run reaches the stream end: ceil(k/depth) cycles, each
            // draining min(depth, remaining) rows.
            self.pos = n;
            self.loaded = 0;
            (k as u64).div_ceil(self.depth as u64)
        } else {
            // A non-zero row sits at `end`, so only windows fully inside
            // the run schedule as pure skips. (The window is full here:
            // `loaded < depth` implies the refill hit the stream end,
            // contradicting `end < n`.)
            debug_assert_eq!(self.loaded, self.depth);
            let cycles = (k / self.depth) as u64;
            self.pos += cycles as usize * self.depth;
            self.loaded = 0;
            self.refill();
            cycles
        }
    }
}

/// One retirement event of [`drive`].
pub enum StreamEvent {
    /// A scheduled cycle. `pos` is the stream index of window step 0 at
    /// schedule time; `advance` is the applied (capped) row advance.
    Cycle { pos: usize, sched: Schedule, advance: usize },
    /// `cycles` all-skip cycles retiring `rows` all-zero stream rows
    /// arithmetically. Every skip cycle advances `depth` rows except
    /// possibly the last (`rows - (cycles - 1) * depth`).
    ZeroRun { cycles: u64, rows: usize },
}

/// Run one stream to completion through the cached scheduler, invoking
/// `sink` for every retirement event in stream order. This is the
/// shared free-running loop of the PE simulator and the compression
/// engine; the tile steps [`StreamWindow`]s directly against its
/// inter-row lead bound.
pub fn drive(sched: &mut CachedScheduler, stream: &[u16], mut sink: impl FnMut(StreamEvent)) {
    let mut win = StreamWindow::new(stream, sched.depth());
    while !win.done() {
        let pos = win.pos();
        let skipped = win.skip_zero_run();
        if skipped > 0 {
            sched.stats.skipped_cycles += skipped;
            sink(StreamEvent::ZeroRun { cycles: skipped, rows: win.pos() - pos });
            continue;
        }
        let s = sched.schedule(win.z());
        let advance = win.apply(&s);
        sink(StreamEvent::Cycle { pos, sched: s, advance });
    }
}

pub mod reference {
    //! The pre-refactor, uncached per-cycle loops — kept verbatim as the
    //! differential baseline. `rust/tests/stream_differential.rs` pins
    //! the cached/skipping core byte-identical to these;
    //! `rust/benches/tile_hotpath.rs` measures the throughput win
    //! against them. Not used on any simulation path.

    use super::super::connectivity::{Connectivity, LANES};
    use super::super::pe::StreamStats;
    use super::super::scheduler::schedule_cycle;
    use super::super::tile::TileStats;

    /// Naive PE stream simulation: one [`schedule_cycle`] walk per
    /// simulated cycle, no memo, no zero-run skipping.
    pub fn simulate_stream_stats(conn: &Connectivity, rows: &[u16]) -> StreamStats {
        let depth = conn.depth;
        let n = rows.len();
        let mut stats = StreamStats::default();
        if n == 0 {
            return stats;
        }
        let mut z = 0u64;
        let mut pos = 0usize;
        let mut loaded = 0usize;
        while loaded < depth && pos + loaded < n {
            z |= (rows[pos + loaded] as u64) << (loaded * LANES);
            loaded += 1;
        }
        loop {
            let sched = schedule_cycle(conn, z);
            stats.cycles += 1;
            stats.schedules += 1;
            stats.macs += sched.picks.count_ones() as u64;
            let adv = (sched.advance as usize).min(loaded);
            debug_assert!(adv >= 1, "head row must drain every cycle");
            z = (z & !sched.picks) >> (adv * LANES);
            pos += adv;
            loaded -= adv;
            while loaded < depth && pos + loaded < n {
                z |= (rows[pos + loaded] as u64) << (loaded * LANES);
                loaded += 1;
            }
            if loaded == 0 {
                break;
            }
        }
        stats
    }

    /// Naive tile pass: the old per-row window state machine with one
    /// scheduler walk per active row per cycle.
    pub fn tile_pass_stats(
        conn: &Connectivity,
        streams: &[Vec<u16>],
        lead_limit: usize,
    ) -> TileStats {
        struct RowState<'a> {
            stream: &'a [u16],
            z: u64,
            pos: usize,
            loaded: usize,
        }
        impl<'a> RowState<'a> {
            fn refill(&mut self, depth: usize) {
                while self.loaded < depth && self.pos + self.loaded < self.stream.len() {
                    self.z |= (self.stream[self.pos + self.loaded] as u64) << (self.loaded * LANES);
                    self.loaded += 1;
                }
            }
            fn done(&self) -> bool {
                self.loaded == 0 && self.pos >= self.stream.len()
            }
        }
        let depth = conn.depth;
        let mut stats = TileStats::default();
        let mut rows: Vec<RowState> = streams
            .iter()
            .map(|s| {
                let mut r = RowState { stream: s.as_slice(), z: 0, pos: 0, loaded: 0 };
                r.refill(depth);
                r
            })
            .collect();
        if rows.iter().all(|r| r.done()) {
            return stats;
        }
        loop {
            let min_pos = rows.iter().filter(|r| !r.done()).map(|r| r.pos).min().unwrap();
            for row in rows.iter_mut() {
                if row.done() {
                    continue;
                }
                if row.pos > min_pos + lead_limit {
                    stats.imbalance_stall_row_cycles += 1;
                    continue;
                }
                let sched = schedule_cycle(conn, row.z);
                stats.schedules += 1;
                stats.macs += sched.picks.count_ones() as u64;
                let adv = (sched.advance as usize).min(row.loaded);
                debug_assert!(adv >= 1);
                row.z = (row.z & !sched.picks) >> (adv * LANES);
                row.pos += adv;
                row.loaded -= adv;
                row.refill(depth);
            }
            stats.cycles += 1;
            if rows.iter().all(|r| r.done()) {
                return stats;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn memo_index_in_range() {
        let mut rng = Rng::new(1);
        for _ in 0..10_000 {
            assert!(memo_index(rng.next_u64()) < MEMO_SIZE);
        }
        assert!(memo_index(0) < MEMO_SIZE);
        assert!(memo_index(u64::MAX) < MEMO_SIZE);
    }

    #[test]
    fn memo_key_carries_the_window_and_separates_depths() {
        for depth in [2usize, 3] {
            for z in [1u64, 0xFFFF, 0x8000_0000_0001, 0xFFFF_FFFF_FFFF] {
                let key = memo_key(z, depth);
                assert_ne!(key, 0, "real keys must never hit the empty sentinel");
                assert_eq!(key & 0xFFFF_FFFF_FFFF, z, "window bits must survive");
            }
        }
        assert_ne!(memo_key(5, 2), memo_key(5, 3), "depth must widen the key");
    }

    #[test]
    fn cached_matches_combinational_for_random_windows() {
        for depth in [2usize, 3] {
            let conn = Connectivity::new(depth);
            let mut cached = CachedScheduler::new(conn.clone());
            let mut rng = Rng::new(0xCAFE + depth as u64);
            for trial in 0..20_000u64 {
                // Mix fresh windows with deliberate repeats so the memo
                // hit path is exercised, plus forced edge windows.
                let z = match trial % 7 {
                    0 => 0,
                    1 => 0xFFFF, // dense head, rest empty
                    2 => conn.window_mask(), // fully dense
                    3 => 0xFFFF | (rng.next_u64() & conn.window_mask() & !0xFFFF),
                    _ => rng.next_u64() & conn.window_mask(),
                };
                assert_eq!(cached.schedule(z), schedule_cycle(&conn, z), "z={z:#x} depth={depth}");
            }
            assert!(cached.stats.hits > 0, "memo never hit");
            assert!(cached.stats.fast_paths > 0, "fast paths never taken");
        }
    }

    #[test]
    fn collision_eviction_stays_correct() {
        // Two distinct windows mapping to the same memo slot must each
        // still get their own schedule (direct-mapped eviction, never a
        // stale answer).
        let conn = Connectivity::new(3);
        let (za, zb) = memo_collision_pair(3);
        assert_ne!(za, zb);
        assert_eq!(memo_index(memo_key(za, 3)), memo_index(memo_key(zb, 3)));
        let mut cached = CachedScheduler::new(conn.clone());
        for _ in 0..4 {
            assert_eq!(cached.schedule(za), schedule_cycle(&conn, za));
            assert_eq!(cached.schedule(zb), schedule_cycle(&conn, zb));
        }
        // Direct-mapped: the alternation thrashes the slot — all walks.
        assert_eq!(cached.stats.walks, 8);
        assert_eq!(cached.stats.hits, 0);
    }

    #[test]
    fn packed_rows_round_trip_and_straddle_word_seams() {
        let mut rng = Rng::new(0xBEEF);
        for len in [0usize, 1, 3, 4, 5, 63, 64, 65, 130] {
            let rows: Vec<u16> = (0..len).map(|_| rng.next_u64() as u16).collect();
            let p = PackedStream::pack(&rows);
            assert_eq!(p.len(), len);
            for (i, &m) in rows.iter().enumerate() {
                assert_eq!(p.row(i), m, "row {i} of {len}");
            }
            // Unaligned 4-row loads across every word seam: row start+s
            // at bits 16s, rows past the end read as zero.
            for start in 0..len {
                let got = p.load4(start);
                for s in 0..ROWS_PER_WORD {
                    let want =
                        if start + s < len { rows[start + s] as u64 } else { 0 };
                    assert_eq!((got >> (s * LANES)) & 0xFFFF, want, "start {start} step {s}");
                }
            }
        }
    }

    #[test]
    fn next_effectual_matches_linear_scan() {
        let mut rng = Rng::new(0x5CA7);
        for trial in 0..120usize {
            let target = 70 + trial; // spans word counts 18..48
            let mut rows: Vec<u16> = Vec::new();
            while rows.len() < target {
                if rng.chance(0.5) {
                    for _ in 0..=rng.below(20) {
                        rows.push(0);
                    }
                } else {
                    rows.push(rng.mask16(0.4) | 1);
                }
            }
            let p = PackedStream::pack(&rows);
            for start in 0..=rows.len() {
                let want = (start..rows.len()).find(|&i| rows[i] != 0).unwrap_or(rows.len());
                assert_eq!(p.next_effectual(start), want, "trial {trial} start {start}");
            }
        }
    }

    #[test]
    fn zero_run_skip_matches_iterated_loop() {
        for depth in [2usize, 3] {
            let mut rng = Rng::new(0x5EED + depth as u64);
            for trial in 0..400 {
                // Streams with engineered zero runs in random positions.
                let mut rows: Vec<u16> = Vec::new();
                let segs = 1 + trial % 4;
                for _ in 0..=segs {
                    for _ in 0..rng.below(6) {
                        rows.push(rng.mask16(0.5));
                    }
                    for _ in 0..rng.below(12) {
                        rows.push(0);
                    }
                }
                let mut skip_cycles = 0u64;
                let mut win = StreamWindow::new(&rows, depth);
                // Iterated reference cursor (no skipping).
                let mut rz = 0u64;
                let mut rpos = 0usize;
                let mut rloaded = 0usize;
                let conn = Connectivity::new(depth);
                let refill = |z: &mut u64, pos: usize, loaded: &mut usize| {
                    while *loaded < depth && pos + *loaded < rows.len() {
                        *z |= (rows[pos + *loaded] as u64) << (*loaded * LANES);
                        *loaded += 1;
                    }
                };
                refill(&mut rz, rpos, &mut rloaded);
                while rloaded > 0 {
                    if rz == 0 {
                        // Step the reference one all-skip cycle; step the
                        // skipping cursor only when it has fallen behind.
                        if skip_cycles == 0 {
                            skip_cycles = win.skip_zero_run();
                            assert!(skip_cycles > 0, "empty window must skip");
                        }
                        skip_cycles -= 1;
                        let adv = rloaded.min(depth);
                        rz >>= adv * LANES;
                        rpos += adv;
                        rloaded -= adv;
                        refill(&mut rz, rpos, &mut rloaded);
                        if skip_cycles == 0 {
                            // The skip batch is spent: both cursors must
                            // coincide exactly.
                            assert_eq!(win.pos(), rpos, "depth {depth}");
                            assert_eq!(win.z(), rz);
                            assert_eq!(win.loaded(), rloaded);
                        }
                    } else {
                        assert_eq!(skip_cycles, 0, "skip overran into a scheduled cycle");
                        assert_eq!(win.z(), rz);
                        assert_eq!(win.pos(), rpos);
                        let s = schedule_cycle(&conn, rz);
                        let adv = (s.advance as usize).min(rloaded);
                        rz = (rz & !s.picks) >> (adv * LANES);
                        rpos += adv;
                        rloaded -= adv;
                        refill(&mut rz, rpos, &mut rloaded);
                        win.apply(&s);
                    }
                }
                assert_eq!(skip_cycles, 0);
                assert!(win.done());
            }
        }
    }

    #[test]
    fn all_zero_stream_retires_in_ceil_k_over_depth() {
        for depth in [2usize, 3] {
            for k in [1usize, 2, 3, 4, 5, 6, 7, 29, 96, 97] {
                let rows = vec![0u16; k];
                let mut win = StreamWindow::new(&rows, depth);
                let cycles = win.skip_zero_run();
                assert_eq!(cycles, (k as u64).div_ceil(depth as u64), "k={k} depth={depth}");
                assert!(win.done());
                assert_eq!(win.skip_zero_run(), 0, "done window must not skip again");
            }
        }
    }
}
