//! Single processing-element stream simulation (paper §3.1, Fig. 7/8).
//!
//! A PE consumes an operand stream of `R` rows x 16 lanes through its
//! staging buffer. The *effectual mask* of a row is a `u16` with bit `l`
//! set iff the lane-`l` pair must actually be multiplied (for two-side
//! extraction the caller ANDs the A and B masks; for one-side, the B mask
//! alone). The baseline PE takes exactly `R` cycles; TensorDash takes
//! between `ceil(R / depth)` and `R`.

use super::connectivity::{Connectivity, LANES};
use super::scheduler::schedule_cycle;

/// Cycle count of the baseline dense PE for a stream of `rows` rows.
#[inline]
pub fn baseline_cycles(rows: usize) -> u64 {
    rows as u64
}

/// Counters accumulated while simulating a stream.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StreamStats {
    pub cycles: u64,
    /// Effectual MACs issued (equals the popcount of all input masks).
    pub macs: u64,
    /// Scheduler invocations (one per cycle — it is combinational).
    pub schedules: u64,
}

/// Simulate one PE over a stream of effectual masks, returning cycles.
pub fn simulate_stream(conn: &Connectivity, rows: &[u16]) -> u64 {
    simulate_stream_stats(conn, rows).cycles
}

/// Full-stats variant of [`simulate_stream`].
pub fn simulate_stream_stats(conn: &Connectivity, rows: &[u16]) -> StreamStats {
    let depth = conn.depth;
    let n = rows.len();
    let mut stats = StreamStats::default();
    if n == 0 {
        return stats;
    }
    // Window state: remaining-effectual masks of rows `pos .. pos+loaded`,
    // packed directly as the scheduler's Z vector (row s at bits 16s..).
    let mut z = 0u64;
    let mut pos = 0usize; // index of the row at window step 0
    let mut loaded = 0usize;
    while loaded < depth && pos + loaded < n {
        z |= (rows[pos + loaded] as u64) << (loaded * LANES);
        loaded += 1;
    }
    loop {
        let sched = schedule_cycle(conn, z);
        stats.cycles += 1;
        stats.schedules += 1;
        stats.macs += sched.picks.count_ones() as u64;
        // Consume, then advance: the scheduler reports drained rows over
        // the full depth (missing rows look drained); cap at what is
        // actually loaded. The shift drops the drained rows in one op.
        let adv = (sched.advance as usize).min(loaded);
        debug_assert!(adv >= 1, "head row must drain every cycle");
        z = (z & !sched.picks) >> (adv * LANES);
        pos += adv;
        loaded -= adv;
        while loaded < depth && pos + loaded < n {
            z |= (rows[pos + loaded] as u64) << (loaded * LANES);
            loaded += 1;
        }
        if loaded == 0 {
            break;
        }
    }
    stats
}

/// Effectual-MAC popcount of a stream (for work-conservation checks).
pub fn effectual_macs(rows: &[u16]) -> u64 {
    rows.iter().map(|r| r.count_ones() as u64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c3() -> Connectivity {
        Connectivity::new(3)
    }

    #[test]
    fn dense_stream_matches_baseline() {
        let rows = vec![0xFFFFu16; 100];
        assert_eq!(simulate_stream(&c3(), &rows), 100);
    }

    #[test]
    fn all_zero_stream_hits_3x_cap() {
        let rows = vec![0u16; 99];
        assert_eq!(simulate_stream(&c3(), &rows), 33);
        let rows = vec![0u16; 100];
        assert_eq!(simulate_stream(&c3(), &rows), 34);
    }

    #[test]
    fn all_zero_stream_depth2_hits_2x_cap() {
        let rows = vec![0u16; 100];
        assert_eq!(simulate_stream(&Connectivity::new(2), &rows), 50);
    }

    #[test]
    fn empty_stream_is_free() {
        assert_eq!(simulate_stream(&c3(), &[]), 0);
    }

    #[test]
    fn fig7_example_compresses_4_rows_to_2_cycles() {
        // The paper's worked example (Fig. 7, scaled to 16 lanes): 16
        // value pairs in 4 rows with 7 effectual, ideally 2 cycles. Use a
        // pattern with the same character on our 16-lane PE: rows at 50%
        // density arranged so lookahead/lookaside can pack them.
        // Exact Fig. 7 (4-lane) is checked in tile tests via density;
        // here: two half-dense rows + two empty rows => 2 cycles.
        let rows = vec![0x00FFu16, 0xFF00u16, 0u16, 0u16];
        let cycles = simulate_stream(&c3(), &rows);
        assert_eq!(cycles, 2);
    }

    #[test]
    fn work_conservation_and_bounds_random() {
        // TensorDash never slows down (cycles <= baseline), never beats
        // the structural caps, and always issues every effectual MAC.
        let c = c3();
        let mut state = 0x12345678u64;
        for trial in 0..200 {
            let len = 1 + (trial % 37);
            let rows: Vec<u16> = (0..len)
                .map(|_| {
                    state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                    (state >> 33) as u16
                })
                .collect();
            let stats = simulate_stream_stats(&c, &rows);
            let base = baseline_cycles(rows.len());
            assert!(stats.cycles <= base);
            assert_eq!(stats.macs, effectual_macs(&rows), "lost/duplicated MACs");
            let min_by_width = (effectual_macs(&rows) + 15) / 16;
            let min_by_depth = (rows.len() as u64 + 2) / 3;
            assert!(stats.cycles >= min_by_width.max(min_by_depth).max(1).min(base));
        }
    }

    #[test]
    fn single_dense_lane_compressed_by_neighbors() {
        // One lane always effectual (lane 5). Its own lane drains (0,5),
        // while lane 6 steals (+1, i-1) and lane 7 steals (+2, i-2) — so
        // three rows retire per cycle and the stream compresses 3x.
        let rows = vec![1u16 << 5; 30];
        assert_eq!(simulate_stream(&c3(), &rows), 10);
    }

    #[test]
    fn struggler_lane_relieved_by_lookaside() {
        // Alternating-lane pattern: lane 5 then lane 6 effectual. The
        // neighbours CAN steal: (+1, i-1)/(+1, i+1) movements compress it.
        let mut rows = Vec::new();
        for k in 0..30 {
            rows.push(if k % 2 == 0 { 1u16 << 5 } else { 1u16 << 6 });
        }
        let cycles = simulate_stream(&c3(), &rows);
        assert!(cycles < 30, "lookaside should beat the dense schedule, got {cycles}");
    }
}
