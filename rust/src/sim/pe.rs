//! Single processing-element stream simulation (paper §3.1, Fig. 7/8).
//!
//! A PE consumes an operand stream of `R` rows x 16 lanes through its
//! staging buffer. The *effectual mask* of a row is a `u16` with bit `l`
//! set iff the lane-`l` pair must actually be multiplied (for two-side
//! extraction the caller ANDs the A and B masks; for one-side, the B mask
//! alone). The baseline PE takes exactly `R` cycles; TensorDash takes
//! between `ceil(R / depth)` and `R`.
//!
//! The window/refill state machine lives in [`crate::sim::stream`]
//! (shared with the tile and the compression engine); this module is a
//! thin per-cycle sink over [`drive`] that accumulates [`StreamStats`].

use super::connectivity::Connectivity;
use super::stream::{drive, CachedScheduler, StreamEvent};

/// Cycle count of the baseline dense PE for a stream of `rows` rows.
#[inline]
pub fn baseline_cycles(rows: usize) -> u64 {
    rows as u64
}

/// Counters accumulated while simulating a stream.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StreamStats {
    pub cycles: u64,
    /// Effectual MACs issued (equals the popcount of all input masks).
    pub macs: u64,
    /// Actual encoder walks performed — i.e. scheduler-cache misses.
    /// Historically one per cycle (the scheduler is combinational);
    /// since the memoizing [`CachedScheduler`] this is the cache
    /// telemetry: `cycles - skipped_cycles = schedules + cache_hits +
    /// fast_paths`.
    pub schedules: u64,
    /// Scheduler answers served from the direct-mapped memo table.
    pub cache_hits: u64,
    /// Scheduler answers served by the analytical fast paths (empty
    /// window / fully-dense head row).
    pub fast_paths: u64,
    /// Cycles retired arithmetically by zero-run skipping (included in
    /// `cycles`; these never invoke the scheduler at all).
    pub skipped_cycles: u64,
}

/// Simulate one PE over a stream of effectual masks, returning cycles.
pub fn simulate_stream(conn: &Connectivity, rows: &[u16]) -> u64 {
    simulate_stream_stats(conn, rows).cycles
}

/// Full-stats variant of [`simulate_stream`] (fresh scheduler cache —
/// use [`simulate_stream_cached`] to amortise one across streams).
pub fn simulate_stream_stats(conn: &Connectivity, rows: &[u16]) -> StreamStats {
    let mut sched = CachedScheduler::new(conn.clone());
    simulate_stream_cached(&mut sched, rows)
}

/// Simulate one PE stream through a caller-owned [`CachedScheduler`],
/// so a worker processing many streams keeps its warm memo table. The
/// returned telemetry covers this stream only (counter deltas).
pub fn simulate_stream_cached(sched: &mut CachedScheduler, rows: &[u16]) -> StreamStats {
    let before = sched.stats;
    let mut stats = StreamStats::default();
    drive(sched, rows, |ev| match ev {
        StreamEvent::Cycle { sched: s, .. } => {
            stats.cycles += 1;
            stats.macs += s.picks.count_ones() as u64;
        }
        StreamEvent::ZeroRun { cycles, .. } => stats.cycles += cycles,
    });
    let d = sched.stats.since(&before);
    stats.schedules = d.walks;
    stats.cache_hits = d.hits;
    stats.fast_paths = d.fast_paths;
    stats.skipped_cycles = d.skipped_cycles;
    stats
}

/// Effectual-MAC popcount of a stream (for work-conservation checks).
pub fn effectual_macs(rows: &[u16]) -> u64 {
    rows.iter().map(|r| r.count_ones() as u64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c3() -> Connectivity {
        Connectivity::new(3)
    }

    #[test]
    fn dense_stream_matches_baseline() {
        let rows = vec![0xFFFFu16; 100];
        assert_eq!(simulate_stream(&c3(), &rows), 100);
    }

    #[test]
    fn all_zero_stream_hits_3x_cap() {
        let rows = vec![0u16; 99];
        assert_eq!(simulate_stream(&c3(), &rows), 33);
        let rows = vec![0u16; 100];
        assert_eq!(simulate_stream(&c3(), &rows), 34);
    }

    #[test]
    fn all_zero_stream_depth2_hits_2x_cap() {
        let rows = vec![0u16; 100];
        assert_eq!(simulate_stream(&Connectivity::new(2), &rows), 50);
    }

    #[test]
    fn empty_stream_is_free() {
        assert_eq!(simulate_stream(&c3(), &[]), 0);
    }

    #[test]
    fn fig7_example_compresses_4_rows_to_2_cycles() {
        // The paper's worked example (Fig. 7, scaled to 16 lanes): 16
        // value pairs in 4 rows with 7 effectual, ideally 2 cycles. Use a
        // pattern with the same character on our 16-lane PE: rows at 50%
        // density arranged so lookahead/lookaside can pack them.
        // Exact Fig. 7 (4-lane) is checked in tile tests via density;
        // here: two half-dense rows + two empty rows => 2 cycles.
        let rows = vec![0x00FFu16, 0xFF00u16, 0u16, 0u16];
        let cycles = simulate_stream(&c3(), &rows);
        assert_eq!(cycles, 2);
    }

    #[test]
    fn work_conservation_and_bounds_random() {
        // TensorDash never slows down (cycles <= baseline), never beats
        // the structural caps, and always issues every effectual MAC.
        let c = c3();
        let mut state = 0x12345678u64;
        for trial in 0..200 {
            let len = 1 + (trial % 37);
            let rows: Vec<u16> = (0..len)
                .map(|_| {
                    state = state
                        .wrapping_mul(6364136223846793005)
                        .wrapping_add(1442695040888963407);
                    (state >> 33) as u16
                })
                .collect();
            let stats = simulate_stream_stats(&c, &rows);
            let base = baseline_cycles(rows.len());
            assert!(stats.cycles <= base);
            assert_eq!(stats.macs, effectual_macs(&rows), "lost/duplicated MACs");
            let min_by_width = (effectual_macs(&rows) + 15) / 16;
            let min_by_depth = (rows.len() as u64 + 2) / 3;
            assert!(stats.cycles >= min_by_width.max(min_by_depth).max(1).min(base));
        }
    }

    #[test]
    fn telemetry_accounts_for_every_cycle() {
        // Every cycle is either zero-run-skipped or answered by exactly
        // one of walk / memo hit / fast path.
        let c = c3();
        let mut state = 0xFEEDu64;
        for len in [1usize, 7, 64, 300] {
            let rows: Vec<u16> = (0..len)
                .map(|_| {
                    state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                    (state >> 40) as u16 & (state >> 20) as u16
                })
                .collect();
            let s = simulate_stream_stats(&c, &rows);
            assert_eq!(
                s.cycles - s.skipped_cycles,
                s.schedules + s.cache_hits + s.fast_paths,
                "len {len}"
            );
        }
    }

    #[test]
    fn zero_runs_are_skipped_not_iterated() {
        let mut rows = vec![0xFFFFu16; 4];
        rows.extend(vec![0u16; 30]);
        let s = simulate_stream_stats(&c3(), &rows);
        assert_eq!(s.cycles, 4 + 10);
        assert_eq!(s.skipped_cycles, 10, "the 30-zero tail must retire arithmetically");
        // The dense prefix is answered by the dense-head fast path.
        assert_eq!(s.fast_paths, 4);
        assert_eq!(s.schedules, 0, "no encoder walk needed anywhere");
    }

    #[test]
    fn single_dense_lane_compressed_by_neighbors() {
        // One lane always effectual (lane 5). Its own lane drains (0,5),
        // while lane 6 steals (+1, i-1) and lane 7 steals (+2, i-2) — so
        // three rows retire per cycle and the stream compresses 3x.
        let rows = vec![1u16 << 5; 30];
        assert_eq!(simulate_stream(&c3(), &rows), 10);
    }

    #[test]
    fn recurring_pattern_hits_the_memo_table() {
        // The single-dense-lane stream presents the identical window
        // every cycle: one walk, then memo hits.
        let rows = vec![1u16 << 5; 30];
        let s = simulate_stream_stats(&c3(), &rows);
        assert_eq!(s.cycles, 10);
        assert_eq!(s.schedules, 1, "first window walks");
        assert_eq!(s.cache_hits, 9, "recurrences hit");
    }

    #[test]
    fn struggler_lane_relieved_by_lookaside() {
        // Alternating-lane pattern: lane 5 then lane 6 effectual. The
        // neighbours CAN steal: (+1, i-1)/(+1, i+1) movements compress it.
        let mut rows = Vec::new();
        for k in 0..30 {
            rows.push(if k % 2 == 0 { 1u16 << 5 } else { 1u16 << 6 });
        }
        let cycles = simulate_stream(&c3(), &rows);
        assert!(cycles < 30, "lookaside should beat the dense schedule, got {cycles}");
    }
}
