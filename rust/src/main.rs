//! `tensordash` CLI — leader entrypoint.
//!
//! Subcommands:
//!   repro     regenerate the paper's tables/figures (--fig N | --table 3|bf16 | --all)
//!   simulate  run one model profile through the cycle simulator
//!   train     run REAL training steps through the AOT artifacts and
//!             project TensorDash speedup from the captured sparsity
//!   serve     persistent JSON-lines simulation service (stdin/stdout
//!             or --listen TCP) over a shared content-addressed unit
//!             cache with batched request coalescing
//!   store     persistent experiment store: ingest report/bench JSON
//!             into a single indexed record-log file, query metric
//!             trajectories across commits, diff two commits
//!   info      print configuration + area model summary
//!
//! Every result is built as a structured `api::Report` first; `--format`
//! picks the renderer (aligned text table, `tensordash.report.v1` JSON,
//! or CSV), `--out` redirects it to a file, and `--jobs` sizes the
//! engine's worker pool — sweep results are byte-identical for every
//! worker count thanks to per-cell seed derivation.
//!
//! Examples:
//!   tensordash repro --all --jobs 8
//!   tensordash repro --fig 13 --samples 6 --seed 42 --format json --out fig13.json
//!   tensordash simulate --model resnet50 --epoch 0.4
//!   tensordash train --steps 50 --log-every 10

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use tensordash::api::params;
use tensordash::api::{self, Cell, Engine, Report, ServeOptions, Service, SimRequest, UnitCache};
use tensordash::config::{ChipConfig, DataType};
use tensordash::coordinator::data::DataGen;
use tensordash::coordinator::Trainer;
use tensordash::repro;
use tensordash::runtime::Runtime;
use tensordash::search::{self, ExploreSpec, SearchSpace};
use tensordash::store::{registered_schemas, ExperimentStore, QueryFilter};
use tensordash::util::cli::Args;
use tensordash::util::json::Json;

const USAGE: &str = "usage: tensordash <repro|simulate|train|explore|serve|store|info> [options]
  repro    --all | --fig <1|13|14|15|16|17|18|19|20|gcn|ablations>
           | --table <3|bf16>  [--samples N] [--seed S]
  simulate --model <name> [--epoch F] [--samples N] [--seed S]
           [--regime uniform|nm:N:M|schedule:<curve>]
           [--rows R] [--cols C] [--depth 2|3] [--bf16] [--power-gate]
           [--per-layer]
           --epoch is an [0, 1] training fraction; --regime picks the
           sparsity regime (run `info` for the model zoo + regime
           spellings and bounds). A fixed seed is byte-deterministic
           under every regime at any --jobs/--shards
  train    [--steps N] [--log-every K] [--seed S] [--artifacts DIR]
           [--samples N] [--sim-every K] [--per-layer]
  explore  [--models m1,m2] [--budget N] [--population N] [--epoch F]
           [--samples N] [--seed S]
           [--regime uniform|nm:N:M|schedule:<curve>]
           [--space FILE | --axis name=v1,v2 [--axis ...]]
           [--cache-cap N] [--cache-dir DIR]
           cache-driven Pareto search over ChipConfig axes (run `info`
           for the axis list + bounds). Emits a tensordash.frontier.v1
           report; a fixed seed is byte-deterministic at any --jobs,
           and the run fails if its staging-depth slice violates the
           fig-19 depth ordering
  serve    [--listen ADDR] [--jobs N] [--workers N] [--queue-depth N]
           [--request-timeout MS] [--cache-cap N] [--cache-dir DIR]
           [--shards N] [--preload m1,m2,...]
           JSON-lines loop (tensordash.serve.v1): one request object per
           line on stdin (or per TCP connection with --listen), one
           response per line in request order. Ops: simulate, sweep,
           trace, explore, batch, stats, store_ingest, store_query,
           store_diff, shutdown. Identical units across a batch
           coalesce onto one computation. With --listen requests are
           multiplexed: per-connection readers feed one --queue-depth
           bounded request queue (default 64) drained by --workers
           compute threads (default 8), responses re-sequence into
           request order — or stream out of order, tagged with an
           \"op\" echo, when a request carries \"stream\":true. Past
           the queue depth a request is shed with an explicit
           \"overloaded\" error (the connection stays open);
           --request-timeout MS (default 0 = off; per-request
           \"timeout_ms\" overrides) answers \"timeout\" for requests
           that outwait their deadline in the queue, and work queued
           for a disconnected client is cancelled.
  store    ingest --db FILE --commit ID file.json [file2.json ...]
           | query --db FILE [--schema S] [--id R] [--commit C]
                   [--model M] [--metric COL]
           | diff --db FILE --id R --from C1 --to C2
           | compact --db FILE
           single-file indexed experiment history (crash-safe record
           log, no external DB). ingest stores report/layers/frontier/
           bench JSON keyed by (commit, config hash, seed, schema) and
           is idempotent; query prints the record catalog, or with
           --metric one metric's trajectory across commits; diff
           compares two commits' reports (per-metric deltas) or
           frontiers (added/kept/removed/newly-dominated points);
           compact rewrites the log keeping only live records. Run
           `info` for the registered schema list
  info     chip configuration + area model, the model zoo (paper nine
           + the bert transformer tier), sparsity-regime spellings and
           bounds, explore axes, store schemas, serve defaults

report options (repro, simulate, train, explore, store query/diff):
  --format table|json|csv   renderer (default table). json emits the
                            tensordash.report.v1 schema; several reports
                            nest in one tensordash.reportset.v1 document
  --out FILE                write the rendering to FILE instead of stdout
  --jobs N                  engine worker threads (default: all cores);
                            results are byte-identical for any N —
                            a single model simulation fans its
                            (layer, op) units out over the pool
  --per-layer               (simulate, train only) append the
                            tensordash.layers.v1 per-(layer, op)
                            breakdown (speedup/energy/bottleneck)
  --cache                   serve units from an in-memory
                            content-addressed cache: repeated and
                            overlapping sweep cells (multi-figure runs
                            share dense baselines) compute once.
                            Results are byte-identical; unit_cache_*
                            meta keys record the telemetry
  --cache-cap N             cache capacity in units (default 65536)
  --shards N                lock-striped cache shards (default 8); any
                            shard count yields byte-identical results
                            and telemetry — more shards only reduce
                            lock contention under concurrent load
  --cache-dir DIR           also mirror cached units to DIR (implies
                            --cache; persists across runs)";

fn main() {
    let args = Args::parse(&["all", "bf16", "power-gate", "help", "per-layer", "cache"]);
    if args.flag("help") || args.positional.is_empty() {
        println!("{USAGE}");
        return;
    }
    let cmd = args.positional[0].clone();
    let result = match cmd.as_str() {
        "repro" => cmd_repro(&args),
        "simulate" => cmd_simulate(&args),
        "train" => cmd_train(&args),
        "explore" => cmd_explore(&args),
        "serve" => cmd_serve(&args),
        "store" => cmd_store(&args),
        "info" => cmd_info(&args),
        other => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Lift a shared-parameter parse error into the CLI's error type.
fn param<T>(r: std::result::Result<T, String>) -> Result<T> {
    r.map_err(anyhow::Error::msg)
}

/// Chip geometry from the CLI flags, through the same validated path
/// the serve protocol uses ([`params::chip_config`]) — `--depth 9` now
/// fails up front with the same wording a serve request would get,
/// instead of asserting deep inside a worker.
fn chip_from_args(args: &Args) -> Result<ChipConfig> {
    param(params::chip_config(args))
}

/// Build a unit cache of `cap` entries over `shards` lock stripes,
/// disk-mirrored when `dir` is given. Shared by the `--cache*` flags
/// and the `serve` subcommand.
fn build_cache(cap: usize, shards: usize, dir: Option<&str>) -> Result<UnitCache> {
    let cache = UnitCache::with_shards(cap, shards);
    Ok(match dir {
        Some(d) => cache
            .with_disk(d)
            .map_err(|e| anyhow::anyhow!("opening cache dir {d}: {e}"))?,
        None => cache,
    })
}

/// Build the cache `--cache`/`--cache-cap`/`--cache-dir` ask for
/// (`--cache-dir` implies `--cache`); `None` when caching is off.
fn cache_from_args(args: &Args) -> Result<Option<Arc<UnitCache>>> {
    let dir = args.get("cache-dir");
    if !args.flag("cache") && dir.is_none() {
        return Ok(None);
    }
    let cap = args.get_usize("cache-cap", api::DEFAULT_CACHE_CAP)?;
    let shards = args.get_usize("shards", api::DEFAULT_CACHE_SHARDS)?;
    Ok(Some(Arc::new(build_cache(cap, shards, dir)?)))
}

fn engine_from_args(args: &Args) -> Result<(Engine, Option<Arc<UnitCache>>)> {
    let mut engine = Engine::new(args.get_usize("jobs", api::default_jobs())?);
    let cache = cache_from_args(args)?;
    if let Some(c) = &cache {
        engine = engine.with_cache(Arc::clone(c));
    }
    Ok((engine, cache))
}

/// Print the unit-cache session summary to stderr (stdout belongs to
/// the report).
fn report_cache_use(cache: &Option<Arc<UnitCache>>) {
    if let Some(c) = cache {
        let s = c.stats();
        eprintln!(
            "unit cache: {} hits / {} misses ({:.0}% hit rate), {} coalesced, \
             {} evictions, {} resident",
            s.hits,
            s.misses,
            s.hit_rate() * 100.0,
            s.coalesced,
            s.evictions,
            c.len()
        );
    }
}

/// Validate `--format` up front, before any simulation runs — a typo
/// should fail in milliseconds, not after a full sweep.
fn report_format<'a>(args: &'a Args) -> Result<&'a str> {
    let format = args.get_or("format", "table");
    match format {
        "table" | "json" | "csv" => Ok(format),
        other => anyhow::bail!("unknown --format '{other}' (table|json|csv)"),
    }
}

/// Render reports per `--format` and deliver them per `--out`.
fn emit(reports: &[Report], args: &Args) -> Result<()> {
    let rendered = match report_format(args)? {
        "table" => reports.iter().map(|r| r.render_text()).collect::<Vec<_>>().join(""),
        "json" => {
            let mut s = api::report_set_json(reports).render_pretty();
            s.push('\n');
            s
        }
        "csv" => reports.iter().map(|r| r.render_csv()).collect::<Vec<_>>().join("\n"),
        _ => unreachable!("report_format validated"),
    };
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, rendered.as_bytes())
                .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
            eprintln!("wrote {path} ({} bytes)", rendered.len());
        }
        None => print!("{rendered}"),
    }
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    let format = report_format(args)?;
    let samples = param(params::get_usize(args, "samples", repro::DEFAULT_SAMPLES))?;
    let seed = param(params::get_seed(args, params::DEFAULT_SEED))?;
    let all = args.flag("all");
    let fig = args.get("fig").map(|s| s.to_string());
    let table = args.get("table").map(|s| s.to_string());
    if !all && fig.is_none() && table.is_none() {
        anyhow::bail!("repro needs --all, --fig N or --table 3|bf16");
    }
    let (engine, cache) = engine_from_args(args)?;
    let cfg = ChipConfig::default();
    let want = |f: &str| all || fig.as_deref() == Some(f);
    let mut reports: Vec<Report> = Vec::new();
    // Progressive output: with the default table-to-stdout rendering,
    // each figure prints as soon as it completes (a full --all run
    // takes minutes); file/JSON/CSV deliveries stay whole-document.
    let progressive = format == "table" && args.get("out").is_none();
    let mut add = |mut r: Report| {
        // With the unit cache on, each figure records the cumulative
        // cache telemetry at the moment it was produced — the rows
        // themselves never depend on the cache (tested invariant).
        if let Some(c) = &cache {
            c.stats().annotate(&mut r);
        }
        if progressive {
            r.print();
        }
        reports.push(r);
    };

    if want("1") {
        add(repro::fig1());
    }
    // Figs 13/15/16 share one simulation sweep.
    if want("13") || want("15") || want("16") {
        let sims = repro::run_fig13_sims(&engine, &cfg, samples, seed);
        if want("13") {
            add(repro::fig13(&sims));
        }
        if want("15") {
            add(repro::fig15(&sims));
        }
        if want("16") {
            add(repro::fig16(&sims));
        }
    }
    if want("14") {
        add(repro::fig14(&engine, &cfg, samples, seed));
    }
    if want("17") {
        add(repro::fig17_rows(&engine, samples, seed));
    }
    if want("18") {
        add(repro::fig18_cols(&engine, samples, seed));
    }
    if want("19") {
        add(repro::fig19(&engine, samples, seed));
    }
    if want("20") {
        // Fig. 20's sampling knob is tensor draws per sparsity level; it
        // honors --samples like every other figure (default 10, the
        // paper's setting).
        let per_level = param(params::get_usize(args, "samples", 10))?;
        add(repro::fig20(&engine, per_level, seed));
    }
    if want("gcn") {
        add(repro::gcn_control(&engine, samples, seed));
    }
    if all || table.as_deref() == Some("3") {
        add(repro::table3(DataType::Fp32));
    }
    if all || table.as_deref() == Some("bf16") {
        add(repro::table3(DataType::Bf16));
    }
    if all || fig.as_deref() == Some("ablations") {
        add(repro::ablations::ablation_two_side(&engine, 3, seed));
        add(repro::ablations::ablation_lead(&engine, 3, seed));
        add(repro::ablations::ablation_dram_gate(&engine, 3, seed));
        add(repro::ablations::ablation_backside_scheduler());
    }
    if all {
        add(repro::sampling_report(seed));
    }
    report_cache_use(&cache);
    if progressive {
        return Ok(());
    }
    emit(&reports, args)
}

fn cmd_simulate(args: &Args) -> Result<()> {
    report_format(args)?;
    let model = args.get("model").unwrap_or("resnet50").to_string();
    let epoch = param(params::get_epoch(args, "epoch", repro::MID_EPOCH))?;
    let samples = param(params::get_usize(args, "samples", repro::DEFAULT_SAMPLES))?;
    let seed = param(params::get_seed(args, params::DEFAULT_SEED))?;
    let regime = param(params::get_regime(args))?;
    let cfg = chip_from_args(args)?;
    let (engine, cache) = engine_from_args(args)?;
    let req = SimRequest::profile(&model, epoch, cfg.clone(), samples, seed)
        .map_err(|e| anyhow::anyhow!(e))?
        .with_regime(regime);
    let sim = engine.run(&req);

    let mut r = repro::simulate_report(&model, epoch, &cfg, samples, seed, &sim);
    if let Some(c) = &cache {
        c.stats().annotate(&mut r);
    }
    report_cache_use(&cache);
    let mut reports = vec![r];
    if args.flag("per-layer") {
        reports.push(api::layers_report(&sim));
    }
    emit(&reports, args)
}

fn cmd_train(args: &Args) -> Result<()> {
    report_format(args)?;
    let steps = args.get_usize("steps", 50)?;
    let log_every = args.get_usize("log-every", 10)?.max(1);
    let sim_every = args.get_usize("sim-every", 10)?.max(1);
    let samples = param(params::get_usize(args, "samples", repro::DEFAULT_SAMPLES))?;
    let seed = param(params::get_seed(args, params::DEFAULT_SEED))?;
    let dir = args.get_or("artifacts", "artifacts");
    let cfg = chip_from_args(args)?;
    // Captured bitmaps change every step, but the cache still helps
    // when --sim-every re-projects overlapping steps or when a sweep
    // shares the projection config.
    let (engine, cache) = engine_from_args(args)?;

    let rt = Runtime::new(dir)?;
    // Progress goes to stderr: stdout belongs to the report, so
    // `train --format json | jq` stays parseable.
    eprintln!("PJRT platform: {}", rt.platform());
    let mut trainer = Trainer::new(&rt, seed as i32)?;
    let (n, h, w, c) = trainer.meta.input;
    let mut data = DataGen::new(h, w, c, trainer.meta.classes, seed);
    eprintln!(
        "model: {} conv layers, batch {}, input {}x{}x{}, {} classes",
        trainer.meta.convs.len(),
        n,
        h,
        w,
        c,
        trainer.meta.classes
    );
    let shapes = trainer.meta.convs.clone();
    // The captured-trace label is the real model name from
    // artifacts/meta.json (older artifacts fall back to "captured").
    let model_name = trainer.meta.name.clone();
    let mut report = Report::new(
        "train_projection",
        format!("TensorDash projection for '{model_name}' over {steps} real training steps"),
        &[
            "step",
            "loss",
            "accuracy",
            "A sparsity",
            "G sparsity",
            "speedup",
            "compute eff",
            "chip eff",
        ],
    );
    report.meta_str("model", &model_name);
    report.meta_num("seed", seed as f64);
    report.meta_num("samples", samples as f64);
    let mut last_sim = None;
    for step in 1..=steps {
        let (x, y) = data.batch(n);
        let out = trainer.step(&x, &y)?;
        let should_log = step % log_every == 0 || step == 1 || step == steps;
        let should_sim = step % sim_every == 0 || step == steps;
        if !(should_log || should_sim) {
            continue;
        }
        // Bitmap popcounts are not free; only pay them on steps that
        // log or simulate.
        let (sa, sg) = out.trace.mean_sparsity();
        if should_log {
            eprintln!(
                "step {:>4}  loss {:.4}  acc {:.3}  sparsity A {:.2} G {:.2}",
                step, out.loss, out.accuracy, sa, sg
            );
        }
        if should_sim {
            let req = SimRequest::trace(
                &model_name,
                shapes.clone(),
                out.trace.layers.clone(),
                cfg.clone(),
                samples,
                seed,
            );
            let sim = engine.run(&req);
            eprintln!(
                "        projected TensorDash speedup {:.2}x (compute eff {:.2}x, chip eff {:.2}x)",
                sim.overall_speedup(),
                sim.compute_efficiency(),
                sim.total_efficiency()
            );
            report.row(vec![
                Cell::fmt(step.to_string(), step as f64),
                Cell::fmt(format!("{:.4}", out.loss), out.loss as f64),
                Cell::fmt(format!("{:.3}", out.accuracy), out.accuracy as f64),
                Cell::num(sa),
                Cell::num(sg),
                Cell::num(sim.overall_speedup()),
                Cell::num(sim.compute_efficiency()),
                Cell::num(sim.total_efficiency()),
            ]);
            last_sim = Some(sim);
        }
    }
    if let Some(last) = report.rows.last() {
        eprintln!("\nfinal projection: {} speedup", last.cells[5].text);
    }
    if let Some(c) = &cache {
        c.stats().annotate(&mut report);
    }
    report_cache_use(&cache);
    let mut reports = vec![report];
    // Breakdown of the final projection step's captured tensors.
    if let (true, Some(sim)) = (args.flag("per-layer"), last_sim.as_ref()) {
        reports.push(api::layers_report(sim));
    }
    emit(&reports, args)
}

/// Build the search space the `explore` flags describe: an explicit
/// `--space FILE` (tensordash.space.v1), else the `--axis name=v1,v2`
/// pairs, else the default Figs. 17–19 axes.
fn space_from_args(args: &Args) -> Result<SearchSpace> {
    if let Some(path) = args.get("space") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))?;
        return SearchSpace::from_json(&j).map_err(|e| anyhow::anyhow!(e));
    }
    let axis_args = args.get_multi("axis");
    if axis_args.is_empty() {
        return Ok(SearchSpace::default_space());
    }
    let mut pairs = Vec::with_capacity(axis_args.len());
    for a in &axis_args {
        match a.split_once('=') {
            Some((k, v)) => pairs.push((k.to_string(), v.to_string())),
            None => anyhow::bail!("--axis expects name=v1,v2,..., got '{a}'"),
        }
    }
    SearchSpace::from_pairs(&pairs).map_err(|e| anyhow::anyhow!(e))
}

fn cmd_explore(args: &Args) -> Result<()> {
    report_format(args)?;
    let models = args.get_list("models").unwrap_or_else(|| vec!["alexnet".to_string()]);
    if models.is_empty() {
        anyhow::bail!("--models needs at least one model name");
    }
    let epoch = param(params::get_epoch(args, "epoch", repro::MID_EPOCH))?;
    let samples = param(params::get_usize(args, "samples", repro::DEFAULT_SAMPLES))?.max(1);
    let seed = param(params::get_seed(args, params::DEFAULT_SEED))?;
    let regime = param(params::get_regime(args))?;
    let budget = param(params::get_usize(args, "budget", params::DEFAULT_EXPLORE_BUDGET))?.max(1);
    let population =
        param(params::get_usize(args, "population", search::default_population(budget)))?;
    let space = space_from_args(args)?;
    // Exploration always runs cached — survivor re-evaluations and
    // revisited design points are the whole workload. --cache-cap and
    // --cache-dir size/persist it; --jobs sizes the worker pool.
    let cap = args.get_usize("cache-cap", api::DEFAULT_CACHE_CAP)?;
    let shards = args.get_usize("shards", api::DEFAULT_CACHE_SHARDS)?;
    let cache = Arc::new(build_cache(cap, shards, args.get("cache-dir"))?);
    let engine = Engine::new(args.get_usize("jobs", api::default_jobs())?)
        .with_cache(Arc::clone(&cache));
    let names: Vec<&str> = models.iter().map(String::as_str).collect();
    let spec = ExploreSpec::new(space, &names, epoch, samples, seed, budget)
        .map_err(|e| anyhow::anyhow!(e))?
        .with_population(population)
        .with_regime(regime);
    let (res, report) = search::run(&engine, &spec);
    eprintln!(
        "explore: {} evaluations over {} generations, frontier size {} \
         (space {} points, depth pairs {})",
        res.evaluated.len(),
        res.generations,
        res.frontier.len(),
        spec.space.size(),
        res.depth_pairs
    );
    report_cache_use(&Some(Arc::clone(&cache)));
    emit(&[report], args)?;
    // The fig-19 validation gate: a depth slice that orders the wrong
    // way means the simulator (or the search) regressed — fail loudly,
    // after the report is already delivered for inspection.
    if !res.depth_ordered {
        anyhow::bail!(
            "fig-19 validation gate failed: staging depth 3 was slower than depth 2 \
             over {} explored pair(s)",
            res.depth_pairs
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let jobs = args.get_usize("jobs", api::default_jobs())?;
    let cap = args.get_usize("cache-cap", api::DEFAULT_CACHE_CAP)?;
    let shards = args.get_usize("shards", api::DEFAULT_CACHE_SHARDS)?;
    let workers = args.get_usize("workers", api::DEFAULT_SERVE_WORKERS)?;
    let queue_depth = args.get_usize("queue-depth", api::DEFAULT_QUEUE_DEPTH)?;
    // Default per-request deadline in milliseconds; 0 = off. Requests
    // can override it with their own `timeout_ms` field.
    let request_timeout_ms = args.get_u64("request-timeout", 0)?;
    let cache = Arc::new(build_cache(cap, shards, args.get("cache-dir"))?);
    let service = Service::new(Engine::new(jobs), Arc::clone(&cache));
    // Pre-resolve profiles into the artifact store so first requests
    // skip the load too.
    if let Some(models) = args.get_list("preload") {
        for m in &models {
            if service.artifacts().profile(m).is_none() {
                anyhow::bail!("--preload: unknown model '{m}'");
            }
        }
    }
    match args.get("listen") {
        Some(addr) => {
            let opts = ServeOptions {
                workers,
                queue_depth,
                request_timeout: (request_timeout_ms > 0)
                    .then(|| Duration::from_millis(request_timeout_ms)),
            };
            service.serve_tcp(addr, opts)?
        }
        None => {
            let stdin = std::io::stdin();
            let stdout = std::io::stdout();
            service.serve_lines(stdin.lock(), stdout.lock())?;
        }
    }
    let s = cache.stats();
    eprintln!(
        "serve: session ended — {} hits / {} misses ({:.0}% hit rate), {} coalesced",
        s.hits,
        s.misses,
        s.hit_rate() * 100.0,
        s.coalesced
    );
    Ok(())
}

/// Open an existing store file. `query`/`diff`/`compact` must never
/// create one — a typo'd --db should fail fast, not mint an empty
/// database; only `ingest` creates.
fn open_store(db: &str) -> Result<ExperimentStore> {
    if !std::path::Path::new(db).exists() {
        anyhow::bail!("store {db} does not exist (run `store ingest` first)");
    }
    Ok(ExperimentStore::open(db)?)
}

fn cmd_store(args: &Args) -> Result<()> {
    let sub = args.positional.get(1).map(String::as_str).unwrap_or("");
    let db = args
        .get("db")
        .ok_or_else(|| anyhow::anyhow!("store needs --db FILE (the record-log file)"))?;
    match sub {
        "ingest" => {
            let commit = args
                .get("commit")
                .ok_or_else(|| anyhow::anyhow!("store ingest needs --commit ID"))?;
            let files = &args.positional[2..];
            if files.is_empty() {
                anyhow::bail!("store ingest needs at least one report/bench JSON file");
            }
            let mut store = ExperimentStore::open(db)?;
            let mut written = 0usize;
            for f in files {
                written += store
                    .ingest_file(f, commit)
                    .map_err(|e| anyhow::anyhow!("ingesting {f}: {e}"))?;
            }
            // Seal: fsync + write the in-file index so the next open
            // takes the fast path.
            store.commit()?;
            eprintln!(
                "store: ingested {} file(s) at commit {commit} — {written} new record(s), \
                 {} total in {db}",
                files.len(),
                store.len()
            );
            Ok(())
        }
        "query" => {
            let mut store = open_store(db)?;
            let filter = QueryFilter {
                schema: args.get("schema").map(str::to_string),
                id: args.get("id").map(str::to_string),
                commit: args.get("commit").map(str::to_string),
                model: args.get("model").map(str::to_string),
                metric: args.get("metric").map(str::to_string),
            };
            let report = store.query(&filter)?;
            emit(&[report], args)
        }
        "diff" => {
            let id = args.get("id").ok_or_else(|| anyhow::anyhow!("store diff needs --id R"))?;
            let from =
                args.get("from").ok_or_else(|| anyhow::anyhow!("store diff needs --from C1"))?;
            let to = args.get("to").ok_or_else(|| anyhow::anyhow!("store diff needs --to C2"))?;
            let mut store = open_store(db)?;
            let report = store.diff(id, from, to)?;
            emit(&[report], args)
        }
        "compact" => {
            let before = std::fs::metadata(db).map(|m| m.len()).unwrap_or(0);
            let mut store = open_store(db)?;
            store.compact()?;
            let after = std::fs::metadata(db).map(|m| m.len()).unwrap_or(0);
            eprintln!(
                "store: compacted {db} — {before} -> {after} bytes, {} live record(s)",
                store.len()
            );
            Ok(())
        }
        other => anyhow::bail!("unknown store subcommand '{other}' (ingest|query|diff|compact)"),
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = chip_from_args(args)?;
    println!("TensorDash reproduction — configuration (paper Table 2 defaults)");
    println!("  PEs: {} ({} tiles of {}x{}), {} MACs/cycle @ {} MHz",
        cfg.total_pes(), cfg.tiles, cfg.tile_rows, cfg.tile_cols,
        cfg.macs_per_cycle(), cfg.freq_mhz);
    println!("  staging depth {}, dtype {:?}, side {:?}", cfg.staging_depth, cfg.dtype, cfg.side);
    println!("  DRAM: {} GB/s ({:.1} B/cycle)", cfg.dram_gbps, cfg.dram_bytes_per_cycle());
    repro::table3(cfg.dtype).print();
    // The model zoo: every name `simulate`/`serve`/`explore` resolve,
    // with its plan size. The paper's fig-13 nine are tagged; `bert`
    // is the transformer tier beyond the 2020 zoo.
    println!("\nmodels (--model NAME; layers x 3 training ops = plan units):");
    for name in tensordash::models::ALL_MODELS {
        let topo = tensordash::models::topology(name, tensordash::models::BATCH)
            .expect("ALL_MODELS entries resolve");
        let tier = if tensordash::models::FIG13_MODELS.contains(&name) {
            "paper zoo"
        } else {
            "transformer tier"
        };
        println!(
            "  {:<14} {:>3} layers, {:>3} units  {}",
            name,
            topo.layers.len(),
            topo.layers.len() * 3,
            tier
        );
    }
    // Sparsity regimes: every --regime spelling with its parameter
    // bounds, straight from the parser's own help table so `info`
    // cannot drift from what `Regime::parse` accepts.
    println!("\nsparsity regimes (--regime R; also the serve \"regime\" field):");
    for (spelling, what) in tensordash::sparsity::Regime::help() {
        println!("  {spelling:<34} {what}");
    }
    // Self-documenting search surface: every explorable axis with its
    // default value and accepted bounds (`explore --axis name=v1,v2`).
    println!("\nexplore search axes (use: explore --axis name=v1,v2 [--axis ...]):");
    for axis in SearchSpace::trivial().axes() {
        println!(
            "  {:<16} default {:<8} bounds {}",
            axis.name,
            axis.values[0],
            search::axis_bounds(&axis.name)
        );
    }
    // The experiment store's contract: every schema `store ingest`
    // accepts (alias = what `store query --schema` takes) and the
    // record-key tuple that deduplicates runs.
    println!("\nstore schemas (records keyed by commit, config hash, seed, schema):");
    for (alias, tag) in registered_schemas() {
        println!("  {alias:<10} {tag}");
    }
    // Serve transport defaults, kept in lockstep with the constants the
    // service actually uses so `info` cannot drift from `serve`.
    println!("\nserve transport defaults ({}):", api::SERVE_SCHEMA);
    println!(
        "  --workers          {:<6} compute threads draining the request queue",
        api::DEFAULT_SERVE_WORKERS
    );
    println!(
        "  --queue-depth      {:<6} bounded request queue; excess requests get an \
         in-band \"overloaded\" error",
        api::DEFAULT_QUEUE_DEPTH
    );
    println!(
        "  --request-timeout  {:<6} ms queue deadline (0 = off; per-request \
         \"timeout_ms\" overrides)",
        0
    );
    println!(
        "  --shards           {:<6} unit-cache shards",
        api::DEFAULT_CACHE_SHARDS
    );
    println!(
        "  request \"stream\":true opts out of response ordering; streamed replies \
         carry an \"op\" echo"
    );
    Ok(())
}
