//! `tensordash` CLI — leader entrypoint.
//!
//! Subcommands:
//!   repro     regenerate the paper's tables/figures (--fig N | --table 3|bf16 | --all)
//!   simulate  run one model profile through the cycle simulator
//!   train     run REAL training steps through the AOT artifacts and
//!             project TensorDash speedup from the captured sparsity
//!   info      print configuration + area model summary
//!
//! Examples:
//!   tensordash repro --all
//!   tensordash repro --fig 13 --samples 6 --seed 42
//!   tensordash simulate --model resnet50 --epoch 0.4
//!   tensordash train --steps 50 --log-every 10

use anyhow::Result;
use tensordash::config::{ChipConfig, DataType};
use tensordash::coordinator::data::DataGen;
use tensordash::coordinator::Trainer;
use tensordash::metrics::{f2, Table};
use tensordash::repro;
use tensordash::runtime::Runtime;
use tensordash::trace::profiles::ModelProfile;
use tensordash::util::cli::Args;

const USAGE: &str = "usage: tensordash <repro|simulate|train|info> [options]
  repro    --all | --fig <1|13|14|15|16|17|18|19|20|gcn|ablations>
           | --table <3|bf16>  [--samples N] [--seed S]
  simulate --model <name> [--epoch F] [--samples N] [--seed S]
           [--rows R] [--cols C] [--depth 2|3] [--bf16] [--power-gate]
  train    [--steps N] [--log-every K] [--seed S] [--artifacts DIR]
           [--samples N] [--sim-every K]
  info";

fn main() {
    let args = Args::parse(&["all", "bf16", "power-gate", "help"]);
    if args.flag("help") || args.positional.is_empty() {
        println!("{USAGE}");
        return;
    }
    let cmd = args.positional[0].clone();
    let result = match cmd.as_str() {
        "repro" => cmd_repro(&args),
        "simulate" => cmd_simulate(&args),
        "train" => cmd_train(&args),
        "info" => cmd_info(&args),
        other => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn chip_from_args(args: &Args) -> Result<ChipConfig> {
    let mut cfg = ChipConfig::default();
    cfg.tile_rows = args.get_usize("rows", cfg.tile_rows)?;
    cfg.tile_cols = args.get_usize("cols", cfg.tile_cols)?;
    cfg.staging_depth = args.get_usize("depth", cfg.staging_depth)?;
    if args.flag("bf16") {
        cfg.dtype = DataType::Bf16;
    }
    if args.flag("power-gate") {
        cfg.power_gate = true;
    }
    Ok(cfg)
}

fn cmd_repro(args: &Args) -> Result<()> {
    let samples = args.get_usize("samples", repro::DEFAULT_SAMPLES)?;
    let seed = args.get_u64("seed", 42)?;
    let all = args.flag("all");
    let fig = args.get("fig").map(|s| s.to_string());
    let table = args.get("table").map(|s| s.to_string());
    if !all && fig.is_none() && table.is_none() {
        anyhow::bail!("repro needs --all, --fig N or --table 3|bf16");
    }
    let cfg = ChipConfig::default();
    let want = |f: &str| all || fig.as_deref() == Some(f);

    if want("1") {
        repro::fig1().print();
    }
    // Figs 13/15/16 share one simulation sweep.
    if want("13") || want("15") || want("16") {
        let sims = repro::run_fig13_sims(&cfg, samples, seed);
        if want("13") {
            repro::fig13(&sims).print();
        }
        if want("15") {
            repro::fig15(&sims).print();
        }
        if want("16") {
            repro::fig16(&sims).print();
        }
    }
    if want("14") {
        repro::fig14(&cfg, samples, seed).print();
    }
    if want("17") {
        repro::fig17_rows(samples, seed).print();
    }
    if want("18") {
        repro::fig18_cols(samples, seed).print();
    }
    if want("19") {
        repro::fig19(samples, seed).print();
    }
    if want("20") {
        repro::fig20(10, seed).print();
    }
    if want("gcn") {
        repro::gcn_control(samples, seed).print();
    }
    if all || table.as_deref() == Some("3") {
        repro::table3(DataType::Fp32).print();
    }
    if all || table.as_deref() == Some("bf16") {
        repro::table3(DataType::Bf16).print();
    }
    if all || fig.as_deref() == Some("ablations") {
        repro::ablations::ablation_two_side(3, seed).print();
        repro::ablations::ablation_lead(3, seed).print();
        repro::ablations::ablation_dram_gate(3, seed).print();
        repro::ablations::ablation_backside_scheduler().print();
    }
    if all {
        let (exact, sampled) = repro::validate_sampling(seed);
        println!(
            "\nsampling validation: exhaustive speedup {} vs sampled {} ({} passes)",
            f2(exact),
            f2(sampled),
            samples
        );
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let model = args.get("model").unwrap_or("resnet50").to_string();
    let epoch = args.get_f64("epoch", repro::MID_EPOCH)?;
    let samples = args.get_usize("samples", repro::DEFAULT_SAMPLES)?;
    let seed = args.get_u64("seed", 42)?;
    let cfg = chip_from_args(args)?;
    let profile = ModelProfile::for_model(&model)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{model}' (see models::FIG13_MODELS)"))?;
    let sim = repro::simulate_profile(&cfg, &profile, epoch, samples, seed);
    let mut t = Table::new(
        format!("{model} @ epoch {epoch} ({}x{} tile, depth {})", cfg.tile_rows, cfg.tile_cols, cfg.staging_depth),
        &["metric", "A*W", "A*G", "W*G", "overall"],
    );
    use tensordash::conv::TrainOp;
    t.row(vec![
        "speedup".into(),
        f2(sim.op_speedup(TrainOp::Fwd)),
        f2(sim.op_speedup(TrainOp::Igrad)),
        f2(sim.op_speedup(TrainOp::Wgrad)),
        f2(sim.overall_speedup()),
    ]);
    t.print();
    println!(
        "energy efficiency: compute {}x, whole chip {}x",
        f2(sim.compute_efficiency()),
        f2(sim.total_efficiency())
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let steps = args.get_usize("steps", 50)?;
    let log_every = args.get_usize("log-every", 10)?.max(1);
    let sim_every = args.get_usize("sim-every", 10)?.max(1);
    let samples = args.get_usize("samples", repro::DEFAULT_SAMPLES)?;
    let seed = args.get_u64("seed", 42)?;
    let dir = args.get_or("artifacts", "artifacts");
    let cfg = chip_from_args(args)?;

    let rt = Runtime::new(dir)?;
    println!("PJRT platform: {}", rt.platform());
    let mut trainer = Trainer::new(&rt, seed as i32)?;
    let (n, h, w, c) = trainer.meta.input;
    let mut data = DataGen::new(h, w, c, trainer.meta.classes, seed);
    println!(
        "model: {} conv layers, batch {}, input {}x{}x{}, {} classes",
        trainer.meta.convs.len(),
        n,
        h,
        w,
        c,
        trainer.meta.classes
    );
    let shapes = trainer.meta.convs.clone();
    let mut last_sim: Option<tensordash::repro::ModelSim> = None;
    for step in 1..=steps {
        let (x, y) = data.batch(n);
        let out = trainer.step(&x, &y)?;
        if step % log_every == 0 || step == 1 || step == steps {
            let (sa, sg) = out.trace.mean_sparsity();
            println!(
                "step {:>4}  loss {:.4}  acc {:.3}  sparsity A {:.2} G {:.2}",
                step, out.loss, out.accuracy, sa, sg
            );
        }
        if step % sim_every == 0 || step == steps {
            let sim = repro::simulate_trace(&cfg, &shapes, &out.trace.layers, samples, seed);
            println!(
                "        projected TensorDash speedup {:.2}x (compute eff {:.2}x, chip eff {:.2}x)",
                sim.overall_speedup(),
                sim.compute_efficiency(),
                sim.total_efficiency()
            );
            last_sim = Some(sim);
        }
    }
    if let Some(sim) = last_sim {
        println!("\nfinal projection: {:.2}x speedup", sim.overall_speedup());
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = chip_from_args(args)?;
    println!("TensorDash reproduction — configuration (paper Table 2 defaults)");
    println!("  PEs: {} ({} tiles of {}x{}), {} MACs/cycle @ {} MHz",
        cfg.total_pes(), cfg.tiles, cfg.tile_rows, cfg.tile_cols,
        cfg.macs_per_cycle(), cfg.freq_mhz);
    println!("  staging depth {}, dtype {:?}, side {:?}", cfg.staging_depth, cfg.dtype, cfg.side);
    println!("  DRAM: {} GB/s ({:.1} B/cycle)", cfg.dram_gbps, cfg.dram_bytes_per_cycle());
    repro::table3(cfg.dtype).print();
    Ok(())
}
