//! Plain-text table rendering — the *text renderer* of the experiment
//! pipeline.
//!
//! Since the typed-API redesign, experiment results are
//! [`crate::api::Report`]s; `Table` is one renderer over them (via
//! `Report::to_table`), alongside the JSON and CSV renderers. Nothing
//! builds `Table`s as a result type anymore.

/// A simple aligned-column table printer.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|h| h.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n## {}\n", self.title));
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format helpers.
pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

pub fn f3(v: f64) -> String {
    format!("{v:.3}")
}

pub fn pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

/// Geometric mean (the right average for speedups).
pub fn geomean(vals: impl IntoIterator<Item = f64>) -> f64 {
    let (mut logsum, mut n) = (0.0, 0u32);
    for v in vals {
        logsum += v.max(1e-12).ln();
        n += 1;
    }
    if n == 0 {
        1.0
    } else {
        (logsum / n as f64).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["model", "speedup"]);
        t.row(vec!["alexnet".into(), f2(1.953)]);
        t.row(vec!["gcn".into(), f2(1.01)]);
        let s = t.render();
        assert!(s.contains("## demo"));
        assert!(s.contains("1.95"));
        assert!(s.lines().count() >= 5);
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn geomean_math() {
        assert!((geomean([2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert!((geomean([]) - 1.0).abs() < 1e-12);
    }
}
