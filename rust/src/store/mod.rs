//! Persistent experiment store with indexed history.
//!
//! Every artifact the pipeline emits — `tensordash.report.v1` figures,
//! `tensordash.layers.v1` breakdowns, `tensordash.frontier.v1` Pareto
//! frontiers, `tensordash.bench.v1` perf records — is write-once JSON
//! that dies with its CI run. The store gives them a history: one
//! single-file, append-friendly, indexed database (the
//! [`RecordLog`]; no external DB dependency) keyed by
//!
//! ```text
//!   (schema, id, commit, canonical-config hash, seed)
//! ```
//!
//! so "did PR N regress fig-13 cycles?" and "how did the frontier
//! move?" become `store query` / `store diff` one-liners. The config
//! hash is FNV-1a ([`crate::util::hash::fnv1a64`]) over the canonical
//! render of the document's meta block *minus* volatile presentation
//! keys (`unit_cache_*` counters), so warm- and cold-cache runs of the
//! same experiment land on the same key — re-ingest is idempotent and
//! last-wins.
//!
//! Query and diff results are ordinary [`Report`]s, so they inherit the
//! text/JSON/CSV renderers and their byte-determinism contract: the
//! same store contents produce byte-identical output at any `--jobs`
//! count, warm or cold.

pub mod log;

pub use log::{LogStats, RecordLog};

use std::collections::BTreeMap;
use std::fmt;
use std::io;
use std::path::Path;

use crate::api::report::{
    Cell, Report, FRONTIER_SCHEMA, LAYERS_SCHEMA, REPORT_SCHEMA, REPORT_SET_SCHEMA,
};
use crate::search::frontier::{diff_points, DiffStatus};
use crate::search::objective::Score;
use crate::util::hash::fnv1a64;
use crate::util::json::Json;

/// Version tag of the `BENCH_*.json` perf artifacts
/// (`rust/benches/*.rs` all emit this envelope).
pub const BENCH_SCHEMA: &str = "tensordash.bench.v1";
/// Version tag of one stored record envelope (`{schema, key, doc}`).
pub const STORE_RECORD_SCHEMA: &str = "tensordash.store.v1";
/// Version tag of the canonical key tuple a record is stored under.
pub const STORE_KEY_SCHEMA: &str = "tensordash.storekey.v1";

/// The document schemas the store ingests, as `(alias, version tag)`
/// pairs. The alias is what `store query --schema <alias>` accepts;
/// `info` lists both columns.
pub fn registered_schemas() -> &'static [(&'static str, &'static str)] {
    &[
        ("report", REPORT_SCHEMA),
        ("layers", LAYERS_SCHEMA),
        ("frontier", FRONTIER_SCHEMA),
        ("reportset", REPORT_SET_SCHEMA),
        ("bench", BENCH_SCHEMA),
    ]
}

/// Typed store failure. Notably [`StoreError::UnknownSchema`]: feeding
/// the store a document it has no schema handler for is an error, not
/// a silent skip.
#[derive(Debug)]
pub enum StoreError {
    Io(io::Error),
    /// The document claims a registered schema but doesn't parse as it.
    Parse(String),
    /// The document's `schema` field names no registered schema (or is
    /// missing entirely).
    UnknownSchema(String),
    /// A stored record failed validation on the way back out.
    Corrupt(String),
    /// `diff` asked for a (id, commit) pair the store doesn't hold.
    NotFound(String),
}

impl fmt::Display for StoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StoreError::Io(e) => write!(f, "store io error: {e}"),
            StoreError::Parse(m) => write!(f, "store parse error: {m}"),
            StoreError::UnknownSchema(s) => write!(
                f,
                "unknown document schema '{s}' (registered: {})",
                registered_schemas()
                    .iter()
                    .map(|(_, v)| *v)
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
            StoreError::Corrupt(m) => write!(f, "store corrupt record: {m}"),
            StoreError::NotFound(m) => write!(f, "store record not found: {m}"),
        }
    }
}

impl std::error::Error for StoreError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            StoreError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<io::Error> for StoreError {
    fn from(e: io::Error) -> StoreError {
        StoreError::Io(e)
    }
}

/// The canonical key tuple a record is stored under.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StoreKey {
    /// Document schema tag (`tensordash.report.v1`, ...).
    pub schema: String,
    /// Document identity: report `id` or bench name.
    pub id: String,
    /// Source-tree commit the artifact was produced at.
    pub commit: String,
    /// FNV-1a over the canonical meta render minus volatile keys;
    /// 0 when the document carries no config-bearing meta.
    pub cfg_hash: u64,
    /// Experiment seed (0 when the document has none).
    pub seed: u64,
}

impl StoreKey {
    /// Canonical key encoding: a compact-rendered JSON object with
    /// BTreeMap-sorted fields. u64s render as fixed-width hex strings
    /// (JSON numbers are f64 and lose integers past 2^53).
    pub fn canon(&self) -> String {
        let mut m = BTreeMap::new();
        m.insert("cfg".to_string(), Json::Str(format!("{:016x}", self.cfg_hash)));
        m.insert("commit".to_string(), Json::Str(self.commit.clone()));
        m.insert("id".to_string(), Json::Str(self.id.clone()));
        m.insert("schema".to_string(), Json::Str(self.schema.clone()));
        m.insert("seed".to_string(), Json::Str(format!("{:016x}", self.seed)));
        m.insert("v".to_string(), Json::Str(STORE_KEY_SCHEMA.to_string()));
        Json::Obj(m).render()
    }

    fn parse(canon: &str) -> Result<StoreKey, StoreError> {
        let j = Json::parse(canon)
            .map_err(|e| StoreError::Corrupt(format!("unparseable store key: {e}")))?;
        let field = |name: &str| -> Result<String, StoreError> {
            j.get(name)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| StoreError::Corrupt(format!("store key missing '{name}': {canon}")))
        };
        if field("v")? != STORE_KEY_SCHEMA {
            return Err(StoreError::Corrupt(format!("store key version mismatch: {canon}")));
        }
        let hex = |name: &str| -> Result<u64, StoreError> {
            u64::from_str_radix(&field(name)?, 16)
                .map_err(|_| StoreError::Corrupt(format!("store key bad hex '{name}': {canon}")))
        };
        Ok(StoreKey {
            schema: field("schema")?,
            id: field("id")?,
            commit: field("commit")?,
            cfg_hash: hex("cfg")?,
            seed: hex("seed")?,
        })
    }
}

/// One record read back from the store: its key plus the original
/// ingested document.
#[derive(Debug, Clone)]
pub struct StoreRecord {
    pub key: StoreKey,
    pub doc: Json,
}

impl StoreRecord {
    /// Row count of the underlying document (report rows or bench
    /// records) — the catalog query's size column.
    fn row_count(&self) -> usize {
        let arr = if self.key.schema == BENCH_SCHEMA {
            self.doc.get("records")
        } else {
            self.doc.get("rows")
        };
        arr.and_then(Json::as_arr).map_or(0, Vec::len)
    }
}

/// Record selection for [`ExperimentStore::query`]. Empty filter =
/// everything; all present fields must match.
#[derive(Debug, Clone, Default)]
pub struct QueryFilter {
    /// Schema alias (`report`) or full tag (`tensordash.report.v1`).
    pub schema: Option<String>,
    /// Report id / bench name (`fig13`, `store_warmstart`).
    pub id: Option<String>,
    pub commit: Option<String>,
    /// Row label filter (first-column text): model or config name.
    pub model: Option<String>,
    /// Column (report docs) or record field (bench docs) to extract a
    /// trajectory of. Without it, `query` prints the record catalog.
    pub metric: Option<String>,
}

impl QueryFilter {
    fn schema_tag(&self) -> Option<String> {
        let s = self.schema.as_deref()?;
        let tag = registered_schemas()
            .iter()
            .find(|(alias, _)| *alias == s)
            .map_or(s, |(_, tag)| *tag);
        Some(tag.to_string())
    }

    fn matches(&self, key: &StoreKey) -> bool {
        if let Some(tag) = self.schema_tag() {
            if key.schema != tag {
                return false;
            }
        }
        if let Some(id) = &self.id {
            if &key.id != id {
                return false;
            }
        }
        if let Some(commit) = &self.commit {
            if &key.commit != commit {
                return false;
            }
        }
        true
    }
}

/// Hash the config-bearing part of a report's meta block: canonical
/// render with volatile presentation keys (`unit_cache_*` counters)
/// removed, so warm- and cold-cache runs key identically.
fn config_hash(meta: &BTreeMap<String, Json>) -> u64 {
    let stable: BTreeMap<String, Json> = meta
        .iter()
        .filter(|(k, _)| !k.starts_with("unit_cache_"))
        .map(|(k, v)| (k.clone(), v.clone()))
        .collect();
    if stable.is_empty() {
        return 0;
    }
    fnv1a64(Json::Obj(stable).render().as_bytes())
}

/// The experiment store: schema-aware ingestion, catalog/trajectory
/// queries, and commit-to-commit diffs over one [`RecordLog`] file.
#[derive(Debug)]
pub struct ExperimentStore {
    log: RecordLog,
}

impl ExperimentStore {
    pub fn open(path: impl AsRef<Path>) -> Result<ExperimentStore, StoreError> {
        Ok(ExperimentStore { log: RecordLog::open(path)? })
    }

    pub fn len(&self) -> usize {
        self.log.len()
    }

    pub fn is_empty(&self) -> bool {
        self.log.is_empty()
    }

    /// Backend telemetry (fast-path open, scans, truncations, IO).
    pub fn log_stats(&self) -> LogStats {
        self.log.stats()
    }

    /// Ingest one JSON file produced at `commit`. Returns the number of
    /// records actually written (0 when everything was already stored
    /// byte-identically — re-ingest is idempotent).
    pub fn ingest_file(&mut self, path: impl AsRef<Path>, commit: &str) -> Result<usize, StoreError> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)?;
        let doc = Json::parse(&text)
            .map_err(|e| StoreError::Parse(format!("{}: {e}", path.display())))?;
        self.ingest_json(&doc, commit)
    }

    /// Ingest one parsed document produced at `commit`. Reportsets
    /// unwrap to their member reports; unknown schemas are a typed
    /// [`StoreError::UnknownSchema`], never a silent skip.
    pub fn ingest_json(&mut self, doc: &Json, commit: &str) -> Result<usize, StoreError> {
        let Some(schema) = doc.get("schema").and_then(Json::as_str) else {
            return Err(StoreError::UnknownSchema("(missing schema field)".to_string()));
        };
        if schema == REPORT_SET_SCHEMA {
            let reports = doc.get("reports").and_then(Json::as_arr).ok_or_else(|| {
                StoreError::Parse("reportset document without a 'reports' array".to_string())
            })?;
            let mut written = 0;
            for r in reports {
                written += self.ingest_json(r, commit)?;
            }
            return Ok(written);
        }
        if schema == REPORT_SCHEMA || schema == LAYERS_SCHEMA || schema == FRONTIER_SCHEMA {
            let report = Report::from_json(doc)
                .ok_or_else(|| StoreError::Parse(format!("malformed {schema} document")))?;
            let key = StoreKey {
                schema: schema.to_string(),
                id: report.id.clone(),
                commit: commit.to_string(),
                cfg_hash: config_hash(&report.meta),
                seed: report.meta.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64,
            };
            return self.put(&key, doc);
        }
        if schema == BENCH_SCHEMA {
            let id = doc.get("bench").and_then(Json::as_str).ok_or_else(|| {
                StoreError::Parse("bench document without a 'bench' name".to_string())
            })?;
            if doc.get("records").and_then(Json::as_arr).is_none() {
                return Err(StoreError::Parse(format!(
                    "bench document '{id}' without a 'records' array"
                )));
            }
            let key = StoreKey {
                schema: schema.to_string(),
                id: id.to_string(),
                commit: commit.to_string(),
                cfg_hash: 0,
                seed: 0,
            };
            return self.put(&key, doc);
        }
        Err(StoreError::UnknownSchema(schema.to_string()))
    }

    /// Store `doc` under `key`: last-wins per key, no-op (returns 0)
    /// when the stored payload is already byte-identical.
    fn put(&mut self, key: &StoreKey, doc: &Json) -> Result<usize, StoreError> {
        let canon = key.canon();
        let mut env = BTreeMap::new();
        env.insert("doc".to_string(), doc.clone());
        env.insert("key".to_string(), Json::Str(canon.clone()));
        env.insert("schema".to_string(), Json::Str(STORE_RECORD_SCHEMA.to_string()));
        let payload = Json::Obj(env).render();
        if self.log.get(&canon)?.as_deref() == Some(payload.as_str()) {
            return Ok(0);
        }
        self.log.append(&canon, &payload)?;
        Ok(1)
    }

    /// fsync + write the in-file index; the next open is a no-scan
    /// fast path.
    pub fn commit(&mut self) -> Result<(), StoreError> {
        Ok(self.log.seal()?)
    }

    /// Rewrite the backing file keeping only live record versions.
    pub fn compact(&mut self) -> Result<(), StoreError> {
        Ok(self.log.compact()?)
    }

    /// Every stored record (validated envelope + key) in insertion
    /// order.
    pub fn records(&mut self) -> Result<Vec<StoreRecord>, StoreError> {
        let raw = self.log.records()?;
        let mut out = Vec::with_capacity(raw.len());
        for (log_key, payload) in raw {
            let env = Json::parse(&payload)
                .map_err(|e| StoreError::Corrupt(format!("record '{log_key}': {e}")))?;
            if env.get("schema").and_then(Json::as_str) != Some(STORE_RECORD_SCHEMA) {
                return Err(StoreError::Corrupt(format!(
                    "record '{log_key}' is not a {STORE_RECORD_SCHEMA} envelope"
                )));
            }
            if env.get("key").and_then(Json::as_str) != Some(log_key.as_str()) {
                return Err(StoreError::Corrupt(format!(
                    "record '{log_key}' envelope key does not match its log key"
                )));
            }
            let doc = env
                .get("doc")
                .cloned()
                .ok_or_else(|| StoreError::Corrupt(format!("record '{log_key}' has no doc")))?;
            out.push(StoreRecord { key: StoreKey::parse(&log_key)?, doc });
        }
        Ok(out)
    }

    /// Catalog or trajectory query; see [`QueryFilter`]. The result is
    /// an ordinary [`Report`] (text/JSON/CSV renderable). An empty
    /// selection yields an empty report, not an error.
    pub fn query(&mut self, f: &QueryFilter) -> Result<Report, StoreError> {
        let records: Vec<StoreRecord> =
            self.records()?.into_iter().filter(|r| f.matches(&r.key)).collect();
        match &f.metric {
            Some(metric) => Self::trajectory(&records, f, metric),
            None => Ok(Self::catalog(&records)),
        }
    }

    /// The no-metric query: one row per stored record.
    fn catalog(records: &[StoreRecord]) -> Report {
        let mut r = Report::new(
            "store_query",
            format!("Experiment store catalog — {} records", records.len()),
            &["commit", "schema", "id", "rows", "seed"],
        );
        for rec in records {
            let n = rec.row_count();
            r.row(vec![
                Cell::text(rec.key.commit.clone()),
                Cell::text(rec.key.schema.clone()),
                Cell::text(rec.key.id.clone()),
                Cell::fmt(n.to_string(), n as f64),
                Cell::fmt(rec.key.seed.to_string(), rec.key.seed as f64),
            ]);
        }
        r.meta_num("records", records.len() as f64);
        r
    }

    /// The metric query: one row per (record, matching row) holding the
    /// metric's value — the trajectory of that metric across commits.
    fn trajectory(
        records: &[StoreRecord],
        f: &QueryFilter,
        metric: &str,
    ) -> Result<Report, StoreError> {
        let mut r = Report::new(
            "store_query",
            format!("Trajectory of '{metric}' — {} records", records.len()),
            &["commit", "id", "row", metric],
        );
        for rec in records {
            if rec.key.schema == BENCH_SCHEMA {
                let bench_recs = rec.doc.get("records").and_then(Json::as_arr);
                for bench_rec in bench_recs.map(Vec::as_slice).unwrap_or_default() {
                    let Some(name) = bench_rec.get("name").and_then(Json::as_str) else {
                        continue;
                    };
                    if let Some(model) = &f.model {
                        if name != model {
                            continue;
                        }
                    }
                    if let Some(v) = bench_rec.get(metric).and_then(Json::as_f64) {
                        r.row(vec![
                            Cell::text(rec.key.commit.clone()),
                            Cell::text(rec.key.id.clone()),
                            Cell::text(name),
                            Cell::num(v),
                        ]);
                    }
                }
                continue;
            }
            let report = Report::from_json(&rec.doc).ok_or_else(|| {
                StoreError::Corrupt(format!(
                    "stored {} document '{}' no longer parses",
                    rec.key.schema, rec.key.id
                ))
            })?;
            let Some(col) = report.columns.iter().position(|c| c == metric) else {
                continue;
            };
            for row in &report.rows {
                let label = &row.cells[0].text;
                if let Some(model) = &f.model {
                    if label != model {
                        continue;
                    }
                }
                let cell = &row.cells[col];
                if let Some(v) = cell.value {
                    r.row(vec![
                        Cell::text(rec.key.commit.clone()),
                        Cell::text(rec.key.id.clone()),
                        Cell::text(label.clone()),
                        Cell::fmt(cell.text.clone(), v),
                    ]);
                }
            }
        }
        r.meta_str("metric", metric);
        if let Some(model) = &f.model {
            r.meta_str("model", model);
        }
        r.meta_num("records", records.len() as f64);
        Ok(r)
    }

    /// The latest stored record for (`id`, `commit`), if any.
    fn latest(records: &[StoreRecord], id: &str, commit: &str) -> Option<StoreRecord> {
        records
            .iter()
            .rev()
            .find(|r| r.key.id == id && r.key.commit == commit)
            .cloned()
    }

    /// Compare document `id` between two commits. Two frontiers diff by
    /// Pareto dominance (added / kept / removed / newly-dominated, via
    /// [`diff_points`]); everything else diffs per-metric
    /// (from/to/delta/pct, rows matched by first-column label).
    pub fn diff(&mut self, id: &str, from: &str, to: &str) -> Result<Report, StoreError> {
        let records = self.records()?;
        let a = Self::latest(&records, id, from).ok_or_else(|| {
            StoreError::NotFound(format!("no record for id '{id}' at commit '{from}'"))
        })?;
        let b = Self::latest(&records, id, to).ok_or_else(|| {
            StoreError::NotFound(format!("no record for id '{id}' at commit '{to}'"))
        })?;
        if a.key.schema == BENCH_SCHEMA || b.key.schema == BENCH_SCHEMA {
            return Err(StoreError::Parse(format!(
                "diff compares report/layers/frontier documents; '{id}' is a bench record \
                 (query a bench metric's trajectory instead)"
            )));
        }
        let ar = Report::from_json(&a.doc).ok_or_else(|| {
            StoreError::Corrupt(format!("stored document '{id}'@{from} no longer parses"))
        })?;
        let br = Report::from_json(&b.doc).ok_or_else(|| {
            StoreError::Corrupt(format!("stored document '{id}'@{to} no longer parses"))
        })?;
        let mut r = if ar.schema == FRONTIER_SCHEMA && br.schema == FRONTIER_SCHEMA {
            Self::diff_frontiers(&ar, &br)?
        } else {
            Self::diff_reports(&ar, &br)
        };
        r.meta_str("id", id);
        r.meta_str("from", from);
        r.meta_str("to", to);
        Ok(r)
    }

    /// Extract `(config label, score)` points from a stored
    /// `tensordash.frontier.v1` report.
    fn frontier_points(r: &Report) -> Result<Vec<(String, Score)>, StoreError> {
        let mut out = Vec::with_capacity(r.rows.len());
        for (i, row) in r.rows.iter().enumerate() {
            let need = |col: &str| -> Result<f64, StoreError> {
                r.value(i, col).ok_or_else(|| {
                    StoreError::Corrupt(format!("frontier row {i} has no numeric '{col}'"))
                })
            };
            out.push((
                row.cells[0].text.clone(),
                Score {
                    td_cycles: need("td cycles")?,
                    energy_pj: need("energy pJ")?,
                    area_mm2: need("area mm2")?,
                },
            ));
        }
        Ok(out)
    }

    fn diff_frontiers(ar: &Report, br: &Report) -> Result<Report, StoreError> {
        let from_pts = Self::frontier_points(ar)?;
        let to_pts = Self::frontier_points(br)?;
        let classified = diff_points(&from_pts, &to_pts);
        let count = |s: DiffStatus| classified.iter().filter(|(_, _, st)| *st == s).count();
        let mut r = Report::new(
            "store_diff",
            format!(
                "Frontier diff — {} added, {} kept, {} newly-dominated, {} removed",
                count(DiffStatus::Added),
                count(DiffStatus::Kept),
                count(DiffStatus::NewlyDominated),
                count(DiffStatus::Removed),
            ),
            &["config", "status", "td cycles", "energy pJ", "area mm2"],
        );
        for (label, score, status) in &classified {
            r.row(vec![
                Cell::text(label.clone()),
                Cell::text(status.as_str()),
                Cell::fmt((score.td_cycles as u64).to_string(), score.td_cycles),
                Cell::fmt(format!("{:.3e}", score.energy_pj), score.energy_pj),
                Cell::num(score.area_mm2),
            ]);
        }
        r.meta_num("added", count(DiffStatus::Added) as f64);
        r.meta_num("kept", count(DiffStatus::Kept) as f64);
        r.meta_num("newly_dominated", count(DiffStatus::NewlyDominated) as f64);
        r.meta_num("removed", count(DiffStatus::Removed) as f64);
        Ok(r)
    }

    fn diff_reports(ar: &Report, br: &Report) -> Report {
        let mut r = Report::new(
            "store_diff",
            format!("Report diff — '{}'", br.id),
            &["row", "metric", "from", "to", "delta", "pct"],
        );
        let mut compared = 0usize;
        for (bi, brow) in br.rows.iter().enumerate() {
            let label = &brow.cells[0].text;
            let Some(ai) = ar.rows.iter().position(|a| &a.cells[0].text == label) else {
                continue;
            };
            for col in br.columns.iter().skip(1) {
                let (Some(fv), Some(tv)) = (ar.value(ai, col), br.value(bi, col)) else {
                    continue;
                };
                let delta = tv - fv;
                let pct = if fv != 0.0 { delta / fv * 100.0 } else { 0.0 };
                r.row(vec![
                    Cell::text(label.clone()),
                    Cell::text(col.clone()),
                    Cell::num(fv),
                    Cell::num(tv),
                    Cell::fmt(format!("{delta:+.4}"), delta),
                    Cell::fmt(format!("{pct:+.2}%"), pct),
                ]);
                compared += 1;
            }
        }
        r.meta_num("metrics_compared", compared as f64);
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_store(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("td_store_{tag}_{}.tdstore", std::process::id()))
    }

    fn demo_report(id: &str, v: f64) -> Report {
        let mut r = Report::new(id, "Demo", &["model", "overall"]);
        r.row(vec![Cell::text("alexnet"), Cell::num(v)]);
        r.meta_num("seed", 42.0);
        r
    }

    #[test]
    fn unknown_schema_is_a_typed_error_not_a_skip() {
        let path = temp_store("unknown");
        let _ = std::fs::remove_file(&path);
        let mut store = ExperimentStore::open(&path).unwrap();
        let doc = Json::parse(r#"{"schema":"tensordash.report.v9","id":"x"}"#).unwrap();
        assert!(matches!(
            store.ingest_json(&doc, "c1"),
            Err(StoreError::UnknownSchema(s)) if s == "tensordash.report.v9"
        ));
        let doc = Json::parse(r#"{"id":"x"}"#).unwrap();
        assert!(matches!(store.ingest_json(&doc, "c1"), Err(StoreError::UnknownSchema(_))));
        assert!(store.is_empty(), "failed ingest must write nothing");
        drop(store);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn reingest_is_idempotent_and_update_is_last_wins() {
        let path = temp_store("idem");
        let _ = std::fs::remove_file(&path);
        let mut store = ExperimentStore::open(&path).unwrap();
        let doc = demo_report("fig13", 1.95).to_json();
        assert_eq!(store.ingest_json(&doc, "c1").unwrap(), 1);
        assert_eq!(store.ingest_json(&doc, "c1").unwrap(), 0, "byte-identical re-ingest");
        assert_eq!(store.len(), 1);
        // Same key, different content: replaced, not duplicated.
        let doc2 = demo_report("fig13", 2.05).to_json();
        assert_eq!(store.ingest_json(&doc2, "c1").unwrap(), 1);
        assert_eq!(store.len(), 1);
        let recs = store.records().unwrap();
        assert_eq!(recs[0].doc, doc2);
        // A different commit is a different key.
        assert_eq!(store.ingest_json(&doc, "c2").unwrap(), 1);
        assert_eq!(store.len(), 2);
        drop(store);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn config_hash_ignores_volatile_cache_counters() {
        let mut warm = demo_report("fig13", 1.95);
        warm.meta_num("unit_cache_hits", 120.0);
        warm.meta_num("unit_cache_hit_rate", 0.93);
        let cold = demo_report("fig13", 1.95);
        assert_eq!(config_hash(&warm.meta), config_hash(&cold.meta));
        let mut other = demo_report("fig13", 1.95);
        other.meta_num("seed", 43.0);
        assert_ne!(config_hash(&other.meta), config_hash(&cold.meta));
    }

    #[test]
    fn key_canon_round_trips() {
        let key = StoreKey {
            schema: REPORT_SCHEMA.to_string(),
            id: "fig13".to_string(),
            commit: "abc123".to_string(),
            cfg_hash: 0xdead_beef_0000_0001,
            seed: 42,
        };
        let canon = key.canon();
        assert!(canon.contains(STORE_KEY_SCHEMA));
        assert_eq!(StoreKey::parse(&canon).unwrap(), key);
    }

    #[test]
    fn catalog_and_trajectory_queries() {
        let path = temp_store("query");
        let _ = std::fs::remove_file(&path);
        let mut store = ExperimentStore::open(&path).unwrap();
        store.ingest_json(&demo_report("fig13", 1.95).to_json(), "c1").unwrap();
        store.ingest_json(&demo_report("fig13", 2.05).to_json(), "c2").unwrap();
        let catalog = store.query(&QueryFilter::default()).unwrap();
        assert_eq!(catalog.rows.len(), 2);
        let traj = store
            .query(&QueryFilter { metric: Some("overall".to_string()), ..Default::default() })
            .unwrap();
        assert_eq!(traj.columns, vec!["commit", "id", "row", "overall"]);
        assert_eq!(traj.rows.len(), 2);
        assert_eq!(traj.value(0, "overall"), Some(1.95));
        assert_eq!(traj.value(1, "overall"), Some(2.05));
        // Unmatched filters are empty reports, not errors.
        let none = store
            .query(&QueryFilter { commit: Some("c9".to_string()), ..Default::default() })
            .unwrap();
        assert!(none.rows.is_empty());
        drop(store);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn report_diff_computes_deltas() {
        let path = temp_store("diff");
        let _ = std::fs::remove_file(&path);
        let mut store = ExperimentStore::open(&path).unwrap();
        store.ingest_json(&demo_report("fig13", 2.0).to_json(), "c1").unwrap();
        store.ingest_json(&demo_report("fig13", 2.5).to_json(), "c2").unwrap();
        let d = store.diff("fig13", "c1", "c2").unwrap();
        assert_eq!(d.rows.len(), 1);
        assert_eq!(d.value(0, "from"), Some(2.0));
        assert_eq!(d.value(0, "to"), Some(2.5));
        assert_eq!(d.value(0, "delta"), Some(0.5));
        assert_eq!(d.rows[0].cells[5].text, "+25.00%");
        assert!(matches!(
            store.diff("fig13", "c1", "c9"),
            Err(StoreError::NotFound(_))
        ));
        drop(store);
        let _ = std::fs::remove_file(&path);
    }
}
