//! Single-file, append-friendly record log with an in-file index.
//!
//! The disk format backing both the experiment store
//! ([`super::ExperimentStore`]) and the unit cache's disk mirror
//! ([`crate::api::cache::UnitCache`]). One file, no external DB:
//!
//! ```text
//!   header   "TDSTORE1" (8B) | version u64 LE (8B)
//!   frame*   body_len u32 LE | body
//!   body     kind u8 | key_hash u64 LE | key_len u32 LE | key bytes
//!            | payload bytes | checksum u64 LE
//!   [index frame  kind=2, key empty, payload = entry table]
//!   [trailer      index_offset u64 LE (8B) | "TDINDEX1" (8B)]
//! ```
//!
//! * `checksum` is FNV-1a ([`crate::util::hash::fnv1a64`]) over the
//!   body bytes before it, so torn tail writes are detected.
//! * Records are last-wins per key; re-appending a key replaces its
//!   value while keeping the key's original position in iteration
//!   order, so reads stay deterministic across updates.
//! * [`RecordLog::seal`] writes an index frame (the live entry table)
//!   plus a fixed-size trailer pointing at it; the next
//!   [`RecordLog::open`] then restores the index from that one frame
//!   without scanning — the compacted warm-start path. Appending to a
//!   sealed file first truncates the stale index + trailer.
//! * **Crash safety is recovery by tail truncation**: opening a file
//!   without a valid trailer scans frame-by-frame, drops everything
//!   from the first torn/corrupt frame onward (`set_len`), and keeps
//!   every intact record before it. Committed prefixes survive;
//!   half-written tails never alias as data.
//! * [`RecordLog::compact`] rewrites only the live frames (dropping
//!   superseded versions) into a fresh sealed file and atomically
//!   renames it over the old one.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{self, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::util::hash::fnv1a64;

/// File magic: first 8 bytes of every record-log file.
pub const LOG_MAGIC: &[u8; 8] = b"TDSTORE1";
/// Trailer magic: last 8 bytes of a sealed file.
pub const TRAILER_MAGIC: &[u8; 8] = b"TDINDEX1";
/// On-disk format version (bump on any layout change).
pub const LOG_VERSION: u64 = 1;

const HEADER_LEN: u64 = 16;
const TRAILER_LEN: u64 = 16;
const KIND_RECORD: u8 = 1;
const KIND_INDEX: u8 = 2;
/// Smallest legal body: kind + key_hash + key_len + checksum.
const MIN_BODY: u32 = 21;
/// Upper bound keeps a corrupt length field from allocating wild.
const MAX_BODY: u32 = 1 << 30;

/// Open/read/append telemetry of one log handle — the evidence behind
/// "one compacted index instead of thousands of per-key files".
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LogStats {
    /// The open restored the index from the sealed trailer (no scan).
    pub fast_path: bool,
    /// Frames walked by the scanning open path (0 on the fast path).
    pub frames_scanned: u64,
    /// Bytes dropped by crash recovery (torn/corrupt tail).
    pub truncated_bytes: u64,
    /// Record frames read back (`get`/`records`).
    pub reads: u64,
    /// Record frames appended through this handle.
    pub appends: u64,
}

#[derive(Debug, Clone)]
struct Entry {
    key: String,
    offset: u64,
}

/// A single-file keyed record log. All operations go through one file
/// handle; callers needing sharing wrap it in a `Mutex`.
#[derive(Debug)]
pub struct RecordLog {
    path: PathBuf,
    file: File,
    /// End of record data: the next append goes here; a sealed index
    /// frame + trailer, when present, sit at this offset.
    data_end: u64,
    /// The file currently ends with a valid index frame + trailer.
    indexed: bool,
    /// Records were appended since the last seal.
    dirty: bool,
    /// Live entries in first-insertion order (last-wins offsets).
    entries: Vec<Entry>,
    by_key: HashMap<String, usize>,
    stats: LogStats,
}

fn corrupt(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

fn u32_at(b: &[u8], off: usize) -> u32 {
    u32::from_le_bytes(b[off..off + 4].try_into().expect("4 bytes"))
}

fn u64_at(b: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(b[off..off + 8].try_into().expect("8 bytes"))
}

/// Encode one frame (length prefix + body + checksum).
fn encode_frame(kind: u8, key: &[u8], payload: &[u8]) -> Vec<u8> {
    let body_len = 1 + 8 + 4 + key.len() + payload.len() + 8;
    let mut out = Vec::with_capacity(4 + body_len);
    out.extend_from_slice(&(body_len as u32).to_le_bytes());
    out.push(kind);
    out.extend_from_slice(&fnv1a64(key).to_le_bytes());
    out.extend_from_slice(&(key.len() as u32).to_le_bytes());
    out.extend_from_slice(key);
    out.extend_from_slice(payload);
    let sum = fnv1a64(&out[4..4 + body_len - 8]);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

/// A validated, decoded frame body.
struct Frame {
    kind: u8,
    key: String,
    payload: Vec<u8>,
}

/// Decode + validate one frame body (everything after the length
/// prefix). Returns `None` on any integrity failure.
fn decode_body(body: &[u8]) -> Option<Frame> {
    if body.len() < MIN_BODY as usize {
        return None;
    }
    let sum_off = body.len() - 8;
    if u64_at(body, sum_off) != fnv1a64(&body[..sum_off]) {
        return None;
    }
    let kind = body[0];
    if kind != KIND_RECORD && kind != KIND_INDEX {
        return None;
    }
    let key_hash = u64_at(body, 1);
    let key_len = u32_at(body, 9) as usize;
    if 13 + key_len > sum_off {
        return None;
    }
    let key = std::str::from_utf8(&body[13..13 + key_len]).ok()?;
    if fnv1a64(key.as_bytes()) != key_hash {
        return None;
    }
    Some(Frame {
        kind,
        key: key.to_string(),
        payload: body[13 + key_len..sum_off].to_vec(),
    })
}

impl RecordLog {
    /// Open (or create) the log at `path`. A sealed file restores its
    /// index from the trailer; anything else is scanned with crash
    /// recovery by tail truncation.
    pub fn open(path: impl AsRef<Path>) -> io::Result<RecordLog> {
        let path = path.as_ref().to_path_buf();
        let file = OpenOptions::new().read(true).write(true).create(true).open(&path)?;
        let file_len = file.metadata()?.len();
        let mut log = RecordLog {
            path,
            file,
            data_end: HEADER_LEN,
            indexed: false,
            dirty: false,
            entries: Vec::new(),
            by_key: HashMap::new(),
            stats: LogStats::default(),
        };
        if file_len == 0 {
            let mut header = Vec::with_capacity(HEADER_LEN as usize);
            header.extend_from_slice(LOG_MAGIC);
            header.extend_from_slice(&LOG_VERSION.to_le_bytes());
            log.file.write_all(&header)?;
            log.file.sync_all()?;
            return Ok(log);
        }
        if file_len < HEADER_LEN {
            return Err(corrupt(format!("{}: shorter than the header", log.path.display())));
        }
        let mut header = [0u8; HEADER_LEN as usize];
        log.read_at(0, &mut header)?;
        if &header[..8] != LOG_MAGIC {
            return Err(corrupt(format!("{}: not a record log (bad magic)", log.path.display())));
        }
        let version = u64_at(&header, 8);
        if version != LOG_VERSION {
            return Err(corrupt(format!(
                "{}: unsupported log version {version} (expected {LOG_VERSION})",
                log.path.display()
            )));
        }
        if log.load_indexed(file_len)? {
            log.stats.fast_path = true;
        } else {
            log.scan(file_len)?;
        }
        Ok(log)
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn stats(&self) -> LogStats {
        self.stats
    }

    pub fn contains(&self, key: &str) -> bool {
        self.by_key.contains_key(key)
    }

    /// Live keys in first-insertion order.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.iter().map(|e| e.key.as_str())
    }

    /// The latest payload stored under `key`, checksum-verified.
    pub fn get(&mut self, key: &str) -> io::Result<Option<String>> {
        let Some(&i) = self.by_key.get(key) else {
            return Ok(None);
        };
        let offset = self.entries[i].offset;
        let frame = self.read_frame(offset)?;
        if frame.key != key {
            return Err(corrupt(format!(
                "{}: index points key '{key}' at a frame holding '{}'",
                self.path.display(),
                frame.key
            )));
        }
        self.stats.reads += 1;
        let payload = String::from_utf8(frame.payload)
            .map_err(|_| corrupt(format!("{}: non-utf8 payload for '{key}'", self.path.display())))?;
        Ok(Some(payload))
    }

    /// Every live (key, payload) pair in first-insertion order.
    pub fn records(&mut self) -> io::Result<Vec<(String, String)>> {
        let offsets: Vec<u64> = self.entries.iter().map(|e| e.offset).collect();
        let mut out = Vec::with_capacity(offsets.len());
        for offset in offsets {
            let frame = self.read_frame(offset)?;
            self.stats.reads += 1;
            let payload = String::from_utf8(frame.payload)
                .map_err(|_| corrupt(format!("{}: non-utf8 payload", self.path.display())))?;
            out.push((frame.key, payload));
        }
        Ok(out)
    }

    /// Append (or replace) `key` -> `payload`. The write lands in the
    /// OS immediately; durability comes from [`RecordLog::commit`] /
    /// [`RecordLog::seal`].
    pub fn append(&mut self, key: &str, payload: &str) -> io::Result<()> {
        if self.indexed {
            // Drop the stale tail index + trailer; records stay put.
            self.file.set_len(self.data_end)?;
            self.indexed = false;
        }
        let frame = encode_frame(KIND_RECORD, key.as_bytes(), payload.as_bytes());
        self.file.seek(SeekFrom::Start(self.data_end))?;
        self.file.write_all(&frame)?;
        self.remember(key.to_string(), self.data_end);
        self.data_end += frame.len() as u64;
        self.dirty = true;
        self.stats.appends += 1;
        Ok(())
    }

    /// fsync the file: every appended record is durable afterwards.
    pub fn commit(&mut self) -> io::Result<()> {
        self.file.sync_all()
    }

    /// Write the index frame + trailer and fsync. The next open takes
    /// the no-scan fast path. Idempotent on an already-sealed file.
    pub fn seal(&mut self) -> io::Result<()> {
        if self.indexed {
            return self.commit();
        }
        let payload = self.index_payload();
        let frame = encode_frame(KIND_INDEX, b"", &payload);
        self.file.seek(SeekFrom::Start(self.data_end))?;
        self.file.write_all(&frame)?;
        let mut trailer = Vec::with_capacity(TRAILER_LEN as usize);
        trailer.extend_from_slice(&self.data_end.to_le_bytes());
        trailer.extend_from_slice(TRAILER_MAGIC);
        self.file.write_all(&trailer)?;
        self.file.sync_all()?;
        self.indexed = true;
        self.dirty = false;
        Ok(())
    }

    /// Rewrite only the live frames (dropping superseded record
    /// versions) into a fresh sealed file, then atomically rename it
    /// over this one.
    pub fn compact(&mut self) -> io::Result<()> {
        let records = self.records()?;
        let tmp = self.path.with_extension("tdstore.tmp");
        let mut entries = Vec::with_capacity(records.len());
        let mut data_end = HEADER_LEN;
        {
            let mut f = File::create(&tmp)?;
            let mut header = Vec::with_capacity(HEADER_LEN as usize);
            header.extend_from_slice(LOG_MAGIC);
            header.extend_from_slice(&LOG_VERSION.to_le_bytes());
            f.write_all(&header)?;
            for (key, payload) in &records {
                let frame = encode_frame(KIND_RECORD, key.as_bytes(), payload.as_bytes());
                f.write_all(&frame)?;
                entries.push(Entry { key: key.clone(), offset: data_end });
                data_end += frame.len() as u64;
            }
            let mut payload = Vec::new();
            payload.extend_from_slice(&(entries.len() as u64).to_le_bytes());
            for e in &entries {
                payload.extend_from_slice(&fnv1a64(e.key.as_bytes()).to_le_bytes());
                payload.extend_from_slice(&e.offset.to_le_bytes());
                payload.extend_from_slice(&(e.key.len() as u32).to_le_bytes());
                payload.extend_from_slice(e.key.as_bytes());
            }
            let frame = encode_frame(KIND_INDEX, b"", &payload);
            f.write_all(&frame)?;
            let mut trailer = Vec::with_capacity(TRAILER_LEN as usize);
            trailer.extend_from_slice(&data_end.to_le_bytes());
            trailer.extend_from_slice(TRAILER_MAGIC);
            f.write_all(&trailer)?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, &self.path)?;
        self.file = OpenOptions::new().read(true).write(true).open(&self.path)?;
        self.by_key = entries
            .iter()
            .enumerate()
            .map(|(i, e)| (e.key.clone(), i))
            .collect();
        self.entries = entries;
        self.data_end = data_end;
        self.indexed = true;
        self.dirty = false;
        Ok(())
    }

    // -- internals ----------------------------------------------------

    fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> io::Result<()> {
        self.file.seek(SeekFrom::Start(offset))?;
        self.file.read_exact(buf)
    }

    /// Read and validate the frame starting at `offset`.
    fn read_frame(&mut self, offset: u64) -> io::Result<Frame> {
        let mut len_buf = [0u8; 4];
        self.read_at(offset, &mut len_buf)?;
        let body_len = u32::from_le_bytes(len_buf);
        if !(MIN_BODY..=MAX_BODY).contains(&body_len) {
            return Err(corrupt(format!(
                "{}: bad frame length {body_len} at offset {offset}",
                self.path.display()
            )));
        }
        let mut body = vec![0u8; body_len as usize];
        self.read_at(offset + 4, &mut body)?;
        decode_body(&body).ok_or_else(|| {
            corrupt(format!("{}: corrupt frame at offset {offset}", self.path.display()))
        })
    }

    fn remember(&mut self, key: String, offset: u64) {
        match self.by_key.get(&key) {
            // Last-wins value, first-insertion position.
            Some(&i) => self.entries[i].offset = offset,
            None => {
                self.entries.push(Entry { key: key.clone(), offset });
                self.by_key.insert(key, self.entries.len() - 1);
            }
        }
    }

    fn index_payload(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&(self.entries.len() as u64).to_le_bytes());
        for e in &self.entries {
            out.extend_from_slice(&fnv1a64(e.key.as_bytes()).to_le_bytes());
            out.extend_from_slice(&e.offset.to_le_bytes());
            out.extend_from_slice(&(e.key.len() as u32).to_le_bytes());
            out.extend_from_slice(e.key.as_bytes());
        }
        out
    }

    /// Try the trailer fast path. `Ok(false)` means "no valid sealed
    /// index — fall back to scanning"; hard IO errors propagate.
    fn load_indexed(&mut self, file_len: u64) -> io::Result<bool> {
        if file_len < HEADER_LEN + TRAILER_LEN {
            return Ok(false);
        }
        let mut trailer = [0u8; TRAILER_LEN as usize];
        self.read_at(file_len - TRAILER_LEN, &mut trailer)?;
        if &trailer[8..] != TRAILER_MAGIC {
            return Ok(false);
        }
        let idx_off = u64_at(&trailer, 0);
        // Bound idx_off before any arithmetic on it: the trailer bytes
        // are untrusted disk content.
        if idx_off < HEADER_LEN || idx_off > file_len - TRAILER_LEN - 4 {
            return Ok(false);
        }
        let mut len_buf = [0u8; 4];
        self.read_at(idx_off, &mut len_buf)?;
        let body_len = u32::from_le_bytes(len_buf);
        if !(MIN_BODY..=MAX_BODY).contains(&body_len)
            || idx_off + 4 + body_len as u64 != file_len - TRAILER_LEN
        {
            return Ok(false);
        }
        let mut body = vec![0u8; body_len as usize];
        self.read_at(idx_off + 4, &mut body)?;
        let Some(frame) = decode_body(&body) else {
            return Ok(false);
        };
        if frame.kind != KIND_INDEX || !frame.key.is_empty() {
            return Ok(false);
        }
        // Parse the entry table.
        let p = &frame.payload;
        if p.len() < 8 {
            return Ok(false);
        }
        let count = u64_at(p, 0) as usize;
        let mut pos = 8usize;
        let mut entries = Vec::with_capacity(count);
        let mut by_key = HashMap::with_capacity(count);
        for _ in 0..count {
            if pos + 20 > p.len() {
                return Ok(false);
            }
            let key_hash = u64_at(p, pos);
            let offset = u64_at(p, pos + 8);
            let key_len = u32_at(p, pos + 16) as usize;
            pos += 20;
            if pos + key_len > p.len() || offset < HEADER_LEN || offset >= idx_off {
                return Ok(false);
            }
            let Ok(key) = std::str::from_utf8(&p[pos..pos + key_len]) else {
                return Ok(false);
            };
            pos += key_len;
            if fnv1a64(key.as_bytes()) != key_hash
                || by_key.insert(key.to_string(), entries.len()).is_some()
            {
                return Ok(false);
            }
            entries.push(Entry { key: key.to_string(), offset });
        }
        if pos != p.len() {
            return Ok(false);
        }
        self.entries = entries;
        self.by_key = by_key;
        self.data_end = idx_off;
        self.indexed = true;
        Ok(true)
    }

    /// Scanning open: walk frames from the header, index records, skip
    /// stale index frames, and truncate at the first torn/corrupt
    /// frame (crash recovery).
    fn scan(&mut self, file_len: u64) -> io::Result<()> {
        let mut off = HEADER_LEN;
        while off < file_len {
            let good = self.scan_frame(off, file_len)?;
            match good {
                Some(next) => off = next,
                None => {
                    self.file.set_len(off)?;
                    self.file.sync_all()?;
                    self.stats.truncated_bytes += file_len - off;
                    break;
                }
            }
        }
        self.data_end = off;
        Ok(())
    }

    /// Validate the frame at `off`; `Ok(Some(next_offset))` on success,
    /// `Ok(None)` when the tail from `off` must be truncated.
    fn scan_frame(&mut self, off: u64, file_len: u64) -> io::Result<Option<u64>> {
        if off + 4 > file_len {
            return Ok(None);
        }
        let mut len_buf = [0u8; 4];
        self.read_at(off, &mut len_buf)?;
        let body_len = u32::from_le_bytes(len_buf);
        if !(MIN_BODY..=MAX_BODY).contains(&body_len) || off + 4 + body_len as u64 > file_len {
            return Ok(None);
        }
        let mut body = vec![0u8; body_len as usize];
        self.read_at(off + 4, &mut body)?;
        let Some(frame) = decode_body(&body) else {
            return Ok(None);
        };
        if frame.kind == KIND_RECORD {
            self.remember(frame.key, off);
        }
        // KIND_INDEX frames found mid-scan are stale; records win.
        self.stats.frames_scanned += 1;
        Ok(Some(off + 4 + body_len as u64))
    }
}

impl Drop for RecordLog {
    fn drop(&mut self) {
        // Best-effort seal so the next open takes the fast path; a
        // failed seal just means that open scans instead.
        if self.dirty {
            let _ = self.seal();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_log(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("td_log_{tag}_{}.tdstore", std::process::id()))
    }

    #[test]
    fn append_get_and_last_wins_update() {
        let path = temp_log("basic");
        let _ = std::fs::remove_file(&path);
        let mut log = RecordLog::open(&path).unwrap();
        assert!(log.is_empty());
        log.append("a", "1").unwrap();
        log.append("b", "2").unwrap();
        log.append("a", "3").unwrap();
        assert_eq!(log.len(), 2);
        assert_eq!(log.get("a").unwrap().as_deref(), Some("3"));
        assert_eq!(log.get("b").unwrap().as_deref(), Some("2"));
        assert_eq!(log.get("missing").unwrap(), None);
        // First-insertion iteration order survives the update.
        assert_eq!(log.keys().collect::<Vec<_>>(), vec!["a", "b"]);
        drop(log);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn sealed_reopen_takes_the_fast_path_and_append_unseals() {
        let path = temp_log("seal");
        let _ = std::fs::remove_file(&path);
        {
            let mut log = RecordLog::open(&path).unwrap();
            log.append("k1", "v1").unwrap();
            log.append("k2", "v2").unwrap();
            log.seal().unwrap();
        }
        {
            let mut log = RecordLog::open(&path).unwrap();
            assert!(log.stats().fast_path, "sealed file must restore without scanning");
            assert_eq!(log.stats().frames_scanned, 0);
            assert_eq!(log.get("k1").unwrap().as_deref(), Some("v1"));
            // Appending truncates the stale index, then Drop re-seals.
            log.append("k3", "v3").unwrap();
        }
        let mut log = RecordLog::open(&path).unwrap();
        assert!(log.stats().fast_path, "drop must have re-sealed");
        assert_eq!(log.len(), 3);
        assert_eq!(log.get("k3").unwrap().as_deref(), Some("v3"));
        drop(log);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_tail_truncates_back_to_the_last_good_frame() {
        let path = temp_log("torn");
        let _ = std::fs::remove_file(&path);
        let len2;
        {
            let mut log = RecordLog::open(&path).unwrap();
            log.append("k1", "payload one").unwrap();
            log.append("k2", "payload two").unwrap();
            log.commit().unwrap();
            len2 = std::fs::metadata(&path).unwrap().len();
            log.append("k3", "payload three").unwrap();
        }
        // Tear the file mid-way through k3's frame (the Drop-seal is
        // cut off with it).
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len2 + 7).unwrap();
        drop(f);
        let mut log = RecordLog::open(&path).unwrap();
        assert!(!log.stats().fast_path);
        assert_eq!(log.stats().truncated_bytes, 7);
        assert_eq!(log.len(), 2, "intact prefix survives, torn tail is dropped");
        assert_eq!(log.get("k1").unwrap().as_deref(), Some("payload one"));
        assert_eq!(log.get("k2").unwrap().as_deref(), Some("payload two"));
        assert_eq!(log.get("k3").unwrap(), None);
        // The log keeps working after recovery.
        log.append("k3", "again").unwrap();
        assert_eq!(log.get("k3").unwrap().as_deref(), Some("again"));
        drop(log);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn flipped_byte_is_detected_and_truncated() {
        let path = temp_log("flip");
        let _ = std::fs::remove_file(&path);
        let (len1, len2);
        {
            let mut log = RecordLog::open(&path).unwrap();
            log.append("k1", "good").unwrap();
            log.commit().unwrap();
            len1 = std::fs::metadata(&path).unwrap().len();
            log.append("k2", "to be corrupted").unwrap();
            log.commit().unwrap();
            len2 = std::fs::metadata(&path).unwrap().len();
        }
        // Chop the Drop-seal's index + trailer (a sealed index trusts
        // its entries without re-reading frames; corruption under it is
        // caught at `get` time, not open time), then flip a payload
        // byte inside k2's frame.
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.truncate(len2 as usize);
        bytes[len1 as usize + 20] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let mut log = RecordLog::open(&path).unwrap();
        assert_eq!(log.len(), 1, "checksum failure truncates from the bad frame");
        assert_eq!(log.stats().truncated_bytes, len2 - len1);
        assert_eq!(log.get("k1").unwrap().as_deref(), Some("good"));
        assert_eq!(log.get("k2").unwrap(), None);
        drop(log);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn compact_drops_superseded_versions_and_stays_readable() {
        let path = temp_log("compact");
        let _ = std::fs::remove_file(&path);
        let mut log = RecordLog::open(&path).unwrap();
        for i in 0..4 {
            log.append("hot", &format!("version {i}")).unwrap();
        }
        log.append("cold", "stable").unwrap();
        log.seal().unwrap();
        let before = std::fs::metadata(&path).unwrap().len();
        log.compact().unwrap();
        let after = std::fs::metadata(&path).unwrap().len();
        assert!(after < before, "compaction must drop dead frames ({before} -> {after})");
        assert_eq!(log.get("hot").unwrap().as_deref(), Some("version 3"));
        assert_eq!(log.get("cold").unwrap().as_deref(), Some("stable"));
        drop(log);
        let mut log = RecordLog::open(&path).unwrap();
        assert!(log.stats().fast_path, "compacted file is sealed");
        assert_eq!(log.records().unwrap().len(), 2);
        drop(log);
        let _ = std::fs::remove_file(&path);
    }
}
