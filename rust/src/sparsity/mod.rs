//! First-class sparsity regimes.
//!
//! Every synthetic workload carries a [`Regime`] describing *how* its
//! operand tensors are sparse, as a typed, cache-keyed dimension of the
//! request (DESIGN.md §Sparsity-regimes):
//!
//! * [`Regime::Uniform`] — the original behaviour: the model profile's
//!   own clustered bitmaps at the requested epoch, untouched. Requests
//!   that never mention a regime get this and stay byte-identical to
//!   every release before the regime existed.
//! * [`Regime::NM`] — N:M structured sparsity (Procrustes, arXiv
//!   2009.10976): on top of the profile bitmaps, a deterministic
//!   keep-mask forces all but `n` positions in every `m`-wide channel
//!   block to zero, per (sample, y, x) site — the block shape hardware
//!   sparsity formats (2:4 et al.) prescribe.
//! * [`Regime::Schedule`] — time-varying sparsity (arXiv 2109.07710):
//!   a reusable [`Curve`] evaluated at the request's epoch fraction
//!   replaces the model's own hard-coded trajectory. This generalises
//!   the fig-14 sparsity-over-time machinery: the built-in model
//!   curves *are* `Curve` values now, so scheduling a model onto its
//!   own curve is bit-identical to `Uniform`.
//!
//! Determinism contract: bitmap generation under any regime is a pure
//! function of `(model, layer, epoch, seed, regime)` — mask RNG streams
//! are seeded per unit from those inputs alone, never from thread or
//! arrival order, so reports stay byte-identical at any `--jobs` and
//! any `--shards`, warm or cold.

use crate::tensor::TensorBitmap;
use crate::util::rng::Rng;

/// How sparsity evolves over training (the Fig. 14 families plus
/// free-form piecewise-linear profiles). The multiplier scales a
/// tensor's base sparsity; epoch fractions live in `[0, 1]`.
#[derive(Debug, Clone, PartialEq)]
pub enum Curve {
    /// Dense models: low at random init, rapid rise over the first
    /// epochs, stable mid-training, mild decline entering the second
    /// half, stable finish — the paper's inverted-U.
    DenseU { swing: f64 },
    /// Pruning-during-training (DS90/SM90): aggressive early pruning
    /// that training then partially "reclaims".
    PrunedReclaim { start_boost: f64 },
    /// No meaningful evolution (GCN).
    Flat,
    /// Free-form piecewise-linear profile over `(epoch, factor)` knots
    /// sorted by epoch; clamped to the end values outside the knots.
    Piecewise { points: Vec<(f64, f64)> },
}

impl Curve {
    /// Multiplier on the base *sparsity* at epoch fraction `e` in `[0, 1]`.
    pub fn factor(&self, e: f64) -> f64 {
        match self {
            Curve::DenseU { swing } => {
                // rise to plateau by e=0.15 from (1 - swing), dip after
                // e=0.5 by swing/2, restabilise by e=0.75.
                let rise = (e / 0.15).min(1.0);
                let dip = ((e - 0.45) / 0.3).clamp(0.0, 1.0);
                1.0 - swing * (1.0 - rise) - (swing * 0.45) * dip
            }
            Curve::PrunedReclaim { start_boost } => {
                // settle from (1 + boost) to 1.0 within the first 5%.
                let settle = (e / 0.05).min(1.0);
                1.0 + start_boost * (1.0 - settle)
            }
            Curve::Flat => 1.0,
            Curve::Piecewise { points } => {
                if points.is_empty() {
                    return 1.0;
                }
                if e <= points[0].0 {
                    return points[0].1;
                }
                for pair in points.windows(2) {
                    let ((e0, f0), (e1, f1)) = (pair[0], pair[1]);
                    if e <= e1 {
                        if e1 <= e0 {
                            return f1;
                        }
                        let t = (e - e0) / (e1 - e0);
                        return f0 + (f1 - f0) * t;
                    }
                }
                points[points.len() - 1].1
            }
        }
    }

    /// The canonical spelling accepted back by [`Regime::parse`].
    pub fn render(&self) -> String {
        match self {
            Curve::DenseU { swing } => format!("dense-u:{swing}"),
            Curve::PrunedReclaim { start_boost } => format!("pruned-reclaim:{start_boost}"),
            Curve::Flat => "flat".to_string(),
            Curve::Piecewise { points } => {
                let knots: Vec<String> =
                    points.iter().map(|(e, f)| format!("{e}@{f}")).collect();
                format!("piecewise:{}", knots.join(","))
            }
        }
    }

    fn parse(s: &str) -> Result<Curve, String> {
        let bad = || {
            "must name a schedule curve: flat, dense-u:<swing>, \
             pruned-reclaim:<boost> or piecewise:<e@f,...>"
                .to_string()
        };
        if s == "flat" {
            return Ok(Curve::Flat);
        }
        if let Some(v) = s.strip_prefix("dense-u:") {
            let swing: f64 = v.parse().map_err(|_| bad())?;
            return Ok(Curve::DenseU { swing });
        }
        if let Some(v) = s.strip_prefix("pruned-reclaim:") {
            let start_boost: f64 = v.parse().map_err(|_| bad())?;
            return Ok(Curve::PrunedReclaim { start_boost });
        }
        if let Some(v) = s.strip_prefix("piecewise:") {
            let mut points = Vec::new();
            for knot in v.split(',') {
                let (e, f) = knot
                    .split_once('@')
                    .ok_or_else(|| "piecewise wants knots 'e@f' with e in [0, 1]".to_string())?;
                let e: f64 = e
                    .parse()
                    .map_err(|_| "piecewise wants knots 'e@f' with e in [0, 1]".to_string())?;
                let f: f64 = f
                    .parse()
                    .map_err(|_| "piecewise wants knots 'e@f' with e in [0, 1]".to_string())?;
                if !(0.0..=1.0).contains(&e) {
                    return Err("piecewise wants knots 'e@f' with e in [0, 1]".to_string());
                }
                points.push((e, f));
            }
            if points.windows(2).any(|p| p[1].0 < p[0].0) {
                return Err("piecewise knots must be sorted by epoch".to_string());
            }
            return Ok(Curve::Piecewise { points });
        }
        Err(bad())
    }
}

/// Which axis the N:M blocks run along. Only the 16-lane channel axis
/// exists today (the axis the PE reduces over), but the key encoding
/// reserves the byte.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MaskAxis {
    Channel,
}

impl MaskAxis {
    pub fn as_str(&self) -> &'static str {
        match self {
            MaskAxis::Channel => "channel",
        }
    }
}

/// The sparsity regime of a synthetic workload. See the module docs for
/// the semantics of each variant.
#[derive(Debug, Clone, PartialEq)]
pub enum Regime {
    Uniform,
    NM { n: usize, m: usize, axis: MaskAxis },
    Schedule { curve: Curve },
}

impl Regime {
    /// Parse the shared CLI/serve spelling: `uniform`, `nm:N:M`, or
    /// `schedule:<curve>`. Error strings are predicates (no subject) so
    /// `api::params` can prefix whichever spelling — `--regime` or
    /// `'regime'` — the request used.
    pub fn parse(s: &str) -> Result<Regime, String> {
        if s == "uniform" {
            return Ok(Regime::Uniform);
        }
        if let Some(v) = s.strip_prefix("nm:") {
            let (n, m) = v
                .split_once(':')
                .ok_or_else(|| "nm wants positive integers n:m".to_string())?;
            let n: usize = n.parse().map_err(|_| "nm wants positive integers n:m".to_string())?;
            let m: usize = m.parse().map_err(|_| "nm wants positive integers n:m".to_string())?;
            if n == 0 || m == 0 {
                return Err("nm wants positive integers n:m".to_string());
            }
            if n > m {
                return Err("nm requires n <= m".to_string());
            }
            if m > 16 {
                return Err("nm block size m must be <= 16".to_string());
            }
            return Ok(Regime::NM { n, m, axis: MaskAxis::Channel });
        }
        if let Some(v) = s.strip_prefix("schedule:") {
            return Ok(Regime::Schedule { curve: Curve::parse(v)? });
        }
        Err("must be 'uniform', 'nm:N:M' or 'schedule:<curve>'".to_string())
    }

    /// The canonical spelling; `parse(render()) == self`.
    pub fn render(&self) -> String {
        match self {
            Regime::Uniform => "uniform".to_string(),
            Regime::NM { n, m, .. } => format!("nm:{n}:{m}"),
            Regime::Schedule { curve } => format!("schedule:{}", curve.render()),
        }
    }

    /// `(spelling, bounds)` rows for the `info` subcommand.
    pub fn help() -> &'static [(&'static str, &'static str)] {
        &[
            ("uniform", "the model profile's own clustered bitmaps (default)"),
            ("nm:N:M", "N:M structured channel mask, 1 <= N <= M <= 16"),
            ("schedule:flat", "no sparsity evolution over epochs"),
            ("schedule:dense-u:<swing>", "inverted-U trajectory, swing in [0, 1]"),
            ("schedule:pruned-reclaim:<boost>", "early boost settling to 1.0, boost in [0, 1]"),
            ("schedule:piecewise:<e@f,...>", "piecewise-linear knots, epochs sorted in [0, 1]"),
        ]
    }
}

/// Domain constant separating N:M mask RNG streams from every other
/// consumer of the same request seed.
const NM_MASK_DOMAIN: u64 = 0x6E4D_6D61_736B_2E31; // "nMmask.1"

/// Seed of the N:M mask stream for one tensor of one unit: a pure
/// function of the request's bitmap seed, the layer and which tensor
/// (0 = A, 1 = G), so mask generation is `--jobs`-independent.
pub fn nm_mask_seed(bitmap_seed: u64, layer: u64, tensor: u64) -> u64 {
    bitmap_seed
        ^ NM_MASK_DOMAIN
        ^ layer.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ tensor.wrapping_mul(0xD1B5_4A32_D192_ED03)
}

/// Deterministic N:M keep-mask over the channel axis: for every
/// (sample, y, x) site and every `m`-wide channel block, exactly
/// `min(n, block_len)` positions are kept (chosen uniformly by the
/// seeded RNG); all others read as zero.
pub fn nm_mask(dims: (usize, usize, usize, usize), n: usize, m: usize, seed: u64) -> TensorBitmap {
    let (nn, h, w, c) = dims;
    assert!(n >= 1 && n <= m && m <= 16, "N:M out of range: {n}:{m}");
    assert_eq!(c % 16, 0);
    let mut rng = Rng::new(seed);
    let cb = c / 16;
    let mut words = Vec::with_capacity(nn * h * w * cb);
    let mut lanes = vec![false; c];
    for _site in 0..nn * h * w {
        lanes.iter_mut().for_each(|b| *b = false);
        let mut c0 = 0;
        while c0 < c {
            let block = m.min(c - c0);
            for k in rng.sample_indices(block, n.min(block)) {
                lanes[c0 + k] = true;
            }
            c0 += block;
        }
        for b in 0..cb {
            let mut word = 0u16;
            for l in 0..16 {
                word |= u16::from(lanes[b * 16 + l]) << l;
            }
            words.push(word);
        }
    }
    TensorBitmap::from_raw(dims, words)
}

/// AND an N:M keep-mask into a generated bitmap: the result carries the
/// bitmap's zeros *plus* the structured zeros the mask forces.
pub fn apply_nm(bm: &TensorBitmap, n: usize, m: usize, seed: u64) -> TensorBitmap {
    let dims = (bm.n, bm.h, bm.w, bm.c);
    let mask = nm_mask(dims, n, m, seed);
    let words = bm
        .words()
        .iter()
        .zip(mask.words())
        .map(|(a, b)| a & b)
        .collect();
    TensorBitmap::from_raw(dims, words)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regime_spellings_round_trip() {
        for s in [
            "uniform",
            "nm:2:4",
            "nm:1:16",
            "schedule:flat",
            "schedule:dense-u:0.3",
            "schedule:pruned-reclaim:0.22",
            "schedule:piecewise:0@1,0.5@0.6,1@0.8",
        ] {
            let r = Regime::parse(s).unwrap();
            assert_eq!(r.render(), s, "round trip of {s}");
            assert_eq!(Regime::parse(&r.render()).unwrap(), r);
        }
    }

    #[test]
    fn regime_parse_rejects_bad_spellings() {
        assert_eq!(
            Regime::parse("nm:4:2").unwrap_err(),
            "nm requires n <= m"
        );
        assert_eq!(
            Regime::parse("nm:2:32").unwrap_err(),
            "nm block size m must be <= 16"
        );
        assert_eq!(
            Regime::parse("nm:0:4").unwrap_err(),
            "nm wants positive integers n:m"
        );
        assert!(Regime::parse("banded").unwrap_err().starts_with("must be 'uniform'"));
        assert!(Regime::parse("schedule:banded").unwrap_err().contains("schedule curve"));
        assert_eq!(
            Regime::parse("schedule:piecewise:0.5@1,0.2@1").unwrap_err(),
            "piecewise knots must be sorted by epoch"
        );
    }

    #[test]
    fn piecewise_interpolates_and_clamps() {
        let c = Curve::Piecewise { points: vec![(0.2, 1.0), (0.6, 0.5)] };
        assert_eq!(c.factor(0.0), 1.0); // clamp low
        assert_eq!(c.factor(0.2), 1.0);
        assert!((c.factor(0.4) - 0.75).abs() < 1e-12);
        assert_eq!(c.factor(0.6), 0.5);
        assert_eq!(c.factor(1.0), 0.5); // clamp high
        assert_eq!(Curve::Piecewise { points: vec![] }.factor(0.3), 1.0);
    }

    #[test]
    fn nm_mask_keeps_exactly_n_per_block() {
        let (n, m) = (2, 4);
        let mask = nm_mask((2, 3, 3, 32), n, m, 7);
        for s in 0..2 {
            for y in 0..3 {
                for x in 0..3 {
                    for c0 in (0..32).step_by(m) {
                        let kept: usize =
                            (c0..c0 + m).map(|c| mask.bit(s, y, x, c) as usize).sum();
                        assert_eq!(kept, n, "site ({s},{y},{x}) block {c0}");
                    }
                }
            }
        }
        // Exact density accounting: n/m of all positions are kept.
        assert_eq!(mask.nonzeros(), mask.values() * n as u64 / m as u64);
    }

    #[test]
    fn nm_mask_is_seed_deterministic() {
        let a = nm_mask((1, 4, 4, 64), 2, 4, 42);
        let b = nm_mask((1, 4, 4, 64), 2, 4, 42);
        assert_eq!(a, b);
        let c = nm_mask((1, 4, 4, 64), 2, 4, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn apply_nm_only_clears_bits() {
        let mut rng = Rng::new(5);
        let bm = crate::trace::synthetic::random_bitmap((1, 4, 4, 32), 0.3, &mut rng);
        let masked = apply_nm(&bm, 2, 4, 11);
        for (a, b) in bm.words().iter().zip(masked.words()) {
            assert_eq!(a & b, *b, "mask set a bit the source lacked");
        }
        assert!(masked.nonzeros() <= bm.nonzeros());
    }
}
