//! # TensorDash — reproduction of Mahmoud et al., MICRO 2020
//!
//! A three-layer Rust + JAX + Pallas reproduction of *TensorDash:
//! Exploiting Sparsity to Accelerate Deep Neural Network Training and
//! Inference*.
//!
//! * **Layer 3 (this crate)** — the paper's hardware contribution as a
//!   cycle-accurate model: the sparse operand interconnect
//!   ([`sim::Connectivity`]), the hierarchical hardware scheduler
//!   ([`sim::scheduler`]), processing elements, tiles and the full chip
//!   ([`sim::chip`]); plus every substrate the evaluation depends on:
//!   tensor layout/transposers ([`tensor`]), the three training
//!   convolutions lowered to MAC streams ([`conv`]), an area/power/energy
//!   model ([`energy`]), sparsity-trace capture and synthetic profiles
//!   ([`trace`], [`models`]) and the PJRT runtime + training coordinator
//!   ([`runtime`], [`coordinator`]) that drive a *real* training loop
//!   through the AOT-compiled JAX/Pallas artifacts.
//! * **Layer 2** — `python/compile/model.py`: the training step written as
//!   the paper's Eq. (4)–(9), AOT-lowered once to HLO text.
//! * **Layer 1** — `python/compile/kernels/`: Pallas kernels with 16-wide
//!   reduction lanes mirroring the PE.
//!
//! Python never runs on the request path: the rust binary loads
//! `artifacts/*.hlo.txt` through the PJRT C API and is self-contained.
//!
//! ## The experiment pipeline
//!
//! All evaluation flows through the typed [`api`] layer:
//! `SimRequest`/`SweepSpec` (what to run) → `ModelPlan` (the request's
//! deterministic parallel unit graph, one unit per layer × training op)
//! → `Engine` (a deterministic `--jobs N` worker pool over the
//! flattened cell×unit list) → `Report` (data first; text/JSON/CSV are
//! renderers). The [`repro`] figure drivers, the CLI subcommands, the
//! `benches/` drivers and the `examples/` all build on it, so a figure
//! regenerates identically — and machine-readably — from every entry
//! point. See DESIGN.md §Experiment-index and the [`api`] module docs.

// Clippy runs in CI with `-D warnings`. Two style lints are opted out
// crate-wide rather than per site: the simulator's constructors
// legitimately take many scalar hardware knobs, and several loops
// mirror the hardware's lane/row/cell indexing too closely for
// iterator rewrites to stay readable.
#![allow(clippy::too_many_arguments, clippy::needless_range_loop)]

pub mod api;
pub mod config;
pub mod conv;
pub mod coordinator;
pub mod energy;
pub mod metrics;
pub mod models;
pub mod repro;
pub mod runtime;
pub mod search;
pub mod sim;
pub mod sparsity;
pub mod store;
pub mod tensor;
pub mod trace;
pub mod util;
