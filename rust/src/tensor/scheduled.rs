//! The scheduled `(value, idx)` compressed tensor form (paper §3.6).
//!
//! TensorDash's scheduler doubles as a compression engine: a tensor can
//! be stored as the *schedule* of its non-zero values — per packed row,
//! each lane holds a value plus the 3-bit movement (`MS`) it performed,
//! and the row records the 2-bit `AS` advance. Decompression (Fig. 12)
//! is the mirror of the multiplexer stage: each `(value, idx)` pair is
//! scattered back to the dense slot its movement came from.
//!
//! One-side scheduling only (the stored `idx` must be interpretable
//! without the second operand), exactly as §3.6/§3.7 describe for the
//! back-side scheduler.
//!
//! The window/refill loop is [`crate::sim::stream::drive`] — shared
//! with the PE simulator — with a sink that gathers the moved values;
//! runs of all-zero rows become arithmetically-emitted all-skip rows.

use crate::sim::connectivity::{Connectivity, LANES};
use crate::sim::scheduler::IDLE;
use crate::sim::stream::{drive, CachedScheduler, StreamEvent};

/// One packed row of the scheduled form.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScheduledRow {
    /// Per-lane value (meaningful only where `idx != IDLE`).
    pub values: [f32; LANES],
    /// Per-lane 3-bit movement select, or [`IDLE`].
    pub idx: [u8; LANES],
    /// The row's `AS`: how many dense rows the window advanced after
    /// this packed row (1..=depth).
    pub advance: u8,
}

impl ScheduledRow {
    /// An all-skip row: no values, the window advanced `advance` rows.
    fn skip(advance: u8) -> ScheduledRow {
        ScheduledRow { values: [0.0; LANES], idx: [IDLE; LANES], advance }
    }
}

/// A tensor stream compressed by one-side scheduling.
#[derive(Debug, Clone, PartialEq)]
pub struct ScheduledTensor {
    pub rows: Vec<ScheduledRow>,
    /// Dense row count of the original stream.
    pub dense_rows: usize,
    pub depth: usize,
}

impl ScheduledTensor {
    /// Compression ratio in storage rows (dense / scheduled); > 1 means
    /// the scheduled form is smaller.
    pub fn compression(&self) -> f64 {
        if self.rows.is_empty() {
            return self.dense_rows.max(1) as f64;
        }
        self.dense_rows as f64 / self.rows.len() as f64
    }
}

/// Effectual mask of one dense row (bit `l` set iff lane `l` is
/// non-zero).
fn mask_of(row: &[f32; LANES]) -> u16 {
    let mut m = 0u16;
    for (l, &v) in row.iter().enumerate() {
        if v != 0.0 {
            m |= 1 << l;
        }
    }
    m
}

/// Compress a dense stream of 16-lane rows with one-side scheduling.
pub fn compress_one_side(conn: &Connectivity, dense: &[[f32; LANES]]) -> ScheduledTensor {
    let mut sched = CachedScheduler::new(conn.clone());
    compress_one_side_cached(&mut sched, dense)
}

/// [`compress_one_side`] through a caller-owned [`CachedScheduler`]
/// (amortises the memo table across tensors).
pub fn compress_one_side_cached(
    sched: &mut CachedScheduler,
    dense: &[[f32; LANES]],
) -> ScheduledTensor {
    let depth = sched.depth();
    let conn = sched.connectivity().clone();
    let masks: Vec<u16> = dense.iter().map(mask_of).collect();
    let mut rows: Vec<ScheduledRow> = Vec::new();
    drive(sched, &masks, |ev| match ev {
        StreamEvent::Cycle { pos, sched: s, advance } => {
            let mut out = ScheduledRow {
                values: [0.0; LANES],
                idx: [IDLE; LANES],
                advance: advance as u8,
            };
            for lane in 0..LANES {
                let m = s.ms[lane];
                if m == IDLE {
                    continue;
                }
                let bit = conn.lanes[lane].bits[m as usize] as usize;
                let (step, src_lane) = (bit / LANES, bit % LANES);
                out.values[lane] = dense[pos + step][src_lane];
                out.idx[lane] = m;
            }
            rows.push(out);
        }
        StreamEvent::ZeroRun { cycles, rows: zero_rows } => {
            // A run of all-zero rows stores as all-skip rows: full-depth
            // advances, with the remainder on the last row — exactly the
            // sequence the iterated scheduler would emit.
            for i in 0..cycles {
                let adv = if i + 1 == cycles {
                    zero_rows - (cycles as usize - 1) * depth
                } else {
                    depth
                };
                rows.push(ScheduledRow::skip(adv as u8));
            }
        }
    });
    ScheduledTensor { rows, dense_rows: dense.len(), depth }
}

/// Decompress back to the dense stream (Fig. 12): scatter each packed
/// value to `(window_base + step, src_lane)` where `(step, src_lane)` is
/// the slot its recorded movement reads from.
pub fn decompress(conn: &Connectivity, t: &ScheduledTensor) -> Vec<[f32; LANES]> {
    let mut dense = vec![[0f32; LANES]; t.dense_rows];
    let mut base = 0usize;
    for row in &t.rows {
        for lane in 0..LANES {
            if row.idx[lane] == IDLE {
                continue;
            }
            let bit = conn.lanes[lane].bits[row.idx[lane] as usize] as usize;
            let (step, src_lane) = (bit / LANES, bit % LANES);
            let r = base + step;
            debug_assert!(r < t.dense_rows);
            dense[r][src_lane] = row.values[lane];
        }
        base += row.advance as usize;
    }
    dense
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c3() -> Connectivity {
        Connectivity::new(3)
    }

    fn stream(seed: u64, len: usize, density_pct: u64) -> Vec<[f32; LANES]> {
        let mut s = seed;
        (0..len)
            .map(|_| {
                let mut row = [0f32; LANES];
                for v in row.iter_mut() {
                    s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                    if (s >> 33) % 100 < density_pct {
                        *v = ((s >> 16) & 0xFF) as f32 + 1.0;
                    }
                }
                row
            })
            .collect()
    }

    #[test]
    fn round_trip_exact() {
        let c = c3();
        for (seed, density) in [(1u64, 10u64), (2, 30), (3, 60), (4, 95), (5, 100), (6, 0)] {
            let dense = stream(seed, 50, density);
            let st = compress_one_side(&c, &dense);
            let back = decompress(&c, &st);
            assert_eq!(back, dense, "round trip failed @density {density}");
        }
    }

    #[test]
    fn compression_tracks_sparsity() {
        let c = c3();
        let sparse = compress_one_side(&c, &stream(7, 300, 15));
        let dense = compress_one_side(&c, &stream(8, 300, 95));
        assert!(sparse.compression() > 1.5, "got {}", sparse.compression());
        assert!(dense.compression() <= 1.1);
        // Structural cap: never beyond depth x.
        assert!(sparse.compression() <= 3.0 + 1e-9);
    }

    #[test]
    fn empty_and_all_zero() {
        let c = c3();
        let st = compress_one_side(&c, &[]);
        assert_eq!(st.rows.len(), 0);
        assert_eq!(decompress(&c, &st).len(), 0);
        let zeros = vec![[0f32; LANES]; 30];
        let st = compress_one_side(&c, &zeros);
        assert_eq!(st.rows.len(), 10); // ceil(30/3) all-skip rows
        assert!(st.rows.iter().all(|r| r.advance == 3 && r.idx.iter().all(|&i| i == IDLE)));
        assert_eq!(decompress(&c, &st), zeros);
    }

    #[test]
    fn partial_trailing_zero_run_keeps_advance_sum() {
        let c = c3();
        // 2 dense rows then 5 zeros: the second dense row's advance
        // absorbs two zeros, the remaining run stores as all-skip rows;
        // the advances must still sum to the dense row count.
        let mut dense = stream(11, 2, 100);
        dense.extend(vec![[0f32; LANES]; 5]);
        let st = compress_one_side(&c, &dense);
        let total: usize = st.rows.iter().map(|r| r.advance as usize).sum();
        assert_eq!(total, 7);
        assert_eq!(decompress(&c, &st), dense);
    }

    #[test]
    fn shared_cache_compress_identical_to_fresh() {
        let c = c3();
        let mut sched = CachedScheduler::new(c.clone());
        for (seed, density) in [(21u64, 20u64), (22, 50), (21, 20)] {
            let dense = stream(seed, 60, density);
            let fresh = compress_one_side(&c, &dense);
            let warm = compress_one_side_cached(&mut sched, &dense);
            assert_eq!(warm, fresh, "cache state must never change the schedule");
        }
    }

    #[test]
    fn depth2_round_trip() {
        let c = Connectivity::new(2);
        let dense = stream(9, 40, 40);
        let st = compress_one_side(&c, &dense);
        assert_eq!(decompress(&c, &st), dense);
        assert!(st.compression() <= 2.0 + 1e-9);
    }

    #[test]
    fn advances_sum_to_dense_rows() {
        let c = c3();
        let dense = stream(10, 64, 50);
        let st = compress_one_side(&c, &dense);
        let total: usize = st.rows.iter().map(|r| r.advance as usize).sum();
        assert_eq!(total, 64);
    }
}
