//! The 16x16 tensor-group memory layout (paper §3.4).
//!
//! Values are stored in groups of 16x16: 16 consecutive blocks along the
//! row (x) dimension, each block 16 contiguous channel values, starting
//! coordinates aligned by 16 in both dimensions; groups are laid out in
//! channel, column, row order. A group can be written straight to 16
//! banks (one block per bank), letting a PE fetch any 16-channel block in
//! one access — and letting a transposer serve the *transposed* view (16
//! values with the same channel across 16 row positions) that the
//! backward-pass operand orders need.

/// Layout geometry of one 2-D slice (fixed sample) of an NHWC tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GroupLayout {
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

impl GroupLayout {
    pub fn new(h: usize, w: usize, c: usize) -> Self {
        assert_eq!(c % 16, 0, "channels must be a multiple of 16");
        GroupLayout { h, w, c }
    }

    /// Number of 16x16 groups per sample (edges padded up).
    pub fn groups(&self) -> usize {
        self.h * self.w.div_ceil(16) * (self.c / 16)
    }

    /// Group index and within-group (block, lane) of element `(y, x, c)`.
    /// Groups are ordered channel-block fastest, then column group, then
    /// row — the §3.4 "channel, column, row order".
    pub fn locate(&self, y: usize, x: usize, c: usize) -> (usize, usize, usize) {
        assert!(y < self.h && x < self.w && c < self.c);
        let xg = x / 16;
        let cb = c / 16;
        let group = (y * self.w.div_ceil(16) + xg) * (self.c / 16) + cb;
        (group, x % 16, c % 16)
    }

    /// Gather one 16x16 group from a dense HWC slice (edge blocks are
    /// zero padded). `group` is row-major `[block][lane]` = `[x][c]`.
    pub fn gather_group(&self, data: &[f32], y: usize, xg: usize, cb: usize) -> [[f32; 16]; 16] {
        assert_eq!(data.len(), self.h * self.w * self.c);
        let mut out = [[0f32; 16]; 16];
        for (bx, row) in out.iter_mut().enumerate() {
            let x = xg * 16 + bx;
            if x >= self.w {
                continue;
            }
            for (l, v) in row.iter_mut().enumerate() {
                *v = data[(y * self.w + x) * self.c + cb * 16 + l];
            }
        }
        out
    }
}

/// Transpose a 16x16 group in place semantics: the transposer's internal
/// buffer is filled block-wise and drained value-wise (§3.4).
pub fn transpose_group(g: &[[f32; 16]; 16]) -> [[f32; 16]; 16] {
    let mut out = [[0f32; 16]; 16];
    for i in 0..16 {
        for j in 0..16 {
            out[j][i] = g[i][j];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn locate_ordering() {
        let l = GroupLayout::new(4, 32, 32);
        // channel-block fastest:
        assert_eq!(l.locate(0, 0, 0).0, 0);
        assert_eq!(l.locate(0, 0, 16).0, 1);
        // then column group:
        assert_eq!(l.locate(0, 16, 0).0, 2);
        // then row:
        assert_eq!(l.locate(1, 0, 0).0, 4);
        // within group: block = x % 16, lane = c % 16.
        assert_eq!(l.locate(2, 17, 21), ((2 * 2 + 1) * 2 + 1, 1, 5));
    }

    #[test]
    fn groups_count_pads_edges() {
        let l = GroupLayout::new(7, 7, 32);
        assert_eq!(l.groups(), 7 * 1 * 2);
    }

    #[test]
    fn transpose_round_trip() {
        let mut g = [[0f32; 16]; 16];
        for (i, row) in g.iter_mut().enumerate() {
            for (j, v) in row.iter_mut().enumerate() {
                *v = (i * 16 + j) as f32;
            }
        }
        let t = transpose_group(&g);
        assert_eq!(t[3][5], g[5][3]);
        assert_eq!(transpose_group(&t), g);
    }

    #[test]
    fn gather_group_zero_pads_edge() {
        let l = GroupLayout::new(1, 20, 16);
        let data: Vec<f32> = (0..20 * 16).map(|i| i as f32 + 1.0).collect();
        let g = l.gather_group(&data, 0, 1, 0);
        // x = 16..19 valid, 20..31 zero padded.
        assert_eq!(g[0][0], data[16 * 16]);
        assert_eq!(g[3][15], data[19 * 16 + 15]);
        assert_eq!(g[4], [0f32; 16]);
    }
}
