//! Tensor substrate: zero bitmaps, the 16x16 group layout (§3.4) and the
//! scheduled `(value, idx)` compressed form (§3.6).

pub mod bitmap;
pub mod layout;
pub mod scheduled;

pub use bitmap::TensorBitmap;
pub use layout::{transpose_group, GroupLayout};
pub use scheduled::{
    compress_one_side, compress_one_side_cached, decompress, ScheduledRow, ScheduledTensor,
};
