//! Packed zero bitmaps of NHWC tensors.
//!
//! The simulator never needs tensor *values* — only which elements are
//! zero. A [`TensorBitmap`] stores one bit per element (set = non-zero),
//! packed 16 channel-contiguous elements per `u16` word: exactly the
//! `AZ`/`BZ` zero vectors the staging buffers feed the hardware
//! scheduler, and exactly what the AOT train-step artifact returns from
//! the Pallas `zero_bitmap16` kernel.
//!
//! Fully-connected tensors are 2-D `(batch, features)`; they are stored
//! as `(n, 1, 1, c)`.

/// Zero bitmap of an `(n, h, w, c)` tensor, `c` a multiple of 16.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorBitmap {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    words: Vec<u16>,
}

impl TensorBitmap {
    pub fn c_blocks(&self) -> usize {
        self.c / 16
    }

    fn word_index(&self, n: usize, y: usize, x: usize, cb: usize) -> usize {
        ((n * self.h + y) * self.w + x) * self.c_blocks() + cb
    }

    /// Build from raw f32 values in NHWC order.
    pub fn from_f32(dims: (usize, usize, usize, usize), data: &[f32]) -> Self {
        let (n, h, w, c) = dims;
        assert_eq!(c % 16, 0, "channel dim must be a multiple of 16");
        assert_eq!(data.len(), n * h * w * c, "data/dims mismatch");
        let mut words = vec![0u16; n * h * w * c / 16];
        for (g, chunk) in data.chunks_exact(16).enumerate() {
            let mut word = 0u16;
            for (l, &v) in chunk.iter().enumerate() {
                if v != 0.0 {
                    word |= 1 << l;
                }
            }
            words[g] = word;
        }
        TensorBitmap { n, h, w, c, words }
    }

    /// Build from the packed int32 words produced by the Pallas
    /// `zero_bitmap16` kernel (one word per 16-channel group).
    pub fn from_words_i32(dims: (usize, usize, usize, usize), words: &[i32]) -> Self {
        let (n, h, w, c) = dims;
        assert_eq!(c % 16, 0, "channel dim must be a multiple of 16");
        assert_eq!(words.len(), n * h * w * c / 16, "word count mismatch");
        TensorBitmap {
            n,
            h,
            w,
            c,
            words: words.iter().map(|&v| v as u16).collect(),
        }
    }

    /// Build a 2-D `(batch, features)` bitmap (fully-connected tensors).
    pub fn from_f32_2d(dims: (usize, usize), data: &[f32]) -> Self {
        Self::from_f32((dims.0, 1, 1, dims.1), data)
    }

    /// Directly wrap pre-packed words.
    pub fn from_raw(dims: (usize, usize, usize, usize), words: Vec<u16>) -> Self {
        let (n, h, w, c) = dims;
        assert_eq!(c % 16, 0);
        assert_eq!(words.len(), n * h * w * c / 16);
        TensorBitmap { n, h, w, c, words }
    }

    /// Is element `(n, y, x, c)` non-zero?
    #[inline]
    pub fn bit(&self, n: usize, y: usize, x: usize, c: usize) -> bool {
        let word = self.words[self.word_index(n, y, x, c / 16)];
        word & (1 << (c % 16)) != 0
    }

    /// The 16-lane word for channel block `cb` at `(n, y, x)` — one
    /// staging-buffer row along the channel dimension.
    #[inline]
    pub fn lane_word(&self, n: usize, y: usize, x: usize, cb: usize) -> u16 {
        self.words[self.word_index(n, y, x, cb)]
    }

    /// Like [`Self::lane_word`] but returns 0 (all-zero) outside bounds —
    /// convolution halo handling.
    #[inline]
    pub fn lane_word_padded(&self, n: usize, y: isize, x: isize, cb: usize) -> u16 {
        if y < 0 || x < 0 || y as usize >= self.h || x as usize >= self.w {
            0
        } else {
            self.lane_word(n, y as usize, x as usize, cb)
        }
    }

    /// A lane word along the **row (x) dimension** for a fixed channel:
    /// bit `l` set iff element `(n, y, x0 + l, c)` is non-zero (used by
    /// the weight-gradient op where the reduction runs over space; this
    /// is the access pattern the §3.4 transposers exist to serve).
    pub fn lane_word_spatial(&self, n: usize, y: usize, x0: usize, c: usize) -> u16 {
        let mut word = 0u16;
        for l in 0..16 {
            let x = x0 + l;
            if x < self.w && self.bit(n, y, x, c) {
                word |= 1 << l;
            }
        }
        word
    }

    pub fn values(&self) -> u64 {
        (self.n * self.h * self.w * self.c) as u64
    }

    pub fn nonzeros(&self) -> u64 {
        self.words.iter().map(|w| w.count_ones() as u64).sum()
    }

    /// Fraction of non-zero elements.
    pub fn density(&self) -> f64 {
        if self.values() == 0 {
            0.0
        } else {
            self.nonzeros() as f64 / self.values() as f64
        }
    }

    /// Fraction of zero elements.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.density()
    }

    pub fn words(&self) -> &[u16] {
        &self.words
    }

    /// Serialize to JSON: dims plus the packed words as one hex string
    /// (4 lowercase hex digits per `u16` word) — the trace-artifact
    /// interchange form the serving layer loads once per model.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut hex = String::with_capacity(self.words.len() * 4);
        for w in &self.words {
            hex.push_str(&format!("{w:04x}"));
        }
        let mut m = std::collections::BTreeMap::new();
        m.insert(
            "dims".to_string(),
            Json::Arr(
                [self.n, self.h, self.w, self.c]
                    .iter()
                    .map(|&d| Json::Num(d as f64))
                    .collect(),
            ),
        );
        m.insert("words".to_string(), Json::Str(hex));
        Json::Obj(m)
    }

    /// Reconstruct from [`Self::to_json`]'s form. `None` on any shape
    /// or encoding mismatch.
    pub fn from_json(j: &crate::util::json::Json) -> Option<TensorBitmap> {
        let dims = j.get("dims")?.as_usize_vec()?;
        let &[n, h, w, c] = dims.as_slice() else { return None };
        if c % 16 != 0 {
            return None;
        }
        let hex = j.get("words")?.as_str()?;
        // Checked product: crafted dims must not wrap in release (and
        // pass the length check on 0 == 0) or panic in debug — a bad
        // document reads as None, never as an inconsistent bitmap.
        let bits = n
            .checked_mul(h)
            .and_then(|v| v.checked_mul(w))
            .and_then(|v| v.checked_mul(c))?;
        if hex.len() % 4 != 0 || hex.len() / 4 != bits / 16 {
            return None;
        }
        let mut words = Vec::with_capacity(hex.len() / 4);
        for i in (0..hex.len()).step_by(4) {
            words.push(u16::from_str_radix(hex.get(i..i + 4)?, 16).ok()?);
        }
        Some(TensorBitmap { n, h, w, c, words })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_f32_roundtrip() {
        let mut data = vec![0f32; 2 * 2 * 2 * 16];
        data[0] = 1.0; // (0,0,0,0)
        data[17] = -2.0; // (0,0,0,17) -> second block? c=16 so (0,0,1,1)
        let bm = TensorBitmap::from_f32((2, 2, 2, 16), &data);
        assert!(bm.bit(0, 0, 0, 0));
        assert!(!bm.bit(0, 0, 0, 1));
        assert!(bm.bit(0, 0, 1, 1));
        assert_eq!(bm.nonzeros(), 2);
        assert!((bm.density() - 2.0 / 128.0).abs() < 1e-12);
    }

    #[test]
    fn lane_word_padded_halo() {
        let data = vec![1f32; 1 * 2 * 2 * 16];
        let bm = TensorBitmap::from_f32((1, 2, 2, 16), &data);
        assert_eq!(bm.lane_word_padded(0, -1, 0, 0), 0);
        assert_eq!(bm.lane_word_padded(0, 0, 2, 0), 0);
        assert_eq!(bm.lane_word_padded(0, 1, 1, 0), 0xFFFF);
    }

    #[test]
    fn spatial_lane_word() {
        // 1x1x20x16 tensor; nonzero at x in {0, 3, 18} for channel 5.
        let mut data = vec![0f32; 20 * 16];
        for x in [0usize, 3, 18] {
            data[x * 16 + 5] = 1.0;
        }
        let bm = TensorBitmap::from_f32((1, 1, 20, 16), &data);
        assert_eq!(bm.lane_word_spatial(0, 0, 0, 5), (1 << 0) | (1 << 3));
        assert_eq!(bm.lane_word_spatial(0, 0, 16, 5), 1 << 2);
        // Out-of-range lanes are zero (group at the tensor edge).
        assert_eq!(bm.lane_word_spatial(0, 0, 16, 4), 0);
    }

    #[test]
    fn from_words_matches_from_f32() {
        let data: Vec<f32> = (0..64).map(|i| if i % 3 == 0 { 0.0 } else { 1.0 }).collect();
        let bm1 = TensorBitmap::from_f32((1, 1, 4, 16), &data);
        let words: Vec<i32> = bm1.words().iter().map(|&w| w as i32).collect();
        let bm2 = TensorBitmap::from_words_i32((1, 1, 4, 16), &words);
        assert_eq!(bm1, bm2);
    }

    #[test]
    fn json_round_trip_preserves_every_word() {
        let data: Vec<f32> = (0..256).map(|i| if i % 5 == 0 { 0.0 } else { 0.5 }).collect();
        let bm = TensorBitmap::from_f32((2, 2, 2, 32), &data);
        let j = bm.to_json();
        let text = j.render_pretty();
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        let back = TensorBitmap::from_json(&parsed).expect("bitmap json reconstructs");
        assert_eq!(back, bm);
        // Corruption is rejected, not mis-read.
        let mut bad = bm.to_json();
        if let crate::util::json::Json::Obj(m) = &mut bad {
            m.insert("words".to_string(), crate::util::json::Json::Str("zz".into()));
        }
        assert!(TensorBitmap::from_json(&bad).is_none());
        // Overflow-crafted dims (n*h*w*c wraps to 0 with unchecked
        // arithmetic) must read as None, not as an empty-word bitmap
        // with huge dims.
        let overflow = crate::util::json::Json::parse(
            r#"{"dims":[1073741824,1073741824,16,16],"words":""}"#,
        )
        .unwrap();
        assert!(TensorBitmap::from_json(&overflow).is_none());
    }

    #[test]
    fn fc_tensor_as_2d() {
        let data = vec![1f32; 4 * 32];
        let bm = TensorBitmap::from_f32_2d((4, 32), &data);
        assert_eq!(bm.n, 4);
        assert_eq!(bm.c, 32);
        assert_eq!(bm.density(), 1.0);
    }
}
