//! Sparsity traces: where the simulator's zero bitmaps come from.
//!
//! Two sources, mirroring the paper's methodology (§4 "Collecting
//! Traces") under this environment's substitutions (DESIGN.md):
//!
//! * [`capture`] — **real** traces: the coordinator runs the AOT-compiled
//!   train step and converts the returned per-layer bitmap words into
//!   [`crate::tensor::TensorBitmap`]s.
//! * [`synthetic`] — synthetic tensors: uniformly random sparsity
//!   (exactly the paper's Fig. 20 experiment) and the *clustered*
//!   variant modelling the §4.4 observation that non-zeros concentrate
//!   in a subset of 2-D feature maps.
//! * [`profiles`] — per-model, per-epoch sparsity profiles for the nine
//!   paper workloads, calibrated to the paper's reported anchors.

pub mod capture;
pub mod profiles;
pub mod synthetic;

pub use profiles::{ModelProfile, PHASES};
pub use synthetic::{clustered_bitmap, random_bitmap};
